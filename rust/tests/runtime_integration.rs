//! Integration tests over the PJRT runtime + AOT artifacts: the L1/L2/L3
//! consistency checks. Require `make artifacts` to have run (they are
//! skipped with a message if artifacts/ is missing).

use phub::coordinator::{KeyTable, NesterovSgd, PHubServer};
use phub::coordinator::server::ServerConfig;
use phub::prop::Rng;
use phub::runtime::{self, Runtime};
use std::sync::Arc;

fn runtime() -> Option<Runtime> {
    let dir = runtime::default_artifacts_dir();
    if !dir.join("manifest.json").exists() {
        eprintln!("skipping: artifacts not built (run `make artifacts`)");
        return None;
    }
    Some(Runtime::cpu(dir).expect("PJRT client"))
}

#[test]
fn manifest_is_consistent() {
    let Some(rt) = runtime() else { return };
    let m = rt.manifest().unwrap();
    assert!(m.param_count > 0);
    assert!(m.padded_size >= m.param_count);
    assert_eq!(m.padded_size % m.chunk_elems, 0);
    let sum: usize = m.keys.iter().map(|(_, _, l)| l).sum();
    assert_eq!(sum, m.param_count);
    // Offsets are contiguous in flat order.
    let mut off = 0;
    for (_, o, l) in &m.keys {
        assert_eq!(*o, off);
        off += l;
    }
    let params = rt.initial_params().unwrap();
    assert_eq!(params.len(), m.padded_size);
    // Pad region is zero.
    assert!(params[m.param_count..].iter().all(|&x| x == 0.0));
}

#[test]
fn grad_step_executes_and_is_deterministic() {
    let Some(rt) = runtime() else { return };
    let m = rt.manifest().unwrap();
    let f = rt.load("grad_step").unwrap();
    let params = rt.initial_params().unwrap();
    let mut rng = Rng::new(1);
    let toks: Vec<i32> = (0..m.batch * (m.seq_len + 1))
        .map(|_| rng.usize_in(0, m.vocab) as i32)
        .collect();
    let call = || {
        let p = runtime::literal_f32(&params, &[m.padded_size as i64]).unwrap();
        let t = runtime::literal_i32(&toks, &[m.batch as i64, (m.seq_len + 1) as i64]).unwrap();
        let out = f.call(&[p, t]).unwrap();
        let loss = runtime::to_scalar_f32(&out[0]).unwrap();
        let grads = runtime::to_vec_f32(&out[1]).unwrap();
        (loss, grads)
    };
    let (l1, g1) = call();
    let (l2, g2) = call();
    assert_eq!(l1, l2, "deterministic loss");
    assert_eq!(g1, g2, "deterministic grads");
    // Sane values: loss near ln(vocab) at init, finite gradient.
    assert!(l1 > 1.0 && l1 < 10.0, "loss {l1}");
    assert!(g1.iter().all(|x| x.is_finite()));
    let norm: f32 = g1.iter().map(|x| x * x).sum::<f32>().sqrt();
    assert!(norm > 1e-4, "gradient is not degenerate: {norm}");
    // Pad region of the gradient is zeroed (PS never folds garbage).
    assert!(g1[m.param_count..].iter().all(|&x| x == 0.0));
}

#[test]
fn eval_loss_matches_grad_step_loss() {
    let Some(rt) = runtime() else { return };
    let m = rt.manifest().unwrap();
    let gs = rt.load("grad_step").unwrap();
    let ev = rt.load("eval_loss").unwrap();
    let params = rt.initial_params().unwrap();
    let mut rng = Rng::new(7);
    let toks: Vec<i32> = (0..m.batch * (m.seq_len + 1))
        .map(|_| rng.usize_in(0, m.vocab) as i32)
        .collect();
    let p = runtime::literal_f32(&params, &[m.padded_size as i64]).unwrap();
    let t = runtime::literal_i32(&toks, &[m.batch as i64, (m.seq_len + 1) as i64]).unwrap();
    let l_grad = runtime::to_scalar_f32(&gs.call(&[p, t]).unwrap()[0]).unwrap();
    let p = runtime::literal_f32(&params, &[m.padded_size as i64]).unwrap();
    let t = runtime::literal_i32(&toks, &[m.batch as i64, (m.seq_len + 1) as i64]).unwrap();
    let l_eval = runtime::to_scalar_f32(&ev.call(&[p, t]).unwrap()[0]).unwrap();
    assert!((l_grad - l_eval).abs() < 1e-5, "{l_grad} vs {l_eval}");
}

/// Cross-layer consistency: the L1 Pallas agg_opt artifact computes the
/// SAME update as the Rust PHub server (tall aggregation + NesterovSgd).
#[test]
fn agg_opt_artifact_matches_live_server() {
    let Some(rt) = runtime() else { return };
    let m = rt.manifest().unwrap();
    let agg = rt.load("agg_opt").unwrap();
    let k = m.padded_size;
    let w = m.n_workers;
    let (lr, mu) = (0.05f32, 0.9f32);
    let mut rng = Rng::new(42);
    let grads: Vec<Vec<f32>> = (0..w).map(|_| rng.vec_f32(k, 0.5)).collect();
    let params = rt.initial_params().unwrap();
    let mom = vec![0.0f32; k];

    // L1 kernel path (one fused call over all workers).
    let flat_grads: Vec<f32> = grads.iter().flatten().copied().collect();
    let out = agg
        .call(&[
            runtime::literal_f32(&flat_grads, &[w as i64, k as i64]).unwrap(),
            runtime::literal_f32(&params, &[k as i64]).unwrap(),
            runtime::literal_f32(&mom, &[k as i64]).unwrap(),
            runtime::literal_scalar(lr),
            runtime::literal_scalar(mu),
        ])
        .unwrap();
    let kernel_params = runtime::to_vec_f32(&out[0]).unwrap();
    let kernel_mom = runtime::to_vec_f32(&out[1]).unwrap();

    // L3 server path.
    let server = PHubServer::start(ServerConfig::cores(3));
    let job = server.init_job(
        KeyTable::flat(k, m.chunk_elems),
        &params,
        Arc::new(NesterovSgd { lr, momentum: mu }),
        w,
    );
    let mut handles: Vec<_> = (0..w).map(|i| server.worker(job, i)).collect();
    let models: Vec<Vec<f32>> = std::thread::scope(|s| {
        let joins: Vec<_> = handles
            .iter_mut()
            .zip(&grads)
            .map(|(h, g)| s.spawn(move || h.push_pull(g)))
            .collect();
        joins.into_iter().map(|j| j.join().unwrap()).collect()
    });
    let server_params = &models[0];

    let mut max_err = 0.0f32;
    for (a, b) in kernel_params.iter().zip(server_params) {
        max_err = max_err.max((a - b).abs());
    }
    assert!(max_err < 1e-5, "L1 kernel vs L3 server drift: {max_err}");
    assert!(kernel_mom.iter().all(|x| x.is_finite()));
    PHubServer::shutdown(server);
}

/// Mini end-to-end: a few live training steps through PJRT + PHub reduce
/// the loss (the full 200-step run is examples/train_e2e.rs).
#[test]
fn live_training_loss_decreases() {
    let Some(_) = runtime() else { return };
    let dir = runtime::default_artifacts_dir();
    let report = phub::e2e::train(&dir, 2, 30, 2, 0.05, 0.9, false).expect("train");
    let (head, tail) = report.mean_loss_head_tail(5);
    assert!(
        tail < head,
        "loss should decrease: {head} -> {tail} ({:?})",
        report.losses
    );
}

/// The quant2bit artifact executes and satisfies the quantizer contract.
#[test]
fn quant_artifact_contract() {
    let Some(rt) = runtime() else { return };
    let m = rt.manifest().unwrap();
    let q = rt.load("quant2bit").unwrap();
    let k = m.padded_size;
    let mut rng = Rng::new(9);
    let grad = rng.vec_f32(k, 1.0);
    let residual = vec![0.0f32; k];
    let t = 0.5f32;
    let out = q
        .call(&[
            runtime::literal_f32(&grad, &[k as i64]).unwrap(),
            runtime::literal_f32(&residual, &[k as i64]).unwrap(),
            runtime::literal_scalar(t),
        ])
        .unwrap();
    let levels = runtime::to_vec_f32(&out[0]).unwrap();
    let new_r = runtime::to_vec_f32(&out[1]).unwrap();
    let dq = runtime::to_vec_f32(&out[2]).unwrap();
    for i in 0..k {
        assert!(
            levels[i] == -1.0 || levels[i] == 0.0 || levels[i] == 1.0,
            "levels[{i}]={}",
            levels[i]
        );
        // Error feedback conserves the signal.
        assert!((dq[i] + new_r[i] - grad[i]).abs() < 1e-5);
    }
}
