//! Integration tests over the TCP transport: chunk-streamed exchange
//! correctness across chunk geometries, leader robustness under hostile
//! clients, and — the tentpole — mid-round worker death with rollback and
//! successor recovery. The in-module tests in `transport.rs` cover
//! single-feature behavior; these exercise multi-worker, multi-round
//! combinations end-to-end.

#![allow(clippy::useless_vec)]

use std::io::{BufReader, BufWriter, Read, Write};
use std::net::TcpStream;

use phub::config::DeadlineConfig;
use phub::coordinator::compress::ChunkQuantizer;
use phub::coordinator::server::ServerConfig;
use phub::coordinator::transport::{JobSpec, RelayConfig, TcpLeader, TcpWorker};
use phub::coordinator::wire::{self, Frame, Op};

fn spec(model: u64, chunk: u64, workers: u32) -> JobSpec {
    JobSpec {
        model_elems: model,
        chunk_elems: chunk,
        n_workers: workers,
        lr: 0.25,
        momentum: 0.9,
    }
}

/// Deterministic per-worker, per-round gradient.
fn grad(n: usize, w: usize, round: usize) -> Vec<f32> {
    (0..n)
        .map(|i| (w as f32 - 0.5) * 0.75 + (round as f32 + 1.0) * 0.125 + i as f32 * 0.01)
        .collect()
}

/// Run `rounds` synchronous rounds with 2 workers, returning the final
/// model (asserting both workers agree bitwise). Gradients come from
/// `grad(n, slot, round)`, keyed by the *leader-assigned* slot so an
/// interrupted run and its clean twin feed identical data per seat.
fn run_two_workers(
    addr: std::net::SocketAddr,
    job: u32,
    s: JobSpec,
    rounds: usize,
    quant: Option<f32>,
) -> Vec<f32> {
    let n = s.model_elems as usize;
    let joins: Vec<_> = (0..2usize)
        .map(|_| {
            std::thread::spawn(move || {
                let mut worker = TcpWorker::connect(addr, job, s).unwrap();
                let slot = worker.slot as usize;
                let mut model = Vec::new();
                for r in 0..rounds {
                    let g = grad(n, slot, r);
                    model = match quant {
                        Some(t) => worker.push_pull_quant(&g, t).unwrap(),
                        None => worker.push_pull(&g).unwrap(),
                    };
                }
                worker.bye();
                model
            })
        })
        .collect();
    let models: Vec<Vec<f32>> = joins.into_iter().map(|j| j.join().unwrap()).collect();
    assert_eq!(models[0], models[1], "synchronous workers agree bitwise");
    models.into_iter().next().unwrap()
}

/// Chunk geometry must be invisible to training: the same job run with a
/// multi-chunk ragged layout and with one whole-model chunk produces
/// bit-identical models, dense and compressed (aggregation and per-chunk
/// error feedback are both elementwise).
#[test]
fn chunk_geometry_does_not_change_the_bits() {
    let leader = TcpLeader::serve("127.0.0.1:0", ServerConfig::cores(3)).unwrap();
    let addr = leader.local_addr();
    // 300 elems at chunk 64 -> 5 chunks including a ragged 44-elem tail.
    let ragged = spec(300, 64, 2);
    let single = spec(300, 300, 2);
    let dense_r = run_two_workers(addr, 100, ragged, 4, None);
    let dense_s = run_two_workers(addr, 101, single, 4, None);
    assert_eq!(dense_r, dense_s, "dense: chunking must not change bits");

    let quant_r = run_two_workers(addr, 102, ragged, 6, Some(0.05));
    let quant_s = run_two_workers(addr, 103, single, 6, Some(0.05));
    assert_eq!(quant_r, quant_s, "quant: chunking must not change bits");
}

/// Streamed exchange at a worker count and chunk count big enough to get
/// real interleaving, checked against exact analytic SGD (worker grads are
/// small integers, so the f32 aggregation is exact in any order).
#[test]
fn four_workers_many_chunks_streamed_exact() {
    let leader = TcpLeader::serve("127.0.0.1:0", ServerConfig::cores(4)).unwrap();
    let addr = leader.local_addr();
    let n = 1000usize;
    let rounds = 3usize;
    let s = JobSpec {
        model_elems: n as u64,
        chunk_elems: 64, // 16 chunks
        n_workers: 4,
        lr: 0.5,
        momentum: 0.0,
    };
    let joins: Vec<_> = (0..4usize)
        .map(|w| {
            std::thread::spawn(move || {
                let mut worker = TcpWorker::connect(addr, 9, s).unwrap();
                let g = vec![w as f32; n]; // mean = 1.5 exactly
                let mut model = Vec::new();
                for _ in 0..rounds {
                    model = worker.push_pull(&g).unwrap();
                }
                worker.bye();
                model
            })
        })
        .collect();
    for j in joins {
        let model = j.join().unwrap();
        let expect = -0.5 * 1.5 * rounds as f32;
        for x in model {
            assert!((x - expect).abs() < 1e-6, "{x} vs {expect}");
        }
    }
}

/// A hostile `Hello` (spec that would trip the server's asserts) must be
/// rejected at the edge while other tenants keep training — the
/// poisoned-lock DoS regression, exercised across a live job.
#[test]
fn hostile_hello_while_other_tenants_train() {
    let leader = TcpLeader::serve("127.0.0.1:0", ServerConfig::cores(2)).unwrap();
    let addr = leader.local_addr();
    // A healthy tenant in the middle of its run.
    let s_ok = spec(128, 64, 1);
    let mut w = TcpWorker::connect(addr, 50, s_ok).unwrap();
    let m1 = w.push_pull(&vec![1.0; 128]).unwrap();

    // Hostile rendezvous attempts, raw on the socket (the client-side
    // validation in `TcpWorker::connect` would refuse to send these).
    for bad in [
        spec(128, 64, 0),   // zero workers
        spec(128, 64, 100), // > 64 workers
        spec(0, 64, 1),     // empty model
        spec(64, 0, 1),     // empty chunks
        spec(64, 128, 1),   // chunk > model
    ] {
        let mut stream = TcpStream::connect(addr).unwrap();
        let mut wr = BufWriter::new(stream.try_clone().unwrap());
        let mut payload = bad.to_bytes();
        wire::push_proto_version(&mut payload, wire::PROTO_EPOCH_TAGGED);
        wire::write_frame(
            &mut wr,
            &Frame {
                op: Op::Hello,
                job: 60,
                worker: 0,
                payload,
            },
        )
        .unwrap();
        // Leader must close the connection (rejection fully processed).
        let mut buf = [0u8; 64];
        loop {
            match stream.read(&mut buf) {
                Ok(0) | Err(_) => break,
                Ok(_) => {}
            }
        }
    }

    // The in-flight tenant continues, and new tenants are admitted.
    let m2 = w.push_pull(&vec![1.0; 128]).unwrap();
    assert!(m2[0] < m1[0], "training still progressing");
    w.bye();
    let mut w2 = TcpWorker::connect(addr, 61, spec(32, 32, 1)).unwrap();
    assert_eq!(w2.push_pull(&vec![0.0; 32]).unwrap().len(), 32);
    w2.bye();
}

/// A raw worker for failure injection: speaks just enough of the wire
/// protocol to run clean rounds and then die at a chosen point.
struct RawWorker {
    reader: BufReader<TcpStream>,
    writer: BufWriter<TcpStream>,
    job: u32,
    slot: u32,
    epoch: u32,
    chunks: Vec<(usize, usize)>, // (offset, len) per chunk
}

impl RawWorker {
    fn connect(addr: std::net::SocketAddr, job: u32, s: JobSpec) -> RawWorker {
        let stream = TcpStream::connect(addr).unwrap();
        let reader = BufReader::new(stream.try_clone().unwrap());
        let mut w = RawWorker {
            reader,
            writer: BufWriter::new(stream),
            job,
            slot: 0,
            epoch: 0,
            chunks: (0..s.model_elems)
                .step_by(s.chunk_elems as usize)
                .map(|o| {
                    (
                        o as usize,
                        (s.chunk_elems.min(s.model_elems - o)) as usize,
                    )
                })
                .collect(),
        };
        let mut payload = s.to_bytes();
        wire::push_proto_version(&mut payload, wire::PROTO_EPOCH_TAGGED);
        wire::write_frame(
            &mut w.writer,
            &Frame {
                op: Op::Hello,
                job,
                worker: 0,
                payload,
            },
        )
        .unwrap();
        let welcome = wire::read_frame(&mut w.reader).unwrap();
        assert_eq!(welcome.op, Op::Welcome);
        w.slot = welcome.worker;
        w.epoch = u32::from_le_bytes(welcome.payload[4..8].try_into().unwrap());
        w
    }

    /// Push chunk `c` of `g` (dense or pre-encoded bytes).
    fn push_chunk_bytes(&mut self, c: usize, bytes: &[u8], op: Op) {
        let (off, _) = self.chunks[c];
        wire::write_chunk_frame_buffered(
            &mut self.writer,
            op,
            self.job,
            self.slot,
            c as u32,
            self.epoch,
            off as u64,
            bytes,
        )
        .unwrap();
        self.writer.flush().unwrap();
    }

    /// One full clean dense round: push every chunk, read every reply.
    fn full_round(&mut self, g: &[f32]) {
        for c in 0..self.chunks.len() {
            let (off, len) = self.chunks[c];
            self.push_chunk_bytes(c, &wire::f32s_to_bytes(&g[off..off + len]), Op::PushChunk);
        }
        let mut got = 0;
        while got < self.chunks.len() {
            let f = wire::read_frame(&mut self.reader).unwrap();
            assert_eq!(f.op, Op::ModelChunk, "clean round expects model chunks");
            got += 1;
        }
    }
}

/// The tentpole's acceptance bar: a worker killed *mid-round* (after a
/// clean first round, partway through its second) no longer wedges the
/// job. The leader rolls the round back, the survivor transparently
/// replays it, a successor takes the dead worker's seat and finishes
/// training — and the final parameters are bit-identical to a run that
/// was never interrupted.
#[test]
fn worker_killed_mid_round_successor_recovers_bit_identical() {
    let leader = TcpLeader::serve("127.0.0.1:0", ServerConfig::cores(2)).unwrap();
    let addr = leader.local_addr();
    let n = 256usize;
    let s = spec(n as u64, 64, 2); // 4 chunks
    let rounds = 3usize;
    let job = 200u32;

    // Victim connects first (slot 0), survivor second (slot 1).
    let mut victim = RawWorker::connect(addr, job, s);
    assert_eq!(victim.slot, 0);
    let survivor = std::thread::spawn(move || {
        let mut w = TcpWorker::connect(addr, job, s).unwrap();
        assert_eq!(w.slot, 1);
        let mut model = Vec::new();
        for r in 0..rounds {
            // Round 1 is interrupted under this worker's feet: push_pull
            // sees a RollbackRound frame and replays internally.
            model = w.push_pull(&grad(n, 1, r)).unwrap();
        }
        w.bye();
        model
    });

    // Victim: clean round 0, then die after pushing 1 of 4 chunks of
    // round 1.
    victim.full_round(&grad(n, 0, 0));
    let g1 = grad(n, 0, 1);
    let (off, len) = victim.chunks[0];
    victim.push_chunk_bytes(0, &wire::f32s_to_bytes(&g1[off..off + len]), Op::PushChunk);
    drop(victim); // no Bye: a crash mid-round

    // Successor: takes slot 0 once the leader has noticed the death and
    // rolled the round back, then finishes rounds 1..3 with the same
    // per-seat gradients the victim would have pushed.
    let deadline = std::time::Instant::now() + std::time::Duration::from_secs(10);
    let mut successor = loop {
        match TcpWorker::connect(addr, job, s) {
            Ok(w) => break w,
            Err(_) => {
                assert!(
                    std::time::Instant::now() < deadline,
                    "dead worker's slot never recycled"
                );
                std::thread::sleep(std::time::Duration::from_millis(20));
            }
        }
    };
    assert_eq!(successor.slot, 0, "successor takes the dead worker's seat");
    assert_eq!(successor.epoch(), 1, "welcome carries the bumped epoch");
    assert_eq!(
        successor.rounds_done(),
        1,
        "welcome tells the successor where its predecessor left off"
    );
    let mut succ_model = Vec::new();
    for r in successor.rounds_done() as usize..rounds {
        succ_model = successor.push_pull(&grad(n, 0, r)).unwrap();
    }
    successor.bye();
    let surv_model = survivor.join().unwrap();
    assert_eq!(surv_model, succ_model, "survivor and successor agree");

    // Uninterrupted twin job: identical gradients, no failure.
    let clean = run_two_workers(addr, 201, s, rounds, None);
    assert_eq!(
        surv_model, clean,
        "recovered run must be bit-identical to the uninterrupted run"
    );
}

/// Quantized recovery: the survivor's round is rolled back and replayed
/// *without re-quantizing* — its per-chunk error-feedback residuals
/// advance exactly once per round — and the successor starts from fresh
/// residuals exactly like the worker it replaces would have at round 0.
/// End state must be bit-identical to an uninterrupted compressed run.
#[test]
fn quantized_worker_killed_mid_round_recovers_bit_identical() {
    let leader = TcpLeader::serve("127.0.0.1:0", ServerConfig::cores(2)).unwrap();
    let addr = leader.local_addr();
    let n = 128usize;
    let s = spec(n as u64, 64, 2); // 2 chunks
    let rounds = 4usize;
    let t = 0.05f32;
    let job = 210u32;
    // Sub-threshold gradients: progress exists only through error
    // feedback, so any double-advanced residual shows up in the bits.
    let qgrad = move |slot: usize, r: usize| -> Vec<f32> {
        (0..n)
            .map(|i| {
                0.6 * t * (1.0 + 0.1 * slot as f32) + 0.001 * (i % 7) as f32 + 0.002 * r as f32
            })
            .collect()
    };

    // Victim (slot 0): pushes one *quantized* chunk of round 0, dies.
    let mut victim = RawWorker::connect(addr, job, s);
    assert_eq!(victim.slot, 0);
    let survivor = std::thread::spawn(move || {
        let mut w = TcpWorker::connect(addr, job, s).unwrap();
        assert_eq!(w.slot, 1);
        let mut model = Vec::new();
        for r in 0..rounds {
            model = w.push_pull_quant(&qgrad(1, r), t).unwrap();
        }
        w.bye();
        model
    });
    let g0 = qgrad(0, 0);
    let (off, len) = victim.chunks[0];
    let mut vq = ChunkQuantizer::new(&[len, len], t);
    let bytes = vq.quantize_chunk(0, &g0[off..off + len]).to_bytes();
    victim.push_chunk_bytes(0, &bytes, Op::PushChunkQuant);
    drop(victim);

    // Successor restarts seat 0 from round 0 with fresh residuals — the
    // same state the dead worker had when it first quantized round 0.
    let deadline = std::time::Instant::now() + std::time::Duration::from_secs(10);
    let mut successor = loop {
        match TcpWorker::connect(addr, job, s) {
            Ok(w) => break w,
            Err(_) => {
                assert!(
                    std::time::Instant::now() < deadline,
                    "dead worker's slot never recycled"
                );
                std::thread::sleep(std::time::Duration::from_millis(20));
            }
        }
    };
    assert_eq!(successor.slot, 0);
    let mut succ_model = Vec::new();
    for r in 0..rounds {
        succ_model = successor.push_pull_quant(&qgrad(0, r), t).unwrap();
    }
    successor.bye();
    let surv_model = survivor.join().unwrap();
    assert_eq!(surv_model, succ_model, "survivor and successor agree");

    // Uninterrupted compressed twin with the same per-seat gradients.
    let clean_q = {
        let job = 212u32;
        let joins: Vec<_> = (0..2usize)
            .map(|_| {
                std::thread::spawn(move || {
                    let mut w = TcpWorker::connect(addr, job, s).unwrap();
                    let slot = w.slot as usize;
                    let mut model = Vec::new();
                    for r in 0..rounds {
                        model = w.push_pull_quant(&qgrad(slot, r), t).unwrap();
                    }
                    w.bye();
                    model
                })
            })
            .collect();
        let models: Vec<Vec<f32>> = joins.into_iter().map(|j| j.join().unwrap()).collect();
        assert_eq!(models[0], models[1]);
        models.into_iter().next().unwrap()
    };
    assert_eq!(
        surv_model, clean_q,
        "recovered compressed run must be bit-identical to the clean run"
    );
}

// ---------------------------------------------------------------------------
// Hierarchical (leader-of-leaders) deployments
// ---------------------------------------------------------------------------

/// A spec whose hyperparameters are powers of two: with dyadic gradients
/// (multiples of 2^-k, bounded) every sum, mean, and optimizer product is
/// exact in f32 under *any* association, so a flat 4-worker run and a
/// 2-rack × 2-worker two-level run must agree bit-for-bit.
fn dyadic_spec(model: u64, chunk: u64, workers: u32) -> JobSpec {
    JobSpec {
        model_elems: model,
        chunk_elems: chunk,
        n_workers: workers,
        lr: 0.25,
        momentum: 0.5,
    }
}

/// Dyadic per-seat, per-round gradient: worker `w` of the *global* 4-seat
/// layout (rack·2 + rack-local slot).
fn dyadic_grad(n: usize, w: usize, round: usize) -> Vec<f32> {
    (0..n)
        .map(|i| (w as f32 - 1.5) * 0.5 + (i % 16) as f32 * 0.125 + round as f32 * 0.25)
        .collect()
}

/// Run `k` leaf workers against `addr`, gradients keyed by
/// `base + leader-assigned slot` so racks map onto disjoint global seats.
/// Returns the final model (asserting all `k` agree bitwise).
fn run_leaves(
    addr: std::net::SocketAddr,
    job: u32,
    s: JobSpec,
    rounds: usize,
    quant: Option<f32>,
    base: usize,
) -> Vec<f32> {
    let n = s.model_elems as usize;
    let joins: Vec<_> = (0..s.n_workers as usize)
        .map(|_| {
            std::thread::spawn(move || {
                let mut w = TcpWorker::connect(addr, job, s).unwrap();
                let seat = base + w.slot as usize;
                let mut model = Vec::new();
                for r in 0..rounds {
                    let g = dyadic_grad(n, seat, r);
                    model = match quant {
                        Some(t) => w.push_pull_quant(&g, t).unwrap(),
                        None => w.push_pull(&g).unwrap(),
                    };
                }
                w.bye();
                model
            })
        })
        .collect();
    let models: Vec<Vec<f32>> = joins.into_iter().map(|j| j.join().unwrap()).collect();
    for m in &models[1..] {
        assert_eq!(&models[0], m, "leaf workers agree bitwise");
    }
    models.into_iter().next().unwrap()
}

/// The hierarchy acceptance bar: 2 racks × 2 workers through two
/// `serve_relay` leaders and one root produce the *same bits* as 4
/// workers on a flat single leader — dense and quantized. The relays
/// forward raw rack sums with an aggregation weight of 2, so the root's
/// mean divides by 4 leaf workers exactly like the flat leader does.
#[test]
fn two_level_two_racks_bit_identical_to_flat() {
    let n = 192u64;
    let rounds = 3usize;
    let rack_spec = dyadic_spec(n, 48, 2); // 4 chunks per rack job

    for quant in [None, Some(0.0625f32)] {
        let flat_leader = TcpLeader::serve("127.0.0.1:0", ServerConfig::cores(2)).unwrap();
        let flat = run_leaves(
            flat_leader.local_addr(),
            300,
            dyadic_spec(n, 48, 4),
            rounds,
            quant,
            0,
        );

        let root = TcpLeader::serve("127.0.0.1:0", ServerConfig::cores(2)).unwrap();
        let parent = root.local_addr().to_string();
        let racks: Vec<_> = (0..2)
            .map(|_| {
                TcpLeader::serve_relay(
                    "127.0.0.1:0",
                    ServerConfig::cores(2),
                    RelayConfig {
                        parent: parent.clone(),
                        racks: 2,
                    },
                )
                .unwrap()
            })
            .collect();
        // Both racks register the same wire job so their uplinks meet in
        // one root job; leaf seats are rack·2 + rack-local slot.
        let joins: Vec<_> = racks
            .iter()
            .enumerate()
            .map(|(ri, rack)| {
                let addr = rack.local_addr();
                std::thread::spawn(move || run_leaves(addr, 300, rack_spec, rounds, quant, ri * 2))
            })
            .collect();
        let rack_models: Vec<Vec<f32>> = joins.into_iter().map(|j| j.join().unwrap()).collect();
        for (ri, m) in rack_models.iter().enumerate() {
            assert_eq!(
                &flat, m,
                "rack {ri} (quant={quant:?}): two-level must be bit-identical to flat"
            );
        }
    }
}

/// Recovery composes across levels: a worker killed mid-round in rack A
/// rewinds *only* rack A — rack B's workers never see an epoch bump and
/// the root's round is never rolled back (rack A's uplink connection
/// stays alive throughout). The recovered two-level run is still
/// bit-identical to an uninterrupted flat run.
#[test]
fn worker_death_in_one_rack_rewinds_only_that_rack() {
    let n = 192usize;
    let rounds = 3usize;
    let rack_spec = dyadic_spec(n as u64, 48, 2); // 4 chunks
    let job = 310u32;

    let root = TcpLeader::serve("127.0.0.1:0", ServerConfig::cores(2)).unwrap();
    let parent = root.local_addr().to_string();
    let mk_rack = |parent: &str| {
        TcpLeader::serve_relay(
            "127.0.0.1:0",
            ServerConfig::cores(2),
            RelayConfig {
                parent: parent.to_string(),
                racks: 2,
            },
        )
        .unwrap()
    };
    let rack_a = mk_rack(&parent);
    let rack_b = mk_rack(&parent);
    let addr_a = rack_a.local_addr();
    let addr_b = rack_b.local_addr();

    // Rack A: victim takes slot 0 first, then the survivor (slot 1).
    let mut victim = RawWorker::connect(addr_a, job, rack_spec);
    assert_eq!(victim.slot, 0);
    let survivor = std::thread::spawn(move || {
        let mut w = TcpWorker::connect(addr_a, job, rack_spec).unwrap();
        assert_eq!(w.slot, 1);
        let mut model = Vec::new();
        for r in 0..rounds {
            model = w.push_pull(&dyadic_grad(n, 1, r)).unwrap();
        }
        let epoch = w.epoch();
        w.bye();
        (model, epoch)
    });
    // Rack B: two clean workers on global seats 2 and 3.
    let rack_b_run = std::thread::spawn(move || {
        let joins: Vec<_> = (0..2)
            .map(|_| {
                std::thread::spawn(move || {
                    let mut w = TcpWorker::connect(addr_b, job, rack_spec).unwrap();
                    let seat = 2 + w.slot as usize;
                    let mut model = Vec::new();
                    for r in 0..rounds {
                        model = w.push_pull(&dyadic_grad(n, seat, r)).unwrap();
                    }
                    let epoch = w.epoch();
                    w.bye();
                    (model, epoch)
                })
            })
            .collect();
        let out: Vec<(Vec<f32>, u32)> = joins.into_iter().map(|j| j.join().unwrap()).collect();
        assert_eq!(out[0].0, out[1].0, "rack B workers agree bitwise");
        out.into_iter().next().unwrap()
    });

    // Victim: clean round 0, then die after 1 of 4 chunks of round 1.
    victim.full_round(&dyadic_grad(n, 0, 0));
    let g1 = dyadic_grad(n, 0, 1);
    let (off, len) = victim.chunks[0];
    victim.push_chunk_bytes(0, &wire::f32s_to_bytes(&g1[off..off + len]), Op::PushChunk);
    drop(victim); // crash mid-round, rack A only

    // Successor takes rack A's seat 0 in the bumped rack-local epoch.
    let deadline = std::time::Instant::now() + std::time::Duration::from_secs(10);
    let mut successor = loop {
        match TcpWorker::connect(addr_a, job, rack_spec) {
            Ok(w) => break w,
            Err(_) => {
                assert!(
                    std::time::Instant::now() < deadline,
                    "dead worker's slot never recycled"
                );
                std::thread::sleep(std::time::Duration::from_millis(20));
            }
        }
    };
    assert_eq!(successor.slot, 0, "successor takes the dead worker's seat");
    assert_eq!(successor.epoch(), 1, "rack A's epoch was bumped");
    assert_eq!(successor.rounds_done(), 1, "round 0 completed before the death");
    let mut succ_model = Vec::new();
    for r in successor.rounds_done() as usize..rounds {
        succ_model = successor.push_pull(&dyadic_grad(n, 0, r)).unwrap();
    }
    let succ_epoch = successor.epoch();
    successor.bye();

    let (surv_model, surv_epoch) = survivor.join().unwrap();
    let (rack_b_model, rack_b_epoch) = rack_b_run.join().unwrap();
    assert_eq!(surv_model, succ_model, "rack A survivor and successor agree");
    assert_eq!(succ_epoch, 1, "rack A finished in its bumped epoch");
    assert_eq!(surv_epoch, 1, "rack A's survivor replayed into epoch 1");
    assert_eq!(
        rack_b_epoch, 0,
        "rack B must never rewind for rack A's failure"
    );
    assert_eq!(surv_model, rack_b_model, "both racks converge to one model");

    // Uninterrupted flat twin with the same per-seat gradients.
    let flat_leader = TcpLeader::serve("127.0.0.1:0", ServerConfig::cores(2)).unwrap();
    let flat = run_leaves(
        flat_leader.local_addr(),
        311,
        dyadic_spec(n as u64, 48, 4),
        rounds,
        None,
        0,
    );
    assert_eq!(
        surv_model, flat,
        "recovered two-level run must be bit-identical to the flat run"
    );
}

// ---------------------------------------------------------------------------
// Deadline supervision & residual checkpointing (the failure-model
// contract in `coordinator::transport`)
// ---------------------------------------------------------------------------

/// A worker that dies *mid-frame* — half a `PushChunk` frame on the
/// wire, then the socket closes — exercises the torn-read hardening:
/// `read_frame_into` fails with a clean typed error at the truncation
/// point, the leader treats the connection as dead, and the job
/// finishes bit-identical. The torn frame never reached the engine, so
/// no rollback is needed: the successor resumes in epoch 0.
#[test]
fn mid_frame_death_recovers_bit_identical() {
    let leader = TcpLeader::serve("127.0.0.1:0", ServerConfig::cores(2)).unwrap();
    let addr = leader.local_addr();
    let n = 256usize;
    let s = spec(n as u64, 64, 2); // 4 chunks
    let rounds = 3usize;
    let job = 220u32;

    let mut victim = RawWorker::connect(addr, job, s);
    assert_eq!(victim.slot, 0);
    let survivor = std::thread::spawn(move || {
        let mut w = TcpWorker::connect(addr, job, s).unwrap();
        assert_eq!(w.slot, 1);
        let mut model = Vec::new();
        for r in 0..rounds {
            model = w.push_pull(&grad(n, 1, r)).unwrap();
        }
        w.bye();
        model
    });

    // Clean round 0, then round 1 dies halfway through chunk 0's frame.
    victim.full_round(&grad(n, 0, 0));
    let g1 = grad(n, 0, 1);
    let (off, len) = victim.chunks[0];
    let mut frame = Vec::new();
    wire::write_chunk_frame_buffered(
        &mut frame,
        Op::PushChunk,
        job,
        victim.slot,
        0,
        victim.epoch,
        off as u64,
        &wire::f32s_to_bytes(&g1[off..off + len]),
    )
    .unwrap();
    victim.writer.write_all(&frame[..frame.len() / 2]).unwrap();
    victim.writer.flush().unwrap();
    drop(victim); // the frame's second half never arrives

    let deadline = std::time::Instant::now() + std::time::Duration::from_secs(10);
    let mut successor = loop {
        match TcpWorker::connect(addr, job, s) {
            Ok(w) => break w,
            Err(_) => {
                assert!(
                    std::time::Instant::now() < deadline,
                    "dead worker's slot never recycled"
                );
                std::thread::sleep(std::time::Duration::from_millis(20));
            }
        }
    };
    assert_eq!(successor.slot, 0, "successor takes the dead worker's seat");
    assert_eq!(
        successor.epoch(),
        0,
        "a frame torn before the engine saw it needs no rollback"
    );
    assert_eq!(successor.rounds_done(), 1);
    let mut succ_model = Vec::new();
    for r in successor.rounds_done() as usize..rounds {
        succ_model = successor.push_pull(&grad(n, 0, r)).unwrap();
    }
    successor.bye();
    let surv_model = survivor.join().unwrap();
    assert_eq!(surv_model, succ_model, "survivor and successor agree");

    let clean = run_two_workers(addr, 221, s, rounds, None);
    assert_eq!(
        surv_model, clean,
        "mid-frame death must recover bit-identical to the clean run"
    );
}

/// A worker that goes silent *mid-round* with its socket still open used
/// to wedge the job forever — no disconnect, no progress. The leader's
/// round deadline now declares it dead, feeds the exact same
/// epoch-bump/rollback/replay recovery as a detected socket death, and
/// records the trip in the fault counters.
#[test]
fn stalled_worker_trips_round_deadline_and_recovers() {
    let dl = DeadlineConfig {
        round_deadline: Some(std::time::Duration::from_millis(150)),
        ..DeadlineConfig::default()
    };
    let leader = TcpLeader::serve_with("127.0.0.1:0", ServerConfig::cores(2), dl).unwrap();
    let addr = leader.local_addr();
    let n = 256usize;
    let s = spec(n as u64, 64, 2); // 4 chunks
    let rounds = 3usize;
    let job = 230u32;

    let mut victim = RawWorker::connect(addr, job, s);
    assert_eq!(victim.slot, 0);
    let survivor = std::thread::spawn(move || {
        let mut w = TcpWorker::connect(addr, job, s).unwrap();
        assert_eq!(w.slot, 1);
        let mut model = Vec::new();
        for r in 0..rounds {
            model = w.push_pull(&grad(n, 1, r)).unwrap();
        }
        w.bye();
        model
    });

    // Clean round 0, one chunk of round 1 — and then: silence. The
    // socket stays open; the worker just stops sending.
    victim.full_round(&grad(n, 0, 0));
    let g1 = grad(n, 0, 1);
    let (off, len) = victim.chunks[0];
    victim.push_chunk_bytes(0, &wire::f32s_to_bytes(&g1[off..off + len]), Op::PushChunk);

    let deadline = std::time::Instant::now() + std::time::Duration::from_secs(10);
    let mut successor = loop {
        match TcpWorker::connect(addr, job, s) {
            Ok(w) => break w,
            Err(_) => {
                assert!(
                    std::time::Instant::now() < deadline,
                    "stalled worker's slot never recycled: round deadline never fired"
                );
                std::thread::sleep(std::time::Duration::from_millis(20));
            }
        }
    };
    assert_eq!(successor.slot, 0, "successor takes the stalled worker's seat");
    assert_eq!(successor.epoch(), 1, "the stall was declared a death: epoch bumped");
    assert_eq!(successor.rounds_done(), 1);
    let mut succ_model = Vec::new();
    for r in successor.rounds_done() as usize..rounds {
        succ_model = successor.push_pull(&grad(n, 0, r)).unwrap();
    }
    successor.bye();
    let surv_model = survivor.join().unwrap();
    assert_eq!(surv_model, succ_model, "survivor and successor agree");

    // Satellite: the fault counters are observable at the server level
    // and moved under the injected stall.
    let m = leader.server().metrics();
    assert!(m.deadline_trips.get() >= 1, "round deadline trip was counted");
    assert!(m.timeouts.get() >= 1, "the fired deadline was counted");
    drop(victim); // outlived the whole recovery: a stall, not a disconnect

    let clean = run_two_workers(addr, 231, s, rounds, None);
    assert_eq!(
        surv_model, clean,
        "deadline-recovered run must be bit-identical to the clean run"
    );
}

/// A relay whose parent is permanently dead no longer redials forever:
/// the uplink's capped exponential backoff exhausts its attempt budget,
/// gives up with a typed `UplinkError`, and evicts the job — so every
/// worker blocked on the exchange fails with an error instead of
/// hanging. The redial and give-up counters record the whole episode.
#[test]
fn dead_parent_uplink_gives_up_and_fails_the_job() {
    // A parent address that is *guaranteed* dead: bind, take the port,
    // drop the listener.
    let dead = std::net::TcpListener::bind("127.0.0.1:0").unwrap();
    let parent = dead.local_addr().unwrap().to_string();
    drop(dead);

    let dl = DeadlineConfig {
        redial_base: std::time::Duration::from_millis(1),
        redial_cap: std::time::Duration::from_millis(8),
        redial_attempts: 3,
        ..DeadlineConfig::default()
    };
    let rack = TcpLeader::serve_relay_with(
        "127.0.0.1:0",
        ServerConfig::cores(2),
        RelayConfig { parent, racks: 1 },
        dl,
    )
    .unwrap();
    let s = spec(128, 64, 1);
    let mut w = TcpWorker::connect(rack.local_addr(), 400, s).unwrap();
    // The push can never complete (sums have nowhere to go); once the
    // uplink gives up and evicts the job, the blocked exchange must
    // surface an error rather than wait forever.
    let err = w.push_pull(&vec![1.0; 128]);
    assert!(err.is_err(), "exchange against a dead parent must fail, not hang");

    let m = rack.server().metrics();
    assert!(m.uplink_giveups.get() >= 1, "the give-up was counted");
    assert!(
        m.redials.get() >= dl.redial_attempts as u64,
        "every failed rendezvous attempt was counted"
    );
}

/// The residual-checkpoint acceptance bar (the ROADMAP's last recovery
/// gap): a *quantized* worker killed at round 2 — after its
/// error-feedback residuals have drifted well away from zero — is
/// replaced by a successor that restores the checkpoint the victim
/// saved through the leader at the round-1 boundary, and the finished
/// run is bit-identical to one that was never interrupted. Before
/// residual checkpointing this could not hold for any death at
/// round ≥ 1: a successor's fresh residuals diverge from the victim's
/// in their first re-quantization.
#[test]
fn quantized_victim_at_round_two_successor_restores_checkpoint() {
    let leader = TcpLeader::serve("127.0.0.1:0", ServerConfig::cores(2)).unwrap();
    let addr = leader.local_addr();
    let n = 128usize;
    let s = spec(n as u64, 64, 2); // 2 chunks
    let rounds = 4usize;
    let t = 0.05f32;
    let job = 240u32;
    // Sub-threshold gradients: progress exists only through error
    // feedback, so a successor starting from fresh residuals would
    // produce visibly different bits.
    let qgrad = move |slot: usize, r: usize| -> Vec<f32> {
        (0..n)
            .map(|i| {
                0.6 * t * (1.0 + 0.1 * slot as f32) + 0.001 * (i % 7) as f32 + 0.002 * r as f32
            })
            .collect()
    };

    let mut victim = RawWorker::connect(addr, job, s);
    assert_eq!(victim.slot, 0);
    let survivor = std::thread::spawn(move || {
        let mut w = TcpWorker::connect(addr, job, s).unwrap();
        assert_eq!(w.slot, 1);
        let mut model = Vec::new();
        for r in 0..rounds {
            model = w.push_pull_quant(&qgrad(1, r), t).unwrap();
        }
        w.bye();
        model
    });

    // Victim: two full quantized rounds, speaking the production wire
    // order — each chunk's post-round residual checkpoint immediately
    // before its push, so the leader commits the full checkpoint at
    // each round boundary.
    let lens: Vec<usize> = victim.chunks.iter().map(|&(_, l)| l).collect();
    let mut vq = ChunkQuantizer::new(&lens, t);
    let push_quant_chunk = |v: &mut RawWorker, vq: &mut ChunkQuantizer, c: usize, r: usize| {
        let g = qgrad(0, r);
        let (off, len) = v.chunks[c];
        let bytes = vq.quantize_chunk(c, &g[off..off + len]).to_bytes();
        wire::write_residual_frame(
            &mut v.writer,
            Op::ResidualSave,
            job,
            v.slot,
            c as u32,
            v.epoch,
            off as u64,
            t,
            vq.residual_chunk(c),
        )
        .unwrap();
        v.push_chunk_bytes(c, &bytes, Op::PushChunkQuant);
    };
    for r in 0..2 {
        for c in 0..victim.chunks.len() {
            push_quant_chunk(&mut victim, &mut vq, c, r);
        }
        let mut got = 0;
        while got < victim.chunks.len() {
            let f = wire::read_frame(&mut victim.reader).unwrap();
            assert_eq!(f.op, Op::ModelChunk);
            got += 1;
        }
    }
    // Round 2: one chunk (checkpoint staged but never committed — the
    // round doesn't complete), then death. The successor must resume
    // from the *committed* round-1 checkpoint, not fresh residuals and
    // not the torn round-2 staging.
    push_quant_chunk(&mut victim, &mut vq, 0, 2);
    drop(victim);

    let deadline = std::time::Instant::now() + std::time::Duration::from_secs(10);
    let mut successor = loop {
        match TcpWorker::connect(addr, job, s) {
            Ok(w) => break w,
            Err(_) => {
                assert!(
                    std::time::Instant::now() < deadline,
                    "dead worker's slot never recycled"
                );
                std::thread::sleep(std::time::Duration::from_millis(20));
            }
        }
    };
    assert_eq!(successor.slot, 0, "successor takes the dead worker's seat");
    assert_eq!(successor.epoch(), 1, "mid-round-2 death bumped the epoch");
    assert_eq!(successor.rounds_done(), 2, "rounds 0-1 completed before the death");
    let mut succ_model = Vec::new();
    for r in successor.rounds_done() as usize..rounds {
        succ_model = successor.push_pull_quant(&qgrad(0, r), t).unwrap();
    }
    successor.bye();
    let surv_model = survivor.join().unwrap();
    assert_eq!(surv_model, succ_model, "survivor and successor agree");

    let m = leader.server().metrics();
    assert!(
        m.residual_saves.get() >= 4,
        "the victim's 2 rounds x 2 chunks of checkpoints were stored"
    );
    assert!(
        m.residual_restores.get() >= 1,
        "the successor was handed the stored checkpoint"
    );

    // Uninterrupted compressed twin with the same per-seat gradients.
    let clean_q = {
        let job = 242u32;
        let joins: Vec<_> = (0..2usize)
            .map(|_| {
                std::thread::spawn(move || {
                    let mut w = TcpWorker::connect(addr, job, s).unwrap();
                    let slot = w.slot as usize;
                    let mut model = Vec::new();
                    for r in 0..rounds {
                        model = w.push_pull_quant(&qgrad(slot, r), t).unwrap();
                    }
                    w.bye();
                    model
                })
            })
            .collect();
        let models: Vec<Vec<f32>> = joins.into_iter().map(|j| j.join().unwrap()).collect();
        assert_eq!(models[0], models[1]);
        models.into_iter().next().unwrap()
    };
    assert_eq!(
        surv_model, clean_q,
        "checkpoint-restored run must be bit-identical to the clean run"
    );
}
