//! Integration tests over the TCP transport: the protocol-version matrix
//! (v0 monolithic vs v1 chunk-streamed), bit-identity of the two exchange
//! patterns, and leader robustness under hostile clients. The in-module
//! tests in `transport.rs` cover single-feature behavior; these exercise
//! cross-version and multi-worker combinations end-to-end.

#![allow(clippy::useless_vec)]

use phub::coordinator::server::ServerConfig;
use phub::coordinator::transport::{JobSpec, TcpLeader, TcpWorker};
use phub::coordinator::wire;

fn spec(model: u64, chunk: u64, workers: u32) -> JobSpec {
    JobSpec {
        model_elems: model,
        chunk_elems: chunk,
        n_workers: workers,
        lr: 0.25,
        momentum: 0.9,
    }
}

/// Deterministic per-worker, per-round gradient.
fn grad(n: usize, w: usize, round: usize) -> Vec<f32> {
    (0..n)
        .map(|i| (w as f32 - 0.5) * 0.75 + (round as f32 + 1.0) * 0.125 + i as f32 * 0.01)
        .collect()
}

/// Run `rounds` synchronous rounds with 2 workers on `proto`, returning
/// the final model (asserting both workers agree bitwise).
fn run_two_workers(
    addr: std::net::SocketAddr,
    job: u32,
    s: JobSpec,
    proto: u32,
    rounds: usize,
    quant: Option<f32>,
) -> Vec<f32> {
    let n = s.model_elems as usize;
    let joins: Vec<_> = (0..2usize)
        .map(|w| {
            std::thread::spawn(move || {
                let mut worker = TcpWorker::connect_with_proto(addr, job, s, proto).unwrap();
                assert_eq!(worker.proto(), proto.min(wire::PROTO_MAX));
                let mut model = Vec::new();
                for r in 0..rounds {
                    let g = grad(n, w, r);
                    model = match quant {
                        Some(t) => worker.push_pull_quant(&g, t).unwrap(),
                        None => worker.push_pull(&g).unwrap(),
                    };
                }
                worker.bye();
                model
            })
        })
        .collect();
    let models: Vec<Vec<f32>> = joins.into_iter().map(|j| j.join().unwrap()).collect();
    assert_eq!(models[0], models[1], "synchronous workers agree bitwise");
    models.into_iter().next().unwrap()
}

/// The tentpole's correctness bar: the chunk-streamed protocol produces
/// bit-identical models to the monolithic one, dense and compressed, on a
/// ragged multi-chunk layout.
#[test]
fn streamed_and_monolithic_protocols_bit_identical() {
    let leader = TcpLeader::serve("127.0.0.1:0", ServerConfig { n_cores: 3 }).unwrap();
    let addr = leader.local_addr();
    // 300 elems at chunk 64 -> 5 chunks including a ragged 44-elem tail.
    let s = spec(300, 64, 2);
    let dense_v0 = run_two_workers(addr, 100, s, wire::PROTO_MONOLITHIC, 4, None);
    let dense_v1 = run_two_workers(addr, 101, s, wire::PROTO_CHUNK_STREAMED, 4, None);
    assert_eq!(dense_v0, dense_v1, "dense: v0 and v1 must agree bitwise");

    // Compressed path: per-chunk error feedback is elementwise identical
    // to whole-model error feedback, so trajectories match bitwise too.
    let quant_v0 = run_two_workers(addr, 102, s, wire::PROTO_MONOLITHIC, 6, Some(0.05));
    let quant_v1 = run_two_workers(addr, 103, s, wire::PROTO_CHUNK_STREAMED, 6, Some(0.05));
    assert_eq!(quant_v0, quant_v1, "quant: v0 and v1 must agree bitwise");
}

/// Old and new workers can share one job: the leader serves each
/// connection at its own negotiated version against the same aggregation
/// engine (the one-release compatibility window).
#[test]
fn mixed_version_workers_share_a_job() {
    let leader = TcpLeader::serve("127.0.0.1:0", ServerConfig { n_cores: 2 }).unwrap();
    let addr = leader.local_addr();
    let n = 256usize;
    let s = spec(n as u64, 64, 2);
    let joins: Vec<_> = [wire::PROTO_CHUNK_STREAMED, wire::PROTO_MONOLITHIC]
        .into_iter()
        .enumerate()
        .map(|(w, proto)| {
            std::thread::spawn(move || {
                let mut worker = TcpWorker::connect_with_proto(addr, 7, s, proto).unwrap();
                assert_eq!(worker.proto(), proto);
                let mut model = Vec::new();
                for r in 0..3 {
                    model = worker.push_pull(&grad(n, w, r)).unwrap();
                }
                worker.bye();
                model
            })
        })
        .collect();
    let models: Vec<Vec<f32>> = joins.into_iter().map(|j| j.join().unwrap()).collect();
    assert_eq!(models[0], models[1], "mixed-version workers agree bitwise");
}

/// Streamed exchange at a worker count and chunk count big enough to get
/// real interleaving, checked against exact analytic SGD (worker grads are
/// small integers, so the f32 aggregation is exact in any order).
#[test]
fn four_workers_many_chunks_streamed_exact() {
    let leader = TcpLeader::serve("127.0.0.1:0", ServerConfig { n_cores: 4 }).unwrap();
    let addr = leader.local_addr();
    let n = 1000usize;
    let rounds = 3usize;
    let s = JobSpec {
        model_elems: n as u64,
        chunk_elems: 64, // 16 chunks
        n_workers: 4,
        lr: 0.5,
        momentum: 0.0,
    };
    let joins: Vec<_> = (0..4usize)
        .map(|w| {
            std::thread::spawn(move || {
                let mut worker = TcpWorker::connect(addr, 9, s).unwrap();
                let g = vec![w as f32; n]; // mean = 1.5 exactly
                let mut model = Vec::new();
                for _ in 0..rounds {
                    model = worker.push_pull(&g).unwrap();
                }
                worker.bye();
                model
            })
        })
        .collect();
    for j in joins {
        let model = j.join().unwrap();
        let expect = -0.5 * 1.5 * rounds as f32;
        for x in model {
            assert!((x - expect).abs() < 1e-6, "{x} vs {expect}");
        }
    }
}

/// A hostile `Hello` (spec that would trip the server's asserts) must be
/// rejected at the edge while other tenants keep training — the
/// poisoned-lock DoS regression, exercised across a live job.
#[test]
fn hostile_hello_while_other_tenants_train() {
    let leader = TcpLeader::serve("127.0.0.1:0", ServerConfig { n_cores: 2 }).unwrap();
    let addr = leader.local_addr();
    // A healthy tenant in the middle of its run.
    let s_ok = spec(128, 64, 1);
    let mut w = TcpWorker::connect(addr, 50, s_ok).unwrap();
    let m1 = w.push_pull(&vec![1.0; 128]).unwrap();

    // Hostile rendezvous attempts, raw on the socket (the client-side
    // validation in `TcpWorker::connect` would refuse to send these).
    use phub::coordinator::wire::{Frame, Op};
    use std::io::{BufWriter, Read};
    use std::net::TcpStream;
    for bad in [
        spec(128, 64, 0),   // zero workers
        spec(128, 64, 100), // > 64 workers
        spec(0, 64, 1),     // empty model
        spec(64, 0, 1),     // empty chunks
        spec(64, 128, 1),   // chunk > model
    ] {
        let mut stream = TcpStream::connect(addr).unwrap();
        let mut wr = BufWriter::new(stream.try_clone().unwrap());
        wire::write_frame(
            &mut wr,
            &Frame {
                op: Op::Hello,
                job: 60,
                worker: 0,
                payload: bad.to_bytes(),
            },
        )
        .unwrap();
        // Leader must close the connection (rejection fully processed).
        let mut buf = [0u8; 64];
        loop {
            match stream.read(&mut buf) {
                Ok(0) | Err(_) => break,
                Ok(_) => {}
            }
        }
    }

    // The in-flight tenant continues, and new tenants are admitted.
    let m2 = w.push_pull(&vec![1.0; 128]).unwrap();
    assert!(m2[0] < m1[0], "training still progressing");
    w.bye();
    let mut w2 = TcpWorker::connect(addr, 61, spec(32, 32, 1)).unwrap();
    assert_eq!(w2.push_pull(&vec![0.0; 32]).unwrap().len(), 32);
    w2.bye();
}
