//! End-to-end exercise of the status/export plane (ISSUE 9): a live TCP
//! leader with two workers training while `/metrics`, `/jobs`, and
//! `/trace` are scraped over real HTTP — counters and latency must
//! move mid-training, every body must be well-formed (Prometheus text /
//! JSON / chrome-tracing JSON), and with auth bound, one tenant's nonce
//! must never read another tenant's trace.

use std::io::{Read, Write};
use std::net::{SocketAddr, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

use phub::coordinator::chunk::KeyTable;
use phub::coordinator::optimizer::Sgd;
use phub::coordinator::service::ConnectionManager;
use phub::coordinator::status::{JobAuth, StatusServer};
use phub::coordinator::transport::{JobSpec, TcpLeader, TcpWorker};
use phub::coordinator::{PHubServer, ServerConfig};
use phub::jsonlite;

/// Minimal scrape client: one GET, read to EOF (the server sends
/// `Connection: close`), return (status code, body).
fn http_get(addr: SocketAddr, path: &str) -> (u16, String) {
    let mut s = TcpStream::connect(addr).expect("connect status endpoint");
    s.set_read_timeout(Some(Duration::from_secs(10))).unwrap();
    write!(s, "GET {path} HTTP/1.1\r\nHost: test\r\n\r\n").unwrap();
    s.flush().unwrap();
    let mut raw = Vec::new();
    s.read_to_end(&mut raw).expect("read response");
    let text = String::from_utf8(raw).expect("utf-8 response");
    let (head, body) = text.split_once("\r\n\r\n").expect("header/body split");
    let code: u16 = head
        .split_whitespace()
        .nth(1)
        .expect("status code")
        .parse()
        .expect("numeric status");
    (code, body.to_string())
}

/// `rounds_completed` of the first job in a `/jobs` body.
fn scraped_rounds(body: &str) -> u64 {
    let v = jsonlite::parse(body).expect("valid /jobs json");
    let jobs = v.get("jobs").expect("jobs key").as_arr().expect("array");
    if jobs.is_empty() {
        return 0;
    }
    jobs[0]
        .get("rounds_completed")
        .expect("rounds_completed")
        .as_usize()
        .expect("numeric") as u64
}

/// Two TCP workers train while the endpoint is scraped: `/metrics` and
/// `/jobs` are well-formed and their counters/latency move between
/// scrapes taken mid-training; `/trace` returns chrome-tracing JSON.
#[test]
fn scraping_a_live_leader_observes_training() {
    let leader = TcpLeader::serve("127.0.0.1:0", ServerConfig::cores(2)).expect("leader");
    let status = StatusServer::bind("127.0.0.1:0", leader.metrics_arc()).expect("status");
    let addr = status.local_addr();
    let spec = JobSpec {
        model_elems: 4096,
        chunk_elems: 1024,
        n_workers: 2,
        lr: 0.1,
        momentum: 0.9,
    };

    // Workers push rounds until the scraper has seen what it needs. The
    // stop decision is barrier-synchronized: rounds are synchronous, so
    // if one worker exited while its peer had begun the next round, the
    // peer would block in `push_pull` forever. The barrier leader
    // samples the flag once per round and both workers act on that one
    // sample, so both always push the same number of rounds.
    let stop = Arc::new(AtomicBool::new(false));
    let quit = Arc::new(AtomicBool::new(false));
    let barrier = Arc::new(std::sync::Barrier::new(2));
    let leader_addr = leader.local_addr();
    let workers: Vec<_> = (0..2)
        .map(|_| {
            let stop = stop.clone();
            let quit = quit.clone();
            let barrier = barrier.clone();
            std::thread::spawn(move || {
                let mut w = TcpWorker::connect(leader_addr, 7, spec).expect("worker connect");
                let grad = vec![0.25f32; 4096];
                let mut rounds = 0u64;
                loop {
                    w.push_pull(&grad).expect("push_pull");
                    rounds += 1;
                    if barrier.wait().is_leader() {
                        quit.store(stop.load(Ordering::Acquire), Ordering::Release);
                    }
                    barrier.wait();
                    if quit.load(Ordering::Acquire) {
                        break;
                    }
                }
                rounds
            })
        })
        .collect();

    // Mid-training: wait for attribution to appear, then for it to move.
    let deadline = Instant::now() + Duration::from_secs(30);
    let first = loop {
        let (code, body) = http_get(addr, "/jobs");
        assert_eq!(code, 200);
        let r = scraped_rounds(&body);
        if r > 0 {
            break r;
        }
        assert!(Instant::now() < deadline, "no rounds attributed in 30s");
        std::thread::sleep(Duration::from_millis(10));
    };
    let second = loop {
        let (code, body) = http_get(addr, "/jobs");
        assert_eq!(code, 200);
        let r = scraped_rounds(&body);
        if r > first {
            break r;
        }
        assert!(Instant::now() < deadline, "rounds stopped moving mid-training");
        std::thread::sleep(Duration::from_millis(10));
    };
    assert!(second > first, "counters must move between scrapes");

    // /jobs: latency histogram populated, byte counters attributed.
    let (_, body) = http_get(addr, "/jobs");
    let v = jsonlite::parse(&body).expect("valid /jobs json");
    let jobs = v.get("jobs").unwrap().as_arr().unwrap();
    assert_eq!(jobs.len(), 1, "one tenant registered");
    let lat = jobs[0].get("round_latency").expect("latency summary");
    assert!(lat.get("count").unwrap().as_usize().unwrap() > 0);
    assert!(lat.get("mean_ns").unwrap().as_f64().unwrap() > 0.0);
    assert!(jobs[0].get("push_bytes").unwrap().as_usize().unwrap() > 0);
    assert!(jobs[0].get("pull_bytes").unwrap().as_usize().unwrap() > 0);

    // /metrics: Prometheus text, line-oriented, with the per-job series.
    let (code, body) = http_get(addr, "/metrics");
    assert_eq!(code, 200);
    assert!(body.contains("phub_dropped_messages_total"));
    assert!(body.contains("phub_job_rounds_completed_total{job="));
    assert!(body.contains("phub_job_round_latency_ns_count{job="));
    for line in body.lines().filter(|l| !l.starts_with('#')) {
        let mut parts = line.split_whitespace();
        assert!(parts.next().unwrap().starts_with("phub_"), "{line}");
        assert!(parts.next().unwrap().parse::<f64>().is_ok(), "{line}");
    }

    // /trace (no auth bound): chrome-tracing JSON with a traceEvents
    // array; with the recorder compiled in (the default), a training
    // leader has recorded per-stage spans by now.
    let (code, body) = http_get(addr, "/trace");
    assert_eq!(code, 200);
    let v = jsonlite::parse(&body).expect("valid chrome trace json");
    let events = v.get("traceEvents").expect("traceEvents").as_arr().unwrap();
    #[cfg(feature = "trace")]
    assert!(!events.is_empty(), "recorder enabled but no events captured");
    for ev in events {
        assert!(ev.get("name").unwrap().as_str().is_some());
        assert!(ev.get("ts").unwrap().as_f64().is_some());
    }

    // Unknown routes are 404, never a hang or a panic.
    assert_eq!(http_get(addr, "/nope").0, 404);

    stop.store(true, Ordering::Release);
    for w in workers {
        assert!(w.join().expect("worker thread") >= 1);
    }
    status.shutdown();
}

/// With auth bound, `/trace` is tenant-scoped by service nonce: job A's
/// nonce reads only job A's events and can never read job B's trace.
#[test]
fn trace_endpoint_enforces_tenant_isolation() {
    let server = PHubServer::start(ServerConfig::cores(2));
    let cm = ConnectionManager::new(server.clone());
    let ha = cm.create_service("tenant-a", 1).unwrap();
    let hb = cm.create_service("tenant-b", 1).unwrap();
    let sgd = || Arc::new(Sgd { lr: 0.1 });
    cm.init_service(&ha, KeyTable::flat(256, 64), &[0.0; 256], sgd())
        .unwrap();
    cm.init_service(&hb, KeyTable::flat(256, 64), &[0.0; 256], sgd())
        .unwrap();
    let ja = cm.service_job("tenant-a").unwrap();
    let jb = cm.service_job("tenant-b").unwrap();

    // A round each, so the recorder holds events for both jobs.
    let mut wa = cm.connect_service(&ha, 0).unwrap();
    let mut wb = cm.connect_service(&hb, 0).unwrap();
    let _ = wa.push_pull(&[1.0; 256]);
    let _ = wb.push_pull(&[2.0; 256]);

    let auth: Arc<dyn JobAuth> = cm.clone();
    let status =
        StatusServer::bind_with_auth("127.0.0.1:0", server.metrics_arc(), auth).expect("status");
    let addr = status.local_addr();

    // The right nonce reads its own job — and only its own events.
    let (code, body) = http_get(addr, &format!("/trace?job={ja}&nonce={:x}", ha.nonce));
    assert_eq!(code, 200);
    let v = jsonlite::parse(&body).expect("valid chrome trace json");
    for ev in v.get("traceEvents").unwrap().as_arr().unwrap() {
        let job = ev.get("args").unwrap().get("job").unwrap().as_usize().unwrap();
        assert_eq!(job as u32, ja, "foreign job leaked into a scoped trace");
    }

    // Job A's nonce cannot read job B's trace; nor can garbage, nor can
    // a credential-less request.
    assert_eq!(http_get(addr, &format!("/trace?job={jb}&nonce={:x}", ha.nonce)).0, 403);
    assert_eq!(http_get(addr, &format!("/trace?job={ja}&nonce={:x}", hb.nonce)).0, 403);
    assert_eq!(http_get(addr, &format!("/trace?job={ja}&nonce=deadbeef")).0, 403);
    assert_eq!(http_get(addr, "/trace").0, 403);

    // Aggregate operator surfaces stay open under auth.
    assert_eq!(http_get(addr, "/metrics").0, 200);
    assert_eq!(http_get(addr, "/jobs").0, 200);

    status.shutdown();
    PHubServer::shutdown(server);
}
