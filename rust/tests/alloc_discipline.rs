//! Allocation discipline of the steady-state data plane (the tentpole's
//! acceptance bar): after warm-up, the leader-shaped
//! push → aggregate → fused-optimize → reply path performs **exactly
//! zero** heap allocations per round — dense and 2-bit alike, multi-
//! puller fan-out included — and the client's round encoding *and*
//! `_into`-style round decoding are likewise allocation-free. There are
//! no exclusions left: the `std::sync::mpsc` hop whose amortized
//! queue-block allocation this test used to carve out is gone, replaced
//! by the bounded lock-free SPSC rings of `coordinator/ring.rs`, and the
//! measured loop now drives the real fabric — frames enter through
//! pooled `read_frame_into` buffers, travel conceptually as the
//! core-side absorb, and every completion broadcasts one refcount-shared
//! pooled buffer over real reply rings to three pulling workers, each
//! serialized to wire form from the shared buffer. The RackRelay role's
//! uplink leg is covered too: sums drain off the uplink lane into reused
//! replay caches, serialize as upstream `PushChunk` frames, and the
//! parent's returned parameters install through `install_params_src`
//! straight from their wire bytes, firing the deferred pull broadcast —
//! all at exact-zero allocations once warm.
//!
//! The same loop is also mutex-free by construction: rings are
//! Acquire/Release atomics, pools are single-taker Treiber stacks, and
//! the engine itself holds no lock (see `ring.rs` / `pool.rs` for the
//! verified contracts; this binary asserts the allocation half, which a
//! counting global allocator can observe directly).
//!
//! The absorb folds and fused optimizer passes dispatch to the explicit
//! SIMD kernels of `coordinator/kernels.rs`; the tier is resolved once
//! (an env read, which allocates) before warm-up, so the invariant holds
//! identically under scalar, SSE2, and AVX2 dispatch — CI runs this test
//! in both the native and the forced-scalar (`PHUB_KERNELS=scalar`)
//! lanes. Affine chunk→core placement is init-time-only and adds no
//! steady-state work.
//!
//! Keep this binary to a single #[test]: the allocation counter is
//! process-global, so a concurrently running test would break the exact
//! zero assertion.

use std::alloc::{GlobalAlloc, Layout, System};
use std::io::Cursor;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::Arc;

use phub::coordinator::aggregation::GradSrc;
use phub::coordinator::compress::{ChunkQuantizer, QuantView};
use phub::coordinator::engine::{
    single_lane_fabrics, NodeRole, PushOutcome, Reply, ReplyRx, RoundTag, ShardEngine,
};
use phub::coordinator::optimizer::NesterovSgd;
use phub::coordinator::pool::{BytePool, Pool};
use phub::coordinator::wire::{self, Op};

struct CountingAlloc;

static ALLOCS: AtomicUsize = AtomicUsize::new(0);

unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        System.alloc(layout)
    }

    unsafe fn alloc_zeroed(&self, layout: Layout) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        System.alloc_zeroed(layout)
    }

    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        System.realloc(ptr, layout, new_size)
    }

    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        System.dealloc(ptr, layout)
    }
}

#[global_allocator]
static GLOBAL: CountingAlloc = CountingAlloc;

fn allocs() -> usize {
    ALLOCS.load(Ordering::Relaxed)
}

const JOB: u32 = 1;
const WORKERS: usize = 3;
const CHUNKS: usize = 4;
const CHUNK_ELEMS: usize = 96; // not a lane multiple: tails exercised

/// Pre-encode one round's worth of `PushChunk`/`PushChunkQuant` frames
/// (worker-major, like the engine's bit-identity tests) into one byte
/// stream the measured loop replays each round.
fn encode_round(quant: bool) -> Vec<u8> {
    let mut out = Vec::new();
    let mut quants = ChunkQuantizer::new(&[CHUNK_ELEMS; CHUNKS], 0.05);
    for w in 0..WORKERS {
        for c in 0..CHUNKS {
            let grad: Vec<f32> = (0..CHUNK_ELEMS)
                .map(|i| ((i + 7 * w + 13 * c) as f32 * 0.37).sin() * 0.1)
                .collect();
            let off = (c * CHUNK_ELEMS) as u64;
            if quant {
                let mut payload = Vec::new();
                quants.quantize_chunk_into(c, &grad, &mut payload);
                wire::write_chunk_frame_buffered(
                    &mut out,
                    Op::PushChunkQuant,
                    JOB,
                    w as u32,
                    c as u32,
                    0,
                    off,
                    &payload,
                )
                .unwrap();
            } else {
                wire::write_chunk_frame_f32s(
                    &mut out,
                    Op::PushChunk,
                    JOB,
                    w as u32,
                    c as u32,
                    0,
                    off,
                    &grad,
                )
                .unwrap();
            }
        }
    }
    out
}

/// One leader-shaped round over the pre-encoded frame stream: pooled
/// frame reads, byte-level absorb into the engine with `pull = true`
/// for **every** worker, and — on each chunk completion — the reply leg
/// exactly as deployed: the engine broadcasts one refcount-shared
/// parameter buffer over the three workers' SPSC reply rings, and each
/// "connection" serializes its `ModelChunk` frame straight out of the
/// shared buffer into its reused staging vector. Returns the number of
/// chunk replies collected (must be `WORKERS * CHUNKS` per round).
fn run_round(
    frames: &[u8],
    eng: &mut ShardEngine,
    pool: &Arc<BytePool>,
    rxs: &mut [ReplyRx],
    ready: &mut [Vec<u8>],
    round: u64,
) -> usize {
    let tag = RoundTag::new(0, round);
    let mut cur = Cursor::new(frames);
    let mut replies = 0usize;
    for _ in 0..WORKERS * CHUNKS {
        let mut fb = pool.take();
        let (op, chunk, worker) = {
            let v = wire::read_frame_into(&mut cur, &mut fb).unwrap();
            let (chunk, _epoch, _off, _bytes) = wire::decode_chunk_payload(v.payload).unwrap();
            (v.op, chunk, v.worker)
        };
        let bytes = &fb[wire::CHUNK_PREFIX_BYTES..];
        let outcome = match op {
            Op::PushChunk => eng
                .push_src(JOB, chunk, worker, GradSrc::LeBytes(bytes), true, tag)
                .unwrap(),
            Op::PushChunkQuant => {
                let q = QuantView::parse(bytes).unwrap();
                eng.push_src(
                    JOB,
                    chunk,
                    worker,
                    GradSrc::Quant2Bit {
                        threshold: q.threshold,
                        len: q.len,
                        packed: q.packed,
                    },
                    true,
                    tag,
                )
                .unwrap()
            }
            other => panic!("unexpected op {other:?}"),
        };
        if outcome == PushOutcome::Completed {
            // Drain the fan-out: every worker pulled, so every worker's
            // reply ring now holds a refcount bump of the one shared
            // buffer. Serialize each as its connection would.
            for (w, rx) in rxs.iter_mut().enumerate() {
                match rx.try_recv() {
                    Some(Reply::Chunk {
                        chunk, epoch, data, ..
                    }) => {
                        replies += 1;
                        ready[w].clear();
                        wire::write_chunk_frame_f32s(
                            &mut ready[w],
                            Op::ModelChunk,
                            JOB,
                            w as u32,
                            chunk,
                            epoch,
                            chunk as u64 * CHUNK_ELEMS as u64,
                            &data,
                        )
                        .unwrap();
                        // `data` drops here: the last worker's drop
                        // recycles the shared buffer to the engine pool.
                    }
                    other => panic!("expected a chunk reply, got {other:?}"),
                }
            }
        }
        // `fb` drops here and recycles to the frame pool.
    }
    replies
}

fn fresh_engine() -> (ShardEngine, Vec<ReplyRx>) {
    let mut eng = ShardEngine::new();
    let chunks: Vec<(u32, Vec<f32>)> = (0..CHUNKS)
        .map(|c| (c as u32, vec![0.25f32; CHUNK_ELEMS]))
        .collect();
    // Real reply fabric, one single-core lane per worker — the rings the
    // deployed server would use, consumed in this same thread.
    let (txs, rxs) = single_lane_fabrics(JOB, WORKERS, 32);
    eng.init_job(
        JOB,
        chunks,
        Arc::new(NesterovSgd {
            lr: 0.01,
            momentum: 0.9,
        }),
        WORKERS,
        txs,
    );
    (eng, rxs)
}

/// A RackRelay-shaped engine plus both ends of its fabric: worker reply
/// lanes and the uplink sum lane.
fn fresh_relay_engine() -> (ShardEngine, Vec<ReplyRx>, ReplyRx) {
    let mut eng = ShardEngine::new();
    let chunks: Vec<(u32, Vec<f32>)> = (0..CHUNKS)
        .map(|c| (c as u32, vec![0.25f32; CHUNK_ELEMS]))
        .collect();
    let (txs, rxs) = single_lane_fabrics(JOB, WORKERS, 32);
    let (mut utx, mut urx) = single_lane_fabrics(JOB, 1, 32);
    eng.init_job_with_role(
        JOB,
        chunks,
        Arc::new(NesterovSgd {
            lr: 0.01,
            momentum: 0.9,
        }),
        WORKERS,
        txs,
        NodeRole::RackRelay,
        Some(utx.pop().expect("uplink lane")),
    );
    (eng, rxs, urx.pop().expect("uplink lane"))
}

/// One relay-shaped round: the downlink is the same pooled push path as
/// [`run_round`], but completions emit a raw `Reply::Sum` on the uplink
/// lane instead of optimizing — the uplink leg copies each sum into its
/// reused replay cache and serializes the upstream `PushChunk` frame into
/// a reused sink (exactly what `transport::run_uplink` does per chunk).
/// Then the "parent's" `ModelChunk` payloads (built in a reused byte
/// buffer) install through `install_params_src`, firing the deferred
/// pull broadcast, which each connection serializes as usual. Returns
/// the number of chunk replies delivered (must be `WORKERS * CHUNKS`).
#[allow(clippy::too_many_arguments)]
fn relay_round(
    frames: &[u8],
    eng: &mut ShardEngine,
    pool: &Arc<BytePool>,
    urx: &mut ReplyRx,
    rxs: &mut [ReplyRx],
    ready: &mut [Vec<u8>],
    sum_cache: &mut [Vec<f32>],
    upsink: &mut Vec<u8>,
    model_bytes: &mut [u8],
    round: u64,
) -> usize {
    let tag = RoundTag::new(0, round);
    let mut cur = Cursor::new(frames);
    upsink.clear();
    for _ in 0..WORKERS * CHUNKS {
        let mut fb = pool.take();
        let (chunk, worker) = {
            let v = wire::read_frame_into(&mut cur, &mut fb).unwrap();
            let (chunk, _epoch, _off, _bytes) = wire::decode_chunk_payload(v.payload).unwrap();
            assert_eq!(v.op, Op::PushChunk);
            (chunk, v.worker)
        };
        let bytes = &fb[wire::CHUNK_PREFIX_BYTES..];
        let outcome = eng
            .push_src(JOB, chunk, worker, GradSrc::LeBytes(bytes), true, tag)
            .unwrap();
        if outcome == PushOutcome::Completed {
            // "Local sum ready": drain the uplink lane and forward.
            match urx.try_recv() {
                Some(Reply::Sum { chunk, data, .. }) => {
                    let ci = chunk as usize;
                    sum_cache[ci].copy_from_slice(&data);
                    // `data` drops here and recycles to the engine pool.
                    wire::write_chunk_frame_f32s(
                        upsink,
                        Op::PushChunk,
                        JOB,
                        0,
                        chunk,
                        0,
                        ci as u64 * CHUNK_ELEMS as u64,
                        &sum_cache[ci],
                    )
                    .unwrap();
                }
                other => panic!("expected an uplink sum, got {other:?}"),
            }
        }
    }
    // "Parameters ready": the parent's ModelChunk payloads come back (a
    // round-trip of the sums here — the values are immaterial, the path
    // is what's measured) and install straight from their wire bytes.
    let mut replies = 0usize;
    for c in 0..CHUNKS {
        for (i, x) in sum_cache[c].iter().enumerate() {
            model_bytes[i * 4..i * 4 + 4].copy_from_slice(&x.to_le_bytes());
        }
        let installed = eng
            .install_params_src(JOB, c as u32, GradSrc::LeBytes(model_bytes))
            .unwrap();
        assert!(installed, "chunk {c} was not awaiting its install");
        for (w, rx) in rxs.iter_mut().enumerate() {
            match rx.try_recv() {
                Some(Reply::Chunk {
                    chunk, epoch, data, ..
                }) => {
                    replies += 1;
                    ready[w].clear();
                    wire::write_chunk_frame_f32s(
                        &mut ready[w],
                        Op::ModelChunk,
                        JOB,
                        w as u32,
                        chunk,
                        epoch,
                        chunk as u64 * CHUNK_ELEMS as u64,
                        &data,
                    )
                    .unwrap();
                }
                other => panic!("expected a deferred chunk reply, got {other:?}"),
            }
        }
    }
    replies
}

#[test]
fn steady_state_data_plane_is_allocation_free() {
    // Resolve the SIMD dispatch tier up front: the one-time `resolve`
    // reads the PHUB_KERNELS environment variable (which allocates).
    // Every driver hits this during warm-up anyway — doing it explicitly
    // documents that steady-state dispatch is a single cached atomic
    // load, and keeps the exact-zero assertion honest whichever tier
    // (scalar/SSE2/AVX2) this host dispatches to. Placement needs no
    // equivalent: chunk→core assignment is computed once at init and is
    // a table lookup per message thereafter.
    let tier = phub::coordinator::kernels::active_tier();
    eprintln!("alloc_discipline: kernel tier {}", tier.name());
    // ---- Phase 1: dense leader path (push → aggregate → broadcast). ----
    let frames = encode_round(false);
    let (mut eng, mut rxs) = fresh_engine();
    let pool: Arc<BytePool> = Pool::new(16);
    let mut ready: Vec<Vec<u8>> = vec![Vec::new(); WORKERS];
    for r in 0..3 {
        assert_eq!(
            run_round(&frames, &mut eng, &pool, &mut rxs, &mut ready, r),
            WORKERS * CHUNKS,
            "warm-up round {r} must deliver every worker every chunk"
        );
    }
    let before = allocs();
    for r in 3..19 {
        run_round(&frames, &mut eng, &pool, &mut rxs, &mut ready, r);
    }
    let dense_delta = allocs() - before;
    assert_eq!(
        dense_delta, 0,
        "dense steady-state rounds must not allocate at all — rings, \
         shared reply broadcast, and pools included (got {dense_delta} \
         allocations over 16 rounds)"
    );

    // ---- Phase 2: 2-bit leader path (dequantize folded into absorb). ----
    let qframes = encode_round(true);
    let (mut qeng, mut qrxs) = fresh_engine();
    for r in 0..3 {
        assert_eq!(
            run_round(&qframes, &mut qeng, &pool, &mut qrxs, &mut ready, r),
            WORKERS * CHUNKS
        );
    }
    let before = allocs();
    for r in 3..19 {
        run_round(&qframes, &mut qeng, &pool, &mut qrxs, &mut ready, r);
    }
    let quant_delta = allocs() - before;
    assert_eq!(
        quant_delta, 0,
        "quantized steady-state rounds must not allocate (got {quant_delta})"
    );

    // ---- Phase 3: client-side round encoding. ----
    // Dense frames serialize straight from the gradient; quantized
    // rounds encode into per-chunk buffers reused across rounds.
    let grad: Vec<f32> = (0..CHUNKS * CHUNK_ELEMS)
        .map(|i| (i as f32 * 0.13).sin() * 0.1)
        .collect();
    let mut quants = ChunkQuantizer::new(&[CHUNK_ELEMS; CHUNKS], 0.05);
    let mut quant_round: Vec<Vec<u8>> = vec![Vec::new(); CHUNKS];
    let mut sink: Vec<u8> = Vec::new();
    let mut client_round = |sink: &mut Vec<u8>,
                            quants: &mut ChunkQuantizer,
                            quant_round: &mut Vec<Vec<u8>>| {
        sink.clear();
        for c in 0..CHUNKS {
            let g = &grad[c * CHUNK_ELEMS..(c + 1) * CHUNK_ELEMS];
            wire::write_chunk_frame_f32s(
                sink,
                Op::PushChunk,
                JOB,
                0,
                c as u32,
                0,
                (c * CHUNK_ELEMS) as u64,
                g,
            )
            .unwrap();
            quants.quantize_chunk_into(c, g, &mut quant_round[c]);
            wire::write_chunk_frame_buffered(
                sink,
                Op::PushChunkQuant,
                JOB,
                0,
                c as u32,
                0,
                (c * CHUNK_ELEMS) as u64,
                &quant_round[c],
            )
            .unwrap();
        }
    };
    for _ in 0..3 {
        client_round(&mut sink, &mut quants, &mut quant_round);
    }
    let before = allocs();
    for _ in 0..16 {
        client_round(&mut sink, &mut quants, &mut quant_round);
    }
    let client_delta = allocs() - before;
    assert_eq!(
        client_delta, 0,
        "client round encoding must not allocate once warm (got {client_delta})"
    );

    // ---- Phase 4: relay uplink steady path (RackRelay role). ----
    // Downlink pushes complete into raw sums on the uplink lane; the
    // uplink leg caches + serializes them upstream, and the parent's
    // returned parameters install back, releasing the deferred pulls.
    let (mut reng, mut rrxs, mut urx) = fresh_relay_engine();
    let mut sum_cache: Vec<Vec<f32>> = vec![vec![0.0f32; CHUNK_ELEMS]; CHUNKS];
    let mut upsink: Vec<u8> = Vec::new();
    let mut model_bytes: Vec<u8> = vec![0u8; CHUNK_ELEMS * 4];
    for r in 0..3 {
        assert_eq!(
            relay_round(
                &frames,
                &mut reng,
                &pool,
                &mut urx,
                &mut rrxs,
                &mut ready,
                &mut sum_cache,
                &mut upsink,
                &mut model_bytes,
                r,
            ),
            WORKERS * CHUNKS,
            "relay warm-up round {r} must deliver every worker every chunk"
        );
    }
    let before = allocs();
    for r in 3..19 {
        relay_round(
            &frames,
            &mut reng,
            &pool,
            &mut urx,
            &mut rrxs,
            &mut ready,
            &mut sum_cache,
            &mut upsink,
            &mut model_bytes,
            r,
        );
    }
    let relay_delta = allocs() - before;
    assert_eq!(
        relay_delta, 0,
        "relay uplink steady-state rounds must not allocate — sum lane, \
         replay cache, upstream encode, and install broadcast included \
         (got {relay_delta} allocations over 16 rounds)"
    );

    // ---- Phase 5: client-side `_into` round decoding. ----
    // The pull half of `push_pull_into`: ModelChunk frames decode through
    // the reused receive buffer and land in a caller-owned model slice,
    // arrival flags in a reused vector — nothing allocated per round.
    let mut mframes: Vec<u8> = Vec::new();
    for c in 0..CHUNKS {
        wire::write_chunk_frame_f32s(
            &mut mframes,
            Op::ModelChunk,
            JOB,
            0,
            c as u32,
            0,
            (c * CHUNK_ELEMS) as u64,
            &grad[c * CHUNK_ELEMS..(c + 1) * CHUNK_ELEMS],
        )
        .unwrap();
    }
    let mut model = vec![0.0f32; CHUNKS * CHUNK_ELEMS];
    let mut recv_seen = vec![false; CHUNKS];
    let mut recv_buf: Vec<u8> = Vec::new();
    let mut pull_round =
        |model: &mut [f32], recv_seen: &mut [bool], recv_buf: &mut Vec<u8>| {
            recv_seen.fill(false);
            let mut cur = Cursor::new(&mframes[..]);
            for _ in 0..CHUNKS {
                let v = wire::read_frame_into(&mut cur, recv_buf).unwrap();
                let (chunk, _e, off, bytes) = wire::decode_chunk_payload(v.payload).unwrap();
                let ci = chunk as usize;
                assert!(!recv_seen[ci], "duplicate model chunk {ci}");
                recv_seen[ci] = true;
                let lo = off as usize;
                wire::copy_f32s_from_le(&mut model[lo..lo + CHUNK_ELEMS], bytes).unwrap();
            }
        };
    for _ in 0..3 {
        pull_round(&mut model, &mut recv_seen, &mut recv_buf);
    }
    let before = allocs();
    for _ in 0..16 {
        pull_round(&mut model, &mut recv_seen, &mut recv_buf);
    }
    let pull_delta = allocs() - before;
    assert_eq!(
        pull_delta, 0,
        "into-style round decoding must not allocate once warm (got {pull_delta})"
    );

    // The pools actually recycled rather than growing without bound.
    assert!(pool.free_count() <= 16);
}
