//! Allocation discipline of the steady-state data plane (the tentpole's
//! acceptance bar): after warm-up, the leader-shaped
//! push → aggregate → fused-optimize → reply path performs **zero** heap
//! allocations per chunk, dense and 2-bit alike, and the client's round
//! encoding is likewise allocation-free.
//!
//! The test installs a counting global allocator and drives the exact
//! per-chunk work a leader connection + core perform — pooled
//! `read_frame_into`, `ShardEngine::push_src` on the wire bytes, and
//! reply serialization from a pooled parameter buffer through the reused
//! staging vector — synchronously on one thread. The one piece of the
//! real deployment deliberately *outside* the measured region is the
//! `std::sync::mpsc` hop between connection and core threads, whose
//! internal queue allocates a block per ~31 messages; that cost is
//! amortized, not per-chunk, and is documented in the ROADMAP as the
//! remaining gap. Everything this crate controls is asserted to be
//! allocation-free.
//!
//! Keep this binary to a single #[test]: the allocation counter is
//! process-global, so a concurrently running test would break the exact
//! zero assertion.

use std::alloc::{GlobalAlloc, Layout, System};
use std::io::Cursor;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::mpsc::channel;
use std::sync::Arc;

use phub::coordinator::aggregation::GradSrc;
use phub::coordinator::compress::{ChunkQuantizer, QuantView};
use phub::coordinator::engine::{PushOutcome, RoundTag, ShardEngine};
use phub::coordinator::optimizer::NesterovSgd;
use phub::coordinator::pool::{BytePool, F32Pool, Pool};
use phub::coordinator::wire::{self, Op};

struct CountingAlloc;

static ALLOCS: AtomicUsize = AtomicUsize::new(0);

unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        System.alloc(layout)
    }

    unsafe fn alloc_zeroed(&self, layout: Layout) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        System.alloc_zeroed(layout)
    }

    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        System.realloc(ptr, layout, new_size)
    }

    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        System.dealloc(ptr, layout)
    }
}

#[global_allocator]
static GLOBAL: CountingAlloc = CountingAlloc;

fn allocs() -> usize {
    ALLOCS.load(Ordering::Relaxed)
}

const JOB: u32 = 1;
const WORKERS: usize = 3;
const CHUNKS: usize = 4;
const CHUNK_ELEMS: usize = 96; // not a lane multiple: tails exercised

/// Pre-encode one round's worth of `PushChunk`/`PushChunkQuant` frames
/// (worker-major, like the engine's bit-identity tests) into one byte
/// stream the measured loop replays each round.
fn encode_round(quant: bool) -> Vec<u8> {
    let mut out = Vec::new();
    let mut quants = ChunkQuantizer::new(&[CHUNK_ELEMS; CHUNKS], 0.05);
    for w in 0..WORKERS {
        for c in 0..CHUNKS {
            let grad: Vec<f32> = (0..CHUNK_ELEMS)
                .map(|i| ((i + 7 * w + 13 * c) as f32 * 0.37).sin() * 0.1)
                .collect();
            let off = (c * CHUNK_ELEMS) as u64;
            if quant {
                let mut payload = Vec::new();
                quants.quantize_chunk_into(c, &grad, &mut payload);
                wire::write_chunk_frame_buffered(
                    &mut out,
                    Op::PushChunkQuant,
                    JOB,
                    w as u32,
                    c as u32,
                    0,
                    off,
                    &payload,
                )
                .unwrap();
            } else {
                wire::write_chunk_frame_f32s(
                    &mut out,
                    Op::PushChunk,
                    JOB,
                    w as u32,
                    c as u32,
                    0,
                    off,
                    &grad,
                )
                .unwrap();
            }
        }
    }
    out
}

/// One leader-shaped round over the pre-encoded frame stream: pooled
/// frame reads, byte-level absorb into the engine, and — on each chunk
/// completion — the reply leg (pooled parameter copy serialized into the
/// reused staging vector). Exactly the per-chunk work of
/// `transport::serve_streamed` + the core loop, minus the channel hop.
#[allow(clippy::too_many_arguments)]
fn run_round(
    frames: &[u8],
    eng: &mut ShardEngine,
    pool: &Arc<BytePool>,
    fpool: &Arc<F32Pool>,
    ready: &mut Vec<u8>,
    round: u64,
) -> usize {
    let tag = RoundTag::new(0, round);
    let mut cur = Cursor::new(frames);
    let mut completed = 0usize;
    for _ in 0..WORKERS * CHUNKS {
        let mut fb = pool.take();
        let (op, chunk, worker) = {
            let v = wire::read_frame_into(&mut cur, &mut fb).unwrap();
            let (chunk, _epoch, _off, _bytes) = wire::decode_chunk_payload(v.payload).unwrap();
            (v.op, chunk, v.worker)
        };
        let bytes = &fb[wire::CHUNK_PREFIX_BYTES..];
        let outcome = match op {
            Op::PushChunk => eng
                .push_src(JOB, chunk, worker, GradSrc::LeBytes(bytes), false, tag)
                .unwrap(),
            Op::PushChunkQuant => {
                let q = QuantView::parse(bytes).unwrap();
                eng.push_src(
                    JOB,
                    chunk,
                    worker,
                    GradSrc::Quant2Bit {
                        threshold: q.threshold,
                        len: q.len,
                        packed: q.packed,
                    },
                    false,
                    tag,
                )
                .unwrap()
            }
            other => panic!("unexpected op {other:?}"),
        };
        if outcome == PushOutcome::Completed {
            completed += 1;
            // Reply leg: copy the fresh parameters into a pooled buffer
            // and serialize the ModelChunk frame into the reused staging
            // vector (what `apply_reply` does per puller).
            let params = eng.chunk_params(JOB, chunk).unwrap();
            let mut rb = fpool.take();
            rb.extend_from_slice(params);
            ready.clear();
            wire::write_chunk_frame_f32s(
                ready,
                Op::ModelChunk,
                JOB,
                0,
                chunk,
                0,
                chunk as u64 * CHUNK_ELEMS as u64,
                &rb,
            )
            .unwrap();
        }
        // `fb` and `rb` drop here: both recycle to their pools.
    }
    completed
}

fn fresh_engine() -> ShardEngine {
    let mut eng = ShardEngine::new();
    let chunks: Vec<(u32, Vec<f32>)> = (0..CHUNKS)
        .map(|c| (c as u32, vec![0.25f32; CHUNK_ELEMS]))
        .collect();
    let (tx, _rx) = channel();
    // Reply senders are required by the engine API; with pull=false in
    // the driver they are never used, keeping the mpsc internals (whose
    // block allocations are outside our control) out of the measurement.
    eng.init_job(
        JOB,
        chunks,
        Arc::new(NesterovSgd {
            lr: 0.01,
            momentum: 0.9,
        }),
        WORKERS,
        vec![tx; WORKERS],
    );
    eng
}

#[test]
fn steady_state_data_plane_is_allocation_free() {
    // ---- Phase 1: dense leader path (push → aggregate → reply). ----
    let frames = encode_round(false);
    let mut eng = fresh_engine();
    let pool: Arc<BytePool> = Pool::new(16);
    let fpool: Arc<F32Pool> = Pool::new(16);
    let mut ready: Vec<u8> = Vec::new();
    for r in 0..3 {
        assert_eq!(
            run_round(&frames, &mut eng, &pool, &fpool, &mut ready, r),
            CHUNKS,
            "warm-up round {r} must complete every chunk"
        );
    }
    let before = allocs();
    for r in 3..19 {
        run_round(&frames, &mut eng, &pool, &fpool, &mut ready, r);
    }
    let dense_delta = allocs() - before;
    assert_eq!(
        dense_delta, 0,
        "dense steady-state rounds must not allocate (got {dense_delta} \
         allocations over 16 rounds)"
    );

    // ---- Phase 2: 2-bit leader path (dequantize folded into absorb). ----
    let qframes = encode_round(true);
    let mut qeng = fresh_engine();
    for r in 0..3 {
        assert_eq!(
            run_round(&qframes, &mut qeng, &pool, &fpool, &mut ready, r),
            CHUNKS
        );
    }
    let before = allocs();
    for r in 3..19 {
        run_round(&qframes, &mut qeng, &pool, &fpool, &mut ready, r);
    }
    let quant_delta = allocs() - before;
    assert_eq!(
        quant_delta, 0,
        "quantized steady-state rounds must not allocate (got {quant_delta})"
    );

    // ---- Phase 3: client-side round encoding. ----
    // Dense frames serialize straight from the gradient; quantized
    // rounds encode into per-chunk buffers reused across rounds.
    let grad: Vec<f32> = (0..CHUNKS * CHUNK_ELEMS)
        .map(|i| (i as f32 * 0.13).sin() * 0.1)
        .collect();
    let mut quants = ChunkQuantizer::new(&[CHUNK_ELEMS; CHUNKS], 0.05);
    let mut quant_round: Vec<Vec<u8>> = vec![Vec::new(); CHUNKS];
    let mut sink: Vec<u8> = Vec::new();
    let mut client_round = |sink: &mut Vec<u8>,
                            quants: &mut ChunkQuantizer,
                            quant_round: &mut Vec<Vec<u8>>| {
        sink.clear();
        for c in 0..CHUNKS {
            let g = &grad[c * CHUNK_ELEMS..(c + 1) * CHUNK_ELEMS];
            wire::write_chunk_frame_f32s(
                sink,
                Op::PushChunk,
                JOB,
                0,
                c as u32,
                0,
                (c * CHUNK_ELEMS) as u64,
                g,
            )
            .unwrap();
            quants.quantize_chunk_into(c, g, &mut quant_round[c]);
            wire::write_chunk_frame_buffered(
                sink,
                Op::PushChunkQuant,
                JOB,
                0,
                c as u32,
                0,
                (c * CHUNK_ELEMS) as u64,
                &quant_round[c],
            )
            .unwrap();
        }
    };
    for _ in 0..3 {
        client_round(&mut sink, &mut quants, &mut quant_round);
    }
    let before = allocs();
    for _ in 0..16 {
        client_round(&mut sink, &mut quants, &mut quant_round);
    }
    let client_delta = allocs() - before;
    assert_eq!(
        client_delta, 0,
        "client round encoding must not allocate once warm (got {client_delta})"
    );

    // The pools actually recycled rather than growing without bound.
    assert!(pool.free_count() <= 16 && fpool.free_count() <= 16);
}
