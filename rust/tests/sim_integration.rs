//! Integration tests asserting the paper's headline claims hold in the
//! simulated testbed — the figure-level "shape" contracts that the bench
//! binaries print. If a calibration change breaks a paper claim, these
//! fail before EXPERIMENTS.md goes stale.

use phub::compute::Gpu;
use phub::config::{ClusterConfig, ExchangeConfig, NetConfig, PsConfig, Stack};
use phub::dnn::Dnn;
use phub::sim::{self, SimOpts};

fn testbed() -> ClusterConfig {
    ClusterConfig::paper_testbed()
}

fn mxnet_tcp(net: NetConfig) -> ClusterConfig {
    testbed()
        .with_ps(PsConfig::ColocatedSharded)
        .with_stack(Stack::MxnetTcp)
        .with_net(net)
        .with_exchange(ExchangeConfig::mxnet())
}

fn mxnet_ib(net: NetConfig) -> ClusterConfig {
    mxnet_tcp(net).with_stack(Stack::MxnetIb)
}

/// Table 1 shape: MXNet TCP at 8 workers lands within 25% of the paper's
/// 688 samples/s and scales poorly (<60% efficiency); PHub scales ~linearly.
#[test]
fn table1_shape() {
    let d = Dnn::by_abbrev("RN50").unwrap();
    let tcp8 = sim::simulate(&mxnet_tcp(NetConfig::infiniband_56g()), &d, Gpu::Gtx1080Ti);
    assert!(
        (tcp8.throughput - 688.0).abs() / 688.0 < 0.25,
        "MXNet TCP @8: {} vs paper 688",
        tcp8.throughput
    );
    let ideal = 8.0 * d.local_throughput();
    assert!(tcp8.throughput / ideal < 0.6);
    let phub8 = sim::simulate(&testbed(), &d, Gpu::Gtx1080Ti);
    assert!(phub8.throughput / ideal > 0.9, "{}", phub8.throughput / ideal);
}

/// Figure 11: the IB data plane alone speeds up every network; the
/// largest wins are the big-model networks (AN, VGG).
#[test]
fn fig11_dataplane_speedups() {
    let mut an_speedup = 0.0;
    let mut gn_speedup = 0.0;
    for abbrev in ["AN", "GN"] {
        let d = Dnn::by_abbrev(abbrev).unwrap();
        let tcp = sim::simulate(&mxnet_tcp(NetConfig::infiniband_56g()), &d, Gpu::Gtx1080Ti);
        let ib = sim::simulate(&mxnet_ib(NetConfig::infiniband_56g()), &d, Gpu::Gtx1080Ti);
        let s = ib.throughput / tcp.throughput;
        assert!(s >= 1.0, "{abbrev}: {s}");
        if abbrev == "AN" {
            an_speedup = s;
        } else {
            gn_speedup = s;
        }
    }
    assert!(an_speedup > gn_speedup, "{an_speedup} vs {gn_speedup}");
}

/// Figure 12: on 10 Gbps, PBox beats the enhanced baseline on every
/// network, with the peak speedup in the paper's 1.8-2.8x band and
/// PShard strictly between baseline and PBox for network-bound models.
#[test]
fn fig12_pbox_wins_on_10g() {
    let mut peak: f64 = 0.0;
    for abbrev in ["AN", "V11", "RN50", "GN"] {
        let d = Dnn::by_abbrev(abbrev).unwrap();
        let base = sim::simulate(&mxnet_ib(NetConfig::cloud_10g()), &d, Gpu::Gtx1080Ti);
        let pshard = sim::simulate(
            &testbed()
                .with_ps(PsConfig::ColocatedSharded)
                .with_net(NetConfig::cloud_10g()),
            &d,
            Gpu::Gtx1080Ti,
        );
        let pbox = sim::simulate(&testbed().with_net(NetConfig::cloud_10g()), &d, Gpu::Gtx1080Ti);
        let s_box = pbox.throughput / base.throughput;
        let s_shard = pshard.throughput / base.throughput;
        assert!(s_box >= s_shard * 0.99, "{abbrev}: pbox {s_box} < pshard {s_shard}");
        assert!(s_shard >= 0.95, "{abbrev}: pshard {s_shard}");
        peak = peak.max(s_box);
    }
    assert!(peak > 1.8 && peak < 2.9, "peak speedup {peak} (paper: up to 2.7x)");
}

/// Figure 13: at 56 Gbps, compute-bound networks see ~1x, AlexNet/VGG
/// remain network-bound and keep a large win.
#[test]
fn fig13_56g_only_big_models_win() {
    for (abbrev, lo, hi) in [("GN", 0.98, 1.1), ("I3", 0.98, 1.1), ("RN269", 0.98, 1.15)] {
        let d = Dnn::by_abbrev(abbrev).unwrap();
        let base = sim::simulate(&mxnet_ib(NetConfig::infiniband_56g()), &d, Gpu::Gtx1080Ti);
        let pbox = sim::simulate(&testbed(), &d, Gpu::Gtx1080Ti);
        let s = pbox.throughput / base.throughput;
        assert!(s >= lo && s <= hi, "{abbrev}: {s}");
    }
    for abbrev in ["AN", "V11"] {
        let d = Dnn::by_abbrev(abbrev).unwrap();
        let base = sim::simulate(&mxnet_ib(NetConfig::infiniband_56g()), &d, Gpu::Gtx1080Ti);
        let pbox = sim::simulate(&testbed(), &d, Gpu::Gtx1080Ti);
        let s = pbox.throughput / base.throughput;
        assert!(s > 1.5, "{abbrev}: {s} (stays network-bound on 56G)");
    }
}

/// Figure 15: with infinitely fast compute, PBox total exchange
/// throughput scales ~linearly 1->8 workers and dwarfs MXNet TCP.
#[test]
fn fig15_zerocompute_scaling() {
    let d = Dnn::by_abbrev("RN18").unwrap();
    let r1 = sim::simulate(&testbed().with_workers(1), &d, Gpu::ZeroCompute);
    let r8 = sim::simulate(&testbed().with_workers(8), &d, Gpu::ZeroCompute);
    let scaling = (8.0 * r8.exchange_rate) / r1.exchange_rate;
    assert!(scaling > 5.5, "PBox scaling 1->8: {scaling}x (paper: ~linear)");
    let tcp8 = sim::simulate(
        &mxnet_tcp(NetConfig::infiniband_56g()).with_workers(8),
        &d,
        Gpu::ZeroCompute,
    );
    let vs_tcp = r8.exchange_rate / tcp8.exchange_rate;
    assert!(vs_tcp > 10.0, "PBox vs MXNet TCP: {vs_tcp}x (paper: up to 40x)");
}

/// Section 4.5: Key-by-Interface beats Worker-by-Interface by ~1.4x.
#[test]
fn sec45_key_affinity() {
    let d = Dnn::by_abbrev("RN18").unwrap();
    let kbi = sim::simulate(&testbed(), &d, Gpu::ZeroCompute);
    let mut wbi_cfg = testbed();
    wbi_cfg.exchange.key_by_interface = false;
    let wbi = sim::simulate(&wbi_cfg, &d, Gpu::ZeroCompute);
    let ratio = kbi.exchange_rate / wbi.exchange_rate;
    assert!(
        ratio > 1.2 && ratio < 1.8,
        "KbI/WbI {ratio} (paper: 1.43x)"
    );
}

/// Figure 16 left: throughput peaks in the 16-64KB chunk band and falls
/// off on both sides (paper optimum: 32 KB).
#[test]
fn fig16_chunk_size_sweet_spot() {
    let d = Dnn::by_abbrev("RN18").unwrap();
    let rate = |kb: usize| {
        let mut c = testbed();
        c.exchange.chunk_bytes = kb * 1024;
        sim::simulate(&c, &d, Gpu::ZeroCompute).exchange_rate
    };
    let tiny = rate(4);
    let sweet = rate(32);
    let huge = rate(2048);
    assert!(sweet > tiny * 1.2, "small chunks should hurt: {sweet} vs {tiny}");
    assert!(sweet > huge * 1.5, "huge chunks should hurt: {sweet} vs {huge}");
}

/// Figure 16 right: more QPs per connection never helps (cache pressure).
#[test]
fn fig16_qp_monotone() {
    let d = Dnn::by_abbrev("RN18").unwrap();
    let mut prev = f64::INFINITY;
    for qps in [1usize, 4, 16, 64] {
        let mut c = testbed();
        c.net.qps_per_connection = qps;
        let r = sim::simulate(&c, &d, Gpu::ZeroCompute).exchange_rate;
        assert!(r <= prev * 1.01, "qps={qps}: {r} > {prev}");
        prev = r;
    }
}

/// Figure 18: per-job efficiency under multi-tenancy stays within a few
/// percent of fair share (the paper's "low interference" claim).
#[test]
fn fig18_low_tenant_interference() {
    let d = Dnn::by_abbrev("RN50").unwrap();
    let c = testbed().with_net(NetConfig::cloud_10g());
    let solo = sim::simulate(&c, &d, Gpu::Gtx1080Ti).throughput;
    for jobs in [2usize, 8] {
        let r = sim::simulate_opts(
            &c,
            &d,
            Gpu::Gtx1080Ti,
            SimOpts {
                tenants: jobs,
                ..SimOpts::default()
            },
        );
        let normalized = r.throughput * jobs as f64 / solo;
        assert!(
            normalized > 0.85 && normalized < 1.1,
            "jobs={jobs}: normalized per-job efficiency {normalized}"
        );
    }
}

/// The progressive breakdown is internally consistent across stacks: PHub
/// strictly reduces every overhead segment vs MXNet TCP on AlexNet.
#[test]
fn breakdown_phub_reduces_every_segment() {
    let d = Dnn::by_abbrev("AN").unwrap();
    let mx =
        sim::breakdown::progressive(&mxnet_tcp(NetConfig::infiniband_56g()), &d, Gpu::Gtx1080Ti);
    let ph = sim::breakdown::progressive(&testbed(), &d, Gpu::Gtx1080Ti);
    assert!(ph.data_copy_comm < mx.data_copy_comm);
    assert!(ph.aggregation <= mx.aggregation + 1e-9);
    assert!(ph.optimization <= mx.optimization + 1e-9);
    assert!(ph.sync_other <= mx.sync_other + 1e-9);
    assert_eq!(ph.compute, mx.compute);
}
