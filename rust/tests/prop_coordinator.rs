//! Property-based tests over coordinator invariants (routing, chunking,
//! aggregation, optimizer state) using the in-crate `prop` harness.

use std::sync::Arc;

use phub::coordinator::aggregation::ChunkAggregator;
use phub::coordinator::chunk::KeyTable;
use phub::coordinator::compress::{ChunkQuantizer, QuantGrad};
use phub::coordinator::engine::{Reply, RoundTag};
use phub::coordinator::kernels;
use phub::coordinator::mapping;
use phub::coordinator::optimizer::{NesterovSgd, Optimizer, Sgd};
use phub::coordinator::pool::{BytePool, Pool};
use phub::coordinator::server::{PHubServer, ServerConfig, WorkerHandle};
use phub::coordinator::wire;
use phub::prop::{check, Rng};

/// Chunking invariant: for any key layout and chunk size, chunks tile the
/// flat model exactly, never span keys, and never exceed the chunk size.
#[test]
fn prop_chunking_tiles_model() {
    check("chunking tiles model", 200, |rng: &mut Rng| {
        let n_keys = rng.usize_in(1, 40);
        let keys: Vec<(String, usize)> = (0..n_keys)
            .map(|i| (format!("k{i}"), rng.usize_in(1, 5000)))
            .collect();
        let chunk = rng.usize_in(1, 1024);
        let t = KeyTable::new(&keys, chunk);
        t.check_invariants();
        let expect: usize = keys
            .iter()
            .map(|(_, l)| l.div_ceil(chunk))
            .sum();
        if t.n_chunks() != expect {
            return Err(format!("chunk count {} != {expect}", t.n_chunks()));
        }
        Ok(())
    });
}

/// LPT routing invariant: every item is assigned exactly one bin, and the
/// makespan respects the 4/3 bound vs the trivial lower bound.
#[test]
fn prop_lpt_within_bound() {
    check("lpt 4/3 bound", 300, |rng: &mut Rng| {
        let n = rng.usize_in(1, 200);
        let bins = rng.usize_in(1, 32);
        let w = rng.weights(n, 10_000);
        let assign = mapping::lpt_partition(&w, bins);
        if assign.len() != n {
            return Err("assignment length".into());
        }
        if assign.iter().any(|&b| b >= bins) {
            return Err("bin out of range".into());
        }
        let ms = mapping::makespan(&w, &assign, bins) as f64;
        let total: usize = w.iter().sum();
        let lb = (total as f64 / bins as f64).max(*w.iter().max().unwrap() as f64);
        if ms > lb * 4.0 / 3.0 + 1.0 {
            return Err(format!("makespan {ms} > 4/3 * {lb}"));
        }
        Ok(())
    });
}

/// NUMA invariant: chunk_slot never pairs a core with a NIC from another
/// NUMA domain, for any (nics, cores, numa) geometry.
#[test]
fn prop_chunk_slot_numa_affinity() {
    check("chunk_slot numa affinity", 300, |rng: &mut Rng| {
        let numa = rng.usize_in(1, 5);
        let nics = rng.usize_in(numa, 33);
        let cores = rng.usize_in(numa.max(2), 129);
        for g in 0..500 {
            let (iface, core) = mapping::chunk_slot(g, nics, cores, numa);
            if iface >= nics || core >= cores {
                return Err(format!("slot out of range: {iface},{core}"));
            }
            if mapping::nic_numa(iface, nics, numa) != mapping::core_numa(core, cores, numa) {
                return Err(format!(
                    "numa mismatch g={g} iface={iface} core={core} ({nics},{cores},{numa})"
                ));
            }
        }
        Ok(())
    });
}

/// Aggregation invariant: for any worker count and arrival order, the mean
/// equals the arithmetic mean (to f32 tolerance), independent of order.
#[test]
fn prop_aggregation_order_independent() {
    check("aggregation order independent", 200, |rng: &mut Rng| {
        let n = rng.usize_in(1, 17);
        let len = rng.usize_in(1, 300);
        let grads: Vec<Vec<f32>> = (0..n).map(|_| rng.vec_f32(len, 10.0)).collect();
        // Random arrival order.
        let mut order: Vec<usize> = (0..n).collect();
        for i in (1..n).rev() {
            let j = rng.usize_in(0, i + 1);
            order.swap(i, j);
        }
        let mut agg = ChunkAggregator::new(len, n);
        let mut ready = false;
        for &w in &order {
            ready = agg.absorb(w, &grads[w]).map_err(|e| e.to_string())?;
        }
        if !ready {
            return Err("not ready after all workers".into());
        }
        let mean = agg.take_mean().map_err(|e| e.to_string())?;
        for i in 0..len {
            let expect: f32 = grads.iter().map(|g| g[i]).sum::<f32>() / n as f32;
            if (mean[i] - expect).abs() > 1e-4 * expect.abs().max(1.0) {
                return Err(format!("mean[{i}] {} != {expect}", mean[i]));
            }
        }
        Ok(())
    });
}

/// Byte-level absorption is the slice path bit-for-bit for *arbitrary*
/// payload bit patterns — NaN payloads, infinities, and subnormals
/// included (`f32::from_le_bytes` is a pure bit reinterpretation, and
/// both paths run the same accumulate in the same order, so even NaN
/// propagation is identical).
#[test]
fn prop_absorb_bytes_bit_identical_to_absorb() {
    check("absorb_bytes == absorb", 150, |rng: &mut Rng| {
        let n = rng.usize_in(1, 9);
        let len = rng.usize_in(1, 100);
        // Raw bit patterns: a large fraction of u32 space is NaN/inf.
        let payloads: Vec<Vec<u8>> = (0..n)
            .map(|_| (0..len * 4).map(|_| rng.next_u64() as u8).collect())
            .collect();
        let mut by_slice = ChunkAggregator::new(len, n);
        let mut by_bytes = ChunkAggregator::new(len, n);
        for (w, p) in payloads.iter().enumerate() {
            let decoded = wire::bytes_to_f32s(p).map_err(|e| e.to_string())?;
            by_slice.absorb(w, &decoded).map_err(|e| e.to_string())?;
            by_bytes.absorb_bytes(w, p).map_err(|e| e.to_string())?;
        }
        let a: Vec<u32> = by_slice
            .take_mean()
            .map_err(|e| e.to_string())?
            .iter()
            .map(|x| x.to_bits())
            .collect();
        let b: Vec<u32> = by_bytes
            .take_mean()
            .map_err(|e| e.to_string())?
            .iter()
            .map(|x| x.to_bits())
            .collect();
        if a != b {
            return Err(format!("bit mismatch (n={n} len={len})"));
        }
        Ok(())
    });
}

/// The dequantize-absorb fold is dequantize-then-absorb bit-for-bit for
/// arbitrary packed level bytes (invalid 0b11 codes and ragged tails
/// included) and thresholds.
#[test]
fn prop_absorb_quant_bit_identical_to_dense() {
    check("absorb_quant == dequantize+absorb", 150, |rng: &mut Rng| {
        let n = rng.usize_in(1, 6);
        let len = rng.usize_in(1, 80);
        let threshold = 0.01 + rng.f64() as f32;
        let packed_len = len.div_ceil(4);
        let payloads: Vec<Vec<u8>> = (0..n)
            .map(|_| (0..packed_len).map(|_| rng.next_u64() as u8).collect())
            .collect();
        let mut dense = ChunkAggregator::new(len, n);
        let mut quant = ChunkAggregator::new(len, n);
        for (w, p) in payloads.iter().enumerate() {
            let qg = QuantGrad {
                threshold,
                len,
                packed: p.clone(),
            };
            dense.absorb(w, &qg.dequantize()).map_err(|e| e.to_string())?;
            quant
                .absorb_quant(w, threshold, len, p)
                .map_err(|e| e.to_string())?;
        }
        let a = dense.take_mean().map_err(|e| e.to_string())?.to_vec();
        let b = quant.take_mean().map_err(|e| e.to_string())?.to_vec();
        if a != b {
            return Err(format!("quant fold mismatch (n={n} len={len})"));
        }
        Ok(())
    });
}

/// The fused mean+optimizer pass (`take_mean_into_step` + `step_scaled`)
/// equals the unfused `take_mean` → `step` sequence bit-for-bit, for both
/// built-in optimizers, arbitrary worker counts, lengths, and
/// hyperparameters.
#[test]
fn prop_fused_mean_step_bit_identical() {
    check("fused == unfused mean+step", 100, |rng: &mut Rng| {
        let n = rng.usize_in(1, 8);
        let len = rng.usize_in(1, 100);
        let grads: Vec<Vec<f32>> = (0..n).map(|_| rng.vec_f32(len, 2.0)).collect();
        let fill = |agg: &mut ChunkAggregator| -> Result<(), String> {
            for (w, g) in grads.iter().enumerate() {
                agg.absorb(w, g).map_err(|e| e.to_string())?;
            }
            Ok(())
        };
        let opts: [Box<dyn Optimizer>; 2] = [
            Box::new(Sgd {
                lr: 0.01 + rng.f64() as f32,
            }),
            Box::new(NesterovSgd {
                lr: 0.01 + rng.f64() as f32,
                momentum: rng.f64() as f32 * 0.95,
            }),
        ];
        for opt in &opts {
            let mut p_unfused = rng.vec_f32(len, 1.0);
            let mut s_unfused = rng.vec_f32(len * opt.state_words(), 0.5);
            let mut p_fused = p_unfused.clone();
            let mut s_fused = s_unfused.clone();

            let mut a = ChunkAggregator::new(len, n);
            fill(&mut a)?;
            let mean = a.take_mean().map_err(|e| e.to_string())?;
            opt.step(&mut p_unfused, &mut s_unfused, mean);

            let mut b = ChunkAggregator::new(len, n);
            fill(&mut b)?;
            b.take_mean_into_step(|sum, inv_n| {
                opt.step_scaled(&mut p_fused, &mut s_fused, sum, inv_n)
            })
            .map_err(|e| e.to_string())?;

            if p_unfused != p_fused || s_unfused != s_fused {
                return Err(format!(
                    "{} fused pass diverged (n={n} len={len})",
                    opt.name()
                ));
            }
        }
        Ok(())
    });
}

/// Server state invariant: any number of rounds on any chunking equals the
/// sequential whole-vector optimizer (the server's sharded, multi-threaded
/// state machine introduces no drift).
#[test]
fn prop_server_matches_sequential() {
    check("server matches sequential", 25, |rng: &mut Rng| {
        let n_workers = rng.usize_in(1, 5);
        let elems = rng.usize_in(1, 40) * 8;
        let chunk = [8usize, 16, 64, 1024][rng.usize_in(0, 4)].min(elems);
        let cores = rng.usize_in(1, 5);
        let rounds = rng.usize_in(1, 4);
        let lr = 0.01 + rng.f64() as f32 * 0.2;
        let mu = rng.f64() as f32 * 0.95;
        let init = rng.vec_f32(elems, 1.0);
        let grads: Vec<Vec<Vec<f32>>> = (0..rounds)
            .map(|_| (0..n_workers).map(|_| rng.vec_f32(elems, 1.0)).collect())
            .collect();

        // Server path.
        let server = PHubServer::start(ServerConfig::cores(cores));
        let opt = NesterovSgd { lr, momentum: mu };
        let job = server.init_job(
            KeyTable::flat(elems, chunk),
            &init,
            Arc::new(opt.clone()),
            n_workers,
        );
        let mut handles: Vec<_> = (0..n_workers).map(|w| server.worker(job, w)).collect();
        let mut got = Vec::new();
        for r in 0..rounds {
            let models: Vec<Vec<f32>> = std::thread::scope(|s| {
                let joins: Vec<_> = handles
                    .iter_mut()
                    .enumerate()
                    .map(|(w, h)| {
                        let g = grads[r][w].clone();
                        s.spawn(move || h.push_pull(&g))
                    })
                    .collect();
                joins.into_iter().map(|j| j.join().unwrap()).collect()
            });
            for m in &models[1..] {
                if m != &models[0] {
                    return Err(format!("workers diverged at round {r}"));
                }
            }
            got = models.into_iter().next().unwrap();
        }
        PHubServer::shutdown(server);

        // Sequential reference.
        let mut p = init;
        let mut st = vec![0.0f32; elems];
        for r in 0..rounds {
            let mut mean = vec![0.0f32; elems];
            for w in 0..n_workers {
                for (a, g) in mean.iter_mut().zip(&grads[r][w]) {
                    *a += g / n_workers as f32;
                }
            }
            opt.step(&mut p, &mut st, &mean);
        }
        for (i, (a, b)) in got.iter().zip(&p).enumerate() {
            if (a - b).abs() > 1e-4 {
                return Err(format!("elem {i}: {a} vs {b}"));
            }
        }
        Ok(())
    });
}

/// Optimizer chunk-composition invariant for arbitrary split points: a
/// chunked application over any partition equals the whole-vector step.
#[test]
fn prop_optimizer_partition_invariant() {
    check("optimizer partition invariant", 150, |rng: &mut Rng| {
        let elems = rng.usize_in(2, 200);
        let opt = NesterovSgd {
            lr: 0.1,
            momentum: 0.9,
        };
        let g = rng.vec_f32(elems, 1.0);
        let mut p_whole = rng.vec_f32(elems, 1.0);
        let mut m_whole = rng.vec_f32(elems, 0.2);
        let mut p_split = p_whole.clone();
        let mut m_split = m_whole.clone();
        let cut = rng.usize_in(1, elems);
        opt.step(&mut p_whole, &mut m_whole, &g);
        {
            let (pa, pb) = p_split.split_at_mut(cut);
            let (ma, mb) = m_split.split_at_mut(cut);
            opt.step(pa, ma, &g[..cut]);
            opt.step(pb, mb, &g[cut..]);
        }
        if p_whole != p_split || m_whole != m_split {
            return Err(format!("partition at {cut} diverged"));
        }
        Ok(())
    });
}

/// Stateless SGD: same partition invariant.
#[test]
fn prop_sgd_partition_invariant() {
    check("sgd partition invariant", 100, |rng: &mut Rng| {
        let elems = rng.usize_in(2, 100);
        let opt = Sgd { lr: 0.3 };
        let g = rng.vec_f32(elems, 1.0);
        let mut whole = rng.vec_f32(elems, 1.0);
        let mut split = whole.clone();
        let cut = rng.usize_in(1, elems);
        opt.step(&mut whole, &mut [], &g);
        opt.step(&mut split[..cut], &mut [], &g[..cut]);
        opt.step(&mut split[cut..], &mut [], &g[cut..]);
        if whole != split {
            return Err("sgd split diverged".into());
        }
        Ok(())
    });
}

/// Collectives invariant: ring and halving-doubling all-reduce both equal
/// the elementwise sum for arbitrary rank counts / lengths.
#[test]
fn prop_collectives_equal_sum() {
    check("collectives equal sum", 100, |rng: &mut Rng| {
        let n = rng.usize_in(1, 12);
        let len = rng.usize_in(1, 200);
        let bufs: Vec<Vec<f32>> = (0..n).map(|_| rng.vec_f32(len, 5.0)).collect();
        let mut sum = vec![0.0f32; len];
        for b in &bufs {
            for (a, x) in sum.iter_mut().zip(b) {
                *a += x;
            }
        }
        let mut ring = bufs.clone();
        phub::collectives::ring_allreduce_inplace(&mut ring);
        for b in &ring {
            for (a, s) in b.iter().zip(&sum) {
                if (a - s).abs() > 1e-3 * s.abs().max(1.0) {
                    return Err(format!("ring mismatch n={n} len={len}"));
                }
            }
        }
        // Halving-doubling needs a power of two.
        let n2 = 1usize << rng.usize_in(0, 4);
        let bufs2: Vec<Vec<f32>> = (0..n2).map(|_| rng.vec_f32(len, 5.0)).collect();
        let mut sum2 = vec![0.0f32; len];
        for b in &bufs2 {
            for (a, x) in sum2.iter_mut().zip(b) {
                *a += x;
            }
        }
        let mut hd = bufs2.clone();
        phub::collectives::halving_doubling_allreduce_inplace(&mut hd);
        for b in &hd {
            for (a, s) in b.iter().zip(&sum2) {
                if (a - s).abs() > 1e-3 * s.abs().max(1.0) {
                    return Err(format!("hd mismatch n={n2} len={len}"));
                }
            }
        }
        Ok(())
    });
}

/// Hierarchical two-level reduction equals the flat mean for arbitrary
/// rack shapes.
#[test]
fn prop_two_level_reduce_equals_flat() {
    check("two-level reduce equals flat", 100, |rng: &mut Rng| {
        let racks = rng.usize_in(1, 5);
        let len = rng.usize_in(1, 100);
        let grads: Vec<Vec<Vec<f32>>> = (0..racks)
            .map(|_| {
                let workers = rng.usize_in(1, 5);
                (0..workers).map(|_| rng.vec_f32(len, 2.0)).collect()
            })
            .collect();
        let hier = phub::coordinator::hierarchy::two_level_reduce(&grads);
        let mut flat = vec![0.0f32; len];
        let mut cnt = 0usize;
        for rack in &grads {
            for g in rack {
                for (a, x) in flat.iter_mut().zip(g) {
                    *a += x;
                }
                cnt += 1;
            }
        }
        for x in flat.iter_mut() {
            *x /= cnt as f32;
        }
        for (i, (a, b)) in hier.iter().zip(&flat).enumerate() {
            if (a - b).abs() > 1e-3 {
                return Err(format!("elem {i}: {a} vs {b}"));
            }
        }
        Ok(())
    });
}

/// JSON parser round-trip-ish property: parse never panics on fuzzed
/// garbage, and valid generated documents parse to the expected depth.
#[test]
fn prop_jsonlite_fuzz_no_panic() {
    check("jsonlite fuzz", 500, |rng: &mut Rng| {
        let len = rng.usize_in(0, 64);
        let bytes: Vec<u8> = (0..len)
            .map(|_| b" {}[]\",:0123456789truefalsenul\\"[rng.usize_in(0, 31)])
            .collect();
        let s = String::from_utf8_lossy(&bytes);
        let _ = phub::jsonlite::parse(&s); // must not panic
        Ok(())
    });
}

/// The streaming chunk API (`push_chunk`/`recv_reply`, which the v1 wire
/// protocol rides on) produces bit-identical models to the monolithic
/// `push_pull` for any model/chunk geometry, core count, and per-worker
/// chunk submission order.
#[test]
fn prop_chunk_streaming_matches_monolithic() {
    check("chunk streaming == monolithic", 25, |rng: &mut Rng| {
        let n = rng.usize_in(4, 600);
        let chunk = rng.usize_in(1, n + 1);
        let cores = rng.usize_in(1, 5);
        let server = PHubServer::start(ServerConfig::cores(cores));
        let init = rng.vec_f32(n, 1.0);
        let opt = NesterovSgd {
            lr: 0.1,
            momentum: 0.9,
        };
        let ja = server.init_job(KeyTable::flat(n, chunk), &init, Arc::new(opt.clone()), 2);
        let jb = server.init_job(KeyTable::flat(n, chunk), &init, Arc::new(opt.clone()), 2);
        let g0 = rng.vec_f32(n, 1.0);
        let g1 = rng.vec_f32(n, 1.0);

        // Job A: monolithic push_pull, two concurrent workers.
        let mut ha: Vec<_> = (0..2).map(|w| server.worker(ja, w)).collect();
        let (a0, a1) = ha.split_at_mut(1);
        let ma = std::thread::scope(|s| {
            let t = s.spawn(|| a1[0].push_pull(&g1));
            let m = a0[0].push_pull(&g0);
            let _ = t.join().unwrap();
            m
        });

        // Job B: per-chunk pushes in independent shuffled orders.
        let mut hb: Vec<_> = (0..2).map(|w| server.worker(jb, w)).collect();
        let n_chunks = hb[0].n_chunks();
        let shuffled = |rng: &mut Rng| {
            let mut order: Vec<usize> = (0..n_chunks).collect();
            for i in (1..n_chunks).rev() {
                order.swap(i, rng.usize_in(0, i + 1));
            }
            order
        };
        let order0 = shuffled(rng);
        let order1 = shuffled(rng);
        let stream = |h: &mut WorkerHandle, g: &[f32], order: &[usize]| -> Vec<f32> {
            for &i in order {
                let (lo, hi) = h.chunk_range(i);
                h.push_chunk(i as u32, g[lo..hi].into(), true);
            }
            let mut model = vec![0.0f32; h.model_len()];
            for _ in 0..order.len() {
                match h.recv_reply() {
                    Reply::Chunk { chunk, data, .. } => {
                        let (lo, hi) = h.chunk_range(chunk as usize);
                        model[lo..hi].copy_from_slice(&data);
                    }
                    other => panic!("unexpected reply {other:?}"),
                }
            }
            h.advance_round();
            model
        };
        let (b0, b1) = hb.split_at_mut(1);
        let mb = std::thread::scope(|s| {
            let t = s.spawn(|| stream(&mut b1[0], &g1, &order1));
            let m = stream(&mut b0[0], &g0, &order0);
            let _ = t.join().unwrap();
            m
        });

        PHubServer::shutdown(server);
        if ma != mb {
            return Err(format!(
                "streamed != monolithic (n={n} chunk={chunk} cores={cores})"
            ));
        }
        Ok(())
    });
}

/// Collect exactly one `epoch`-stamped reply per chunk for this worker,
/// skipping anything left over from rolled-back rounds (stale chunk
/// replies, rollback notices).
fn collect_epoch(h: &mut WorkerHandle, epoch: u32) -> Vec<f32> {
    let n_chunks = h.n_chunks();
    let mut model = vec![0.0f32; h.model_len()];
    let mut seen = vec![false; n_chunks];
    let mut got = 0usize;
    while got < n_chunks {
        if let Reply::Chunk {
            chunk,
            epoch: e,
            data,
            ..
        } = h.recv_reply()
        {
            let ci = chunk as usize;
            if e != epoch || seen[ci] {
                continue;
            }
            seen[ci] = true;
            let (lo, hi) = h.chunk_range(ci);
            model[lo..hi].copy_from_slice(&data);
            got += 1;
        }
    }
    model
}

/// Rollback equivalence (the tentpole's correctness bar): for any model /
/// chunk geometry, core count, and worker count, a round that is
/// partially pushed, rolled back with `rollback_round`, and then fully
/// replayed produces parameters bit-identical to a clean round on a twin
/// job. Pushes are issued worker-major in both jobs so every chunk sees
/// the same absorb order (f32 addition is order-sensitive beyond two
/// workers; the engine must not add any reordering of its own).
///
/// The interrupted job pushes through the **pooled byte path**
/// (`push_chunk_bytes_tagged` with recycling frame buffers — the form
/// the TCP leader forwards) while the clean twin uses plain slices, so
/// this also proves replay through pooled buffers changes no bits.
#[test]
fn prop_rollback_replay_bit_identical() {
    check("rollback replay bit identical", 20, |rng: &mut Rng| {
        let n_workers = rng.usize_in(2, 7);
        let elems = rng.usize_in(1, 30) * 8;
        let chunk = [4usize, 8, 16, 64][rng.usize_in(0, 4)].min(elems);
        let cores = rng.usize_in(1, 5);
        let server = PHubServer::start(ServerConfig::cores(cores));
        let init = rng.vec_f32(elems, 1.0);
        let opt = NesterovSgd {
            lr: 0.05 + rng.f64() as f32 * 0.2,
            momentum: rng.f64() as f32 * 0.9,
        };
        let ja = server.init_job(
            KeyTable::flat(elems, chunk),
            &init,
            Arc::new(opt.clone()),
            n_workers,
        );
        let jb = server.init_job(
            KeyTable::flat(elems, chunk),
            &init,
            Arc::new(opt.clone()),
            n_workers,
        );
        let grads: Vec<Vec<f32>> = (0..n_workers).map(|_| rng.vec_f32(elems, 1.0)).collect();

        // Job A: a random partial round (worker-major) pushed through
        // the pooled byte path, then rollback, then a full worker-major
        // byte-path replay.
        let pool: Arc<BytePool> = Pool::new(64);
        let push_bytes = |h: &WorkerHandle, c: usize, g: &[f32], tag: RoundTag| {
            let (lo, hi) = h.chunk_range(c);
            let mut fb = pool.take();
            for x in &g[lo..hi] {
                fb.extend_from_slice(&x.to_le_bytes());
            }
            h.push_chunk_bytes_tagged(c as u32, fb, 0, false, true, tag);
        };
        let mut ha: Vec<_> = (0..n_workers).map(|w| server.worker(ja, w)).collect();
        let n_chunks = ha[0].n_chunks();
        for (w, h) in ha.iter_mut().enumerate() {
            for c in 0..n_chunks {
                if rng.usize_in(0, 3) == 0 {
                    push_bytes(h, c, &grads[w], RoundTag::new(0, 0));
                }
            }
        }
        server.rollback_round(ja, 1);
        for (w, h) in ha.iter_mut().enumerate() {
            h.set_tag(1, 0);
            for c in 0..n_chunks {
                push_bytes(h, c, &grads[w], RoundTag::new(1, 0));
            }
        }
        let models_a: Vec<Vec<f32>> = ha.iter_mut().map(|h| collect_epoch(h, 1)).collect();

        // Job B: one clean worker-major round.
        let mut hb: Vec<_> = (0..n_workers).map(|w| server.worker(jb, w)).collect();
        for (w, h) in hb.iter_mut().enumerate() {
            for c in 0..n_chunks {
                let (lo, hi) = h.chunk_range(c);
                h.push_chunk(c as u32, grads[w][lo..hi].into(), true);
            }
        }
        let models_b: Vec<Vec<f32>> = hb.iter_mut().map(|h| collect_epoch(h, 0)).collect();

        PHubServer::shutdown(server);
        for w in 0..n_workers {
            if models_a[w] != models_b[w] {
                return Err(format!(
                    "worker {w}: replayed round != clean round \
                     (elems={elems} chunk={chunk} cores={cores} workers={n_workers})"
                ));
            }
        }
        Ok(())
    });
}

/// Hierarchy equivalence (the leader-of-leaders correctness bar): for
/// any rack count, workers-per-rack, geometry, and core counts, two
/// aggregation levels — rack relays forwarding raw sums to a root whose
/// mean is weighted by each relay's worker count — produce parameters
/// **bit-identical** to a flat single-leader run over the same leaf
/// gradients. Dense and quantized. Gradients are dyadic rationals
/// (multiples of 1/8, bounded) and the hyperparameters powers of two,
/// so every sum and optimizer product is exact in f32 under any
/// association — the flat `((g0+g1)+g2)+g3` and the two-level
/// `(g0+g1)+(g2+g3)` must therefore agree to the last bit.
#[test]
fn prop_two_level_bit_identical_to_flat() {
    check("two level bit identical to flat", 10, |rng: &mut Rng| {
        let racks = rng.usize_in(2, 4);
        let k = rng.usize_in(1, 3); // workers per rack
        let elems = rng.usize_in(1, 12) * 8;
        let chunk = [4usize, 8, 16, 64][rng.usize_in(0, 4)].min(elems);
        let rounds = rng.usize_in(1, 3);
        let threshold = 0.0625f32; // dyadic, so dequantized sums stay exact
        let leaves = racks * k;
        let opt = NesterovSgd {
            lr: 0.25,
            momentum: 0.5,
        };
        let init: Vec<f32> = (0..elems).map(|i| (i % 8) as f32 * 0.25).collect();
        let dyadic = |rng: &mut Rng| -> Vec<f32> {
            (0..elems)
                .map(|_| (rng.usize_in(0, 65) as f32 - 32.0) * 0.125)
                .collect()
        };
        let grads: Vec<Vec<Vec<f32>>> = (0..rounds)
            .map(|_| (0..leaves).map(|_| dyadic(rng)).collect())
            .collect();
        let table = || KeyTable::flat(elems, chunk);
        let n_chunks = table().n_chunks();
        let chunk_lens: Vec<usize> = {
            let t = table();
            (0..n_chunks)
                .map(|c| {
                    let ck = t.chunks[c];
                    ck.len
                })
                .collect()
        };
        let ranges: Vec<(usize, usize)> = {
            let t = table();
            (0..n_chunks)
                .map(|c| {
                    let ck = t.chunks[c];
                    (ck.offset, ck.offset + ck.len)
                })
                .collect()
        };

        for quant in [false, true] {
            // Per-seat payload bytes, quantized exactly once per round so
            // the flat job and the hierarchy consume identical bytes
            // (and identical error-feedback residual evolution).
            let mut banks: Vec<ChunkQuantizer> = (0..leaves)
                .map(|_| ChunkQuantizer::new(&chunk_lens, threshold))
                .collect();
            let payloads: Vec<Vec<Vec<Vec<u8>>>> = (0..rounds)
                .map(|r| {
                    (0..leaves)
                        .map(|s| {
                            (0..n_chunks)
                                .map(|c| {
                                    let (lo, hi) = ranges[c];
                                    let g = &grads[r][s][lo..hi];
                                    if quant {
                                        banks[s].quantize_chunk(c, g).to_bytes()
                                    } else {
                                        wire::f32s_to_bytes(g)
                                    }
                                })
                                .collect()
                        })
                        .collect()
                })
                .collect();
            let pool: Arc<BytePool> = Pool::new(64);
            let push = |h: &WorkerHandle, bytes: &[u8], c: usize, tag: RoundTag| {
                let mut fb = pool.take();
                fb.extend_from_slice(bytes);
                h.push_chunk_bytes_tagged(c as u32, fb, 0, quant, true, tag);
            };

            // Flat reference: one leader, all leaves direct.
            let flat_srv = PHubServer::start(ServerConfig::cores(rng.usize_in(1, 4)));
            let jf = flat_srv.init_job(table(), &init, Arc::new(opt.clone()), leaves);
            let mut hf: Vec<_> = (0..leaves).map(|s| flat_srv.worker(jf, s)).collect();
            let mut flat_model = Vec::new();
            for r in 0..rounds {
                for (s, h) in hf.iter().enumerate() {
                    for c in 0..n_chunks {
                        push(h, &payloads[r][s][c], c, RoundTag::new(0, r as u64));
                    }
                }
                let models: Vec<Vec<f32>> =
                    hf.iter_mut().map(|h| collect_epoch(h, 0)).collect();
                for h in hf.iter_mut() {
                    h.advance_round();
                }
                flat_model = models.into_iter().next().unwrap();
            }
            PHubServer::shutdown(flat_srv);

            // Two-level: one relay server per rack, raw sums pumped into
            // a root whose per-rack weights are the rack sizes.
            let root_srv = PHubServer::start(ServerConfig::cores(rng.usize_in(1, 4)));
            let jr = root_srv.init_job(table(), &init, Arc::new(opt.clone()), racks);
            for ri in 0..racks {
                root_srv.set_worker_weight(jr, ri as u32, k as u32);
            }
            let mut rack_srvs = Vec::new();
            let mut pumps = Vec::new();
            let mut rack_handles: Vec<Vec<WorkerHandle>> = Vec::new();
            for ri in 0..racks {
                let srv = PHubServer::start(ServerConfig::cores(rng.usize_in(1, 4)));
                let (job, mut up) =
                    srv.init_relay_job(table(), &init, Arc::new(opt.clone()), k);
                rack_handles.push((0..k).map(|w| srv.worker(job, w)).collect());
                let mut root_h = root_srv.worker(jr, ri);
                let pool = pool.clone();
                pumps.push(std::thread::spawn(move || {
                    for _ in 0..rounds {
                        for _ in 0..n_chunks {
                            match up.recv_sum() {
                                Some(Reply::Sum { chunk, data, .. }) => {
                                    root_h.push_chunk(chunk, data[..].into(), true);
                                }
                                other => panic!("pump expected Sum, got {other:?}"),
                            }
                        }
                        for _ in 0..n_chunks {
                            match root_h.recv_reply() {
                                Reply::Chunk { chunk, data, .. } => {
                                    let mut fb = pool.take();
                                    for x in &data[..] {
                                        fb.extend_from_slice(&x.to_le_bytes());
                                    }
                                    up.install_chunk_bytes(chunk, fb, 0);
                                }
                                other => panic!("pump expected Chunk, got {other:?}"),
                            }
                        }
                        root_h.advance_round();
                    }
                }));
                rack_srvs.push(srv);
            }
            let mut hier_models = Vec::new();
            for r in 0..rounds {
                for (ri, hs) in rack_handles.iter().enumerate() {
                    for (w, h) in hs.iter().enumerate() {
                        let seat = ri * k + w;
                        for c in 0..n_chunks {
                            push(h, &payloads[r][seat][c], c, RoundTag::new(0, r as u64));
                        }
                    }
                }
                hier_models = rack_handles
                    .iter_mut()
                    .flat_map(|hs| hs.iter_mut().map(|h| collect_epoch(h, 0)))
                    .collect::<Vec<_>>();
                for hs in rack_handles.iter_mut() {
                    for h in hs.iter_mut() {
                        h.advance_round();
                    }
                }
            }
            for p in pumps {
                p.join().unwrap();
            }
            for srv in rack_srvs {
                PHubServer::shutdown(srv);
            }
            PHubServer::shutdown(root_srv);

            for (i, m) in hier_models.iter().enumerate() {
                if m != &flat_model {
                    return Err(format!(
                        "leaf {i}: two-level != flat (quant={quant} racks={racks} \
                         k={k} elems={elems} chunk={chunk} rounds={rounds})"
                    ));
                }
            }
        }
        Ok(())
    });
}

/// Quantized rollback equivalence: per-chunk error-feedback residuals
/// live with the *worker*, and a replayed round re-applies the same
/// dequantized bytes exactly once — so a run whose second round is
/// interrupted and replayed matches a clean run bit-for-bit, residuals
/// included (each round's gradients are quantized exactly once and the
/// identical dequantized data drives both jobs).
#[test]
fn prop_rollback_replay_quantized_error_feedback() {
    check("quant rollback error feedback", 15, |rng: &mut Rng| {
        let n_workers = rng.usize_in(2, 5);
        let elems = rng.usize_in(1, 16) * 8;
        let chunk = [4usize, 8, 32][rng.usize_in(0, 3)].min(elems);
        let cores = rng.usize_in(1, 4);
        let threshold = 0.02 + rng.f64() as f32 * 0.1;
        let server = PHubServer::start(ServerConfig::cores(cores));
        let init = rng.vec_f32(elems, 0.5);
        let opt = NesterovSgd {
            lr: 0.1,
            momentum: 0.9,
        };
        let ja = server.init_job(
            KeyTable::flat(elems, chunk),
            &init,
            Arc::new(opt.clone()),
            n_workers,
        );
        let jb = server.init_job(
            KeyTable::flat(elems, chunk),
            &init,
            Arc::new(opt.clone()),
            n_workers,
        );
        let mut ha: Vec<_> = (0..n_workers).map(|w| server.worker(ja, w)).collect();
        let mut hb: Vec<_> = (0..n_workers).map(|w| server.worker(jb, w)).collect();
        let n_chunks = ha[0].n_chunks();
        let chunk_lens: Vec<usize> = (0..n_chunks)
            .map(|c| {
                let (lo, hi) = ha[0].chunk_range(c);
                hi - lo
            })
            .collect();
        // One client-side quantizer bank per worker, shared by both jobs:
        // each round is quantized exactly once, like a real worker would.
        let mut quants: Vec<ChunkQuantizer> = (0..n_workers)
            .map(|_| ChunkQuantizer::new(&chunk_lens, threshold))
            .collect();

        for round in 0..2u64 {
            // Sub-threshold gradients so only error feedback moves params.
            let dq: Vec<Vec<Vec<f32>>> = (0..n_workers)
                .map(|w| {
                    let g = rng.vec_f32(elems, threshold * 0.9);
                    (0..n_chunks)
                        .map(|c| {
                            let (lo, hi) = ha[0].chunk_range(c);
                            quants[w].quantize_chunk(c, &g[lo..hi]).dequantize()
                        })
                        .collect()
                })
                .collect();

            // Job A, round 1 only: partial push, rollback, full replay.
            if round == 1 {
                for (w, h) in ha.iter_mut().enumerate() {
                    if w % 2 == 0 {
                        h.push_chunk(0, dq[w][0].clone().into(), true);
                    }
                }
                server.rollback_round(ja, 1);
                for h in ha.iter_mut() {
                    h.set_tag(1, round);
                }
            }
            for (w, h) in ha.iter_mut().enumerate() {
                for c in 0..n_chunks {
                    h.push_chunk(c as u32, dq[w][c].clone().into(), true);
                }
            }
            let epoch_a = if round == 1 { 1 } else { 0 };
            let ma: Vec<Vec<f32>> = ha.iter_mut().map(|h| collect_epoch(h, epoch_a)).collect();
            for h in ha.iter_mut() {
                h.advance_round();
            }

            // Job B: clean rounds from the same dequantized data.
            for (w, h) in hb.iter_mut().enumerate() {
                for c in 0..n_chunks {
                    h.push_chunk(c as u32, dq[w][c].clone().into(), true);
                }
            }
            let mb: Vec<Vec<f32>> = hb.iter_mut().map(|h| collect_epoch(h, 0)).collect();
            for h in hb.iter_mut() {
                h.advance_round();
            }

            if ma != mb {
                return Err(format!(
                    "round {round}: interrupted quant run != clean run \
                     (elems={elems} chunk={chunk} workers={n_workers})"
                ));
            }
        }
        PHubServer::shutdown(server);
        Ok(())
    });
}

// ---------------------------------------------------------------------
// SIMD kernel bit-identity (see kernels.rs's dispatch contract): every
// available tier must match the scalar reference bit-for-bit on
// *arbitrary* input bit patterns — NaN payloads, infinities, and
// subnormals included — for the dense fold, the copy, the fused 2-bit
// dequantize paths, and both fused optimizers. The CI matrix runs these
// twice: once with native dispatch (AVX2 on hosted runners) and once
// under PHUB_KERNELS=scalar, so both dispatch arms stay proven.
// ---------------------------------------------------------------------

/// `tier_result == scalar_result`, compared as bit vectors.
fn bits_match(
    name: &str,
    tier: kernels::KernelTier,
    want: &[f32],
    got: &[f32],
) -> Result<(), String> {
    let w: Vec<u32> = want.iter().map(|x| x.to_bits()).collect();
    let g: Vec<u32> = got.iter().map(|x| x.to_bits()).collect();
    if w != g {
        return Err(format!(
            "{name} on {tier:?} diverged from scalar (len {})",
            want.len()
        ));
    }
    Ok(())
}

/// Dense kernels: copy-on-first-arrival and the LE-byte absorb fold.
#[test]
fn prop_simd_dense_kernels_bit_identical_to_scalar() {
    use kernels::KernelTier;
    let tiers = kernels::available_tiers();
    check("simd dense kernels == scalar", 200, |rng: &mut Rng| {
        // Lengths crossing both the 4-lane and 8-lane remainders.
        let len = rng.usize_in(1, 120);
        let bytes: Vec<u8> = (0..len * 4).map(|_| rng.next_u64() as u8).collect();
        let acc0: Vec<f32> = (0..len).map(|_| f32::from_bits(rng.next_u64() as u32)).collect();
        for &tier in &tiers {
            let mut want = vec![0.0f32; len];
            kernels::copy_f32s_le_tier(KernelTier::Scalar, &mut want, &bytes);
            let mut got = vec![0.0f32; len];
            kernels::copy_f32s_le_tier(tier, &mut got, &bytes);
            bits_match("copy_f32s_le", tier, &want, &got)?;

            let mut want = acc0.clone();
            kernels::add_assign_le_tier(KernelTier::Scalar, &mut want, &bytes);
            let mut got = acc0.clone();
            kernels::add_assign_le_tier(tier, &mut got, &bytes);
            bits_match("add_assign_le", tier, &want, &got)?;
        }
        Ok(())
    });
}

/// Quantized kernels: fused dequantize-copy and dequantize-absorb, with
/// arbitrary packed codes (invalid 0b11 included) and an arbitrary
/// threshold *bit pattern* — the mask-select decode must pass NaN and
/// negative-zero thresholds through untouched, exactly like the scalar
/// match.
#[test]
fn prop_simd_quant_kernels_bit_identical_to_scalar() {
    use kernels::KernelTier;
    let tiers = kernels::available_tiers();
    check("simd quant kernels == scalar", 200, |rng: &mut Rng| {
        let len = rng.usize_in(1, 120);
        let packed: Vec<u8> = (0..len.div_ceil(4)).map(|_| rng.next_u64() as u8).collect();
        let threshold = f32::from_bits(rng.next_u64() as u32);
        let acc0: Vec<f32> = (0..len).map(|_| f32::from_bits(rng.next_u64() as u32)).collect();
        for &tier in &tiers {
            let mut want = vec![0.0f32; len];
            kernels::copy_dequant_tier(KernelTier::Scalar, &mut want, threshold, &packed);
            let mut got = vec![0.0f32; len];
            kernels::copy_dequant_tier(tier, &mut got, threshold, &packed);
            bits_match("copy_dequant", tier, &want, &got)?;

            let mut want = acc0.clone();
            kernels::add_assign_dequant_tier(KernelTier::Scalar, &mut want, threshold, &packed);
            let mut got = acc0.clone();
            kernels::add_assign_dequant_tier(tier, &mut got, threshold, &packed);
            bits_match("add_assign_dequant", tier, &want, &got)?;
        }
        Ok(())
    });
}

/// Fused optimizer kernels: mean+SGD and mean+Nesterov, with arbitrary
/// bit patterns for parameters, momentum state, and the gradient sum
/// (finite hyperparameters, as real configs have).
#[test]
fn prop_simd_optimizer_kernels_bit_identical_to_scalar() {
    use kernels::KernelTier;
    let tiers = kernels::available_tiers();
    check("simd optimizer kernels == scalar", 200, |rng: &mut Rng| {
        let len = rng.usize_in(1, 120);
        let raw = |rng: &mut Rng| -> Vec<f32> {
            (0..len).map(|_| f32::from_bits(rng.next_u64() as u32)).collect()
        };
        let sum = raw(rng);
        let params0 = raw(rng);
        let state0 = raw(rng);
        let inv_n = 1.0f32 / rng.usize_in(1, 64) as f32;
        let lr = rng.f32_sym(2.0);
        let mu = rng.f32_sym(1.0);
        for &tier in &tiers {
            let mut want = params0.clone();
            kernels::sgd_step_scaled_tier(KernelTier::Scalar, &mut want, &sum, inv_n, lr);
            let mut got = params0.clone();
            kernels::sgd_step_scaled_tier(tier, &mut got, &sum, inv_n, lr);
            bits_match("sgd_step_scaled", tier, &want, &got)?;

            let (mut wp, mut wm) = (params0.clone(), state0.clone());
            kernels::nesterov_step_scaled_tier(
                KernelTier::Scalar,
                &mut wp,
                &mut wm,
                &sum,
                inv_n,
                lr,
                mu,
            );
            let (mut gp, mut gm) = (params0.clone(), state0.clone());
            kernels::nesterov_step_scaled_tier(tier, &mut gp, &mut gm, &sum, inv_n, lr, mu);
            bits_match("nesterov params", tier, &wp, &gp)?;
            bits_match("nesterov momentum", tier, &wm, &gm)?;
        }
        Ok(())
    });
}

/// End-to-end: a full aggregation round (absorb folds + fused optimizer)
/// through the *dispatched* path equals the forced-scalar tier composed
/// by hand — the wrappers in aggregation.rs/optimizer.rs delegate to the
/// same kernels the property tests above prove, so whatever tier the
/// host machine selects, rounds are bit-identical to scalar.
#[test]
fn prop_dispatched_round_bit_identical_to_scalar_tier() {
    use kernels::KernelTier;
    check("dispatched round == scalar tier", 100, |rng: &mut Rng| {
        let n = rng.usize_in(1, 5);
        let len = rng.usize_in(1, 80);
        let payloads: Vec<Vec<u8>> = (0..n)
            .map(|_| (0..len * 4).map(|_| rng.next_u64() as u8).collect())
            .collect();
        let lr = 0.1f32;

        // Dispatched path: ChunkAggregator + Sgd::step_scaled.
        let mut agg = ChunkAggregator::new(len, n);
        for (w, p) in payloads.iter().enumerate() {
            agg.absorb_bytes(w, p).map_err(|e| e.to_string())?;
        }
        let mut params: Vec<f32> = (0..len).map(|i| i as f32 * 0.01).collect();
        let opt = Sgd { lr };
        agg.take_mean_into_step(|sum, inv| opt.step_scaled(&mut params, &mut [], sum, inv))
            .map_err(|e| e.to_string())?;

        // Forced-scalar reference, composed from the tier-explicit fns.
        let mut acc = vec![0.0f32; len];
        kernels::copy_f32s_le_tier(KernelTier::Scalar, &mut acc, &payloads[0]);
        for p in &payloads[1..] {
            kernels::add_assign_le_tier(KernelTier::Scalar, &mut acc, p);
        }
        let mut want: Vec<f32> = (0..len).map(|i| i as f32 * 0.01).collect();
        kernels::sgd_step_scaled_tier(KernelTier::Scalar, &mut want, &acc, 1.0 / n as f32, lr);
        bits_match("round", kernels::active_tier(), &want, &params)
    });
}

/// Affine partition invariants, for arbitrary ragged chunk sizes: every
/// chunk gets a valid core, extents are contiguous (assignment is
/// non-decreasing), and no core's load exceeds its ideal share by more
/// than one chunk.
#[test]
fn prop_affine_partition_contiguous_and_balanced() {
    check("affine partition", 300, |rng: &mut Rng| {
        let n = rng.usize_in(1, 250);
        let cores = rng.usize_in(1, 24);
        let lens = rng.weights(n, 8192);
        let a = mapping::affine_partition(&lens, cores);
        if a.len() != n {
            return Err("assignment length".into());
        }
        if a.iter().any(|&c| c >= cores) {
            return Err("core out of range".into());
        }
        if !a.windows(2).all(|p| p[0] <= p[1]) {
            return Err(format!("extents not contiguous: {a:?}"));
        }
        let total: usize = lens.iter().sum();
        let max_len = *lens.iter().max().unwrap();
        let ms = mapping::makespan(&lens, &a, cores);
        if ms > total / cores + max_len {
            return Err(format!(
                "makespan {ms} > share {} + max chunk {max_len}",
                total / cores
            ));
        }
        Ok(())
    });
}
