//! Chaos soak over the TCP transport: a seeded, randomized fault
//! schedule (connection kills, mid-frame cuts, delays, duplicate frames
//! — see `coordinator::faults`) injected *under* every worker connection
//! must leave training **bit-identical** to an unfaulted twin, for
//! dense, quantized, and two-level deployments.
//!
//! Drivers react to injected failures exactly like production workers:
//! reconnect through a fresh proxy and resume from `rounds_done()`; the
//! leader's epoch-bump/rollback/replay recovery and the quantizers'
//! residual checkpoints (`ResidualSave` / `ResidualChunk`) do the rest.
//! Because a fault can tear the *final* model read, each faulted run
//! ends with an unfaulted **verification round** driven by fresh
//! successor connections — which doubles as the restore proof: for
//! quantized jobs the verification workers resume purely from
//! leader-held residual checkpoints, so their output bits match the
//! twin's only if the checkpoint equals the twin's in-memory
//! error-feedback state.
//!
//! `PHUB_FAULT_SEED=<u64>` pins the run to one seed (the CI chaos lane
//! runs a seed matrix); unset, a small built-in seed list runs.

use std::net::SocketAddr;
use std::time::{Duration, Instant};

use phub::config::QuotaConfig;
use phub::coordinator::faults::{FaultPlan, FaultProxy, FaultRates};
use phub::coordinator::server::ServerConfig;
use phub::coordinator::transport::{JobSpec, RelayConfig, TcpLeader, TcpWorker};

/// Training rounds driven under fault injection; round `ROUNDS` is the
/// unfaulted verification round.
const ROUNDS: usize = 5;
/// Overall per-frame fault probability (split 40/30/20/10 across
/// kill/cut/delay/duplicate by [`FaultRates::uniform`]).
const RATE: f32 = 0.06;
/// Quantization threshold for the quantized topology.
const THRESHOLD: f32 = 0.05;

fn spec(model: u64, chunk: u64, workers: u32) -> JobSpec {
    JobSpec {
        model_elems: model,
        chunk_elems: chunk,
        n_workers: workers,
        lr: 0.25,
        momentum: 0.9,
    }
}

/// Deterministic per-seat, per-round gradient. Mixes components above
/// and below [`THRESHOLD`] so quantization always leaves nonzero
/// error-feedback residuals for the checkpoint path to carry.
fn grad(n: usize, seat: usize, round: usize) -> Vec<f32> {
    (0..n)
        .map(|i| (seat as f32 - 0.5) * 0.7 + (round as f32 + 1.0) * 0.11 + (i % 13) as f32 * 0.009)
        .collect()
}

fn bits(v: &[f32]) -> Vec<u32> {
    v.iter().map(|x| x.to_bits()).collect()
}

fn seeds() -> Vec<u64> {
    match std::env::var("PHUB_FAULT_SEED") {
        Ok(v) => vec![v.trim().parse().expect("PHUB_FAULT_SEED must be a u64")],
        Err(_) => vec![1, 7, 1337],
    }
}

/// Drive one worker seat to `target` completed rounds against
/// `leader`, with every connection tunnelled through a fresh
/// single-connection [`FaultProxy`]. Each (re)connection attempt draws a
/// sub-seeded schedule, so the whole run is a function of `seed` plus
/// recovery-race timing — and the bit-identity assertion must hold for
/// *any* interleaving. Gradients are keyed by the leader-assigned slot
/// (`grad_base + slot`) so seats feed identical data no matter which
/// connection currently holds them.
fn chaos_seat(
    leader: SocketAddr,
    job: u32,
    s: JobSpec,
    quant: Option<f32>,
    grad_base: usize,
    seed: u64,
    target: usize,
) {
    let n = s.model_elems as usize;
    let mut scratch = vec![0.0f32; n];
    let rates = FaultRates::uniform(RATE);
    let deadline = Instant::now() + Duration::from_secs(60);
    let mut attempt = 0u64;
    loop {
        assert!(
            Instant::now() < deadline,
            "chaos seat wedged: job {job} seed {seed} never reached {target} rounds"
        );
        attempt += 1;
        let plan = FaultPlan::new(seed ^ attempt.wrapping_mul(0x9E37_79B9_7F4A_7C15), rates);
        let Ok(proxy) = FaultProxy::spawn(leader, plan) else {
            continue;
        };
        // A kill can land on the Hello frame itself, failing the
        // rendezvous; that is just another death to retry.
        let mut w = match TcpWorker::connect(proxy.addr(), job, s) {
            Ok(w) => w,
            Err(_) => {
                std::thread::sleep(Duration::from_millis(2));
                continue;
            }
        };
        let mut r = w.rounds_done() as usize;
        let slot = w.slot as usize;
        let mut died = false;
        while r < target {
            let g = grad(n, grad_base + slot, r);
            let res = match quant {
                Some(t) => w.push_pull_quant_into(&g, t, &mut scratch),
                None => w.push_pull_into(&g, &mut scratch),
            };
            match res {
                Ok(()) => r += 1,
                Err(_) => {
                    died = true;
                    break;
                }
            }
        }
        if !died {
            // Covers both a clean finish and a reconnect that found the
            // predecessor already done (`rounds_done() == target`).
            w.bye();
            return;
        }
        // Injected death: drop the connection (the leader parks the
        // seat and rolls the round back) and rejoin as a successor.
    }
}

/// Claim a seat after the chaos phase and run one unfaulted
/// verification round. Connecting can race the leader still parking a
/// dead predecessor's connection, so retry briefly.
fn verify_seat(
    leader: SocketAddr,
    job: u32,
    s: JobSpec,
    quant: Option<f32>,
    grad_base: usize,
) -> Vec<f32> {
    let n = s.model_elems as usize;
    let deadline = Instant::now() + Duration::from_secs(10);
    let mut w = loop {
        match TcpWorker::connect(leader, job, s) {
            Ok(w) => break w,
            Err(e) => {
                assert!(Instant::now() < deadline, "verification connect failed: {e:#}");
                std::thread::sleep(Duration::from_millis(2));
            }
        }
    };
    assert_eq!(w.rounds_done(), ROUNDS as u64, "chaos phase left the seat at the wrong round");
    let g = grad(n, grad_base + w.slot as usize, ROUNDS);
    let mut model = vec![0.0f32; n];
    match quant {
        Some(t) => w.push_pull_quant_into(&g, t, &mut model).unwrap(),
        None => w.push_pull_into(&g, &mut model).unwrap(),
    }
    w.bye();
    model
}

/// One unfaulted worker: `ROUNDS + 1` rounds (training plus the
/// verification round), same gradient schedule as the faulted run.
fn clean_worker(
    leader: SocketAddr,
    job: u32,
    s: JobSpec,
    quant: Option<f32>,
    grad_base: usize,
) -> Vec<f32> {
    let n = s.model_elems as usize;
    let mut w = TcpWorker::connect(leader, job, s).unwrap();
    let slot = w.slot as usize;
    let mut model = vec![0.0f32; n];
    for r in 0..=ROUNDS {
        let g = grad(n, grad_base + slot, r);
        match quant {
            Some(t) => w.push_pull_quant_into(&g, t, &mut model).unwrap(),
            None => w.push_pull_into(&g, &mut model).unwrap(),
        }
    }
    w.bye();
    model
}

/// Faulted flat run (2 seats through proxies, then 2 verification
/// successors) vs an unfaulted twin on a fresh leader. Returns the two
/// final models for the caller's bit-compare.
fn flat_run(seed: u64, quant: Option<f32>) -> (Vec<f32>, Vec<f32>) {
    let s = spec(192, 48, 2);
    let faulted = TcpLeader::serve("127.0.0.1:0", ServerConfig::cores(2)).unwrap();
    let addr = faulted.local_addr();
    let drivers: Vec<_> = (0..2u64)
        .map(|i| {
            let sub = seed ^ (i + 1).wrapping_mul(0xA24B_AED4_963E_E407);
            std::thread::spawn(move || chaos_seat(addr, 900, s, quant, 0, sub, ROUNDS))
        })
        .collect();
    for d in drivers {
        d.join().unwrap();
    }
    let verifiers: Vec<_> = (0..2)
        .map(|_| std::thread::spawn(move || verify_seat(addr, 900, s, quant, 0)))
        .collect();
    let models: Vec<Vec<f32>> = verifiers.into_iter().map(|j| j.join().unwrap()).collect();
    assert_eq!(bits(&models[0]), bits(&models[1]), "verification seats disagree");

    if quant.is_some() {
        // The verification successors resumed purely from leader-held
        // checkpoints — make sure that path actually ran.
        let m = faulted.server().metrics();
        assert!(m.residual_saves.get() > 0, "quantized soak committed no checkpoints");
        assert!(m.residual_restores.get() >= 2, "verification seats were not restored");
    }

    let clean = TcpLeader::serve("127.0.0.1:0", ServerConfig::cores(2)).unwrap();
    let clean_addr = clean.local_addr();
    let twins: Vec<_> = (0..2)
        .map(|_| std::thread::spawn(move || clean_worker(clean_addr, 901, s, quant, 0)))
        .collect();
    let twin_models: Vec<Vec<f32>> = twins.into_iter().map(|j| j.join().unwrap()).collect();
    assert_eq!(bits(&twin_models[0]), bits(&twin_models[1]), "clean twin seats disagree");

    (models.into_iter().next().unwrap(), twin_models.into_iter().next().unwrap())
}

/// Faulted quantized run *composed with an idle eviction and
/// readmission* (the tenant-guardrail path — see "Tenant guardrails" in
/// `coordinator::transport`): the leader evicts a job with zero live
/// connections after a short idle horizon, staging a parameter handoff
/// (params + optimizer state + residual checkpoints + per-seat rounds).
/// The schedule here forces that to happen mid-training — phase one
/// drives the seats to `ROUNDS / 2` under fault injection, every
/// connection leaves, the janitor evicts, and phase two readmits from
/// the handoff and finishes the run, still under fault injection. The
/// final bits must equal an unfaulted, never-evicted twin: eviction plus
/// readmission is exactly bit-neutral even when composed with kills,
/// cuts, duplicates, and rollback recovery on either side of it.
fn evicting_quant_run(seed: u64) -> (Vec<f32>, Vec<f32>) {
    let s = spec(192, 48, 2);
    let quota = QuotaConfig {
        idle_evict_after: Some(Duration::from_millis(25)),
        ..QuotaConfig::default()
    };
    let faulted =
        TcpLeader::serve("127.0.0.1:0", ServerConfig::cores(2).with_quota(quota)).unwrap();
    let addr = faulted.local_addr();
    let half = ROUNDS / 2;
    for (phase, target) in [(1u64, half), (2, ROUNDS)] {
        let drivers: Vec<_> = (0..2u64)
            .map(|i| {
                let sub = seed ^ (phase * 10 + i + 1).wrapping_mul(0xA24B_AED4_963E_E407);
                std::thread::spawn(move || {
                    chaos_seat(addr, 920, s, Some(THRESHOLD), 0, sub, target)
                })
            })
            .collect();
        for d in drivers {
            d.join().unwrap();
        }
        if phase == 1 {
            // All connections are gone; the janitor must evict the idle
            // job (and stage its handoff) before phase two readmits.
            let m = faulted.server().metrics();
            let deadline = Instant::now() + Duration::from_secs(10);
            while m.idle_evictions.get() == 0 {
                assert!(Instant::now() < deadline, "idle eviction never fired (seed {seed})");
                std::thread::sleep(Duration::from_millis(5));
            }
        }
    }
    let verifiers: Vec<_> = (0..2)
        .map(|_| std::thread::spawn(move || verify_seat(addr, 920, s, Some(THRESHOLD), 0)))
        .collect();
    let models: Vec<Vec<f32>> = verifiers.into_iter().map(|j| j.join().unwrap()).collect();
    assert_eq!(bits(&models[0]), bits(&models[1]), "evicting verification seats disagree");

    let m = faulted.server().metrics();
    assert!(m.readmissions.get() >= 1, "phase two never readmitted from the handoff");
    assert!(m.residual_saves.get() > 0, "evicting quantized soak committed no checkpoints");
    assert!(m.residual_restores.get() >= 2, "verification seats were not restored");

    let clean = TcpLeader::serve("127.0.0.1:0", ServerConfig::cores(2)).unwrap();
    let clean_addr = clean.local_addr();
    let twins: Vec<_> = (0..2)
        .map(|_| std::thread::spawn(move || clean_worker(clean_addr, 921, s, Some(THRESHOLD), 0)))
        .collect();
    let twin_models: Vec<Vec<f32>> = twins.into_iter().map(|j| j.join().unwrap()).collect();
    assert_eq!(bits(&twin_models[0]), bits(&twin_models[1]), "clean twin seats disagree");

    (models.into_iter().next().unwrap(), twin_models.into_iter().next().unwrap())
}

/// Faulted two-level run: a root, two rack relays, and four leaf seats
/// (two per rack) driven through proxies — faults land on the leaf
/// connections, so every rack-internal epoch bump must stay invisible
/// upstream. The unfaulted twin runs on a fresh root + relays.
fn two_level_run(seed: u64) -> (Vec<f32>, Vec<f32>) {
    let s = spec(192, 48, 2);

    let serve_tree = || {
        let root = TcpLeader::serve("127.0.0.1:0", ServerConfig::cores(2)).unwrap();
        let racks: Vec<_> = (0..2)
            .map(|_| {
                TcpLeader::serve_relay(
                    "127.0.0.1:0",
                    ServerConfig::cores(2),
                    RelayConfig { parent: root.local_addr().to_string(), racks: 2 },
                )
                .unwrap()
            })
            .collect();
        (root, racks)
    };

    let (_root, racks) = serve_tree();
    let drivers: Vec<_> = (0..4u64)
        .map(|j| {
            let rack = (j / 2) as usize;
            let addr = racks[rack].local_addr();
            let sub = seed ^ (j + 1).wrapping_mul(0xA24B_AED4_963E_E407);
            std::thread::spawn(move || chaos_seat(addr, 910, s, None, rack * 2, sub, ROUNDS))
        })
        .collect();
    for d in drivers {
        d.join().unwrap();
    }
    let verifiers: Vec<_> = (0..4usize)
        .map(|j| {
            let rack = j / 2;
            let addr = racks[rack].local_addr();
            std::thread::spawn(move || verify_seat(addr, 910, s, None, rack * 2))
        })
        .collect();
    let models: Vec<Vec<f32>> = verifiers.into_iter().map(|j| j.join().unwrap()).collect();
    for m in &models[1..] {
        assert_eq!(bits(&models[0]), bits(m), "two-level verification seats disagree");
    }

    let (_clean_root, clean_racks) = serve_tree();
    let twins: Vec<_> = (0..4usize)
        .map(|j| {
            let rack = j / 2;
            let addr = clean_racks[rack].local_addr();
            std::thread::spawn(move || clean_worker(addr, 911, s, None, rack * 2))
        })
        .collect();
    let twin_models: Vec<Vec<f32>> = twins.into_iter().map(|j| j.join().unwrap()).collect();
    for m in &twin_models[1..] {
        assert_eq!(bits(&twin_models[0]), bits(m), "two-level clean twin seats disagree");
    }

    (models.into_iter().next().unwrap(), twin_models.into_iter().next().unwrap())
}

/// The soak property: for every seed, a run laced with injected kills,
/// cuts, delays, and duplicates converges to exactly the bits of a run
/// that never saw a fault — dense flat, quantized flat (including
/// checkpoint restore of successor quantizer state), and two-level.
#[test]
fn prop_chaos_schedule_bit_identical() {
    for seed in seeds() {
        let (faulted, clean) = flat_run(seed, None);
        assert_eq!(bits(&faulted), bits(&clean), "dense flat diverged under fault seed {seed}");

        let (faulted, clean) = flat_run(seed.wrapping_add(101), Some(THRESHOLD));
        assert_eq!(bits(&faulted), bits(&clean), "quantized diverged under fault seed {seed}");

        let (faulted, clean) = two_level_run(seed.wrapping_add(202));
        assert_eq!(bits(&faulted), bits(&clean), "two-level diverged under fault seed {seed}");

        let (faulted, clean) = evicting_quant_run(seed.wrapping_add(303));
        assert_eq!(
            bits(&faulted),
            bits(&clean),
            "eviction/readmission diverged under fault seed {seed}"
        );
    }
}
