//! Tenant-guardrail integration tests over the live TCP leader (see
//! "Tenant guardrails" in `coordinator::transport`): weighted-fair core
//! scheduling keeps a small tenant's round latency bounded while a
//! noisy neighbor floods the same cores, and refusals are attributed to
//! the tenant that earned them — both observed exactly the way an
//! operator would see them, through `DataPlaneMetrics` / the per-job
//! registry that backs the `/jobs` status route.
//!
//! These are *robustness* assertions, not performance ones: the latency
//! bound is a generous absolute ceiling (CI runners are not a stable
//! perf environment — relative fairness ratios are `benches/tenancy.rs`'
//! concern), and every check reads counters the status plane already
//! exports, so a regression here is visible in production too.

use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

use phub::config::QuotaConfig;
use phub::coordinator::server::ServerConfig;
use phub::coordinator::transport::{JobSpec, TcpLeader, TcpWorker};
use phub::coordinator::{Refusal, RefuseReason};
use phub::metrics::JobMetricsSnapshot;

/// Victim (fair tenant) model size — distinct from [`FLOOD_ELEMS`] so
/// metric snapshots can identify tenants without knowing internal ids.
const VICTIM_ELEMS: u64 = 4 * 1024;
/// Flooder model size: 16x the victim, so each flooder round occupies
/// the cores 16x longer than a victim round does.
const FLOOD_ELEMS: u64 = 64 * 1024;
const CHUNK_ELEMS: u64 = 1024;
const VICTIM_ROUNDS: usize = 40;

fn spec(model: u64, workers: u32) -> JobSpec {
    JobSpec {
        model_elems: model,
        chunk_elems: CHUNK_ELEMS,
        n_workers: workers,
        lr: 0.01,
        momentum: 0.9,
    }
}

/// Pull the per-job snapshot entries whose `model_elems` gauge matches
/// `elems` (wire-job ids are not in the snapshot; the gauge is).
fn jobs_with_model(snap: &[JobMetricsSnapshot], elems: u64) -> Vec<JobMetricsSnapshot> {
    snap.iter().filter(|j| j.model_elems == elems).cloned().collect()
}

/// A 1-worker tenant with scheduling weight 8 shares a 2-core leader
/// with two single-worker flooder tenants (weight 1 each) hammering
/// 16x-larger models as fast as they can. Under deficit-round-robin the
/// victim's rounds keep landing: every one of its rounds completes and
/// its leader-observed p99 round latency stays under a generous
/// absolute ceiling, while the flooders demonstrably made progress (so
/// the test really measured contention, not an idle leader).
#[test]
fn noisy_neighbor_fair_tenant_round_latency_stays_bounded() {
    let quota = QuotaConfig {
        fair_sched: true,
        weights: vec![(1, 8), (2, 1), (3, 1)],
        ..QuotaConfig::default()
    };
    let leader = TcpLeader::serve("127.0.0.1:0", ServerConfig::cores(2).with_quota(quota)).unwrap();
    let addr = leader.local_addr();
    let metrics = leader.metrics_arc();

    // Flooders are single-worker jobs so each can stop at any round
    // boundary without deadlocking a push-pull peer.
    let stop = Arc::new(AtomicBool::new(false));
    let flooders: Vec<_> = [2u32, 3]
        .into_iter()
        .map(|wire_job| {
            let stop = stop.clone();
            std::thread::spawn(move || {
                let n = FLOOD_ELEMS as usize;
                let mut w = TcpWorker::connect(addr, wire_job, spec(FLOOD_ELEMS, 1)).unwrap();
                let grad = vec![0.25f32; n];
                let mut model = vec![0.0f32; n];
                let mut rounds = 0u64;
                while !stop.load(Ordering::Relaxed) {
                    w.push_pull_into(&grad, &mut model).unwrap();
                    rounds += 1;
                }
                w.bye();
                rounds
            })
        })
        .collect();

    // Only start the victim once both flooders are demonstrably mid-flood.
    let deadline = Instant::now() + Duration::from_secs(10);
    loop {
        let floods = jobs_with_model(&metrics.snapshot().jobs, FLOOD_ELEMS);
        if floods.len() == 2 && floods.iter().all(|j| j.rounds_completed >= 1) {
            break;
        }
        assert!(Instant::now() < deadline, "flooders never completed a round");
        std::thread::sleep(Duration::from_millis(2));
    }

    let n = VICTIM_ELEMS as usize;
    let mut victim = TcpWorker::connect(addr, 1, spec(VICTIM_ELEMS, 1)).unwrap();
    let grad = vec![0.5f32; n];
    let mut model = vec![0.0f32; n];
    for r in 0..VICTIM_ROUNDS {
        victim.push_pull_into(&grad, &mut model).unwrap_or_else(|e| {
            panic!("victim round {r} failed under flood: {e:#}");
        });
    }
    victim.bye();

    stop.store(true, Ordering::Relaxed);
    let flood_rounds: u64 = flooders.into_iter().map(|t| t.join().unwrap()).sum();
    assert!(flood_rounds > 0, "flooders made no progress");

    let snap = metrics.snapshot();
    let victims = jobs_with_model(&snap.jobs, VICTIM_ELEMS);
    assert_eq!(victims.len(), 1, "exactly one victim tenant expected");
    let v = &victims[0];
    assert_eq!(v.rounds_completed, VICTIM_ROUNDS as u64, "victim lost rounds");
    assert_eq!(v.refusals, 0, "victim was refused despite being admitted");
    assert_eq!(v.sched_weight, 8, "victim's configured weight not surfaced");
    for f in jobs_with_model(&snap.jobs, FLOOD_ELEMS) {
        assert_eq!(f.sched_weight, 1, "flooder weight not surfaced");
    }
    // Generous absolute ceiling (the histogram rounds quantiles up to
    // the next power-of-two bucket bound): a victim round is sub-ms of
    // work, so anything near seconds means the flooders starved it.
    let p99_ns = v.round_latency.quantile_ns(0.99);
    assert!(
        p99_ns < 2_000_000_000,
        "victim p99 round latency {:.1} ms under flood",
        p99_ns as f64 / 1e6
    );
}

/// Refusals are charged to the tenant that earned them: a well-behaved
/// tenant and an oversubscribing tenant share a leader, the
/// oversubscriber's extra worker is refused with the typed
/// `WorkerSlots` reason, and only *its* per-job `refusals` counter
/// moves — the neighbor's stays zero and its training is untouched.
#[test]
fn refusals_are_attributed_to_the_offending_tenant() {
    let leader = TcpLeader::serve("127.0.0.1:0", ServerConfig::cores(1)).unwrap();
    let addr = leader.local_addr();

    let n = VICTIM_ELEMS as usize;
    let mut good = TcpWorker::connect(addr, 1, spec(VICTIM_ELEMS, 1)).unwrap();
    let over = spec(FLOOD_ELEMS, 1);
    let seated = TcpWorker::connect(addr, 2, over).unwrap();

    // Second worker for a 1-seat job: typed, retriable, non-fatal.
    let err = TcpWorker::connect(addr, 2, over).unwrap_err();
    let refusal = err
        .downcast_ref::<Refusal>()
        .unwrap_or_else(|| panic!("expected a typed Refusal, got: {err:#}"));
    assert_eq!(refusal.reason, RefuseReason::WorkerSlots);
    assert!(refusal.retry_after > Duration::ZERO);

    // The neighbor trains straight through the refusal.
    let grad = vec![1.0f32; n];
    let mut model = vec![0.0f32; n];
    good.push_pull_into(&grad, &mut model).unwrap();
    good.bye();
    drop(seated);

    let snap = leader.metrics_arc().snapshot();
    assert!(snap.refused_quota >= 1, "global refusal counter did not move");
    let offender = &jobs_with_model(&snap.jobs, FLOOD_ELEMS)[0];
    let neighbor = &jobs_with_model(&snap.jobs, VICTIM_ELEMS)[0];
    assert_eq!(offender.refusals, 1, "refusal not charged to the offender");
    assert_eq!(neighbor.refusals, 0, "refusal leaked onto the neighbor");
    assert_eq!(neighbor.rounds_completed, 1);
}
