//! DNN model zoo: the nine networks of the paper's evaluation (Table 3).
//!
//! Each model carries its size, single-GPU forward+backward time per batch
//! (measured by the authors on a GTX 1080 Ti), and a synthetic per-layer
//! key table. The key table matters: a PS shards and schedules *keys*
//! (= layers), and the shape of the distribution — AlexNet/VGG dominated by
//! a few enormous fully-connected keys, ResNet/GoogleNet made of hundreds
//! of small convolutional keys — drives every overlap and load-balance
//! result in the paper.
//!
//! Layer tables are generated procedurally to match each family's
//! published architecture shape, then scaled so the total equals Table 3's
//! model size exactly.

/// One PS key (= one layer's parameter tensor).
#[derive(Debug, Clone, PartialEq)]
pub struct LayerKey {
    pub name: String,
    /// Parameter bytes (f32).
    pub bytes: usize,
    /// Fraction of the *backward* pass compute attributed to this layer.
    /// Gradients become available in reverse layer order; this controls
    /// when each key's gradient is ready for exchange.
    pub compute_frac: f64,
}

/// A network from Table 3.
#[derive(Debug, Clone)]
pub struct Dnn {
    pub name: &'static str,
    pub abbrev: &'static str,
    /// Total model size in bytes (Table 3 "Model Size").
    pub model_bytes: usize,
    /// Forward+backward time per batch on a GTX 1080 Ti, seconds (Table 3).
    pub time_per_batch: f64,
    /// Per-GPU batch size used in the paper.
    pub batch: usize,
    /// Per-layer key table, in *forward* order.
    pub layers: Vec<LayerKey>,
}

const MB: usize = 1024 * 1024;

/// Layer-family descriptor used by the procedural generator.
enum Family {
    /// Conv front + FC tail: (n_conv, fc_fracs of total size).
    FcHeavy { n_conv: usize, fc_fracs: &'static [f64] },
    /// Many conv keys with a mild geometric size ramp (deeper = wider).
    ConvHeavy { n_keys: usize },
}

fn gen_layers(total_bytes: usize, family: Family) -> Vec<LayerKey> {
    let mut layers = Vec::new();
    match family {
        Family::FcHeavy { n_conv, fc_fracs } => {
            let fc_total: f64 = fc_fracs.iter().sum();
            assert!(fc_total < 1.0);
            let conv_total = 1.0 - fc_total;
            // Conv sizes ramp geometrically (early convs are small).
            let ratio = 1.6f64;
            let weight_sum: f64 = (0..n_conv).map(|i| ratio.powi(i as i32)).sum();
            for i in 0..n_conv {
                let frac = conv_total * ratio.powi(i as i32) / weight_sum;
                layers.push(LayerKey {
                    name: format!("conv{i}"),
                    bytes: (total_bytes as f64 * frac) as usize,
                    // Convs dominate compute: weight them heavily.
                    compute_frac: 0.0, // filled below
                });
            }
            for (i, f) in fc_fracs.iter().enumerate() {
                layers.push(LayerKey {
                    name: format!("fc{i}"),
                    bytes: (total_bytes as f64 * f) as usize,
                    compute_frac: 0.0,
                });
            }
        }
        Family::ConvHeavy { n_keys } => {
            let ratio = 1.02f64;
            let weight_sum: f64 = (0..n_keys).map(|i| ratio.powi(i as i32)).sum();
            for i in 0..n_keys {
                let frac = ratio.powi(i as i32) / weight_sum;
                layers.push(LayerKey {
                    name: format!("conv{i}"),
                    bytes: (total_bytes as f64 * frac) as usize,
                    compute_frac: 0.0,
                });
            }
        }
    }
    // Fix rounding so sizes sum exactly to total_bytes.
    let sum: usize = layers.iter().map(|l| l.bytes).sum();
    let last = layers.len() - 1;
    layers[last].bytes += total_bytes - sum;

    // Compute weights: convolution backward is FLOP-heavy relative to its
    // parameter count; FC backward is a single GEMM over its (large)
    // parameters. Weight conv layers 16x per byte vs FC layers.
    let weights: Vec<f64> = layers
        .iter()
        .map(|l| {
            let w = if l.name.starts_with("conv") { 16.0 } else { 1.0 };
            w * l.bytes as f64
        })
        .collect();
    let wsum: f64 = weights.iter().sum();
    for (l, w) in layers.iter_mut().zip(weights) {
        l.compute_frac = w / wsum;
    }
    layers
}

impl Dnn {
    fn new(
        name: &'static str,
        abbrev: &'static str,
        model_mb: usize,
        time_ms: f64,
        batch: usize,
        family: Family,
    ) -> Self {
        let model_bytes = model_mb * MB;
        Dnn {
            name,
            abbrev,
            model_bytes,
            time_per_batch: time_ms / 1e3,
            batch,
            layers: gen_layers(model_bytes, family),
        }
    }

    /// All nine evaluation networks (paper Table 3).
    pub fn zoo() -> Vec<Dnn> {
        vec![
            // AlexNet: 5 convs, 3 FCs; fc6/fc7/fc8 hold ~95% of weights.
            Dnn::new("AlexNet", "AN", 194, 16.0, 32,
                Family::FcHeavy { n_conv: 5, fc_fracs: &[0.645, 0.245, 0.061] }),
            // VGG 11: 8 convs + 3 FCs; fc6 alone is ~74% of the model.
            Dnn::new("VGG 11", "V11", 505, 121.0, 32,
                Family::FcHeavy { n_conv: 8, fc_fracs: &[0.74, 0.12, 0.029] }),
            // VGG 19: 16 convs + 3 FCs.
            Dnn::new("VGG 19", "V19", 548, 268.0, 32,
                Family::FcHeavy { n_conv: 16, fc_fracs: &[0.68, 0.112, 0.027] }),
            Dnn::new("GoogleNet", "GN", 38, 100.0, 32, Family::ConvHeavy { n_keys: 59 }),
            Dnn::new("Inception V3", "I3", 91, 225.0, 32, Family::ConvHeavy { n_keys: 94 }),
            Dnn::new("ResNet 18", "RN18", 45, 54.0, 32, Family::ConvHeavy { n_keys: 21 }),
            Dnn::new("ResNet 50", "RN50", 97, 161.0, 32, Family::ConvHeavy { n_keys: 54 }),
            Dnn::new("ResNet 269", "RN269", 390, 350.0, 16, Family::ConvHeavy { n_keys: 269 }),
            Dnn::new("ResNext 269", "RX269", 390, 386.0, 8, Family::ConvHeavy { n_keys: 269 }),
        ]
    }

    /// Look up a network by abbreviation (e.g. "RN50").
    pub fn by_abbrev(abbrev: &str) -> Option<Dnn> {
        Self::zoo().into_iter().find(|d| d.abbrev == abbrev)
    }

    /// Local (single-node) training throughput in samples/s.
    pub fn local_throughput(&self) -> f64 {
        self.batch as f64 / self.time_per_batch
    }

    /// Communication-to-computation ratio: bytes exchanged per second of
    /// compute (one full model each way per iteration).
    pub fn comm_compute_ratio(&self) -> f64 {
        2.0 * self.model_bytes as f64 / self.time_per_batch
    }

    /// Number of PHub chunks for a given chunk size.
    pub fn n_chunks(&self, chunk_bytes: usize) -> usize {
        self.layers
            .iter()
            .map(|l| l.bytes.div_ceil(chunk_bytes))
            .sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn zoo_matches_table3_sizes() {
        let zoo = Dnn::zoo();
        assert_eq!(zoo.len(), 9);
        let expect: &[(&str, usize, f64)] = &[
            ("AN", 194, 16.0),
            ("V11", 505, 121.0),
            ("V19", 548, 268.0),
            ("GN", 38, 100.0),
            ("I3", 91, 225.0),
            ("RN18", 45, 54.0),
            ("RN50", 97, 161.0),
            ("RN269", 390, 350.0),
            ("RX269", 390, 386.0),
        ];
        for (abbrev, mb, ms) in expect {
            let d = Dnn::by_abbrev(abbrev).unwrap();
            assert_eq!(d.model_bytes, mb * MB, "{abbrev}");
            assert!((d.time_per_batch - ms / 1e3).abs() < 1e-9, "{abbrev}");
        }
    }

    #[test]
    fn layer_bytes_sum_exactly() {
        for d in Dnn::zoo() {
            let sum: usize = d.layers.iter().map(|l| l.bytes).sum();
            assert_eq!(sum, d.model_bytes, "{}", d.name);
        }
    }

    #[test]
    fn compute_fracs_sum_to_one() {
        for d in Dnn::zoo() {
            let sum: f64 = d.layers.iter().map(|l| l.compute_frac).sum();
            assert!((sum - 1.0).abs() < 1e-9, "{}: {sum}", d.name);
        }
    }

    #[test]
    fn alexnet_is_fc_dominated() {
        let an = Dnn::by_abbrev("AN").unwrap();
        let fc_bytes: usize = an
            .layers
            .iter()
            .filter(|l| l.name.starts_with("fc"))
            .map(|l| l.bytes)
            .sum();
        assert!(fc_bytes as f64 > 0.9 * an.model_bytes as f64);
    }

    #[test]
    fn resnet_has_many_small_keys() {
        let rn = Dnn::by_abbrev("RN269").unwrap();
        assert_eq!(rn.layers.len(), 269);
        let max = rn.layers.iter().map(|l| l.bytes).max().unwrap();
        // No single key dominates a conv-heavy model.
        assert!((max as f64) < 0.05 * rn.model_bytes as f64);
    }

    #[test]
    fn chunk_count() {
        let an = Dnn::by_abbrev("AN").unwrap();
        let n = an.n_chunks(32 * 1024);
        // 194 MB / 32 KB = 6208, plus per-layer ceil rounding.
        assert!(n >= 6208 && n < 6300, "{n}");
    }

    #[test]
    fn local_throughput_alexnet() {
        let an = Dnn::by_abbrev("AN").unwrap();
        assert!((an.local_throughput() - 2000.0).abs() < 1.0); // 32 / 16ms
    }
}
