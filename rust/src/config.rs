//! Cluster, network, and PS configuration types shared across the crate.
//!
//! These mirror the paper's experimental axes: PS placement (colocated vs
//! non-colocated, centralized vs sharded — Figure 4), link speed (10 vs
//! 56 Gbps), chunk size (section 3.2.3), queue-pair count (section 4.6),
//! and the PBox hardware balance point (section 3.3).

/// Parameter-server placement/sharding configuration (paper Figure 4).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum PsConfig {
    /// Colocated Centralized: one PS process on one worker machine.
    ColocatedCentralized,
    /// Colocated Sharded: a PS process on every worker machine (MXNet default).
    ColocatedSharded,
    /// Non-colocated Centralized: one dedicated PS machine.
    NonColocatedCentralized,
    /// Non-colocated Sharded: dedicated PS machines, one per worker.
    NonColocatedSharded,
    /// PBox: non-colocated centralized on balanced multi-NIC hardware (section 3.3).
    PBox,
}

impl PsConfig {
    pub const ALL: [PsConfig; 5] = [
        PsConfig::ColocatedCentralized,
        PsConfig::ColocatedSharded,
        PsConfig::NonColocatedCentralized,
        PsConfig::NonColocatedSharded,
        PsConfig::PBox,
    ];

    pub fn colocated(self) -> bool {
        matches!(
            self,
            PsConfig::ColocatedCentralized | PsConfig::ColocatedSharded
        )
    }

    pub fn sharded(self) -> bool {
        matches!(
            self,
            PsConfig::ColocatedSharded | PsConfig::NonColocatedSharded
        )
    }

    pub fn label(self) -> &'static str {
        match self {
            PsConfig::ColocatedCentralized => "CC",
            PsConfig::ColocatedSharded => "CS",
            PsConfig::NonColocatedCentralized => "NCC",
            PsConfig::NonColocatedSharded => "NCS",
            PsConfig::PBox => "PBox",
        }
    }
}

/// Which PS software stack runs the exchange (the paper's comparison axis).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Stack {
    /// MXNet PS-Lite over TCP/ZMQ: 4 data copies, wide aggregation,
    /// dispatcher-thread synchronization (section 2.3.2).
    MxnetTcp,
    /// "MXNet IB" enhanced baseline: zero-copy InfiniBand data plane but the
    /// unchanged PS architecture (section 4.3.1).
    MxnetIb,
    /// PHub software: chunking, tall aggregation, chunk→core mapping.
    PHub,
}

impl Stack {
    pub fn label(self) -> &'static str {
        match self {
            Stack::MxnetTcp => "MXNet",
            Stack::MxnetIb => "MXNet IB",
            Stack::PHub => "PHub",
        }
    }
}

/// Network fabric parameters.
#[derive(Debug, Clone)]
pub struct NetConfig {
    /// Per-port link bandwidth, Gbit/s (e.g. 10 or 56).
    pub link_gbps: f64,
    /// One-way propagation + switching latency per message, seconds.
    pub base_latency: f64,
    /// ToR-to-core oversubscription factor (1.0 = full bisection).
    pub oversubscription: f64,
    /// Queue pairs per (worker, interface) pair.
    pub qps_per_connection: usize,
    /// NIC QP-state cache capacity (entries) — misses add latency (section 4.6).
    pub qp_cache_entries: usize,
    /// Extra per-message latency on a QP cache miss, seconds.
    pub qp_cache_miss_penalty: f64,
}

impl NetConfig {
    pub fn infiniband_56g() -> Self {
        NetConfig {
            link_gbps: 56.0,
            base_latency: 2e-6,
            oversubscription: 1.0,
            qps_per_connection: 1,
            qp_cache_entries: 64,
            qp_cache_miss_penalty: 1.2e-6,
        }
    }

    /// Cloud-like 10 Gbps setting (the paper's down-clocked IB).
    pub fn cloud_10g() -> Self {
        NetConfig {
            link_gbps: 10.0,
            base_latency: 10e-6,
            ..Self::infiniband_56g()
        }
    }

    /// Link bandwidth in bytes/second.
    pub fn link_bytes_per_sec(&self) -> f64 {
        self.link_gbps * 1e9 / 8.0
    }
}

/// PHub/PBox host hardware (paper section 3.3 / 4.1 prototype).
#[derive(Debug, Clone)]
pub struct HostConfig {
    /// Physical cores available for gradient processing.
    pub cores: usize,
    /// NUMA domains.
    pub numa_domains: usize,
    /// Network interfaces attached (PBox = 10, worker = 1).
    pub nics: usize,
    /// Sustainable 1:1 read:write DRAM bandwidth, bytes/s.
    pub dram_bw: f64,
    /// PCIe-to-memory-bridge ceiling, bytes/s (section 4.7: the real limit).
    pub pcie_bridge_bw: f64,
    /// Per-core aggregation throughput with cache-resident buffers, bytes/s.
    pub core_agg_bw: f64,
}

impl HostConfig {
    /// The paper's PBox prototype: dual E5-2690 v4, 28 cores, 10x56 Gbps.
    pub fn pbox() -> Self {
        HostConfig {
            cores: 28,
            numa_domains: 2,
            nics: 10,
            dram_bw: 120e9,
            pcie_bridge_bw: 90e9,
            core_agg_bw: 7e9,
        }
    }

    /// The paper's worker: dual E5-2680 v4, one ConnectX-3.
    pub fn worker() -> Self {
        HostConfig {
            cores: 28,
            numa_domains: 2,
            nics: 1,
            dram_bw: 120e9,
            pcie_bridge_bw: 90e9,
            core_agg_bw: 7e9,
        }
    }
}

/// Chunking and exchange policy (paper sections 3.2.3-3.2.4).
#[derive(Debug, Clone)]
pub struct ExchangeConfig {
    /// Wire/aggregation chunk size in bytes (PHub default 32 KB).
    pub chunk_bytes: usize,
    /// Tall (chunked, per-core) vs wide (whole-key, thread-gang) aggregation.
    pub tall_aggregation: bool,
    /// Cached loads/stores vs non-temporal (cache-bypassing) agg/opt (section 4.5).
    pub cached_agg: bool,
    /// Key-affinity policy: keys by interface/core (true) vs worker by
    /// interface (false) (section 4.5 "Key Affinity in PBox").
    pub key_by_interface: bool,
}

impl Default for ExchangeConfig {
    fn default() -> Self {
        ExchangeConfig {
            chunk_bytes: 32 * 1024,
            tall_aggregation: true,
            cached_agg: true,
            key_by_interface: true,
        }
    }
}

impl ExchangeConfig {
    /// MXNet-like policy: 4 MB chunks, wide aggregation.
    pub fn mxnet() -> Self {
        ExchangeConfig {
            chunk_bytes: 4 * 1024 * 1024,
            tall_aggregation: false,
            cached_agg: true,
            key_by_interface: false,
        }
    }
}

/// Deadline supervision for the live TCP connection plane (not a paper
/// axis — operational robustness; see "Failure model & recovery
/// contract" in `coordinator::transport`). One struct names every knob
/// so `TcpLeader::serve_with` / client connects / the relay uplink all
/// share a single policy value.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct DeadlineConfig {
    /// Socket read/write timeout on client and uplink connections
    /// (`None` = block forever). Fires as a typed
    /// `wire::WireError::Timeout`.
    pub io_timeout: Option<std::time::Duration>,
    /// Leader-side per-connection read deadline. A worker that goes
    /// silent mid-round for this long is declared dead and its round is
    /// recovered via the normal epoch-bump/rollback/replay path. Idle
    /// connections *between* rounds are exempt (a parked tenant is not
    /// a stalled worker).
    pub round_deadline: Option<std::time::Duration>,
    /// First relay-uplink redial backoff; doubles per failed attempt.
    pub redial_base: std::time::Duration,
    /// Backoff ceiling for the uplink redial loop.
    pub redial_cap: std::time::Duration,
    /// Redial attempts before the uplink gives up and fails the job
    /// with a typed error (0 = retry forever, the legacy behavior).
    pub redial_attempts: u32,
}

impl Default for DeadlineConfig {
    fn default() -> Self {
        DeadlineConfig {
            io_timeout: Some(std::time::Duration::from_secs(30)),
            round_deadline: Some(std::time::Duration::from_secs(30)),
            redial_base: std::time::Duration::from_millis(25),
            redial_cap: std::time::Duration::from_millis(1600),
            redial_attempts: 60,
        }
    }
}

/// Tenant guardrails for a shared leader (not a paper axis —
/// operational robustness; see "Tenant guardrails" in
/// `coordinator::transport`). One struct names every admission,
/// fairness, shedding, and eviction knob so `TcpLeader`, the in-process
/// `PHubServer`, and tests all share a single policy value.
///
/// `Default` is fixed constants (no environment reads — tests stay
/// hermetic); [`QuotaConfig::from_env`] starts from the defaults and
/// applies `PHUB_*` overrides, which is what `ServerConfig::cores`
/// uses so deployments can be tuned without a rebuild.
#[derive(Debug, Clone, PartialEq)]
pub struct QuotaConfig {
    /// Leader-wide cap on concurrently hosted jobs (was the hard-coded
    /// `MAX_JOBS` const in `coordinator::transport`). Re-`Hello` of an
    /// already-hosted job is never counted against this cap.
    /// Env: `PHUB_MAX_JOBS`.
    pub max_jobs: usize,
    /// Per-job cap on worker seats a `JobSpec` may declare. The wire
    /// format enforces its own (larger) structural limit; this is the
    /// *policy* cap. Env: `PHUB_MAX_WORKERS_PER_JOB`.
    pub max_workers_per_job: u32,
    /// Per-job cap on model elements (f32 parameters). Env:
    /// `PHUB_MAX_MODEL_ELEMS`.
    pub max_model_elems_per_job: u64,
    /// Leader-wide cap on the sum of hosted model elements across all
    /// jobs — the memory guardrail. Env: `PHUB_MAX_TOTAL_MODEL_ELEMS`.
    pub max_total_model_elems: u64,
    /// Leader-wide cap on the sum of declared worker seats across all
    /// jobs — bounds aggregate in-flight push bandwidth, since every
    /// seat owns one fixed-capacity request ring. Env:
    /// `PHUB_MAX_TOTAL_WORKERS`.
    pub max_total_workers: u64,
    /// Per-job cap on aggregation cores (0 = all cores). Chunk
    /// placement partitions a job over at most this many cores, so one
    /// tenant cannot spread onto every core of a big leader. Env:
    /// `PHUB_MAX_CORES_PER_JOB`.
    pub max_cores_per_job: usize,
    /// Deficit-round-robin scheduling weight for jobs not listed in
    /// [`QuotaConfig::weights`] (min 1). Env: `PHUB_DEFAULT_WEIGHT`.
    pub default_weight: u32,
    /// Per-tenant scheduling weights, `(wire_job, weight)`. Env:
    /// `PHUB_TENANT_WEIGHTS` as `job=weight` pairs, e.g. `"7=4,9=2"`.
    pub weights: Vec<(u32, u32)>,
    /// Weighted-fair core scheduling on (true, the default) or the
    /// legacy greedy per-port sweep (false) — the control arm for the
    /// tenancy bench. Env: `PHUB_FAIR_SCHED` (`0`/`false` to disable).
    pub fair_sched: bool,
    /// Messages one weight unit buys a job per core sweep. The
    /// effective per-sweep budget of a job is `weight * sched_quantum`,
    /// with unused budget banked up to one extra sweep. Env:
    /// `PHUB_SCHED_QUANTUM`.
    pub sched_quantum: usize,
    /// Round-deadline trips inside [`QuotaConfig::shed_window`] that
    /// trip the overload watermark: while tripped, *new* admissions are
    /// shed with a retriable refusal; existing jobs are untouched. Env:
    /// `PHUB_SHED_TRIPS`.
    pub shed_trip_threshold: u32,
    /// Sliding window over which deadline trips are counted toward the
    /// overload watermark. Env: `PHUB_SHED_WINDOW_MS`.
    pub shed_window: std::time::Duration,
    /// Evict a job with zero live connections idle for this long,
    /// staging a parameter handoff so the tenant can readmit and resume
    /// bit-exact (`None` = never evict; the default). Env:
    /// `PHUB_IDLE_EVICT_MS` (`0` = off).
    pub idle_evict_after: Option<std::time::Duration>,
    /// Retry-after hint carried in every refusal frame. Env:
    /// `PHUB_RETRY_AFTER_MS`.
    pub retry_after: std::time::Duration,
}

impl Default for QuotaConfig {
    fn default() -> Self {
        QuotaConfig {
            max_jobs: 64,
            max_workers_per_job: 256,
            max_model_elems_per_job: 1 << 28,
            max_total_model_elems: 1 << 30,
            max_total_workers: 4096,
            max_cores_per_job: 0,
            default_weight: 1,
            weights: Vec::new(),
            fair_sched: true,
            sched_quantum: 64,
            shed_trip_threshold: 3,
            shed_window: std::time::Duration::from_secs(10),
            idle_evict_after: None,
            retry_after: std::time::Duration::from_millis(250),
        }
    }
}

impl QuotaConfig {
    /// Defaults with `PHUB_*` environment overrides applied (see the
    /// per-field docs for variable names). Malformed values fall back
    /// to the default rather than panicking a starting leader.
    pub fn from_env() -> Self {
        fn num<T: std::str::FromStr>(name: &str) -> Option<T> {
            std::env::var(name).ok().and_then(|v| v.trim().parse().ok())
        }
        let mut q = QuotaConfig::default();
        if let Some(v) = num("PHUB_MAX_JOBS") {
            q.max_jobs = v;
        }
        if let Some(v) = num("PHUB_MAX_WORKERS_PER_JOB") {
            q.max_workers_per_job = v;
        }
        if let Some(v) = num("PHUB_MAX_MODEL_ELEMS") {
            q.max_model_elems_per_job = v;
        }
        if let Some(v) = num("PHUB_MAX_TOTAL_MODEL_ELEMS") {
            q.max_total_model_elems = v;
        }
        if let Some(v) = num("PHUB_MAX_TOTAL_WORKERS") {
            q.max_total_workers = v;
        }
        if let Some(v) = num("PHUB_MAX_CORES_PER_JOB") {
            q.max_cores_per_job = v;
        }
        if let Some(v) = num::<u32>("PHUB_DEFAULT_WEIGHT") {
            q.default_weight = v.max(1);
        }
        if let Ok(spec) = std::env::var("PHUB_TENANT_WEIGHTS") {
            q.weights = Self::parse_weights(&spec);
        }
        if let Some(v) = num::<u8>("PHUB_FAIR_SCHED") {
            q.fair_sched = v != 0;
        }
        if let Some(v) = num::<usize>("PHUB_SCHED_QUANTUM") {
            q.sched_quantum = v.max(1);
        }
        if let Some(v) = num("PHUB_SHED_TRIPS") {
            q.shed_trip_threshold = v;
        }
        if let Some(v) = num::<u64>("PHUB_SHED_WINDOW_MS") {
            q.shed_window = std::time::Duration::from_millis(v);
        }
        if let Some(v) = num::<u64>("PHUB_IDLE_EVICT_MS") {
            q.idle_evict_after =
                (v > 0).then(|| std::time::Duration::from_millis(v));
        }
        if let Some(v) = num::<u64>("PHUB_RETRY_AFTER_MS") {
            q.retry_after = std::time::Duration::from_millis(v);
        }
        q
    }

    /// Parse a `"job=weight,job=weight"` tenant-weight spec; malformed
    /// pairs are skipped, weights clamp to at least 1.
    fn parse_weights(spec: &str) -> Vec<(u32, u32)> {
        spec.split(',')
            .filter_map(|pair| {
                let (job, w) = pair.split_once('=')?;
                let job: u32 = job.trim().parse().ok()?;
                let w: u32 = w.trim().parse().ok()?;
                Some((job, w.max(1)))
            })
            .collect()
    }

    /// Scheduling weight for a wire job id (min 1).
    pub fn weight_for(&self, wire_job: u32) -> u32 {
        self.weights
            .iter()
            .find(|(j, _)| *j == wire_job)
            .map(|&(_, w)| w)
            .unwrap_or(self.default_weight)
            .max(1)
    }
}

/// A full cluster description for one training job.
#[derive(Debug, Clone)]
pub struct ClusterConfig {
    pub n_workers: usize,
    pub ps: PsConfig,
    pub stack: Stack,
    pub net: NetConfig,
    pub worker_host: HostConfig,
    pub ps_host: HostConfig,
    pub exchange: ExchangeConfig,
    /// Number of racks the job spans (1 = rack-local, >1 exercises
    /// hierarchical reduction, section 3.4).
    pub racks: usize,
}

impl ClusterConfig {
    /// The paper's main testbed: 8 workers + PBox on 56 Gbps IB.
    pub fn paper_testbed() -> Self {
        ClusterConfig {
            n_workers: 8,
            ps: PsConfig::PBox,
            stack: Stack::PHub,
            net: NetConfig::infiniband_56g(),
            worker_host: HostConfig::worker(),
            ps_host: HostConfig::pbox(),
            exchange: ExchangeConfig::default(),
            racks: 1,
        }
    }

    pub fn with_stack(mut self, stack: Stack) -> Self {
        self.stack = stack;
        self
    }

    pub fn with_ps(mut self, ps: PsConfig) -> Self {
        self.ps = ps;
        self
    }

    pub fn with_workers(mut self, n: usize) -> Self {
        self.n_workers = n;
        self
    }

    pub fn with_net(mut self, net: NetConfig) -> Self {
        self.net = net;
        self
    }

    pub fn with_exchange(mut self, e: ExchangeConfig) -> Self {
        self.exchange = e;
        self
    }

    /// Number of PS processes implied by the PS configuration.
    pub fn n_ps_processes(&self) -> usize {
        if self.ps.sharded() {
            self.n_workers
        } else {
            1
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ps_config_axes() {
        assert!(PsConfig::ColocatedSharded.colocated());
        assert!(PsConfig::ColocatedSharded.sharded());
        assert!(!PsConfig::NonColocatedCentralized.colocated());
        assert!(!PsConfig::NonColocatedCentralized.sharded());
        assert!(!PsConfig::PBox.colocated());
    }

    #[test]
    fn link_bandwidth_conversion() {
        let n = NetConfig::infiniband_56g();
        assert!((n.link_bytes_per_sec() - 7e9).abs() < 1.0);
    }

    #[test]
    fn sharded_process_count() {
        let c = ClusterConfig::paper_testbed().with_ps(PsConfig::ColocatedSharded);
        assert_eq!(c.n_ps_processes(), 8);
        let c = c.with_ps(PsConfig::PBox);
        assert_eq!(c.n_ps_processes(), 1);
    }

    #[test]
    fn deadline_defaults_are_bounded() {
        let d = DeadlineConfig::default();
        // Every supervision knob is finite by default: a dead parent or
        // stalled worker cannot hang a job forever out of the box.
        assert!(d.io_timeout.is_some());
        assert!(d.round_deadline.is_some());
        assert!(d.redial_attempts > 0);
        assert!(d.redial_base <= d.redial_cap);
        // Worst-case redial wall clock stays bounded: attempts × cap.
        let worst = d.redial_cap * d.redial_attempts;
        assert!(worst <= std::time::Duration::from_secs(120));
    }

    #[test]
    fn quota_defaults_are_bounded_and_fair() {
        let q = QuotaConfig::default();
        // Every admission cap is finite and nonzero out of the box: a
        // leader can always host at least one sane job, and no single
        // tenant can take unbounded memory or seats.
        assert!(q.max_jobs >= 1);
        assert!(q.max_workers_per_job >= 1);
        assert!(q.max_model_elems_per_job >= 1);
        assert!(q.max_total_model_elems >= q.max_model_elems_per_job);
        assert!(q.max_total_workers >= u64::from(q.max_workers_per_job));
        // Fairness on by default, with a usable quantum and weight.
        assert!(q.fair_sched);
        assert!(q.sched_quantum >= 1);
        assert_eq!(q.weight_for(42), 1);
        // Shedding recovers (finite window), eviction is opt-in, and
        // the refusal hint tells clients to actually wait.
        assert!(q.shed_window > std::time::Duration::ZERO);
        assert!(q.idle_evict_after.is_none());
        assert!(q.retry_after > std::time::Duration::ZERO);
    }

    #[test]
    fn tenant_weight_spec_parses_and_clamps() {
        let w = QuotaConfig::parse_weights("7=4, 9=2,bad,3=,=5,11=0");
        assert_eq!(w, vec![(7, 4), (9, 2), (11, 1)]);
        let q = QuotaConfig { weights: w, ..QuotaConfig::default() };
        assert_eq!(q.weight_for(7), 4);
        assert_eq!(q.weight_for(9), 2);
        assert_eq!(q.weight_for(11), 1); // clamped up from 0
        assert_eq!(q.weight_for(999), 1); // default
    }

    #[test]
    fn default_exchange_is_phub_defaults() {
        let e = ExchangeConfig::default();
        assert_eq!(e.chunk_bytes, 32 * 1024);
        assert!(e.tall_aggregation);
        let m = ExchangeConfig::mxnet();
        assert_eq!(m.chunk_bytes, 4 * 1024 * 1024);
        assert!(!m.tall_aggregation);
    }
}
