//! Collective-communication baselines (paper section 5, Figure 20).
//!
//! The paper compares PHub against Gloo's collectives: ring all-reduce
//! (Baidu/Horovod style) and recursive halving-doubling (used in the
//! Facebook 1-hour ImageNet run). Both are implemented here *for real*
//! (executable data-parallel reductions used by the hierarchical path and
//! tests) and as *analytic time models* on the alpha-beta cost model for
//! the Figure 20 comparison.
//!
//! Why collectives lose to PBox (paper's analysis): (1) every participant
//! is effectively colocated — its NIC carries ~2x the data of a
//! non-colocated PS's client; (2) multi-round schedules (log N or N-1
//! rounds) multiply latency, while PBox needs exactly one round.

/// In-place ring all-reduce over `n` equal-length vectors: after the call
/// every vector holds the elementwise *sum*.
///
/// Reduce-scatter then all-gather, each `n-1` steps over contiguous
/// segments — the standard bandwidth-optimal schedule.
pub fn ring_allreduce_inplace(bufs: &mut [Vec<f32>]) {
    let n = bufs.len();
    assert!(n > 0);
    if n == 1 {
        return;
    }
    let len = bufs[0].len();
    assert!(bufs.iter().all(|b| b.len() == len));
    // Segment boundaries (segment s = [seg[s], seg[s+1])).
    let seg: Vec<usize> = (0..=n).map(|s| s * len / n).collect();

    // Reduce-scatter: at step t, rank r sends segment (r - t) to r+1 and
    // accumulates the segment arriving from r-1.
    for t in 0..n - 1 {
        // Compute all transfers for this step before mutating (simulating
        // the synchronous ring step).
        let moves: Vec<(usize, usize, Vec<f32>)> = (0..n)
            .map(|r| {
                let s = (r + n - t) % n;
                let src = &bufs[r][seg[s]..seg[s + 1]];
                ((r + 1) % n, s, src.to_vec())
            })
            .collect();
        for (dst, s, data) in moves {
            for (a, x) in bufs[dst][seg[s]..seg[s + 1]].iter_mut().zip(&data) {
                *a += x;
            }
        }
    }
    // All-gather: segment (r + 1 - t) travels around the ring.
    for t in 0..n - 1 {
        let moves: Vec<(usize, usize, Vec<f32>)> = (0..n)
            .map(|r| {
                let s = (r + 1 + n - t) % n;
                let src = &bufs[r][seg[s]..seg[s + 1]];
                ((r + 1) % n, s, src.to_vec())
            })
            .collect();
        for (dst, s, data) in moves {
            bufs[dst][seg[s]..seg[s + 1]].copy_from_slice(&data);
        }
    }
}

/// In-place recursive halving-doubling all-reduce (power-of-two ranks):
/// reduce-scatter by recursive vector halving, then all-gather by
/// recursive doubling.
pub fn halving_doubling_allreduce_inplace(bufs: &mut [Vec<f32>]) {
    let n = bufs.len();
    assert!(n.is_power_of_two(), "halving-doubling needs 2^k ranks");
    if n == 1 {
        return;
    }
    let len = bufs[0].len();
    // Track each rank's owned range through the halving.
    let mut lo = vec![0usize; n];
    let mut hi = vec![len; n];
    let mut dist = n / 2;
    while dist >= 1 {
        let snapshot: Vec<Vec<f32>> = bufs.to_vec();
        for r in 0..n {
            let peer = r ^ dist;
            let mid = (lo[r] + hi[r]) / 2;
            // Lower-half owner keeps [lo, mid), upper keeps [mid, hi).
            let keep_low = r & dist == 0;
            let (a, b) = if keep_low { (lo[r], mid) } else { (mid, hi[r]) };
            for i in a..b {
                bufs[r][i] += snapshot[peer][i];
            }
            if keep_low {
                hi[r] = mid;
            } else {
                lo[r] = mid;
            }
        }
        dist /= 2;
    }
    // All-gather by doubling: exchange owned ranges back up.
    dist = 1;
    while dist < n {
        let snapshot: Vec<Vec<f32>> = bufs.to_vec();
        for r in 0..n {
            let peer = r ^ dist;
            for i in lo[peer]..hi[peer] {
                bufs[r][i] = snapshot[peer][i];
            }
        }
        for r in 0..n {
            let peer = r ^ dist;
            lo[r] = lo[r].min(lo[peer]);
            hi[r] = hi[r].max(hi[peer]);
        }
        dist *= 2;
    }
}

// ---------------------------------------------------------------------------
// Analytic alpha-beta time models (Figure 20)
// ---------------------------------------------------------------------------

/// Alpha-beta cost parameters: per-message latency `alpha` (s) and
/// per-byte time `beta` (s/byte, = 1/bandwidth).
#[derive(Debug, Clone, Copy)]
pub struct AlphaBeta {
    pub alpha: f64,
    pub beta: f64,
}

/// Ring all-reduce time for `n` ranks and `m` bytes:
/// `2(n-1) * alpha + 2 (n-1)/n * m * beta`.
pub fn ring_time(ab: AlphaBeta, n: usize, m: f64) -> f64 {
    if n <= 1 {
        return 0.0;
    }
    let nf = n as f64;
    2.0 * (nf - 1.0) * ab.alpha + 2.0 * (nf - 1.0) / nf * m * ab.beta
}

/// Recursive halving-doubling time:
/// `2 log2(n) * alpha + 2 (n-1)/n * m * beta`.
pub fn halving_doubling_time(ab: AlphaBeta, n: usize, m: f64) -> f64 {
    if n <= 1 {
        return 0.0;
    }
    let nf = n as f64;
    2.0 * nf.log2() * ab.alpha + 2.0 * (nf - 1.0) / nf * m * ab.beta
}

/// Centralized non-colocated PS exchange time (PBox-style, single round):
/// workers push m bytes and pull m bytes; with chunk-pipelined full-duplex
/// links the push and pull streams overlap, so the worker side costs one
/// model pass of serialization. The PS side has `ps_bw_scale` times a
/// single worker's bandwidth (PBox: 10 NICs) and also runs full duplex.
pub fn central_ps_time(ab: AlphaBeta, n: usize, m: f64, ps_bw_scale: f64) -> f64 {
    let worker = 2.0 * ab.alpha + m * ab.beta;
    let ps = (n as f64) * m * ab.beta / ps_bw_scale;
    worker.max(ps)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn mk(n: usize, len: usize) -> (Vec<Vec<f32>>, Vec<f32>) {
        let bufs: Vec<Vec<f32>> = (0..n)
            .map(|r| (0..len).map(|i| ((r * 131 + i * 17) % 23) as f32 - 11.0).collect())
            .collect();
        let mut sum = vec![0.0f32; len];
        for b in &bufs {
            for (a, x) in sum.iter_mut().zip(b) {
                *a += x;
            }
        }
        (bufs, sum)
    }

    #[test]
    fn ring_allreduce_sums() {
        for (n, len) in [(2, 10), (3, 17), (5, 64), (8, 33)] {
            let (mut bufs, sum) = mk(n, len);
            ring_allreduce_inplace(&mut bufs);
            for b in &bufs {
                for (a, s) in b.iter().zip(&sum) {
                    assert!((a - s).abs() < 1e-4, "n={n} len={len}");
                }
            }
        }
    }

    #[test]
    fn halving_doubling_sums() {
        for (n, len) in [(2, 8), (4, 33), (8, 128), (16, 40)] {
            let (mut bufs, sum) = mk(n, len);
            halving_doubling_allreduce_inplace(&mut bufs);
            for (r, b) in bufs.iter().enumerate() {
                for (i, (a, s)) in b.iter().zip(&sum).enumerate() {
                    assert!((a - s).abs() < 1e-4, "n={n} len={len} r={r} i={i}");
                }
            }
        }
    }

    #[test]
    fn collectives_agree_with_each_other() {
        let (mut r, _) = mk(8, 100);
        let mut h = r.clone();
        ring_allreduce_inplace(&mut r);
        halving_doubling_allreduce_inplace(&mut h);
        for (a, b) in r[0].iter().zip(&h[0]) {
            assert!((a - b).abs() < 1e-4);
        }
    }

    #[test]
    fn single_rank_is_identity() {
        let mut b = vec![vec![1.0f32, 2.0, 3.0]];
        ring_allreduce_inplace(&mut b);
        assert_eq!(b[0], vec![1.0, 2.0, 3.0]);
    }

    #[test]
    fn time_models_basic_shape() {
        let ab = AlphaBeta {
            alpha: 5e-6,
            beta: 1.0 / 1.25e9,
        };
        let m = 100e6;
        // Same bandwidth term, ring pays more latency rounds.
        assert!(ring_time(ab, 8, m) > halving_doubling_time(ab, 8, m));
        // PBox-style central PS with 10x fan-in beats both at n=8 (one
        // round, half the per-NIC data of a colocated collective).
        let ps = central_ps_time(ab, 8, m, 10.0);
        assert!(ps < halving_doubling_time(ab, 8, m), "{ps}");
    }

    #[test]
    fn latency_matters_for_small_messages() {
        let ab = AlphaBeta {
            alpha: 50e-6,
            beta: 1.0 / 1.25e9,
        };
        // Tiny message: halving-doubling's log rounds beat ring's linear.
        let small = 1e3;
        assert!(halving_doubling_time(ab, 16, small) < ring_time(ab, 16, small));
    }
}
