//! 2-bit gradient compression with error feedback (paper section 5).
//!
//! Rust mirror of the L1 `quant2bit` Pallas kernel: quantize to
//! {-1, 0, +1} against a threshold, carry the quantization error in a
//! residual, pack 4 levels/byte for the wire. The server dequantizes into
//! its normal tall-aggregation path, so compression composes with PHub
//! exactly as the paper argues ("PHub can also work with gradient
//! compression to gain further benefits").
//!
//! Memory discipline: the round hot path is [`Quantizer::quantize_into`],
//! which writes the wire encoding into a caller-owned buffer reused
//! across rounds (zero allocations at steady state — the old per-call
//! `vec![0u8; ..]` is gone from the round loop). Server-side the wire
//! bytes are *not* decoded into a dense vector at all: [`QuantGrad::parse`]
//! borrows the packed levels in place and the aggregator folds
//! dequantization into its accumulate loop
//! (`aggregation::add_assign_dequant`). The owning [`QuantGrad`] /
//! [`QuantGrad::dequantize`] forms remain for tests and cold paths, and
//! share the same decode mapping.

use super::aggregation;

/// Per-worker compressor state (the error-feedback residual).
#[derive(Debug, Clone)]
pub struct Quantizer {
    pub threshold: f32,
    residual: Vec<f32>,
}

/// A compressed gradient: packed 2-bit levels plus the threshold.
#[derive(Debug, Clone, PartialEq)]
pub struct QuantGrad {
    pub threshold: f32,
    pub len: usize,
    /// 4 levels per byte; level encoding 0b00 = 0, 0b01 = +1, 0b10 = -1.
    pub packed: Vec<u8>,
}

/// A compressed gradient borrowed from its wire bytes (no copy): what the
/// server-side hot path hands to the aggregator's dequantize-fold.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct QuantView<'a> {
    pub threshold: f32,
    pub len: usize,
    pub packed: &'a [u8],
}

/// Wire header: `[len u64][threshold f32]` before the packed levels.
pub const QUANT_HEADER_BYTES: usize = 12;

impl Quantizer {
    pub fn new(len: usize, threshold: f32) -> Self {
        assert!(threshold > 0.0);
        Quantizer {
            threshold,
            residual: vec![0.0; len],
        }
    }

    /// Quantize `grad` (accumulating the carried residual) and write the
    /// wire encoding `[len u64][threshold f32][packed]` into `out`
    /// (cleared first; capacity reused across rounds — the round hot
    /// path allocates nothing once warm). Updates the residual in place;
    /// matches `quant2bit_ref` elementwise. This is *the* quantization
    /// implementation — [`Quantizer::quantize`] wraps it.
    pub fn quantize_into(&mut self, grad: &[f32], out: &mut Vec<u8>) {
        assert_eq!(grad.len(), self.residual.len());
        let t = self.threshold;
        out.clear();
        out.extend_from_slice(&(grad.len() as u64).to_le_bytes());
        out.extend_from_slice(&t.to_le_bytes());
        out.resize(QUANT_HEADER_BYTES + grad.len().div_ceil(4), 0);
        let packed = &mut out[QUANT_HEADER_BYTES..];
        for (i, (g, r)) in grad.iter().zip(self.residual.iter_mut()).enumerate() {
            let acc = g + *r;
            let (code, dq) = if acc > t {
                (0b01u8, t)
            } else if acc < -t {
                (0b10u8, -t)
            } else {
                (0b00u8, 0.0)
            };
            *r = acc - dq;
            packed[i / 4] |= code << ((i % 4) * 2);
        }
    }

    /// Quantize into a fresh owning [`QuantGrad`] (tests/cold paths; the
    /// round loop uses [`Quantizer::quantize_into`] with a reused buffer).
    pub fn quantize(&mut self, grad: &[f32]) -> QuantGrad {
        let mut out = Vec::new();
        self.quantize_into(grad, &mut out);
        QuantGrad {
            threshold: self.threshold,
            len: grad.len(),
            packed: out.split_off(QUANT_HEADER_BYTES),
        }
    }

    /// Max |residual| (diagnostic; bounded by `threshold` for bounded input).
    pub fn residual_linf(&self) -> f32 {
        self.residual.iter().fold(0.0f32, |m, x| m.max(x.abs()))
    }

    /// The carried error-feedback residual — what a worker checkpoints
    /// through the leader at round boundaries so a successor can resume
    /// bit-exact (see `transport.rs`, `ResidualSave`).
    pub fn residual(&self) -> &[f32] {
        &self.residual
    }

    /// Overwrite the carried residual from a checkpoint. Lengths must
    /// match; restoring `residual()` bytes reproduces the exact
    /// quantizer state, so the next `quantize_into` is bit-identical to
    /// the dead predecessor's would-have-been output.
    pub fn restore_residual(&mut self, residual: &[f32]) {
        assert_eq!(residual.len(), self.residual.len());
        self.residual.copy_from_slice(residual);
    }
}

/// Per-chunk compressor bank for the chunk-streamed wire protocol:
/// one error-feedback [`Quantizer`] per chunk, so each chunk's residual
/// lives with the chunk and compression composes with streaming exactly
/// like the dense path. Because quantization is elementwise over
/// `grad + residual`, the concatenation of per-chunk segments is
/// bit-identical to one whole-model [`Quantizer`] pass.
#[derive(Debug, Clone)]
pub struct ChunkQuantizer {
    quants: Vec<Quantizer>,
}

impl ChunkQuantizer {
    /// One quantizer per chunk, `chunk_lens[i]` elements each.
    pub fn new(chunk_lens: &[usize], threshold: f32) -> Self {
        ChunkQuantizer {
            quants: chunk_lens
                .iter()
                .map(|&len| Quantizer::new(len, threshold))
                .collect(),
        }
    }

    pub fn n_chunks(&self) -> usize {
        self.quants.len()
    }

    /// Quantize chunk `i`'s gradient slice, carrying that chunk's residual.
    pub fn quantize_chunk(&mut self, i: usize, grad: &[f32]) -> QuantGrad {
        self.quants[i].quantize(grad)
    }

    /// [`Quantizer::quantize_into`] for chunk `i`: the round hot path,
    /// writing the wire bytes into a caller-reused buffer.
    pub fn quantize_chunk_into(&mut self, i: usize, grad: &[f32], out: &mut Vec<u8>) {
        self.quants[i].quantize_into(grad, out);
    }

    /// The shared threshold every chunk quantizes against.
    pub fn threshold(&self) -> f32 {
        self.quants[0].threshold
    }

    /// Chunk `i`'s carried residual (for round-boundary checkpointing).
    pub fn residual_chunk(&self, i: usize) -> &[f32] {
        self.quants[i].residual()
    }

    /// Restore chunk `i`'s residual from a checkpoint (length-checked).
    pub fn restore_chunk_residual(&mut self, i: usize, residual: &[f32]) {
        self.quants[i].restore_residual(residual);
    }
}

impl<'a> QuantView<'a> {
    /// Borrow a compressed gradient straight from its wire bytes —
    /// validates the header and packed length, copies nothing.
    pub fn parse(b: &'a [u8]) -> std::io::Result<QuantView<'a>> {
        if b.len() < QUANT_HEADER_BYTES {
            return Err(std::io::Error::new(
                std::io::ErrorKind::InvalidData,
                "quant payload too short",
            ));
        }
        let len = u64::from_le_bytes(b[0..8].try_into().unwrap()) as usize;
        let threshold = f32::from_le_bytes(b[8..12].try_into().unwrap());
        let packed = &b[QUANT_HEADER_BYTES..];
        if packed.len() != len.div_ceil(4) {
            return Err(std::io::Error::new(
                std::io::ErrorKind::InvalidData,
                "quant payload length mismatch",
            ));
        }
        Ok(QuantView {
            threshold,
            len,
            packed,
        })
    }
}

impl QuantGrad {
    /// Dequantize into a dense f32 vector (tests/cold paths; the server's
    /// hot path folds dequantization into the aggregator instead — same
    /// decode mapping, one home: `aggregation::copy_dequant`).
    pub fn dequantize(&self) -> Vec<f32> {
        let mut out = vec![0.0f32; self.len];
        aggregation::copy_dequant(&mut out, self.threshold, &self.packed);
        out
    }

    /// Wire encoding: [len u64][threshold f32][packed bytes].
    pub fn to_bytes(&self) -> Vec<u8> {
        let mut out = Vec::with_capacity(QUANT_HEADER_BYTES + self.packed.len());
        out.extend_from_slice(&(self.len as u64).to_le_bytes());
        out.extend_from_slice(&self.threshold.to_le_bytes());
        out.extend_from_slice(&self.packed);
        out
    }

    pub fn from_bytes(b: &[u8]) -> std::io::Result<QuantGrad> {
        let v = QuantView::parse(b)?;
        Ok(QuantGrad {
            threshold: v.threshold,
            len: v.len,
            packed: v.packed.to_vec(),
        })
    }

    /// Compression ratio vs dense f32 (≈16x for large models).
    pub fn ratio(&self) -> f64 {
        (self.len * 4) as f64 / self.to_bytes().len() as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn quantize_dequantize_levels() {
        let mut q = Quantizer::new(6, 0.5);
        let g = [1.0f32, -1.0, 0.2, -0.2, 0.51, -0.51];
        let c = q.quantize(&g);
        assert_eq!(c.dequantize(), vec![0.5, -0.5, 0.0, 0.0, 0.5, -0.5]);
    }

    #[test]
    fn error_feedback_conserves_signal() {
        let mut q = Quantizer::new(4, 0.5);
        let g = [0.3f32, 0.3, 0.3, 0.3];
        let mut dq_sum = vec![0.0f32; 4];
        for _ in 0..10 {
            let c = q.quantize(&g);
            for (a, b) in dq_sum.iter_mut().zip(c.dequantize()) {
                *a += b;
            }
        }
        // 10 rounds of 0.3 = 3.0 total; dequantized sum within threshold.
        for s in dq_sum {
            assert!((s - 3.0).abs() <= 0.5 + 1e-6, "{s}");
        }
    }

    #[test]
    fn matches_kernel_reference_semantics() {
        // Same recurrence as quant2bit_ref: acc = g + r; q in {-1,0,1};
        // r' = acc - q*t.
        let mut q = Quantizer::new(1, 0.5);
        let rounds = [0.4f32, 0.4, -0.9, 0.1];
        let mut r_ref = 0.0f32;
        for g in rounds {
            let c = q.quantize(&[g]);
            let acc = g + r_ref;
            let expect = if acc > 0.5 {
                0.5
            } else if acc < -0.5 {
                -0.5
            } else {
                0.0
            };
            assert_eq!(c.dequantize()[0], expect);
            r_ref = acc - expect;
        }
        assert!((q.residual_linf() - r_ref.abs()).abs() < 1e-7);
    }

    #[test]
    fn wire_roundtrip() {
        let mut q = Quantizer::new(13, 0.25);
        let g: Vec<f32> = (0..13).map(|i| (i as f32 - 6.0) * 0.1).collect();
        let c = q.quantize(&g);
        let d = QuantGrad::from_bytes(&c.to_bytes()).unwrap();
        assert_eq!(c, d);
        assert_eq!(c.dequantize(), d.dequantize());
    }

    /// `quantize_into` writes exactly the bytes `quantize().to_bytes()`
    /// produces, reusing the output buffer across rounds (the residual
    /// recurrence advances identically through both forms).
    #[test]
    fn quantize_into_matches_quantize_and_reuses_buffer() {
        let mut qa = Quantizer::new(11, 0.3);
        let mut qb = Quantizer::new(11, 0.3);
        let mut out = Vec::new();
        let mut last_cap = 0usize;
        for round in 0..5 {
            let g: Vec<f32> = (0..11)
                .map(|i| ((i + round) as f32 * 0.47).sin() * 0.5)
                .collect();
            qa.quantize_into(&g, &mut out);
            let want = qb.quantize(&g).to_bytes();
            assert_eq!(out, want, "round {round}");
            let v = QuantView::parse(&out).unwrap();
            assert_eq!((v.len, v.threshold), (11, 0.3));
            if round > 0 {
                assert_eq!(out.capacity(), last_cap, "buffer capacity is stable");
            }
            last_cap = out.capacity();
        }
        assert_eq!(qa.residual_linf(), qb.residual_linf());
    }

    #[test]
    fn compression_ratio_near_16x() {
        let mut q = Quantizer::new(1 << 16, 0.5);
        let g = vec![0.7f32; 1 << 16];
        let c = q.quantize(&g);
        assert!(c.ratio() > 15.0, "{}", c.ratio());
    }

    /// Per-chunk error feedback segments concatenate to exactly the
    /// whole-model quantizer's output, round after round.
    #[test]
    fn chunked_quantizer_matches_whole_model() {
        let lens = [5usize, 4, 3];
        let total: usize = lens.iter().sum();
        let mut whole = Quantizer::new(total, 0.4);
        let mut chunked = ChunkQuantizer::new(&lens, 0.4);
        assert_eq!(chunked.n_chunks(), 3);
        for round in 0..6 {
            let g: Vec<f32> = (0..total)
                .map(|i| ((i + round) as f32 * 0.37).sin() * 0.6)
                .collect();
            let want = whole.quantize(&g).dequantize();
            let mut got = Vec::with_capacity(total);
            let mut off = 0;
            for (i, &len) in lens.iter().enumerate() {
                got.extend(chunked.quantize_chunk(i, &g[off..off + len]).dequantize());
                off += len;
            }
            assert_eq!(want, got, "round {round}");
        }
    }

    /// Checkpoint/restore: a fresh quantizer with the restored residual
    /// continues bit-identically to the original — the exact property a
    /// successor worker needs after restoring a `ResidualChunk`.
    #[test]
    fn restored_residual_resumes_bit_identical() {
        let mut original = Quantizer::new(9, 0.35);
        for round in 0..3 {
            let g: Vec<f32> = (0..9)
                .map(|i| ((i * 7 + round * 3) as f32 * 0.29).sin() * 0.8)
                .collect();
            original.quantize(&g);
        }
        // Checkpoint, then resurrect into a brand-new quantizer.
        let ckpt: Vec<f32> = original.residual().to_vec();
        let mut successor = Quantizer::new(9, 0.35);
        successor.restore_residual(&ckpt);
        for round in 3..6 {
            let g: Vec<f32> = (0..9)
                .map(|i| ((i * 7 + round * 3) as f32 * 0.29).sin() * 0.8)
                .collect();
            let mut a = Vec::new();
            let mut b = Vec::new();
            original.quantize_into(&g, &mut a);
            successor.quantize_into(&g, &mut b);
            assert_eq!(a, b, "round {round}");
        }
        // Per-chunk access mirrors the per-quantizer API.
        let mut bank = ChunkQuantizer::new(&[4, 5], 0.35);
        assert_eq!(bank.threshold(), 0.35);
        bank.quantize_chunk(1, &[0.9, -0.9, 0.1, 0.2, -0.4]);
        let r = bank.residual_chunk(1).to_vec();
        let mut bank2 = ChunkQuantizer::new(&[4, 5], 0.35);
        bank2.restore_chunk_residual(1, &r);
        assert_eq!(bank.residual_chunk(1), bank2.residual_chunk(1));
        assert_eq!(bank.residual_chunk(0), bank2.residual_chunk(0));
    }

    #[test]
    fn bad_wire_payloads_rejected() {
        assert!(QuantGrad::from_bytes(&[0; 4]).is_err());
        assert!(QuantView::parse(&[0; 4]).is_err());
        let mut q = Quantizer::new(8, 0.5);
        let mut bytes = q.quantize(&[0.9; 8]).to_bytes();
        bytes.pop();
        assert!(QuantGrad::from_bytes(&bytes).is_err());
        assert!(QuantView::parse(&bytes).is_err());
    }
}
