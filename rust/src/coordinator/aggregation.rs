//! Tall (chunk-granular, streaming) gradient aggregation
//! (paper section 3.2.2).
//!
//! Each chunk owns an aggregation buffer; worker gradients are summed into
//! it as they arrive ("streaming" aggregation — processing starts with the
//! first chunk, not the full key). When the last worker's copy lands, the
//! buffer is scaled to a mean and handed to the optimizer *by the same
//! thread on the same core* — no coordination with any other chunk.

/// `acc += src`, the aggregation inner loop. Kept as a free function so
/// benches can target it directly; the optimizer pass reuses it.
#[inline]
pub fn add_assign(acc: &mut [f32], src: &[f32]) {
    debug_assert_eq!(acc.len(), src.len());
    for (a, s) in acc.iter_mut().zip(src) {
        *a += s;
    }
}

/// `v *= k` (mean scaling).
#[inline]
pub fn scale(v: &mut [f32], k: f32) {
    for x in v.iter_mut() {
        *x *= k;
    }
}

/// Most workers one aggregation round supports — the arrival bitmask is a
/// u64. Single source of truth: the service and transport edges validate
/// against this before anything reaches the assert below.
pub const MAX_WORKERS: usize = 64;

/// Streaming aggregation state for one chunk.
#[derive(Debug, Clone)]
pub struct ChunkAggregator {
    acc: Vec<f32>,
    /// Bitmask of workers whose gradient has been absorbed this round.
    seen: u64,
    n_workers: usize,
}

impl ChunkAggregator {
    pub fn new(len: usize, n_workers: usize) -> Self {
        assert!(
            (1..=MAX_WORKERS).contains(&n_workers),
            "worker bitmask is u64"
        );
        ChunkAggregator {
            acc: vec![0.0; len],
            seen: 0,
            n_workers,
        }
    }

    pub fn len(&self) -> usize {
        self.acc.len()
    }

    pub fn is_empty(&self) -> bool {
        self.acc.is_empty()
    }

    /// Workers absorbed so far this round.
    pub fn arrived(&self) -> usize {
        self.seen.count_ones() as usize
    }

    /// Absorb worker `w`'s gradient for this chunk. Returns `true` when all
    /// workers have been absorbed (the chunk is ready to optimize).
    ///
    /// Panics on a duplicate push from the same worker in one round — that
    /// is a protocol violation upstream (the PS must see exactly one
    /// gradient per worker per round).
    pub fn absorb(&mut self, w: usize, grad: &[f32]) -> bool {
        assert!(w < self.n_workers, "worker {w} out of range");
        assert_eq!(grad.len(), self.acc.len(), "chunk length mismatch");
        let bit = 1u64 << w;
        assert_eq!(self.seen & bit, 0, "duplicate push from worker {w}");
        if self.seen == 0 {
            // First arrival: copy instead of add (buffer may hold stale sums).
            self.acc.copy_from_slice(grad);
        } else {
            add_assign(&mut self.acc, grad);
        }
        self.seen |= bit;
        self.arrived() == self.n_workers
    }

    /// Finish the round: scale the sum to a mean, reset arrival state, and
    /// expose the mean for the optimizer. The returned slice is valid until
    /// the next `absorb`.
    pub fn take_mean(&mut self) -> &[f32] {
        assert_eq!(
            self.arrived(),
            self.n_workers,
            "take_mean before all workers arrived"
        );
        scale(&mut self.acc, 1.0 / self.n_workers as f32);
        self.seen = 0;
        &self.acc
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn absorb_and_mean() {
        let mut a = ChunkAggregator::new(4, 3);
        assert!(!a.absorb(0, &[3.0, 0.0, 3.0, 3.0]));
        assert!(!a.absorb(2, &[3.0, 3.0, 0.0, 3.0]));
        assert!(a.absorb(1, &[3.0, 3.0, 3.0, 0.0]));
        let m = a.take_mean();
        assert_eq!(m, &[3.0, 2.0, 2.0, 2.0]);
    }

    #[test]
    fn rounds_reuse_buffer() {
        let mut a = ChunkAggregator::new(2, 2);
        a.absorb(0, &[1.0, 1.0]);
        a.absorb(1, &[3.0, 3.0]);
        assert_eq!(a.take_mean(), &[2.0, 2.0]);
        // Second round must not see residue from the first.
        a.absorb(1, &[10.0, 10.0]);
        a.absorb(0, &[20.0, 20.0]);
        assert_eq!(a.take_mean(), &[15.0, 15.0]);
    }

    #[test]
    #[should_panic(expected = "duplicate push")]
    fn duplicate_worker_panics() {
        let mut a = ChunkAggregator::new(2, 2);
        a.absorb(0, &[0.0, 0.0]);
        a.absorb(0, &[0.0, 0.0]);
    }

    #[test]
    #[should_panic(expected = "before all workers")]
    fn early_take_mean_panics() {
        let mut a = ChunkAggregator::new(2, 2);
        a.absorb(0, &[0.0, 0.0]);
        a.take_mean();
    }

    #[test]
    fn single_worker_mean_is_identity() {
        let mut a = ChunkAggregator::new(3, 1);
        assert!(a.absorb(0, &[1.0, 2.0, 3.0]));
        assert_eq!(a.take_mean(), &[1.0, 2.0, 3.0]);
    }

    #[test]
    fn order_independence() {
        let g0 = [1.0f32, 2.0];
        let g1 = [5.0f32, -2.0];
        let mut a = ChunkAggregator::new(2, 2);
        a.absorb(0, &g0);
        a.absorb(1, &g1);
        let m1: Vec<f32> = a.take_mean().to_vec();
        let mut b = ChunkAggregator::new(2, 2);
        b.absorb(1, &g1);
        b.absorb(0, &g0);
        assert_eq!(m1, b.take_mean());
    }
}
