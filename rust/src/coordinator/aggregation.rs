//! Tall (chunk-granular, streaming) gradient aggregation
//! (paper section 3.2.2).
//!
//! Each chunk owns an aggregation buffer; worker gradients are summed into
//! it as they arrive ("streaming" aggregation — processing starts with the
//! first chunk, not the full key). When the last worker's copy lands, the
//! buffer is scaled to a mean and handed to the optimizer *by the same
//! thread on the same core* — no coordination with any other chunk.
//!
//! Protocol violations (a duplicate push, taking the mean early) are typed
//! [`AggError`]s, not panics: the aggregator runs on *shared* core threads
//! (see [`super::engine`]), and a hostile or buggy peer must only ever be
//! able to kill its own connection, never a core. The inner loops keep
//! `debug_assert!`s for the hot path instead of release-mode checks.
//!
//! # Memory discipline
//!
//! The pipeline is memory-bandwidth-bound (paper §4.3), so the aggregator
//! is built to touch each gradient byte exactly once and allocate nothing
//! at steady state:
//!
//! * [`GradSrc`] lets a push be absorbed straight from its wire form. The
//!   TCP leader hands the pooled frame payload to the core and
//!   [`ChunkAggregator::absorb_bytes`] folds `f32::from_le_bytes` (a pure
//!   bit reinterpretation) directly into the accumulate loop — the
//!   intermediate `Vec<f32>` the old `bytes_to_f32s` path materialized is
//!   gone. The 2-bit path does the same: dequantization folds into the
//!   accumulate ([`ChunkAggregator::absorb_quant`]), no dense scratch
//!   vector. The slice-based [`ChunkAggregator::absorb`] remains for the
//!   in-process server; the byte paths are bit-identical to it
//!   (property-tested, NaN/inf payloads included — `from_le_bytes`
//!   preserves every bit pattern).
//! * A round's gradient is touched twice total: once by the absorb fold,
//!   once by the fused mean+optimizer pass
//!   ([`ChunkAggregator::take_mean_into_step`]), which hands the raw sum
//!   and `1/n` to the optimizer's single fused loop instead of
//!   materializing the mean with a separate `scale` pass. Bit-identical
//!   to the unfused `take_mean` → `step` sequence (property-tested).
//! * The wire-facing inner loops (byte fold, dequant fold, and the fused
//!   optimizer passes) are explicit SIMD: this module's entry points
//!   delegate to [`super::kernels`], which dispatches once-selected
//!   AVX2/SSE2/scalar implementations, property-tested bit-identical to
//!   each other. See the *kernel dispatch contract* table in
//!   `kernels.rs` — nothing outside that module may call a raw vector
//!   fn, and this module's delegating wrappers keep the wire-form
//!   signatures (and `debug_assert!` length contracts) stable for
//!   callers. The slice-form [`add_assign`]/[`scale`] below stay
//!   lane-chunked in place: they are the in-process reference path, not
//!   a wire hot loop.
//!
//! Copies per chunk per round (leader receive side), before → after this
//! refactor: frame body `Vec` + payload re-slice `Vec` + `bytes_to_f32s`
//! `Vec` + accumulate (3 copies, ≥3 allocations) → pooled frame read +
//! accumulate fold (1 copy, 0 allocations at steady state).

use std::fmt;

/// Lane width of the chunked inner loops. Eight f32s = one 256-bit
/// vector; the fixed-size inner loops below are shaped for the
/// autovectorizer, not unrolling by hand.
const LANES: usize = 8;

/// `acc += src`, the aggregation inner loop. Kept as a free function so
/// benches can target it directly; the optimizer pass reuses it.
#[inline]
pub fn add_assign(acc: &mut [f32], src: &[f32]) {
    debug_assert_eq!(acc.len(), src.len());
    let mut a = acc.chunks_exact_mut(LANES);
    let mut s = src.chunks_exact(LANES);
    for (aa, ss) in (&mut a).zip(&mut s) {
        for i in 0..LANES {
            aa[i] += ss[i];
        }
    }
    for (aa, ss) in a.into_remainder().iter_mut().zip(s.remainder()) {
        *aa += ss;
    }
}

/// `v *= k` (mean scaling).
#[inline]
pub fn scale(v: &mut [f32], k: f32) {
    let mut c = v.chunks_exact_mut(LANES);
    for vv in &mut c {
        for x in vv.iter_mut() {
            *x *= k;
        }
    }
    for x in c.into_remainder() {
        *x *= k;
    }
}

/// `dst = le_bytes` reinterpreted as little-endian f32s (bit-exact; NaN
/// payloads survive). `le_bytes.len()` must be `4 * dst.len()`.
/// Dispatches to the active SIMD tier (see [`super::kernels`]).
#[inline]
pub fn copy_f32s_le(dst: &mut [f32], le_bytes: &[u8]) {
    super::kernels::copy_f32s_le(dst, le_bytes)
}

/// `acc += le_bytes` reinterpreted as little-endian f32s: the byte-level
/// aggregation fold — decode and accumulate in one pass, no intermediate
/// f32 vector. Bit-identical to `bytes_to_f32s` + [`add_assign`].
/// Dispatches to the active SIMD tier (see [`super::kernels`]).
#[inline]
pub fn add_assign_le(acc: &mut [f32], le_bytes: &[u8]) {
    super::kernels::add_assign_le(acc, le_bytes)
}

/// `dst = dequantize(packed)`: 4 levels per byte (0b00 = 0, 0b01 = +t,
/// 0b10 = -t), `packed.len()` must be `dst.len().div_ceil(4)`. The decode
/// mapping lives in `kernels::scalar::dequant_level`;
/// `QuantGrad::dequantize` delegates here. Dispatches to the active SIMD
/// tier (see [`super::kernels`]).
#[inline]
pub fn copy_dequant(dst: &mut [f32], threshold: f32, packed: &[u8]) {
    super::kernels::copy_dequant(dst, threshold, packed)
}

/// `acc += dequantize(packed)`: dequantization folded into the
/// accumulate — the 2-bit wire path never materializes a dense scratch
/// vector. Bit-identical to `dequantize` + [`add_assign`]. Dispatches to
/// the active SIMD tier (see [`super::kernels`]).
#[inline]
pub fn add_assign_dequant(acc: &mut [f32], threshold: f32, packed: &[u8]) {
    super::kernels::add_assign_dequant(acc, threshold, packed)
}

/// Most workers one aggregation round supports — the arrival bitmask is a
/// u64. Single source of truth: the service and transport edges validate
/// against this before anything reaches the aggregator.
pub const MAX_WORKERS: usize = 64;

/// One worker's chunk gradient in whatever form it arrived — the
/// aggregator absorbs each form directly, so the transport never has to
/// materialize an intermediate `Vec<f32>` to push.
#[derive(Debug, Clone, Copy)]
pub enum GradSrc<'a> {
    /// Decoded f32 slice (the in-process server's zero-copy path).
    F32s(&'a [f32]),
    /// Raw little-endian f32 bytes straight off the wire.
    LeBytes(&'a [u8]),
    /// 2-bit quantized levels straight off the wire: threshold, element
    /// count, and the packed levels (4 per byte).
    Quant2Bit {
        threshold: f32,
        len: usize,
        packed: &'a [u8],
    },
}

impl GradSrc<'_> {
    /// Gradient length in elements, or a typed error for a malformed
    /// payload (misaligned dense bytes, short/long packed levels).
    pub fn elems(&self) -> Result<usize, AggError> {
        match *self {
            GradSrc::F32s(g) => Ok(g.len()),
            GradSrc::LeBytes(b) => {
                if b.len() % 4 != 0 {
                    Err(AggError::MisalignedBytes { bytes: b.len() })
                } else {
                    Ok(b.len() / 4)
                }
            }
            GradSrc::Quant2Bit { len, packed, .. } => {
                if packed.len() != len.div_ceil(4) {
                    Err(AggError::QuantPayloadMismatch {
                        packed: packed.len(),
                        want: len.div_ceil(4),
                    })
                } else {
                    Ok(len)
                }
            }
        }
    }
}

/// A round-protocol violation detected by the aggregator.
///
/// (Hand-implemented `Display`/`Error`: the offline environment has no
/// `thiserror`.)
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum AggError {
    /// Worker index outside `0..n_workers`.
    WorkerOutOfRange { worker: usize, n_workers: usize },
    /// Gradient length does not match the chunk length.
    LengthMismatch { got: usize, want: usize },
    /// A dense byte payload whose length is not a multiple of 4.
    MisalignedBytes { bytes: usize },
    /// A 2-bit payload whose packed length disagrees with its element
    /// count.
    QuantPayloadMismatch { packed: usize, want: usize },
    /// The same worker pushed twice in one round.
    DuplicatePush { worker: usize },
    /// `take_mean` before every worker's gradient arrived.
    NotReady { arrived: usize, n_workers: usize },
}

impl fmt::Display for AggError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            AggError::WorkerOutOfRange { worker, n_workers } => {
                write!(f, "worker {worker} out of range (n_workers {n_workers})")
            }
            AggError::LengthMismatch { got, want } => {
                write!(f, "chunk length mismatch: got {got}, want {want}")
            }
            AggError::MisalignedBytes { bytes } => {
                write!(f, "dense payload of {bytes} bytes is not f32-aligned")
            }
            AggError::QuantPayloadMismatch { packed, want } => {
                write!(f, "quant payload has {packed} packed bytes, want {want}")
            }
            AggError::DuplicatePush { worker } => {
                write!(f, "duplicate push from worker {worker} in one round")
            }
            AggError::NotReady { arrived, n_workers } => {
                write!(f, "take_mean with {arrived}/{n_workers} workers arrived")
            }
        }
    }
}

impl std::error::Error for AggError {}

/// Streaming aggregation state for one chunk.
#[derive(Debug, Clone)]
pub struct ChunkAggregator {
    acc: Vec<f32>,
    /// Bitmask of workers whose gradient has been absorbed this round.
    seen: u64,
    n_workers: usize,
}

impl ChunkAggregator {
    pub fn new(len: usize, n_workers: usize) -> Self {
        assert!(
            (1..=MAX_WORKERS).contains(&n_workers),
            "worker bitmask is u64"
        );
        ChunkAggregator {
            acc: vec![0.0; len],
            seen: 0,
            n_workers,
        }
    }

    pub fn len(&self) -> usize {
        self.acc.len()
    }

    pub fn is_empty(&self) -> bool {
        self.acc.is_empty()
    }

    /// Workers absorbed so far this round.
    pub fn arrived(&self) -> usize {
        self.seen.count_ones() as usize
    }

    /// Absorb worker `w`'s gradient for this chunk, in whatever wire form
    /// it arrived (see [`GradSrc`]). Returns `Ok(true)` when all workers
    /// have been absorbed (the chunk is ready to optimize).
    ///
    /// The first arrival of a round *copies* (decodes) into the buffer
    /// instead of adding — the buffer may hold the previous round's stale
    /// sums — and every later arrival folds its decode directly into the
    /// accumulate loop.
    ///
    /// A duplicate push from the same worker in one round is a protocol
    /// violation upstream (the PS must see exactly one gradient per worker
    /// per round) and comes back as [`AggError::DuplicatePush`] — the
    /// caller decides whose connection that costs.
    pub fn absorb_src(&mut self, w: usize, src: GradSrc<'_>) -> Result<bool, AggError> {
        if w >= self.n_workers {
            return Err(AggError::WorkerOutOfRange {
                worker: w,
                n_workers: self.n_workers,
            });
        }
        let len = src.elems()?;
        if len != self.acc.len() {
            return Err(AggError::LengthMismatch {
                got: len,
                want: self.acc.len(),
            });
        }
        let bit = 1u64 << w;
        if self.seen & bit != 0 {
            return Err(AggError::DuplicatePush { worker: w });
        }
        let first = self.seen == 0;
        match src {
            GradSrc::F32s(g) => {
                if first {
                    self.acc.copy_from_slice(g);
                } else {
                    add_assign(&mut self.acc, g);
                }
            }
            GradSrc::LeBytes(b) => {
                if first {
                    copy_f32s_le(&mut self.acc, b);
                } else {
                    add_assign_le(&mut self.acc, b);
                }
            }
            GradSrc::Quant2Bit {
                threshold, packed, ..
            } => {
                if first {
                    copy_dequant(&mut self.acc, threshold, packed);
                } else {
                    add_assign_dequant(&mut self.acc, threshold, packed);
                }
            }
        }
        self.seen |= bit;
        Ok(self.arrived() == self.n_workers)
    }

    /// Slice-form [`ChunkAggregator::absorb_src`] (the in-process server's
    /// path).
    pub fn absorb(&mut self, w: usize, grad: &[f32]) -> Result<bool, AggError> {
        self.absorb_src(w, GradSrc::F32s(grad))
    }

    /// Byte-form [`ChunkAggregator::absorb_src`]: the wire hot path —
    /// `le_bytes` is the dense frame payload, decoded inside the
    /// accumulate fold. Bit-identical to `absorb(bytes_to_f32s(..))`.
    pub fn absorb_bytes(&mut self, w: usize, le_bytes: &[u8]) -> Result<bool, AggError> {
        self.absorb_src(w, GradSrc::LeBytes(le_bytes))
    }

    /// 2-bit-form [`ChunkAggregator::absorb_src`]: dequantization folded
    /// into the accumulate. Bit-identical to `absorb(&q.dequantize())`.
    pub fn absorb_quant(
        &mut self,
        w: usize,
        threshold: f32,
        len: usize,
        packed: &[u8],
    ) -> Result<bool, AggError> {
        self.absorb_src(
            w,
            GradSrc::Quant2Bit {
                threshold,
                len,
                packed,
            },
        )
    }

    /// Finish the round: scale the sum to a mean, reset arrival state, and
    /// expose the mean for the optimizer. The returned slice is valid until
    /// the next `absorb`.
    ///
    /// This is the *unfused* finish (two passes: scale, then the caller's
    /// optimizer step). The engine uses
    /// [`ChunkAggregator::take_mean_into_step`], which does both in one
    /// pass; this form remains for callers that want the mean itself and
    /// as the reference the fused path is property-tested against.
    pub fn take_mean(&mut self) -> Result<&[f32], AggError> {
        if self.arrived() != self.n_workers {
            return Err(AggError::NotReady {
                arrived: self.arrived(),
                n_workers: self.n_workers,
            });
        }
        scale(&mut self.acc, 1.0 / self.n_workers as f32);
        self.seen = 0;
        Ok(&self.acc)
    }

    /// Fused finish: close the round and hand `(sum, 1/n)` to `step` —
    /// one pass over the accumulator instead of a scale pass followed by
    /// an optimizer pass (the paper's "touch the gradient twice, not five
    /// times" pipeline; see `Optimizer::step_scaled`). The step computes
    /// `mean[i] = sum[i] * inv_n` inline, which is bit-identical to
    /// [`ChunkAggregator::take_mean`]'s scale (same multiply, same
    /// rounding) — property-tested.
    ///
    /// The accumulator is left holding the raw sum; the next round's
    /// first absorb overwrites it (copy-on-first-arrival), so rollback
    /// and replay semantics are unchanged.
    pub fn take_mean_into_step<R>(
        &mut self,
        step: impl FnOnce(&[f32], f32) -> R,
    ) -> Result<R, AggError> {
        if self.arrived() != self.n_workers {
            return Err(AggError::NotReady {
                arrived: self.arrived(),
                n_workers: self.n_workers,
            });
        }
        let out = step(&self.acc, 1.0 / self.n_workers as f32);
        self.seen = 0;
        Ok(out)
    }

    /// Rewind the open round: forget every arrival recorded so far and
    /// return the bitmask of workers whose gradients are being discarded.
    ///
    /// This is all a mid-round rollback needs — the accumulation buffer is
    /// *not* cleared because the first `absorb` of a round copies instead
    /// of adding, so stale sums can never leak into the replay.
    pub fn rollback(&mut self) -> u64 {
        std::mem::take(&mut self.seen)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn absorb_and_mean() {
        let mut a = ChunkAggregator::new(4, 3);
        assert!(!a.absorb(0, &[3.0, 0.0, 3.0, 3.0]).unwrap());
        assert!(!a.absorb(2, &[3.0, 3.0, 0.0, 3.0]).unwrap());
        assert!(a.absorb(1, &[3.0, 3.0, 3.0, 0.0]).unwrap());
        let m = a.take_mean().unwrap();
        assert_eq!(m, &[3.0, 2.0, 2.0, 2.0]);
    }

    #[test]
    fn rounds_reuse_buffer() {
        let mut a = ChunkAggregator::new(2, 2);
        a.absorb(0, &[1.0, 1.0]).unwrap();
        a.absorb(1, &[3.0, 3.0]).unwrap();
        assert_eq!(a.take_mean().unwrap(), &[2.0, 2.0]);
        // Second round must not see residue from the first.
        a.absorb(1, &[10.0, 10.0]).unwrap();
        a.absorb(0, &[20.0, 20.0]).unwrap();
        assert_eq!(a.take_mean().unwrap(), &[15.0, 15.0]);
    }

    #[test]
    fn duplicate_worker_is_typed_error() {
        let mut a = ChunkAggregator::new(2, 2);
        a.absorb(0, &[0.0, 0.0]).unwrap();
        assert_eq!(
            a.absorb(0, &[0.0, 0.0]),
            Err(AggError::DuplicatePush { worker: 0 })
        );
        // The round is still usable after the rejected duplicate.
        assert!(a.absorb(1, &[2.0, 2.0]).unwrap());
        assert_eq!(a.take_mean().unwrap(), &[1.0, 1.0]);
    }

    #[test]
    fn early_take_mean_is_typed_error() {
        let mut a = ChunkAggregator::new(2, 2);
        a.absorb(0, &[0.0, 0.0]).unwrap();
        assert_eq!(
            a.take_mean(),
            Err(AggError::NotReady {
                arrived: 1,
                n_workers: 2
            })
        );
        assert_eq!(
            a.take_mean_into_step(|_, _| ()),
            Err(AggError::NotReady {
                arrived: 1,
                n_workers: 2
            })
        );
    }

    #[test]
    fn out_of_range_and_length_mismatch_are_typed_errors() {
        let mut a = ChunkAggregator::new(2, 2);
        assert_eq!(
            a.absorb(2, &[0.0, 0.0]),
            Err(AggError::WorkerOutOfRange {
                worker: 2,
                n_workers: 2
            })
        );
        assert_eq!(
            a.absorb(0, &[0.0]),
            Err(AggError::LengthMismatch { got: 1, want: 2 })
        );
    }

    #[test]
    fn malformed_byte_payloads_are_typed_errors() {
        let mut a = ChunkAggregator::new(2, 2);
        assert_eq!(
            a.absorb_bytes(0, &[0u8; 7]),
            Err(AggError::MisalignedBytes { bytes: 7 })
        );
        assert_eq!(
            a.absorb_bytes(0, &[0u8; 12]),
            Err(AggError::LengthMismatch { got: 3, want: 2 })
        );
        assert_eq!(
            a.absorb_quant(0, 0.5, 2, &[0u8; 3]),
            Err(AggError::QuantPayloadMismatch { packed: 3, want: 1 })
        );
        assert_eq!(
            a.absorb_quant(0, 0.5, 5, &[0u8; 2]),
            Err(AggError::LengthMismatch { got: 5, want: 2 })
        );
        // None of the rejections recorded an arrival.
        assert_eq!(a.arrived(), 0);
    }

    #[test]
    fn single_worker_mean_is_identity() {
        let mut a = ChunkAggregator::new(3, 1);
        assert!(a.absorb(0, &[1.0, 2.0, 3.0]).unwrap());
        assert_eq!(a.take_mean().unwrap(), &[1.0, 2.0, 3.0]);
    }

    #[test]
    fn order_independence() {
        let g0 = [1.0f32, 2.0];
        let g1 = [5.0f32, -2.0];
        let mut a = ChunkAggregator::new(2, 2);
        a.absorb(0, &g0).unwrap();
        a.absorb(1, &g1).unwrap();
        let m1: Vec<f32> = a.take_mean().unwrap().to_vec();
        let mut b = ChunkAggregator::new(2, 2);
        b.absorb(1, &g1).unwrap();
        b.absorb(0, &g0).unwrap();
        assert_eq!(m1, b.take_mean().unwrap());
    }

    /// The byte fold is the slice path bit-for-bit, first arrival and
    /// accumulate alike, for lengths that exercise lane remainders.
    #[test]
    fn absorb_bytes_matches_absorb() {
        for len in [1usize, 7, 8, 9, 16, 37] {
            let g0: Vec<f32> = (0..len).map(|i| (i as f32 * 0.7).sin()).collect();
            let g1: Vec<f32> = (0..len).map(|i| (i as f32 * 1.3).cos()).collect();
            let bytes = |g: &[f32]| -> Vec<u8> {
                g.iter().flat_map(|x| x.to_le_bytes()).collect()
            };
            let mut a = ChunkAggregator::new(len, 2);
            a.absorb(0, &g0).unwrap();
            a.absorb(1, &g1).unwrap();
            let mut b = ChunkAggregator::new(len, 2);
            b.absorb_bytes(0, &bytes(&g0)).unwrap();
            b.absorb_bytes(1, &bytes(&g1)).unwrap();
            let ma: Vec<u32> = a.take_mean().unwrap().iter().map(|x| x.to_bits()).collect();
            let mb: Vec<u32> = b.take_mean().unwrap().iter().map(|x| x.to_bits()).collect();
            assert_eq!(ma, mb, "len {len}");
        }
    }

    /// The dequantize fold matches dequantize-then-absorb bit-for-bit,
    /// including ragged tails (len not a multiple of 4 or 8).
    #[test]
    fn absorb_quant_matches_dense_dequantized() {
        for len in [1usize, 4, 5, 9, 13, 16, 23] {
            let t = 0.5f32;
            // All four 2-bit codes cycled through the packed bytes.
            let packed: Vec<u8> = (0..len.div_ceil(4)).map(|i| (i as u8).wrapping_mul(0x39)).collect();
            let mut dense = vec![0.0f32; len];
            copy_dequant(&mut dense, t, &packed);
            let mut a = ChunkAggregator::new(len, 2);
            a.absorb(0, &dense).unwrap();
            a.absorb(1, &dense).unwrap();
            let mut b = ChunkAggregator::new(len, 2);
            b.absorb_quant(0, t, len, &packed).unwrap();
            b.absorb_quant(1, t, len, &packed).unwrap();
            assert_eq!(a.take_mean().unwrap(), b.take_mean().unwrap(), "len {len}");
        }
    }

    /// The fused finish equals the unfused scale+read bit-for-bit.
    #[test]
    fn take_mean_into_step_matches_take_mean() {
        let g0 = [1.5f32, -0.25, 3.0];
        let g1 = [0.125f32, 8.0, -1.0];
        let mut a = ChunkAggregator::new(3, 2);
        a.absorb(0, &g0).unwrap();
        a.absorb(1, &g1).unwrap();
        let want: Vec<f32> = a.take_mean().unwrap().to_vec();
        let mut b = ChunkAggregator::new(3, 2);
        b.absorb(0, &g0).unwrap();
        b.absorb(1, &g1).unwrap();
        let got: Vec<f32> = b
            .take_mean_into_step(|sum, inv| sum.iter().map(|x| x * inv).collect())
            .unwrap();
        assert_eq!(want, got);
        // Both paths closed the round.
        assert_eq!(b.arrived(), 0);
        b.absorb(0, &g0).unwrap();
        assert_eq!(b.arrived(), 1);
    }

    /// Partial round → rollback → full replay is bit-identical to a clean
    /// round: the bitmask reset plus copy-on-first-arrival is sufficient.
    #[test]
    fn rollback_then_replay_matches_clean_round() {
        let g0 = [1.5f32, -0.25];
        let g1 = [0.125f32, 8.0];
        let mut clean = ChunkAggregator::new(2, 2);
        clean.absorb(0, &g0).unwrap();
        clean.absorb(1, &g1).unwrap();
        let want: Vec<f32> = clean.take_mean().unwrap().to_vec();

        let mut a = ChunkAggregator::new(2, 2);
        a.absorb(1, &g1).unwrap();
        assert_eq!(a.rollback(), 1u64 << 1);
        assert_eq!(a.arrived(), 0);
        a.absorb(0, &g0).unwrap();
        a.absorb(1, &g1).unwrap();
        assert_eq!(a.take_mean().unwrap(), &want[..]);
    }

    #[test]
    fn rollback_on_idle_round_is_a_noop() {
        let mut a = ChunkAggregator::new(2, 2);
        assert_eq!(a.rollback(), 0);
        a.absorb(0, &[1.0, 1.0]).unwrap();
        a.absorb(1, &[3.0, 3.0]).unwrap();
        assert_eq!(a.take_mean().unwrap(), &[2.0, 2.0]);
    }
}
