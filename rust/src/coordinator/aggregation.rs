//! Tall (chunk-granular, streaming) gradient aggregation
//! (paper section 3.2.2).
//!
//! Each chunk owns an aggregation buffer; worker gradients are summed into
//! it as they arrive ("streaming" aggregation — processing starts with the
//! first chunk, not the full key). When the last worker's copy lands, the
//! buffer is scaled to a mean and handed to the optimizer *by the same
//! thread on the same core* — no coordination with any other chunk.
//!
//! Protocol violations (a duplicate push, taking the mean early) are typed
//! [`AggError`]s, not panics: the aggregator runs on *shared* core threads
//! (see [`super::engine`]), and a hostile or buggy peer must only ever be
//! able to kill its own connection, never a core. The inner loops keep
//! `debug_assert!`s for the hot path instead of release-mode checks.

use std::fmt;

/// `acc += src`, the aggregation inner loop. Kept as a free function so
/// benches can target it directly; the optimizer pass reuses it.
#[inline]
pub fn add_assign(acc: &mut [f32], src: &[f32]) {
    debug_assert_eq!(acc.len(), src.len());
    for (a, s) in acc.iter_mut().zip(src) {
        *a += s;
    }
}

/// `v *= k` (mean scaling).
#[inline]
pub fn scale(v: &mut [f32], k: f32) {
    for x in v.iter_mut() {
        *x *= k;
    }
}

/// Most workers one aggregation round supports — the arrival bitmask is a
/// u64. Single source of truth: the service and transport edges validate
/// against this before anything reaches the aggregator.
pub const MAX_WORKERS: usize = 64;

/// A round-protocol violation detected by the aggregator.
///
/// (Hand-implemented `Display`/`Error`: the offline environment has no
/// `thiserror`.)
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum AggError {
    /// Worker index outside `0..n_workers`.
    WorkerOutOfRange { worker: usize, n_workers: usize },
    /// Gradient length does not match the chunk length.
    LengthMismatch { got: usize, want: usize },
    /// The same worker pushed twice in one round.
    DuplicatePush { worker: usize },
    /// `take_mean` before every worker's gradient arrived.
    NotReady { arrived: usize, n_workers: usize },
}

impl fmt::Display for AggError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            AggError::WorkerOutOfRange { worker, n_workers } => {
                write!(f, "worker {worker} out of range (n_workers {n_workers})")
            }
            AggError::LengthMismatch { got, want } => {
                write!(f, "chunk length mismatch: got {got}, want {want}")
            }
            AggError::DuplicatePush { worker } => {
                write!(f, "duplicate push from worker {worker} in one round")
            }
            AggError::NotReady { arrived, n_workers } => {
                write!(f, "take_mean with {arrived}/{n_workers} workers arrived")
            }
        }
    }
}

impl std::error::Error for AggError {}

/// Streaming aggregation state for one chunk.
#[derive(Debug, Clone)]
pub struct ChunkAggregator {
    acc: Vec<f32>,
    /// Bitmask of workers whose gradient has been absorbed this round.
    seen: u64,
    n_workers: usize,
}

impl ChunkAggregator {
    pub fn new(len: usize, n_workers: usize) -> Self {
        assert!(
            (1..=MAX_WORKERS).contains(&n_workers),
            "worker bitmask is u64"
        );
        ChunkAggregator {
            acc: vec![0.0; len],
            seen: 0,
            n_workers,
        }
    }

    pub fn len(&self) -> usize {
        self.acc.len()
    }

    pub fn is_empty(&self) -> bool {
        self.acc.is_empty()
    }

    /// Workers absorbed so far this round.
    pub fn arrived(&self) -> usize {
        self.seen.count_ones() as usize
    }

    /// Absorb worker `w`'s gradient for this chunk. Returns `Ok(true)` when
    /// all workers have been absorbed (the chunk is ready to optimize).
    ///
    /// A duplicate push from the same worker in one round is a protocol
    /// violation upstream (the PS must see exactly one gradient per worker
    /// per round) and comes back as [`AggError::DuplicatePush`] — the
    /// caller decides whose connection that costs.
    pub fn absorb(&mut self, w: usize, grad: &[f32]) -> Result<bool, AggError> {
        if w >= self.n_workers {
            return Err(AggError::WorkerOutOfRange {
                worker: w,
                n_workers: self.n_workers,
            });
        }
        if grad.len() != self.acc.len() {
            return Err(AggError::LengthMismatch {
                got: grad.len(),
                want: self.acc.len(),
            });
        }
        let bit = 1u64 << w;
        if self.seen & bit != 0 {
            return Err(AggError::DuplicatePush { worker: w });
        }
        if self.seen == 0 {
            // First arrival: copy instead of add (buffer may hold stale sums).
            self.acc.copy_from_slice(grad);
        } else {
            add_assign(&mut self.acc, grad);
        }
        self.seen |= bit;
        Ok(self.arrived() == self.n_workers)
    }

    /// Finish the round: scale the sum to a mean, reset arrival state, and
    /// expose the mean for the optimizer. The returned slice is valid until
    /// the next `absorb`.
    pub fn take_mean(&mut self) -> Result<&[f32], AggError> {
        if self.arrived() != self.n_workers {
            return Err(AggError::NotReady {
                arrived: self.arrived(),
                n_workers: self.n_workers,
            });
        }
        scale(&mut self.acc, 1.0 / self.n_workers as f32);
        self.seen = 0;
        Ok(&self.acc)
    }

    /// Rewind the open round: forget every arrival recorded so far and
    /// return the bitmask of workers whose gradients are being discarded.
    ///
    /// This is all a mid-round rollback needs — the accumulation buffer is
    /// *not* cleared because the first `absorb` of a round copies instead
    /// of adding, so stale sums can never leak into the replay.
    pub fn rollback(&mut self) -> u64 {
        std::mem::take(&mut self.seen)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn absorb_and_mean() {
        let mut a = ChunkAggregator::new(4, 3);
        assert!(!a.absorb(0, &[3.0, 0.0, 3.0, 3.0]).unwrap());
        assert!(!a.absorb(2, &[3.0, 3.0, 0.0, 3.0]).unwrap());
        assert!(a.absorb(1, &[3.0, 3.0, 3.0, 0.0]).unwrap());
        let m = a.take_mean().unwrap();
        assert_eq!(m, &[3.0, 2.0, 2.0, 2.0]);
    }

    #[test]
    fn rounds_reuse_buffer() {
        let mut a = ChunkAggregator::new(2, 2);
        a.absorb(0, &[1.0, 1.0]).unwrap();
        a.absorb(1, &[3.0, 3.0]).unwrap();
        assert_eq!(a.take_mean().unwrap(), &[2.0, 2.0]);
        // Second round must not see residue from the first.
        a.absorb(1, &[10.0, 10.0]).unwrap();
        a.absorb(0, &[20.0, 20.0]).unwrap();
        assert_eq!(a.take_mean().unwrap(), &[15.0, 15.0]);
    }

    #[test]
    fn duplicate_worker_is_typed_error() {
        let mut a = ChunkAggregator::new(2, 2);
        a.absorb(0, &[0.0, 0.0]).unwrap();
        assert_eq!(
            a.absorb(0, &[0.0, 0.0]),
            Err(AggError::DuplicatePush { worker: 0 })
        );
        // The round is still usable after the rejected duplicate.
        assert!(a.absorb(1, &[2.0, 2.0]).unwrap());
        assert_eq!(a.take_mean().unwrap(), &[1.0, 1.0]);
    }

    #[test]
    fn early_take_mean_is_typed_error() {
        let mut a = ChunkAggregator::new(2, 2);
        a.absorb(0, &[0.0, 0.0]).unwrap();
        assert_eq!(
            a.take_mean(),
            Err(AggError::NotReady {
                arrived: 1,
                n_workers: 2
            })
        );
    }

    #[test]
    fn out_of_range_and_length_mismatch_are_typed_errors() {
        let mut a = ChunkAggregator::new(2, 2);
        assert_eq!(
            a.absorb(2, &[0.0, 0.0]),
            Err(AggError::WorkerOutOfRange {
                worker: 2,
                n_workers: 2
            })
        );
        assert_eq!(
            a.absorb(0, &[0.0]),
            Err(AggError::LengthMismatch { got: 1, want: 2 })
        );
    }

    #[test]
    fn single_worker_mean_is_identity() {
        let mut a = ChunkAggregator::new(3, 1);
        assert!(a.absorb(0, &[1.0, 2.0, 3.0]).unwrap());
        assert_eq!(a.take_mean().unwrap(), &[1.0, 2.0, 3.0]);
    }

    #[test]
    fn order_independence() {
        let g0 = [1.0f32, 2.0];
        let g1 = [5.0f32, -2.0];
        let mut a = ChunkAggregator::new(2, 2);
        a.absorb(0, &g0).unwrap();
        a.absorb(1, &g1).unwrap();
        let m1: Vec<f32> = a.take_mean().unwrap().to_vec();
        let mut b = ChunkAggregator::new(2, 2);
        b.absorb(1, &g1).unwrap();
        b.absorb(0, &g0).unwrap();
        assert_eq!(m1, b.take_mean().unwrap());
    }

    /// Partial round → rollback → full replay is bit-identical to a clean
    /// round: the bitmask reset plus copy-on-first-arrival is sufficient.
    #[test]
    fn rollback_then_replay_matches_clean_round() {
        let g0 = [1.5f32, -0.25];
        let g1 = [0.125f32, 8.0];
        let mut clean = ChunkAggregator::new(2, 2);
        clean.absorb(0, &g0).unwrap();
        clean.absorb(1, &g1).unwrap();
        let want: Vec<f32> = clean.take_mean().unwrap().to_vec();

        let mut a = ChunkAggregator::new(2, 2);
        a.absorb(1, &g1).unwrap();
        assert_eq!(a.rollback(), 1u64 << 1);
        assert_eq!(a.arrived(), 0);
        a.absorb(0, &g0).unwrap();
        a.absorb(1, &g1).unwrap();
        assert_eq!(a.take_mean().unwrap(), &want[..]);
    }

    #[test]
    fn rollback_on_idle_round_is_a_noop() {
        let mut a = ChunkAggregator::new(2, 2);
        assert_eq!(a.rollback(), 0);
        a.absorb(0, &[1.0, 1.0]).unwrap();
        a.absorb(1, &[3.0, 3.0]).unwrap();
        assert_eq!(a.take_mean().unwrap(), &[2.0, 2.0]);
    }
}
