//! The live status/export plane: a dependency-free blocking HTTP
//! endpoint on a side thread, serving the observability layer's three
//! read paths (see the "Observability contract" in [`super`] and
//! [`crate::metrics`]):
//!
//! * **`/metrics`** — Prometheus text exposition (hand-rolled; format
//!   version 0.0.4): every global [`crate::metrics::DataPlaneMetrics`]
//!   counter as `phub_<name>_total`, the kernel-tier/placement settings
//!   as gauges, and each job's attribution set as `phub_job_*` series
//!   labeled `{job="<id>"}` including round-latency quantile series.
//! * **`/jobs`** — per-tenant JSON snapshot (one object per registered
//!   job: rounds, bytes, drops/replays/rollbacks, latency summary).
//! * **`/trace`** — the flight recorder ([`crate::trace`]) drained as
//!   chrome://tracing "trace event" JSON: load the response in
//!   `chrome://tracing` / Perfetto and a captured round renders as the
//!   paper's per-stage timeline figure.
//!
//! # Cost model
//!
//! Scrapes read relaxed-atomic snapshots and seqlock-guarded trace
//! slots; they take the metrics registry's control-plane lock briefly
//! but never block a core thread, park a ring, or allocate on any
//! data-plane thread. The HTTP server itself is deliberately primitive:
//! one blocking accept loop on a named side thread, one request per
//! connection, bounded header reads, `Connection: close`. Operators
//! point `curl` or a Prometheus scraper at it; it is not a general web
//! server.
//!
//! # Tenant isolation
//!
//! Without auth ([`StatusServer::bind`]) every route serves everything
//! — the single-operator deployment. With auth
//! ([`StatusServer::bind_with_auth`]), `/trace` requires
//! `?job=<id>&nonce=<hex>` and serves only that job's events after the
//! [`JobAuth`] check passes (the TCP control plane's
//! [`super::service::ConnectionManager`] implements it with the same
//! per-service nonce it issues at `create_service`): job A's nonce
//! cannot read job B's trace. `/metrics` and `/jobs` stay open — they
//! are aggregate operator surfaces, like every Prometheus endpoint.

use std::io::{Read, Write};
use std::net::{SocketAddr, TcpListener, TcpStream, ToSocketAddrs};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::thread::JoinHandle;
use std::time::Duration;

use crate::metrics::{DataPlaneMetrics, JobMetricsSnapshot, MetricsSnapshot};

/// Authorization hook for the tenant-scoped `/trace` route: whether
/// `nonce` (issued to the tenant at service creation) authorizes
/// reading `job`'s data. Implemented by
/// [`super::service::ConnectionManager`].
pub trait JobAuth: Send + Sync {
    fn check(&self, job: u32, nonce: u64) -> bool;
}

/// Per-connection read deadline: a stalled scraper may cost the status
/// thread this long, never forever.
const READ_TIMEOUT: Duration = Duration::from_secs(5);

/// Request head cap (request line + headers). Anything longer is not a
/// scrape; the connection is dropped.
const MAX_HEAD_BYTES: usize = 8 * 1024;

/// The status endpoint: owns the listener's accept thread. Dropping the
/// handle stops the thread (idempotent, bounded by one in-flight
/// request).
pub struct StatusServer {
    addr: SocketAddr,
    stop: Arc<AtomicBool>,
    thread: Option<JoinHandle<()>>,
}

impl StatusServer {
    /// Bind and serve `metrics` with no tenant auth: every route,
    /// including `/trace`, serves the full view. `bind` may be
    /// `"127.0.0.1:0"` to pick a free port (see
    /// [`StatusServer::local_addr`]).
    pub fn bind(
        bind: impl ToSocketAddrs,
        metrics: Arc<DataPlaneMetrics>,
    ) -> std::io::Result<StatusServer> {
        StatusServer::bind_inner(bind, metrics, None)
    }

    /// [`StatusServer::bind`] with tenant isolation on `/trace`: the
    /// route requires `?job=<id>&nonce=<hex>`, rejects a failed
    /// [`JobAuth::check`] with 403, and filters the dump to that job.
    pub fn bind_with_auth(
        bind: impl ToSocketAddrs,
        metrics: Arc<DataPlaneMetrics>,
        auth: Arc<dyn JobAuth>,
    ) -> std::io::Result<StatusServer> {
        StatusServer::bind_inner(bind, metrics, Some(auth))
    }

    fn bind_inner(
        bind: impl ToSocketAddrs,
        metrics: Arc<DataPlaneMetrics>,
        auth: Option<Arc<dyn JobAuth>>,
    ) -> std::io::Result<StatusServer> {
        let listener = TcpListener::bind(bind)?;
        let addr = listener.local_addr()?;
        let stop = Arc::new(AtomicBool::new(false));
        let thread = {
            let stop = stop.clone();
            std::thread::Builder::new()
                .name("phub-status".into())
                .spawn(move || {
                    for stream in listener.incoming() {
                        if stop.load(Ordering::Acquire) {
                            break;
                        }
                        let Ok(mut s) = stream else { continue };
                        let _ = serve_one(&mut s, &metrics, auth.as_deref());
                    }
                })?
        };
        Ok(StatusServer {
            addr,
            stop,
            thread: Some(thread),
        })
    }

    /// The bound address (resolves a `:0` bind).
    pub fn local_addr(&self) -> SocketAddr {
        self.addr
    }

    /// Stop the accept thread and wait for it. Also runs on drop.
    pub fn shutdown(mut self) {
        self.stop_thread();
    }

    fn stop_thread(&mut self) {
        let Some(thread) = self.thread.take() else {
            return;
        };
        self.stop.store(true, Ordering::Release);
        // Unblock the accept with a throwaway connection; the loop sees
        // the flag before serving it.
        let _ = TcpStream::connect(self.addr);
        let _ = thread.join();
    }
}

impl Drop for StatusServer {
    fn drop(&mut self) {
        self.stop_thread();
    }
}

/// Serve one request on `s`: bounded head read, route, respond, close.
fn serve_one(
    s: &mut TcpStream,
    metrics: &DataPlaneMetrics,
    auth: Option<&dyn JobAuth>,
) -> std::io::Result<()> {
    s.set_read_timeout(Some(READ_TIMEOUT))?;
    s.set_write_timeout(Some(READ_TIMEOUT))?;
    let head = read_head(s)?;
    let Some(target) = request_target(&head) else {
        return respond(s, 400, "text/plain; charset=utf-8", "bad request\n");
    };
    let (path, query) = match target.split_once('?') {
        Some((p, q)) => (p, q),
        None => (target, ""),
    };
    match path {
        "/metrics" => {
            let body = render_prometheus(&metrics.snapshot());
            respond(s, 200, "text/plain; version=0.0.4; charset=utf-8", &body)
        }
        "/jobs" => {
            let body = render_jobs_json(&metrics.snapshot());
            respond(s, 200, "application/json", &body)
        }
        "/trace" => {
            let job = query_param(query, "job").and_then(|v| v.parse::<u32>().ok());
            match auth {
                Some(auth) => {
                    // Tenant-scoped: both credentials present and valid,
                    // or nothing is served.
                    let nonce = query_param(query, "nonce")
                        .and_then(|v| u64::from_str_radix(v, 16).ok());
                    let (Some(job), Some(nonce)) = (job, nonce) else {
                        return respond(
                            s,
                            403,
                            "text/plain; charset=utf-8",
                            "trace requires ?job=<id>&nonce=<hex>\n",
                        );
                    };
                    if !auth.check(job, nonce) {
                        return respond(s, 403, "text/plain; charset=utf-8", "bad nonce\n");
                    }
                    let events = crate::trace::snapshot_filtered(Some(job));
                    respond(s, 200, "application/json", &crate::trace::chrome_trace_json(&events))
                }
                None => {
                    let events = crate::trace::snapshot_filtered(job);
                    respond(s, 200, "application/json", &crate::trace::chrome_trace_json(&events))
                }
            }
        }
        _ => respond(s, 404, "text/plain; charset=utf-8", "not found\n"),
    }
}

/// Read the request head (request line + headers) up to the blank line,
/// bounded by [`MAX_HEAD_BYTES`].
fn read_head(s: &mut TcpStream) -> std::io::Result<String> {
    let mut head = Vec::new();
    let mut byte = [0u8; 1];
    while head.len() < MAX_HEAD_BYTES {
        match s.read(&mut byte) {
            Ok(0) => break,
            Ok(_) => {
                head.push(byte[0]);
                if head.ends_with(b"\r\n\r\n") || head.ends_with(b"\n\n") {
                    break;
                }
            }
            Err(e) => return Err(e),
        }
    }
    Ok(String::from_utf8_lossy(&head).into_owned())
}

/// The request target of a `GET <target> HTTP/1.x` request line.
fn request_target(head: &str) -> Option<&str> {
    let line = head.lines().next()?;
    let mut parts = line.split_whitespace();
    if parts.next()? != "GET" {
        return None;
    }
    parts.next()
}

/// The value of `key` in an `a=1&b=2` query string.
fn query_param<'a>(query: &'a str, key: &str) -> Option<&'a str> {
    query
        .split('&')
        .filter_map(|kv| kv.split_once('='))
        .find(|(k, _)| *k == key)
        .map(|(_, v)| v)
}

fn respond(s: &mut TcpStream, code: u16, content_type: &str, body: &str) -> std::io::Result<()> {
    let reason = match code {
        200 => "OK",
        400 => "Bad Request",
        403 => "Forbidden",
        _ => "Not Found",
    };
    let head = format!(
        "HTTP/1.1 {code} {reason}\r\nContent-Type: {content_type}\r\n\
         Content-Length: {}\r\nConnection: close\r\n\r\n",
        body.len()
    );
    s.write_all(head.as_bytes())?;
    s.write_all(body.as_bytes())?;
    s.flush()
}

/// Prometheus text exposition of a snapshot (format 0.0.4).
fn render_prometheus(snap: &MetricsSnapshot) -> String {
    use std::fmt::Write as _;
    let mut out = String::new();
    for (name, value) in snap.counters() {
        let _ = writeln!(out, "# TYPE phub_{name}_total counter");
        let _ = writeln!(out, "phub_{name}_total {value}");
    }
    let _ = writeln!(out, "# TYPE phub_kernel_tier gauge");
    let _ = writeln!(out, "phub_kernel_tier {}", snap.kernel_tier);
    let _ = writeln!(out, "# TYPE phub_placement_mode gauge");
    let _ = writeln!(out, "phub_placement_mode {}", snap.placement_mode);
    for j in &snap.jobs {
        let job = j.job;
        let _ = writeln!(
            out,
            "phub_job_rounds_completed_total{{job=\"{job}\"}} {}",
            j.rounds_completed
        );
        let _ = writeln!(out, "phub_job_push_bytes_total{{job=\"{job}\"}} {}", j.push_bytes);
        let _ = writeln!(out, "phub_job_pull_bytes_total{{job=\"{job}\"}} {}", j.pull_bytes);
        let _ = writeln!(out, "phub_job_drops_total{{job=\"{job}\"}} {}", j.drops);
        let _ = writeln!(out, "phub_job_replays_total{{job=\"{job}\"}} {}", j.replays);
        let _ = writeln!(out, "phub_job_rollbacks_total{{job=\"{job}\"}} {}", j.rollbacks);
        let _ = writeln!(out, "phub_job_deferrals_total{{job=\"{job}\"}} {}", j.deferrals);
        let _ = writeln!(out, "phub_job_refusals_total{{job=\"{job}\"}} {}", j.refusals);
        let _ = writeln!(out, "phub_job_sched_weight{{job=\"{job}\"}} {}", j.sched_weight);
        let _ = writeln!(out, "phub_job_model_elems{{job=\"{job}\"}} {}", j.model_elems);
        let _ = writeln!(out, "phub_job_workers{{job=\"{job}\"}} {}", j.n_workers);
        let _ = writeln!(out, "phub_job_live_workers{{job=\"{job}\"}} {}", j.live_workers);
        let h = &j.round_latency;
        for (q, label) in [(0.5, "0.5"), (0.9, "0.9"), (0.99, "0.99")] {
            let _ = writeln!(
                out,
                "phub_job_round_latency_ns{{job=\"{job}\",quantile=\"{label}\"}} {}",
                h.quantile_ns(q)
            );
        }
        let _ = writeln!(out, "phub_job_round_latency_ns_sum{{job=\"{job}\"}} {}", h.sum_ns);
        let _ = writeln!(out, "phub_job_round_latency_ns_count{{job=\"{job}\"}} {}", h.count);
    }
    out
}

/// JSON snapshot of the per-job sets (hand-rolled; parseable by
/// [`crate::jsonlite`]).
fn render_jobs_json(snap: &MetricsSnapshot) -> String {
    use std::fmt::Write as _;
    let mut out = String::from("{\"jobs\":[");
    for (i, j) in snap.jobs.iter().enumerate() {
        if i > 0 {
            out.push(',');
        }
        append_job_json(&mut out, j);
    }
    let _ = write!(
        out,
        "],\"kernel_tier\":{},\"placement_mode\":{}}}",
        snap.kernel_tier, snap.placement_mode
    );
    out
}

fn append_job_json(out: &mut String, j: &JobMetricsSnapshot) {
    use std::fmt::Write as _;
    let h = &j.round_latency;
    let _ = write!(
        out,
        "{{\"job\":{},\"rounds_completed\":{},\"push_bytes\":{},\"pull_bytes\":{},\
         \"drops\":{},\"replays\":{},\"rollbacks\":{},\
         \"deferrals\":{},\"refusals\":{},\"quota\":{{\
         \"sched_weight\":{},\"model_elems\":{},\"workers\":{},\"live_workers\":{}}},\
         \"round_latency\":{{\
         \"count\":{},\"mean_ns\":{:.3},\"p50_ns\":{},\"p90_ns\":{},\"p99_ns\":{}}}}}",
        j.job,
        j.rounds_completed,
        j.push_bytes,
        j.pull_bytes,
        j.drops,
        j.replays,
        j.rollbacks,
        j.deferrals,
        j.refusals,
        j.sched_weight,
        j.model_elems,
        j.n_workers,
        j.live_workers,
        h.count,
        h.mean_ns(),
        h.quantile_ns(0.5),
        h.quantile_ns(0.9),
        h.quantile_ns(0.99),
    );
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample_snapshot() -> MetricsSnapshot {
        let m = DataPlaneMetrics::default();
        m.dropped_messages.inc();
        m.drop_future_round.inc();
        let jm = m.per_job.register(3);
        jm.rounds_completed.add(4);
        jm.push_bytes.add(1024);
        jm.pull_bytes.add(2048);
        jm.round_latency.record_ns(1_000_000);
        jm.sched_weight.set(4);
        jm.model_elems.set(64);
        jm.n_workers.set(2);
        jm.live_workers.set(1);
        jm.deferrals.add(5);
        m.snapshot()
    }

    #[test]
    fn prometheus_rendering_is_line_oriented_and_complete() {
        let text = render_prometheus(&sample_snapshot());
        assert!(text.contains("# TYPE phub_dropped_messages_total counter"));
        assert!(text.contains("phub_dropped_messages_total 1"));
        assert!(text.contains("phub_drop_future_round_total 1"));
        assert!(text.contains("phub_job_rounds_completed_total{job=\"3\"} 4"));
        assert!(text.contains("phub_job_deferrals_total{job=\"3\"} 5"));
        assert!(text.contains("phub_job_sched_weight{job=\"3\"} 4"));
        assert!(text.contains("phub_job_live_workers{job=\"3\"} 1"));
        assert!(text.contains("phub_refused_overload_total 0"));
        assert!(text.contains("phub_sched_deferrals_total 0"));
        assert!(text.contains("phub_job_round_latency_ns{job=\"3\",quantile=\"0.5\"}"));
        assert!(text.contains("phub_job_round_latency_ns_count{job=\"3\"} 1"));
        // Every non-comment line is `name[{labels}] value`.
        for line in text.lines() {
            if line.starts_with('#') {
                continue;
            }
            let mut parts = line.split_whitespace();
            let name = parts.next().unwrap();
            assert!(name.starts_with("phub_"), "{line}");
            assert!(parts.next().unwrap().parse::<f64>().is_ok(), "{line}");
            assert!(parts.next().is_none(), "{line}");
        }
    }

    #[test]
    fn jobs_json_parses_with_jsonlite() {
        let body = render_jobs_json(&sample_snapshot());
        let v = crate::jsonlite::parse(&body).expect("valid json");
        let jobs = v.get("jobs").expect("jobs").as_arr().expect("array");
        assert_eq!(jobs.len(), 1);
        assert_eq!(jobs[0].get("job").unwrap().as_usize(), Some(3));
        assert_eq!(jobs[0].get("rounds_completed").unwrap().as_usize(), Some(4));
        assert_eq!(jobs[0].get("deferrals").unwrap().as_usize(), Some(5));
        let quota = jobs[0].get("quota").expect("quota view");
        assert_eq!(quota.get("sched_weight").unwrap().as_usize(), Some(4));
        assert_eq!(quota.get("model_elems").unwrap().as_usize(), Some(64));
        assert_eq!(quota.get("workers").unwrap().as_usize(), Some(2));
        assert_eq!(quota.get("live_workers").unwrap().as_usize(), Some(1));
        let lat = jobs[0].get("round_latency").expect("latency");
        assert_eq!(lat.get("count").unwrap().as_usize(), Some(1));
        assert!(lat.get("mean_ns").unwrap().as_f64().unwrap() > 0.0);
    }

    #[test]
    fn empty_registry_renders_empty_but_valid() {
        let m = DataPlaneMetrics::default();
        let body = render_jobs_json(&m.snapshot());
        let v = crate::jsonlite::parse(&body).expect("valid json");
        assert_eq!(v.get("jobs").unwrap().as_arr().unwrap().len(), 0);
        let text = render_prometheus(&m.snapshot());
        assert!(text.contains("phub_dropped_messages_total 0"));
    }

    #[test]
    fn query_params_and_request_targets_parse() {
        assert_eq!(query_param("job=3&nonce=ff", "job"), Some("3"));
        assert_eq!(query_param("job=3&nonce=ff", "nonce"), Some("ff"));
        assert_eq!(query_param("job=3", "nonce"), None);
        assert_eq!(query_param("", "job"), None);
        assert_eq!(
            request_target("GET /metrics HTTP/1.1\r\nHost: x\r\n\r\n"),
            Some("/metrics")
        );
        assert_eq!(request_target("POST /metrics HTTP/1.1\r\n\r\n"), None);
        assert_eq!(request_target(""), None);
    }
}
