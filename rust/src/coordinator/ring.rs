//! Bounded lock-free SPSC rings: the queue-per-core fabric.
//!
//! PHub's data plane is fast because its cores share nothing (paper
//! §3.2; the same queue-per-core discipline underpins the PBox appliance
//! in *Parameter Box*): a chunk is pinned to one core for its whole
//! lifetime and nothing on the NIC→optimizer path takes a lock or
//! allocates. `std::sync::mpsc` broke that discipline twice — its
//! receiver takes a lock under contention and its internal queue
//! allocates a block every ~31 sends. This module replaces it with the
//! paper-shaped primitive: a **bounded single-producer/single-consumer
//! ring** whose whole life is
//!
//! * **zero allocation after construction** — the slot array is allocated
//!   once, messages are moved in and out of it by value;
//! * **lock-free progress** — one cache-line-padded Acquire/Release
//!   head/tail pair; the producer writes only `tail`, the consumer only
//!   `head`, so the steady state is two uncontended atomic ops per
//!   message and no RMW at all;
//! * **park/unpark blocking at the edges** — an idle consumer spins
//!   briefly then parks instead of burning its core; a full ring blocks
//!   the producer (backpressure) instead of dropping or deadlocking;
//! * **monotone epoch sideband** — [`Producer::post_epoch`] publishes a
//!   rollback epoch *past* the ring capacity (a `fetch_max` on a
//!   dedicated atomic), so recovery notices can never be wedged behind a
//!   full ring of dead-round traffic. This is the transport half of the
//!   drain-on-epoch-bump rule: consumers observe the bulletin, then
//!   drain and discard stale-epoch messages instead of blocking on them
//!   (`engine.rs` owns the state-machine half).
//!
//! # Topology
//!
//! [`spsc`] builds an isolated pair. [`spsc_shared`] builds a pair whose
//! *consumer-side* wakeups go to a caller-supplied [`Waiter`], which is
//! how one thread multiplexes many rings without locks: the in-process
//! server gives every core one `Waiter` shared by all the request rings
//! it consumes, and every worker one `Waiter` shared by its per-core
//! reply rings. A producer finishing a push notifies that shared waiter;
//! the consumer re-scans its rings before parking (Dekker-style
//! registration, see [`Waiter::wait_until`]) so a wakeup can never be
//! lost between the scan and the park.
//!
//! # Contract
//!
//! Exactly one thread may use the [`Producer`] and one the [`Consumer`]
//! at a time (they are `Send` but deliberately not `Clone`/`Sync`), and
//! when several rings share a `Waiter`, all their consumer endpoints
//! must be polled by that same single thread.

use std::cell::UnsafeCell;
use std::mem::MaybeUninit;
use std::sync::atomic::{fence, AtomicBool, AtomicU64, AtomicU8, AtomicUsize, Ordering};
use std::sync::Arc;
use std::thread::Thread;

/// Pad-and-align wrapper keeping the producer's and consumer's hot
/// indices on separate cache lines (false sharing would otherwise make
/// every push invalidate the consumer's line and vice versa).
#[repr(align(64))]
struct CachePadded<T>(T);

/// Iterations of the spin phase before a blocked endpoint registers and
/// parks. Sized so a ping-pong between two running threads stays in user
/// space, while a genuinely idle core reaches `thread::park` quickly.
const SPIN_BEFORE_PARK: u32 = 256;

// ---------------------------------------------------------------------------
// Waiter: one-thread park/unpark cell (the blocking half of the fabric).
// ---------------------------------------------------------------------------

const W_EMPTY: u8 = 0;
const W_REGISTERING: u8 = 1;
const W_WAITING: u8 = 2;
const W_NOTIFYING: u8 = 3;
const W_NOTIFIED: u8 = 4;

/// A lock-free park/unpark cell for **one** waiting thread and any number
/// of notifiers.
///
/// The waiter publishes its `Thread` handle through a small state machine
/// (`EMPTY → REGISTERING → WAITING → NOTIFYING → NOTIFIED → EMPTY`) so a
/// notifier can clone the handle out without a mutex and without ever
/// racing the waiter's re-registration: the handle cell is only written
/// in `REGISTERING` and only read in `NOTIFYING`, and the two states
/// exclude each other by CAS. `Thread::clone` is a refcount bump, so
/// notification allocates nothing.
pub struct Waiter {
    state: AtomicU8,
    /// Written by the waiter in `REGISTERING`, read by the notifier in
    /// `NOTIFYING`; the state machine makes the two exclusive.
    thread: UnsafeCell<Option<Thread>>,
}

// Safety: the `thread` cell is guarded by the `state` machine as
// documented above; all other fields are atomics.
unsafe impl Send for Waiter {}
unsafe impl Sync for Waiter {}

impl Default for Waiter {
    fn default() -> Self {
        Waiter::new()
    }
}

impl Waiter {
    pub fn new() -> Waiter {
        Waiter {
            state: AtomicU8::new(W_EMPTY),
            thread: UnsafeCell::new(None),
        }
    }

    /// Block the calling thread until `ready()` returns true, parking
    /// between checks. `ready` must be driven by state the notifiers
    /// change *before* calling [`Waiter::notify`]; the Dekker-style
    /// re-check after registration then guarantees no lost wakeup:
    /// either the notifier sees `WAITING` and unparks us, or we see its
    /// state change in the re-check and never park.
    ///
    /// Only one thread may wait on a `Waiter` (the fabric's consumer
    /// sides are single-threaded by contract).
    pub fn wait_until(&self, mut ready: impl FnMut() -> bool) {
        let mut spins = 0u32;
        loop {
            if ready() {
                return;
            }
            if spins < SPIN_BEFORE_PARK {
                spins += 1;
                std::hint::spin_loop();
                continue;
            }
            // Register for a wakeup. A leftover NOTIFYING/NOTIFIED from a
            // notifier we raced on the previous lap is consumed first.
            match self.state.compare_exchange(
                W_EMPTY,
                W_REGISTERING,
                Ordering::Acquire,
                Ordering::Acquire,
            ) {
                Ok(_) => {}
                Err(_) => {
                    self.settle();
                    continue;
                }
            }
            // Sole writer while in REGISTERING (notifiers only read the
            // cell from NOTIFYING, which this state excludes).
            unsafe { *self.thread.get() = Some(std::thread::current()) };
            self.state.store(W_WAITING, Ordering::Release);
            // The store-load fence of the Dekker handshake: our WAITING
            // store must be globally visible before we re-read the
            // condition, mirroring the notifier's publish-then-fence.
            fence(Ordering::SeqCst);
            if ready() {
                self.cancel_wait();
                return;
            }
            loop {
                match self.state.load(Ordering::Acquire) {
                    W_WAITING | W_NOTIFYING => std::thread::park(),
                    _ => break,
                }
            }
            self.state.store(W_EMPTY, Ordering::Release);
        }
    }

    /// Withdraw a registration (the condition turned true on its own).
    fn cancel_wait(&self) {
        if self
            .state
            .compare_exchange(W_WAITING, W_EMPTY, Ordering::AcqRel, Ordering::Acquire)
            .is_ok()
        {
            return;
        }
        // A notifier is mid-flight; let it finish with the handle cell,
        // then absorb the (now spurious) notification.
        self.settle();
    }

    /// Spin out a NOTIFYING/NOTIFIED transient back to EMPTY.
    fn settle(&self) {
        loop {
            match self.state.load(Ordering::Acquire) {
                W_NOTIFIED => {
                    self.state.store(W_EMPTY, Ordering::Release);
                    return;
                }
                W_NOTIFYING => std::hint::spin_loop(),
                // EMPTY (or a concurrent re-registration state we cannot
                // be in ourselves): nothing to settle.
                _ => return,
            }
        }
    }

    /// Wake the waiter if one is registered. Callers must change the
    /// waited-on state (e.g. publish a message with Release) *before*
    /// notifying. The fast path is one fence and one load.
    pub fn notify(&self) {
        // Store-load fence pairing with the waiter's post-registration
        // re-check: either our state change is visible to its re-check,
        // or its WAITING is visible to us here.
        fence(Ordering::SeqCst);
        if self.state.load(Ordering::Relaxed) != W_WAITING {
            return;
        }
        if self
            .state
            .compare_exchange(W_WAITING, W_NOTIFYING, Ordering::AcqRel, Ordering::Acquire)
            .is_ok()
        {
            // Clone the handle out (refcount bump, no allocation), hand
            // the cell back, then unpark. The waiter cannot touch the
            // cell until it sees NOTIFIED.
            let t = unsafe { (*self.thread.get()).clone() };
            self.state.store(W_NOTIFIED, Ordering::Release);
            if let Some(t) = t {
                t.unpark();
            }
        }
    }
}

// ---------------------------------------------------------------------------
// The ring itself.
// ---------------------------------------------------------------------------

struct Ring<T> {
    /// Slot array, allocated once at construction; `mask` is `cap - 1`.
    buf: Box<[UnsafeCell<MaybeUninit<T>>]>,
    mask: usize,
    /// Consumer's read index (free-running; slot = index & mask).
    head: CachePadded<AtomicUsize>,
    /// Producer's write index.
    tail: CachePadded<AtomicUsize>,
    /// Monotone out-of-band epoch bulletin (rollback notices must not be
    /// able to wedge behind a full ring; see module docs).
    epoch: AtomicU64,
    tx_alive: AtomicBool,
    rx_alive: AtomicBool,
    /// Wakes the consumer; possibly shared across a thread's rings.
    rx_waiter: Arc<Waiter>,
    /// Wakes the producer blocked on a full ring; always private.
    tx_waiter: Waiter,
}

// Safety: slots are handed off producer→consumer through the
// Acquire/Release tail/head protocol; each slot is written by exactly
// one side at a time.
unsafe impl<T: Send> Send for Ring<T> {}
unsafe impl<T: Send> Sync for Ring<T> {}

impl<T> Drop for Ring<T> {
    fn drop(&mut self) {
        // Both endpoints are gone; drop any messages still in flight.
        let head = self.head.0.load(Ordering::Relaxed);
        let tail = self.tail.0.load(Ordering::Relaxed);
        let mut i = head;
        while i != tail {
            unsafe { (*self.buf[i & self.mask].get()).assume_init_drop() };
            i = i.wrapping_add(1);
        }
    }
}

/// Error from [`Producer::send`]: the consumer is gone; the message is
/// handed back.
#[derive(Debug, PartialEq, Eq)]
pub struct SendError<T>(pub T);

/// Error from [`Producer::try_send`].
#[derive(Debug, PartialEq, Eq)]
pub enum TrySendError<T> {
    /// Ring at capacity; the message is handed back. Blocking [`
    /// Producer::send`] turns this into backpressure.
    Full(T),
    /// Consumer dropped; the message is handed back.
    Disconnected(T),
}

/// The sending half of an SPSC ring. `Send` but not `Clone`/`Sync`:
/// exactly one producer.
pub struct Producer<T> {
    ring: Arc<Ring<T>>,
    /// `Cell` is `Send + !Sync`: the endpoint may move between threads
    /// but two threads can never share it by reference, which is what
    /// makes the unsynchronized `tail` ownership sound.
    _not_sync: std::marker::PhantomData<std::cell::Cell<()>>,
}

impl<T> Producer<T> {
    /// Non-blocking send.
    pub fn try_send(&self, v: T) -> Result<(), TrySendError<T>> {
        if !self.ring.rx_alive.load(Ordering::Acquire) {
            return Err(TrySendError::Disconnected(v));
        }
        let tail = self.ring.tail.0.load(Ordering::Relaxed); // we own tail
        let head = self.ring.head.0.load(Ordering::Acquire);
        if tail.wrapping_sub(head) > self.ring.mask {
            return Err(TrySendError::Full(v));
        }
        unsafe { (*self.ring.buf[tail & self.ring.mask].get()).write(v) };
        self.ring.tail.0.store(tail.wrapping_add(1), Ordering::Release);
        self.ring.rx_waiter.notify();
        Ok(())
    }

    /// Blocking send: parks while the ring is full (backpressure — a slow
    /// consumer stalls exactly its own producers, nothing else), errors
    /// only if the consumer is gone.
    pub fn send(&self, v: T) -> Result<(), SendError<T>> {
        let mut v = v;
        loop {
            match self.try_send(v) {
                Ok(()) => return Ok(()),
                Err(TrySendError::Disconnected(x)) => return Err(SendError(x)),
                Err(TrySendError::Full(x)) => {
                    v = x;
                    let ring = &self.ring;
                    ring.tx_waiter.wait_until(|| {
                        let tail = ring.tail.0.load(Ordering::Relaxed);
                        let head = ring.head.0.load(Ordering::Acquire);
                        tail.wrapping_sub(head) <= ring.mask
                            || !ring.rx_alive.load(Ordering::Acquire)
                    });
                }
            }
        }
    }

    /// Publish a rollback epoch on the out-of-band bulletin: a monotone
    /// `fetch_max` that bypasses ring capacity entirely, so a recovery
    /// notice can never be wedged behind a full ring (the other half of
    /// the drain-on-epoch-bump rule). Wakes the consumer.
    pub fn post_epoch(&self, epoch: u32) {
        self.ring.epoch.fetch_max(epoch as u64 + 1, Ordering::AcqRel);
        self.ring.rx_waiter.notify();
    }

    /// Slots currently queued (diagnostics/tests).
    pub fn len(&self) -> usize {
        let tail = self.ring.tail.0.load(Ordering::Relaxed);
        let head = self.ring.head.0.load(Ordering::Acquire);
        tail.wrapping_sub(head)
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Slot capacity fixed at construction.
    pub fn capacity(&self) -> usize {
        self.ring.mask + 1
    }
}

impl<T> Drop for Producer<T> {
    fn drop(&mut self) {
        self.ring.tx_alive.store(false, Ordering::Release);
        self.ring.rx_waiter.notify();
    }
}

/// Error from [`Consumer::try_recv`].
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum TryRecvError {
    /// Nothing queued right now.
    Empty,
    /// Nothing queued and the producer is gone: nothing will ever arrive.
    Disconnected,
}

/// The receiving half of an SPSC ring. `Send` but not `Clone`/`Sync`:
/// exactly one consumer.
pub struct Consumer<T> {
    ring: Arc<Ring<T>>,
    /// See [`Producer`]: movable, never shareable by reference.
    _not_sync: std::marker::PhantomData<std::cell::Cell<()>>,
}

impl<T> Consumer<T> {
    /// Non-blocking receive.
    pub fn try_recv(&self) -> Result<T, TryRecvError> {
        let head = self.ring.head.0.load(Ordering::Relaxed); // we own head
        let tail = self.ring.tail.0.load(Ordering::Acquire);
        if head == tail {
            if self.ring.tx_alive.load(Ordering::Acquire) {
                return Err(TryRecvError::Empty);
            }
            // The producer may have pushed right before dropping; one
            // re-read after the Acquire on the flag settles it.
            if self.ring.tail.0.load(Ordering::Acquire) == head {
                return Err(TryRecvError::Disconnected);
            }
        }
        let v = unsafe { (*self.ring.buf[head & self.ring.mask].get()).assume_init_read() };
        self.ring.head.0.store(head.wrapping_add(1), Ordering::Release);
        self.ring.tx_waiter.notify();
        Ok(v)
    }

    /// Blocking receive: spins briefly, then parks on the ring's waiter.
    /// `Err` means the producer is gone and the ring is drained.
    pub fn recv(&self) -> Result<T, TryRecvError> {
        loop {
            match self.try_recv() {
                Ok(v) => return Ok(v),
                Err(TryRecvError::Disconnected) => return Err(TryRecvError::Disconnected),
                Err(TryRecvError::Empty) => {
                    let ring = &self.ring;
                    ring.rx_waiter.wait_until(|| {
                        ring.head.0.load(Ordering::Relaxed)
                            != ring.tail.0.load(Ordering::Acquire)
                            || !ring.tx_alive.load(Ordering::Acquire)
                    });
                }
            }
        }
    }

    /// Latest epoch posted on the out-of-band bulletin, if any. Returns
    /// the raw monotone level: 0 = never posted, `e + 1` = epoch `e`
    /// posted. Callers keep their own high-water mark and deliver the
    /// difference (see `engine::ReplyRx`).
    pub fn epoch_level(&self) -> u64 {
        self.ring.epoch.load(Ordering::Acquire)
    }

    /// Messages currently queued.
    pub fn len(&self) -> usize {
        let head = self.ring.head.0.load(Ordering::Relaxed);
        let tail = self.ring.tail.0.load(Ordering::Acquire);
        tail.wrapping_sub(head)
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// True once the producer is gone *and* the ring is drained — the
    /// point at which a port can be retired from a poll set.
    pub fn is_disconnected(&self) -> bool {
        // Empty-check first: tx_alive must be read after tail so a final
        // push before the drop is never missed.
        self.is_empty() && !self.ring.tx_alive.load(Ordering::Acquire) && self.is_empty()
    }

    /// True when a scan of this port could make progress (data queued, a
    /// fresh bulletin above `seen_epoch`, or a disconnect to observe).
    pub fn pollable(&self, seen_epoch: u64) -> bool {
        !self.is_empty()
            || self.epoch_level() > seen_epoch
            || !self.ring.tx_alive.load(Ordering::Acquire)
    }

    /// The waiter producer-side pushes notify — shared across all rings
    /// built with [`spsc_shared`] on the same waiter.
    pub fn waiter(&self) -> &Arc<Waiter> {
        &self.ring.rx_waiter
    }
}

impl<T> Drop for Consumer<T> {
    fn drop(&mut self) {
        self.ring.rx_alive.store(false, Ordering::Release);
        self.ring.tx_waiter.notify();
    }
}

/// Build a bounded SPSC ring holding at least `capacity` messages
/// (rounded up to a power of two). All slot memory is allocated here;
/// nothing allocates afterwards.
pub fn spsc<T>(capacity: usize) -> (Producer<T>, Consumer<T>) {
    spsc_shared(capacity, Arc::new(Waiter::new()))
}

/// [`spsc`] whose consumer-side wakeups go to `rx_waiter`, so one thread
/// can park once for many rings (see module docs).
pub fn spsc_shared<T>(capacity: usize, rx_waiter: Arc<Waiter>) -> (Producer<T>, Consumer<T>) {
    assert!(capacity >= 1, "ring capacity must be at least 1");
    let cap = capacity.next_power_of_two();
    let buf: Box<[UnsafeCell<MaybeUninit<T>>]> = (0..cap)
        .map(|_| UnsafeCell::new(MaybeUninit::uninit()))
        .collect();
    let ring = Arc::new(Ring {
        buf,
        mask: cap - 1,
        head: CachePadded(AtomicUsize::new(0)),
        tail: CachePadded(AtomicUsize::new(0)),
        epoch: AtomicU64::new(0),
        tx_alive: AtomicBool::new(true),
        rx_alive: AtomicBool::new(true),
        rx_waiter,
        tx_waiter: Waiter::new(),
    });
    (
        Producer {
            ring: ring.clone(),
            _not_sync: std::marker::PhantomData,
        },
        Consumer {
            ring,
            _not_sync: std::marker::PhantomData,
        },
    )
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::time::{Duration, Instant};

    #[test]
    fn fifo_roundtrip_same_thread() {
        let (tx, rx) = spsc::<u32>(4);
        assert_eq!(rx.try_recv(), Err(TryRecvError::Empty));
        for i in 0..4 {
            tx.try_send(i).unwrap();
        }
        assert_eq!(tx.try_send(99), Err(TrySendError::Full(99)));
        for i in 0..4 {
            assert_eq!(rx.try_recv(), Ok(i));
        }
        assert_eq!(rx.try_recv(), Err(TryRecvError::Empty));
        // Space reclaimed: the ring cycles indefinitely.
        for lap in 0..100u32 {
            tx.try_send(lap).unwrap();
            assert_eq!(rx.try_recv(), Ok(lap));
        }
    }

    #[test]
    fn capacity_rounds_up_to_power_of_two() {
        let (tx, _rx) = spsc::<u8>(5);
        assert_eq!(tx.capacity(), 8);
        let (tx, _rx) = spsc::<u8>(1);
        assert_eq!(tx.capacity(), 1);
    }

    #[test]
    fn cross_thread_blocking_roundtrip() {
        let (tx, rx) = spsc::<u64>(8);
        let n = 10_000u64;
        let h = std::thread::spawn(move || {
            let mut sum = 0u64;
            for _ in 0..n {
                sum += rx.recv().unwrap();
            }
            assert_eq!(rx.recv(), Err(TryRecvError::Disconnected));
            sum
        });
        for i in 0..n {
            tx.send(i).unwrap();
        }
        drop(tx);
        assert_eq!(h.join().unwrap(), n * (n - 1) / 2);
    }

    /// Backpressure: a full ring blocks its producer without dropping or
    /// reordering anything; every message arrives exactly once.
    #[test]
    fn full_ring_blocks_producer_without_drop() {
        let (tx, rx) = spsc::<u32>(2);
        let n = 1000u32;
        let h = std::thread::spawn(move || {
            for i in 0..n {
                tx.send(i).unwrap();
            }
        });
        // Drain slowly at first so the producer provably hits Full.
        std::thread::sleep(Duration::from_millis(10));
        for i in 0..n {
            assert_eq!(rx.recv(), Ok(i));
        }
        h.join().unwrap();
    }

    /// The queue-per-core isolation claim: a deliberately slow consumer
    /// stalls only its own producer; an independent ring pair on the same
    /// machine streams freely the whole time.
    #[test]
    fn slow_consumer_stalls_only_its_own_producer() {
        let (slow_tx, slow_rx) = spsc::<u32>(2);
        let (fast_tx, fast_rx) = spsc::<u32>(8);
        let slow = std::thread::spawn(move || {
            for i in 0..100 {
                slow_tx.send(i).unwrap(); // blocks almost immediately
            }
        });
        let fast = std::thread::spawn(move || {
            for i in 0..100_000u32 {
                fast_tx.send(i).unwrap();
            }
        });
        // The fast pair completes while the slow consumer sleeps.
        for i in 0..100_000u32 {
            assert_eq!(fast_rx.recv(), Ok(i));
        }
        fast.join().unwrap();
        assert!(!slow.is_finished(), "slow producer should still be blocked");
        for i in 0..100 {
            assert_eq!(slow_rx.recv(), Ok(i)); // no drop, order intact
        }
        slow.join().unwrap();
    }

    /// The epoch bulletin bypasses a full ring: a rollback posted while
    /// the ring is wedged with dead-round traffic is visible immediately.
    #[test]
    fn epoch_bulletin_bypasses_a_full_ring() {
        let (tx, rx) = spsc::<u32>(2);
        tx.try_send(1).unwrap();
        tx.try_send(2).unwrap();
        assert!(matches!(tx.try_send(3), Err(TrySendError::Full(3))));
        assert_eq!(rx.epoch_level(), 0);
        tx.post_epoch(4);
        tx.post_epoch(2); // monotone: lower epochs never regress the level
        assert_eq!(rx.epoch_level(), 5, "level = epoch + 1");
        // The wedged data is still there, in order, behind the bulletin.
        assert_eq!(rx.try_recv(), Ok(1));
    }

    /// A consumer parked on a shared waiter wakes for a bulletin post
    /// even when no message is ever pushed (rollback still delivered).
    #[test]
    fn parked_consumer_wakes_on_bulletin_alone() {
        let (tx, rx) = spsc::<u32>(2);
        let h = std::thread::spawn(move || {
            let mut seen = 0u64;
            rx.waiter().clone().wait_until(|| rx.pollable(seen));
            seen = rx.epoch_level();
            seen
        });
        std::thread::sleep(Duration::from_millis(20));
        tx.post_epoch(7);
        assert_eq!(h.join().unwrap(), 8);
    }

    #[test]
    fn producer_drop_unblocks_and_disconnects_consumer() {
        let (tx, rx) = spsc::<String>(4);
        tx.send("last".to_string()).unwrap();
        let h = std::thread::spawn(move || {
            assert_eq!(rx.recv().unwrap(), "last");
            // Blocks until the drop below, then reports disconnect.
            rx.recv()
        });
        std::thread::sleep(Duration::from_millis(10));
        drop(tx);
        assert_eq!(h.join().unwrap(), Err(TryRecvError::Disconnected));
    }

    #[test]
    fn consumer_drop_unblocks_producer_with_message_back() {
        let (tx, rx) = spsc::<u32>(1);
        tx.send(1).unwrap();
        let h = std::thread::spawn(move || tx.send(2));
        std::thread::sleep(Duration::from_millis(10));
        drop(rx);
        assert_eq!(h.join().unwrap(), Err(SendError(2)));
    }

    /// In-flight messages are dropped (destructors run) when both ends go.
    #[test]
    fn ring_drop_releases_in_flight_messages() {
        let payload = Arc::new(());
        let (tx, rx) = spsc::<Arc<()>>(4);
        for _ in 0..3 {
            tx.try_send(payload.clone()).unwrap();
        }
        drop(rx.try_recv().unwrap());
        drop((tx, rx));
        assert_eq!(Arc::strong_count(&payload), 1, "queued clones dropped");
    }

    /// Two rings sharing one waiter: the consumer thread parks once and
    /// wakes for traffic on either.
    #[test]
    fn shared_waiter_multiplexes_rings() {
        let waiter = Arc::new(Waiter::new());
        let (tx_a, rx_a) = spsc_shared::<u32>(4, waiter.clone());
        let (tx_b, rx_b) = spsc_shared::<u32>(4, waiter.clone());
        let h = std::thread::spawn(move || {
            let mut got = Vec::new();
            while got.len() < 4 {
                waiter.wait_until(|| rx_a.pollable(0) || rx_b.pollable(0));
                while let Ok(v) = rx_a.try_recv() {
                    got.push(v);
                }
                while let Ok(v) = rx_b.try_recv() {
                    got.push(v);
                }
            }
            got.sort_unstable();
            got
        });
        std::thread::sleep(Duration::from_millis(5));
        tx_a.send(1).unwrap();
        tx_b.send(2).unwrap();
        std::thread::sleep(Duration::from_millis(5));
        tx_a.send(3).unwrap();
        tx_b.send(4).unwrap();
        assert_eq!(h.join().unwrap(), vec![1, 2, 3, 4]);
        drop((tx_a, tx_b));
    }

    /// An idle consumer parks rather than spinning: its thread burns no
    /// meaningful CPU while waiting (smoke check via wall-clock park).
    #[test]
    fn idle_consumer_parks_until_notified() {
        let (tx, rx) = spsc::<u32>(2);
        let t0 = Instant::now();
        let h = std::thread::spawn(move || {
            let v = rx.recv().unwrap();
            (v, Instant::now())
        });
        std::thread::sleep(Duration::from_millis(50));
        tx.send(42).unwrap();
        let (v, woke) = h.join().unwrap();
        assert_eq!(v, 42);
        assert!(woke.duration_since(t0) >= Duration::from_millis(45));
    }
}
