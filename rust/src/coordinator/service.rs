//! The PHub service API surface (paper section 3.1): job rendezvous,
//! namespace isolation, and nonce-based access control.
//!
//! `CreateService` establishes a namespace + nonce on the connection
//! manager; `ConnectService` rendezvouses workers (replacing
//! `Van::Connect` / `connectFullMesh` / `GrpcServer::Init` in MXNet /
//! Caffe2 / TensorFlow); `InitService` allocates and registers the
//! receive/merge buffers. Authentication is a one-time overhead: once a
//! worker is admitted, its identity is assumed stable for the run.

use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

use super::chunk::KeyTable;
use super::optimizer::Optimizer;
use super::server::{JobId, PHubServer, WorkerHandle};

/// Errors from the service control plane.
///
/// (Hand-implemented `Display`/`Error`: the offline environment has no
/// `thiserror`, and the derive was the crate's only proc-macro dependency.)
#[derive(Debug, PartialEq)]
pub enum ServiceError {
    NamespaceTaken(String),
    UnknownNamespace(String),
    BadNonce(String),
    NotInitialized,
    SlotTaken(usize),
    /// Rejected at the control-plane edge so invalid parameters can never
    /// reach an assert while a lock is held (see `transport.rs` for the
    /// equivalent wire-level check).
    InvalidSpec(String),
}

impl std::fmt::Display for ServiceError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            ServiceError::NamespaceTaken(ns) => write!(f, "namespace {ns:?} already exists"),
            ServiceError::UnknownNamespace(ns) => write!(f, "unknown namespace {ns:?}"),
            ServiceError::BadNonce(ns) => write!(f, "bad nonce for namespace {ns:?}"),
            ServiceError::NotInitialized => write!(f, "service not initialized"),
            ServiceError::SlotTaken(w) => write!(f, "worker slot {w} already connected"),
            ServiceError::InvalidSpec(why) => write!(f, "invalid service spec: {why}"),
        }
    }
}

impl std::error::Error for ServiceError {}

/// Most workers a single job supports (see the u64 arrival bitmask in
/// `aggregation.rs` — that module owns the authoritative constant).
pub use super::aggregation::MAX_WORKERS;

/// Handle returned by `CreateService`; the nonce is the job's credential.
#[derive(Debug, Clone)]
pub struct ServiceHandle {
    pub namespace: String,
    pub nonce: u64,
}

struct ServiceState {
    nonce: u64,
    n_workers: usize,
    job: Option<JobId>,
    connected: Vec<bool>,
    /// Round epoch of the job (bumped by [`ConnectionManager::rollback_service`]).
    epoch: u32,
}

/// The connection manager: the control-plane front of a PHub instance.
pub struct ConnectionManager {
    server: Arc<PHubServer>,
    services: Mutex<HashMap<String, ServiceState>>,
    nonce_seed: AtomicU64,
}

impl ConnectionManager {
    pub fn new(server: Arc<PHubServer>) -> Arc<ConnectionManager> {
        Arc::new(ConnectionManager {
            server,
            services: Mutex::new(HashMap::new()),
            nonce_seed: AtomicU64::new(0x9E3779B97F4A7C15),
        })
    }

    pub fn server(&self) -> &Arc<PHubServer> {
        &self.server
    }

    /// `PHub::CreateService`: reserve a namespace for a training job and
    /// mint its nonce.
    pub fn create_service(
        &self,
        namespace: &str,
        n_workers: usize,
    ) -> Result<ServiceHandle, ServiceError> {
        if n_workers == 0 || n_workers > MAX_WORKERS {
            return Err(ServiceError::InvalidSpec(format!(
                "n_workers {n_workers} not in 1..={MAX_WORKERS}"
            )));
        }
        let mut svcs = self.services.lock().unwrap();
        if svcs.contains_key(namespace) {
            return Err(ServiceError::NamespaceTaken(namespace.to_string()));
        }
        // splitmix64 step: deterministic but well-mixed nonces.
        let mut z = self
            .nonce_seed
            .fetch_add(0x9E3779B97F4A7C15, Ordering::SeqCst);
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
        let nonce = z ^ (z >> 31);
        svcs.insert(
            namespace.to_string(),
            ServiceState {
                nonce,
                n_workers,
                job: None,
                connected: vec![false; n_workers],
                epoch: 0,
            },
        );
        Ok(ServiceHandle {
            namespace: namespace.to_string(),
            nonce,
        })
    }

    /// `PHub::InitService`: allocate receive/merge buffers (the chunk
    /// slots on the core threads) and install the initial model.
    pub fn init_service(
        &self,
        handle: &ServiceHandle,
        table: KeyTable,
        init_params: &[f32],
        opt: Arc<dyn Optimizer>,
    ) -> Result<(), ServiceError> {
        // Validate before touching state: `init_job` asserts on bad input,
        // and a panic under `services` would poison the control plane.
        if init_params.len() != table.total_elems {
            return Err(ServiceError::InvalidSpec(format!(
                "init_params length {} != model elems {}",
                init_params.len(),
                table.total_elems
            )));
        }
        let mut svcs = self.services.lock().unwrap();
        let st = svcs
            .get_mut(&handle.namespace)
            .ok_or_else(|| ServiceError::UnknownNamespace(handle.namespace.clone()))?;
        if st.nonce != handle.nonce {
            return Err(ServiceError::BadNonce(handle.namespace.clone()));
        }
        let job = self
            .server
            .init_job(table, init_params, opt, st.n_workers);
        st.job = Some(job);
        Ok(())
    }

    /// `PHub::ConnectService`: authenticate worker `w` by nonce and hand
    /// it its data-plane handle.
    pub fn connect_service(
        &self,
        handle: &ServiceHandle,
        w: usize,
    ) -> Result<WorkerHandle, ServiceError> {
        let mut svcs = self.services.lock().unwrap();
        let st = svcs
            .get_mut(&handle.namespace)
            .ok_or_else(|| ServiceError::UnknownNamespace(handle.namespace.clone()))?;
        if st.nonce != handle.nonce {
            return Err(ServiceError::BadNonce(handle.namespace.clone()));
        }
        let job = st.job.ok_or(ServiceError::NotInitialized)?;
        if w >= st.connected.len() {
            return Err(ServiceError::InvalidSpec(format!(
                "worker slot {w} out of range for {}-worker service",
                st.n_workers
            )));
        }
        if st.connected[w] {
            return Err(ServiceError::SlotTaken(w));
        }
        st.connected[w] = true;
        Ok(self.server.worker(job, w))
    }

    /// Rewind the namespace's open round (nonce-authenticated): bump the
    /// job's round epoch and issue a `RollbackRound` to the cores via
    /// [`PHubServer::rollback_round`]. Connected in-process workers learn
    /// about it from the rollback notice on their reply channels and
    /// replay transparently inside `push_pull` — the embedder's lever for
    /// recovering a job whose worker died mid-round (the TCP leader does
    /// this automatically; see `transport.rs`).
    ///
    /// Returns the new epoch.
    pub fn rollback_service(&self, handle: &ServiceHandle) -> Result<u32, ServiceError> {
        let mut svcs = self.services.lock().unwrap();
        let st = svcs
            .get_mut(&handle.namespace)
            .ok_or_else(|| ServiceError::UnknownNamespace(handle.namespace.clone()))?;
        if st.nonce != handle.nonce {
            return Err(ServiceError::BadNonce(handle.namespace.clone()));
        }
        let job = st.job.ok_or(ServiceError::NotInitialized)?;
        st.epoch += 1;
        self.server.rollback_round(job, st.epoch);
        Ok(st.epoch)
    }

    /// Tear down a namespace and evict its state from the cores.
    pub fn destroy_service(&self, handle: &ServiceHandle) -> Result<(), ServiceError> {
        let mut svcs = self.services.lock().unwrap();
        let st = svcs
            .remove(&handle.namespace)
            .ok_or_else(|| ServiceError::UnknownNamespace(handle.namespace.clone()))?;
        if st.nonce != handle.nonce {
            svcs.insert(handle.namespace.clone(), st);
            return Err(ServiceError::BadNonce(handle.namespace.clone()));
        }
        if let Some(job) = st.job {
            self.server.evict(job);
        }
        Ok(())
    }

    pub fn n_services(&self) -> usize {
        self.services.lock().unwrap().len()
    }

    /// The engine-side job id of an initialized namespace — how an
    /// embedder maps a `ServiceHandle` to the id the status plane's
    /// `/trace?job=` route and the per-job metrics use.
    pub fn service_job(&self, namespace: &str) -> Option<JobId> {
        self.services.lock().unwrap().get(namespace)?.job
    }
}

/// The status plane's tenant check, backed by the same per-service
/// nonce minted at `create_service`: `nonce` authorizes `job` exactly
/// when some initialized service maps to that job and holds that nonce.
/// Job A's nonce can never read job B's trace.
impl super::status::JobAuth for ConnectionManager {
    fn check(&self, job: JobId, nonce: u64) -> bool {
        self.services
            .lock()
            .unwrap()
            .values()
            .any(|st| st.job == Some(job) && st.nonce == nonce)
    }
}

#[cfg(test)]
#[allow(clippy::useless_vec)]
mod tests {
    use super::*;
    use crate::coordinator::optimizer::Sgd;
    use crate::coordinator::server::ServerConfig;

    fn setup() -> Arc<ConnectionManager> {
        ConnectionManager::new(PHubServer::start(ServerConfig::cores(2)))
    }

    #[test]
    fn create_init_connect_roundtrip() {
        let cm = setup();
        let h = cm.create_service("jobA", 2).unwrap();
        cm.init_service(&h, KeyTable::flat(32, 8), &vec![0.0; 32], Arc::new(Sgd { lr: 0.1 }))
            .unwrap();
        let w0 = cm.connect_service(&h, 0).unwrap();
        assert_eq!(w0.model_len(), 32);
        // Slot reuse rejected.
        assert_eq!(
            cm.connect_service(&h, 0).err().unwrap(),
            ServiceError::SlotTaken(0)
        );
    }

    #[test]
    fn namespace_collision_rejected() {
        let cm = setup();
        cm.create_service("dup", 1).unwrap();
        assert_eq!(
            cm.create_service("dup", 1).unwrap_err(),
            ServiceError::NamespaceTaken("dup".into())
        );
    }

    #[test]
    fn bad_nonce_rejected() {
        let cm = setup();
        let mut h = cm.create_service("job", 1).unwrap();
        h.nonce ^= 1;
        assert!(matches!(
            cm.init_service(&h, KeyTable::flat(8, 8), &vec![0.0; 8], Arc::new(Sgd { lr: 0.1 })),
            Err(ServiceError::BadNonce(_))
        ));
    }

    #[test]
    fn connect_before_init_fails() {
        let cm = setup();
        let h = cm.create_service("early", 1).unwrap();
        assert_eq!(
            cm.connect_service(&h, 0).err().unwrap(),
            ServiceError::NotInitialized
        );
    }

    #[test]
    fn nonces_differ_across_services() {
        let cm = setup();
        let a = cm.create_service("a", 1).unwrap();
        let b = cm.create_service("b", 1).unwrap();
        assert_ne!(a.nonce, b.nonce);
    }

    #[test]
    fn invalid_specs_rejected_without_poisoning() {
        let cm = setup();
        // Worker counts outside 1..=64 never reach the u64-bitmask assert.
        assert!(matches!(
            cm.create_service("zero", 0),
            Err(ServiceError::InvalidSpec(_))
        ));
        assert!(matches!(
            cm.create_service("huge", MAX_WORKERS + 1),
            Err(ServiceError::InvalidSpec(_))
        ));
        // Mismatched init params are an error, not an assert under the lock.
        let h = cm.create_service("job", 1).unwrap();
        assert!(matches!(
            cm.init_service(&h, KeyTable::flat(32, 8), &vec![0.0; 16], Arc::new(Sgd { lr: 0.1 })),
            Err(ServiceError::InvalidSpec(_))
        ));
        // Out-of-range slot is an error, not an index panic.
        cm.init_service(&h, KeyTable::flat(32, 8), &vec![0.0; 32], Arc::new(Sgd { lr: 0.1 }))
            .unwrap();
        assert!(matches!(
            cm.connect_service(&h, 5),
            Err(ServiceError::InvalidSpec(_))
        ));
        // The control plane still works after every rejection.
        assert_eq!(cm.connect_service(&h, 0).unwrap().model_len(), 32);
    }

    /// The rollback lever is nonce-gated and requires an initialized job;
    /// a legitimate rollback on a partially-pushed round lets the round
    /// replay to the exact clean-round result.
    #[test]
    fn rollback_service_authenticated_and_recovers() {
        let cm = setup();
        let h = cm.create_service("rb", 2).unwrap();
        assert_eq!(
            cm.rollback_service(&h).unwrap_err(),
            ServiceError::NotInitialized
        );
        cm.init_service(&h, KeyTable::flat(16, 8), &vec![0.0; 16], Arc::new(Sgd { lr: 0.5 }))
            .unwrap();
        let mut bad = h.clone();
        bad.nonce ^= 1;
        assert!(matches!(
            cm.rollback_service(&bad),
            Err(ServiceError::BadNonce(_))
        ));

        let mut w0 = cm.connect_service(&h, 0).unwrap();
        let mut w1 = cm.connect_service(&h, 1).unwrap();
        // Worker 1 pushes half the round, then the embedder rolls it back
        // (as if worker 1's owner had died and been replaced).
        let (lo, hi) = w1.chunk_range(0);
        w1.push_chunk(0, vec![9.0f32; hi - lo].into(), true);
        assert_eq!(cm.rollback_service(&h).unwrap(), 1);
        // Full replay: both workers run the round; the half-push is gone.
        let g0 = vec![1.0f32; 16];
        let g1 = vec![3.0f32; 16];
        let (m0, m1) = std::thread::scope(|s| {
            let t = s.spawn(|| w1.push_pull(&g1));
            (w0.push_pull(&g0), t.join().unwrap())
        });
        assert_eq!(m0, m1);
        // p -= 0.5 * mean(1, 3) = -1, not tainted by the 9s.
        assert!(m0.iter().all(|&x| (x + 1.0).abs() < 1e-6), "{:?}", &m0[..2]);
    }

    /// The status plane's tenant check: a namespace's nonce authorizes
    /// exactly its own job — never a sibling's.
    #[test]
    fn job_auth_scopes_nonce_to_own_job() {
        use crate::coordinator::status::JobAuth as _;
        let cm = setup();
        let ha = cm.create_service("a", 1).unwrap();
        let hb = cm.create_service("b", 1).unwrap();
        let sgd = || Arc::new(Sgd { lr: 0.1 });
        cm.init_service(&ha, KeyTable::flat(8, 8), &vec![0.0; 8], sgd())
            .unwrap();
        cm.init_service(&hb, KeyTable::flat(8, 8), &vec![0.0; 8], sgd())
            .unwrap();
        let ja = cm.service_job("a").unwrap();
        let jb = cm.service_job("b").unwrap();
        assert_ne!(ja, jb);
        assert!(cm.check(ja, ha.nonce));
        assert!(cm.check(jb, hb.nonce));
        assert!(!cm.check(jb, ha.nonce), "job A's nonce must not read job B");
        assert!(!cm.check(ja, hb.nonce));
        assert!(!cm.check(ja, ha.nonce ^ 1));
        assert_eq!(cm.service_job("missing"), None);
    }

    #[test]
    fn destroy_frees_namespace() {
        let cm = setup();
        let h = cm.create_service("gone", 1).unwrap();
        cm.destroy_service(&h).unwrap();
        assert_eq!(cm.n_services(), 0);
        // Namespace reusable after destroy.
        cm.create_service("gone", 1).unwrap();
    }
}
