//! Optimizers (paper section 3.2.2).
//!
//! PHub's aggregators and optimizers are "fully extensible: implementations
//! that comply with PHub's API can be used during runtime". The API here is
//! chunk-granular: the thread that aggregates a chunk immediately optimizes
//! the same chunk on the same core, so implementations must be pure
//! element-range updates with per-chunk state slices and no cross-chunk
//! coupling.

/// A chunk-granular optimizer.
///
/// `step` updates `params[..]` in place from the *mean* gradient `grad`,
/// with `state` the optimizer's slice of per-element state for this chunk
/// (e.g. the momentum buffer). All slices have equal length.
pub trait Optimizer: Send + Sync {
    /// Per-element f32 state words required (0 = stateless).
    fn state_words(&self) -> usize;
    fn step(&self, params: &mut [f32], state: &mut [f32], grad: &[f32]);
    fn name(&self) -> &'static str;
}

/// Plain SGD: `p -= lr * g`.
#[derive(Debug, Clone)]
pub struct Sgd {
    pub lr: f32,
}

impl Optimizer for Sgd {
    fn state_words(&self) -> usize {
        0
    }

    fn step(&self, params: &mut [f32], _state: &mut [f32], grad: &[f32]) {
        debug_assert_eq!(params.len(), grad.len());
        for (p, g) in params.iter_mut().zip(grad) {
            *p -= self.lr * g;
        }
    }

    fn name(&self) -> &'static str {
        "sgd"
    }
}

/// SGD with Nesterov's accelerated gradient (the paper's evaluation
/// optimizer, section 4.2), MXNet update rule:
///
/// ```text
/// m' = mu * m + g
/// p' = p - lr * (g + mu * m')
/// ```
///
/// This matches `agg_opt_ref`/the Pallas kernel exactly, so the Rust PS and
/// the AOT artifact produce identical training trajectories.
#[derive(Debug, Clone)]
pub struct NesterovSgd {
    pub lr: f32,
    pub momentum: f32,
}

impl Optimizer for NesterovSgd {
    fn state_words(&self) -> usize {
        1
    }

    fn step(&self, params: &mut [f32], state: &mut [f32], grad: &[f32]) {
        debug_assert_eq!(params.len(), grad.len());
        debug_assert_eq!(state.len(), grad.len());
        let (lr, mu) = (self.lr, self.momentum);
        for i in 0..params.len() {
            let m = mu * state[i] + grad[i];
            state[i] = m;
            params[i] -= lr * (grad[i] + mu * m);
        }
    }

    fn name(&self) -> &'static str {
        "nesterov-sgd"
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sgd_step() {
        let o = Sgd { lr: 0.5 };
        let mut p = vec![1.0f32, 2.0];
        o.step(&mut p, &mut [], &[0.2, -0.4]);
        assert_eq!(p, vec![0.9, 2.2]);
    }

    #[test]
    fn nesterov_matches_reference_recurrence() {
        let o = NesterovSgd {
            lr: 0.1,
            momentum: 0.9,
        };
        let mut p = vec![1.0f32];
        let mut m = vec![0.0f32];
        // Two steps with g = 1.0.
        o.step(&mut p, &mut m, &[1.0]);
        // m = 1.0; p = 1 - 0.1*(1 + 0.9) = 0.81
        assert!((p[0] - 0.81).abs() < 1e-6, "{}", p[0]);
        o.step(&mut p, &mut m, &[1.0]);
        // m = 0.9 + 1 = 1.9; p = 0.81 - 0.1*(1 + 1.71) = 0.539
        assert!((m[0] - 1.9).abs() < 1e-6);
        assert!((p[0] - 0.539).abs() < 1e-6, "{}", p[0]);
    }

    #[test]
    fn chunk_composition_equals_whole_vector() {
        // Optimizing two half-chunks must equal optimizing the whole
        // vector: the no-cross-chunk-coupling property tall aggregation
        // relies on.
        let o = NesterovSgd {
            lr: 0.05,
            momentum: 0.8,
        };
        let g: Vec<f32> = (0..64).map(|i| (i as f32 * 0.37).sin()).collect();
        let mut p1: Vec<f32> = (0..64).map(|i| i as f32 * 0.01).collect();
        let mut m1 = vec![0.0f32; 64];
        let mut p2 = p1.clone();
        let mut m2 = m1.clone();
        for _ in 0..3 {
            o.step(&mut p1, &mut m1, &g);
            let (pa, pb) = p2.split_at_mut(32);
            let (ma, mb) = m2.split_at_mut(32);
            o.step(pa, ma, &g[..32]);
            o.step(pb, mb, &g[32..]);
        }
        assert_eq!(p1, p2);
        assert_eq!(m1, m2);
    }

    #[test]
    fn state_words() {
        assert_eq!(Sgd { lr: 0.1 }.state_words(), 0);
        assert_eq!(
            NesterovSgd {
                lr: 0.1,
                momentum: 0.9
            }
            .state_words(),
            1
        );
    }
}
