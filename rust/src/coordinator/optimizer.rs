//! Optimizers (paper section 3.2.2).
//!
//! PHub's aggregators and optimizers are "fully extensible: implementations
//! that comply with PHub's API can be used during runtime". The API here is
//! chunk-granular: the thread that aggregates a chunk immediately optimizes
//! the same chunk on the same core, so implementations must be pure
//! element-range updates with per-chunk state slices and no cross-chunk
//! coupling.
//!
//! The engine drives the *fused* entry point [`Optimizer::step_scaled`]:
//! it receives the raw gradient **sum** plus `1/n` and computes the mean
//! inline, so finishing a round is one pass over the accumulator instead
//! of a scale pass followed by an optimizer pass. Built-in impls override
//! it by delegating to the explicit SIMD kernels in [`super::kernels`]
//! (AVX2/SSE2/scalar, selected once at startup, property-tested
//! bit-identical across tiers); the default materializes the mean and
//! delegates to `step`, so any external impl stays correct unchanged.
//! `step_scaled` must be bit-identical to `scale(sum, 1/n)` followed by
//! `step` — compute `g = sum[i] * inv_n` first (one f32 rounding, same
//! as the unfused scale) and never reassociate it into the update
//! arithmetic; the kernels preserve exactly this evaluation order.

/// Lane width of the unfused `step` loops (mirrors `aggregation::LANES`).
/// The fused `step_scaled` hot paths dispatch through `kernels` instead.
const LANES: usize = 8;

/// A chunk-granular optimizer.
///
/// `step` updates `params[..]` in place from the *mean* gradient `grad`,
/// with `state` the optimizer's slice of per-element state for this chunk
/// (e.g. the momentum buffer). All slices have equal length.
pub trait Optimizer: Send + Sync {
    /// Per-element f32 state words required (0 = stateless).
    fn state_words(&self) -> usize;
    fn step(&self, params: &mut [f32], state: &mut [f32], grad: &[f32]);
    fn name(&self) -> &'static str;

    /// Fused mean+step: update from the raw gradient sum `grad_sum`,
    /// where the mean gradient is `grad_sum[i] * inv_n`. Must produce
    /// exactly the bits of scaling first and then calling
    /// [`Optimizer::step`] (the engine relies on this for
    /// rollback-replay bit-identity). The default does exactly that —
    /// with an allocation — so implementations on the hot path should
    /// override it with a single fused loop.
    fn step_scaled(&self, params: &mut [f32], state: &mut [f32], grad_sum: &[f32], inv_n: f32) {
        let mean: Vec<f32> = grad_sum.iter().map(|g| g * inv_n).collect();
        self.step(params, state, &mean);
    }
}

/// Plain SGD: `p -= lr * g`.
#[derive(Debug, Clone)]
pub struct Sgd {
    pub lr: f32,
}

impl Optimizer for Sgd {
    fn state_words(&self) -> usize {
        0
    }

    fn step(&self, params: &mut [f32], _state: &mut [f32], grad: &[f32]) {
        debug_assert_eq!(params.len(), grad.len());
        let lr = self.lr;
        let mut p = params.chunks_exact_mut(LANES);
        let mut g = grad.chunks_exact(LANES);
        for (pp, gg) in (&mut p).zip(&mut g) {
            for i in 0..LANES {
                pp[i] -= lr * gg[i];
            }
        }
        for (pp, gg) in p.into_remainder().iter_mut().zip(g.remainder()) {
            *pp -= lr * gg;
        }
    }

    fn step_scaled(&self, params: &mut [f32], _state: &mut [f32], grad_sum: &[f32], inv_n: f32) {
        debug_assert_eq!(params.len(), grad_sum.len());
        super::kernels::sgd_step_scaled(params, grad_sum, inv_n, self.lr);
    }

    fn name(&self) -> &'static str {
        "sgd"
    }
}

/// SGD with Nesterov's accelerated gradient (the paper's evaluation
/// optimizer, section 4.2), MXNet update rule:
///
/// ```text
/// m' = mu * m + g
/// p' = p - lr * (g + mu * m')
/// ```
///
/// This matches `agg_opt_ref`/the Pallas kernel exactly, so the Rust PS and
/// the AOT artifact produce identical training trajectories.
#[derive(Debug, Clone)]
pub struct NesterovSgd {
    pub lr: f32,
    pub momentum: f32,
}

impl Optimizer for NesterovSgd {
    fn state_words(&self) -> usize {
        1
    }

    fn step(&self, params: &mut [f32], state: &mut [f32], grad: &[f32]) {
        debug_assert_eq!(params.len(), grad.len());
        debug_assert_eq!(state.len(), grad.len());
        let (lr, mu) = (self.lr, self.momentum);
        let mut p = params.chunks_exact_mut(LANES);
        let mut st = state.chunks_exact_mut(LANES);
        let mut g = grad.chunks_exact(LANES);
        for ((pp, mm), gg) in (&mut p).zip(&mut st).zip(&mut g) {
            for i in 0..LANES {
                let m = mu * mm[i] + gg[i];
                mm[i] = m;
                pp[i] -= lr * (gg[i] + mu * m);
            }
        }
        for ((pp, mm), gg) in p
            .into_remainder()
            .iter_mut()
            .zip(st.into_remainder().iter_mut())
            .zip(g.remainder())
        {
            let m = mu * *mm + gg;
            *mm = m;
            *pp -= lr * (gg + mu * m);
        }
    }

    fn step_scaled(&self, params: &mut [f32], state: &mut [f32], grad_sum: &[f32], inv_n: f32) {
        debug_assert_eq!(params.len(), grad_sum.len());
        debug_assert_eq!(state.len(), grad_sum.len());
        super::kernels::nesterov_step_scaled(
            params,
            state,
            grad_sum,
            inv_n,
            self.lr,
            self.momentum,
        );
    }

    fn name(&self) -> &'static str {
        "nesterov-sgd"
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn sgd_step() {
        let o = Sgd { lr: 0.5 };
        let mut p = vec![1.0f32, 2.0];
        o.step(&mut p, &mut [], &[0.2, -0.4]);
        assert_eq!(p, vec![0.9, 2.2]);
    }

    #[test]
    fn nesterov_matches_reference_recurrence() {
        let o = NesterovSgd {
            lr: 0.1,
            momentum: 0.9,
        };
        let mut p = vec![1.0f32];
        let mut m = vec![0.0f32];
        // Two steps with g = 1.0.
        o.step(&mut p, &mut m, &[1.0]);
        // m = 1.0; p = 1 - 0.1*(1 + 0.9) = 0.81
        assert!((p[0] - 0.81).abs() < 1e-6, "{}", p[0]);
        o.step(&mut p, &mut m, &[1.0]);
        // m = 0.9 + 1 = 1.9; p = 0.81 - 0.1*(1 + 1.71) = 0.539
        assert!((m[0] - 1.9).abs() < 1e-6);
        assert!((p[0] - 0.539).abs() < 1e-6, "{}", p[0]);
    }

    #[test]
    fn chunk_composition_equals_whole_vector() {
        // Optimizing two half-chunks must equal optimizing the whole
        // vector: the no-cross-chunk-coupling property tall aggregation
        // relies on.
        let o = NesterovSgd {
            lr: 0.05,
            momentum: 0.8,
        };
        let g: Vec<f32> = (0..64).map(|i| (i as f32 * 0.37).sin()).collect();
        let mut p1: Vec<f32> = (0..64).map(|i| i as f32 * 0.01).collect();
        let mut m1 = vec![0.0f32; 64];
        let mut p2 = p1.clone();
        let mut m2 = m1.clone();
        for _ in 0..3 {
            o.step(&mut p1, &mut m1, &g);
            let (pa, pb) = p2.split_at_mut(32);
            let (ma, mb) = m2.split_at_mut(32);
            o.step(pa, ma, &g[..32]);
            o.step(pb, mb, &g[32..]);
        }
        assert_eq!(p1, p2);
        assert_eq!(m1, m2);
    }

    /// The fused pass equals scale-then-step bit-for-bit for both
    /// built-ins, across lengths that exercise the lane remainders.
    #[test]
    fn step_scaled_matches_scale_then_step() {
        for len in [1usize, 7, 8, 9, 40] {
            let sum: Vec<f32> = (0..len).map(|i| (i as f32 * 0.61).sin() * 3.0).collect();
            let inv_n = 1.0f32 / 3.0;
            let mean: Vec<f32> = sum.iter().map(|g| g * inv_n).collect();

            let sgd = Sgd { lr: 0.37 };
            let mut pa: Vec<f32> = (0..len).map(|i| i as f32 * 0.1).collect();
            let mut pb = pa.clone();
            sgd.step(&mut pa, &mut [], &mean);
            sgd.step_scaled(&mut pb, &mut [], &sum, inv_n);
            assert_eq!(pa, pb, "sgd len {len}");

            let nes = NesterovSgd {
                lr: 0.1,
                momentum: 0.9,
            };
            let mut pa: Vec<f32> = (0..len).map(|i| i as f32 * 0.1).collect();
            let mut ma: Vec<f32> = (0..len).map(|i| (i as f32 * 0.2).cos()).collect();
            let mut pb = pa.clone();
            let mut mb = ma.clone();
            nes.step(&mut pa, &mut ma, &mean);
            nes.step_scaled(&mut pb, &mut mb, &sum, inv_n);
            assert_eq!(pa, pb, "nesterov params len {len}");
            assert_eq!(ma, mb, "nesterov momentum len {len}");
        }
    }

    #[test]
    fn state_words() {
        assert_eq!(Sgd { lr: 0.1 }.state_words(), 0);
        assert_eq!(
            NesterovSgd {
                lr: 0.1,
                momentum: 0.9
            }
            .state_words(),
            1
        );
    }
}
