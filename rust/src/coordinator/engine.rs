//! The round-epoch engine: the single home of PHub's per-chunk round
//! state machine.
//!
//! PHub's data plane (paper §3.2) gives every chunk one pinned core that
//! owns its whole life — receive, aggregate, optimize, transmit — with no
//! cross-core synchronization. Before this module existed that state
//! machine lived twice: once in the in-process server's core loop and once
//! re-derived inside the TCP leader's connection threads. Both copies
//! panicked on protocol violations and neither could recover a round, so a
//! worker dying mid-round permanently wedged its job.
//!
//! This module is now the only place that knows what a round *is*:
//!
//! * [`ShardEngine`] — the server side. One instance per core thread, it
//!   owns that core's shard of every job's chunk slots, tagged with an
//!   explicit `(epoch, round)` ([`RoundTag`]): `epoch` counts rollbacks of
//!   the job, `round` counts completed rounds of each chunk. `absorb` /
//!   `complete` / `rollback` transitions return `Result` — a protocol
//!   violation can cost at most the offending connection, never a shared
//!   core thread.
//! * [`WorkerRound`] — the connection edge. Tracks one worker's progress
//!   through the open round (which chunks it pushed, how many replies it
//!   is owed, which epoch it lives in) so transports stay thin framing
//!   shells with no arrival bookkeeping of their own.
//!
//! # Memory discipline
//!
//! The engine's steady-state round is **exact-zero allocation** (no
//! exclusions) and touches each gradient twice (absorb fold, fused
//! mean+optimizer pass):
//!
//! * Pushes arrive as [`GradSrc`] — an f32 slice from the in-process
//!   path, or raw wire bytes (dense or 2-bit) from the TCP leader's
//!   pooled frame buffers. The aggregator folds the decode into its
//!   accumulate loop, so no intermediate `Vec<f32>` exists on the push
//!   path (`aggregation.rs` has the loop-level contract).
//! * Round completion runs `ChunkAggregator::take_mean_into_step` +
//!   `Optimizer::step_scaled`: one fused pass over the accumulator
//!   instead of a scale pass plus an optimizer pass.
//! * A completion with `P` pullers copies the fresh parameters **once**
//!   into a refcount-shared pooled buffer ([`SharedF32`]) and hands each
//!   puller a refcount bump; the buffer (refcount block included)
//!   recycles to the engine's pool when the last receiver drops it —
//!   single-copy broadcast with no per-completion `Arc` allocation.
//! * Replies travel over bounded lock-free SPSC rings ([`super::ring`],
//!   one per (worker, core)); the old `std::sync::mpsc` hop — a lock
//!   under contention plus a queue-block allocation every ~31 sends —
//!   is gone, so the reply route holds the same exact-zero invariant as
//!   the rest of the path (`rust/tests/alloc_discipline.rs`).
//!
//! # Mid-round rollback
//!
//! When a worker dies after pushing some chunks, the leader bumps the
//! job's epoch and issues a `RollbackRound` to the owning cores. Each core
//! rewinds only the chunks that saw partial arrivals (using the arrival
//! bitmask — completed chunks keep their optimized parameters and their
//! advanced `round` tag), drops the job's pending pull masks, and notifies
//! every worker's reply channel. Surviving workers replay the round; a
//! push that replays a chunk that had already completed is answered
//! directly from the slot's current parameters, so the replayed round is
//! bit-identical to an uninterrupted one. In-flight pushes that still
//! carry the old epoch are rejected by tag ([`PushOutcome::StaleEpoch`])
//! instead of corrupting the fresh round — and a *replayed* push that
//! overtakes its own core's `RollbackRound` message (the pusher learned
//! the new epoch from a faster core) makes the shard apply the rollback
//! itself from the push's epoch tag, so the message race can never drop
//! a replayed gradient.
//!
//! The same rollback machinery serves two triggers: a *detected* death
//! (the worker's socket closes, mid-frame or between frames) and a
//! *declared* one (the leader's round deadline fires on a worker that
//! went silent mid-round — see `DeadlineConfig` and the failure-model
//! contract in `super::transport`). Either way the engine only ever
//! sees "this connection's round ended early"; the recovery path is
//! identical and bit-exact.
//!
//! # Node roles: Root vs RackRelay
//!
//! The chunk-complete transition is role-parameterized ([`NodeRole`]),
//! splitting "local sum ready" from "parameters ready" so the same
//! engine can sit at either level of the paper's hierarchy (§3.4,
//! Fig. 19):
//!
//! * **Root** — today's single-rack behavior: the last arrival triggers
//!   the fused mean+optimizer pass (dividing by the job's **total
//!   worker weight**, not the direct pusher count — a relay pushing the
//!   sum of `k` workers registers weight `k` via
//!   [`ShardEngine::set_worker_weight`], so the root's mean is exact),
//!   the round advances, and parameters broadcast to pullers. With all
//!   weights at their default of 1 the divisor is bit-for-bit
//!   `1/n_workers`, so flat deployments are unchanged.
//! * **RackRelay** — the last *local* arrival closes only the
//!   aggregation: the raw per-chunk **sum** (never divided, never
//!   optimized) is copied once into a pooled buffer and sent as
//!   [`Reply::Sum`] over the shard's uplink lane, the chunk enters an
//!   `awaiting` state, and pull masks are held. When the parent's
//!   parameters come back, [`ShardEngine::install_params_src`] writes
//!   them into the slot and performs the deferred broadcast — the
//!   "parameters ready" half. Replayed pushes of an awaiting chunk
//!   defer their pull to that same install instead of answering with
//!   stale parameters, so rack-local recovery composes with the
//!   upstream exchange: a rack's epoch bump rewinds only its partial
//!   chunks, replays re-complete them to bit-identical sums, and the
//!   uplink forwards each chunk's sum exactly once per round.

use std::collections::HashMap;
use std::fmt;
use std::sync::Arc;

use super::aggregation::{copy_dequant, copy_f32s_le, AggError, ChunkAggregator, GradSrc};
use super::optimizer::Optimizer;
use super::pool::{SharedF32, SharedF32Pool, SharedPool};
use super::ring;

/// Job identifier (one training job / tenant namespace).
pub type JobId = u32;

/// Idle reply buffers an engine retains (soft cap; see `pool.rs`). Sized
/// comfortably above the in-flight reply count of a busy core so the
/// steady state never re-allocates.
const REPLY_POOL_MAX_FREE: usize = 1024;

/// Position of a push in a job's life: which rollback epoch it belongs to
/// and which round of its chunk it contributes to.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct RoundTag {
    /// Rollback generation of the job; bumped once per mid-round recovery.
    pub epoch: u32,
    /// Completed-round count of the target chunk at the time of the push.
    pub round: u64,
}

impl RoundTag {
    pub fn new(epoch: u32, round: u64) -> RoundTag {
        RoundTag { epoch, round }
    }
}

/// Which level of the hierarchy a job's aggregation node sits at — the
/// parameter that splits the chunk-complete transition into "local sum
/// ready" (RackRelay) vs "parameters ready" (Root). See the module docs.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum NodeRole {
    /// Top of the hierarchy: optimize exactly once per round, fan
    /// parameters down. The flat single-rack leader is a Root with every
    /// worker at weight 1.
    Root,
    /// Rack level: tall-aggregate the rack's workers, forward the raw
    /// per-chunk sum upstream ([`Reply::Sum`]), and fan the parent's
    /// returned parameters back down
    /// ([`ShardEngine::install_params_src`]).
    RackRelay,
}

/// A round-protocol violation detected by the engine.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum EngineError {
    UnknownJob(JobId),
    UnknownChunk { job: JobId, chunk: u32 },
    /// A push for a round its chunk has not opened yet (the pusher ran
    /// ahead of the synchronous barrier).
    FutureRound { got: u64, open: u64 },
    /// This worker already pushed this chunk in the open round.
    DuplicateChunk { chunk: u32 },
    /// An aggregation-level violation (duplicate worker, bad length, ...).
    Agg(AggError),
}

impl fmt::Display for EngineError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            EngineError::UnknownJob(job) => write!(f, "unknown job {job}"),
            EngineError::UnknownChunk { job, chunk } => {
                write!(f, "chunk {chunk} not on this core for job {job}")
            }
            EngineError::FutureRound { got, open } => {
                write!(f, "push tagged round {got} ahead of open round {open}")
            }
            EngineError::DuplicateChunk { chunk } => {
                write!(f, "duplicate push of chunk {chunk} in one round")
            }
            EngineError::Agg(e) => write!(f, "{e}"),
        }
    }
}

impl std::error::Error for EngineError {}

impl From<AggError> for EngineError {
    fn from(e: AggError) -> EngineError {
        EngineError::Agg(e)
    }
}

/// What a successful push did.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum PushOutcome {
    /// Absorbed; the chunk's round is still open.
    Absorbed,
    /// Absorbed the last missing gradient: the chunk was optimized, its
    /// round completed, and replies went out to every puller.
    Completed,
    /// The push carried a pre-rollback epoch (in flight when the round was
    /// rewound); it was dropped by tag. Not a protocol violation.
    StaleEpoch,
    /// The push replayed a round its chunk had already completed (rollback
    /// recovery); the current parameters were re-sent to the pusher.
    Replayed,
}

/// Updated parameters (or a rollback notice) for one worker.
///
/// `epoch` stamps the state generation a chunk reply belongs to, so a
/// receiver that has been told about a rollback can discard replies that
/// were already in flight for the dead round.
#[derive(Debug, Clone)]
pub enum Reply {
    /// Updated parameters for one chunk. `data` is a refcount-shared
    /// pooled buffer: every puller of the completion holds the *same*
    /// serialized-once parameters, and the last receiver to drop its
    /// reference recycles the buffer to the owning engine's pool.
    Chunk {
        job: JobId,
        chunk: u32,
        epoch: u32,
        data: SharedF32,
    },
    /// The job's open round was rewound; replay it under `epoch`. On the
    /// wire between engine and worker this never occupies a ring slot —
    /// it is synthesized by [`ReplyRx`] from the ring's monotone epoch
    /// bulletin ([`ring::Producer::post_epoch`]), so a full ring of
    /// dead-round replies can never wedge a recovery notice.
    RolledBack { job: JobId, epoch: u32 },
    /// A RackRelay chunk's locally-complete raw gradient **sum** (no
    /// divide, no optimizer step), bound for the relay's uplink thread
    /// over the shard's uplink lane. `round` is the local round the sum
    /// closes; `epoch` is the rack-local rollback generation at close
    /// time (diagnostic — rack epochs are invisible upstream). `data` is
    /// an exclusively-held pooled buffer that recycles when the uplink
    /// drops it after encoding.
    Sum {
        job: JobId,
        chunk: u32,
        epoch: u32,
        round: u64,
        data: SharedF32,
    },
}

/// The engine side of one worker's reply route: one SPSC producer per
/// (worker, core) ring.
pub type ReplyTx = ring::Producer<Reply>;

/// The worker side of its reply route: the per-core reply rings
/// multiplexed behind one waiter, with rollback notices synthesized from
/// the rings' epoch bulletins.
///
/// Delivery order is the drain-on-epoch-bump rule from the recovery
/// design: before any queued reply from a ring is handed out, that
/// ring's bulletin is checked, so a worker always learns about a
/// rollback **no later than** the first reply sent after it — exactly
/// the FIFO guarantee the old in-band mpsc notice gave — while stale
/// dead-round replies drain naturally through the receiver's existing
/// epoch filters.
pub struct ReplyRx {
    job: JobId,
    rings: Vec<ring::Consumer<Reply>>,
    /// Bulletin level already delivered, per ring.
    seen: Vec<u64>,
    /// Ring observed empty+disconnected (job evicted / engine gone).
    dead: Vec<bool>,
    /// A reply popped together with fresh bulletin news: the notice goes
    /// out first, this reply on the next call.
    stashed: Option<Reply>,
    /// Scan cursor for round-robin fairness across rings.
    cursor: usize,
    waiter: Arc<ring::Waiter>,
}

impl ReplyRx {
    /// Multiplex `rings` (all built on `waiter` via [`ring::spsc_shared`])
    /// into one receiver for `job`'s worker.
    pub fn new(job: JobId, rings: Vec<ring::Consumer<Reply>>, waiter: Arc<ring::Waiter>) -> ReplyRx {
        let n = rings.len();
        ReplyRx {
            job,
            rings,
            seen: vec![0; n],
            dead: vec![false; n],
            stashed: None,
            cursor: 0,
            waiter,
        }
    }

    /// Non-blocking receive across all rings; `None` when nothing is
    /// deliverable right now.
    pub fn try_recv(&mut self) -> Option<Reply> {
        if let Some(r) = self.stashed.take() {
            return Some(r);
        }
        // Bulletins first: a rollback notice outranks queued data.
        for i in 0..self.rings.len() {
            let lvl = self.rings[i].epoch_level();
            if lvl > self.seen[i] {
                self.seen[i] = lvl;
                return Some(Reply::RolledBack {
                    job: self.job,
                    epoch: (lvl - 1) as u32,
                });
            }
        }
        let n = self.rings.len();
        for k in 0..n {
            let i = (self.cursor + k) % n;
            match self.rings[i].try_recv() {
                Ok(r) => {
                    self.cursor = (i + 1) % n;
                    // Same-ring ordering: if this ring posted a bulletin
                    // before (or while) sending `r`, deliver the notice
                    // first and stash the reply.
                    let lvl = self.rings[i].epoch_level();
                    if lvl > self.seen[i] {
                        self.seen[i] = lvl;
                        self.stashed = Some(r);
                        return Some(Reply::RolledBack {
                            job: self.job,
                            epoch: (lvl - 1) as u32,
                        });
                    }
                    return Some(r);
                }
                Err(ring::TryRecvError::Empty) => {}
                Err(ring::TryRecvError::Disconnected) => self.dead[i] = true,
            }
        }
        None
    }

    /// Blocking receive: parks until a reply or rollback notice arrives.
    /// `None` means every ring's engine side is gone (job evicted or
    /// server shut down) — nothing will ever arrive.
    pub fn recv(&mut self) -> Option<Reply> {
        loop {
            if let Some(r) = self.try_recv() {
                return Some(r);
            }
            if self.dead.iter().all(|&d| d) {
                return None;
            }
            let ReplyRx {
                rings,
                seen,
                dead,
                waiter,
                ..
            } = self;
            waiter.wait_until(|| {
                rings
                    .iter()
                    .zip(seen.iter())
                    .zip(dead.iter())
                    .any(|((r, &s), &d)| !d && r.pollable(s))
            });
        }
    }
}

/// Build one worker's reply fabric across `n_cores` cores: the engine
/// producers (index = core) and the worker's multiplexed receiver. Each
/// ring holds `capacity` replies; producers block (backpressure) beyond
/// that.
pub fn reply_fabric(job: JobId, n_cores: usize, capacity: usize) -> (Vec<ReplyTx>, ReplyRx) {
    let waiter = Arc::new(ring::Waiter::new());
    let mut txs = Vec::with_capacity(n_cores);
    let mut rxs = Vec::with_capacity(n_cores);
    for _ in 0..n_cores {
        let (tx, rx) = ring::spsc_shared(capacity, waiter.clone());
        txs.push(tx);
        rxs.push(rx);
    }
    (txs, ReplyRx::new(job, rxs, waiter))
}

/// [`reply_fabric`] in the common test/bench shape: `n_workers`
/// independent single-core lanes for `job`. Returns the engine-side
/// producers (index = worker, as `ShardEngine::init_job` expects) and
/// each worker's receiver.
pub fn single_lane_fabrics(
    job: JobId,
    n_workers: usize,
    capacity: usize,
) -> (Vec<ReplyTx>, Vec<ReplyRx>) {
    let mut txs = Vec::with_capacity(n_workers);
    let mut rxs = Vec::with_capacity(n_workers);
    for _ in 0..n_workers {
        let (mut tx, rx) = reply_fabric(job, 1, capacity);
        txs.push(tx.pop().expect("single lane"));
        rxs.push(rx);
    }
    (txs, rxs)
}

/// One chunk's server-side state: parameters, optimizer state, streaming
/// aggregator, and the `(epoch, round)` position — the paper's receive →
/// aggregate → optimize → transmit pipeline stage, pinned to one core.
struct ChunkSlot {
    params: Vec<f32>,
    state: Vec<f32>,
    agg: ChunkAggregator,
    /// Completed rounds of this chunk (the `round` half of its tag; the
    /// `epoch` half is job-wide and lives on the shard).
    round: u64,
    /// RackRelay only: the local sum for round `round - 1` went upstream
    /// and the parent's parameters have not come back yet. Pull masks
    /// (including replayed pulls) are held until the install.
    awaiting: bool,
}

impl ChunkSlot {
    fn new(params: Vec<f32>, state_words: usize, n_workers: usize) -> ChunkSlot {
        let len = params.len();
        ChunkSlot {
            state: vec![0.0; len * state_words],
            agg: ChunkAggregator::new(len, n_workers),
            params,
            round: 0,
            awaiting: false,
        }
    }

    /// Rebuild a slot from an exported [`ChunkState`] — the readmission
    /// half of idle-eviction parameter handoff. The aggregator starts
    /// empty (no round is open for an idle job) and the `(params,
    /// state, round)` triple is installed verbatim, so the first round
    /// after readmission computes exactly what round `round` of the
    /// uninterrupted job would have.
    fn resume(cs: ChunkState, state_words: usize, n_workers: usize) -> ChunkSlot {
        let len = cs.params.len();
        debug_assert_eq!(cs.state.len(), len * state_words, "optimizer state shape mismatch");
        ChunkSlot {
            agg: ChunkAggregator::new(len, n_workers),
            params: cs.params,
            state: cs.state,
            round: cs.round,
            awaiting: false,
        }
    }
}

/// One chunk's exportable round position: everything the optimizer math
/// of future rounds depends on. The handoff unit of idle eviction — a
/// job rebuilt from its `ChunkState`s (plus the transport's residual
/// checkpoints for quantized tenants) trains bit-identically to one
/// that was never evicted.
#[derive(Debug, Clone, PartialEq)]
pub struct ChunkState {
    /// Chunk id within the job.
    pub chunk: u32,
    /// Final parameters at eviction.
    pub params: Vec<f32>,
    /// Optimizer state (`params.len() * state_words` f32s; empty for
    /// stateless optimizers).
    pub state: Vec<f32>,
    /// Completed rounds of this chunk.
    pub round: u64,
}

/// One job's state on one core: that core's shard of the job's chunks.
struct JobShard {
    chunks: HashMap<u32, ChunkSlot>,
    opt: Arc<dyn Optimizer>,
    /// One SPSC reply ring producer per worker (this core's lane of each
    /// worker's reply fabric).
    replies: Vec<ReplyTx>,
    /// Which workers asked to pull each chunk this round.
    pull_mask: HashMap<u32, u64>,
    /// Rollback generation; pushes tagged with an older epoch are stale.
    epoch: u32,
    n_workers: usize,
    /// Which level of the hierarchy this node plays for the job.
    role: NodeRole,
    /// Downstream worker weights (how many leaf workers each direct
    /// pusher represents; plain workers are 1, a relay is its rack
    /// size). The Root's mean divides by the sum of these.
    weights: Vec<u32>,
    /// `1 / weights.sum()`, cached so the completion path stays a single
    /// multiply. Bit-for-bit `1/n_workers` when all weights are 1.
    inv_weight: f32,
    /// RackRelay only: this core's lane of the uplink reply fabric, the
    /// route [`Reply::Sum`] takes to the uplink thread.
    uplink: Option<ReplyTx>,
}

/// Copy `params` once into a refcount-shared pooled buffer and send it
/// to every worker whose bit is set in `mask` — the single-copy reply
/// broadcast. Each send is a refcount bump, not a copy; the buffer
/// (refcount block included) recycles to `pool` when the last receiver
/// drops it. A send to a vanished worker is ignored: the handed-back
/// reply drops its reference on the spot.
///
/// The serialization work on the core is therefore independent of the
/// puller count: one copy of `params.len()` floats whether 1 or 64
/// workers pulled (`benches/ring.rs` measures exactly this).
///
/// The sends block on a full ring (backpressure). Within the round
/// protocol that cannot happen: a worker has at most one round in
/// flight (the TCP connection thread reads no further frames until the
/// round's replies drain; the in-process `push_pull`/`pull` APIs are
/// `&mut self` barriers), so outstanding replies per (worker, core)
/// ring never exceed the `2 * chunks_on_core + slack` the server sizes
/// it for — a hostile wire peer cannot wedge a shared core. Only an
/// in-process embedder driving the manual `push_chunk(pull=true)` API
/// across rounds without collecting replies can invoke the
/// backpressure, and it stalls exactly the chunks it shares a core
/// with — the documented bounded-memory trade, not a protocol hazard.
fn broadcast_params(
    pool: &Arc<SharedF32Pool>,
    txs: &[ReplyTx],
    mask: u64,
    job: JobId,
    chunk: u32,
    epoch: u32,
    params: &[f32],
) {
    if mask == 0 {
        return;
    }
    let mut buf = pool.take();
    buf.extend_from_slice(params);
    let data = buf; // shared from here on: clones bump the pooled refcount
    for (i, tx) in txs.iter().enumerate() {
        if mask & (1u64 << i) != 0 {
            let _ = tx.send(Reply::Chunk {
                job,
                chunk,
                epoch,
                data: data.clone(),
            });
        }
    }
    // `data` drops here; the buffer returns to the pool once every
    // receiver is done with it.
}

/// The per-core round engine: owns every job shard on one core thread and
/// every transition of the round state machine.
pub struct ShardEngine {
    jobs: HashMap<JobId, JobShard>,
    /// Recycling pool behind every reply this engine sends (buffer and
    /// refcount block recycle together).
    pool: Arc<SharedF32Pool>,
}

impl Default for ShardEngine {
    fn default() -> Self {
        ShardEngine::new()
    }
}

impl ShardEngine {
    pub fn new() -> ShardEngine {
        ShardEngine {
            jobs: HashMap::new(),
            pool: SharedPool::new(REPLY_POOL_MAX_FREE),
        }
    }

    /// Install a job's shard as a [`NodeRole::Root`] (the flat
    /// single-rack leader): this core's chunks with their initial
    /// parameters, the shared optimizer, and one reply channel per
    /// worker.
    pub fn init_job(
        &mut self,
        job: JobId,
        chunks: Vec<(u32, Vec<f32>)>,
        opt: Arc<dyn Optimizer>,
        n_workers: usize,
        replies: Vec<ReplyTx>,
    ) {
        self.init_job_with_role(job, chunks, opt, n_workers, replies, NodeRole::Root, None);
    }

    /// [`ShardEngine::init_job`] with an explicit [`NodeRole`]. A
    /// `RackRelay` shard must be given `uplink` — this core's lane of
    /// the uplink reply fabric — since that is where its chunk sums go.
    /// Worker weights start at 1; the admission path raises a relay
    /// connection's weight via [`ShardEngine::set_worker_weight`].
    pub fn init_job_with_role(
        &mut self,
        job: JobId,
        chunks: Vec<(u32, Vec<f32>)>,
        opt: Arc<dyn Optimizer>,
        n_workers: usize,
        replies: Vec<ReplyTx>,
        role: NodeRole,
        uplink: Option<ReplyTx>,
    ) {
        assert!(
            role != NodeRole::RackRelay || uplink.is_some(),
            "a RackRelay shard needs an uplink lane for its sums"
        );
        let mut map = HashMap::new();
        for (id, params) in chunks {
            map.insert(id, ChunkSlot::new(params, opt.state_words(), n_workers));
        }
        self.jobs.insert(
            job,
            JobShard {
                chunks: map,
                opt,
                replies,
                pull_mask: HashMap::new(),
                epoch: 0,
                n_workers,
                role,
                weights: vec![1; n_workers],
                inv_weight: 1.0 / n_workers as f32,
                uplink,
            },
        );
    }

    /// Register how many leaf workers direct pusher `worker` represents
    /// (a relay's rack size; plain workers stay at the default 1). The
    /// Root's mean divides by the job's total weight, so with two
    /// relays of weight `k` the divisor is `2k` — exactly the flat
    /// deployment's `1/n` over the same leaf workers. Weights below 1
    /// are clamped to 1. Idempotent per connection; a reconnecting
    /// relay re-registers the same weight.
    pub fn set_worker_weight(
        &mut self,
        job: JobId,
        worker: u32,
        weight: u32,
    ) -> Result<(), EngineError> {
        let shard = self.jobs.get_mut(&job).ok_or(EngineError::UnknownJob(job))?;
        let w = worker as usize;
        if w >= shard.n_workers {
            return Err(EngineError::Agg(AggError::WorkerOutOfRange {
                worker: w,
                n_workers: shard.n_workers,
            }));
        }
        shard.weights[w] = weight.max(1);
        // Sum in u64: 64 workers × u32 weights must not overflow on a
        // hostile registration (the quotient is approximate in f32 for
        // huge totals, which is fine — only its exactness for real
        // power-of-two totals is load-bearing).
        shard.inv_weight = 1.0 / shard.weights.iter().map(|&w| w as u64).sum::<u64>() as f32;
        Ok(())
    }

    /// Install a job's shard from exported [`ChunkState`]s — the
    /// readmission half of idle-eviction parameter handoff
    /// ([`NodeRole::Root`] only: relays hold no durable state worth
    /// handing off, their parameters come from the parent). Each slot
    /// resumes at its exported `(params, state, round)` position with a
    /// fresh epoch 0: eviction requires zero live connections, so no
    /// stale-epoch traffic from the previous incarnation can exist.
    pub fn init_job_resumed(
        &mut self,
        job: JobId,
        chunks: Vec<ChunkState>,
        opt: Arc<dyn Optimizer>,
        n_workers: usize,
        replies: Vec<ReplyTx>,
    ) {
        let state_words = opt.state_words();
        let mut map = HashMap::new();
        for cs in chunks {
            map.insert(cs.chunk, ChunkSlot::resume(cs, state_words, n_workers));
        }
        self.jobs.insert(
            job,
            JobShard {
                chunks: map,
                opt,
                replies,
                pull_mask: HashMap::new(),
                epoch: 0,
                n_workers,
                role: NodeRole::Root,
                weights: vec![1; n_workers],
                inv_weight: 1.0 / n_workers as f32,
                uplink: None,
            },
        );
    }

    /// Export this shard's chunks of `job` for parameter handoff:
    /// parameters, optimizer state, and round position, cloned (the
    /// shard keeps serving until [`ShardEngine::evict`]). Control-plane
    /// only — eviction happens with zero live connections, never on a
    /// round path. Chunks come back in arbitrary order; an unknown job
    /// exports empty.
    pub fn export_job(&self, job: JobId) -> Vec<ChunkState> {
        let Some(shard) = self.jobs.get(&job) else {
            return Vec::new();
        };
        shard
            .chunks
            .iter()
            .map(|(&chunk, slot)| ChunkState {
                chunk,
                params: slot.params.clone(),
                state: slot.state.clone(),
                round: slot.round,
            })
            .collect()
    }

    /// Borrow a chunk's current parameters (tests/diagnostics — the data
    /// plane reads them only through replies).
    pub fn chunk_params(&self, job: JobId, chunk: u32) -> Option<&[f32]> {
        self.jobs
            .get(&job)
            .and_then(|s| s.chunks.get(&chunk))
            .map(|c| c.params.as_slice())
    }

    /// Absorb worker `worker`'s gradient for `chunk` from a decoded f32
    /// slice (see [`ShardEngine::push_src`] for the wire-byte forms).
    pub fn push(
        &mut self,
        job: JobId,
        chunk: u32,
        worker: u32,
        data: &[f32],
        pull: bool,
        tag: RoundTag,
    ) -> Result<PushOutcome, EngineError> {
        self.push_src(job, chunk, worker, GradSrc::F32s(data), pull, tag)
    }

    /// Absorb worker `worker`'s gradient for `chunk`, tagged with the
    /// pusher's `(epoch, round)` position. The gradient arrives in
    /// whatever form the transport has ([`GradSrc`]) and is folded into
    /// the accumulator without intermediate buffers. On the last arrival
    /// the chunk is optimized in place (fused mean+step, one pass) and
    /// pooled-parameter replies go out to every worker that pulled.
    pub fn push_src(
        &mut self,
        job: JobId,
        chunk: u32,
        worker: u32,
        src: GradSrc<'_>,
        pull: bool,
        tag: RoundTag,
    ) -> Result<PushOutcome, EngineError> {
        let ShardEngine { jobs, pool } = self;
        let shard = jobs.get_mut(&job).ok_or(EngineError::UnknownJob(job))?;
        let w = worker as usize;
        if w >= shard.n_workers {
            return Err(EngineError::Agg(AggError::WorkerOutOfRange {
                worker: w,
                n_workers: shard.n_workers,
            }));
        }
        if tag.epoch < shard.epoch {
            // In flight when the round was rewound; the pusher has (or will
            // shortly receive) a RolledBack notice telling it to replay.
            return Ok(PushOutcome::StaleEpoch);
        }
        if tag.epoch > shard.epoch {
            // The pusher learned this epoch from a core that already
            // processed the rollback; this core's RollbackRound message is
            // still in flight behind the push. Apply the rollback now —
            // idempotent with the in-flight message — so a replayed
            // gradient can never be lost to the message race.
            rollback_shard(shard, job, tag.epoch);
        }
        let slot = shard
            .chunks
            .get_mut(&chunk)
            .ok_or(EngineError::UnknownChunk { job, chunk })?;
        if tag.round < slot.round {
            // Rollback replay of a chunk that had already completed this
            // round. On a Root (or an installed relay chunk) the slot's
            // parameters already include every worker's gradient, so
            // answer straight from the slot. On a relay chunk still
            // awaiting the parent's parameters, answering now would hand
            // out the *previous* round — hold the pull until
            // `install_params_src` performs the deferred broadcast.
            if slot.awaiting && tag.round + 1 == slot.round {
                if pull {
                    *shard.pull_mask.entry(chunk).or_insert(0) |= 1u64 << w;
                }
                return Ok(PushOutcome::Replayed);
            }
            if pull {
                broadcast_params(
                    pool,
                    &shard.replies,
                    1u64 << w,
                    job,
                    chunk,
                    shard.epoch,
                    &slot.params,
                );
            }
            return Ok(PushOutcome::Replayed);
        }
        if tag.round > slot.round {
            return Err(EngineError::FutureRound {
                got: tag.round,
                open: slot.round,
            });
        }
        let t_absorb = crate::trace::start();
        let done = slot.agg.absorb_src(w, src)?;
        crate::trace::span(crate::trace::Stage::Absorb, job, chunk, worker, t_absorb);
        if pull {
            *shard.pull_mask.entry(chunk).or_insert(0) |= 1u64 << w;
        }
        if !done {
            return Ok(PushOutcome::Absorbed);
        }
        // Last worker arrived — the role-parameterized transition.
        let ChunkSlot {
            params,
            state,
            agg,
            round,
            awaiting,
        } = slot;
        match shard.role {
            NodeRole::Root => {
                // Parameters ready: fused mean+optimizer step on this
                // same core (one pass over the accumulator, dividing by
                // the total worker weight), then broadcast to every
                // worker that pulled.
                let inv_w = shard.inv_weight;
                let t_opt = crate::trace::start();
                agg.take_mean_into_step(|sum, _inv_n| {
                    shard
                        .opt
                        .step_scaled(&mut params[..], &mut state[..], sum, inv_w)
                })?;
                crate::trace::span(crate::trace::Stage::Optimize, job, chunk, worker, t_opt);
                *round += 1;
                let mask = shard.pull_mask.remove(&chunk).unwrap_or(0);
                broadcast_params(pool, &shard.replies, mask, job, chunk, shard.epoch, params);
            }
            NodeRole::RackRelay => {
                // Local sum ready: copy the raw sum once into a pooled
                // buffer and hand it to the uplink lane — no divide, no
                // optimizer step, and the pull mask is held until the
                // parent's parameters come back (install_params_src).
                let uplink = shard
                    .uplink
                    .as_ref()
                    .expect("RackRelay shard initialized without an uplink lane");
                let epoch = shard.epoch;
                agg.take_mean_into_step(|sum, _inv_n| {
                    let mut buf = pool.take();
                    buf.extend_from_slice(sum);
                    let _ = uplink.send(Reply::Sum {
                        job,
                        chunk,
                        epoch,
                        round: *round,
                        data: buf,
                    });
                })?;
                *round += 1;
                *awaiting = true;
            }
        }
        Ok(PushOutcome::Completed)
    }

    /// The "parameters ready" half of a RackRelay round: write the
    /// parent's returned parameters for `chunk` into the slot (straight
    /// from their wire form — no intermediate buffer) and perform the
    /// broadcast deferred at sum time, stamped with the rack's *current*
    /// epoch so workers that rolled back while the sum was upstream
    /// still accept it. Returns `Ok(false)` if the chunk was not
    /// awaiting parameters (a duplicate install after a parent-side
    /// replay re-broadcast — the values are identical, the write is
    /// skipped), `Ok(true)` when installed and broadcast.
    pub fn install_params_src(
        &mut self,
        job: JobId,
        chunk: u32,
        src: GradSrc<'_>,
    ) -> Result<bool, EngineError> {
        let ShardEngine { jobs, pool } = self;
        let shard = jobs.get_mut(&job).ok_or(EngineError::UnknownJob(job))?;
        let slot = shard
            .chunks
            .get_mut(&chunk)
            .ok_or(EngineError::UnknownChunk { job, chunk })?;
        if !slot.awaiting {
            return Ok(false);
        }
        let len = src.elems()?;
        if len != slot.params.len() {
            return Err(EngineError::Agg(AggError::LengthMismatch {
                got: len,
                want: slot.params.len(),
            }));
        }
        match src {
            GradSrc::F32s(p) => slot.params.copy_from_slice(p),
            GradSrc::LeBytes(b) => copy_f32s_le(&mut slot.params, b),
            GradSrc::Quant2Bit {
                threshold, packed, ..
            } => copy_dequant(&mut slot.params, threshold, packed),
        }
        slot.awaiting = false;
        let mask = shard.pull_mask.remove(&chunk).unwrap_or(0);
        broadcast_params(
            pool,
            &shard.replies,
            mask,
            job,
            chunk,
            shard.epoch,
            &slot.params,
        );
        Ok(true)
    }

    /// Read-only pull of `chunk`'s current parameters for `worker`.
    pub fn pull(&mut self, job: JobId, chunk: u32, worker: u32) -> Result<(), EngineError> {
        let ShardEngine { jobs, pool } = self;
        let shard = jobs.get_mut(&job).ok_or(EngineError::UnknownJob(job))?;
        let w = worker as usize;
        if w >= shard.n_workers {
            return Err(EngineError::Agg(AggError::WorkerOutOfRange {
                worker: w,
                n_workers: shard.n_workers,
            }));
        }
        let slot = shard
            .chunks
            .get(&chunk)
            .ok_or(EngineError::UnknownChunk { job, chunk })?;
        broadcast_params(
            pool,
            &shard.replies,
            1u64 << w,
            job,
            chunk,
            shard.epoch,
            &slot.params,
        );
        Ok(())
    }

    /// Rewind the open round of `job` to recover from a mid-round worker
    /// death: advance the shard to `epoch`, roll back every chunk with
    /// partial arrivals (completed chunks keep their parameters and round
    /// tag), drop pending pull masks, and notify every worker's reply
    /// channel to replay. Idempotent: an epoch the shard already reached is
    /// a no-op, so duplicate rollback messages are harmless.
    ///
    /// Returns the number of chunks rewound.
    pub fn rollback(&mut self, job: JobId, epoch: u32) -> Result<usize, EngineError> {
        let shard = self.jobs.get_mut(&job).ok_or(EngineError::UnknownJob(job))?;
        Ok(rollback_shard(shard, job, epoch))
    }

    /// Drop a job's shard.
    pub fn evict(&mut self, job: JobId) {
        self.jobs.remove(&job);
    }
}

/// The rollback transition on one shard: advance the epoch, rewind every
/// chunk with partial arrivals, drop pending pull masks, notify every
/// worker. Idempotent — an epoch the shard already reached is a no-op, so
/// a duplicate `RollbackRound` message (or one arriving after a push
/// already self-healed the shard forward) is harmless. Returns the number
/// of chunks rewound.
///
/// The notice rides the reply rings' out-of-band epoch bulletin
/// ([`ring::Producer::post_epoch`]), not a ring slot: it is monotone and
/// capacity-independent, so a worker whose reply ring is wedged full of
/// dead-round traffic (or whose seat is parked awaiting a successor)
/// still learns the new epoch immediately — recovery can never deadlock
/// behind the very round it is rewinding.
fn rollback_shard(shard: &mut JobShard, job: JobId, epoch: u32) -> usize {
    if epoch <= shard.epoch {
        return 0;
    }
    shard.epoch = epoch;
    crate::trace::instant(crate::trace::Stage::Rollback, job, 0, 0);
    let mut rewound = 0usize;
    for slot in shard.chunks.values_mut() {
        if slot.agg.rollback() != 0 {
            rewound += 1;
        }
    }
    shard.pull_mask.clear();
    for tx in &shard.replies {
        tx.post_epoch(epoch);
    }
    rewound
}

/// One worker's view of the round state machine, kept at the connection
/// edge (the TCP leader holds one per connection; the in-process
/// `WorkerHandle` embeds the same counters). Transports own *no* round
/// bookkeeping of their own — they ask this tracker.
#[derive(Debug, Clone)]
pub struct WorkerRound {
    n_chunks: usize,
    epoch: u32,
    round: u64,
    /// Chunks this worker pushed in the open round.
    seen: Vec<bool>,
    pushed: usize,
    /// Replies owed to this worker for pulls issued this round.
    outstanding: usize,
}

impl WorkerRound {
    pub fn new(n_chunks: usize) -> WorkerRound {
        WorkerRound::resume(n_chunks, 0, 0)
    }

    /// Resume a worker slot at a known position — how a successor picks up
    /// where a parked (crashed) predecessor left off.
    pub fn resume(n_chunks: usize, epoch: u32, round: u64) -> WorkerRound {
        WorkerRound {
            n_chunks,
            epoch,
            round,
            seen: vec![false; n_chunks],
            pushed: 0,
            outstanding: 0,
        }
    }

    pub fn epoch(&self) -> u32 {
        self.epoch
    }

    pub fn round(&self) -> u64 {
        self.round
    }

    pub fn outstanding(&self) -> usize {
        self.outstanding
    }

    /// The tag every push of the open round carries.
    pub fn tag(&self) -> RoundTag {
        RoundTag::new(self.epoch, self.round)
    }

    /// Record a push of `chunk` (with a pull) in the open round.
    pub fn begin_push(&mut self, chunk: u32) -> Result<(), EngineError> {
        let ci = chunk as usize;
        debug_assert!(ci < self.n_chunks);
        if self.seen[ci] {
            return Err(EngineError::DuplicateChunk { chunk });
        }
        self.seen[ci] = true;
        self.pushed += 1;
        self.outstanding += 1;
        Ok(())
    }

    /// Every chunk of the round has been pushed; only replies remain.
    pub fn push_phase_done(&self) -> bool {
        self.pushed == self.n_chunks
    }

    /// Record a reply stamped with `epoch`. Returns `true` if it belongs
    /// to the current epoch (count it, forward it); `false` if it was in
    /// flight for a rolled-back round (drop it).
    pub fn note_reply(&mut self, epoch: u32) -> bool {
        if epoch != self.epoch {
            return false;
        }
        debug_assert!(self.outstanding > 0);
        self.outstanding = self.outstanding.saturating_sub(1);
        true
    }

    /// Apply a rollback notice. Returns `true` (state reset, epoch
    /// advanced, same round re-opened) when `epoch` is news; duplicate
    /// notices from other cores return `false`.
    pub fn apply_rollback(&mut self, epoch: u32) -> bool {
        if epoch <= self.epoch {
            return false;
        }
        self.epoch = epoch;
        self.seen.fill(false);
        self.pushed = 0;
        self.outstanding = 0;
        true
    }

    /// Close the round: every chunk pushed and every reply delivered.
    pub fn complete_round(&mut self) {
        debug_assert!(self.push_phase_done() && self.outstanding == 0);
        self.round += 1;
        self.seen.fill(false);
        self.pushed = 0;
    }

    /// Whether the connection is inside an open round — the state in which
    /// a disconnect requires a rollback before the slot can be recycled.
    pub fn mid_round(&self) -> bool {
        self.pushed > 0 || self.outstanding > 0
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::optimizer::Sgd;

    fn engine_with_job(
        n_workers: usize,
        chunks: Vec<(u32, Vec<f32>)>,
        lr: f32,
    ) -> (ShardEngine, Vec<ReplyRx>) {
        let mut eng = ShardEngine::new();
        // One "core" in these unit tests: single-lane reply fabrics.
        let (txs, rxs) = single_lane_fabrics(1, n_workers, 64);
        eng.init_job(1, chunks, Arc::new(Sgd { lr }), n_workers, txs);
        (eng, rxs)
    }

    fn chunk_reply(r: Reply) -> (u32, u32, Vec<f32>) {
        match r {
            Reply::Chunk {
                chunk, epoch, data, ..
            } => (chunk, epoch, data.to_vec()),
            other => panic!("expected chunk reply, got {other:?}"),
        }
    }

    #[test]
    fn push_completes_and_replies_to_pullers() {
        let (mut eng, mut rxs) = engine_with_job(2, vec![(0, vec![1.0, 1.0])], 0.5);
        let t = RoundTag::new(0, 0);
        assert_eq!(
            eng.push(1, 0, 0, &[2.0, 2.0], true, t).unwrap(),
            PushOutcome::Absorbed
        );
        assert_eq!(
            eng.push(1, 0, 1, &[4.0, 4.0], false, t).unwrap(),
            PushOutcome::Completed
        );
        // p -= 0.5 * mean(2, 4) = 1 - 1.5 = -0.5; only worker 0 pulled.
        let (chunk, epoch, data) = chunk_reply(rxs[0].recv().unwrap());
        assert_eq!((chunk, epoch), (0, 0));
        assert_eq!(data, vec![-0.5, -0.5]);
        assert!(rxs[1].try_recv().is_none());
    }

    /// Wire-byte pushes produce the same completion and bits as slice
    /// pushes — the leader's pooled-buffer path rides `push_src`.
    #[test]
    fn push_src_bytes_matches_slices() {
        let (mut eng_a, mut rxs_a) = engine_with_job(2, vec![(0, vec![1.0, 1.0])], 0.5);
        let (mut eng_b, mut rxs_b) = engine_with_job(2, vec![(0, vec![1.0, 1.0])], 0.5);
        let t = RoundTag::new(0, 0);
        let g0 = [2.0f32, -3.5];
        let g1 = [4.0f32, 0.25];
        let le = |g: &[f32]| -> Vec<u8> { g.iter().flat_map(|x| x.to_le_bytes()).collect() };
        eng_a.push(1, 0, 0, &g0, true, t).unwrap();
        eng_a.push(1, 0, 1, &g1, true, t).unwrap();
        eng_b
            .push_src(1, 0, 0, GradSrc::LeBytes(&le(&g0)), true, t)
            .unwrap();
        eng_b
            .push_src(1, 0, 1, GradSrc::LeBytes(&le(&g1)), true, t)
            .unwrap();
        for rxs in [&mut rxs_a, &mut rxs_b] {
            for rx in rxs.iter_mut() {
                assert!(matches!(rx.recv().unwrap(), Reply::Chunk { .. }));
            }
        }
        assert_eq!(eng_a.chunk_params(1, 0), eng_b.chunk_params(1, 0));
    }

    #[test]
    fn violations_are_typed_errors_not_panics() {
        let (mut eng, _rxs) = engine_with_job(2, vec![(0, vec![0.0])], 1.0);
        let t = RoundTag::new(0, 0);
        assert_eq!(eng.push(9, 0, 0, &[1.0], false, t), Err(EngineError::UnknownJob(9)));
        assert_eq!(
            eng.push(1, 7, 0, &[1.0], false, t),
            Err(EngineError::UnknownChunk { job: 1, chunk: 7 })
        );
        eng.push(1, 0, 0, &[1.0], false, t).unwrap();
        assert_eq!(
            eng.push(1, 0, 0, &[1.0], false, t),
            Err(EngineError::Agg(AggError::DuplicatePush { worker: 0 }))
        );
        assert_eq!(
            eng.push(1, 0, 1, &[1.0], false, RoundTag::new(0, 5)),
            Err(EngineError::FutureRound { got: 5, open: 0 })
        );
        // Malformed wire bytes are typed errors too, not panics.
        assert_eq!(
            eng.push_src(1, 0, 1, GradSrc::LeBytes(&[0u8; 3]), false, t),
            Err(EngineError::Agg(AggError::MisalignedBytes { bytes: 3 }))
        );
        // The engine is still healthy: the round can complete.
        assert_eq!(
            eng.push(1, 0, 1, &[3.0], false, t).unwrap(),
            PushOutcome::Completed
        );
    }

    /// Parameter handoff: a job exported mid-training and rebuilt via
    /// `init_job_resumed` continues bit-identically to the original —
    /// parameters, momentum state, and round position all survive.
    #[test]
    fn export_then_resume_continues_bit_identical() {
        use crate::coordinator::optimizer::NesterovSgd;
        let opt = || Arc::new(NesterovSgd { lr: 0.25, momentum: 0.9 });
        let mut eng = ShardEngine::new();
        let (txs, mut rxs) = single_lane_fabrics(1, 1, 64);
        eng.init_job(1, vec![(0, vec![1.0, 2.0]), (1, vec![-3.0])], opt(), 1, txs);
        // Two rounds so momentum state is nonzero at export.
        for r in 0..2u64 {
            for c in 0..2u32 {
                let g = [0.5 + r as f32, -0.25];
                let g = if c == 0 { &g[..] } else { &g[..1] };
                eng.push(1, c, 0, g, true, RoundTag::new(0, r)).unwrap();
                assert!(matches!(rxs[0].recv().unwrap(), Reply::Chunk { .. }));
            }
        }
        let mut exported = eng.export_job(1);
        exported.sort_by_key(|cs| cs.chunk);
        assert_eq!(exported.len(), 2);
        assert_eq!(exported[0].round, 2);
        assert!(exported[0].state.iter().any(|&s| s != 0.0), "momentum must export");
        assert_eq!(eng.export_job(999), Vec::new(), "unknown job exports empty");

        // Rebuild in a fresh engine; drive round 2 on both side by side.
        let mut resumed = ShardEngine::new();
        let (txs2, mut rxs2) = single_lane_fabrics(1, 1, 64);
        resumed.init_job_resumed(1, exported, opt(), 1, txs2);
        for c in 0..2u32 {
            let g = [9.0f32, -1.5];
            let g = if c == 0 { &g[..] } else { &g[..1] };
            let t = RoundTag::new(0, 2);
            eng.push(1, c, 0, g, true, t).unwrap();
            resumed.push(1, c, 0, g, true, t).unwrap();
            let a = chunk_reply(rxs[0].recv().unwrap());
            let b = chunk_reply(rxs2[0].recv().unwrap());
            assert_eq!(a, b, "chunk {c} diverged after handoff");
        }
    }

    /// The rollback/replay message race: a replayed push can reach a core
    /// *before* that core's RollbackRound message (the pusher learned the
    /// new epoch from a faster core). The engine must apply the rollback
    /// itself rather than dropping the replayed gradient — otherwise the
    /// recovery path would recreate the very wedge it exists to fix.
    #[test]
    fn future_epoch_push_self_heals_the_race() {
        let (mut eng, mut rxs) = engine_with_job(2, vec![(0, vec![1.0])], 0.5);
        // A partial round at epoch 0 (this is what the rollback rewinds).
        eng.push(1, 0, 0, &[99.0], true, RoundTag::new(0, 0)).unwrap();
        // Worker 1 replays at epoch 1 before this core saw RollbackRound.
        let t1 = RoundTag::new(1, 0);
        assert_eq!(
            eng.push(1, 0, 1, &[4.0], true, t1).unwrap(),
            PushOutcome::Absorbed
        );
        // The shard self-healed: partial state rewound, notices sent.
        assert!(matches!(
            rxs[0].recv().unwrap(),
            Reply::RolledBack { epoch: 1, .. }
        ));
        // The in-flight RollbackRound message arrives late: no-op.
        assert_eq!(eng.rollback(1, 1).unwrap(), 0);
        // The replay completes with worker 0's re-push; the 99s are gone.
        assert_eq!(
            eng.push(1, 0, 0, &[2.0], true, t1).unwrap(),
            PushOutcome::Completed
        );
        // p -= 0.5 * mean(2, 4) = 1 - 1.5 = -0.5.
        loop {
            if let Reply::Chunk { epoch, data, .. } = rxs[0].recv().unwrap() {
                assert_eq!(epoch, 1);
                assert_eq!(data.to_vec(), vec![-0.5]);
                break;
            }
        }
    }

    #[test]
    fn rollback_rewinds_partial_keeps_completed_and_replays_bit_identical() {
        // Two chunks: chunk 0 completes the round, chunk 1 stays partial.
        let (mut eng, mut rxs) =
            engine_with_job(2, vec![(0, vec![1.0]), (1, vec![10.0])], 0.5);
        let t0 = RoundTag::new(0, 0);
        eng.push(1, 0, 0, &[2.0], true, t0).unwrap();
        assert_eq!(eng.push(1, 0, 1, &[4.0], true, t0).unwrap(), PushOutcome::Completed);
        let completed: Vec<f32> = chunk_reply(rxs[0].recv().unwrap()).2;
        eng.push(1, 1, 0, &[8.0], true, t0).unwrap(); // partial on chunk 1

        // Worker 1 dies; the leader rolls the job to epoch 1.
        assert_eq!(eng.rollback(1, 1).unwrap(), 1); // only chunk 1 rewound
        for rx in rxs.iter_mut() {
            match rx.recv().unwrap() {
                Reply::RolledBack { epoch, .. } => assert_eq!(epoch, 1),
                other => panic!("expected rollback notice, got {other:?}"),
            }
        }

        // Full replay at epoch 1: the completed chunk answers from its
        // slot, the rewound chunk re-aggregates from scratch.
        let t1 = RoundTag::new(1, 0);
        assert_eq!(eng.push(1, 0, 0, &[2.0], true, t1).unwrap(), PushOutcome::Replayed);
        assert_eq!(chunk_reply(rxs[0].recv().unwrap()).2, completed);
        eng.push(1, 1, 0, &[8.0], true, t1).unwrap();
        assert_eq!(eng.push(1, 1, 1, &[16.0], true, t1).unwrap(), PushOutcome::Completed);
        // 10 - 0.5 * mean(8, 16) = 10 - 6 = 4 — as if never interrupted.
        assert_eq!(chunk_reply(rxs[0].recv().unwrap()).2, vec![4.0]);

        // A push still in flight with the dead epoch is dropped by tag.
        assert_eq!(
            eng.push(1, 1, 1, &[99.0], true, t0).unwrap(),
            PushOutcome::StaleEpoch
        );
    }

    #[test]
    fn rollback_is_idempotent() {
        let (mut eng, mut rxs) = engine_with_job(1, vec![(0, vec![0.0])], 1.0);
        assert_eq!(eng.rollback(1, 1).unwrap(), 0);
        assert_eq!(eng.rollback(1, 1).unwrap(), 0);
        // Exactly one notice per effective rollback (the bulletin is
        // monotone, so the duplicate rollback posts nothing new).
        assert!(matches!(rxs[0].recv().unwrap(), Reply::RolledBack { epoch: 1, .. }));
        assert!(rxs[0].try_recv().is_none());
    }

    /// Reply buffers recycle: after the receiver drops a reply, the next
    /// completion reuses its buffer instead of allocating a fresh one.
    #[test]
    fn reply_buffers_recycle_through_the_pool() {
        let (mut eng, mut rxs) = engine_with_job(1, vec![(0, vec![0.0, 0.0])], 1.0);
        eng.push(1, 0, 0, &[1.0, 1.0], true, RoundTag::new(0, 0)).unwrap();
        let (_, _, first) = chunk_reply(rxs[0].recv().unwrap()); // buffer dropped here
        assert_eq!(eng.pool.free_count(), 1, "dropped reply returned its buffer");
        eng.push(1, 0, 0, &[1.0, 1.0], true, RoundTag::new(0, 1)).unwrap();
        let (_, _, second) = chunk_reply(rxs[0].recv().unwrap());
        assert_eq!(eng.pool.free_count(), 1);
        assert_eq!(first, vec![-1.0, -1.0]);
        assert_eq!(second, vec![-2.0, -2.0]);
    }

    /// Single-copy broadcast: a completion with several pullers sends
    /// refcount bumps of *one* pooled buffer, and the pool gets exactly
    /// one slot back once every receiver has dropped its reference.
    #[test]
    fn completion_broadcasts_one_shared_buffer() {
        let (mut eng, mut rxs) = engine_with_job(3, vec![(0, vec![1.0, 1.0])], 0.5);
        let t = RoundTag::new(0, 0);
        eng.push(1, 0, 0, &[3.0, 3.0], true, t).unwrap();
        eng.push(1, 0, 1, &[3.0, 3.0], true, t).unwrap();
        assert_eq!(
            eng.push(1, 0, 2, &[3.0, 3.0], true, t).unwrap(),
            PushOutcome::Completed
        );
        let datas: Vec<SharedF32> = rxs
            .iter_mut()
            .map(|rx| match rx.recv().unwrap() {
                Reply::Chunk { data, .. } => data,
                other => panic!("expected chunk reply, got {other:?}"),
            })
            .collect();
        let ptr = datas[0].as_ptr();
        for d in &datas {
            assert_eq!(d.as_ptr(), ptr, "all pullers share the one buffer");
            assert_eq!(&**d, &vec![-0.5, -0.5]); // 1 - 0.5 * 3
        }
        assert_eq!(eng.pool.free_count(), 0, "still referenced");
        drop(datas);
        assert_eq!(eng.pool.free_count(), 1, "one buffer recycled, not three");
    }

    fn relay_with_job(
        n_workers: usize,
        chunks: Vec<(u32, Vec<f32>)>,
    ) -> (ShardEngine, Vec<ReplyRx>, ReplyRx) {
        let mut eng = ShardEngine::new();
        let (txs, rxs) = single_lane_fabrics(1, n_workers, 64);
        let (mut utx, urx) = reply_fabric(1, 1, 64);
        eng.init_job_with_role(
            1,
            chunks,
            Arc::new(Sgd { lr: 0.5 }),
            n_workers,
            txs,
            NodeRole::RackRelay,
            Some(utx.pop().expect("single uplink lane")),
        );
        (eng, rxs, urx)
    }

    /// A Root whose two direct pushers each carry weight 2 (two relays
    /// of two workers) divides by 4, matching a flat 4-worker engine fed
    /// the same leaf gradients bit-for-bit.
    #[test]
    fn weighted_root_mean_divides_by_total_weight() {
        let leaf = [[1.0f32, -2.0], [0.5, 4.0], [2.5, 0.25], [-1.0, 8.0]];
        // Flat reference: 4 workers, weights all 1.
        let (mut flat, mut flat_rxs) = engine_with_job(4, vec![(0, vec![1.0, 1.0])], 0.5);
        let t = RoundTag::new(0, 0);
        for (w, g) in leaf.iter().enumerate() {
            flat.push(1, 0, w as u32, g, w == 0, t).unwrap();
        }
        let flat_params = chunk_reply(flat_rxs[0].recv().unwrap()).2;

        // Two-level root: 2 pushers (the relays), each weight 2, pushing
        // their racks' sums in the same grouping two_level_reduce uses.
        let (mut root, mut root_rxs) = engine_with_job(2, vec![(0, vec![1.0, 1.0])], 0.5);
        root.set_worker_weight(1, 0, 2).unwrap();
        root.set_worker_weight(1, 1, 2).unwrap();
        let rack0 = [leaf[0][0] + leaf[1][0], leaf[0][1] + leaf[1][1]];
        let rack1 = [leaf[2][0] + leaf[3][0], leaf[2][1] + leaf[3][1]];
        root.push(1, 0, 0, &rack0, true, t).unwrap();
        assert_eq!(
            root.push(1, 0, 1, &rack1, false, t).unwrap(),
            PushOutcome::Completed
        );
        let two_level = chunk_reply(root_rxs[0].recv().unwrap()).2;
        // The leaf values are dyadic rationals, so both sum groupings
        // are exact and the runs agree bit-for-bit.
        assert_eq!(flat_params, two_level);
    }

    /// RackRelay completion forwards the raw local sum on the uplink
    /// lane and holds every pull until the parent's parameters install.
    #[test]
    fn relay_forwards_sum_then_installs_params() {
        let (mut eng, mut rxs, mut urx) = relay_with_job(2, vec![(0, vec![1.0, 1.0])]);
        let t = RoundTag::new(0, 0);
        eng.push(1, 0, 0, &[2.0, 2.0], true, t).unwrap();
        assert_eq!(
            eng.push(1, 0, 1, &[4.0, 4.0], true, t).unwrap(),
            PushOutcome::Completed
        );
        // The uplink got the *sum* (no divide, no optimizer step)...
        match urx.recv().unwrap() {
            Reply::Sum {
                chunk, round, data, ..
            } => {
                assert_eq!((chunk, round), (0, 0));
                assert_eq!(data.to_vec(), vec![6.0, 6.0]);
            }
            other => panic!("expected a sum, got {other:?}"),
        }
        // ...and the pullers got nothing yet: parameters aren't ready.
        assert!(rxs[0].try_recv().is_none());
        assert!(rxs[1].try_recv().is_none());
        assert_eq!(eng.chunk_params(1, 0), Some(&[1.0f32, 1.0][..]));

        // The parent's parameters come back: deferred broadcast fires.
        assert!(eng.install_params_src(1, 0, GradSrc::F32s(&[0.25, -0.5])).unwrap());
        for rx in rxs.iter_mut() {
            assert_eq!(chunk_reply(rx.recv().unwrap()).2, vec![0.25, -0.5]);
        }
        assert_eq!(eng.chunk_params(1, 0), Some(&[0.25f32, -0.5][..]));
        // A duplicate install (parent-side replay re-broadcast) is a
        // recognized no-op, not an error.
        assert!(!eng.install_params_src(1, 0, GradSrc::F32s(&[0.25, -0.5])).unwrap());
    }

    /// Rack-local recovery composes with the upstream exchange: a
    /// rollback while a chunk's sum is upstream rewinds only the partial
    /// chunks, replays of the awaiting chunk defer their pulls (no
    /// second sum goes up), and the eventual install reaches the
    /// replayed pullers under the new epoch.
    #[test]
    fn relay_rollback_rewinds_only_partial_and_defers_replayed_pulls() {
        let (mut eng, mut rxs, mut urx) =
            relay_with_job(2, vec![(0, vec![1.0]), (1, vec![10.0])]);
        let t0 = RoundTag::new(0, 0);
        eng.push(1, 0, 0, &[2.0], true, t0).unwrap();
        assert_eq!(eng.push(1, 0, 1, &[4.0], true, t0).unwrap(), PushOutcome::Completed);
        assert!(matches!(urx.recv().unwrap(), Reply::Sum { chunk: 0, .. }));
        eng.push(1, 1, 0, &[8.0], true, t0).unwrap(); // chunk 1 partial

        // Worker 1 dies: only the partial chunk rewinds.
        assert_eq!(eng.rollback(1, 1).unwrap(), 1);
        for rx in rxs.iter_mut() {
            assert!(matches!(rx.recv().unwrap(), Reply::RolledBack { epoch: 1, .. }));
        }

        // Replay at epoch 1: the awaiting chunk answers Replayed with
        // its pull deferred (no stale params, no duplicate sum), the
        // rewound chunk re-completes to a bit-identical sum.
        let t1 = RoundTag::new(1, 0);
        assert_eq!(eng.push(1, 0, 0, &[2.0], true, t1).unwrap(), PushOutcome::Replayed);
        assert_eq!(eng.push(1, 0, 1, &[4.0], true, t1).unwrap(), PushOutcome::Replayed);
        assert!(rxs[0].try_recv().is_none());
        eng.push(1, 1, 0, &[8.0], true, t1).unwrap();
        assert_eq!(eng.push(1, 1, 1, &[16.0], true, t1).unwrap(), PushOutcome::Completed);
        match urx.recv().unwrap() {
            Reply::Sum { chunk, data, .. } => {
                assert_eq!(chunk, 1);
                assert_eq!(data.to_vec(), vec![24.0]);
            }
            other => panic!("expected a sum, got {other:?}"),
        }
        assert!(urx.try_recv().is_none(), "exactly one sum per chunk per round");

        // Installs release both chunks' pullers under epoch 1.
        eng.install_params_src(1, 0, GradSrc::F32s(&[0.5])).unwrap();
        eng.install_params_src(1, 1, GradSrc::F32s(&[7.0])).unwrap();
        for rx in rxs.iter_mut() {
            let (chunk, epoch, data) = chunk_reply(rx.recv().unwrap());
            assert_eq!((chunk, epoch), (0, 1));
            assert_eq!(data, vec![0.5]);
            let (chunk, epoch, data) = chunk_reply(rx.recv().unwrap());
            assert_eq!((chunk, epoch), (1, 1));
            assert_eq!(data, vec![7.0]);
        }
    }

    #[test]
    fn worker_round_tracks_a_round() {
        let mut wr = WorkerRound::new(2);
        assert!(!wr.mid_round());
        wr.begin_push(0).unwrap();
        assert_eq!(
            wr.begin_push(0),
            Err(EngineError::DuplicateChunk { chunk: 0 })
        );
        wr.begin_push(1).unwrap();
        assert!(wr.push_phase_done() && wr.mid_round());
        assert!(wr.note_reply(0));
        assert!(wr.note_reply(0));
        assert_eq!(wr.outstanding(), 0);
        wr.complete_round();
        assert_eq!(wr.round(), 1);
        assert!(!wr.mid_round());
    }

    #[test]
    fn worker_round_rollback_resets_but_keeps_round() {
        let mut wr = WorkerRound::resume(2, 0, 7);
        wr.begin_push(0).unwrap();
        assert!(wr.apply_rollback(1));
        assert!(!wr.apply_rollback(1), "duplicate notice ignored");
        assert_eq!((wr.epoch(), wr.round()), (1, 7));
        assert!(!wr.mid_round());
        // Stale replies from the dead epoch are not counted.
        wr.begin_push(0).unwrap();
        assert!(!wr.note_reply(0));
        assert!(wr.note_reply(1));
    }
}
