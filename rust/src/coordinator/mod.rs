//! The PHub coordinator: a real, executable rack-scale parameter server.
//!
//! Unlike [`crate::sim`] (which models the paper's testbed to regenerate
//! its figures), this module *is* PHub: chunked keys, a fixed chunk→core
//! mapping computed at init, per-core aggregation threads with no
//! cross-core synchronization (tall aggregation), fused optimization, a
//! multi-tenant namespace registry, and the paper's service API
//! (`CreateService` / `ConnectService` / `InitService`,
//! `Push` / `Pull` / `PushPull`).
//!
//! # Layering
//!
//! The round state machine — who pushed what, when a chunk's round
//! completes, what a mid-round rollback means — has exactly one home:
//! [`engine`]. Every chunk slot carries an explicit `(epoch, round)` tag;
//! `absorb`/`complete`/`rollback` transitions return `Result`, so a
//! protocol violation can never kill a shared core thread. Two thin
//! transport shells frame and route bytes into that engine:
//!
//! * [`server`] — in-process: channels carry chunk-sized `f32` buffers to
//!   per-core engine instances; workers are threads holding
//!   `WorkerHandle`s.
//! * [`transport`] — distributed: a TCP leader speaks the chunk-streamed
//!   wire protocol ([`wire`]) and drives the *same* engine, including
//!   mid-round recovery — a worker dying mid-round triggers a round
//!   rollback and slot recycle instead of wedging its job.
//!
//! Workers are threads (or PJRT-executing processes in `examples/`)
//! exchanging real `f32` gradients; the aggregation math matches the L1
//! Pallas kernel bit-for-bit up to float associativity, and pytest checks
//! the kernel against the same Nesterov reference.

pub mod aggregation;
pub mod chunk;
pub mod compress;
pub mod engine;
pub mod hierarchy;
pub mod mapping;
pub mod optimizer;
pub mod server;
pub mod service;
pub mod tenancy;
pub mod transport;
pub mod wire;

pub use chunk::{ChunkId, KeyTable};
pub use engine::{EngineError, PushOutcome, Reply, RoundTag, ShardEngine, WorkerRound};
pub use optimizer::{NesterovSgd, Optimizer, Sgd};
pub use server::{PHubServer, ServerConfig};
pub use service::{ConnectionManager, ServiceHandle};
