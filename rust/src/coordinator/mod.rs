//! The PHub coordinator: a real, executable rack-scale parameter server.
//!
//! Unlike [`crate::sim`] (which models the paper's testbed to regenerate
//! its figures), this module *is* PHub: chunked keys, a fixed chunk→core
//! mapping computed at init, per-core aggregation threads with no
//! cross-core synchronization (tall aggregation), fused optimization, a
//! multi-tenant namespace registry, and the paper's service API
//! (`CreateService` / `ConnectService` / `InitService`,
//! `Push` / `Pull` / `PushPull`).
//!
//! # Layering
//!
//! The round state machine — who pushed what, when a chunk's round
//! completes, what a mid-round rollback means — has exactly one home:
//! [`engine`]. Every chunk slot carries an explicit `(epoch, round)` tag;
//! `absorb`/`complete`/`rollback` transitions return `Result`, so a
//! protocol violation can never kill a shared core thread. Two thin
//! transport shells frame and route bytes into that engine:
//!
//! * [`server`] — in-process: bounded lock-free SPSC rings ([`ring`])
//!   carry chunk-sized `f32` buffers to per-core engine instances, one
//!   request ring per (worker, core); workers are threads holding
//!   `WorkerHandle`s.
//! * [`transport`] — distributed: a TCP leader speaks the chunk-streamed
//!   wire protocol ([`wire`]) and drives the *same* engine, including
//!   mid-round recovery — a worker dying mid-round triggers a round
//!   rollback and slot recycle instead of wedging its job.
//!
//! The engine itself is **role-parameterized** (paper §3.4, Fig. 19):
//! the chunk-complete transition splits into "local sum ready" vs
//! "parameters ready", so the same node runs as a `Root` (optimize
//! exactly once, fan parameters down) or as a `RackRelay`
//! (tall-aggregate the rack, stream raw per-chunk sums upstream over
//! the same v2 chunk frames with a worker-count weight, and fan the
//! root's returned parameters back down). See
//! [`engine::NodeRole`] and `transport::RelayConfig`; recovery composes
//! across levels because a rack's epoch bumps stay rack-internal — the
//! relay replays byte-identical sums upstream from its round cache.
//!
//! Workers are threads (or PJRT-executing processes in `examples/`)
//! exchanging real `f32` gradients; the aggregation math matches the L1
//! Pallas kernel bit-for-bit up to float associativity, and pytest checks
//! the kernel against the same Nesterov reference.
//!
//! # Memory discipline
//!
//! The data plane is memory-bandwidth-bound (paper §4.3), so the steady
//! state of a round is **exact-zero** — no heap allocation and no mutex
//! acquisition per chunk, with no exclusions — and touches each gradient
//! byte as few times as possible. Buffer and queue ownership:
//!
//! * **Frame buffers** (leader receive): owned by each connection's
//!   recycling [`pool::BytePool`]. `wire::read_frame_into` fills one,
//!   the buffer travels to the chunk's pinned core, the core folds the
//!   wire bytes straight into the accumulator
//!   (`aggregation::absorb_bytes` / `absorb_quant` — no intermediate
//!   `Vec<f32>`, no dequantize scratch), and the drop recycles it.
//! * **Reply buffers** (engine → worker): owned by each core engine's
//!   [`pool::SharedF32Pool`]. Completion copies the chunk slot's
//!   parameters **once** into a refcount-shared pooled buffer and every
//!   puller gets a refcount bump (single-copy broadcast, no
//!   per-completion `Arc` allocation — the refcount block recycles with
//!   the buffer); the transport serializes straight out of the shared
//!   buffer into its reused staging vector
//!   (`wire::write_chunk_frame_f32s`) and the last drop recycles it.
//! * **Queues** (the fabric): bounded lock-free SPSC rings ([`ring`]),
//!   one request ring per (worker, core) and one reply ring back, each
//!   allocated once at job init. Cores poll only their own rings and
//!   park when idle; a full ring blocks exactly its one producer
//!   (backpressure). `std::sync::mpsc` — a lock under contention plus a
//!   queue-block allocation every ~31 sends — is gone from the tree.
//! * **Accumulators, optimizer state, round caches**: owned by their
//!   chunk slots / connections and reused for the process lifetime;
//!   the fused `take_mean_into_step` + `step_scaled` pass finishes a
//!   round in one sweep over the accumulator.
//! * **Uplink lane** (RackRelay only): the same ring-and-pool shape
//!   pointed up. Each core's completed chunk sum is copied once into a
//!   `SharedF32Pool` buffer and sent over a per-core SPSC sum ring to
//!   the uplink thread, which copies it into its per-chunk replay cache
//!   (reused `Vec<f32>`, also the rollback-replay source) and recycles
//!   the pooled buffer; the parent's returned `ModelChunk` payload is
//!   received into the uplink's own `BytePool` buffer and travels down
//!   a per-core SPSC install ring to the chunk's core, which writes the
//!   slot parameters and fires the deferred pull broadcast. No mutex,
//!   no steady-state allocation on either direction.
//!
//! Per chunk per round the leader path is one copy in (socket →
//! pooled buffer), one absorb fold, one fused optimize pass, one shared
//! copy out regardless of puller count — and exactly zero steady-state
//! heap allocations and mutex acquisitions, asserted with no exclusions
//! by `rust/tests/alloc_discipline.rs` and measured by
//! `benches/dataplane.rs` and `benches/ring.rs`.
//!
//! # Failure model & recovery contract
//!
//! The distributed plane assumes **crash-stop with rejoin**: a worker,
//! relay, or parent may die at any byte boundary, and a successor may
//! later claim the dead party's slot. The contract (stated in full in
//! [`transport`]'s module docs):
//!
//! * **No silent hangs.** Every blocking edge is deadline-supervised
//!   ([`crate::config::DeadlineConfig`]): socket I/O timeouts on the
//!   client, a leader-side round deadline that converts a stalled
//!   worker into the normal death-recovery path (idle parked tenants
//!   exempt), and a capped-backoff uplink redial loop that gives up
//!   with a typed [`transport::UplinkError`] instead of spinning
//!   forever against a dead parent.
//! * **Bit-exact resumption.** Mid-round deaths roll the round back
//!   (epoch bump + byte-identical replay, see [`engine`]); quantized
//!   workers additionally checkpoint their error-feedback residuals
//!   through the leader at round boundaries (`ResidualSave` /
//!   `ResidualChunk` in [`wire`]) so a successor resumes bit-exact
//!   from *any* death round, not just round 0.
//! * **Deterministic fault replay.** [`faults`] injects seeded
//!   connection kills, mid-frame cuts, torn writes, delays, and
//!   duplicate frames *under* the TCP stream, so every recovery path
//!   above is exercised by reproducible chaos schedules
//!   (`tests/chaos.rs`) without touching production code paths.
//!
//! # Tenant guardrails
//!
//! The leader is a shared appliance (paper §3.3: one PBox serves a
//! rack), so multi-tenancy is enforced, not assumed. The guardrail
//! layer ([`admission`], policy in [`crate::config::QuotaConfig`]):
//!
//! * **Admission control.** Every job-creating `Hello` is checked
//!   against per-job caps (worker seats, model elements, cores) and
//!   leader-wide totals (job count, summed model elements, summed
//!   seats). An over-quota or shed request receives a typed, retriable
//!   `wire::Op::Refused` frame (reason code + retry-after hint) instead
//!   of a hang or an opaque disconnect; re-`Hello`s of hosted jobs are
//!   never capacity-checked, so a full leader can always heal the jobs
//!   it already owns.
//! * **Weighted-fair core scheduling.** Each core's poll loop runs a
//!   deficit round-robin over *jobs* (weights from
//!   `QuotaConfig::weights`), so a tenant flooding its rings delays
//!   only its own rounds. Schedule state is fixed-size, core-owned,
//!   plain integers — the exact-zero alloc/mutex discipline above is
//!   preserved.
//! * **Load shedding + idle eviction.** Round-deadline trips inside a
//!   sliding window trip an overload watermark that sheds *new*
//!   admissions first; jobs idle past a configurable horizon (zero live
//!   connections) are evicted with a **parameter handoff** — final
//!   parameters, optimizer state, per-chunk round positions, and any
//!   quantized residual checkpoints are staged so a returning tenant
//!   readmits and resumes bit-exact.
//!
//! The full admission rules, refusal wire format, fairness semantics,
//! and eviction/handoff lifecycle are specified in [`transport`]'s
//! module docs; refusals and guardrail actions are observable via
//! [`crate::metrics::DataPlaneMetrics`] and the `/jobs` quota view.
//!
//! # Kernel dispatch and placement
//!
//! The absorb folds and fused optimizer passes execute as explicit SIMD
//! in [`kernels`] (AVX2 / SSE2 / scalar, one tier selected per process —
//! `PHUB_KERNELS` overrides detection), and chunk→core placement
//! defaults to PHub's key-affinity scheme (contiguous per-core model
//! extents — [`mapping::PlacementMode`], `PHUB_PLACEMENT` overrides).
//! The contract, in addition to the ownership rules above:
//!
//! | rule | where enforced |
//! |---|---|
//! | Raw `unsafe` vector fns are private to `kernels`; everything else calls its safe dispatchers (directly or via the `aggregation`/`optimizer` wrappers) | `kernels.rs` visibility + the dispatchers' availability proof |
//! | Every tier is bit-identical to scalar on arbitrary bit patterns (NaN/inf/denormals), dense, quantized, and both optimizers | `tests/prop_coordinator.rs` tier sweeps + `kernels.rs` unit tests, both arms in CI (forced-scalar lane) |
//! | No alignment assumptions (unaligned vector memory ops only); wire bytes decode in place on little-endian x86_64 | `kernels.rs` contract table |
//! | Tier resolution and placement both happen at init/warm-up; steady-state rounds stay exact-zero alloc/mutex | `alloc_discipline.rs`, `active_tier`'s cached atomic |
//! | The selected tier and placement mode are observable | `DataPlaneMetrics::{kernel_tier, placement_mode}`, set by `PHubServer::start` |
//! | Placement changes locality only, never results: either mode gives bit-identical training | `server.rs` placement tests |
//!
//! # Observability contract
//!
//! The coordinator measures itself the way the paper measured MXNet —
//! per stage, per tenant — without giving up the exact-zero discipline
//! above. Three surfaces, by cost:
//!
//! * **Flight recorder** ([`crate::trace`], `trace` cargo feature,
//!   default on): per-thread fixed-capacity ring buffers of timestamped
//!   span events at the existing stage boundaries of a round — frame
//!   read, ring enqueue/dequeue, absorb, fused mean+optimize, reply
//!   encode, socket write — plus recovery instants (rollback, deadline
//!   trip, residual commit). Recording is seqlock-write + relaxed
//!   atomics into preallocated slots: no allocation, no mutex, no
//!   blocking, so `alloc_discipline.rs` passes with tracing compiled in
//!   and enabled (the one-time ring allocation rides the documented
//!   warm-up window). Toggle at runtime with `PHubServer::set_tracing`;
//!   compile out entirely with `--no-default-features`.
//! * **Counters and per-job attribution** ([`crate::metrics`]): global
//!   [`crate::metrics::DataPlaneMetrics`] (drops split by reject
//!   reason, rollbacks, timeouts, replays, residual traffic) plus a
//!   per-job registry (rounds, push/pull bytes, round-latency
//!   histogram, drop/replay/rollback attribution). Hot paths pay one
//!   relaxed atomic add per event through a pre-resolved
//!   `Arc<JobMetrics>`; the registry lock is control-plane/error-path
//!   only.
//! * **Export plane** ([`status`]): a dependency-free HTTP endpoint on
//!   a side thread — `/metrics` (Prometheus text), `/jobs` (per-tenant
//!   JSON), `/trace` (chrome://tracing JSON, tenant-scoped by service
//!   nonce when bound with auth). Scrapes read snapshots and
//!   seqlock-guarded slots; they never block a core thread or touch a
//!   data-plane lock.

pub mod admission;
pub mod aggregation;
pub mod chunk;
pub mod compress;
pub mod engine;
pub mod faults;
pub mod hierarchy;
pub mod kernels;
pub mod mapping;
pub mod optimizer;
pub mod pool;
pub mod ring;
pub mod server;
pub mod service;
pub mod status;
pub mod tenancy;
pub mod transport;
pub mod wire;

pub use admission::{AdmissionController, LeaderUsage, RefuseReason, Refusal};
pub use aggregation::GradSrc;
pub use chunk::{ChunkId, KeyTable};
pub use engine::{
    ChunkState, EngineError, NodeRole, PushOutcome, Reply, ReplyRx, ReplyTx, RoundTag,
    ShardEngine, WorkerRound,
};
pub use kernels::KernelTier;
pub use mapping::PlacementMode;
pub use optimizer::{NesterovSgd, Optimizer, Sgd};
pub use pool::{
    BytePool, F32Pool, Pool, Pooled, PooledBytes, PooledF32, SharedF32, SharedF32Pool, SharedPool,
    SharedPooled,
};
pub use server::{PHubServer, RelayUplink, ServerConfig};
pub use service::{ConnectionManager, ServiceHandle};
pub use status::{JobAuth, StatusServer};
