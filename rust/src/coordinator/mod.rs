//! The PHub coordinator: a real, executable rack-scale parameter server.
//!
//! Unlike [`crate::sim`] (which models the paper's testbed to regenerate
//! its figures), this module *is* PHub: chunked keys, a fixed chunk→core
//! mapping computed at init, per-core aggregation threads with no
//! cross-core synchronization (tall aggregation), fused optimization, a
//! multi-tenant namespace registry, and the paper's service API
//! (`CreateService` / `ConnectService` / `InitService`,
//! `Push` / `Pull` / `PushPull`).
//!
//! Workers are threads (or PJRT-executing processes in `examples/`)
//! exchanging real `f32` gradients; the aggregation math matches the L1
//! Pallas kernel bit-for-bit up to float associativity, and pytest checks
//! the kernel against the same Nesterov reference.

pub mod aggregation;
pub mod chunk;
pub mod compress;
pub mod hierarchy;
pub mod mapping;
pub mod optimizer;
pub mod server;
pub mod service;
pub mod tenancy;
pub mod transport;
pub mod wire;

pub use chunk::{ChunkId, KeyTable};
pub use optimizer::{NesterovSgd, Optimizer, Sgd};
pub use server::{PHubServer, ServerConfig};
pub use service::{ConnectionManager, ServiceHandle};
