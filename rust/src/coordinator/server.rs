//! The live PHub server: a thin shell over the round-epoch engine, wired
//! with the lock-free queue-per-core fabric.
//!
//! This is the paper's architecture realized in-process: the "wire" is a
//! bounded SPSC ring ([`super::ring`]) carrying chunk-sized `f32`
//! buffers, each chunk is pinned to one core-thread for its whole
//! lifetime (reception, aggregation, optimization, transmission —
//! section 3.2.4), cores share nothing, and chunk→core assignment is
//! computed once at init with the LPT balancer.
//!
//! # The port mesh
//!
//! Every core thread polls only its own rings — a *port list* of SPSC
//! consumers, all sharing that core's one parker:
//!
//! * one **control ring** per core (port 0), carrying `InitJob` /
//!   `RollbackRound` / `Evict` / `Connect` from the server frontend
//!   (its producer sits behind a mutex, but that mutex is control-plane
//!   only — nothing on the data path touches it);
//! * one **request ring** per (worker-slot, core) pair, carrying that
//!   worker's `Push`/`PushBytes`/`Pull` traffic with no lock and no
//!   allocation; a full ring blocks the one worker pushing into it
//!   (backpressure) and nobody else;
//! * one **reply ring** per (worker-slot, core) pair going the other
//!   way, multiplexed worker-side by [`super::engine::ReplyRx`].
//!
//! New request ports reach a core as `Connect` messages *behind* the
//! job's `InitJob` on the same FIFO control ring, so a push can never be
//! popped by a core that has not yet installed its job. Ports whose
//! producer is gone (worker handle dropped) are retired once drained;
//! the core exits when its last port disconnects. Rollback notices ride
//! the reply rings' monotone epoch bulletin rather than ring slots, so
//! recovery is delivered even to a wedged or parked consumer
//! (drain-on-epoch-bump; see `engine.rs` and `ring.rs`).
//!
//! All round logic — arrival bitmasks, `(epoch, round)` tags, completion,
//! mid-round rollback — lives in [`super::engine::ShardEngine`]; each core
//! thread here just drains its ports into its engine instance. A
//! protocol violation surfaces as a typed [`super::engine::EngineError`]
//! and costs the offending message, never the core thread — counted in
//! [`crate::metrics::DataPlaneMetrics`] (no stderr scraping). The TCP
//! leader in [`super::transport`] is the other shell over the same
//! engine.
//!
//! Two push forms reach the cores: `Push` carries a shared `Arc<[f32]>`
//! gradient (the in-process zero-copy path), and `PushBytes` carries the
//! TCP leader's pooled frame buffer so the core absorbs the wire bytes
//! directly and the buffer recycles — the allocation-free data plane
//! (see `aggregation.rs` for the memory-discipline contract).
//!
//! `examples/train_e2e.rs` drives this server with real gradients produced
//! by the AOT-compiled JAX model running under PJRT.

use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;

use crate::config::QuotaConfig;
use crate::metrics::{DataPlaneMetrics, JobMetrics};

use super::aggregation::GradSrc;
use super::chunk::KeyTable;
use super::compress::QuantView;
use super::engine::{
    ChunkState, EngineError, NodeRole, PushOutcome, ReplyRx, ReplyTx, RoundTag, ShardEngine,
};
use super::mapping;
use super::optimizer::Optimizer;
use super::pool::PooledBytes;
use super::ring;

pub use super::engine::{JobId, Reply};

/// Server construction parameters.
#[derive(Debug, Clone)]
pub struct ServerConfig {
    /// Aggregation core-threads (the PBox prototype uses 28).
    pub n_cores: usize,
    /// Chunk→core placement (see [`mapping::PlacementMode`]). The
    /// [`ServerConfig::cores`] constructor reads the `PHUB_PLACEMENT`
    /// override and defaults to [`mapping::PlacementMode::Affine`];
    /// either mode trains bit-identically — only locality differs.
    pub placement: mapping::PlacementMode,
    /// Tenant guardrails: admission caps, fair-scheduling weights,
    /// shedding and eviction policy (see [`QuotaConfig`]). The server
    /// enforces the scheduling half (weighted-fair core sweeps, core
    /// caps); the TCP leader enforces admission/eviction on top.
    pub quota: QuotaConfig,
}

impl ServerConfig {
    /// Config with `n` cores and the environment-selected placement
    /// mode and quota — the standard way tests/benches/examples build
    /// one.
    pub fn cores(n: usize) -> ServerConfig {
        ServerConfig {
            n_cores: n,
            placement: mapping::PlacementMode::from_env(),
            quota: QuotaConfig::from_env(),
        }
    }

    /// Replace the guardrail policy (builder-style, for tests and
    /// benches that need explicit quotas).
    pub fn with_quota(mut self, quota: QuotaConfig) -> ServerConfig {
        self.quota = quota;
        self
    }
}

impl Default for ServerConfig {
    fn default() -> Self {
        ServerConfig::cores(4)
    }
}

/// Slack added on top of a ring's worst-case in-flight count so replay
/// traffic racing a drain can never wedge capacity (see the sizing notes
/// in [`PHubServer::init_job`]).
const RING_SLACK: usize = 8;

/// Control-ring capacity per core. Control messages are rare and the
/// frontend may block briefly if a burst fills it (the core always
/// drains); data traffic never rides this ring.
const CTRL_RING_CAP: usize = 256;

/// Messages a core pops from one port before moving to the next, so one
/// hot producer cannot starve its neighbours.
const PORT_BATCH: usize = 64;

enum CoreMsg {
    /// Register a job's chunks owned by this core: (chunk id, initial
    /// params, optimizer, n_workers, reply-ring producers per worker),
    /// plus the node role and — for a RackRelay — this core's lane of
    /// the uplink sum fabric.
    InitJob {
        job: JobId,
        chunks: Vec<(u32, Vec<f32>)>,
        opt: Arc<dyn Optimizer>,
        n_workers: usize,
        replies: Vec<ReplyTx>,
        role: NodeRole,
        uplink: Option<ReplyTx>,
    },
    /// Attach a new request port to this core's poll set. Always sent on
    /// the control ring *after* the owning job's `InitJob`, so FIFO order
    /// guarantees a push popped from the port finds its job installed.
    /// `job`/`weight` bind the port to its tenant's deficit-round-robin
    /// schedule entry (see [`core_loop`]).
    Connect {
        port: ring::Consumer<CoreMsg>,
        job: JobId,
        weight: u32,
    },
    /// Worker gradient push for one chunk (optionally pulls the update).
    /// `data` is the worker's whole flat gradient, shared zero-copy (the
    /// in-process analogue of RDMA zero-copy, section 3.2.1); the core
    /// reads only its chunk's range. `tag` is the pusher's round position.
    Push {
        job: JobId,
        chunk: u32,
        worker: u32,
        data: Arc<[f32]>,
        range: (usize, usize),
        pull: bool,
        tag: RoundTag,
    },
    /// Worker gradient push for one chunk as raw wire bytes in a pooled,
    /// recycling frame buffer — the TCP leader's allocation-free path.
    /// The gradient bytes are `data[grad_off..]` (dense LE f32s, or a
    /// `QuantGrad` wire encoding when `quant`); the engine folds them
    /// straight into the accumulator and dropping `data` here recycles
    /// the buffer back to the connection's pool.
    PushBytes {
        job: JobId,
        chunk: u32,
        worker: u32,
        data: PooledBytes,
        grad_off: usize,
        quant: bool,
        pull: bool,
        tag: RoundTag,
    },
    /// Read-only pull of current chunk params.
    Pull { job: JobId, chunk: u32, worker: u32 },
    /// Register how many leaf workers direct pusher `worker` represents
    /// (a relay registering its rack size at admission; see
    /// `ShardEngine::set_worker_weight`). Control-plane only. `done` is
    /// bumped once applied so the frontend can wait for every core — a
    /// weight must be in force before any push it covers can complete.
    SetWeight {
        job: JobId,
        worker: u32,
        weight: u32,
        done: Arc<AtomicUsize>,
    },
    /// RackRelay downlink: the parent's returned parameters for one
    /// chunk, as dense LE f32 bytes in a pooled frame buffer
    /// (`data[off..]`). The core writes them into the slot and fires the
    /// deferred pull broadcast; dropping `data` recycles the buffer to
    /// the uplink's pool.
    InstallParams {
        job: JobId,
        chunk: u32,
        data: PooledBytes,
        off: usize,
    },
    /// Rewind the job's open round to recover from a mid-round worker
    /// death (see `ShardEngine::rollback`).
    RollbackRound { job: JobId, epoch: u32 },
    /// Drop a job's state.
    Evict { job: JobId },
    /// Snapshot this core's share of a job for parameter handoff
    /// (idle eviction): the core appends its owned chunks' final
    /// params/optimizer-state/round to `out` and bumps `done` so the
    /// frontend can wait for every core. Control-plane only — the
    /// mutex and clones are off the steady-state path.
    ExportJob {
        job: JobId,
        out: Arc<Mutex<Vec<ChunkState>>>,
        done: Arc<AtomicUsize>,
    },
    /// Reinstall a previously exported job shard verbatim (tenant
    /// readmission after idle eviction): like `InitJob` but each chunk
    /// resumes at its exported params, optimizer state, and round, so
    /// a returning tenant continues bit-exactly. Root role only.
    InitJobResumed {
        job: JobId,
        chunks: Vec<ChunkState>,
        opt: Arc<dyn Optimizer>,
        n_workers: usize,
        replies: Vec<ReplyTx>,
    },
}

/// Record recovery-path push outcomes: replayed and stale-epoch pushes
/// are absorbed idempotently by design (the sender replays its whole
/// round after a rollback), but an operator watching a chaotic fleet
/// wants to see how much of the traffic is replay.
fn note_push_outcome(out: PushOutcome, job: JobId, metrics: &DataPlaneMetrics) {
    if matches!(out, PushOutcome::Replayed | PushOutcome::StaleEpoch) {
        metrics.replayed_frames.inc();
        // Recovery traffic only, never the steady state — the registry's
        // control-plane lock is acceptable here.
        if let Some(jm) = metrics.per_job.get(job) {
            jm.replays.inc();
        }
    }
}

/// Apply one message to this core's engine. Returns the new port plus
/// its owning job and fair-schedule weight when the message was
/// `Connect`.
fn apply_core_msg(
    engine: &mut ShardEngine,
    msg: CoreMsg,
    metrics: &DataPlaneMetrics,
) -> Option<(ring::Consumer<CoreMsg>, JobId, u32)> {
    // Job id for drop attribution below (0 is never a live job —
    // allocation starts at 1).
    let msg_job = match &msg {
        CoreMsg::InitJob { job, .. }
        | CoreMsg::Push { job, .. }
        | CoreMsg::PushBytes { job, .. }
        | CoreMsg::Pull { job, .. }
        | CoreMsg::SetWeight { job, .. }
        | CoreMsg::InstallParams { job, .. }
        | CoreMsg::RollbackRound { job, .. }
        | CoreMsg::Evict { job }
        | CoreMsg::ExportJob { job, .. }
        | CoreMsg::InitJobResumed { job, .. } => *job,
        CoreMsg::Connect { .. } => 0,
    };
    let res = match msg {
        CoreMsg::InitJob {
            job,
            chunks,
            opt,
            n_workers,
            replies,
            role,
            uplink,
        } => {
            engine.init_job_with_role(job, chunks, opt, n_workers, replies, role, uplink);
            Ok(())
        }
        CoreMsg::Connect { port, job, weight } => return Some((port, job, weight)),
        CoreMsg::Push {
            job,
            chunk,
            worker,
            data,
            range,
            pull,
            tag,
        } => {
            crate::trace::instant(crate::trace::Stage::RingDequeue, job, chunk, worker);
            engine
                .push(job, chunk, worker, &data[range.0..range.1], pull, tag)
                .map(|out| note_push_outcome(out, job, metrics))
        }
        CoreMsg::PushBytes {
            job,
            chunk,
            worker,
            data,
            grad_off,
            quant,
            pull,
            tag,
        } => {
            crate::trace::instant(crate::trace::Stage::RingDequeue, job, chunk, worker);
            let bytes = &data[grad_off..];
            let src = if quant {
                match QuantView::parse(bytes) {
                    Ok(q) => GradSrc::Quant2Bit {
                        threshold: q.threshold,
                        len: q.len,
                        packed: q.packed,
                    },
                    Err(_) => {
                        // The transport validates before sending, so this
                        // is a bug or a torn message: drop it like any
                        // other protocol violation, observably.
                        metrics.dropped_quant_payloads.inc();
                        return None;
                    }
                }
            } else {
                GradSrc::LeBytes(bytes)
            };
            engine
                .push_src(job, chunk, worker, src, pull, tag)
                .map(|out| note_push_outcome(out, job, metrics))
            // `data` drops at the end of this arm: the frame buffer
            // recycles to its pool.
        }
        CoreMsg::Pull { job, chunk, worker } => engine.pull(job, chunk, worker),
        CoreMsg::SetWeight {
            job,
            worker,
            weight,
            done,
        } => {
            let res = engine.set_worker_weight(job, worker, weight);
            done.fetch_add(1, Ordering::Release);
            res
        }
        CoreMsg::InstallParams {
            job,
            chunk,
            data,
            off,
        } => engine
            .install_params_src(job, chunk, GradSrc::LeBytes(&data[off..]))
            .map(|_| ()),
        // (`data` drops at the end of the arm: the buffer recycles.)
        CoreMsg::RollbackRound { job, epoch } => {
            metrics.rollbacks.inc();
            // Control plane: the registry lock is fine here.
            if let Some(jm) = metrics.per_job.get(job) {
                jm.rollbacks.inc();
            }
            engine.rollback(job, epoch).map(|_| ())
        }
        CoreMsg::Evict { job } => {
            engine.evict(job);
            Ok(())
        }
        CoreMsg::ExportJob { job, out, done } => {
            let part = engine.export_job(job);
            if !part.is_empty() {
                out.lock().unwrap().extend(part);
            }
            done.fetch_add(1, Ordering::Release);
            Ok(())
        }
        CoreMsg::InitJobResumed {
            job,
            chunks,
            opt,
            n_workers,
            replies,
        } => {
            engine.init_job_resumed(job, chunks, opt, n_workers, replies);
            Ok(())
        }
    };
    // A protocol violation must never kill a shared core thread: the
    // transports reject violations at the connection edge, so anything
    // that still reaches here is dropped (the violator's round simply
    // never completes) and counted where an operator can see it —
    // both in the aggregate and split by reject reason, plus against
    // the offending job's own metric set (error path: the registry's
    // control-plane lock is acceptable).
    if let Err(e) = &res {
        metrics.dropped_messages.inc();
        match e {
            EngineError::UnknownJob(_) => metrics.drop_unknown_job.inc(),
            EngineError::UnknownChunk { .. } => metrics.drop_unknown_chunk.inc(),
            EngineError::DuplicateChunk { .. } => metrics.drop_duplicate.inc(),
            EngineError::FutureRound { .. } => metrics.drop_future_round.inc(),
            EngineError::Agg(_) => metrics.drop_agg.inc(),
        }
        if let Some(jm) = metrics.per_job.get(msg_job) {
            jm.drops.inc();
        }
    }
    None
}

/// Per-job deficit-round-robin state on one core. Fixed-size plain
/// integers only: the scheduler adds no allocation, no locking, and no
/// atomics to the steady-state sweep (entry 0 is the control
/// pseudo-job, never throttled; retired entries are recycled on the
/// control plane so the table stays bounded by concurrently hosted
/// jobs, not jobs ever seen).
struct JobSched {
    job: JobId,
    /// Budget refilled each sweep: `weight * sched_quantum` messages.
    quantum: usize,
    /// Banked unused budget, capped at `2 * quantum` so an idle tenant
    /// cannot hoard an unbounded burst allowance.
    deficit: usize,
    /// Live ports bound to this entry; a zeroed entry is reusable.
    ports: usize,
    /// Pre-resolved attribution counters (`None` for the control
    /// pseudo-entry or when the job was never registered).
    jm: Option<Arc<JobMetrics>>,
}

/// One pollable port and the index of its job's [`JobSched`] entry.
struct PortSlot {
    port: ring::Consumer<CoreMsg>,
    sched: usize,
}

/// Bind a `Connect`ed port to its job's schedule entry, creating or
/// recycling one as needed (control plane — allocation is fine here).
fn adopt_sched(
    scheds: &mut Vec<JobSched>,
    job: JobId,
    weight: u32,
    quantum: usize,
    metrics: &DataPlaneMetrics,
) -> usize {
    if let Some(ix) = scheds.iter().position(|s| s.ports > 0 && s.job == job) {
        scheds[ix].ports += 1;
        return ix;
    }
    let q = (weight.max(1) as usize) * quantum.max(1);
    let fresh = JobSched {
        job,
        quantum: q,
        // Start with a full refill so the first sweep after Connect
        // serves the port instead of deferring it.
        deficit: q,
        ports: 1,
        jm: metrics.per_job.get(job),
    };
    // Entry 0 (control) is never recycled.
    if let Some(ix) = scheds.iter().skip(1).position(|s| s.ports == 0) {
        scheds[ix + 1] = fresh;
        ix + 1
    } else {
        scheds.push(fresh);
        scheds.len() - 1
    }
}

/// One core thread: poll the port list (control ring first — it carries
/// the `InitJob`s that `Connect`ed ports' traffic depends on), retire
/// disconnected ports, and park on the shared waiter when every port is
/// idle. The whole loop is lock-free and allocation-free at steady state;
/// the only allocation is port/schedule-table growth on `Connect`
/// (control plane).
///
/// With `fair` set (the default, [`QuotaConfig::fair_sched`]) the
/// per-port batch budget becomes a deficit-weighted round-robin over
/// jobs: each sweep refills every job's deficit by `weight * quantum`
/// messages (banked up to one extra sweep) and a job's ports stop
/// draining when its deficit is spent, so a flooding tenant defers only
/// its own rounds — its backlog parks in its own rings while neighbours
/// keep their full share of the core. Deferrals are counted globally
/// (`sched_deferrals`) and per job. With `fair` unset the legacy greedy
/// path runs: a flat `PORT_BATCH` per port per sweep.
fn core_loop(
    ctrl: ring::Consumer<CoreMsg>,
    waiter: Arc<ring::Waiter>,
    metrics: Arc<DataPlaneMetrics>,
    fair: bool,
    quantum: usize,
) {
    let mut engine = ShardEngine::new();
    let mut scheds: Vec<JobSched> = vec![JobSched {
        job: 0,
        quantum: 0,
        deficit: 0,
        ports: 1,
        jm: None,
    }];
    let mut slots: Vec<PortSlot> = vec![PortSlot { port: ctrl, sched: 0 }];
    loop {
        if fair {
            // Refill at sweep start; plain integer writes only.
            for s in scheds.iter_mut().skip(1) {
                if s.ports > 0 {
                    s.deficit = (s.deficit + s.quantum).min(2 * s.quantum);
                }
            }
        }
        let mut progressed = false;
        let mut i = 0;
        while i < slots.len() {
            // Bounded batch per port per sweep: one hot worker cannot
            // starve its neighbours on the same core. Under fair
            // scheduling the bound also honours the job's remaining
            // deficit (control ports keep the flat batch).
            let sched_ix = slots[i].sched;
            let budget = if fair && sched_ix != 0 {
                scheds[sched_ix].deficit.min(PORT_BATCH)
            } else {
                PORT_BATCH
            };
            let mut popped = 0usize;
            while popped < budget {
                match slots[i].port.try_recv() {
                    Ok(msg) => {
                        popped += 1;
                        progressed = true;
                        if let Some((port, job, weight)) =
                            apply_core_msg(&mut engine, msg, &metrics)
                        {
                            let sched = adopt_sched(&mut scheds, job, weight, quantum, &metrics);
                            slots.push(PortSlot { port, sched });
                        }
                    }
                    Err(_) => break,
                }
            }
            if fair && sched_ix != 0 {
                let s = &mut scheds[sched_ix];
                s.deficit -= popped; // popped <= budget <= deficit
                if s.deficit == 0 && !slots[i].port.is_empty() {
                    // Budget spent with traffic still queued: the job
                    // waits for its next refill while neighbours run.
                    metrics.sched_deferrals.inc();
                    if let Some(jm) = &s.jm {
                        jm.deferrals.inc();
                    }
                }
            }
            i += 1;
        }
        if !progressed {
            let mut i = 0;
            while i < slots.len() {
                if slots[i].port.is_disconnected() {
                    let dead = slots.swap_remove(i);
                    scheds[dead.sched].ports -= 1;
                } else {
                    i += 1;
                }
            }
            if slots.is_empty() {
                // Control ring and every worker port gone: shutdown.
                return;
            }
            waiter.wait_until(|| {
                slots
                    .iter()
                    .any(|p| !p.port.is_empty() || p.port.is_disconnected())
            });
        }
    }
}

/// A worker slot's half of the fabric, parked until claimed by
/// [`PHubServer::worker`]: one request-ring producer per core plus the
/// multiplexed reply receiver.
struct WorkerPort {
    reqs: Vec<ring::Producer<CoreMsg>>,
    rx: ReplyRx,
}

/// What a job's chunks start from: a fresh flat init vector, or the
/// exported [`ChunkState`]s of a previously evicted job (parameter
/// handoff — see [`PHubServer::export_job`]).
enum JobSource<'a> {
    Fresh(&'a [f32]),
    Resumed(Vec<ChunkState>),
}

/// Per-job bookkeeping on the server frontend.
struct JobMeta {
    table: Arc<KeyTable>,
    /// Core index per chunk.
    core_of: Vec<usize>,
    n_workers: usize,
    /// Worker-slot fabric ends not yet claimed by worker handles.
    pending: Vec<Option<WorkerPort>>,
}

/// The frontend's handle on one core: the control-ring producer (mutex
/// here is control-plane only — init/rollback/evict/connect; the data
/// path never touches it) and the core's parker, shared by every ring
/// the core consumes.
struct CoreCtrl {
    ctrl: Mutex<ring::Producer<CoreMsg>>,
    waiter: Arc<ring::Waiter>,
}

impl CoreCtrl {
    /// Send a control message, preserving FIFO order against concurrent
    /// frontend threads. Panics if the core thread died (it only exits on
    /// orderly shutdown).
    fn send(&self, msg: CoreMsg) {
        self.ctrl
            .lock()
            .unwrap()
            .send(msg)
            .map_err(|_| ())
            .expect("core thread gone");
    }
}

/// The PHub server: owns the core threads.
pub struct PHubServer {
    cores: Vec<CoreCtrl>,
    handles: Vec<JoinHandle<()>>,
    jobs: Mutex<HashMap<JobId, JobMeta>>,
    next_job: AtomicU64,
    placement: mapping::PlacementMode,
    quota: QuotaConfig,
    metrics: Arc<DataPlaneMetrics>,
}

impl PHubServer {
    pub fn start(cfg: ServerConfig) -> Arc<PHubServer> {
        assert!(cfg.n_cores >= 1);
        let metrics = Arc::new(DataPlaneMetrics::default());
        // Record the dispatch tier and placement so operators/tests can
        // assert which path actually ran; this also resolves the kernel
        // tier once, before any core thread touches the data plane.
        metrics
            .kernel_tier
            .set(super::kernels::active_tier() as u8);
        metrics.placement_mode.set(cfg.placement as u8);
        let mut cores = Vec::new();
        let mut handles = Vec::new();
        for i in 0..cfg.n_cores {
            let waiter = Arc::new(ring::Waiter::new());
            let (tx, rx) = ring::spsc_shared(CTRL_RING_CAP, waiter.clone());
            cores.push(CoreCtrl {
                ctrl: Mutex::new(tx),
                waiter: waiter.clone(),
            });
            let metrics = metrics.clone();
            let fair = cfg.quota.fair_sched;
            let quantum = cfg.quota.sched_quantum;
            handles.push(
                std::thread::Builder::new()
                    .name(format!("phub-core-{i}"))
                    .spawn(move || core_loop(rx, waiter, metrics, fair, quantum))
                    .expect("spawn core thread"),
            );
        }
        Arc::new(PHubServer {
            cores,
            handles,
            jobs: Mutex::new(HashMap::new()),
            next_job: AtomicU64::new(1),
            placement: cfg.placement,
            quota: cfg.quota,
            metrics,
        })
    }

    /// The guardrail policy this server was started with.
    pub fn quota(&self) -> &QuotaConfig {
        &self.quota
    }

    pub fn n_cores(&self) -> usize {
        self.cores.len()
    }

    /// Data-plane counters (dropped messages, rollbacks, ...) shared by
    /// every core thread of this server.
    pub fn metrics(&self) -> &DataPlaneMetrics {
        &self.metrics
    }

    /// Shared handle on the same counters — what a
    /// [`super::status::StatusServer`] serves.
    pub fn metrics_arc(&self) -> Arc<DataPlaneMetrics> {
        self.metrics.clone()
    }

    /// Turn the flight recorder on or off (see [`crate::trace`]). The
    /// recorder's rings are process-wide, so this is the operator-facing
    /// switch exposed on the server rather than per-server state; with
    /// it off, `trace::start()` returns 0 and every hook is a single
    /// relaxed load.
    pub fn set_tracing(&self, on: bool) {
        crate::trace::set_enabled(on);
    }

    /// Register a job: allocate chunk→core mapping, install initial model
    /// state on the core threads (the `PHub::InitService` step), and
    /// build each worker slot's fabric (request ring + reply ring per
    /// core).
    ///
    /// Ring sizing: a synchronous worker never has more than one round in
    /// flight, so per (worker, core) at most `chunks_on_core` requests
    /// and `chunks_on_core` replies are outstanding — doubled for replay
    /// traffic racing a post-rollback drain, plus [`RING_SLACK`]. Within
    /// those bounds a full ring means a genuinely slow core (requests) or
    /// a genuinely slow worker (replies), and blocking the one producer
    /// involved is exactly the backpressure the shared-nothing design
    /// wants.
    ///
    /// Returns the job id. Worker handles are then created with
    /// [`PHubServer::worker`].
    pub fn init_job(
        self: &Arc<Self>,
        table: KeyTable,
        init_params: &[f32],
        opt: Arc<dyn Optimizer>,
        n_workers: usize,
    ) -> JobId {
        let weight = self.quota.default_weight;
        self.init_job_weighted(table, init_params, opt, n_workers, weight)
    }

    /// [`PHubServer::init_job`] with an explicit fair-schedule weight
    /// (how the TCP leader passes a tenant's configured share through;
    /// see [`QuotaConfig::weight_for`]).
    pub fn init_job_weighted(
        self: &Arc<Self>,
        table: KeyTable,
        init_params: &[f32],
        opt: Arc<dyn Optimizer>,
        n_workers: usize,
        sched_weight: u32,
    ) -> JobId {
        let (job, uplink) = self.init_job_inner(
            table,
            JobSource::Fresh(init_params),
            opt,
            n_workers,
            NodeRole::Root,
            sched_weight,
        );
        debug_assert!(uplink.is_none());
        job
    }

    /// Reinstall a job exported with [`PHubServer::export_job`]: every
    /// chunk resumes at its exported params, optimizer state, and round
    /// position, so a tenant readmitted after idle eviction continues
    /// bit-exactly where it left off. Root role only (a relay holds no
    /// durable state worth handing off).
    pub fn init_job_resumed(
        self: &Arc<Self>,
        table: KeyTable,
        chunks: Vec<ChunkState>,
        opt: Arc<dyn Optimizer>,
        n_workers: usize,
        sched_weight: u32,
    ) -> JobId {
        let (job, uplink) = self.init_job_inner(
            table,
            JobSource::Resumed(chunks),
            opt,
            n_workers,
            NodeRole::Root,
            sched_weight,
        );
        debug_assert!(uplink.is_none());
        job
    }

    /// Snapshot a job's full parameter-handoff state — final params,
    /// optimizer state, and per-chunk round — merged from every core
    /// and sorted by chunk id. Control plane: broadcasts an export to
    /// each core and waits for all of them, so the snapshot is coherent
    /// provided no worker is mid-round (the leader only evicts jobs
    /// with zero live connections). Unknown jobs yield an empty vec.
    pub fn export_job(&self, job: JobId) -> Vec<ChunkState> {
        let done = Arc::new(AtomicUsize::new(0));
        let out = Arc::new(Mutex::new(Vec::new()));
        for core in &self.cores {
            core.send(CoreMsg::ExportJob {
                job,
                out: out.clone(),
                done: done.clone(),
            });
        }
        while done.load(Ordering::Acquire) < self.cores.len() {
            std::thread::yield_now();
        }
        let mut states = std::mem::take(&mut *out.lock().unwrap());
        states.sort_by_key(|c| c.chunk);
        states
    }

    /// [`PHubServer::init_job`] for a [`NodeRole::RackRelay`] node: the
    /// job's cores forward each chunk's locally-complete raw sum instead
    /// of optimizing, and the returned [`RelayUplink`] is the (single)
    /// uplink thread's end of that exchange — it receives the sums over
    /// a lock-free per-core reply fabric and feeds the parent's returned
    /// parameters back down with [`RelayUplink::install_chunk_bytes`].
    pub fn init_relay_job(
        self: &Arc<Self>,
        table: KeyTable,
        init_params: &[f32],
        opt: Arc<dyn Optimizer>,
        n_workers: usize,
    ) -> (JobId, RelayUplink) {
        let weight = self.quota.default_weight;
        let (job, uplink) = self.init_job_inner(
            table,
            JobSource::Fresh(init_params),
            opt,
            n_workers,
            NodeRole::RackRelay,
            weight,
        );
        (job, uplink.expect("relay init always builds an uplink"))
    }

    fn init_job_inner(
        self: &Arc<Self>,
        table: KeyTable,
        source: JobSource<'_>,
        opt: Arc<dyn Optimizer>,
        n_workers: usize,
        role: NodeRole,
        sched_weight: u32,
    ) -> (JobId, Option<RelayUplink>) {
        match &source {
            JobSource::Fresh(p) => assert_eq!(p.len(), table.total_elems),
            JobSource::Resumed(states) => {
                assert_eq!(role, NodeRole::Root, "only Root jobs resume from handoff");
                assert_eq!(states.len(), table.chunks.len(), "handoff must cover every chunk");
            }
        }
        assert!((1..=super::aggregation::MAX_WORKERS).contains(&n_workers));
        let job = self.next_job.fetch_add(1, Ordering::SeqCst) as JobId;
        // Admission-time: create the job's attribution counters before
        // any traffic can reference them.
        self.metrics.per_job.register(job);
        let table = Arc::new(table);

        // Chunk → core under the configured placement: affine gives each
        // core one contiguous byte range of the model (PHub key
        // affinity — the chunk's frames land on the owning core's SPSC
        // port directly, and the core's working set stays contiguous);
        // interleave is the old LPT scatter. Both are balanced on chunk
        // lengths and train bit-identically. A `max_cores_per_job` quota
        // confines the job to a prefix of the core set so one tenant
        // cannot spread across (and thrash) every cache domain.
        let lens: Vec<usize> = table.chunks.iter().map(|c| c.len).collect();
        let cores_cap = match self.quota.max_cores_per_job {
            0 => self.cores.len(),
            cap => self.cores.len().min(cap),
        };
        let core_of = self.placement.partition(&lens, cores_cap);
        let chunks_on_core: Vec<usize> = (0..self.cores.len())
            .map(|ci| core_of.iter().filter(|&&c| c == ci).count())
            .collect();

        // Build each worker's fabric: per-core reply rings behind one
        // waiter, per-core request rings behind each core's waiter.
        let mut reply_rows: Vec<Vec<ReplyTx>> = Vec::with_capacity(n_workers);
        let mut req_rows: Vec<Vec<ring::Consumer<CoreMsg>>> = Vec::with_capacity(n_workers);
        let mut pending: Vec<Option<WorkerPort>> = Vec::with_capacity(n_workers);
        for _ in 0..n_workers {
            let reply_waiter = Arc::new(ring::Waiter::new());
            let mut reply_txs = Vec::with_capacity(self.cores.len());
            let mut reply_rxs = Vec::with_capacity(self.cores.len());
            let mut req_txs = Vec::with_capacity(self.cores.len());
            let mut req_rxs = Vec::with_capacity(self.cores.len());
            for (ci, core) in self.cores.iter().enumerate() {
                let cap = 2 * chunks_on_core[ci] + RING_SLACK;
                let (rtx, rrx) = ring::spsc_shared(cap, reply_waiter.clone());
                reply_txs.push(rtx);
                reply_rxs.push(rrx);
                let (qtx, qrx) = ring::spsc_shared(cap, core.waiter.clone());
                req_txs.push(qtx);
                req_rxs.push(qrx);
            }
            reply_rows.push(reply_txs);
            req_rows.push(req_rxs);
            pending.push(Some(WorkerPort {
                reqs: req_txs,
                rx: ReplyRx::new(job, reply_rxs, reply_waiter),
            }));
        }

        // RackRelay only: one extra lock-free lane for the uplink thread
        // — per-core sum rings (core → uplink, a reply fabric carrying
        // `Reply::Sum`) and per-core install rings (uplink → core,
        // carrying `InstallParams`), sized like a worker's lanes so the
        // uplink steady path acquires no mutex and blocks only itself.
        let mut uplink_sum_txs: Vec<Option<ReplyTx>> = (0..self.cores.len()).map(|_| None).collect();
        let mut uplink = None;
        let mut inst_ports: Option<Vec<ring::Consumer<CoreMsg>>> = None;
        if role == NodeRole::RackRelay {
            let sum_waiter = Arc::new(ring::Waiter::new());
            let mut sum_rxs = Vec::with_capacity(self.cores.len());
            let mut inst_txs = Vec::with_capacity(self.cores.len());
            let mut inst_rxs = Vec::with_capacity(self.cores.len());
            for (ci, core) in self.cores.iter().enumerate() {
                let cap = 2 * chunks_on_core[ci] + RING_SLACK;
                let (stx, srx) = ring::spsc_shared(cap, sum_waiter.clone());
                uplink_sum_txs[ci] = Some(stx);
                sum_rxs.push(srx);
                let (itx, irx) = ring::spsc_shared(cap, core.waiter.clone());
                inst_txs.push(itx);
                inst_rxs.push(irx);
            }
            uplink = Some(RelayUplink {
                _server: self.clone(),
                job,
                table: table.clone(),
                core_of: core_of.clone(),
                reqs: inst_txs,
                rx: ReplyRx::new(job, sum_rxs, sum_waiter),
            });
            inst_ports = Some(inst_rxs);
        }

        // Install the job on every core. Holding the control mutex across
        // InitJob + the Connects keeps them contiguous FIFO on the ring:
        // a core adopts a worker's request port only after installing the
        // job, so no push can ever race its own InitJob.
        let mut req_cols: Vec<Vec<ring::Consumer<CoreMsg>>> = (0..self.cores.len())
            .map(|_| Vec::with_capacity(n_workers))
            .collect();
        for row in req_rows {
            for (ci, rx) in row.into_iter().enumerate() {
                req_cols[ci].push(rx);
            }
        }
        let mut reply_cols: Vec<Vec<ReplyTx>> = (0..self.cores.len())
            .map(|_| Vec::with_capacity(n_workers))
            .collect();
        for row in reply_rows {
            for (ci, tx) in row.into_iter().enumerate() {
                reply_cols[ci].push(tx);
            }
        }
        // Split the job source into per-core shares: fresh params are
        // sliced from the flat init vector; resumed chunk states are
        // routed to the core that owns each chunk (the placement is a
        // pure function of chunk lengths and core count, so a job
        // readmitted on the same server shape lands where it lived).
        let (fresh_params, mut resumed_by_core) = match source {
            JobSource::Fresh(p) => (Some(p), Vec::new()),
            JobSource::Resumed(states) => {
                let mut by_core: Vec<Vec<ChunkState>> =
                    (0..self.cores.len()).map(|_| Vec::new()).collect();
                for cs in states {
                    let c = cs.chunk as usize;
                    assert!(c < table.chunks.len(), "exported chunk id out of range");
                    by_core[core_of[c]].push(cs);
                }
                (None, by_core)
            }
        };
        for (ci, core) in self.cores.iter().enumerate() {
            let ctrl = core.ctrl.lock().unwrap();
            match fresh_params {
                Some(init_params) => {
                    let chunks: Vec<(u32, Vec<f32>)> = table
                        .chunks
                        .iter()
                        .enumerate()
                        .filter(|(i, _)| core_of[*i] == ci)
                        .map(|(i, c)| {
                            (i as u32, init_params[c.offset..c.offset + c.len].to_vec())
                        })
                        .collect();
                    ctrl.send(CoreMsg::InitJob {
                        job,
                        chunks,
                        opt: opt.clone(),
                        n_workers,
                        replies: std::mem::take(&mut reply_cols[ci]),
                        role,
                        uplink: uplink_sum_txs[ci].take(),
                    })
                    .map_err(|_| ())
                    .expect("core thread gone");
                }
                None => {
                    ctrl.send(CoreMsg::InitJobResumed {
                        job,
                        chunks: std::mem::take(&mut resumed_by_core[ci]),
                        opt: opt.clone(),
                        n_workers,
                        replies: std::mem::take(&mut reply_cols[ci]),
                    })
                    .map_err(|_| ())
                    .expect("core thread gone");
                }
            }
            for rx in req_cols[ci].drain(..) {
                ctrl.send(CoreMsg::Connect {
                    port: rx,
                    job,
                    weight: sched_weight,
                })
                .map_err(|_| ())
                .expect("core thread gone");
            }
            if let Some(ports) = inst_ports.as_mut() {
                ctrl.send(CoreMsg::Connect {
                    port: ports.remove(0),
                    job,
                    weight: sched_weight,
                })
                .map_err(|_| ())
                .expect("core thread gone");
            }
        }

        self.jobs.lock().unwrap().insert(
            job,
            JobMeta {
                table,
                core_of,
                n_workers,
                pending,
            },
        );
        (job, uplink)
    }

    /// Register how many leaf workers direct pusher `worker` of `job`
    /// represents (a relay connection registering its rack size;
    /// admission-time control plane). Broadcast to every core, then wait
    /// until each has applied it: the weight must be in force before the
    /// caller lets the pusher push, or a round completing in the gap
    /// would divide by a stale total.
    pub fn set_worker_weight(&self, job: JobId, worker: u32, weight: u32) {
        let done = Arc::new(AtomicUsize::new(0));
        for core in &self.cores {
            core.send(CoreMsg::SetWeight {
                job,
                worker,
                weight,
                done: done.clone(),
            });
        }
        while done.load(Ordering::Acquire) < self.cores.len() {
            std::thread::yield_now();
        }
    }

    /// Create the handle for worker `w` of `job` (the client side of
    /// `PHub::ConnectService`).
    pub fn worker(self: &Arc<Self>, job: JobId, w: usize) -> WorkerHandle {
        let mut jobs = self.jobs.lock().unwrap();
        let meta = jobs.get_mut(&job).expect("unknown job");
        assert!(w < meta.n_workers, "worker index out of range");
        let port = meta.pending[w]
            .take()
            .expect("worker handle already taken");
        WorkerHandle {
            _server: self.clone(),
            job,
            worker: w as u32,
            table: meta.table.clone(),
            core_of: meta.core_of.clone(),
            reqs: port.reqs,
            rx: port.rx,
            staging: Vec::new(),
            epoch: 0,
            round: 0,
            jm: self.metrics.per_job.register(job),
        }
    }

    /// Rewind `job`'s open round on every core, advancing it to `epoch`
    /// (the leader's recovery move after a worker dies mid-round; see
    /// `ShardEngine::rollback` for the semantics). Workers learn about the
    /// rollback from a [`Reply::RolledBack`] notice on their reply route
    /// (delivered via the rings' epoch bulletin) and replay the round.
    pub fn rollback_round(&self, job: JobId, epoch: u32) {
        for core in &self.cores {
            core.send(CoreMsg::RollbackRound { job, epoch });
        }
    }

    /// Remove a job's state from all cores.
    pub fn evict(&self, job: JobId) {
        self.jobs.lock().unwrap().remove(&job);
        self.metrics.per_job.remove(job);
        for core in &self.cores {
            core.send(CoreMsg::Evict { job });
        }
    }

    /// Shut down core threads (consumes the last Arc).
    pub fn shutdown(server: Arc<Self>) {
        let mut server = match Arc::try_unwrap(server) {
            Ok(s) => s,
            Err(_) => return, // other handles alive; threads exit when they drop
        };
        // Disconnect every producer the frontend still holds — the
        // unclaimed worker ports in the jobs map and the control rings —
        // so each core's port list drains to empty and its loop exits.
        server.jobs.lock().unwrap().clear();
        server.cores.clear();
        for h in server.handles.drain(..) {
            let _ = h.join();
        }
    }
}

/// Result of collecting one round's replies.
enum Collected {
    Done(Vec<f32>),
    /// The round was rewound server-side; replay it under the new epoch.
    Rolled(u32),
}

/// A worker's connection to the server.
///
/// Carries the worker's `(epoch, round)` position (see
/// [`super::engine::RoundTag`]); `push_pull` / `push` / `pull` keep it
/// current automatically, and `push_pull` transparently replays a round
/// the engine rolled back. Manual `push_chunk` users drive
/// [`WorkerHandle::advance_round`] themselves.
pub struct WorkerHandle {
    /// Keeps the core threads alive for as long as this handle exists
    /// (shutdown requires the last server `Arc`).
    _server: Arc<PHubServer>,
    job: JobId,
    worker: u32,
    table: Arc<KeyTable>,
    core_of: Vec<usize>,
    /// This worker's lane into each core: one SPSC request-ring producer
    /// per core. A full ring blocks this worker alone (backpressure).
    reqs: Vec<ring::Producer<CoreMsg>>,
    /// The per-core reply rings, multiplexed behind one parker.
    rx: ReplyRx,
    /// Reassembly buffer reused across rounds.
    staging: Vec<f32>,
    epoch: u32,
    round: u64,
    /// This job's attribution counters, resolved once at handle creation
    /// so the data path never touches the registry lock.
    jm: Arc<JobMetrics>,
}

impl WorkerHandle {
    pub fn model_len(&self) -> usize {
        self.table.total_elems
    }

    /// Job this handle pushes into.
    pub fn job(&self) -> JobId {
        self.job
    }

    /// This job's attribution counters (pre-resolved; incrementing them
    /// is a relaxed atomic add, registry-lock free). The TCP transport
    /// meters its wire traffic through this.
    pub fn job_metrics(&self) -> &Arc<JobMetrics> {
        &self.jm
    }

    pub fn key_table(&self) -> &KeyTable {
        &self.table
    }

    pub fn n_chunks(&self) -> usize {
        self.table.chunks.len()
    }

    /// Rollback epoch this worker is operating in.
    pub fn epoch(&self) -> u32 {
        self.epoch
    }

    /// Round this worker's next push contributes to.
    pub fn round(&self) -> u64 {
        self.round
    }

    /// Reposition the worker (a transport resuming a parked slot, or an
    /// embedder coordinating an explicit rollback).
    pub fn set_tag(&mut self, epoch: u32, round: u64) {
        self.epoch = epoch;
        self.round = round;
    }

    /// Advance to the next round — for manual `push_chunk` streaming users
    /// after they have collected the round's replies (`push_pull` and
    /// `push` do this internally).
    pub fn advance_round(&mut self) {
        self.round += 1;
    }

    /// Element range `[lo, hi)` of chunk `i` in the flat model.
    pub fn chunk_range(&self, i: usize) -> (usize, usize) {
        let c = &self.table.chunks[i];
        (c.offset, c.offset + c.len)
    }

    /// Route one chunk's gradient straight to its pinned core (the
    /// streaming half of `push_pull`), tagged with this handle's current
    /// `(epoch, round)` position.
    ///
    /// `data` holds exactly this chunk's elements. With `pull` set, the
    /// core sends this worker a [`Reply`] once the chunk's round
    /// completes; collect it with [`WorkerHandle::recv_reply`].
    pub fn push_chunk(&self, chunk: u32, data: Arc<[f32]>, pull: bool) {
        let tag = RoundTag::new(self.epoch, self.round);
        self.push_chunk_tagged(chunk, data, pull, tag);
    }

    /// [`WorkerHandle::push_chunk`] with an explicit tag — the TCP leader
    /// calls this per incoming `PushChunk` frame with its connection
    /// tracker's position, so aggregation starts when the *first* chunk
    /// lands instead of after the whole gradient arrives.
    pub fn push_chunk_tagged(&self, chunk: u32, data: Arc<[f32]>, pull: bool, tag: RoundTag) {
        let ci = chunk as usize;
        assert!(ci < self.table.chunks.len(), "chunk id out of range");
        let len = self.table.chunks[ci].len;
        assert_eq!(data.len(), len, "chunk length mismatch");
        self.reqs[self.core_of[ci]]
            .send(CoreMsg::Push {
                job: self.job,
                chunk,
                worker: self.worker,
                data,
                range: (0, len),
                pull,
                tag,
            })
            .map_err(|_| ())
            .expect("core thread gone");
    }

    /// [`WorkerHandle::push_chunk_tagged`] for raw wire bytes in a pooled
    /// frame buffer — the TCP leader's allocation-free hot path. The
    /// frame payload travels to the pinned core *in the buffer it was
    /// received into*; the core folds the bytes straight into the
    /// accumulator (no intermediate `Vec<f32>`), then the buffer recycles
    /// to the connection's pool. `data[grad_off..]` holds the gradient
    /// bytes: dense LE f32s, or a `QuantGrad` wire encoding when `quant`.
    pub fn push_chunk_bytes_tagged(
        &self,
        chunk: u32,
        data: PooledBytes,
        grad_off: usize,
        quant: bool,
        pull: bool,
        tag: RoundTag,
    ) {
        let ci = chunk as usize;
        assert!(ci < self.table.chunks.len(), "chunk id out of range");
        let len = self.table.chunks[ci].len;
        if !quant {
            assert_eq!(
                data.len() - grad_off,
                len * 4,
                "chunk byte length mismatch"
            );
        }
        // The span covers the SPSC send, so backpressure from a full
        // ring (a genuinely slow core) shows up as enqueue time.
        let t_enq = crate::trace::start();
        self.reqs[self.core_of[ci]]
            .send(CoreMsg::PushBytes {
                job: self.job,
                chunk,
                worker: self.worker,
                data,
                grad_off,
                quant,
                pull,
                tag,
            })
            .map_err(|_| ())
            .expect("core thread gone");
        crate::trace::span(crate::trace::Stage::RingEnqueue, self.job, chunk, self.worker, t_enq);
    }

    /// Block for the next per-chunk reply (one arrives for every chunk
    /// pushed with `pull == true` once its round completes). Rollback
    /// notices are synthesized from the reply rings' epoch bulletin and
    /// always outrank queued data (see `engine::ReplyRx`).
    pub fn recv_reply(&mut self) -> Reply {
        self.rx.recv().expect("server dropped")
    }

    /// Non-panicking variant of [`WorkerHandle::recv_reply`]: `None`
    /// means the server side of the job is gone (evicted — e.g. a relay
    /// uplink gave up on a dead parent and failed the job). Connection
    /// threads use this so an evicted job surfaces as a typed error on
    /// the worker's socket, never a thread panic.
    pub fn recv_reply_opt(&mut self) -> Option<Reply> {
        self.rx.recv()
    }

    /// Non-blocking variant of [`WorkerHandle::recv_reply`].
    pub fn try_recv_reply(&mut self) -> Option<Reply> {
        self.rx.try_recv()
    }

    /// Fused push+pull (the paper's `PHub::PushPull`): push this worker's
    /// gradient, wait for all workers' pushes to aggregate, and return the
    /// updated model. Saves a round trip over separate push-then-pull.
    ///
    /// If the engine rolls the round back mid-exchange (another worker of
    /// the job died), the push is transparently replayed under the new
    /// epoch — the caller just sees the completed round.
    pub fn push_pull(&mut self, grad: &[f32]) -> Vec<f32> {
        assert_eq!(grad.len(), self.table.total_elems, "gradient length");
        let t0 = std::time::Instant::now();
        self.jm.push_bytes.add(grad.len() as u64 * 4);
        // One registration-style copy into a shared buffer (the "NIC DMA"),
        // then chunks are pushed zero-copy: cores read their ranges
        // directly (section 3.2.1 "Minimal Copy" / 3.2.4 disassembly).
        let shared: Arc<[f32]> = grad.into();
        loop {
            let tag = RoundTag::new(self.epoch, self.round);
            for (i, c) in self.table.chunks.iter().enumerate() {
                self.reqs[self.core_of[i]]
                    .send(CoreMsg::Push {
                        job: self.job,
                        chunk: i as u32,
                        worker: self.worker,
                        data: shared.clone(),
                        range: (c.offset, c.offset + c.len),
                        pull: true,
                        tag,
                    })
                    .map_err(|_| ())
                    .expect("core thread gone");
            }
            match self.collect_model() {
                Collected::Done(m) => {
                    self.round += 1;
                    self.jm.rounds_completed.inc();
                    self.jm.pull_bytes.add(m.len() as u64 * 4);
                    self.jm.round_latency.record(t0.elapsed());
                    return m;
                }
                Collected::Rolled(epoch) => {
                    self.epoch = epoch; // same round, fresh epoch: replay
                }
            }
        }
    }

    /// Confirmed push (the paper's `Push`): contribute this worker's
    /// gradient and wait for the round to complete, discarding the
    /// updated parameters.
    ///
    /// A push cannot be fire-and-forget under mid-round recovery: without
    /// waiting for completion there is no way to know whether the round
    /// was rewound after the gradient was absorbed, so an unconfirmed
    /// contribution could be silently lost. Riding the `push_pull`
    /// machinery makes an interrupted round replay transparently here
    /// too.
    pub fn push(&mut self, grad: &[f32]) {
        let _ = self.push_pull(grad);
    }

    /// Pull the current model (no gradient contribution).
    ///
    /// Read-only, so rollbacks need no replay here: a pull is answered
    /// immediately per chunk whatever the round state, and a rollback
    /// never modifies parameters — replies are therefore accepted
    /// regardless of their epoch stamp (a pull has never been atomic
    /// against concurrently completing rounds anyway). Re-requesting
    /// after a rollback notice would orphan the first batch's replies
    /// and desync every later round's collect by one.
    pub fn pull(&mut self) -> Vec<f32> {
        for i in 0..self.table.chunks.len() {
            self.reqs[self.core_of[i]]
                .send(CoreMsg::Pull {
                    job: self.job,
                    chunk: i as u32,
                    worker: self.worker,
                })
                .map_err(|_| ())
                .expect("core thread gone");
        }
        self.staging.clear();
        self.staging.resize(self.table.total_elems, 0.0);
        let n_chunks = self.table.chunks.len();
        let mut seen = vec![false; n_chunks];
        let mut got = 0usize;
        while got < n_chunks {
            match self.rx.recv().expect("server dropped") {
                Reply::Chunk {
                    job, chunk, data, ..
                } => {
                    debug_assert_eq!(job, self.job);
                    let ci = chunk as usize;
                    if seen[ci] {
                        continue;
                    }
                    seen[ci] = true;
                    let c = &self.table.chunks[ci];
                    self.staging[c.offset..c.offset + c.len].copy_from_slice(&data);
                    got += 1;
                }
                Reply::RolledBack { epoch, .. } => {
                    // Note the epoch for later pushes; nothing to replay.
                    if epoch > self.epoch {
                        self.epoch = epoch;
                    }
                }
            }
        }
        self.jm.pull_bytes.add(self.staging.len() as u64 * 4);
        std::mem::take(&mut self.staging)
    }

    /// Receive one reply per chunk and reassemble the flat model, dropping
    /// replies that were in flight for a rolled-back epoch.
    fn collect_model(&mut self) -> Collected {
        self.staging.clear();
        self.staging.resize(self.table.total_elems, 0.0);
        let n_chunks = self.table.chunks.len();
        let mut seen = vec![false; n_chunks];
        let mut got = 0usize;
        while got < n_chunks {
            match self.rx.recv().expect("server dropped") {
                Reply::Chunk {
                    job,
                    chunk,
                    epoch,
                    data,
                } => {
                    // (`data` is the refcount-shared broadcast buffer;
                    // dropping it at the end of this arm releases this
                    // worker's reference.)
                    debug_assert_eq!(job, self.job);
                    if epoch < self.epoch {
                        continue; // superseded by a rollback we already saw
                    }
                    debug_assert_eq!(epoch, self.epoch);
                    let ci = chunk as usize;
                    if seen[ci] {
                        continue;
                    }
                    seen[ci] = true;
                    let c = &self.table.chunks[ci];
                    self.staging[c.offset..c.offset + c.len].copy_from_slice(&data);
                    got += 1;
                }
                Reply::RolledBack { epoch, .. } => {
                    if epoch > self.epoch {
                        return Collected::Rolled(epoch);
                    }
                    // Duplicate notice from another core: already handled.
                }
            }
        }
        Collected::Done(std::mem::take(&mut self.staging))
    }
}

/// The uplink thread's end of a RackRelay job's hierarchical exchange
/// (built by [`PHubServer::init_relay_job`]):
///
/// * **up**: [`RelayUplink::recv_sum`] delivers each chunk's
///   locally-complete raw sum ([`Reply::Sum`]) from its pinned core over
///   a lock-free per-core reply fabric — exactly one per chunk per
///   round, whatever rack-local recovery happened underneath;
/// * **down**: [`RelayUplink::install_chunk_bytes`] hands the parent's
///   returned parameters (still in the pooled frame buffer they were
///   received into) to the chunk's core, which writes them into the
///   slot and fires the pull broadcast deferred at sum time.
///
/// Both directions are SPSC rings: the uplink steady path acquires no
/// mutex and allocates nothing once its pools are warm.
pub struct RelayUplink {
    /// Keeps the core threads alive for as long as this handle exists.
    _server: Arc<PHubServer>,
    job: JobId,
    table: Arc<KeyTable>,
    core_of: Vec<usize>,
    /// One SPSC install-ring producer per core (uplink → core).
    reqs: Vec<ring::Producer<CoreMsg>>,
    /// The per-core sum rings, multiplexed behind one parker.
    rx: ReplyRx,
}

impl RelayUplink {
    pub fn job(&self) -> JobId {
        self.job
    }

    pub fn key_table(&self) -> &KeyTable {
        &self.table
    }

    pub fn n_chunks(&self) -> usize {
        self.table.chunks.len()
    }

    /// Element range `[lo, hi)` of chunk `i` in the flat model.
    pub fn chunk_range(&self, i: usize) -> (usize, usize) {
        let c = &self.table.chunks[i];
        (c.offset, c.offset + c.len)
    }

    /// Block for the next locally-complete chunk sum. `None` means the
    /// job was evicted (every core dropped its lane) — the uplink thread
    /// should exit.
    pub fn recv_sum(&mut self) -> Option<Reply> {
        self.rx.recv()
    }

    /// Non-blocking variant of [`RelayUplink::recv_sum`].
    pub fn try_recv_sum(&mut self) -> Option<Reply> {
        self.rx.try_recv()
    }

    /// Feed the parent's returned parameters for `chunk` — dense LE f32
    /// bytes at `data[off..]`, typically the `ModelChunk` frame payload
    /// still in its pooled receive buffer — down to the chunk's pinned
    /// core. The buffer recycles there after the core's single copy.
    pub fn install_chunk_bytes(&self, chunk: u32, data: PooledBytes, off: usize) {
        let ci = chunk as usize;
        assert!(ci < self.table.chunks.len(), "chunk id out of range");
        debug_assert_eq!(data.len() - off, self.table.chunks[ci].len * 4);
        self.reqs[self.core_of[ci]]
            .send(CoreMsg::InstallParams {
                job: self.job,
                chunk,
                data,
                off,
            })
            .map_err(|_| ())
            .expect("core thread gone");
    }
}

#[cfg(test)]
#[allow(clippy::useless_vec)]
mod tests {
    use super::*;
    use crate::coordinator::optimizer::{NesterovSgd, Sgd};

    fn table(total: usize, chunk: usize) -> KeyTable {
        KeyTable::flat(total, chunk)
    }

    /// N worker threads, one round of push_pull with known gradients:
    /// result must equal p - lr * mean(g).
    #[test]
    fn one_round_sgd_exact() {
        let server = PHubServer::start(ServerConfig::cores(3));
        let n = 64usize;
        let init = vec![1.0f32; n];
        let job = server.init_job(table(n, 16), &init, Arc::new(Sgd { lr: 0.5 }), 4);
        let mut joins = Vec::new();
        for w in 0..4usize {
            let mut h = server.worker(job, w);
            joins.push(std::thread::spawn(move || {
                let g = vec![w as f32; n]; // mean = 1.5
                h.push_pull(&g)
            }));
        }
        for j in joins {
            let model = j.join().unwrap();
            for x in model {
                assert!((x - (1.0 - 0.5 * 1.5)).abs() < 1e-6, "{x}");
            }
        }
        PHubServer::shutdown(server);
    }

    /// Multi-round training equals the sequential Nesterov reference.
    #[test]
    fn multi_round_matches_sequential_reference() {
        let server = PHubServer::start(ServerConfig::cores(2));
        let n = 48usize;
        let init: Vec<f32> = (0..n).map(|i| i as f32 * 0.1).collect();
        let opt = NesterovSgd {
            lr: 0.1,
            momentum: 0.9,
        };
        let job = server.init_job(table(n, 16), &init, Arc::new(opt.clone()), 2);

        // Server path: 2 workers, 3 rounds, deterministic grads.
        let grad = |w: usize, r: usize| -> Vec<f32> {
            (0..n).map(|i| (w + 2 * r) as f32 + i as f32 * 0.01).collect()
        };
        let mut handles: Vec<_> = (0..2).map(|w| server.worker(job, w)).collect();
        let mut final_model = Vec::new();
        for r in 0..3 {
            let (h0, h1) = handles.split_at_mut(1);
            let g1 = grad(1, r);
            let j = std::thread::scope(|s| {
                let t = s.spawn(|| h1[0].push_pull(&g1));
                let m0 = h0[0].push_pull(&grad(0, r));
                let m1 = t.join().unwrap();
                (m0, m1)
            });
            assert_eq!(j.0, j.1, "round {r}: workers disagree");
            final_model = j.0;
        }

        // Sequential reference.
        let mut p = init.clone();
        let mut m = vec![0.0f32; n];
        use crate::coordinator::optimizer::Optimizer as _;
        for r in 0..3 {
            let g0 = grad(0, r);
            let g1 = grad(1, r);
            let mean: Vec<f32> = g0.iter().zip(&g1).map(|(a, b)| (a + b) / 2.0).collect();
            opt.step(&mut p, &mut m, &mean);
        }
        for (a, b) in final_model.iter().zip(&p) {
            assert!((a - b).abs() < 1e-5, "{a} vs {b}");
        }
        PHubServer::shutdown(server);
    }

    #[test]
    fn pull_returns_init_before_any_push() {
        let server = PHubServer::start(ServerConfig::cores(2));
        let init: Vec<f32> = (0..32).map(|i| i as f32).collect();
        let job = server.init_job(table(32, 8), &init, Arc::new(Sgd { lr: 1.0 }), 1);
        let mut h = server.worker(job, 0);
        assert_eq!(h.pull(), init);
        PHubServer::shutdown(server);
    }

    #[test]
    fn two_jobs_are_isolated() {
        let server = PHubServer::start(ServerConfig::cores(2));
        let init_a = vec![0.0f32; 16];
        let init_b = vec![100.0f32; 16];
        let ja = server.init_job(table(16, 8), &init_a, Arc::new(Sgd { lr: 1.0 }), 1);
        let jb = server.init_job(table(16, 8), &init_b, Arc::new(Sgd { lr: 1.0 }), 1);
        let mut ha = server.worker(ja, 0);
        let mut hb = server.worker(jb, 0);
        let ma = ha.push_pull(&vec![1.0; 16]); // 0 - 1 = -1
        let mb = hb.push_pull(&vec![1.0; 16]); // 100 - 1 = 99
        assert!(ma.iter().all(|&x| (x + 1.0).abs() < 1e-6));
        assert!(mb.iter().all(|&x| (x - 99.0).abs() < 1e-6));
        PHubServer::shutdown(server);
    }

    #[test]
    fn push_then_pull_equivalent_to_push_pull() {
        let server = PHubServer::start(ServerConfig::cores(1));
        let init = vec![0.0f32; 8];
        let job = server.init_job(table(8, 8), &init, Arc::new(Sgd { lr: 1.0 }), 1);
        let mut h = server.worker(job, 0);
        h.push(&vec![2.0; 8]);
        let m = h.pull();
        assert!(m.iter().all(|&x| (x + 2.0).abs() < 1e-6), "{m:?}");
        PHubServer::shutdown(server);
    }

    /// Pushing chunk-by-chunk (in any order) through the streaming API
    /// produces the same bits as the monolithic `push_pull`.
    #[test]
    fn chunk_streaming_matches_push_pull() {
        let server = PHubServer::start(ServerConfig::cores(2));
        let n = 40usize;
        let init: Vec<f32> = (0..n).map(|i| i as f32 * 0.5).collect();
        let opt = || Arc::new(NesterovSgd { lr: 0.2, momentum: 0.9 });
        let ja = server.init_job(table(n, 16), &init, opt(), 2);
        let jb = server.init_job(table(n, 16), &init, opt(), 2);
        let grad = |w: usize| -> Vec<f32> {
            (0..n).map(|i| w as f32 + i as f32 * 0.1).collect()
        };

        // Job A: monolithic push_pull.
        let mut ha: Vec<_> = (0..2).map(|w| server.worker(ja, w)).collect();
        let (a0, a1) = ha.split_at_mut(1);
        let ma = std::thread::scope(|s| {
            let t = s.spawn(|| a1[0].push_pull(&grad(1)));
            let m = a0[0].push_pull(&grad(0));
            t.join().unwrap();
            m
        });

        // Job B: per-chunk pushes in *reverse* order, replies in any order.
        let mut hb: Vec<_> = (0..2).map(|w| server.worker(jb, w)).collect();
        let stream = |h: &mut WorkerHandle, g: &[f32]| -> Vec<f32> {
            let n_chunks = h.n_chunks();
            for i in (0..n_chunks).rev() {
                let (lo, hi) = h.chunk_range(i);
                h.push_chunk(i as u32, g[lo..hi].into(), true);
            }
            let mut model = vec![0.0f32; h.model_len()];
            for _ in 0..n_chunks {
                match h.recv_reply() {
                    Reply::Chunk { chunk, data, .. } => {
                        let (lo, hi) = h.chunk_range(chunk as usize);
                        model[lo..hi].copy_from_slice(&data);
                    }
                    other => panic!("unexpected reply {other:?}"),
                }
            }
            h.advance_round();
            model
        };
        let (b0, b1) = hb.split_at_mut(1);
        let mb = std::thread::scope(|s| {
            let t = s.spawn(|| stream(&mut b1[0], &grad(1)));
            let m = stream(&mut b0[0], &grad(0));
            t.join().unwrap();
            m
        });

        assert_eq!(ma, mb, "streamed and monolithic paths must agree bitwise");
        PHubServer::shutdown(server);
    }

    /// In-process mid-round rollback: a partial round rewound with
    /// `rollback_round` and then fully replayed produces bit-identical
    /// parameters to an uninterrupted round on a twin job.
    #[test]
    fn rollback_and_replay_matches_clean_round() {
        let server = PHubServer::start(ServerConfig::cores(2));
        let n = 32usize;
        let init: Vec<f32> = (0..n).map(|i| i as f32 * 0.25).collect();
        let opt = || Arc::new(NesterovSgd { lr: 0.1, momentum: 0.9 });
        let ja = server.init_job(table(n, 8), &init, opt(), 2);
        let jb = server.init_job(table(n, 8), &init, opt(), 2);
        let grad = |w: usize| -> Vec<f32> {
            (0..n).map(|i| (w + 1) as f32 * 0.5 + i as f32 * 0.125).collect()
        };

        // Job A, interrupted: worker 1 pushes chunks 0..2 of the round,
        // then "dies"; the leader rolls the round back; both workers then
        // replay the full round.
        let mut ha: Vec<_> = (0..2).map(|w| server.worker(ja, w)).collect();
        {
            let g1 = grad(1);
            for i in 0..2u32 {
                let (lo, hi) = ha[1].chunk_range(i as usize);
                ha[1].push_chunk(i, g1[lo..hi].into(), true);
            }
        }
        server.rollback_round(ja, 1);
        let ma = std::thread::scope(|s| {
            let (h0, h1) = ha.split_at_mut(1);
            let t = s.spawn(|| h1[0].push_pull(&grad(1)));
            let m = h0[0].push_pull(&grad(0));
            assert_eq!(m, t.join().unwrap());
            m
        });

        // Job B, clean.
        let mut hb: Vec<_> = (0..2).map(|w| server.worker(jb, w)).collect();
        let mb = std::thread::scope(|s| {
            let (h0, h1) = hb.split_at_mut(1);
            let t = s.spawn(|| h1[0].push_pull(&grad(1)));
            let m = h0[0].push_pull(&grad(0));
            t.join().unwrap();
            m
        });

        assert_eq!(ma, mb, "replayed round must be bit-identical to clean");
        PHubServer::shutdown(server);
    }

    /// Two-level in-process deployment (2 rack relays × 2 workers feeding
    /// a weighted root) trains bit-identically to a flat 4-worker job on
    /// the same gradients. Gradients, init, lr, and momentum are dyadic
    /// rationals, so every sum and product is exact in f32 and the
    /// different association orders — flat `((g0+g1)+g2)+g3` vs two-level
    /// `(g0+g1)+(g2+g3)` — cannot hide behind rounding.
    #[test]
    fn two_level_relay_matches_flat_bitwise() {
        use crate::coordinator::pool::{BytePool, Pool};

        let n = 48usize;
        let rounds = 3usize;
        let init: Vec<f32> = (0..n).map(|i| (i % 8) as f32 * 0.25).collect();
        let opt = || {
            Arc::new(NesterovSgd {
                lr: 0.25,
                momentum: 0.5,
            })
        };
        // Leaf gradient for global worker w (dyadic, round-dependent).
        let grad = |w: usize, r: usize| -> Vec<f32> {
            (0..n)
                .map(|i| (w as f32 - 1.5) * 0.5 + (i % 16) as f32 * 0.125 + r as f32 * 0.25)
                .collect()
        };

        // Flat reference: one root, 4 direct workers.
        let flat = PHubServer::start(ServerConfig::cores(2));
        let jf = flat.init_job(table(n, 16), &init, opt(), 4);
        let flat_model = std::thread::scope(|s| {
            let joins: Vec<_> = (0..4)
                .map(|w| {
                    let mut h = flat.worker(jf, w);
                    s.spawn(move || {
                        let mut m = Vec::new();
                        for r in 0..rounds {
                            m = h.push_pull(&grad(w, r));
                        }
                        m
                    })
                })
                .collect();
            let models: Vec<Vec<f32>> = joins.into_iter().map(|j| j.join().unwrap()).collect();
            assert_eq!(models[0], models[1]);
            models.into_iter().next().unwrap()
        });
        PHubServer::shutdown(flat);

        // Two-level: root job with 2 weighted pushers (the relays), each
        // relay a RackRelay job with 2 leaf workers. The pump closure is
        // the uplink thread's job: forward each chunk sum to the root,
        // install the root's replies back into the relay.
        let root = PHubServer::start(ServerConfig::cores(2));
        let jr = root.init_job(table(n, 16), &init, opt(), 2);
        root.set_worker_weight(jr, 0, 2);
        root.set_worker_weight(jr, 1, 2);
        let racks: Vec<Arc<PHubServer>> = (0..2)
            .map(|_| PHubServer::start(ServerConfig::cores(2)))
            .collect();
        let relay_jobs: Vec<(JobId, RelayUplink)> = racks
            .iter()
            .map(|s| s.init_relay_job(table(n, 16), &init, opt(), 2))
            .collect();

        let leaf_models = std::thread::scope(|s| {
            let mut pumps = Vec::new();
            let mut leaves = Vec::new();
            for (rack, (job, up)) in relay_jobs.into_iter().enumerate() {
                for lw in 0..2usize {
                    let w = rack * 2 + lw; // global worker id → same grads
                    let mut h = racks[rack].worker(job, lw);
                    leaves.push(s.spawn(move || {
                        let mut m = Vec::new();
                        for r in 0..rounds {
                            m = h.push_pull(&grad(w, r));
                        }
                        m
                    }));
                }
                let mut root_h = root.worker(jr, rack);
                let mut up = up;
                pumps.push(s.spawn(move || {
                    let pool: Arc<BytePool> = Pool::new(up.n_chunks());
                    for _ in 0..rounds {
                        for _ in 0..up.n_chunks() {
                            match up.recv_sum().unwrap() {
                                Reply::Sum { chunk, data, .. } => {
                                    root_h.push_chunk(chunk, data[..].into(), true);
                                }
                                other => panic!("expected a sum, got {other:?}"),
                            }
                        }
                        for _ in 0..up.n_chunks() {
                            match root_h.recv_reply() {
                                Reply::Chunk { chunk, data, .. } => {
                                    let mut buf = pool.take();
                                    for x in data.iter() {
                                        buf.extend_from_slice(&x.to_le_bytes());
                                    }
                                    up.install_chunk_bytes(chunk, buf, 0);
                                }
                                other => panic!("expected params, got {other:?}"),
                            }
                        }
                        root_h.advance_round();
                    }
                }));
            }
            let models: Vec<Vec<f32>> =
                leaves.into_iter().map(|j| j.join().unwrap()).collect();
            for p in pumps {
                p.join().unwrap();
            }
            models
        });
        for m in &leaf_models {
            assert_eq!(
                m, &flat_model,
                "two-level parameters must be bit-identical to flat"
            );
        }
        for s in racks {
            PHubServer::shutdown(s);
        }
        PHubServer::shutdown(root);
    }

    /// Dropped messages are observable through `PHubServer::metrics()`
    /// instead of stderr: a push that violates the round protocol is
    /// counted, costs only itself, and the job keeps training.
    #[test]
    fn dropped_messages_are_counted_not_printed() {
        let server = PHubServer::start(ServerConfig::cores(1));
        let job = server.init_job(table(8, 8), &vec![0.0; 8], Arc::new(Sgd { lr: 1.0 }), 1);
        let mut h = server.worker(job, 0);
        let g: Arc<[f32]> = vec![1.0f32; 8].into();
        h.set_tag(0, 5); // run ahead of the barrier: a FutureRound violation
        h.push_chunk(0, g.clone(), false);
        h.set_tag(0, 0);
        h.push_chunk(0, g, true); // same ring: processed after the violation
        assert!(matches!(h.recv_reply(), Reply::Chunk { .. }));
        assert_eq!(server.metrics().dropped_messages.get(), 1);
        assert_eq!(server.metrics().dropped_quant_payloads.get(), 0);
        drop(h);
        PHubServer::shutdown(server);
    }

    /// Rollback control messages are counted per core.
    #[test]
    fn rollbacks_are_counted_per_core() {
        let server = PHubServer::start(ServerConfig::cores(2));
        let job = server.init_job(table(16, 8), &vec![0.0; 16], Arc::new(Sgd { lr: 1.0 }), 2);
        let mut h = server.worker(job, 0);
        server.rollback_round(job, 1);
        // Sync: the notice is delivered through the reply route, which
        // proves both cores processed the RollbackRound.
        assert!(matches!(h.recv_reply(), Reply::RolledBack { epoch: 1, .. }));
        let deadline = std::time::Instant::now() + std::time::Duration::from_secs(5);
        while server.metrics().rollbacks.get() < 2 {
            assert!(std::time::Instant::now() < deadline, "second core never rolled back");
            std::thread::yield_now();
        }
        drop(h);
        PHubServer::shutdown(server);
    }

    #[test]
    #[should_panic(expected = "worker handle already taken")]
    fn duplicate_worker_handle_rejected() {
        let server = PHubServer::start(ServerConfig::cores(1));
        let job = server.init_job(table(8, 8), &vec![0.0; 8], Arc::new(Sgd { lr: 1.0 }), 1);
        let _a = server.worker(job, 0);
        let _b = server.worker(job, 0);
    }

    /// The selected kernel tier and placement mode are recorded in the
    /// server's metrics at start, so tests and operators can assert
    /// which path actually ran.
    #[test]
    fn metrics_record_kernel_tier_and_placement() {
        use crate::coordinator::{kernels, mapping::PlacementMode};
        for mode in [PlacementMode::Affine, PlacementMode::Interleave] {
            let server = PHubServer::start(ServerConfig {
                n_cores: 2,
                placement: mode,
                quota: QuotaConfig::default(),
            });
            assert_eq!(
                server.metrics().kernel_tier.get(),
                kernels::active_tier() as u8
            );
            assert_eq!(server.metrics().placement_mode.get(), mode as u8);
            assert_eq!(
                PlacementMode::from_u8(server.metrics().placement_mode.get()),
                Some(mode)
            );
            PHubServer::shutdown(server);
        }
        // The env-reading constructor records *some* valid mode.
        let server = PHubServer::start(ServerConfig::cores(1));
        assert!(PlacementMode::from_u8(server.metrics().placement_mode.get()).is_some());
        assert!(kernels::KernelTier::from_u8(server.metrics().kernel_tier.get()).is_some());
        PHubServer::shutdown(server);
    }

    /// Placement changes locality, never results: the same multi-round
    /// job trains bit-identically under affine and interleave placement
    /// (a chunk is wholly owned by one core either way).
    #[test]
    fn placement_modes_train_bit_identically() {
        use crate::coordinator::mapping::PlacementMode;
        let n = 72usize; // 9 chunks of 8: ragged across 4 cores
        let rounds = 3;
        let grad = |w: usize, r: usize| -> Vec<f32> {
            (0..n)
                .map(|i| ((w + 1) as f32 * 1.7 + r as f32 * 0.3 + i as f32 * 0.011).sin())
                .collect()
        };
        let run = |mode: PlacementMode| -> Vec<u32> {
            let server = PHubServer::start(ServerConfig {
                n_cores: 4,
                placement: mode,
                quota: QuotaConfig::default(),
            });
            let init: Vec<f32> = (0..n).map(|i| (i as f32 * 0.05).cos()).collect();
            let opt = NesterovSgd {
                lr: 0.1,
                momentum: 0.9,
            };
            let job = server.init_job(table(n, 8), &init, Arc::new(opt), 2);
            let mut handles: Vec<_> = (0..2).map(|w| server.worker(job, w)).collect();
            let mut model = Vec::new();
            for r in 0..rounds {
                let (h0, h1) = handles.split_at_mut(1);
                let g1 = grad(1, r);
                let (m0, m1) = std::thread::scope(|s| {
                    let t = s.spawn(|| h1[0].push_pull(&g1));
                    let m0 = h0[0].push_pull(&grad(0, r));
                    (m0, t.join().unwrap())
                });
                assert_eq!(m0, m1, "round {r}");
                model = m0;
            }
            drop(handles);
            PHubServer::shutdown(server);
            model.iter().map(|x| x.to_bits()).collect()
        };
        assert_eq!(
            run(PlacementMode::Affine),
            run(PlacementMode::Interleave),
            "affine and interleave placement must train bit-identically"
        );
    }

    /// Affine placement really hands each core a contiguous extent: with
    /// uniform chunks over 2 cores, chunk ids in the low half land on one
    /// core and the high half on the other (observable through which
    /// reply rings carry which chunks — exercised indirectly here by the
    /// partition function the server calls).
    #[test]
    fn affine_extents_are_contiguous_for_flat_tables() {
        use crate::coordinator::mapping::PlacementMode;
        let t = table(64 * 8, 8);
        let lens: Vec<usize> = t.chunks.iter().map(|c| c.len).collect();
        let assign = PlacementMode::Affine.partition(&lens, 4);
        assert!(assign.windows(2).all(|p| p[0] <= p[1]), "{assign:?}");
        for core in 0..4 {
            assert_eq!(assign.iter().filter(|&&c| c == core).count(), 16);
        }
    }

    /// Deterministic fair-scheduler check: a port pre-loaded with more
    /// traffic than one sweep's deficit gets bounded service per sweep
    /// and the overflow is counted as a deferral — globally and against
    /// the owning job. The core loop is driven directly with hand-built
    /// rings so queue depth (and therefore deferral) is guaranteed.
    #[test]
    fn fair_sweep_defers_overflow_and_counts_it() {
        let metrics = Arc::new(DataPlaneMetrics::default());
        let jm = metrics.per_job.register(1);
        let waiter = Arc::new(ring::Waiter::new());
        let (ctrl_tx, ctrl_rx) = ring::spsc_shared(CTRL_RING_CAP, waiter.clone());
        let reply_waiter = Arc::new(ring::Waiter::new());
        let (reply_tx, reply_rx) = ring::spsc_shared(64, reply_waiter);
        let (port_tx, port_rx) = ring::spsc_shared(64, waiter.clone());

        // Queue the job install, a burst of 10 pulls, then the Connect —
        // all before the core thread starts, so service order and queue
        // depth at each sweep are deterministic.
        ctrl_tx
            .send(CoreMsg::InitJob {
                job: 1,
                chunks: vec![(0, vec![0.0; 4])],
                opt: Arc::new(Sgd { lr: 1.0 }),
                n_workers: 1,
                replies: vec![reply_tx],
                role: NodeRole::Root,
                uplink: None,
            })
            .map_err(|_| ())
            .unwrap();
        for _ in 0..10 {
            port_tx
                .send(CoreMsg::Pull {
                    job: 1,
                    chunk: 0,
                    worker: 0,
                })
                .map_err(|_| ())
                .unwrap();
        }
        ctrl_tx
            .send(CoreMsg::Connect {
                port: port_rx,
                job: 1,
                weight: 1,
            })
            .map_err(|_| ())
            .unwrap();
        drop(ctrl_tx);
        drop(port_tx);

        // quantum 2: the 10-deep burst needs ~5 sweeps, deferring in
        // each sweep that leaves traffic queued.
        let m = metrics.clone();
        let core = std::thread::spawn(move || core_loop(ctrl_rx, waiter, m, true, 2));
        let deadline = std::time::Instant::now() + std::time::Duration::from_secs(5);
        let mut got = 0;
        while got < 10 {
            assert!(
                std::time::Instant::now() < deadline,
                "replies missing: {got}/10"
            );
            match reply_rx.try_recv() {
                Ok(Reply::Chunk { .. }) => got += 1,
                Ok(other) => panic!("unexpected reply {other:?}"),
                Err(_) => std::thread::yield_now(),
            }
        }
        core.join().unwrap();
        assert!(
            metrics.sched_deferrals.get() >= 1,
            "burst past the deficit must count a deferral"
        );
        assert!(
            jm.deferrals.get() >= 1,
            "deferral must be attributed to the owning job"
        );
    }

    /// Parameter handoff through the public server API: export an idle
    /// job, evict it, readmit it with `init_job_resumed`, and the
    /// continued training is bit-identical to a twin that never paused.
    #[test]
    fn export_then_resume_is_bit_identical_across_eviction() {
        let n = 24usize;
        let opt = || {
            Arc::new(NesterovSgd {
                lr: 0.2,
                momentum: 0.9,
            })
        };
        let grad = |r: usize| -> Vec<f32> {
            (0..n)
                .map(|i| (r as f32 * 1.3 + i as f32 * 0.07).sin())
                .collect()
        };
        let server = PHubServer::start(ServerConfig::cores(2));
        let job = server.init_job(table(n, 8), &vec![0.5; n], opt(), 1);
        let mut h = server.worker(job, 0);
        for r in 0..2 {
            h.push_pull(&grad(r));
        }
        drop(h);
        let states = server.export_job(job);
        assert_eq!(states.len(), 3);
        assert!(states.windows(2).all(|w| w[0].chunk < w[1].chunk));
        assert!(states.iter().all(|c| c.round == 2));
        assert!(server.export_job(9999).is_empty());
        server.evict(job);

        let resumed = server.init_job_resumed(table(n, 8), states, opt(), 1, 1);
        let mut hr = server.worker(resumed, 0);
        hr.set_tag(0, 2); // the handoff resumes at round 2
        let twin = server.init_job(table(n, 8), &vec![0.5; n], opt(), 1);
        let mut ht = server.worker(twin, 0);
        for r in 0..2 {
            ht.push_pull(&grad(r));
        }
        let a = hr.push_pull(&grad(2));
        let b = ht.push_pull(&grad(2));
        assert_eq!(a, b, "resumed job must continue bit-identically");
        drop(hr);
        drop(ht);
        PHubServer::shutdown(server);
    }
}
