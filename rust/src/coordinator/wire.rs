//! Wire protocol for the distributed PHub transport.
//!
//! Length-prefixed binary frames over TCP (the environment has no RDMA;
//! `transport.rs` notes what the verbs path would change). Framing keeps
//! PHub's "minimal metadata" spirit (section 3.2.1): a fixed 16-byte
//! header — opcode, job, chunk, worker — plus the raw little-endian f32
//! payload; no per-message serialization framework.

use std::io::{Read, Write};

/// Message opcodes.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
#[repr(u8)]
pub enum Op {
    /// Worker -> server: create+join a job (payload: model elems u64,
    /// chunk elems u64, n_workers u32, lr f32, momentum f32).
    Hello = 1,
    /// Server -> worker: admission (payload: worker slot u32).
    Welcome = 2,
    /// Worker -> server: gradient push for the whole flat model
    /// (payload: f32s); implies pull.
    PushPull = 3,
    /// Server -> worker: updated model (payload: f32s).
    Model = 4,
    /// Worker -> server: 2-bit compressed push (payload: packed levels +
    /// f32 threshold; see `compress.rs`).
    PushPullQuant = 5,
    /// Either direction: orderly shutdown.
    Bye = 6,
}

impl Op {
    pub fn from_u8(v: u8) -> Option<Op> {
        Some(match v {
            1 => Op::Hello,
            2 => Op::Welcome,
            3 => Op::PushPull,
            4 => Op::Model,
            5 => Op::PushPullQuant,
            6 => Op::Bye,
            _ => return None,
        })
    }
}

/// A decoded frame.
#[derive(Debug, Clone, PartialEq)]
pub struct Frame {
    pub op: Op,
    pub job: u32,
    pub worker: u32,
    pub payload: Vec<u8>,
}

/// Header layout: [len u32][op u8][pad u8;3][job u32][worker u32].
pub const HEADER_BYTES: usize = 16;

/// Encode a frame into a byte vector (length prefix covers the rest).
pub fn encode(f: &Frame) -> Vec<u8> {
    let body_len = HEADER_BYTES - 4 + f.payload.len();
    let mut out = Vec::with_capacity(4 + body_len);
    out.extend_from_slice(&(body_len as u32).to_le_bytes());
    out.push(f.op as u8);
    out.extend_from_slice(&[0u8; 3]);
    out.extend_from_slice(&f.job.to_le_bytes());
    out.extend_from_slice(&f.worker.to_le_bytes());
    out.extend_from_slice(&f.payload);
    out
}

/// Write a frame to a stream.
pub fn write_frame(w: &mut impl Write, f: &Frame) -> std::io::Result<()> {
    w.write_all(&encode(f))?;
    w.flush()
}

/// Read one frame from a stream.
pub fn read_frame(r: &mut impl Read) -> std::io::Result<Frame> {
    let mut len4 = [0u8; 4];
    r.read_exact(&mut len4)?;
    let body_len = u32::from_le_bytes(len4) as usize;
    if body_len < HEADER_BYTES - 4 {
        return Err(std::io::Error::new(
            std::io::ErrorKind::InvalidData,
            "frame too short",
        ));
    }
    let mut body = vec![0u8; body_len];
    r.read_exact(&mut body)?;
    let op = Op::from_u8(body[0]).ok_or_else(|| {
        std::io::Error::new(std::io::ErrorKind::InvalidData, "bad opcode")
    })?;
    let job = u32::from_le_bytes(body[4..8].try_into().unwrap());
    let worker = u32::from_le_bytes(body[8..12].try_into().unwrap());
    Ok(Frame {
        op,
        job,
        worker,
        payload: body[12..].to_vec(),
    })
}

/// f32 slice -> raw little-endian bytes.
pub fn f32s_to_bytes(v: &[f32]) -> Vec<u8> {
    let mut out = Vec::with_capacity(v.len() * 4);
    for x in v {
        out.extend_from_slice(&x.to_le_bytes());
    }
    out
}

/// Raw little-endian bytes -> f32 vector.
pub fn bytes_to_f32s(b: &[u8]) -> std::io::Result<Vec<f32>> {
    if b.len() % 4 != 0 {
        return Err(std::io::Error::new(
            std::io::ErrorKind::InvalidData,
            "payload not f32-aligned",
        ));
    }
    Ok(b.chunks_exact(4)
        .map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]]))
        .collect())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn frame_roundtrip() {
        let f = Frame {
            op: Op::PushPull,
            job: 7,
            worker: 3,
            payload: f32s_to_bytes(&[1.0, -2.5, 3.25]),
        };
        let bytes = encode(&f);
        let mut cursor = std::io::Cursor::new(bytes);
        let g = read_frame(&mut cursor).unwrap();
        assert_eq!(f, g);
        assert_eq!(bytes_to_f32s(&g.payload).unwrap(), vec![1.0, -2.5, 3.25]);
    }

    #[test]
    fn empty_payload_roundtrip() {
        let f = Frame {
            op: Op::Bye,
            job: 0,
            worker: 0,
            payload: vec![],
        };
        let mut cursor = std::io::Cursor::new(encode(&f));
        assert_eq!(read_frame(&mut cursor).unwrap(), f);
    }

    #[test]
    fn bad_opcode_rejected() {
        let mut bytes = encode(&Frame {
            op: Op::Hello,
            job: 1,
            worker: 0,
            payload: vec![],
        });
        bytes[4] = 99; // clobber opcode
        let mut cursor = std::io::Cursor::new(bytes);
        assert!(read_frame(&mut cursor).is_err());
    }

    #[test]
    fn truncated_frame_rejected() {
        let bytes = encode(&Frame {
            op: Op::Model,
            job: 1,
            worker: 0,
            payload: vec![1, 2, 3, 4],
        });
        let mut cursor = std::io::Cursor::new(&bytes[..bytes.len() - 2]);
        assert!(read_frame(&mut cursor).is_err());
    }

    #[test]
    fn misaligned_f32_payload_rejected() {
        assert!(bytes_to_f32s(&[1, 2, 3]).is_err());
    }

    #[test]
    fn header_size_is_fixed() {
        let f = Frame {
            op: Op::Welcome,
            job: 9,
            worker: 2,
            payload: vec![0; 10],
        };
        assert_eq!(encode(&f).len(), 4 + (HEADER_BYTES - 4) + 10);
    }
}
