//! Wire protocol for the distributed PHub transport.
//!
//! Length-prefixed binary frames over TCP (the environment has no RDMA;
//! `transport.rs` notes what the verbs path would change). Framing keeps
//! PHub's "minimal metadata" spirit (section 3.2.1): a fixed 16-byte
//! header plus a raw little-endian payload; no per-message serialization
//! framework.
//!
//! # Frame layout
//!
//! ```text
//! offset  size  field
//!      0     4  len     u32 LE — byte length of everything after this field
//!      4     1  op      opcode (see [`Op`])
//!      5     3  pad     zero
//!      8     4  job     u32 LE — wire job id (tenant namespace)
//!     12     4  worker  u32 LE — worker slot (0 before admission)
//!     16   len-12       payload (opcode-specific)
//! ```
//!
//! # Opcodes
//!
//! | op | name            | dir | payload |
//! |----|-----------------|-----|---------|
//! | 1  | `Hello`         | W→L | [`super::transport::JobSpec`] (28 B) + proposed protocol version u32 + optional aggregation weight u32 |
//! | 2  | `Welcome`       | L→W | worker slot u32 + round epoch u32 + rounds-done u64 + accepted protocol version u32 |
//! | 3–5| *retired*       |     | v0 monolithic `PushPull`/`Model`/`PushPullQuant`; never reassigned |
//! | 6  | `Bye`           | any | empty — orderly shutdown |
//! | 7  | `PushChunk`     | W→L | chunk header + chunk gradient LE f32s |
//! | 8  | `ModelChunk`    | L→W | chunk header + chunk params LE f32s |
//! | 9  | `PushChunkQuant`| W→L | chunk header + per-chunk `QuantGrad` |
//! | 10 | `RollbackRound` | L→W | round epoch u32 — rewind + replay the open round |
//! | 11 | `ResidualSave`  | W→L | chunk header + threshold f32 + residual LE f32s — checkpoint one chunk's error-feedback residual |
//! | 12 | `ResidualChunk` | L→W | same layout — restore a checkpointed residual to a successor at admission |
//! | 13 | `Refused`       | L→W | reason code u16 + retry-after hint u32 (ms) — graceful, retriable admission refusal |
//!
//! "W→L" reads "downstream peer → upstream peer": the hierarchical
//! deployment (paper §3.4, Fig. 19) runs the *same* opcodes on the
//! relay→root uplink, where the rack relay plays the worker role. The
//! only uplink-specific bit is the optional `Hello` **weight trailer**
//! ([`push_weight`] / [`weight_at`], u32 LE after the version trailer): a
//! relay admits itself with weight = its rack's worker count, so the root
//! divides its cross-rack sum by total leaf workers and the two-level
//! mean is exactly the flat mean. A plain worker omits the trailer and
//! defaults to weight 1 — flat deployments are byte-identical to v2.
//!
//! Chunk-carrying payloads start with a 16-byte chunk header
//! ([`CHUNK_PREFIX_BYTES`]): `[chunk u32 LE][epoch u32 LE][elem offset
//! u64 LE]`, where `offset` is the chunk's first element in the flat
//! model and `epoch` is the job's **round epoch** — the rollback
//! generation of the round state machine (see `engine.rs`). The receiver
//! validates chunk id and offset against its own key table, so a
//! corrupted or hostile frame can only kill its own connection.
//!
//! # Memory discipline
//!
//! The steady-state round must not allocate or copy per frame beyond the
//! single receive itself (the pipeline is memory-bandwidth-bound; paper
//! §4.3). Buffer ownership on the hot path:
//!
//! * **Receive**: [`read_frame_into`] decodes the 12-byte frame header
//!   *in place* (a stack array, no body `Vec` to re-slice) and reads the
//!   payload into a caller-owned buffer, returning a borrowed
//!   [`FrameView`]. The leader passes buffers from a recycling
//!   [`super::pool::BytePool`]; the payload then travels to the owning
//!   core *in that buffer*, is absorbed directly as bytes
//!   (`aggregation::absorb_bytes` — no `bytes_to_f32s` vector), and the
//!   buffer returns to the pool on drop. Growth is receive-driven
//!   (`read_to_end` after a bounds check on the attacker-controlled
//!   length prefix), so a claimed-huge frame still cannot
//!   allocation-bomb the receiver, and after one warm round the buffer
//!   sits at its high-water capacity: zero allocations per frame.
//! * **Transmit**: [`write_chunk_frame_f32s`] serializes a chunk frame
//!   straight from an `f32` slice through a small stack staging array —
//!   the `f32s_to_bytes` intermediate vector is gone from the round
//!   path. On the leader that slice is the refcount-shared broadcast
//!   buffer (`pool::SharedF32`): the core copies the post-optimize
//!   parameters once, every puller's connection serializes out of the
//!   same buffer, and the last drop recycles it. Quantized payloads are
//!   written from the client's cached round buffers via
//!   [`write_chunk_frame_buffered`].
//! * **Relay uplink** (hierarchical deployments): the rack relay's sum
//!   frames serialize with the same [`write_chunk_frame_f32s`] straight
//!   from the relay's per-chunk replay cache (reused `Vec<f32>`s the
//!   engine's pooled `Reply::Sum` buffers are copied into once, then
//!   recycled), and the root's returned `ModelChunk` payloads ride the
//!   relay's pooled receive buffers all the way to the owning core's
//!   parameter install — both directions allocation- and mutex-free
//!   once warm, same as the leaf legs.
//!
//! Copies per chunk per round, before → after this lineage of changes:
//! leader receive went from 3 payload copies and ~5 allocations (body
//! `Vec`, payload re-slice, `bytes_to_f32s`, `Arc` gradient, reply
//! `f32s_to_bytes`) to 1 copy (the socket read) and 0 allocations; the
//! reply leg went from 1 parameter copy *per puller* on the core to 1
//! copy total, shared by refcount. With the queue hops on lock-free
//! SPSC rings (`ring.rs`) the whole leader round is exact-zero: no heap
//! allocation, no mutex acquisition, asserted with no exclusions by
//! `rust/tests/alloc_discipline.rs`. [`read_frame`] / [`encode`] remain
//! for rendezvous/control frames and tests.
//!
//! # The round epoch
//!
//! A worker learns its job's epoch from `Welcome` and stamps it into
//! every chunk frame it pushes. When a worker dies mid-round the leader
//! bumps the epoch, rewinds the partially aggregated chunks, and sends
//! `RollbackRound` (carrying the new epoch) to the surviving workers;
//! each one re-sends its round's chunk frames — byte-identical payloads,
//! new epoch — so the replayed round produces exactly the parameters the
//! uninterrupted round would have. A push frame that was already in
//! flight with the old epoch is *rejected by tag* (silently dropped, the
//! sender replays anyway) rather than corrupting the fresh round or
//! panicking a core.
//!
//! # Version negotiation
//!
//! The protocol version rides on the rendezvous, so an incompatible peer
//! fails loudly at `Hello` instead of misparsing frames mid-training:
//!
//! * v0 `PROTO_MONOLITHIC` — **retired**. One whole-gradient frame up,
//!   one whole-model frame back per round, fully serializing network and
//!   compute. It was kept for one release after v1 shipped; a v0 `Hello`
//!   (or one with no version trailer) is rejected with a clear error.
//! * v1 `PROTO_CHUNK_STREAMED` — **retired**. The first chunk-streamed
//!   framing, before rounds carried epochs. The epoch field changed the
//!   chunk prefix and the `Welcome` payload incompatibly, so v1 peers
//!   are rejected at rendezvous rather than served bytes they would
//!   misparse.
//! * v2 [`PROTO_EPOCH_TAGGED`] — the paper's data plane shape (§3.2)
//!   plus recovery: the worker writes all `PushChunk` frames
//!   back-to-back; the leader routes each one to its pinned core as it
//!   arrives and returns `ModelChunk` frames per chunk as
//!   aggregation+optimization complete, so a fast chunk's parameters are
//!   on the wire while later chunks are still aggregating. Every chunk
//!   frame carries the round epoch, and `RollbackRound` rewinds/replays
//!   an interrupted round.
//!
//! A worker appends its highest supported version to `Hello`; the leader
//! answers with `min(leader_max, proposed)` in `Welcome` — and drops the
//! connection when that minimum falls below [`PROTO_MIN`].

use std::io::{Read, Write};

use super::aggregation;

/// Legacy whole-model protocol — retired; the leader rejects it at
/// rendezvous. The constant remains so rejection tests and error messages
/// can name it.
pub const PROTO_MONOLITHIC: u32 = 0;
/// First-generation chunk streaming — retired. The epoch-tagged framing
/// changed the chunk prefix (12 → 16 bytes) and the `Welcome` payload
/// incompatibly, so a v1 peer must be rejected at rendezvous rather than
/// silently served frames it would misparse.
pub const PROTO_CHUNK_STREAMED: u32 = 1;
/// Epoch-tagged chunk streaming: per-chunk frames carrying the round
/// epoch, mid-round rollback/replay via `RollbackRound`, and successor
/// resume info (epoch + rounds done) in `Welcome`.
pub const PROTO_EPOCH_TAGGED: u32 = 2;
/// Oldest version this build still serves.
pub const PROTO_MIN: u32 = PROTO_EPOCH_TAGGED;
/// Highest version this build speaks.
pub const PROTO_MAX: u32 = PROTO_EPOCH_TAGGED;

/// Message opcodes. Values 3–5 belonged to the retired v0 monolithic
/// exchange and are never reassigned.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
#[repr(u8)]
pub enum Op {
    /// Worker -> server: create+join a job (payload: model elems u64,
    /// chunk elems u64, n_workers u32, lr f32, momentum f32, then the
    /// proposed protocol version u32).
    Hello = 1,
    /// Server -> worker: admission (payload: worker slot u32, round epoch
    /// u32, completed rounds of the slot u64, accepted protocol version
    /// u32 — the round count is how a successor learns where its crashed
    /// predecessor left off).
    Welcome = 2,
    /// Either direction: orderly shutdown.
    Bye = 6,
    /// Worker -> server: gradient push for one chunk (payload: chunk
    /// header + f32s); implies pull of that chunk.
    PushChunk = 7,
    /// Server -> worker: updated params for one chunk (payload: chunk
    /// header + f32s).
    ModelChunk = 8,
    /// Worker -> server: 2-bit compressed push for one chunk (payload:
    /// chunk header + `QuantGrad` bytes).
    PushChunkQuant = 9,
    /// Server -> worker: the open round was rewound (payload: new round
    /// epoch u32); re-send the round's chunk frames under that epoch.
    RollbackRound = 10,
    /// Worker -> server: checkpoint one chunk's quantizer error-feedback
    /// residual at a round boundary (payload: chunk header + threshold
    /// f32 + residual LE f32s). The leader stores the bytes per slot so
    /// a successor resumes bit-exact from *any* death round.
    ResidualSave = 11,
    /// Server -> worker: restore a checkpointed residual to a successor
    /// at admission (same payload layout as `ResidualSave`).
    ResidualChunk = 12,
    /// Server -> worker: the `Hello` was refused by admission control
    /// (payload: reason code u16 LE + retry-after hint u32 LE, in
    /// milliseconds). Sent *instead of* `Welcome`, then the leader
    /// closes the connection. Every refusal is retriable: the condition
    /// (job cap, quota, overload shed) is expected to clear, and the
    /// hint tells the client how long to back off before retrying. See
    /// `coordinator::admission` for the reason-code registry.
    Refused = 13,
}

impl Op {
    pub fn from_u8(v: u8) -> Option<Op> {
        Some(match v {
            1 => Op::Hello,
            2 => Op::Welcome,
            6 => Op::Bye,
            7 => Op::PushChunk,
            8 => Op::ModelChunk,
            9 => Op::PushChunkQuant,
            10 => Op::RollbackRound,
            11 => Op::ResidualSave,
            12 => Op::ResidualChunk,
            13 => Op::Refused,
            _ => return None,
        })
    }
}

/// Typed classification of connection-plane I/O failures, embedded as
/// the inner error of the `std::io::Error`s this module returns so
/// callers can branch on failure shape without string matching:
/// `WireError::classify(&err)` recovers it from any I/O error.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum WireError {
    /// A read or write deadline fired (`WouldBlock` / `TimedOut`).
    Timeout,
    /// The peer went away cleanly at a frame boundary (0 bytes of the
    /// next frame had arrived).
    Disconnected,
    /// The stream ended mid-frame: a torn length prefix, header, or
    /// payload. Carries which part was cut short.
    Torn(&'static str),
    /// The bytes arrived but violate the protocol (bad opcode, absurd
    /// length, short chunk payload).
    Protocol(&'static str),
}

impl std::fmt::Display for WireError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            WireError::Timeout => write!(f, "wire timeout: deadline fired"),
            WireError::Disconnected => write!(f, "peer disconnected at frame boundary"),
            WireError::Torn(what) => write!(f, "torn frame: truncated {what}"),
            WireError::Protocol(what) => write!(f, "protocol violation: {what}"),
        }
    }
}

impl std::error::Error for WireError {}

impl WireError {
    fn io(self, kind: std::io::ErrorKind) -> std::io::Error {
        std::io::Error::new(kind, self)
    }

    /// Recover the typed classification from any I/O error: the embedded
    /// [`WireError`] when this module produced it, otherwise inferred
    /// from the error kind (timeouts from the socket layer arrive as
    /// `WouldBlock`/`TimedOut` without an inner payload).
    pub fn classify(e: &std::io::Error) -> WireError {
        if is_timeout(e) {
            return WireError::Timeout;
        }
        if let Some(inner) = e.get_ref().and_then(|i| i.downcast_ref::<WireError>()) {
            return *inner;
        }
        match e.kind() {
            std::io::ErrorKind::UnexpectedEof => WireError::Torn("stream"),
            _ => WireError::Disconnected,
        }
    }
}

/// True when an I/O error is a socket deadline firing. Platforms
/// disagree on the kind (`WouldBlock` on Unix, `TimedOut` elsewhere),
/// so both are accepted.
pub fn is_timeout(e: &std::io::Error) -> bool {
    matches!(
        e.kind(),
        std::io::ErrorKind::WouldBlock | std::io::ErrorKind::TimedOut
    )
}

/// A decoded frame (owning form — rendezvous/control paths and tests;
/// the streamed hot path borrows a [`FrameView`] instead).
#[derive(Debug, Clone, PartialEq)]
pub struct Frame {
    pub op: Op,
    pub job: u32,
    pub worker: u32,
    pub payload: Vec<u8>,
}

/// A decoded frame borrowing its payload from the caller's (pooled,
/// reused) receive buffer — the zero-copy result of [`read_frame_into`].
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct FrameView<'a> {
    pub op: Op,
    pub job: u32,
    pub worker: u32,
    pub payload: &'a [u8],
}

/// Header layout: [len u32][op u8][pad u8;3][job u32][worker u32].
pub const HEADER_BYTES: usize = 16;

/// Byte length of the chunk header prefixing chunk-carrying payloads:
/// `[chunk u32][epoch u32][elem offset u64]`.
pub const CHUNK_PREFIX_BYTES: usize = 16;

/// Largest frame body [`read_frame`] accepts: a single-chunk job at the
/// transport's `MAX_MODEL_ELEMS` (2^28 f32s = 1 GiB) plus slack. The
/// length prefix is attacker-controlled, so it must never be trusted for
/// allocation beyond this bound.
pub const MAX_FRAME_BYTES: usize = (1 << 30) + 1024;

/// Encode a frame into a byte vector (length prefix covers the rest).
pub fn encode(f: &Frame) -> Vec<u8> {
    let body_len = HEADER_BYTES - 4 + f.payload.len();
    let mut out = Vec::with_capacity(4 + body_len);
    out.extend_from_slice(&(body_len as u32).to_le_bytes());
    out.push(f.op as u8);
    out.extend_from_slice(&[0u8; 3]);
    out.extend_from_slice(&f.job.to_le_bytes());
    out.extend_from_slice(&f.worker.to_le_bytes());
    out.extend_from_slice(&f.payload);
    out
}

/// Write a frame to a stream and flush it.
pub fn write_frame(w: &mut impl Write, f: &Frame) -> std::io::Result<()> {
    w.write_all(&encode(f))?;
    w.flush()
}

/// Read one frame into `payload` (cleared first; capacity reused across
/// calls), returning a borrowed [`FrameView`]. This is the streamed hot
/// path: the 12-byte frame header is decoded in place from a stack
/// array — no body buffer to re-slice — and once `payload`'s capacity
/// reaches its high-water mark the call performs zero allocations.
///
/// Hostile-input contract: the length prefix is bounded by
/// [`MAX_FRAME_BYTES`], and the payload buffer grows with bytes actually
/// received (`read_to_end`) rather than being pre-allocated from the
/// prefix — a peer that *claims* a huge frame without sending it cannot
/// make the receiver allocate it (no allocation-bomb `Hello`s).
///
/// Torn-input contract: EOF at any byte offset returns a clean typed
/// error immediately — never a hang, never a panic. The inner error is a
/// [`WireError`] distinguishing a clean frame-boundary disconnect (0
/// bytes of the next frame arrived → [`WireError::Disconnected`]) from a
/// mid-frame cut ([`WireError::Torn`], naming the truncated part), so a
/// supervisor can tell "peer left" from "peer died mid-write".
pub fn read_frame_into<'a>(
    r: &mut impl Read,
    payload: &'a mut Vec<u8>,
) -> std::io::Result<FrameView<'a>> {
    // The length prefix is read with a manual loop so 0 bytes (clean
    // boundary disconnect) and 1–3 bytes (torn prefix) classify
    // differently; `read_exact` collapses both into one error.
    let mut len4 = [0u8; 4];
    let mut got = 0usize;
    while got < 4 {
        match r.read(&mut len4[got..]) {
            Ok(0) => {
                return Err(if got == 0 {
                    WireError::Disconnected.io(std::io::ErrorKind::UnexpectedEof)
                } else {
                    WireError::Torn("length prefix").io(std::io::ErrorKind::UnexpectedEof)
                });
            }
            Ok(n) => got += n,
            Err(e) if e.kind() == std::io::ErrorKind::Interrupted => {}
            Err(e) => return Err(e),
        }
    }
    let body_len = u32::from_le_bytes(len4) as usize;
    if body_len < HEADER_BYTES - 4 {
        return Err(WireError::Protocol("frame too short").io(std::io::ErrorKind::InvalidData));
    }
    if body_len > MAX_FRAME_BYTES {
        return Err(
            WireError::Protocol("frame exceeds MAX_FRAME_BYTES").io(std::io::ErrorKind::InvalidData)
        );
    }
    let mut head = [0u8; HEADER_BYTES - 4];
    r.read_exact(&mut head)
        .map_err(|e| match e.kind() {
            std::io::ErrorKind::UnexpectedEof => {
                WireError::Torn("frame header").io(std::io::ErrorKind::UnexpectedEof)
            }
            _ => e,
        })?;
    let op = Op::from_u8(head[0])
        .ok_or_else(|| WireError::Protocol("bad opcode").io(std::io::ErrorKind::InvalidData))?;
    let job = u32::from_le_bytes(head[4..8].try_into().unwrap());
    let worker = u32::from_le_bytes(head[8..12].try_into().unwrap());
    let want = body_len - (HEADER_BYTES - 4);
    payload.clear();
    let got = r.take(want as u64).read_to_end(payload)?;
    if got != want {
        return Err(WireError::Torn("frame payload").io(std::io::ErrorKind::UnexpectedEof));
    }
    Ok(FrameView {
        op,
        job,
        worker,
        payload,
    })
}

/// Read one frame from a stream into an owning [`Frame`] (one payload
/// allocation, no second copy — the header decodes from the stack via
/// [`read_frame_into`]).
pub fn read_frame(r: &mut impl Read) -> std::io::Result<Frame> {
    let mut payload = Vec::new();
    let (op, job, worker) = {
        let v = read_frame_into(r, &mut payload)?;
        (v.op, v.job, v.worker)
    };
    Ok(Frame {
        op,
        job,
        worker,
        payload,
    })
}

/// Write a chunk-carrying frame straight to a (buffered) writer — header,
/// chunk prefix, and raw payload bytes with no intermediate payload/frame
/// buffers. This is the streamed hot path for byte payloads (quantized
/// pushes, cached replays): one call per chunk per round, so the copies
/// [`encode`] would make are worth skipping. No flush.
#[allow(clippy::too_many_arguments)]
pub fn write_chunk_frame_buffered(
    w: &mut impl Write,
    op: Op,
    job: u32,
    worker: u32,
    chunk: u32,
    epoch: u32,
    elem_offset: u64,
    bytes: &[u8],
) -> std::io::Result<()> {
    let body_len = HEADER_BYTES - 4 + CHUNK_PREFIX_BYTES + bytes.len();
    w.write_all(&(body_len as u32).to_le_bytes())?;
    w.write_all(&[op as u8, 0, 0, 0])?;
    w.write_all(&job.to_le_bytes())?;
    w.write_all(&worker.to_le_bytes())?;
    w.write_all(&chunk.to_le_bytes())?;
    w.write_all(&epoch.to_le_bytes())?;
    w.write_all(&elem_offset.to_le_bytes())?;
    w.write_all(bytes)
}

/// [`write_chunk_frame_buffered`] for f32 payloads: serialize the frame
/// straight from the f32 slice (a gradient range or a chunk slot's
/// parameters) through a stack staging array — no `f32s_to_bytes`
/// vector, zero allocations. No flush.
#[allow(clippy::too_many_arguments)]
pub fn write_chunk_frame_f32s(
    w: &mut impl Write,
    op: Op,
    job: u32,
    worker: u32,
    chunk: u32,
    epoch: u32,
    elem_offset: u64,
    data: &[f32],
) -> std::io::Result<()> {
    let body_len = HEADER_BYTES - 4 + CHUNK_PREFIX_BYTES + data.len() * 4;
    w.write_all(&(body_len as u32).to_le_bytes())?;
    w.write_all(&[op as u8, 0, 0, 0])?;
    w.write_all(&job.to_le_bytes())?;
    w.write_all(&worker.to_le_bytes())?;
    w.write_all(&chunk.to_le_bytes())?;
    w.write_all(&epoch.to_le_bytes())?;
    w.write_all(&elem_offset.to_le_bytes())?;
    const GROUP: usize = 64;
    let mut stage = [0u8; GROUP * 4];
    for group in data.chunks(GROUP) {
        let mut n = 0;
        for x in group {
            stage[n..n + 4].copy_from_slice(&x.to_le_bytes());
            n += 4;
        }
        w.write_all(&stage[..n])?;
    }
    Ok(())
}

/// Write a residual-checkpoint frame (`ResidualSave` / `ResidualChunk`):
/// a chunk frame whose payload is `[threshold f32][residual LE f32s]`.
/// Same stack-staged serialization as [`write_chunk_frame_f32s`] — the
/// per-round-boundary checkpoint leg stays allocation-free. No flush.
#[allow(clippy::too_many_arguments)]
pub fn write_residual_frame(
    w: &mut impl Write,
    op: Op,
    job: u32,
    worker: u32,
    chunk: u32,
    epoch: u32,
    elem_offset: u64,
    threshold: f32,
    residual: &[f32],
) -> std::io::Result<()> {
    let body_len = HEADER_BYTES - 4 + CHUNK_PREFIX_BYTES + 4 + residual.len() * 4;
    w.write_all(&(body_len as u32).to_le_bytes())?;
    w.write_all(&[op as u8, 0, 0, 0])?;
    w.write_all(&job.to_le_bytes())?;
    w.write_all(&worker.to_le_bytes())?;
    w.write_all(&chunk.to_le_bytes())?;
    w.write_all(&epoch.to_le_bytes())?;
    w.write_all(&elem_offset.to_le_bytes())?;
    w.write_all(&threshold.to_le_bytes())?;
    const GROUP: usize = 64;
    let mut stage = [0u8; GROUP * 4];
    for group in residual.chunks(GROUP) {
        let mut n = 0;
        for x in group {
            stage[n..n + 4].copy_from_slice(&x.to_le_bytes());
            n += 4;
        }
        w.write_all(&stage[..n])?;
    }
    Ok(())
}

/// Split a residual payload (the bytes after the chunk prefix) into
/// `(threshold, residual LE f32 bytes)`. The f32 bytes must be
/// 4-aligned; decode them with [`copy_f32s_from_le`] / [`bytes_to_f32s`].
pub fn split_residual_payload(bytes: &[u8]) -> std::io::Result<(f32, &[u8])> {
    if bytes.len() < 4 || (bytes.len() - 4) % 4 != 0 {
        return Err(WireError::Protocol("bad residual payload").io(std::io::ErrorKind::InvalidData));
    }
    let threshold = f32::from_le_bytes(bytes[0..4].try_into().unwrap());
    Ok((threshold, &bytes[4..]))
}

/// Build a chunk-carrying payload:
/// `[chunk u32][epoch u32][elem offset u64][bytes]`.
pub fn encode_chunk_payload(chunk: u32, epoch: u32, elem_offset: u64, bytes: &[u8]) -> Vec<u8> {
    let mut out = Vec::with_capacity(CHUNK_PREFIX_BYTES + bytes.len());
    out.extend_from_slice(&chunk.to_le_bytes());
    out.extend_from_slice(&epoch.to_le_bytes());
    out.extend_from_slice(&elem_offset.to_le_bytes());
    out.extend_from_slice(bytes);
    out
}

/// Split a chunk-carrying payload into `(chunk, epoch, elem offset, bytes)`.
pub fn decode_chunk_payload(payload: &[u8]) -> std::io::Result<(u32, u32, u64, &[u8])> {
    if payload.len() < CHUNK_PREFIX_BYTES {
        return Err(std::io::Error::new(
            std::io::ErrorKind::InvalidData,
            "chunk payload too short",
        ));
    }
    let chunk = u32::from_le_bytes(payload[0..4].try_into().unwrap());
    let epoch = u32::from_le_bytes(payload[4..8].try_into().unwrap());
    let offset = u64::from_le_bytes(payload[8..16].try_into().unwrap());
    Ok((chunk, epoch, offset, &payload[CHUNK_PREFIX_BYTES..]))
}

/// Append the proposed/accepted protocol version to a rendezvous payload.
pub fn push_proto_version(payload: &mut Vec<u8>, proto: u32) {
    payload.extend_from_slice(&proto.to_le_bytes());
}

/// Read the protocol version trailer at `at..at+4`, or [`PROTO_MONOLITHIC`]
/// if the peer predates version negotiation and sent a shorter payload
/// (the leader then rejects it: v0 is retired).
pub fn proto_version_at(payload: &[u8], at: usize) -> u32 {
    match payload.get(at..at + 4) {
        Some(b) => u32::from_le_bytes(b.try_into().unwrap()),
        None => PROTO_MONOLITHIC,
    }
}

/// Append the aggregation-weight trailer to a `Hello` payload (after the
/// version trailer). A rack relay admits itself upstream with weight =
/// its rack's worker count, so the root's mean divides by total *leaf*
/// workers and a two-level run reproduces the flat mean exactly.
pub fn push_weight(payload: &mut Vec<u8>, weight: u32) {
    payload.extend_from_slice(&weight.to_le_bytes());
}

/// Read the aggregation-weight trailer at `at..at+4`, defaulting to 1
/// when absent — plain workers don't send it, and weight 1 is exactly
/// the flat-deployment behavior.
pub fn weight_at(payload: &[u8], at: usize) -> u32 {
    match payload.get(at..at + 4) {
        Some(b) => u32::from_le_bytes(b.try_into().unwrap()),
        None => 1,
    }
}

/// Build an [`Op::Refused`] payload: `[reason u16 LE][retry_after_ms
/// u32 LE]`. The reason codes are registered in
/// `coordinator::admission::RefuseReason`; the wire layer only moves
/// the integers so the registry can grow without a framing change.
pub fn encode_refusal(reason: u16, retry_after_ms: u32) -> Vec<u8> {
    let mut out = Vec::with_capacity(6);
    out.extend_from_slice(&reason.to_le_bytes());
    out.extend_from_slice(&retry_after_ms.to_le_bytes());
    out
}

/// Split an [`Op::Refused`] payload into `(reason, retry_after_ms)`.
pub fn decode_refusal(payload: &[u8]) -> std::io::Result<(u16, u32)> {
    if payload.len() < 6 {
        return Err(WireError::Protocol("short refusal payload").io(std::io::ErrorKind::InvalidData));
    }
    let reason = u16::from_le_bytes(payload[0..2].try_into().unwrap());
    let retry = u32::from_le_bytes(payload[2..6].try_into().unwrap());
    Ok((reason, retry))
}

/// f32 slice -> raw little-endian bytes (allocating; tests/cold paths —
/// the round path writes frames with [`write_chunk_frame_f32s`]).
pub fn f32s_to_bytes(v: &[f32]) -> Vec<u8> {
    let mut out = Vec::with_capacity(v.len() * 4);
    for x in v {
        out.extend_from_slice(&x.to_le_bytes());
    }
    out
}

/// Raw little-endian bytes -> f32 vector (allocating; tests/cold paths —
/// the round path decodes in place with [`copy_f32s_from_le`] or absorbs
/// bytes directly server-side).
pub fn bytes_to_f32s(b: &[u8]) -> std::io::Result<Vec<f32>> {
    if b.len() % 4 != 0 {
        return Err(std::io::Error::new(
            std::io::ErrorKind::InvalidData,
            "payload not f32-aligned",
        ));
    }
    let mut out = vec![0.0f32; b.len() / 4];
    aggregation::copy_f32s_le(&mut out, b);
    Ok(out)
}

/// Decode raw little-endian f32 bytes into an existing slice (bit-exact,
/// zero allocations). Errors unless `bytes` is exactly `4 * dst.len()`.
pub fn copy_f32s_from_le(dst: &mut [f32], bytes: &[u8]) -> std::io::Result<()> {
    if bytes.len() != dst.len() * 4 {
        return Err(std::io::Error::new(
            std::io::ErrorKind::InvalidData,
            "payload length does not match destination",
        ));
    }
    aggregation::copy_f32s_le(dst, bytes);
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn frame_roundtrip() {
        let f = Frame {
            op: Op::PushChunk,
            job: 7,
            worker: 3,
            payload: f32s_to_bytes(&[1.0, -2.5, 3.25]),
        };
        let bytes = encode(&f);
        let mut cursor = std::io::Cursor::new(bytes);
        let g = read_frame(&mut cursor).unwrap();
        assert_eq!(f, g);
        assert_eq!(bytes_to_f32s(&g.payload).unwrap(), vec![1.0, -2.5, 3.25]);
    }

    /// The borrowed read path decodes the same frames as the owning one
    /// and reuses the payload buffer's allocation across frames.
    #[test]
    fn read_frame_into_reuses_the_buffer() {
        let mut stream = Vec::new();
        for i in 0..3u32 {
            stream.extend_from_slice(&encode(&Frame {
                op: Op::PushChunk,
                job: i,
                worker: i + 1,
                payload: f32s_to_bytes(&vec![i as f32; 32]),
            }));
        }
        let mut cursor = std::io::Cursor::new(stream);
        let mut buf = Vec::new();
        let mut cap_after_first = 0usize;
        for i in 0..3u32 {
            let v = read_frame_into(&mut cursor, &mut buf).unwrap();
            assert_eq!((v.op, v.job, v.worker), (Op::PushChunk, i, i + 1));
            assert_eq!(bytes_to_f32s(v.payload).unwrap(), vec![i as f32; 32]);
            if i == 0 {
                cap_after_first = buf.capacity();
            } else {
                assert_eq!(buf.capacity(), cap_after_first, "no regrowth");
            }
        }
    }

    #[test]
    fn empty_payload_roundtrip() {
        let f = Frame {
            op: Op::Bye,
            job: 0,
            worker: 0,
            payload: vec![],
        };
        let mut cursor = std::io::Cursor::new(encode(&f));
        assert_eq!(read_frame(&mut cursor).unwrap(), f);
    }

    #[test]
    fn bad_opcode_rejected() {
        let mut bytes = encode(&Frame {
            op: Op::Hello,
            job: 1,
            worker: 0,
            payload: vec![],
        });
        bytes[4] = 99; // clobber opcode
        let mut cursor = std::io::Cursor::new(bytes);
        assert!(read_frame(&mut cursor).is_err());
    }

    /// The v0 monolithic opcodes (3–5) are retired: frames carrying them
    /// no longer decode, so a legacy worker fails fast and loud.
    #[test]
    fn retired_v0_opcodes_rejected() {
        for retired in [3u8, 4, 5] {
            assert_eq!(Op::from_u8(retired), None);
            let mut bytes = encode(&Frame {
                op: Op::Bye,
                job: 1,
                worker: 0,
                payload: vec![],
            });
            bytes[4] = retired;
            let mut cursor = std::io::Cursor::new(bytes);
            assert!(read_frame(&mut cursor).is_err());
        }
    }

    #[test]
    fn truncated_frame_rejected() {
        let bytes = encode(&Frame {
            op: Op::ModelChunk,
            job: 1,
            worker: 0,
            payload: vec![1, 2, 3, 4],
        });
        let mut cursor = std::io::Cursor::new(&bytes[..bytes.len() - 2]);
        assert!(read_frame(&mut cursor).is_err());
    }

    #[test]
    fn misaligned_f32_payload_rejected() {
        assert!(bytes_to_f32s(&[1, 2, 3]).is_err());
        let mut dst = [0.0f32; 2];
        assert!(copy_f32s_from_le(&mut dst, &[0u8; 7]).is_err());
        assert!(copy_f32s_from_le(&mut dst, &[0u8; 12]).is_err());
    }

    #[test]
    fn header_size_is_fixed() {
        let f = Frame {
            op: Op::Welcome,
            job: 9,
            worker: 2,
            payload: vec![0; 10],
        };
        assert_eq!(encode(&f).len(), 4 + (HEADER_BYTES - 4) + 10);
    }

    #[test]
    fn chunk_opcodes_roundtrip_with_epoch() {
        for op in [Op::PushChunk, Op::ModelChunk, Op::PushChunkQuant, Op::RollbackRound] {
            assert_eq!(Op::from_u8(op as u8), Some(op));
        }
        let f = Frame {
            op: Op::PushChunk,
            job: 3,
            worker: 1,
            payload: encode_chunk_payload(5, 2, 320, &f32s_to_bytes(&[1.0, 2.0])),
        };
        let mut cursor = std::io::Cursor::new(encode(&f));
        let g = read_frame(&mut cursor).unwrap();
        let (chunk, epoch, off, bytes) = decode_chunk_payload(&g.payload).unwrap();
        assert_eq!((chunk, epoch, off), (5, 2, 320));
        assert_eq!(bytes_to_f32s(bytes).unwrap(), vec![1.0, 2.0]);
    }

    #[test]
    fn short_chunk_payload_rejected() {
        assert!(decode_chunk_payload(&[0u8; CHUNK_PREFIX_BYTES - 1]).is_err());
    }

    #[test]
    fn oversized_length_prefix_rejected_without_allocation() {
        // A peer claiming a huge frame must be rejected from the prefix
        // alone (no multi-GiB allocation, no waiting for the bytes).
        let mut bytes = Vec::new();
        bytes.extend_from_slice(&u32::MAX.to_le_bytes());
        let mut cursor = std::io::Cursor::new(bytes);
        let err = read_frame(&mut cursor).unwrap_err();
        assert_eq!(err.kind(), std::io::ErrorKind::InvalidData);
    }

    #[test]
    fn buffered_chunk_writer_matches_encode() {
        let payload = encode_chunk_payload(5, 2, 320, &f32s_to_bytes(&[1.0, 2.0]));
        let via_encode = encode(&Frame {
            op: Op::PushChunk,
            job: 3,
            worker: 1,
            payload,
        });
        let mut via_writer = Vec::new();
        write_chunk_frame_buffered(
            &mut via_writer,
            Op::PushChunk,
            3,
            1,
            5,
            2,
            320,
            &f32s_to_bytes(&[1.0, 2.0]),
        )
        .unwrap();
        assert_eq!(via_encode, via_writer, "two encoders, one wire format");
    }

    /// The f32-slice frame writer produces byte-identical frames to the
    /// byte-payload writer, across lengths that exercise the staging
    /// array's group boundary.
    #[test]
    fn f32_chunk_writer_matches_buffered() {
        for len in [0usize, 1, 63, 64, 65, 200] {
            let data: Vec<f32> = (0..len).map(|i| (i as f32 * 0.73).sin()).collect();
            let mut via_bytes = Vec::new();
            write_chunk_frame_buffered(
                &mut via_bytes,
                Op::ModelChunk,
                3,
                1,
                5,
                2,
                320,
                &f32s_to_bytes(&data),
            )
            .unwrap();
            let mut via_f32s = Vec::new();
            write_chunk_frame_f32s(&mut via_f32s, Op::ModelChunk, 3, 1, 5, 2, 320, &data)
                .unwrap();
            assert_eq!(via_bytes, via_f32s, "len {len}");
        }
    }

    #[test]
    fn proto_version_trailer() {
        let mut p = vec![0u8; 28]; // a 28-byte JobSpec from a v0-era worker
        assert_eq!(proto_version_at(&p, 28), PROTO_MONOLITHIC);
        push_proto_version(&mut p, PROTO_EPOCH_TAGGED);
        assert_eq!(proto_version_at(&p, 28), PROTO_EPOCH_TAGGED);
    }

    #[test]
    fn weight_trailer_defaults_to_one() {
        let mut p = vec![0u8; 28];
        push_proto_version(&mut p, PROTO_EPOCH_TAGGED);
        // A plain worker's Hello stops here: weight defaults to 1.
        assert_eq!(weight_at(&p, 32), 1);
        // A relay appends its rack's worker count after the version.
        push_weight(&mut p, 4);
        assert_eq!(proto_version_at(&p, 28), PROTO_EPOCH_TAGGED);
        assert_eq!(weight_at(&p, 32), 4);
    }

    #[test]
    fn retired_versions_fall_below_proto_min() {
        // Both pre-epoch generations are rejected by the PROTO_MIN gate.
        assert!(PROTO_MONOLITHIC < PROTO_MIN);
        assert!(PROTO_CHUNK_STREAMED < PROTO_MIN);
        assert!(PROTO_MIN <= PROTO_MAX);
    }

    /// Feed every strict byte-prefix of a real chunk frame: each one
    /// must return a clean typed error — never hang, never panic — and
    /// the classification must name what was cut (nothing at all =
    /// `Disconnected`; inside the prefix/header/payload = `Torn`).
    #[test]
    fn truncation_at_every_offset_classifies_cleanly() {
        let bytes = encode(&Frame {
            op: Op::PushChunk,
            job: 3,
            worker: 1,
            payload: encode_chunk_payload(0, 2, 0, &f32s_to_bytes(&[1.0, 2.0, 3.0])),
        });
        assert!(bytes.len() > HEADER_BYTES + CHUNK_PREFIX_BYTES);
        for cut in 0..bytes.len() {
            let mut cursor = std::io::Cursor::new(&bytes[..cut]);
            let mut buf = Vec::new();
            let err = read_frame_into(&mut cursor, &mut buf).unwrap_err();
            assert_eq!(err.kind(), std::io::ErrorKind::UnexpectedEof, "cut {cut}");
            let want = match cut {
                0 => WireError::Disconnected,
                1..=3 => WireError::Torn("length prefix"),
                4..=15 => WireError::Torn("frame header"),
                _ => WireError::Torn("frame payload"),
            };
            assert_eq!(WireError::classify(&err), want, "cut {cut}");
        }
        // The full frame still decodes.
        let mut cursor = std::io::Cursor::new(&bytes[..]);
        let mut buf = Vec::new();
        assert!(read_frame_into(&mut cursor, &mut buf).is_ok());
    }

    #[test]
    fn protocol_violations_classify_as_protocol() {
        let mut bytes = encode(&Frame {
            op: Op::Bye,
            job: 1,
            worker: 0,
            payload: vec![],
        });
        bytes[4] = 99; // clobber opcode
        let mut cursor = std::io::Cursor::new(bytes);
        let err = read_frame(&mut cursor).unwrap_err();
        assert_eq!(WireError::classify(&err), WireError::Protocol("bad opcode"));
        let timeout = std::io::Error::new(std::io::ErrorKind::WouldBlock, "deadline");
        assert!(is_timeout(&timeout));
        assert_eq!(WireError::classify(&timeout), WireError::Timeout);
    }

    #[test]
    fn residual_opcodes_roundtrip_and_stay_clear_of_retired_range() {
        for op in [Op::ResidualSave, Op::ResidualChunk] {
            assert_eq!(Op::from_u8(op as u8), Some(op));
            assert!((op as u8) > 10, "3–5 stay retired; new opcodes go above");
        }
        assert_eq!(Op::ResidualSave as u8, 11);
        assert_eq!(Op::ResidualChunk as u8, 12);
    }

    #[test]
    fn refusal_opcode_and_payload_roundtrip() {
        assert_eq!(Op::from_u8(13), Some(Op::Refused));
        assert_eq!(Op::Refused as u8, 13);
        let f = Frame {
            op: Op::Refused,
            job: 7,
            worker: 0,
            payload: encode_refusal(2, 250),
        };
        let mut cursor = std::io::Cursor::new(encode(&f));
        let g = read_frame(&mut cursor).unwrap();
        assert_eq!(g.op, Op::Refused);
        assert_eq!(decode_refusal(&g.payload).unwrap(), (2, 250));
        // A truncated refusal is a typed protocol error, not a panic.
        assert!(decode_refusal(&[0u8; 5]).is_err());
    }

    #[test]
    fn residual_frame_roundtrips_threshold_and_values() {
        let residual = [0.5f32, -0.25, 0.0, 7.75, -1.5];
        let mut wire_bytes = Vec::new();
        write_residual_frame(
            &mut wire_bytes,
            Op::ResidualSave,
            3,
            1,
            2,
            9,
            128,
            0.125,
            &residual,
        )
        .unwrap();
        let mut cursor = std::io::Cursor::new(wire_bytes);
        let f = read_frame(&mut cursor).unwrap();
        assert_eq!(f.op, Op::ResidualSave);
        let (chunk, epoch, off, bytes) = decode_chunk_payload(&f.payload).unwrap();
        assert_eq!((chunk, epoch, off), (2, 9, 128));
        let (threshold, raw) = split_residual_payload(bytes).unwrap();
        assert_eq!(threshold.to_bits(), 0.125f32.to_bits());
        assert_eq!(bytes_to_f32s(raw).unwrap(), residual);
        // Misaligned or headerless payloads are rejected, not panicked on.
        assert!(split_residual_payload(&[0u8; 3]).is_err());
        assert!(split_residual_payload(&[0u8; 7]).is_err());
    }
}
