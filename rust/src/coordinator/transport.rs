//! Distributed transport: the PHub leader serving workers over TCP.
//!
//! This makes the coordinator a real network service: workers in other
//! processes (or machines) connect, rendezvous (`Hello`/`Welcome` — the
//! wire form of `ConnectService`), and exchange gradients with the same
//! round-epoch engine the in-process path uses. The paper's data plane is
//! InfiniBand verbs with zero copy; this environment has neither RDMA
//! NICs nor kernel-bypass, so the transport is length-framed TCP — the
//! *architecture* (one connection per worker, chunk routing to pinned
//! cores, fused aggregation+optimization, dense or 2-bit-compressed
//! pushes) is the paper's.
//!
//! The exchange pattern is epoch-tagged chunk streaming (wire protocol
//! v2; the v0 monolithic and v1 pre-epoch patterns are retired — see
//! `wire.rs`): the worker writes one
//! `PushChunk` frame per chunk back-to-back; the leader's connection
//! thread routes each frame straight to the chunk's pinned core as it
//! arrives and returns `ModelChunk` frames as each chunk finishes
//! aggregation + optimization. Reception, aggregation, optimization, and
//! transmission of different chunks overlap, which is the whole point of
//! the paper's §3.2 data plane.
//!
//! This module is deliberately a *thin framing shell*: every round-state
//! decision — which chunks this worker pushed, how many replies it is
//! owed, which epoch it lives in, what a rollback means — is asked of
//! [`super::engine::WorkerRound`]; the connection loop only parses
//! frames, validates them against the key table, and moves bytes.
//!
//! # Hierarchical deployment (leader-of-leaders)
//!
//! The same leader binary plays either role of the paper's §3.4 / Fig.
//! 19 hierarchy. [`TcpLeader::serve`] is a **Root**: aggregate, optimize
//! exactly once, fan parameters down. [`TcpLeader::serve_relay`] is a
//! **RackRelay**: its cores tall-aggregate the rack's workers as usual,
//! but each chunk's completed *raw sum* is handed to a per-job uplink
//! thread which streams it to the parent over the very same v2
//! `PushChunk` frames a worker would send — admitting itself with an
//! aggregation weight equal to its rack's worker count
//! (`wire::push_weight`), so the root's mean divides by total *leaf*
//! workers and a two-level run is bit-identical to a flat one. The
//! parent's `ModelChunk` replies are fed back down to the cores, which
//! install the parameters and release the rack's waiting pullers.
//!
//! Recovery composes per level. A leaf dying mid-round bumps only its
//! rack's epoch: the rack rewinds its partial chunks and re-aggregates,
//! still producing exactly one sum per chunk per round upstream — the
//! parent never learns. A *relay* dying mid-round is, to the parent,
//! just a worker dying mid-round: the parent rewinds, the relay
//! reconnects and replays its round's sums byte-identically from its
//! per-chunk cache (re-summing is not needed and the rack is not
//! disturbed). The relay↔parent connection carries its own epoch,
//! independent of every rack-internal epoch.
//!
//! # Memory discipline
//!
//! The steady-state round is **exact-zero**: no heap allocation and no
//! mutex acquisition per chunk on either side of the wire (the paper's
//! bandwidth-bound, share-nothing pipeline; `aggregation.rs`, `ring.rs`,
//! and `wire.rs` hold the loop-, queue-, and frame-level contracts).
//! Buffer ownership and copies per chunk per round:
//!
//! * **Leader receive** — 1 copy (the socket read). Each connection owns
//!   a recycling [`super::pool::BytePool`]; `read_frame_into` decodes
//!   into a pooled buffer, the buffer itself travels to the chunk's
//!   pinned core over that worker's lock-free SPSC request ring
//!   (`CoreMsg::PushBytes`), the core folds the wire bytes straight into
//!   the accumulator (dense or 2-bit — no `bytes_to_f32s`, no
//!   dequantize scratch), and the buffer returns to the pool on drop.
//! * **Leader reply** — 1 copy *total*, not per puller. On completion
//!   the core copies the fresh parameters once into a refcount-shared
//!   pooled buffer (`SharedF32`) and every puller's connection gets a
//!   refcount bump over its SPSC reply ring; each connection serializes
//!   straight out of the shared buffer into its reused `ready` staging
//!   vector with `write_chunk_frame_f32s` (no `f32s_to_bytes` vector),
//!   and the last drop recycles buffer + refcount block together.
//! * **Queues** — zero allocation, zero locks. The mpsc hop between
//!   connection and core threads (a lock under contention plus a queue
//!   block every ~31 sends) is gone; bounded rings apply backpressure
//!   to exactly the one producer of a full ring. Rollback notices ride
//!   the rings' monotone epoch bulletin, so recovery is never wedged
//!   behind dead-round traffic.
//! * **Client** — dense rounds serialize frames straight from the
//!   caller's gradient; quantized rounds encode into per-chunk buffers
//!   reused across rounds (`quantize_into`); `ModelChunk` payloads
//!   decode through a single reused receive buffer straight into the
//!   caller-owned model buffer of [`TcpWorker::push_pull_into`] /
//!   [`TcpWorker::push_pull_quant_into`] — zero allocations once warm.
//!   (The `Vec`-returning `push_pull` variants are thin wrappers whose
//!   one allocation is the returned model itself.)
//! * **Relay uplink** — the same discipline pointed up. Each completed
//!   chunk sum arrives from its core in a refcount-shared pooled buffer
//!   over a lock-free ring, is copied once into the uplink's per-chunk
//!   replay cache (a `Vec<f32>` reused every round — also the byte-
//!   identical replay source when the parent rewinds), and serializes
//!   upstream with `write_chunk_frame_f32s`; the parent's `ModelChunk`
//!   payload lands in a buffer from the uplink's own `BytePool` and
//!   travels *in that buffer* down a per-core install ring to the
//!   chunk's core (`RelayUplink::install_chunk_bytes`), recycling after
//!   the single copy into the slot. No mutex, no steady-state
//!   allocation, either direction.
//!
//! # Robustness and mid-round recovery
//!
//! The leader treats every byte off the wire as hostile. Job specs are
//! validated *before* any lock is taken or any state allocated (a
//! malformed `Hello` must never poison the shared jobs mutex), chunk
//! frames are bounds-checked against the key table, and duplicate chunk
//! pushes are rejected at the edge as typed errors (they can no longer
//! panic a shared core thread).
//!
//! A worker that disconnects *between* rounds has its slot released and
//! its server handle parked for a reconnecting successor, as before. A
//! worker that dies *mid-round* — the case that used to wedge its job
//! forever — now triggers recovery: the leader bumps the job's round
//! epoch, issues a `RollbackRound` to the owning cores (each rewinds only
//! the chunks with partial arrivals), and notifies surviving workers with
//! a `RollbackRound` frame so they replay the round; the dead worker's
//! slot is parked and recycled through the ordinary rejoin path, and the
//! successor's replay merges with the survivors' to finish the round with
//! parameters bit-identical to an uninterrupted run. Stale in-flight
//! frames from the dead connection are rejected by their epoch tag.
//!
//! # Tenant guardrails
//!
//! The leader is multi-tenant, so the control plane carries an
//! admission layer (see `super::admission`): every `Hello` that would
//! *create* a job is checked against [`crate::config::QuotaConfig`] —
//! job count, per-job and leader-wide model/worker quotas — and
//! against an overload watermark fed by round-deadline trips. A
//! refused `Hello` is answered with a typed, **retriable** `Refused`
//! frame (reason code + retry-after hint), never a silently dropped
//! socket: clients surface it as a [`super::admission::Refusal`] error
//! and [`TcpWorker::connect_with_backoff`] turns it into capped,
//! jittered waiting. Re-`Hello`s of hosted jobs bypass every capacity
//! gate, so a full leader can always heal the jobs it already
//! admitted. On the cores, per-tenant deficit-round-robin weights
//! (`QuotaConfig::weights`) bound how far one flooding tenant can
//! delay another's rounds. Jobs with zero live connections idle past
//! `QuotaConfig::idle_evict_after` are evicted by a janitor thread
//! *with a parameter handoff*: final parameters, optimizer state,
//! per-seat rounds, and residual checkpoints are staged so the
//! returning tenant readmits and resumes bit-exact. All of this is
//! control-plane only — the per-chunk exchange path is untouched.
//!
//! # Failure model & recovery contract
//!
//! The connection plane assumes **crash-stop with rejoin**: a peer can
//! die (process crash, cable pull, kernel OOM) at any byte boundary —
//! including mid-frame — and may later be replaced by a successor; it
//! never acts Byzantine beyond sending garbage (which the hostile-input
//! validation above already converts into a connection-local typed
//! error). On that model the plane guarantees:
//!
//! * **No silent hangs.** Every blocking edge is deadline-supervised
//!   (see [`crate::config::DeadlineConfig`]). Client sockets carry
//!   read/write timeouts surfacing as `wire::WireError::Timeout`; the
//!   leader arms a per-connection round deadline that declares a worker
//!   silent *mid-round* for too long dead — a *declared* death feeds the
//!   exact same epoch-bump → `RollbackRound` → replay recovery as a
//!   *detected* one (socket close), so supervision adds no new recovery
//!   machinery. Idle tenants parked between rounds are exempt. The
//!   relay uplink redials its parent under capped exponential backoff
//!   with jitter and, after `redial_attempts` failures, gives up and
//!   fails the job with a typed [`UplinkError`] instead of spinning
//!   forever.
//! * **Bit-exact resumption from any death round.** Dense state is
//!   re-derivable (the model lives on the leader; a successor reads
//!   `rounds_done` and continues). The one historically worker-private
//!   piece of state — the 2-bit path's error-feedback residual — is
//!   checkpointed through the leader every round (`ResidualSave`, one
//!   frame per chunk riding immediately before the chunk's push) and
//!   handed back at admission (`ResidualChunk` frames after `Welcome`),
//!   so a successor's quantized stream continues bit-identically to an
//!   unkilled worker. The checkpoint commits **atomically with round
//!   completion**: the leader stages the frames per connection and
//!   publishes them only at `complete_round`, and because every
//!   residual precedes its push on the stream, a completed round
//!   implies a complete checkpoint — a death at any byte boundary
//!   leaves the store at the exact round `rounds_done` reports, never a
//!   mix of two rounds. Committing is round-boundary work: the
//!   steady-state per-chunk exchange stays exact-zero (no allocation,
//!   no mutex).
//! * **Deterministic fault replay.** The whole contract is exercised by
//!   the seeded fault-injection layer in `super::faults` (kills,
//!   mid-frame cuts, delays, duplicate replays, injected *under* the
//!   protocol via a TCP proxy) — production paths run unmodified, and a
//!   faulted run's final parameters are asserted bit-identical to an
//!   unfaulted twin's (`tests/chaos.rs`).

use std::collections::HashMap;
use std::io::{BufReader, BufWriter, Read, Write};
use std::net::{TcpListener, TcpStream, ToSocketAddrs};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use anyhow::{bail, ensure, Context, Result};

use super::admission::{AdmissionController, LeaderUsage, RefuseReason, Refusal};
use super::chunk::KeyTable;
use super::compress::{ChunkQuantizer, QuantView};
use super::engine::{ChunkState, Reply, WorkerRound};
use super::faults::XorShift64;
use super::optimizer::NesterovSgd;
use super::pool::{BytePool, Pool};
use super::server::{JobId, PHubServer, RelayUplink, ServerConfig, WorkerHandle};
use super::wire::{self, Frame, Op};
use crate::config::DeadlineConfig;
use crate::metrics::DataPlaneMetrics;

/// Most workers one job admits (see the u64 arrival bitmask in
/// `aggregation.rs`, which owns the authoritative constant).
pub const MAX_WORKERS_PER_JOB: u32 = super::aggregation::MAX_WORKERS as u32;

/// Largest model accepted from the wire: 2^28 elements (1 GiB of f32),
/// sized so even a single-chunk job's frames fit under
/// [`wire::MAX_FRAME_BYTES`] — the cap `read_frame` enforces on the
/// attacker-controlled length prefix *before* any allocation.
pub const MAX_MODEL_ELEMS: u64 = 1 << 28;

// The former hard-coded `MAX_JOBS` job-count cap now lives in
// [`crate::config::QuotaConfig::max_jobs`] (env-overridable, default 64)
// and is enforced — together with the model/worker quotas and the
// overload watermark — by [`super::admission::AdmissionController`].

/// Job parameters carried in `Hello`.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct JobSpec {
    pub model_elems: u64,
    pub chunk_elems: u64,
    pub n_workers: u32,
    pub lr: f32,
    pub momentum: f32,
}

impl JobSpec {
    /// Wire encoding (28 bytes; the protocol-version trailer is appended
    /// separately by the rendezvous).
    pub fn to_bytes(self) -> Vec<u8> {
        let mut out = Vec::with_capacity(28);
        out.extend_from_slice(&self.model_elems.to_le_bytes());
        out.extend_from_slice(&self.chunk_elems.to_le_bytes());
        out.extend_from_slice(&self.n_workers.to_le_bytes());
        out.extend_from_slice(&self.lr.to_le_bytes());
        out.extend_from_slice(&self.momentum.to_le_bytes());
        out
    }

    pub fn from_bytes(b: &[u8]) -> Result<JobSpec> {
        if b.len() < 28 {
            bail!("short Hello payload");
        }
        Ok(JobSpec {
            model_elems: u64::from_le_bytes(b[0..8].try_into().unwrap()),
            chunk_elems: u64::from_le_bytes(b[8..16].try_into().unwrap()),
            n_workers: u32::from_le_bytes(b[16..20].try_into().unwrap()),
            lr: f32::from_le_bytes(b[20..24].try_into().unwrap()),
            momentum: f32::from_le_bytes(b[24..28].try_into().unwrap()),
        })
    }

    /// Reject out-of-range specs. The leader calls this at the connection
    /// edge, *before* taking the jobs lock: `init_job` asserts on these
    /// conditions, and a panic while holding the mutex would poison it and
    /// brick the leader for every tenant.
    pub fn validate(&self) -> Result<()> {
        ensure!(
            (1..=MAX_WORKERS_PER_JOB).contains(&self.n_workers),
            "n_workers {} not in 1..={MAX_WORKERS_PER_JOB}",
            self.n_workers
        );
        ensure!(self.model_elems > 0, "model_elems must be > 0");
        ensure!(
            self.model_elems <= MAX_MODEL_ELEMS,
            "model_elems {} exceeds max {MAX_MODEL_ELEMS}",
            self.model_elems
        );
        ensure!(self.chunk_elems > 0, "chunk_elems must be > 0");
        ensure!(
            self.chunk_elems <= self.model_elems,
            "chunk_elems {} > model_elems {}",
            self.chunk_elems,
            self.model_elems
        );
        ensure!(
            self.lr.is_finite() && self.momentum.is_finite(),
            "non-finite hyperparameters"
        );
        Ok(())
    }

    fn key_table(&self) -> KeyTable {
        KeyTable::flat(self.model_elems as usize, self.chunk_elems as usize)
    }
}

struct JobEntry {
    job: JobId,
    spec: JobSpec,
    /// Round epoch: bumped once per mid-round rollback. The engine shards
    /// learn it from `RollbackRound` core messages; admissions read it
    /// here so a successor starts in the current epoch.
    epoch: u32,
    /// Next never-used slot.
    next_slot: u32,
    /// Slots whose connection ended; reusable by reconnecting workers.
    free_slots: Vec<u32>,
    /// Server handles of freed slots, keyed by slot, waiting for a
    /// reconnect (the in-process server hands each worker handle out only
    /// once, so the leader must keep it across connections). The handle's
    /// `(epoch, round)` tag records where the predecessor left off.
    parked: HashMap<u32, WorkerHandle>,
    /// Per-slot quantizer residual checkpoints: the full `ResidualSave`
    /// chunk payloads (chunk prefix + threshold + f32 residuals) from a
    /// quantized worker's last *committed* round — staged per connection
    /// and published by `commit_residuals` exactly when the round
    /// completes, so the checkpoint here always matches the slot's
    /// `rounds_done`. Keyed by slot, indexed by chunk. Admission
    /// *clones* (never removes) a slot's checkpoint so a successor that
    /// itself dies before completing a round still leaves the next
    /// successor a restore point.
    residuals: HashMap<u32, Vec<Vec<u8>>>,
    /// Connections currently serving this job (admission increments,
    /// the parking block decrements; both under the jobs lock). The
    /// idle-eviction janitor only considers jobs at zero.
    live_conns: u32,
    /// Milliseconds since [`LeaderState::anchor`] of the job's last
    /// sign of life (admission, round completion, parking). Shared with
    /// connection threads so round completions stamp it with a relaxed
    /// store instead of taking the jobs lock.
    last_active: Arc<AtomicU64>,
    /// For a job readmitted from a staged handoff: the round each
    /// worker seat had completed at eviction. A seat's *first* handle
    /// after readmission is positioned here; parked handles already
    /// carry their own round.
    resume_rounds: Option<Vec<u64>>,
}

/// Parameter handoff staged for an idle-evicted job: everything needed
/// to readmit the tenant and resume training bit-exact — final
/// parameters and optimizer state per chunk, each seat's completed
/// round, and the committed quantizer residual checkpoints.
struct EvictedJob {
    spec: JobSpec,
    chunks: Vec<ChunkState>,
    /// Completed rounds per worker seat at eviction (parked handles'
    /// positions; seats that never connected inherit the job round).
    slot_rounds: Vec<u64>,
    residuals: HashMap<u32, Vec<Vec<u8>>>,
}

/// Shared state of one serving leader: the in-process server, the jobs
/// map, and the tenant-guardrail machinery. One `Arc<LeaderState>` is
/// held by the [`TcpLeader`], the accept loop, every connection
/// thread, every relay uplink pump, and the idle-eviction janitor.
///
/// Lock order: `jobs` before `evicted`, everywhere.
struct LeaderState {
    server: Arc<PHubServer>,
    jobs: Mutex<HashMap<u32, JobEntry>>,
    admission: AdmissionController,
    /// Staged parameter handoffs of idle-evicted jobs, keyed by wire
    /// job id, consumed by the tenant's next `Hello`.
    evicted: Mutex<HashMap<u32, EvictedJob>>,
    relay: Option<Arc<RelayConfig>>,
    dl: DeadlineConfig,
    /// Wall-clock zero for [`JobEntry::last_active`] stamps.
    anchor: Instant,
}

impl LeaderState {
    fn now_ms(&self) -> u64 {
        self.anchor.elapsed().as_millis() as u64
    }

    /// Leader-wide usage a job-creating `Hello` is checked against;
    /// the caller holds the jobs lock, so the view is race-free.
    fn usage(map: &HashMap<u32, JobEntry>) -> LeaderUsage {
        LeaderUsage {
            jobs: map.len(),
            model_elems: map.values().map(|e| e.spec.model_elems).sum(),
            workers: map.values().map(|e| u64::from(e.spec.n_workers)).sum(),
        }
    }
}

/// Typed failure of the relay uplink's deadline supervision (see the
/// failure-model contract in the module docs): raised when the redial
/// budget of [`DeadlineConfig::redial_attempts`] is exhausted, at which
/// point the job is evicted so every blocked exchange fails with an
/// error instead of hanging on the dead parent forever.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum UplinkError {
    /// The parent leader stayed unreachable for the full redial budget.
    ParentUnreachable { attempts: u32 },
}

impl std::fmt::Display for UplinkError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            UplinkError::ParentUnreachable { attempts } => write!(
                f,
                "relay uplink gave up after {attempts} failed rendezvous attempts"
            ),
        }
    }
}

impl std::error::Error for UplinkError {}

/// Hierarchy parameters of a [`TcpLeader::serve_relay`] leader: where
/// its parent lives and how wide the cross-rack level is.
#[derive(Debug, Clone)]
pub struct RelayConfig {
    /// Address of the parent leader (the root, or a higher-level relay).
    pub parent: String,
    /// Direct pushers the *parent* job admits — the number of racks at
    /// this level. A relay cannot infer it from its own rack's spec
    /// (`n_workers` there is the rack's worker count), so the operator
    /// states it once per level.
    pub racks: u32,
}

/// The TCP leader: accepts workers and serves exchanges.
pub struct TcpLeader {
    state: Arc<LeaderState>,
    local_addr: std::net::SocketAddr,
    /// Stops the idle-eviction janitor when the leader drops.
    stop: Arc<AtomicBool>,
}

impl Drop for TcpLeader {
    fn drop(&mut self) {
        self.stop.store(true, Ordering::Relaxed);
    }
}

impl TcpLeader {
    /// Bind and start serving in background threads as a **Root** (the
    /// flat deployment, and the top of a hierarchical one). `bind` may
    /// be `"127.0.0.1:0"` to pick a free port (see `local_addr`).
    /// Deadline supervision runs at [`DeadlineConfig::default`]; use
    /// [`TcpLeader::serve_with`] to tune it.
    pub fn serve(bind: impl ToSocketAddrs, cfg: ServerConfig) -> Result<Arc<TcpLeader>> {
        Self::serve_inner(bind, cfg, None, DeadlineConfig::default())
    }

    /// [`TcpLeader::serve`] with explicit deadline supervision (round
    /// deadlines for stalled workers; see the failure-model contract in
    /// the module docs).
    pub fn serve_with(
        bind: impl ToSocketAddrs,
        cfg: ServerConfig,
        dl: DeadlineConfig,
    ) -> Result<Arc<TcpLeader>> {
        Self::serve_inner(bind, cfg, None, dl)
    }

    /// Bind and start serving as a **RackRelay**: local workers are
    /// admitted and tall-aggregated exactly as under [`TcpLeader::serve`],
    /// but each job's per-chunk sums stream up to `relay.parent` (with an
    /// aggregation weight of the rack's worker count) and the parameters
    /// fan back down from there — the leader never runs the optimizer
    /// itself. The uplink dials the parent lazily on each job's first
    /// admission and redials on upstream failure, replaying the open
    /// round's cached sums byte-identically.
    pub fn serve_relay(
        bind: impl ToSocketAddrs,
        cfg: ServerConfig,
        relay: RelayConfig,
    ) -> Result<Arc<TcpLeader>> {
        Self::serve_relay_with(bind, cfg, relay, DeadlineConfig::default())
    }

    /// [`TcpLeader::serve_relay`] with explicit deadline supervision —
    /// in particular the uplink's redial backoff and give-up budget
    /// against a dead parent.
    pub fn serve_relay_with(
        bind: impl ToSocketAddrs,
        cfg: ServerConfig,
        relay: RelayConfig,
        dl: DeadlineConfig,
    ) -> Result<Arc<TcpLeader>> {
        ensure!(
            (1..=MAX_WORKERS_PER_JOB).contains(&relay.racks),
            "racks {} not in 1..={MAX_WORKERS_PER_JOB}",
            relay.racks
        );
        Self::serve_inner(bind, cfg, Some(Arc::new(relay)), dl)
    }

    fn serve_inner(
        bind: impl ToSocketAddrs,
        cfg: ServerConfig,
        relay: Option<Arc<RelayConfig>>,
        dl: DeadlineConfig,
    ) -> Result<Arc<TcpLeader>> {
        let listener = TcpListener::bind(bind).context("bind leader socket")?;
        let local_addr = listener.local_addr()?;
        let server = PHubServer::start(cfg);
        let admission = AdmissionController::new(server.quota().clone());
        let state = Arc::new(LeaderState {
            server,
            jobs: Mutex::new(HashMap::new()),
            admission,
            evicted: Mutex::new(HashMap::new()),
            relay,
            dl,
            anchor: Instant::now(),
        });
        let stop = Arc::new(AtomicBool::new(false));
        let leader = Arc::new(TcpLeader {
            state: state.clone(),
            local_addr,
            stop: stop.clone(),
        });
        {
            let state = state.clone();
            std::thread::Builder::new()
                .name("phub-accept".into())
                .spawn(move || {
                    for stream in listener.incoming() {
                        let Ok(stream) = stream else { break };
                        let state = state.clone();
                        std::thread::spawn(move || {
                            let _ = handle_worker(stream, state);
                        });
                    }
                })
                .context("spawn accept thread")?;
        }
        // Idle-eviction janitor (Root only — a relay's parameters live
        // upstream, so there is nothing local to hand off). Polls well
        // under the horizon so eviction latency tracks the configured
        // idleness, and exits when the leader drops.
        if state.relay.is_none() {
            if let Some(horizon) = state.server.quota().idle_evict_after {
                let state = state.clone();
                let poll = (horizon / 2)
                    .min(Duration::from_millis(50))
                    .max(Duration::from_millis(1));
                std::thread::Builder::new()
                    .name("phub-janitor".into())
                    .spawn(move || {
                        while !stop.load(Ordering::Relaxed) {
                            std::thread::sleep(poll);
                            janitor_sweep(&state, horizon);
                        }
                    })
                    .context("spawn janitor thread")?;
            }
        }
        Ok(leader)
    }

    pub fn local_addr(&self) -> std::net::SocketAddr {
        self.local_addr
    }

    pub fn server(&self) -> &Arc<PHubServer> {
        &self.state.server
    }

    /// Shared handle on this leader's data-plane counters — what a
    /// [`super::status::StatusServer`] serves over HTTP.
    pub fn metrics_arc(&self) -> Arc<DataPlaneMetrics> {
        self.state.server.metrics_arc()
    }

    /// Operator drain control: force (or release) load shedding. While
    /// forced, every job-creating `Hello` is refused with a retriable
    /// `Overloaded` reason; hosted jobs keep admitting their own
    /// workers and training normally.
    pub fn force_shed(&self, on: bool) {
        self.state.admission.force_shed(on);
    }
}

/// One idle-eviction sweep: jobs with zero live connections whose last
/// sign of life is older than `horizon` are evicted *with a parameter
/// handoff* — final parameters + optimizer state exported from the
/// cores, per-seat rounds, and the committed residual checkpoints are
/// staged under the wire job id so the tenant's next `Hello` readmits
/// and resumes bit-exact.
fn janitor_sweep(state: &LeaderState, horizon: Duration) {
    let now = state.now_ms();
    let h = horizon.as_millis() as u64;
    let mut map = state.jobs.lock().unwrap();
    let idle: Vec<u32> = map
        .iter()
        .filter(|(_, e)| {
            e.live_conns == 0
                && now.saturating_sub(e.last_active.load(Ordering::Relaxed)) >= h
        })
        .map(|(&j, _)| j)
        .collect();
    for wire_job in idle {
        let entry = map.remove(&wire_job).unwrap();
        // Stage the handoff before the engine forgets the job. Seats
        // with a parked handle resume its exact round; seats that never
        // connected inherit the job round (rounds cannot advance while
        // any seat is vacant, so an idle job's seats agree).
        let chunks = state.server.export_job(entry.job);
        let job_round = chunks.iter().map(|c| c.round).max().unwrap_or(0);
        let slot_rounds = (0..entry.spec.n_workers)
            .map(|s| entry.parked.get(&s).map_or(job_round, |h| h.round()))
            .collect();
        state.evicted.lock().unwrap().insert(
            wire_job,
            EvictedJob {
                spec: entry.spec,
                chunks,
                slot_rounds,
                residuals: entry.residuals,
            },
        );
        state.server.evict(entry.job);
        state.server.metrics().idle_evictions.inc();
    }
}

/// Admit one connection: create the job on first contact (subject to
/// admission control), readmit a staged handoff, or allocate/reuse a
/// worker slot of a hosted job, and hand back the server-side handle
/// (positioned at the job's current epoch). All checks that can fail
/// run either before this function (spec validation) or before any
/// bookkeeping mutates, so the jobs mutex can never be poisoned and a
/// rejected connection leaves no trace.
///
/// Capacity refusals are typed [`Refusal`]s and apply **only** to
/// job-creating `Hello`s: an entry hit in phase 1 is admitted before
/// any quota or watermark is consulted, so a full (or shedding) leader
/// can always heal the jobs it already hosts.
///
/// Job *creation* (gigabytes of model allocation + chunk fan-out to the
/// cores for a max-size spec) deliberately happens with the jobs mutex
/// released — one tenant's first `Hello` must not stall every other
/// tenant's admission. Two racing creators are resolved by evicting the
/// loser's freshly built job.
#[allow(clippy::type_complexity)]
fn admit(
    state: &Arc<LeaderState>,
    wire_job: u32,
    spec: JobSpec,
) -> Result<(JobId, u32, WorkerHandle, Option<Vec<Vec<u8>>>, Arc<AtomicU64>)> {
    let server = &state.server;
    loop {
        // Phase 1: admit into an existing entry (or a staged handoff)
        // under the lock — never capacity-checked.
        {
            let mut map = state.jobs.lock().unwrap();
            if let Some(entry) = map.get_mut(&wire_job) {
                return admit_into(server, entry, wire_job, spec);
            }
            // A staged parameter handoff readmits without the fresh-job
            // build: the engine resumes every chunk's parameters,
            // optimizer state, and round, and the seats resume their
            // recorded positions — bit-exact with a job that was never
            // evicted. Runs under the jobs lock (lock order jobs →
            // evicted) so a racing janitor or second readmitter sees
            // exactly one winner.
            let staged = {
                let mut ev = state.evicted.lock().unwrap();
                if let Some(e) = ev.get(&wire_job) {
                    if e.spec != spec {
                        bail!("job {wire_job} spec mismatch with staged handoff");
                    }
                    ev.remove(&wire_job)
                } else {
                    None
                }
            };
            if let Some(ej) = staged {
                let opt = Arc::new(NesterovSgd {
                    lr: spec.lr,
                    momentum: spec.momentum,
                });
                let job = server.init_job_resumed(
                    spec.key_table(),
                    ej.chunks,
                    opt,
                    spec.n_workers as usize,
                    server.quota().weight_for(wire_job),
                );
                server.metrics().readmissions.inc();
                let entry = map.entry(wire_job).or_insert(JobEntry {
                    job,
                    spec,
                    epoch: 0, // safe: zero live connections at eviction
                    next_slot: 0,
                    free_slots: Vec::new(),
                    parked: HashMap::new(),
                    residuals: ej.residuals,
                    live_conns: 0,
                    last_active: Arc::new(AtomicU64::new(state.now_ms())),
                    resume_rounds: Some(ej.slot_rounds),
                });
                return admit_into(server, entry, wire_job, spec);
            }
            // First contact: every job-creating Hello passes admission
            // (quota caps + overload watermark) before any state is
            // built. A failed check is a typed, retriable Refusal.
            state.admission.check_new_job(
                spec.n_workers,
                spec.model_elems,
                LeaderState::usage(&map),
            )?;
        }
        // Phase 2: first contact — build the job outside the lock, then
        // race to install it.
        let init = vec![0.0f32; spec.model_elems as usize];
        let opt = Arc::new(NesterovSgd {
            lr: spec.lr,
            momentum: spec.momentum,
        });
        // Role split: a relay leader's job forwards sums to an uplink
        // lane instead of optimizing (the parent owns the optimizer; the
        // hyperparameters still ride the spec upstream).
        let (job, uplink) = match &state.relay {
            None => (
                server.init_job_weighted(
                    spec.key_table(),
                    &init,
                    opt,
                    spec.n_workers as usize,
                    server.quota().weight_for(wire_job),
                ),
                None,
            ),
            Some(_) => {
                let (job, up) =
                    server.init_relay_job(spec.key_table(), &init, opt, spec.n_workers as usize);
                (job, Some(up))
            }
        };
        drop(init);
        {
            let mut map = state.jobs.lock().unwrap();
            // Re-check admission: another creator may have consumed the
            // last seat (or tripped the watermark) while we were
            // allocating outside the lock.
            if !map.contains_key(&wire_job) {
                if let Err(r) = state.admission.check_new_job(
                    spec.n_workers,
                    spec.model_elems,
                    LeaderState::usage(&map),
                ) {
                    drop(map);
                    drop(uplink);
                    server.evict(job);
                    return Err(r.into());
                }
            }
            match map.entry(wire_job) {
                std::collections::hash_map::Entry::Vacant(v) => {
                    let entry = v.insert(JobEntry {
                        job,
                        spec,
                        epoch: 0,
                        next_slot: 0,
                        free_slots: Vec::new(),
                        parked: HashMap::new(),
                        residuals: HashMap::new(),
                        live_conns: 0,
                        last_active: Arc::new(AtomicU64::new(state.now_ms())),
                        resume_rounds: None,
                    });
                    let res = admit_into(server, entry, wire_job, spec);
                    drop(map);
                    // Won the install race: this job exists now, so start
                    // its uplink pump (one thread per relay job for its
                    // lifetime, like one QP per rack-interface pair). The
                    // pump carries the leader state so a give-up can
                    // fail the job instead of leaking a zombie entry.
                    if let Some(up) = uplink {
                        let rc = state
                            .relay
                            .as_ref()
                            .expect("uplink implies relay config")
                            .clone();
                        let state = state.clone();
                        std::thread::Builder::new()
                            .name(format!("phub-uplink-{wire_job}"))
                            .spawn(move || {
                                let _ = run_uplink(up, rc, wire_job, spec, state);
                            })
                            .context("spawn uplink thread")?;
                    }
                    return res;
                }
                std::collections::hash_map::Entry::Occupied(_) => {}
            }
        }
        // Lost the install race: discard our copy and retry phase 1
        // against the winner's entry. (Dropping the loser's uplink lane
        // before evicting keeps the eviction orderly.)
        drop(uplink);
        server.evict(job);
    }
}

/// Slot allocation half of admission (entry exists, lock held). Also
/// hands back a *clone* of the slot's stored residual checkpoint, if
/// any, for the connection to replay to the successor, plus the job's
/// shared activity stamp.
#[allow(clippy::type_complexity)]
fn admit_into(
    server: &Arc<PHubServer>,
    entry: &mut JobEntry,
    wire_job: u32,
    spec: JobSpec,
) -> Result<(JobId, u32, WorkerHandle, Option<Vec<Vec<u8>>>, Arc<AtomicU64>)> {
    if entry.spec != spec {
        bail!("job {wire_job} spec mismatch");
    }
    // Oversubscription is checked against the job's authoritative spec
    // (`entry.spec`, not the connecting worker's copy) and *before* the
    // slot counter moves, so a rejected worker can't burn a slot.
    let slot = if let Some(s) = entry.free_slots.pop() {
        s
    } else if entry.next_slot < entry.spec.n_workers {
        let s = entry.next_slot;
        entry.next_slot += 1;
        s
    } else {
        // Typed and retriable: every declared seat is taken *right
        // now*, but seats free when workers disconnect — a backing-off
        // client gets one as soon as the leader observes a departure.
        if let Some(jm) = server.metrics().per_job.get(entry.job) {
            jm.refusals.inc();
        }
        return Err(Refusal {
            reason: RefuseReason::WorkerSlots,
            retry_after: server.quota().retry_after,
        }
        .into());
    };
    let (mut handle, resumed) = match entry.parked.remove(&slot) {
        Some(h) => (h, None),
        None => (
            server.worker(entry.job, slot as usize),
            entry
                .resume_rounds
                .as_ref()
                .and_then(|r| r.get(slot as usize).copied()),
        ),
    };
    // Position the handle in the job's current epoch: rollbacks may have
    // happened since the predecessor parked (its `round` stays — rounds
    // cannot advance while any slot is vacant). A seat's first handle
    // after a readmission instead resumes at the round the handoff
    // recorded for it.
    match resumed {
        Some(r) => handle.set_tag(entry.epoch, r),
        None => handle.set_tag(entry.epoch, handle.round()),
    }
    entry.live_conns += 1;
    let restored = entry.residuals.get(&slot).cloned();
    Ok((
        entry.job,
        slot,
        handle,
        restored,
        entry.last_active.clone(),
    ))
}

/// Per-connection worker service loop.
fn handle_worker(stream: TcpStream, state: Arc<LeaderState>) -> Result<()> {
    let server = &state.server;
    let dl = state.dl;
    stream.set_nodelay(true).ok();
    // Arm the round deadline: a read that stalls this long is either an
    // idle parked tenant (serve_streamed keeps waiting) or a dead worker
    // mid-round (declared dead → rollback recovery). Writes get the same
    // bound so a worker that stops draining its socket cannot wedge this
    // connection thread forever.
    stream.set_read_timeout(dl.round_deadline).ok();
    stream.set_write_timeout(dl.round_deadline).ok();
    let mut reader = BufReader::new(stream.try_clone()?);
    let mut writer = BufWriter::new(stream);

    // Rendezvous. Everything here is hostile until proven otherwise:
    // validate the spec before touching any shared state.
    let hello = wire::read_frame(&mut reader)?;
    if hello.op != Op::Hello {
        bail!("expected Hello, got {:?}", hello.op);
    }
    let spec = JobSpec::from_bytes(&hello.payload)?;
    spec.validate()
        .with_context(|| format!("job {} rejected", hello.job))?;
    let proto = wire::proto_version_at(&hello.payload, 28).min(wire::PROTO_MAX);
    ensure!(
        proto >= wire::PROTO_MIN,
        "job {}: wire protocol v{proto} was retired; this leader serves \
         v{}..=v{} (epoch-tagged chunk streaming)",
        hello.job,
        wire::PROTO_MIN,
        wire::PROTO_MAX
    );

    let admitted = admit(&state, hello.job, spec);
    let (job, slot, mut handle, restored, last_active) = match admitted {
        Ok(x) => x,
        Err(e) => {
            // A typed refusal is answered on the wire (reason code +
            // retry-after hint) so the client backs off instead of
            // guessing from a dropped socket; everything else —
            // malformed or hostile Hellos — still just drops.
            if let Some(r) = e.downcast_ref::<Refusal>() {
                let m = server.metrics();
                match r.reason {
                    RefuseReason::Overloaded => m.refused_overload.inc(),
                    RefuseReason::JobCap => m.refused_job_cap.inc(),
                    _ => m.refused_quota.inc(),
                }
                let _ = wire::write_frame(
                    &mut writer,
                    &Frame {
                        op: Op::Refused,
                        job: hello.job,
                        worker: 0,
                        payload: wire::encode_refusal(
                            r.reason as u16,
                            r.retry_after.as_millis() as u32,
                        ),
                    },
                )
                .and_then(|()| writer.flush());
            }
            return Err(e);
        }
    };
    last_active.store(state.now_ms(), Ordering::Relaxed);
    // Guardrail attribution: the tenant's quota view in /metrics and
    // /jobs (idempotent sets; the live-worker gauge pairs with the
    // decrement after the parking block).
    let jm = handle.job_metrics().clone();
    jm.sched_weight
        .set(u64::from(server.quota().weight_for(hello.job)));
    jm.model_elems.set(spec.model_elems);
    jm.n_workers.set(u64::from(spec.n_workers));
    jm.live_workers.add(1);
    // Register the pusher's aggregation weight (a downstream relay's
    // rack size; plain workers default to 1) before Welcome releases its
    // first push: a round must never complete against a stale divisor.
    // Unconditional so a slot whose predecessor was weighted resets when
    // an unweighted successor takes it.
    let weight = wire::weight_at(&hello.payload, 32);
    server.set_worker_weight(job, slot, weight);
    // A crashed predecessor on this slot may have left already-broadcast
    // replies or rollback notices in the handle's queue. Drain them
    // (best-effort — the epoch tag on every reply is the real guard).
    while handle.try_recv_reply().is_some() {}

    // The connection's view of the round state machine, resumed from
    // wherever the slot's predecessor left off.
    let mut wr = WorkerRound::resume(handle.n_chunks(), handle.epoch(), handle.round());

    // From here on every exit path must reach the parking block below: an
    // early `?` between admission and parking would burn the slot forever
    // (e.g. a Welcome write failing on an already-closed socket).
    let res = (|| -> Result<()> {
        let mut payload = slot.to_le_bytes().to_vec();
        payload.extend_from_slice(&wr.epoch().to_le_bytes());
        payload.extend_from_slice(&wr.round().to_le_bytes());
        wire::push_proto_version(&mut payload, proto);
        // Residual-restore trailer: how many `ResidualChunk` frames
        // follow the Welcome (a successor inheriting a quantized
        // predecessor's checkpoint; 0 for everyone else — old clients
        // ignore the trailer, old leaders simply omit it).
        let checkpoint: &[Vec<u8>] = restored.as_deref().unwrap_or(&[]);
        let n_restore = checkpoint.iter().filter(|c| !c.is_empty()).count() as u32;
        payload.extend_from_slice(&n_restore.to_le_bytes());
        wire::write_frame(
            &mut writer,
            &Frame {
                op: Op::Welcome,
                job: hello.job,
                worker: slot,
                payload,
            },
        )?;
        if n_restore > 0 {
            for chunk_payload in checkpoint.iter().filter(|c| !c.is_empty()) {
                wire::write_frame(
                    &mut writer,
                    &Frame {
                        op: Op::ResidualChunk,
                        job: hello.job,
                        worker: slot,
                        payload: chunk_payload.clone(),
                    },
                )?;
            }
            server.metrics().residual_restores.inc();
        }
        // Exchange loop. The chunk fan-out/fan-in runs on the core
        // threads, so workers on other connections proceed concurrently
        // (one service thread per worker, like one QP per
        // worker-interface pair).
        serve_streamed(
            &mut reader,
            &mut writer,
            &mut handle,
            hello.job,
            slot,
            &mut wr,
            &state,
            &last_active,
        )
    })();

    // Connection over (orderly Bye, disconnect, or protocol violation).
    // If it ended *mid-round* — this worker's chunks absorbed into an open
    // round, or replies still owed — the round can no longer complete, so
    // rewind it: bump the job's epoch and issue a RollbackRound to the
    // cores; survivors are notified to replay and the epoch tag fences
    // off this connection's stale in-flight pushes. Either way the slot
    // is released and the handle parked (positioned at the current epoch
    // and this worker's round) so a successor can take the seat — the
    // mid-round wedge this used to cause is gone.
    {
        let mut map = state.jobs.lock().unwrap();
        if let Some(entry) = map.get_mut(&hello.job) {
            if entry.job == job {
                if wr.mid_round() {
                    entry.epoch += 1;
                    server.rollback_round(job, entry.epoch);
                }
                handle.set_tag(entry.epoch, wr.round());
                while handle.try_recv_reply().is_some() {}
                entry.free_slots.push(slot);
                entry.parked.insert(slot, handle);
                entry.live_conns = entry.live_conns.saturating_sub(1);
            }
        }
    }
    jm.live_workers.dec();
    // Parking is a sign of life: the idleness horizon starts counting
    // from the departure, not from the last completed round.
    last_active.store(state.now_ms(), Ordering::Relaxed);
    res
}

/// Forward one engine reply to the connection: a completed chunk is
/// encoded into `ready` (flushed by the caller at safe points), a
/// rollback notice resets the tracker and discards the dead round's
/// queued frames. Returns `true` when a rollback was applied (the caller
/// then tells the worker with a `RollbackRound` frame).
fn apply_reply(
    r: Reply,
    wr: &mut WorkerRound,
    handle: &WorkerHandle,
    wire_job: u32,
    slot: u32,
    ready: &mut Vec<u8>,
) -> std::io::Result<bool> {
    match r {
        Reply::Chunk {
            chunk, epoch, data, ..
        } => {
            // A reply that was in flight for a rolled-back epoch is
            // dropped; the worker re-pushes and gets a fresh one.
            if wr.note_reply(epoch) {
                let (lo, _) = handle.chunk_range(chunk as usize);
                // Serialize straight out of the refcount-shared broadcast
                // buffer (this connection holds one of the references);
                // `data` drops right after, and the last puller's drop
                // recycles the buffer to the engine's pool.
                let t_enc = crate::trace::start();
                wire::write_chunk_frame_f32s(
                    ready,
                    Op::ModelChunk,
                    wire_job,
                    slot,
                    chunk,
                    epoch,
                    lo as u64,
                    &data,
                )?;
                crate::trace::span(
                    crate::trace::Stage::ReplyEncode,
                    handle.job(),
                    chunk,
                    slot,
                    t_enc,
                );
            }
            Ok(false)
        }
        Reply::RolledBack { epoch, .. } => {
            if wr.apply_rollback(epoch) {
                ready.clear();
                Ok(true)
            } else {
                Ok(false) // duplicate notice from another core
            }
        }
    }
}

/// Apply everything the engine has already queued for this worker.
/// Returns `true` if a rollback was among it.
fn drain_replies(
    handle: &mut WorkerHandle,
    wr: &mut WorkerRound,
    wire_job: u32,
    slot: u32,
    ready: &mut Vec<u8>,
) -> std::io::Result<bool> {
    let mut rolled = false;
    while let Some(r) = handle.try_recv_reply() {
        rolled |= apply_reply(r, wr, handle, wire_job, slot, ready)?;
    }
    Ok(rolled)
}

/// Tell the worker its open round was rewound: replay under `epoch`.
fn write_rollback_frame<W: Write>(
    w: &mut W,
    wire_job: u32,
    slot: u32,
    epoch: u32,
) -> std::io::Result<()> {
    wire::write_frame(
        w,
        &Frame {
            op: Op::RollbackRound,
            job: wire_job,
            worker: slot,
            payload: epoch.to_le_bytes().to_vec(),
        },
    )
}

/// Byte-counting shim over the connection reader: distinguishes a read
/// deadline that fired on an *idle* connection (zero bytes of the next
/// frame had arrived — a parked tenant, keep waiting) from one that
/// fired *mid-frame* (the peer stalled with a frame torn on the wire —
/// unrecoverable for this connection, declare it dead). Stack-only; the
/// steady-state read path is unchanged.
struct CountingReader<'a, R: Read> {
    inner: &'a mut R,
    consumed: usize,
}

impl<R: Read> Read for CountingReader<'_, R> {
    fn read(&mut self, buf: &mut [u8]) -> std::io::Result<usize> {
        let n = self.inner.read(buf)?;
        self.consumed += n;
        Ok(n)
    }
}

/// The connection loop: route each incoming chunk frame straight to its
/// pinned core and return `ModelChunk` frames per chunk as rounds
/// complete server-side. All round-state decisions are delegated to `wr`.
#[allow(clippy::too_many_arguments)]
fn serve_streamed<R: Read, W: Write>(
    reader: &mut R,
    writer: &mut W,
    handle: &mut WorkerHandle,
    wire_job: u32,
    slot: u32,
    wr: &mut WorkerRound,
    state: &LeaderState,
    last_active: &AtomicU64,
) -> Result<()> {
    let metrics = state.server.metrics();
    let n_chunks = handle.n_chunks();
    // Frame buffers recycle through this pool: connection thread →
    // owning core (bytes absorbed in place) → dropped → back here.
    // In-flight buffers are bounded by the round's chunk count, and
    // after one warm round the receive loop allocates nothing per frame.
    let pool: Arc<BytePool> = Pool::new(n_chunks.max(8));
    // ModelChunk frames for chunks that finished while later pushes were
    // still arriving. They are encoded immediately (straight from the
    // pooled reply buffers) but written only once the push phase ends:
    // writing into a worker that is still sending could deadlock both
    // sides on full socket buffers.
    let mut ready: Vec<u8> = Vec::new();
    // Staged residual checkpoint for the open round (quantized workers
    // only; buffers reuse across rounds). `ResidualSave` frames land
    // here and are committed to the job only at `complete_round`, so a
    // connection dying at any byte boundary leaves the stored
    // checkpoint at an exact round boundary matching the slot's
    // `rounds_done` — never a mix of two rounds.
    let mut pending_residuals: Vec<Vec<u8>> = vec![Vec::new(); n_chunks];
    // Pre-resolved attribution counters: the frame path pays relaxed
    // atomic adds only, never the registry lock.
    let jm = handle.job_metrics().clone();
    // Wall-clock anchor of the open round's first push, feeding the
    // per-job round-latency histogram (includes any replay).
    let mut round_start = std::time::Instant::now();
    loop {
        let mut fb = pool.take();
        let t_read = crate::trace::start();
        // Decode the frame into the pooled buffer; keep only scalars from
        // the borrowed view so the buffer itself can travel to the core.
        let (op, chunk, epoch, off, grad_len) = {
            let mut cr = CountingReader {
                inner: reader,
                consumed: 0,
            };
            let view = match wire::read_frame_into(&mut cr, &mut fb) {
                Ok(v) => v,
                Err(e) => {
                    if wire::is_timeout(&e) {
                        if !wr.mid_round() && cr.consumed == 0 {
                            // Idle tenant between rounds: a parked
                            // worker is not a stalled worker. Keep
                            // waiting (the buffer recycles).
                            continue;
                        }
                        // Round deadline fired mid-round (or mid-frame):
                        // declare this worker dead. Returning Ok routes
                        // through the parking block, whose `mid_round`
                        // check runs the exact same epoch-bump/rollback
                        // recovery as a detected socket death. A torn
                        // frame with no open round just ends the
                        // connection (the stream cannot be resynced).
                        metrics.timeouts.inc();
                        metrics.deadline_trips.inc();
                        // Feed the overload watermark: enough trips in
                        // a window and new admissions shed until the
                        // pressure clears.
                        state.admission.note_deadline_trip();
                        crate::trace::instant(
                            crate::trace::Stage::DeadlineTrip,
                            handle.job(),
                            0,
                            slot,
                        );
                        return Ok(());
                    }
                    return Ok(()); // disconnect = Bye
                }
            };
            match view.op {
                Op::PushChunk | Op::PushChunkQuant => {
                    let (chunk, epoch, off, bytes) = wire::decode_chunk_payload(view.payload)?;
                    (view.op, chunk, epoch, off, bytes.len())
                }
                Op::ResidualSave => {
                    // Residual checkpoint from a quantized worker:
                    // validated here, staged in the connection, and
                    // committed when the round completes (never touches
                    // the engine or the cores). Replays overwrite with
                    // byte-identical values, so staging is idempotent.
                    let ci = validate_residual_save(view.payload, handle, n_chunks)?;
                    pending_residuals[ci].clear();
                    pending_residuals[ci].extend_from_slice(view.payload);
                    continue;
                }
                Op::Bye => return Ok(()),
                other => bail!("unexpected opcode {other:?} in a chunk-streamed session"),
            }
        };
        crate::trace::span(crate::trace::Stage::FrameRead, handle.job(), chunk, slot, t_read);
        jm.push_bytes.add(grad_len as u64);
        // Apply queued engine notifications first: a rollback that
        // already happened decides how this frame is judged.
        if drain_replies(handle, wr, wire_job, slot, &mut ready)? {
            write_rollback_frame(writer, wire_job, slot, wr.epoch())?;
        }
        if epoch < wr.epoch() {
            // Stale in-flight push from before a rollback: rejected by
            // tag; the worker replays once it sees the RollbackRound
            // frame. (The buffer recycles on this `continue`.)
            metrics.replayed_frames.inc();
            jm.replays.inc();
            continue;
        }
        ensure!(
            epoch == wr.epoch(),
            "push epoch {epoch} ahead of connection epoch {}",
            wr.epoch()
        );
        let ci = chunk as usize;
        ensure!(ci < n_chunks, "chunk id {ci} out of range ({n_chunks} chunks)");
        let (lo, hi) = handle.chunk_range(ci);
        ensure!(
            off as usize == lo,
            "chunk {ci} offset {off} != expected {lo}"
        );
        // Validate the payload shape at the edge (typed rejection costs
        // this connection) without decoding it — the owning core folds
        // the bytes straight into its accumulator.
        let quant = op == Op::PushChunkQuant;
        if quant {
            let q = QuantView::parse(&fb[wire::CHUNK_PREFIX_BYTES..])?;
            ensure!(
                q.len == hi - lo,
                "chunk {ci} quant length {} != expected {}",
                q.len,
                hi - lo
            );
        } else {
            ensure!(
                grad_len == (hi - lo) * 4,
                "chunk {ci} payload {} bytes != expected {}",
                grad_len,
                (hi - lo) * 4
            );
        }
        // A duplicate violates the round protocol; the typed error
        // costs this connection, never a shared core.
        if !wr.mid_round() {
            round_start = std::time::Instant::now();
        }
        wr.begin_push(chunk)?;
        handle.push_chunk_bytes_tagged(
            chunk,
            fb,
            wire::CHUNK_PREFIX_BYTES,
            quant,
            true,
            wr.tag(),
        );
        // Collect chunks the cores already finished (earlier chunks
        // of this round aggregating+optimizing under the incoming
        // frames — the paper's overlap).
        if drain_replies(handle, wr, wire_job, slot, &mut ready)? {
            write_rollback_frame(writer, wire_job, slot, wr.epoch())?;
            continue;
        }
        if wr.push_phase_done() {
            // Round fully received; the worker is now draining its
            // socket. Send everything already finished, then stream
            // each remaining chunk the moment it completes.
            jm.pull_bytes.add(ready.len() as u64);
            let t_wr = crate::trace::start();
            writer.write_all(&ready)?;
            writer.flush()?;
            crate::trace::span(crate::trace::Stage::SocketWrite, handle.job(), 0, slot, t_wr);
            ready.clear();
            let mut rolled = false;
            while !rolled && wr.outstanding() > 0 {
                // `None` means the engine side of the job is gone —
                // evicted mid-exchange (an uplink that exhausted its
                // redial budget, or a shutdown). Fail the connection
                // with an error rather than panicking or hanging.
                let Some(r) = handle.recv_reply_opt() else {
                    bail!(
                        "job {wire_job} evicted mid-exchange \
                         (uplink gave up or leader shut down)"
                    );
                };
                rolled = apply_reply(r, wr, handle, wire_job, slot, &mut ready)?;
                jm.pull_bytes.add(ready.len() as u64);
                let t_wr = crate::trace::start();
                writer.write_all(&ready)?;
                writer.flush()?;
                crate::trace::span(crate::trace::Stage::SocketWrite, handle.job(), 0, slot, t_wr);
                ready.clear();
            }
            if rolled {
                write_rollback_frame(writer, wire_job, slot, wr.epoch())?;
            } else {
                wr.complete_round();
                jm.rounds_completed.inc();
                jm.round_latency.record(round_start.elapsed());
                // Sign of life for the idle-eviction janitor: a relaxed
                // store on the shared stamp, never the jobs lock.
                last_active.store(state.now_ms(), Ordering::Relaxed);
                commit_residuals(
                    handle.job(),
                    &state.jobs,
                    wire_job,
                    slot,
                    &mut pending_residuals,
                    metrics,
                );
            }
        }
    }
}

/// Validate one `ResidualSave` chunk payload (shape and placement)
/// without touching any shared state, returning its chunk index. The
/// caller stages the full payload in the connection's pending
/// checkpoint; [`commit_residuals`] publishes it to the job only when
/// the round completes.
fn validate_residual_save(payload: &[u8], handle: &WorkerHandle, n_chunks: usize) -> Result<usize> {
    let (chunk, _epoch, off, bytes) = wire::decode_chunk_payload(payload)?;
    let ci = chunk as usize;
    ensure!(ci < n_chunks, "residual chunk id {ci} out of range");
    let (lo, hi) = handle.chunk_range(ci);
    ensure!(
        off as usize == lo,
        "residual chunk {ci} offset {off} != expected {lo}"
    );
    let (_threshold, raw) = wire::split_residual_payload(bytes)?;
    ensure!(
        raw.len() == (hi - lo) * 4,
        "residual chunk {ci} payload {} bytes != expected {}",
        raw.len(),
        (hi - lo) * 4
    );
    Ok(ci)
}

/// Publish the connection's staged residual checkpoint into the job's
/// per-slot store — called at the exact round boundary, so what a
/// successor restores always corresponds to the `rounds_done` it is
/// told at Welcome. The full chunk payloads are stored verbatim so the
/// restore path replays them byte-identical. Round-boundary work: one
/// lock acquisition per completed quantized round, never on the
/// per-chunk exchange path (a dense worker's staging stays empty and
/// skips the lock entirely).
fn commit_residuals(
    job: JobId,
    jobs: &Mutex<HashMap<u32, JobEntry>>,
    wire_job: u32,
    slot: u32,
    pending: &mut [Vec<u8>],
    metrics: &DataPlaneMetrics,
) {
    if pending.iter().all(|p| p.is_empty()) {
        return;
    }
    let n_chunks = pending.len();
    let mut committed = 0u64;
    {
        let mut map = jobs.lock().unwrap();
        if let Some(entry) = map.get_mut(&wire_job) {
            let per = entry
                .residuals
                .entry(slot)
                .or_insert_with(|| vec![Vec::new(); n_chunks]);
            for (ci, p) in pending.iter_mut().enumerate() {
                if p.is_empty() {
                    continue;
                }
                per[ci].clear();
                per[ci].extend_from_slice(p);
                p.clear();
                committed += 1;
            }
        }
    }
    metrics.residual_saves.add(committed);
    if committed > 0 {
        crate::trace::instant(crate::trace::Stage::ResidualCommit, job, 0, slot);
    }
}

/// Dial a leader and run the Hello/Welcome rendezvous — the shared
/// client edge of both a leaf worker's connection and a relay's uplink
/// (which additionally registers its aggregation `weight`; leaf workers
/// pass 1 and send no trailer, keeping their Hello bytes unchanged).
/// Returns `(reader, writer, slot, negotiated proto, epoch, rounds
/// done, residual checkpoint payloads)`.
///
/// `io_timeout` arms socket read/write deadlines for the whole client
/// session (`None` = block forever, the legacy behavior); a fired
/// deadline surfaces as a typed [`wire::WireError::Timeout`] in the
/// error chain rather than a hang.
#[allow(clippy::type_complexity)]
fn rendezvous(
    addr: impl ToSocketAddrs,
    job: u32,
    spec: JobSpec,
    proto: u32,
    weight: u32,
    io_timeout: Option<std::time::Duration>,
) -> Result<(
    BufReader<TcpStream>,
    BufWriter<TcpStream>,
    u32,
    u32,
    u32,
    u64,
    Vec<Vec<u8>>,
)> {
    spec.validate()?;
    ensure!(
        proto >= wire::PROTO_MIN,
        "wire protocol v{proto} was retired; use v{} \
         (epoch-tagged chunk streaming) or newer",
        wire::PROTO_MIN
    );
    let stream = TcpStream::connect(addr).context("connect to leader")?;
    stream.set_nodelay(true).ok();
    stream.set_read_timeout(io_timeout).ok();
    stream.set_write_timeout(io_timeout).ok();
    let mut reader = BufReader::new(stream.try_clone()?);
    let mut writer = BufWriter::new(stream);
    let mut payload = spec.to_bytes();
    wire::push_proto_version(&mut payload, proto.min(wire::PROTO_MAX));
    if weight != 1 {
        wire::push_weight(&mut payload, weight);
    }
    wire::write_frame(
        &mut writer,
        &Frame {
            op: Op::Hello,
            job,
            worker: 0,
            payload,
        },
    )
    .map_err(typed_io)
    .context("send Hello")?;
    let welcome = wire::read_frame(&mut reader)
        .map_err(typed_io)
        .context("read Welcome")?;
    if welcome.op == Op::Refused {
        // Typed and retriable: surface the leader's reason + retry
        // hint so callers (and `connect_with_backoff`) can wait and
        // try again instead of treating a full leader as fatal.
        let (code, retry_ms) = wire::decode_refusal(&welcome.payload).map_err(typed_io)?;
        let reason = RefuseReason::from_u16(code)
            .ok_or_else(|| anyhow::anyhow!("unknown refusal reason code {code}"))?;
        return Err(Refusal {
            reason,
            retry_after: Duration::from_millis(u64::from(retry_ms)),
        }
        .into());
    }
    if welcome.op != Op::Welcome {
        bail!("expected Welcome, got {:?}", welcome.op);
    }
    ensure!(welcome.payload.len() >= 20, "short Welcome payload");
    let epoch = u32::from_le_bytes(welcome.payload[4..8].try_into().unwrap());
    let rounds_done = u64::from_le_bytes(welcome.payload[8..16].try_into().unwrap());
    let accepted = wire::proto_version_at(&welcome.payload, 16).min(proto);
    // Residual-restore trailer (absent on pre-checkpoint leaders): the
    // count of ResidualChunk frames that follow the Welcome, each
    // carrying one chunk's checkpointed error-feedback residual for a
    // successor to resume from.
    let n_restore = match welcome.payload.get(20..24) {
        Some(b) => u32::from_le_bytes(b.try_into().unwrap()) as usize,
        None => 0,
    };
    ensure!(
        n_restore as u64 <= spec.model_elems,
        "Welcome claims {n_restore} residual chunks for a \
         {}-element model",
        spec.model_elems
    );
    let mut residuals = Vec::with_capacity(n_restore);
    for _ in 0..n_restore {
        let f = wire::read_frame(&mut reader)
            .map_err(typed_io)
            .context("read ResidualChunk")?;
        ensure!(
            f.op == Op::ResidualChunk,
            "expected ResidualChunk, got {:?}",
            f.op
        );
        residuals.push(f.payload);
    }
    Ok((
        reader,
        writer,
        welcome.worker,
        accepted,
        epoch,
        rounds_done,
        residuals,
    ))
}

/// Lift a client-edge I/O failure into the typed taxonomy of
/// [`wire::WireError`] — a fired socket deadline becomes
/// `WireError::Timeout` in the error chain (downcastable), a peer close
/// becomes `Disconnected`, a mid-frame EOF stays `Torn`.
fn typed_io(e: std::io::Error) -> anyhow::Error {
    anyhow::Error::from(wire::WireError::classify(&e)).context(e)
}

/// The relay's uplink loop: forward each locally-complete chunk **sum**
/// to the parent leader as an ordinary `PushChunk`, then install the
/// returned `ModelChunk` parameters back into the rack's chunk slots
/// (releasing the deferred worker pulls). One thread per relayed job.
///
/// The relay is just another client to its parent — same rendezvous,
/// same frames, plus the aggregation-weight trailer so the root's mean
/// divides by leaf workers, not direct pushers. Three invariants make
/// the simple send-all-sums-then-read-all-models round shape safe:
///
/// * The engine emits **exactly one** `Sum` per chunk per local round,
///   even across rack-internal rollbacks (completed chunks sit in the
///   `awaiting` state, which rollbacks skip), and a worker cannot start
///   round r+1 until every round-r install has fired its replies — so
///   sums arrive strictly round-ordered and Phase A never sees a
///   next-round sum early.
/// * The parent buffers `ModelChunk` replies until our push phase is
///   done, so writing all sums before reading cannot deadlock.
/// * Every forwarded sum stays in a per-chunk replay cache until the
///   round's models are all installed. A parent-side rollback (another
///   rack died mid-round) or a reconnect replays the cached bytes
///   verbatim under the new epoch; re-installs of chunks that already
///   left `awaiting` are engine-side no-ops with byte-identical data.
///
/// Steady state allocates nothing and takes no mutex: sums serialize
/// straight from the reused replay caches (`write_chunk_frame_f32s`),
/// model payloads ride pooled receive buffers to the owning core, and
/// the pooled sum buffers recycle on drop.
///
/// The parent link redials under capped exponential backoff with
/// jitter ([`DeadlineConfig::redial_base`] doubling up to `redial_cap`;
/// a relay outliving its parent across a root restart is the intended
/// recovery story) — but no longer forever: after `redial_attempts`
/// consecutive failures the uplink **gives up**, evicts the job (every
/// blocked worker exchange fails with a typed error instead of hanging
/// on deferred pulls), and returns [`UplinkError::ParentUnreachable`].
/// The thread also exits when the local job is evicted (`recv_sum` →
/// `None`) or the parent says `Bye`.
fn run_uplink(
    mut up: RelayUplink,
    rc: Arc<RelayConfig>,
    wire_job: u32,
    spec: JobSpec,
    state: Arc<LeaderState>,
) -> Result<(), UplinkError> {
    let server = &state.server;
    let dl = state.dl;
    let n_chunks = up.n_chunks();
    // Chunk → element range, copied out so the replay closure below
    // doesn't hold a borrow of `up` across `recv_sum` calls.
    let ranges: Vec<(usize, usize)> = (0..n_chunks).map(|ci| up.chunk_range(ci)).collect();
    // Per-chunk replay caches, reused for the job lifetime.
    let mut sums: Vec<Vec<f32>> = ranges.iter().map(|&(lo, hi)| vec![0.0f32; hi - lo]).collect();
    // `sent[ci]`: chunk ci's sum for the open round was forwarded (and
    // cached); `installed[ci]`: its returned parameters were installed.
    let mut sent = vec![false; n_chunks];
    let mut installed = vec![false; n_chunks];
    // ModelChunk receive buffers recycle: socket → owning core (install
    // reads the bytes in place) → dropped → back here.
    let pool: Arc<BytePool> = Pool::new(n_chunks.max(8));
    // The parent sees one pusher per rack with this rack's leaf count
    // as its aggregation weight.
    let up_spec = JobSpec {
        n_workers: rc.racks,
        ..spec
    };
    let weight = spec.n_workers;
    // Deterministic jitter source, seeded per job so a fleet of relays
    // redialing a restarted root doesn't thundering-herd in lockstep.
    let mut jitter = XorShift64::new(0x9E37_79B9_7F4A_7C15 ^ wire_job as u64);
    let mut attempts: u32 = 0;

    'session: loop {
        let (mut reader, mut writer, slot, _proto, mut epoch, _rounds, _residuals) =
            match rendezvous(
                &rc.parent[..],
                wire_job,
                up_spec,
                wire::PROTO_MAX,
                weight,
                dl.io_timeout,
            ) {
                Ok(x) => {
                    attempts = 0;
                    x
                }
                Err(_) => {
                    // Parent down or not up yet; the rack blocks on its
                    // deferred pulls until the link comes back — or
                    // until the redial budget runs out.
                    server.metrics().redials.inc();
                    attempts += 1;
                    if dl.redial_attempts > 0 && attempts >= dl.redial_attempts {
                        // Give up: fail the job so every blocked worker
                        // gets an error instead of hanging forever. The
                        // transport entry goes first (jobs → server.jobs
                        // is the crate-wide lock order), guarded against
                        // a racing re-creation under the same wire id.
                        server.metrics().uplink_giveups.inc();
                        let mut map = state.jobs.lock().unwrap();
                        let ours = map.get(&wire_job).map(|e| e.job) == Some(up.job());
                        if ours {
                            map.remove(&wire_job);
                        }
                        drop(map);
                        server.evict(up.job());
                        return Err(UplinkError::ParentUnreachable { attempts });
                    }
                    std::thread::sleep(backoff_delay(&dl, attempts, &mut jitter));
                    continue 'session;
                }
            };
        // A reconnect means the parent saw us die mid-round and rolled
        // our partial pushes back: replay the cached sums it lost.
        let ranges = &ranges;
        let replay_all = move |writer: &mut BufWriter<TcpStream>,
                               sent: &[bool],
                               sums: &[Vec<f32>],
                               epoch: u32|
         -> std::io::Result<()> {
            for ci in 0..n_chunks {
                if sent[ci] {
                    wire::write_chunk_frame_f32s(
                        writer,
                        Op::PushChunk,
                        wire_job,
                        slot,
                        ci as u32,
                        epoch,
                        ranges[ci].0 as u64,
                        &sums[ci],
                    )?;
                }
            }
            writer.flush()
        };
        if replay_all(&mut writer, &sent, &sums, epoch).is_err() {
            continue 'session;
        }

        loop {
            // Phase A: forward this round's remaining sums upstream the
            // moment each rack-local chunk completes.
            let mut forwarded = sent.iter().filter(|&&s| s).count();
            while forwarded < n_chunks {
                let (ci, lo) = match up.recv_sum() {
                    None => return Ok(()), // job evicted; rack is shutting down
                    Some(Reply::Sum { chunk, data, .. }) => {
                        let ci = chunk as usize;
                        debug_assert!(!sent[ci], "duplicate sum for chunk {ci}");
                        sums[ci].copy_from_slice(&data[..]);
                        // dropping `data` here recycles the pooled buffer
                        (ci, ranges[ci].0)
                    }
                    Some(_) => continue, // rack-internal notice; not ours
                };
                sent[ci] = true;
                forwarded += 1;
                let io = wire::write_chunk_frame_f32s(
                    &mut writer,
                    Op::PushChunk,
                    wire_job,
                    slot,
                    ci as u32,
                    epoch,
                    lo as u64,
                    &sums[ci],
                )
                .and_then(|()| writer.flush());
                if io.is_err() {
                    continue 'session;
                }
            }

            // Phase B: install the round's returned parameters. Each
            // install releases that chunk's deferred rack pulls.
            installed.fill(false);
            let mut ngot = 0usize;
            while ngot < n_chunks {
                let mut fb = pool.take();
                let (op, chunk, fepoch, off, plen) = {
                    let view = match wire::read_frame_into(&mut reader, &mut fb) {
                        Ok(v) => v,
                        Err(_) => continue 'session,
                    };
                    match view.op {
                        Op::ModelChunk => match wire::decode_chunk_payload(view.payload) {
                            Ok((chunk, e, off, bytes)) => {
                                (view.op, chunk, e, off, bytes.len())
                            }
                            Err(_) => continue 'session,
                        },
                        Op::RollbackRound => {
                            if view.payload.len() < 4 {
                                continue 'session;
                            }
                            let e = u32::from_le_bytes(view.payload[0..4].try_into().unwrap());
                            (view.op, 0, e, 0, 0)
                        }
                        Op::Bye => return Ok(()),
                        _ => continue 'session,
                    }
                };
                if op == Op::RollbackRound {
                    if fepoch <= epoch {
                        continue; // stale notice, already replayed
                    }
                    // Another rack died mid-round upstream: the parent
                    // rewound the round. Replay every cached sum under
                    // the new epoch; the parent will resend all chunks,
                    // and re-installs of already-installed ones are
                    // byte-identical no-ops.
                    epoch = fepoch;
                    if replay_all(&mut writer, &sent, &sums, epoch).is_err() {
                        continue 'session;
                    }
                    installed.fill(false);
                    ngot = 0;
                    continue;
                }
                if fepoch < epoch {
                    continue; // superseded by a rollback we saw
                }
                let ci = chunk as usize;
                let valid = fepoch == epoch && ci < n_chunks && {
                    let (lo, hi) = ranges[ci];
                    off as usize == lo && plen == (hi - lo) * 4
                };
                if !valid {
                    continue 'session; // parent spoke garbage; reconnect
                }
                if installed[ci] {
                    continue; // duplicate after a replay race
                }
                up.install_chunk_bytes(chunk, fb, wire::CHUNK_PREFIX_BYTES);
                installed[ci] = true;
                ngot += 1;
            }
            sent.fill(false);
        }
    }
}

/// Exponential backoff with half-jitter for the uplink redial loop:
/// `base * 2^(attempt-1)` clamped to `cap`, then jittered uniformly
/// into `[d/2, d]` so simultaneously-orphaned relays spread their
/// redials instead of hammering a restarting root in lockstep.
fn backoff_delay(
    dl: &DeadlineConfig,
    attempt: u32,
    rng: &mut XorShift64,
) -> std::time::Duration {
    let exp = attempt.saturating_sub(1).min(20);
    let d = dl
        .redial_base
        .saturating_mul(1u32 << exp)
        .min(dl.redial_cap);
    let nanos = d.as_nanos() as u64;
    let half = nanos / 2;
    std::time::Duration::from_nanos(half + rng.next_u64() % (half.max(1) + 1))
}

/// A remote worker's connection to a [`TcpLeader`].
pub struct TcpWorker {
    reader: BufReader<TcpStream>,
    writer: BufWriter<TcpStream>,
    job: u32,
    pub slot: u32,
    /// Negotiated protocol version (`wire::PROTO_*`).
    proto: u32,
    /// The job's round epoch, learned at Welcome and advanced by
    /// `RollbackRound` frames.
    epoch: u32,
    /// Rounds this worker's *seat* had completed at admission — how a
    /// successor learns where its dead predecessor left off.
    rounds_done: u64,
    /// The worker's copy of the chunk layout (derived deterministically
    /// from the spec, so it always matches the leader's).
    table: KeyTable,
    /// Error-feedback state for the compressed path: one residual per
    /// chunk.
    chunk_quant: Option<ChunkQuantizer>,
    /// The open round's quantized chunk payloads (full `QuantGrad` wire
    /// encodings), one reused buffer per chunk. During a round they are
    /// the replay cache: a `RollbackRound` is answered by re-sending these
    /// byte-identical payloads — re-quantizing would corrupt the
    /// error-feedback residuals. Buffers persist across rounds
    /// (`quantize_into` overwrites in place), so the quantized round loop
    /// allocates nothing once warm. The dense path keeps no copy: its
    /// replay re-encodes from the caller's gradient, which is still
    /// borrowed for the whole exchange.
    quant_round: Vec<Vec<u8>>,
    /// Receive-payload buffer reused across frames (the client handles
    /// one frame at a time, so one buffer suffices — no pool needed).
    recv_buf: Vec<u8>,
    /// Per-chunk arrival flags for the open round's `ModelChunk`s,
    /// reused across rounds so the `_into` pull path allocates nothing.
    recv_seen: Vec<bool>,
    /// Residual checkpoint payloads handed down at admission (a
    /// successor resuming a dead quantized worker's seat; empty
    /// otherwise). Consumed by the first quantized round, which installs
    /// them into the fresh quantizer so the compressed stream continues
    /// bit-identically to the predecessor's.
    restored: Vec<Vec<u8>>,
}

impl TcpWorker {
    /// Connect and rendezvous at the newest protocol both sides speak.
    /// All workers of a job must present an identical `spec` (the first
    /// one creates the job server-side).
    pub fn connect(addr: impl ToSocketAddrs, job: u32, spec: JobSpec) -> Result<TcpWorker> {
        Self::connect_with_proto(addr, job, spec, wire::PROTO_MAX)
    }

    /// Connect proposing a specific protocol version (the leader may
    /// answer with a lower one; see `wire.rs` on negotiation). Proposing
    /// the retired v0 is rejected client-side with the same error the
    /// leader would give.
    pub fn connect_with_proto(
        addr: impl ToSocketAddrs,
        job: u32,
        spec: JobSpec,
        proto: u32,
    ) -> Result<TcpWorker> {
        Self::connect_with_opts(addr, job, spec, proto, DeadlineConfig::default().io_timeout)
    }

    /// [`TcpWorker::connect`] with automatic retry on *typed admission
    /// refusals* (and only those): a leader that answers `Refused` —
    /// over quota, shedding load, every seat momentarily taken — is
    /// retried up to `attempts` times, sleeping the larger of the
    /// leader's retry-after hint and the transport's jittered
    /// exponential backoff between tries. Every other failure
    /// (connection refused, protocol error, timeout) returns
    /// immediately, and an exhausted budget returns the final refusal
    /// still typed, so callers can downcast
    /// [`super::admission::Refusal`] either way.
    pub fn connect_with_backoff(
        addr: impl ToSocketAddrs + Clone,
        job: u32,
        spec: JobSpec,
        attempts: u32,
    ) -> Result<TcpWorker> {
        let dl = DeadlineConfig::default();
        let mut jitter = XorShift64::new(0xC0FF_EE00_D15C_0B01 ^ u64::from(job));
        let mut attempt = 0u32;
        loop {
            match Self::connect(addr.clone(), job, spec) {
                Ok(w) => return Ok(w),
                Err(e) => {
                    attempt += 1;
                    let hint = e.downcast_ref::<Refusal>().map(|r| r.retry_after);
                    match hint {
                        Some(h) if attempt < attempts => {
                            let wait = backoff_delay(&dl, attempt, &mut jitter).max(h);
                            std::thread::sleep(wait);
                        }
                        _ => return Err(e),
                    }
                }
            }
        }
    }

    /// [`TcpWorker::connect_with_proto`] with an explicit socket
    /// read/write deadline (`None` = block forever). A fired deadline
    /// surfaces as a typed [`wire::WireError::Timeout`] in the error
    /// chain instead of hanging the training loop.
    pub fn connect_with_opts(
        addr: impl ToSocketAddrs,
        job: u32,
        spec: JobSpec,
        proto: u32,
        io_timeout: Option<std::time::Duration>,
    ) -> Result<TcpWorker> {
        let (reader, writer, slot, proto, epoch, rounds_done, restored) =
            rendezvous(addr, job, spec, proto, 1, io_timeout)?;
        Ok(TcpWorker {
            reader,
            writer,
            job,
            slot,
            proto,
            epoch,
            rounds_done,
            table: spec.key_table(),
            chunk_quant: None,
            quant_round: Vec::new(),
            recv_buf: Vec::new(),
            recv_seen: Vec::new(),
            restored,
        })
    }

    /// The protocol version negotiated with the leader.
    pub fn proto(&self) -> u32 {
        self.proto
    }

    /// The round epoch this worker is operating in (advanced when the
    /// leader rewinds a round).
    pub fn epoch(&self) -> u32 {
        self.epoch
    }

    /// Completed rounds of this worker's seat at admission time. A fresh
    /// job starts at 0; a successor taking over a crashed worker's slot
    /// reads the round to resume training from here.
    pub fn rounds_done(&self) -> u64 {
        self.rounds_done
    }

    /// Write one round — one chunk frame per chunk, back-to-back with a
    /// single flush, so server-side aggregation of the first chunk runs
    /// under the transmission of the rest. `Some(grad)` serializes dense
    /// frames straight from the gradient slice (no intermediate byte
    /// vector); `None` sends the cached quantized payloads. Also how a
    /// round is *replayed* after `RollbackRound`: identical bytes, new
    /// epoch.
    ///
    /// On the compressed path each chunk's `ResidualSave` checkpoint
    /// frame rides immediately *before* its push, so by the time the
    /// leader has absorbed every push of round `r` it necessarily holds
    /// the complete post-round-`r` residual checkpoint in its staging
    /// area — committing it at round completion. A death at any byte
    /// boundary therefore leaves the stored checkpoint at an exact
    /// round boundary matching `rounds_done`, never a mix of rounds
    /// (replays resend byte-identical residuals, so the staging is
    /// idempotent).
    fn send_round(&mut self, grad: Option<&[f32]>) -> Result<()> {
        for (i, c) in self.table.chunks.iter().enumerate() {
            match grad {
                Some(g) => wire::write_chunk_frame_f32s(
                    &mut self.writer,
                    Op::PushChunk,
                    self.job,
                    self.slot,
                    i as u32,
                    self.epoch,
                    c.offset as u64,
                    &g[c.offset..c.offset + c.len],
                )?,
                None => {
                    let cq = self.chunk_quant.as_ref().unwrap();
                    wire::write_residual_frame(
                        &mut self.writer,
                        Op::ResidualSave,
                        self.job,
                        self.slot,
                        i as u32,
                        self.epoch,
                        c.offset as u64,
                        cq.threshold(),
                        cq.residual_chunk(i),
                    )?;
                    wire::write_chunk_frame_buffered(
                        &mut self.writer,
                        Op::PushChunkQuant,
                        self.job,
                        self.slot,
                        i as u32,
                        self.epoch,
                        c.offset as u64,
                        &self.quant_round[i],
                    )?;
                }
            }
        }
        self.writer.flush().map_err(typed_io)?;
        Ok(())
    }

    /// Dense fused push+pull. Thin wrapper over
    /// [`TcpWorker::push_pull_into`]; the returned `Vec` is the round's
    /// one allocation — steady-state training loops that care should own
    /// the buffer and call the `_into` form.
    pub fn push_pull(&mut self, grad: &[f32]) -> Result<Vec<f32>> {
        let mut model = vec![0.0f32; self.table.total_elems];
        self.push_pull_into(grad, &mut model)?;
        Ok(model)
    }

    /// Dense fused push+pull writing the round's parameters into a
    /// caller-owned buffer (`model.len()` must equal the model size).
    /// With the buffer reused across rounds the whole client round —
    /// encode, push, decode — performs zero heap allocations once warm.
    pub fn push_pull_into(&mut self, grad: &[f32], model: &mut [f32]) -> Result<()> {
        ensure!(
            grad.len() == self.table.total_elems,
            "gradient length {} != model {}",
            grad.len(),
            self.table.total_elems
        );
        self.send_round(Some(grad))?;
        self.read_model_chunks_into(Some(grad), model)
    }

    /// 2-bit compressed push+pull with error feedback (~16x less gradient
    /// traffic on the wire). Each chunk is an independent `QuantGrad`
    /// segment with its own residual; a replayed round re-sends the same
    /// quantized bytes, so the residuals advance exactly once per round no
    /// matter how often the round is rewound.
    pub fn push_pull_quant(&mut self, grad: &[f32], threshold: f32) -> Result<Vec<f32>> {
        let mut model = vec![0.0f32; self.table.total_elems];
        self.push_pull_quant_into(grad, threshold, &mut model)?;
        Ok(model)
    }

    /// [`TcpWorker::push_pull_quant`] into a caller-owned model buffer —
    /// the compressed counterpart of [`TcpWorker::push_pull_into`], with
    /// the same zero-allocation steady state.
    pub fn push_pull_quant_into(
        &mut self,
        grad: &[f32],
        threshold: f32,
        model: &mut [f32],
    ) -> Result<()> {
        ensure!(
            grad.len() == self.table.total_elems,
            "gradient length {} != model {}",
            grad.len(),
            self.table.total_elems
        );
        if self.chunk_quant.is_none() {
            let lens: Vec<usize> = self.table.chunks.iter().map(|c| c.len).collect();
            self.chunk_quant = Some(ChunkQuantizer::new(&lens, threshold));
            // A successor's first quantized round: install the dead
            // predecessor's checkpointed residuals before quantizing
            // anything, so the compressed stream (and therefore the
            // whole training trajectory) continues bit-identically.
            self.restore_residuals(threshold)?;
        }
        if self.quant_round.len() != self.table.chunks.len() {
            self.quant_round = vec![Vec::new(); self.table.chunks.len()];
        }
        // Quantize each chunk into its reused round buffer (wire encoding
        // included): the round loop allocates nothing once warm.
        let cq = self.chunk_quant.as_mut().unwrap();
        for (i, c) in self.table.chunks.iter().enumerate() {
            cq.quantize_chunk_into(
                i,
                &grad[c.offset..c.offset + c.len],
                &mut self.quant_round[i],
            );
        }
        // `send_round` interleaves each chunk's post-round residual
        // checkpoint with its push (see its docs), so the leader commits
        // the checkpoint exactly when this round completes — no separate
        // checkpoint leg a death could tear off.
        self.send_round(None)?;
        self.read_model_chunks_into(None, model)
    }

    /// Install residual checkpoints handed down at admission into the
    /// freshly built quantizer (no-op for a fresh seat).
    fn restore_residuals(&mut self, threshold: f32) -> Result<()> {
        let n_chunks = self.table.chunks.len();
        let cq = self.chunk_quant.as_mut().unwrap();
        let mut scratch: Vec<f32> = Vec::new();
        for payload in self.restored.drain(..) {
            let (chunk, _epoch, off, bytes) = wire::decode_chunk_payload(&payload)?;
            let ci = chunk as usize;
            ensure!(ci < n_chunks, "restored residual chunk {ci} out of range");
            let c = self.table.chunks[ci];
            ensure!(
                off as usize == c.offset,
                "restored residual chunk {ci} offset mismatch"
            );
            let (t, raw) = wire::split_residual_payload(bytes)?;
            ensure!(
                t.to_bits() == threshold.to_bits(),
                "restored residual threshold {t} != requested {threshold} \
                 (a successor must quantize with its predecessor's \
                 threshold to resume bit-exact)"
            );
            ensure!(
                raw.len() == c.len * 4,
                "restored residual chunk {ci} payload {} bytes != {}",
                raw.len(),
                c.len * 4
            );
            scratch.resize(c.len, 0.0);
            wire::copy_f32s_from_le(&mut scratch[..c.len], raw)?;
            cq.restore_chunk_residual(ci, &scratch[..c.len]);
        }
        Ok(())
    }

    /// Collect one `ModelChunk` frame per chunk (in completion order)
    /// into the caller-owned `model`, transparently replaying the round
    /// if the leader rewinds it (`grad` re-encodes a dense replay;
    /// `None` replays the cached quantized payloads). Frames decode
    /// through the reused receive buffer, arrival flags live in a
    /// reused per-connection vector, and payloads land directly in
    /// `model` — zero allocations per round once warm. (A replay
    /// rewrites every chunk range, so partial results from the dead
    /// round need no explicit reset.)
    fn read_model_chunks_into(&mut self, grad: Option<&[f32]>, model: &mut [f32]) -> Result<()> {
        let n_chunks = self.table.chunks.len();
        ensure!(
            model.len() == self.table.total_elems,
            "model buffer length {} != model {}",
            model.len(),
            self.table.total_elems
        );
        if self.recv_seen.len() != n_chunks {
            self.recv_seen = vec![false; n_chunks];
        }
        'round: loop {
            self.recv_seen.fill(false);
            let mut got = 0usize;
            while got < n_chunks {
                // Everything needed from the borrowed frame view is
                // extracted inside this block — replaying a rollback
                // needs `&mut self` again afterwards.
                let rolled_to = {
                    let f = wire::read_frame_into(&mut self.reader, &mut self.recv_buf)
                        .map_err(typed_io)?;
                    match f.op {
                        Op::ModelChunk => {
                            let (chunk, epoch, off, bytes) =
                                wire::decode_chunk_payload(f.payload)?;
                            if epoch < self.epoch {
                                continue; // superseded by a rollback we saw
                            }
                            ensure!(
                                epoch == self.epoch,
                                "model chunk epoch {epoch} ahead of ours ({})",
                                self.epoch
                            );
                            let ci = chunk as usize;
                            ensure!(ci < n_chunks, "model chunk id {ci} out of range");
                            let c = self.table.chunks[ci];
                            ensure!(off as usize == c.offset, "model chunk {ci} offset mismatch");
                            ensure!(!self.recv_seen[ci], "duplicate model chunk {ci}");
                            ensure!(
                                bytes.len() == c.len * 4,
                                "model chunk {ci} payload {} bytes != {}",
                                bytes.len(),
                                c.len * 4
                            );
                            wire::copy_f32s_from_le(
                                &mut model[c.offset..c.offset + c.len],
                                bytes,
                            )?;
                            self.recv_seen[ci] = true;
                            got += 1;
                            None
                        }
                        Op::RollbackRound => {
                            ensure!(f.payload.len() >= 4, "short RollbackRound payload");
                            let e = u32::from_le_bytes(f.payload[0..4].try_into().unwrap());
                            if e <= self.epoch {
                                continue; // stale notice, already replayed
                            }
                            Some(e)
                        }
                        other => bail!("expected ModelChunk, got {other:?}"),
                    }
                };
                if let Some(e) = rolled_to {
                    // The open round was rewound (another worker of the
                    // job died mid-round). Discard partial results and
                    // replay the identical payloads under the new epoch.
                    self.epoch = e;
                    self.send_round(grad)?;
                    continue 'round;
                }
            }
            return Ok(());
        }
    }

    /// Orderly shutdown.
    pub fn bye(mut self) {
        let _ = wire::write_frame(
            &mut self.writer,
            &Frame {
                op: Op::Bye,
                job: self.job,
                worker: self.slot,
                payload: vec![],
            },
        );
    }
}

#[cfg(test)]
#[allow(clippy::useless_vec)]
mod tests {
    use super::*;
    use crate::config::QuotaConfig;

    fn spec(model: u64, workers: u32) -> JobSpec {
        JobSpec {
            model_elems: model,
            chunk_elems: 64,
            n_workers: workers,
            lr: 0.5,
            momentum: 0.0,
        }
    }

    /// Send a raw Hello and wait for the leader to close the connection —
    /// proof the frame was fully processed (and rejected) before we return.
    fn raw_hello_expect_drop(addr: std::net::SocketAddr, job: u32, payload: Vec<u8>) {
        let mut stream = TcpStream::connect(addr).unwrap();
        let mut w = BufWriter::new(stream.try_clone().unwrap());
        wire::write_frame(
            &mut w,
            &Frame {
                op: Op::Hello,
                job,
                worker: 0,
                payload,
            },
        )
        .unwrap();
        let mut buf = [0u8; 64];
        loop {
            match stream.read(&mut buf) {
                Ok(0) | Err(_) => break,
                Ok(_) => {}
            }
        }
    }

    #[test]
    fn spec_roundtrip() {
        let s = spec(4096, 3);
        assert_eq!(JobSpec::from_bytes(&s.to_bytes()).unwrap(), s);
    }

    #[test]
    fn spec_validation() {
        assert!(spec(4096, 3).validate().is_ok());
        assert!(spec(4096, 0).validate().is_err());
        assert!(spec(4096, MAX_WORKERS_PER_JOB + 1).validate().is_err());
        assert!(spec(0, 1).validate().is_err());
        assert!(spec(MAX_MODEL_ELEMS + 1, 1).validate().is_err());
        let mut s = spec(4096, 1);
        s.chunk_elems = 0;
        assert!(s.validate().is_err());
        s.chunk_elems = 8192; // > model_elems
        assert!(s.validate().is_err());
        s = spec(4096, 1);
        s.lr = f32::NAN;
        assert!(s.validate().is_err());
    }

    #[test]
    fn backoff_is_capped_jittered_and_deterministic() {
        let dl = DeadlineConfig {
            redial_base: std::time::Duration::from_millis(10),
            redial_cap: std::time::Duration::from_millis(80),
            ..DeadlineConfig::default()
        };
        let mut rng = XorShift64::new(42);
        for attempt in 1..=10u32 {
            let d = backoff_delay(&dl, attempt, &mut rng);
            let exp = attempt.saturating_sub(1).min(20);
            let nominal = dl
                .redial_base
                .saturating_mul(1u32 << exp)
                .min(dl.redial_cap);
            // Half-jitter window: [nominal/2, nominal], never above cap.
            assert!(
                d >= nominal / 2 && d <= nominal,
                "attempt {attempt}: {d:?} outside [{:?}, {nominal:?}]",
                nominal / 2
            );
            assert!(d <= dl.redial_cap);
        }
        // Same seed, same schedule — the determinism the chaos soak
        // relies on for reproducible fault timelines.
        let mut a = XorShift64::new(7);
        let mut b = XorShift64::new(7);
        let da: Vec<_> = (1..=6).map(|i| backoff_delay(&dl, i, &mut a)).collect();
        let db: Vec<_> = (1..=6).map(|i| backoff_delay(&dl, i, &mut b)).collect();
        assert_eq!(da, db);
    }

    #[test]
    fn two_workers_over_tcp_match_reference() {
        let leader = TcpLeader::serve("127.0.0.1:0", ServerConfig::cores(2)).unwrap();
        let addr = leader.local_addr();
        let n = 256usize;
        let s = spec(n as u64, 2);
        let joins: Vec<_> = (0..2)
            .map(|w| {
                std::thread::spawn(move || {
                    let mut worker = TcpWorker::connect(addr, 1, s).unwrap();
                    assert_eq!(worker.proto(), wire::PROTO_EPOCH_TAGGED);
                    assert_eq!(worker.epoch(), 0);
                    let mut model = vec![0.0f32; n];
                    for round in 0..3 {
                        let grad: Vec<f32> =
                            (0..n).map(|i| (w + round) as f32 + i as f32 * 0.01).collect();
                        model = worker.push_pull(&grad).unwrap();
                    }
                    worker.bye();
                    model
                })
            })
            .collect();
        let models: Vec<Vec<f32>> = joins.into_iter().map(|j| j.join().unwrap()).collect();
        assert_eq!(models[0], models[1], "synchronous workers agree");
        // Sequential reference: p -= lr * mean(g) per round.
        let mut p = vec![0.0f32; n];
        for round in 0..3 {
            for i in 0..n {
                let mean = ((round as f32 + i as f32 * 0.01)
                    + (1.0 + round as f32 + i as f32 * 0.01))
                    / 2.0;
                p[i] -= 0.5 * mean;
            }
        }
        for (a, b) in models[0].iter().zip(&p) {
            assert!((a - b).abs() < 1e-4, "{a} vs {b}");
        }
    }

    /// The retired rendezvous generations (v0 monolithic, v1 pre-epoch
    /// chunk streaming) are refused on both sides with a clear error:
    /// client-side when proposing them, leader-side for raw Hellos with a
    /// retired trailer or none at all — and the leader keeps serving
    /// current-protocol tenants afterwards.
    #[test]
    fn retired_protocols_rejected_with_clear_error() {
        let leader = TcpLeader::serve("127.0.0.1:0", ServerConfig::cores(1)).unwrap();
        let addr = leader.local_addr();
        for retired in [wire::PROTO_MONOLITHIC, wire::PROTO_CHUNK_STREAMED] {
            let err = match TcpWorker::connect_with_proto(addr, 5, spec(64, 1), retired) {
                Err(e) => e,
                Ok(_) => panic!("v{retired} proposal must be rejected client-side"),
            };
            assert!(err.to_string().contains("retired"), "{err}");
            // Raw Hello with the retired trailer.
            let mut payload = spec(64, 1).to_bytes();
            wire::push_proto_version(&mut payload, retired);
            raw_hello_expect_drop(addr, 6 + retired, payload);
        }
        // The trailerless form a v0-era worker would send.
        raw_hello_expect_drop(addr, 8, spec(64, 1).to_bytes());
        // Rejections allocate nothing: the job ids remain usable and the
        // leader still serves the current protocol.
        let mut ok = TcpWorker::connect(addr, 6, spec(64, 1)).unwrap();
        let m = ok.push_pull(&vec![2.0; 64]).unwrap();
        assert!(m.iter().all(|&x| (x + 1.0).abs() < 1e-6));
        ok.bye();
    }

    #[test]
    fn quantized_path_tracks_dense_within_threshold() {
        let leader = TcpLeader::serve("127.0.0.1:0", ServerConfig::cores(1)).unwrap();
        let addr = leader.local_addr();
        let n = 128usize;
        let rounds = 20usize;
        let t = 0.05f32;
        // Single worker: quantized trajectory vs exact math.
        let mut worker = TcpWorker::connect(addr, 2, spec(n as u64, 1)).unwrap();
        let grad = vec![0.03f32; n]; // below threshold: only EF lets it through
        let mut model = vec![0.0f32; n];
        for _ in 0..rounds {
            model = worker.push_pull_quant(&grad, t).unwrap();
        }
        worker.bye();
        // Dense reference: p -= lr * g per round = -0.5*0.03*20 = -0.3.
        // EF guarantees the dequantized stream sum is within `t` of the
        // true sum, so the model is within lr * t of the reference.
        for m in &model {
            assert!((m - (-0.3f32)).abs() <= 0.5 * t + 1e-5, "{m}");
        }
    }

    #[test]
    fn two_jobs_isolated_over_tcp() {
        let leader = TcpLeader::serve("127.0.0.1:0", ServerConfig::cores(1)).unwrap();
        let addr = leader.local_addr();
        let mut wa = TcpWorker::connect(addr, 10, spec(64, 1)).unwrap();
        let mut wb = TcpWorker::connect(addr, 11, spec(64, 1)).unwrap();
        let ma = wa.push_pull(&vec![1.0; 64]).unwrap();
        let mb = wb.push_pull(&vec![2.0; 64]).unwrap();
        assert!(ma.iter().all(|&x| (x + 0.5).abs() < 1e-6));
        assert!(mb.iter().all(|&x| (x + 1.0).abs() < 1e-6));
    }

    #[test]
    fn leader_survives_abrupt_disconnect_and_releases_the_slot() {
        // Failure injection: a worker vanishes without Bye. The leader
        // must keep serving other jobs AND release the dead worker's slot
        // so the job can still reach N/N after a reconnect.
        let leader = TcpLeader::serve("127.0.0.1:0", ServerConfig::cores(1)).unwrap();
        let addr = leader.local_addr();
        {
            let w = TcpWorker::connect(addr, 20, spec(64, 2)).unwrap();
            drop(w); // TCP reset, no Bye; job 20 momentarily at 1/2 workers
        }
        // A fresh single-worker job on the same leader still works.
        let mut w2 = TcpWorker::connect(addr, 21, spec(64, 1)).unwrap();
        let m = w2.push_pull(&vec![4.0; 64]).unwrap();
        assert!(m.iter().all(|&x| (x + 2.0).abs() < 1e-6));
        w2.bye();
        // The crashed worker's slot frees once the leader observes the
        // disconnect; admitting two live workers must eventually succeed
        // (pre-fix, job 20 stayed stuck at 1/2 forever).
        let deadline = std::time::Instant::now() + std::time::Duration::from_secs(10);
        loop {
            let a = TcpWorker::connect(addr, 20, spec(64, 2));
            let b = TcpWorker::connect(addr, 20, spec(64, 2));
            match (a, b) {
                (Ok(mut a), Ok(mut b)) => {
                    let ja = std::thread::spawn(move || {
                        let m = a.push_pull(&vec![1.0; 64]).unwrap();
                        a.bye();
                        m
                    });
                    let mb = b.push_pull(&vec![3.0; 64]).unwrap();
                    b.bye();
                    let ma = ja.join().unwrap();
                    assert_eq!(ma, mb, "rejoined workers agree");
                    // p -= 0.5 * mean(1, 3) = -1.
                    assert!(ma.iter().all(|&x| (x + 1.0).abs() < 1e-6));
                    break;
                }
                _ => {
                    assert!(
                        std::time::Instant::now() < deadline,
                        "slot never released after disconnect"
                    );
                    std::thread::sleep(std::time::Duration::from_millis(20));
                }
            }
        }
    }

    #[test]
    fn malformed_payload_drops_connection_not_leader() {
        let leader = TcpLeader::serve("127.0.0.1:0", ServerConfig::cores(1)).unwrap();
        let addr = leader.local_addr();
        // Raw connection sending a garbage Hello payload.
        raw_hello_expect_drop(addr, 30, vec![1, 2, 3]); // too short for a JobSpec
        // Leader still serves correct clients afterwards.
        let mut ok = TcpWorker::connect(addr, 31, spec(32, 1)).unwrap();
        let m = ok.push_pull(&vec![2.0; 32]).unwrap();
        assert!(m.iter().all(|&x| (x + 1.0).abs() < 1e-6));
        ok.bye();
    }

    /// Regression for the poisoned-lock DoS: a `Hello` whose spec fails
    /// the asserts deep inside `init_job` used to panic *inside*
    /// `or_insert_with` while holding the jobs mutex, poisoning it and
    /// killing the leader for every subsequent tenant.
    #[test]
    fn hostile_hello_never_poisons_the_jobs_mutex() {
        let leader = TcpLeader::serve("127.0.0.1:0", ServerConfig::cores(1)).unwrap();
        let addr = leader.local_addr();
        let hostile = [
            spec(64, 0),                      // zero workers
            spec(64, MAX_WORKERS_PER_JOB + 1), // bitmask overflow
            spec(0, 1),                       // empty model
            {
                let mut s = spec(64, 1);
                s.chunk_elems = 0; // division-by-zero chunking
                s
            },
            {
                let mut s = spec(64, 1);
                s.chunk_elems = 128; // chunk bigger than the model
                s
            },
        ];
        for (i, s) in hostile.iter().enumerate() {
            let mut payload = s.to_bytes();
            wire::push_proto_version(&mut payload, wire::PROTO_EPOCH_TAGGED);
            raw_hello_expect_drop(addr, 300 + i as u32, payload);
        }
        // The leader must still admit and serve brand-new jobs.
        let mut ok = TcpWorker::connect(addr, 399, spec(32, 1)).unwrap();
        let m = ok.push_pull(&vec![2.0; 32]).unwrap();
        assert!(m.iter().all(|&x| (x + 1.0).abs() < 1e-6));
        ok.bye();
    }

    /// A duplicate chunk push in one round must cost the hostile
    /// connection, not a shared core thread (which would otherwise take
    /// down aggregation for every job on that core).
    #[test]
    fn duplicate_chunk_frame_drops_connection_not_cores() {
        let leader = TcpLeader::serve("127.0.0.1:0", ServerConfig::cores(1)).unwrap();
        let addr = leader.local_addr();
        {
            let stream = TcpStream::connect(addr).unwrap();
            let mut r = BufReader::new(stream.try_clone().unwrap());
            let mut w = BufWriter::new(stream);
            // 2-worker job so the round cannot complete and reset state.
            let s = spec(128, 2);
            let mut payload = s.to_bytes();
            wire::push_proto_version(&mut payload, wire::PROTO_EPOCH_TAGGED);
            wire::write_frame(
                &mut w,
                &Frame {
                    op: Op::Hello,
                    job: 40,
                    worker: 0,
                    payload,
                },
            )
            .unwrap();
            assert_eq!(wire::read_frame(&mut r).unwrap().op, Op::Welcome);
            let chunk0 = wire::encode_chunk_payload(0, 0, 0, &wire::f32s_to_bytes(&[1.0; 64]));
            for _ in 0..2 {
                wire::write_frame(
                    &mut w,
                    &Frame {
                        op: Op::PushChunk,
                        job: 40,
                        worker: 0,
                        payload: chunk0.clone(),
                    },
                )
                .unwrap();
            }
            // Leader must drop us (read yields EOF/err, not a ModelChunk).
            assert!(wire::read_frame(&mut r).is_err());
        }
        // With a single core, any core-thread casualty would break this.
        let mut ok = TcpWorker::connect(addr, 41, spec(32, 1)).unwrap();
        let m = ok.push_pull(&vec![2.0; 32]).unwrap();
        assert!(m.iter().all(|&x| (x + 1.0).abs() < 1e-6));
        ok.bye();
    }

    /// A worker that dies *mid-round* no longer wedges its job: the round
    /// is rolled back, the slot recycles, and two live workers finish the
    /// round with the dead worker's partial push fully erased. (Pre-PR
    /// behavior: the slot was consumed forever and the job wedged.)
    #[test]
    fn mid_round_disconnect_rolls_back_and_recycles_the_slot() {
        let leader = TcpLeader::serve("127.0.0.1:0", ServerConfig::cores(1)).unwrap();
        let addr = leader.local_addr();
        {
            let stream = TcpStream::connect(addr).unwrap();
            let mut r = BufReader::new(stream.try_clone().unwrap());
            let mut w = BufWriter::new(stream);
            let s = spec(128, 2); // 2 chunks, 2 workers: round stays open
            let mut payload = s.to_bytes();
            wire::push_proto_version(&mut payload, wire::PROTO_EPOCH_TAGGED);
            wire::write_frame(
                &mut w,
                &Frame {
                    op: Op::Hello,
                    job: 70,
                    worker: 0,
                    payload,
                },
            )
            .unwrap();
            assert_eq!(wire::read_frame(&mut r).unwrap().op, Op::Welcome);
            wire::write_frame(
                &mut w,
                &Frame {
                    op: Op::PushChunk,
                    job: 70,
                    worker: 0,
                    payload: wire::encode_chunk_payload(
                        0,
                        0,
                        0,
                        &wire::f32s_to_bytes(&[999.0; 64]),
                    ),
                },
            )
            .unwrap();
            // Drop mid-round: chunk 0 absorbed into the open round.
        }
        // Both slots must become admittable again (the dead worker's slot
        // recycles once the leader observes the disconnect and rolls the
        // round back), and the job must train to the exact values —
        // untainted by the dead worker's 999s.
        let deadline = std::time::Instant::now() + std::time::Duration::from_secs(10);
        loop {
            let a = TcpWorker::connect(addr, 70, spec(128, 2));
            let b = TcpWorker::connect(addr, 70, spec(128, 2));
            match (a, b) {
                (Ok(mut a), Ok(mut b)) => {
                    let ja = std::thread::spawn(move || {
                        let m = a.push_pull(&vec![1.0; 128]).unwrap();
                        a.bye();
                        m
                    });
                    let mb = b.push_pull(&vec![3.0; 128]).unwrap();
                    b.bye();
                    let ma = ja.join().unwrap();
                    assert_eq!(ma, mb, "recovered workers agree");
                    // p -= 0.5 * mean(1, 3) = -1: the 999s are gone.
                    assert!(ma.iter().all(|&x| (x + 1.0).abs() < 1e-6), "{:?}", &ma[..2]);
                    break;
                }
                _ => {
                    assert!(
                        std::time::Instant::now() < deadline,
                        "slot never recycled after mid-round disconnect"
                    );
                    std::thread::sleep(std::time::Duration::from_millis(20));
                }
            }
        }
        // Cores survived (single core: any casualty would break this).
        let mut ok = TcpWorker::connect(addr, 71, spec(32, 1)).unwrap();
        let m = ok.push_pull(&vec![2.0; 32]).unwrap();
        assert!(m.iter().all(|&x| (x + 1.0).abs() < 1e-6));
        ok.bye();
    }

    /// The leader hosts at most `QuotaConfig::max_jobs` jobs: cheap
    /// `Hello`s with fresh job ids cannot mint unbounded server state.
    /// The refusal is *typed and retriable* — and a re-`Hello` of a
    /// hosted job is never refused by the cap, so a full leader can
    /// still heal the jobs it already admitted.
    #[test]
    fn job_cap_refuses_excess_jobs_with_typed_reason() {
        let quota = QuotaConfig {
            max_jobs: 3,
            ..QuotaConfig::default()
        };
        let cfg = ServerConfig::cores(1).with_quota(quota);
        let leader = TcpLeader::serve("127.0.0.1:0", cfg).unwrap();
        let addr = leader.local_addr();
        let mut keep = Vec::new();
        for j in 0..3u32 {
            keep.push(TcpWorker::connect(addr, 1000 + j, spec(32, 1)).unwrap());
        }
        let err = TcpWorker::connect(addr, 2000, spec(32, 1)).unwrap_err();
        let r = err.downcast_ref::<Refusal>().expect("typed refusal");
        assert_eq!(r.reason, RefuseReason::JobCap);
        assert!(r.retry_after > Duration::ZERO, "hint must be actionable");
        assert_eq!(leader.metrics_arc().snapshot().refused_job_cap, 1);
        // Jobs admitted before the cap still train...
        let m = keep[0].push_pull(&vec![2.0; 32]).unwrap();
        assert!(m.iter().all(|&x| (x + 1.0).abs() < 1e-6));
        // ...and a successor can rejoin a hosted job at the full
        // leader: the seat may still look taken until the disconnect is
        // observed (a typed WorkerSlots refusal), but never JobCap.
        drop(keep.pop());
        let mut w = TcpWorker::connect_with_backoff(addr, 1002, spec(32, 1), 200).unwrap();
        let m = w.push_pull(&vec![2.0; 32]).unwrap();
        assert!(m.iter().all(|&x| (x + 1.0).abs() < 1e-6));
        w.bye();
    }

    #[test]
    fn oversubscribed_job_refused_with_typed_reason() {
        let leader = TcpLeader::serve("127.0.0.1:0", ServerConfig::cores(1)).unwrap();
        let addr = leader.local_addr();
        let _w0 = TcpWorker::connect(addr, 3, spec(64, 1)).unwrap();
        // Second worker for a 1-worker job: typed, retriable refusal
        // (the seat frees when the first worker departs).
        let err = TcpWorker::connect(addr, 3, spec(64, 1)).unwrap_err();
        let r = err.downcast_ref::<Refusal>().expect("typed refusal");
        assert_eq!(r.reason, RefuseReason::WorkerSlots);
        assert!(leader.metrics_arc().snapshot().refused_quota >= 1);
    }

    /// Drain mode refuses job-creating `Hello`s with a retriable
    /// `Overloaded` reason; a client under `connect_with_backoff` rides
    /// the refusals out and admits as soon as the shed releases — and a
    /// job admitted *before* the shed keeps healing while it is on.
    #[test]
    fn shed_refusals_are_retriable_and_backoff_succeeds() {
        let leader = TcpLeader::serve("127.0.0.1:0", ServerConfig::cores(1)).unwrap();
        let addr = leader.local_addr();
        let mut held = TcpWorker::connect(addr, 6, spec(32, 1)).unwrap();
        leader.force_shed(true);
        // New jobs shed with a typed reason.
        let err = TcpWorker::connect(addr, 5, spec(32, 1)).unwrap_err();
        let r = err.downcast_ref::<Refusal>().expect("typed refusal");
        assert_eq!(r.reason, RefuseReason::Overloaded);
        assert!(leader.metrics_arc().snapshot().refused_overload >= 1);
        // The pre-shed job is exempt: drop its worker and rejoin while
        // shedding is on (seat release may lag the disconnect, so back
        // off on WorkerSlots — but never see Overloaded).
        held.bye();
        drop(held);
        let mut back = TcpWorker::connect_with_backoff(addr, 6, spec(32, 1), 200).unwrap();
        let m = back.push_pull(&vec![2.0; 32]).unwrap();
        assert!(m.iter().all(|&x| (x + 1.0).abs() < 1e-6));
        back.bye();
        // A fresh tenant blocked on the shed admits once it releases.
        let waiter =
            std::thread::spawn(move || TcpWorker::connect_with_backoff(addr, 5, spec(32, 1), 200));
        std::thread::sleep(Duration::from_millis(100));
        leader.force_shed(false);
        let mut w = waiter.join().unwrap().unwrap();
        let m = w.push_pull(&vec![2.0; 32]).unwrap();
        assert!(m.iter().all(|&x| (x + 1.0).abs() < 1e-6));
        w.bye();
    }

    /// An idle job is evicted with a parameter handoff and the tenant
    /// readmits and resumes **bit-exact** — on the quantized path, so
    /// parameters, Nesterov state, per-seat rounds, and error-feedback
    /// residual checkpoints must all survive the hop.
    #[test]
    fn idle_evicted_job_readmits_and_resumes_bit_exact() {
        let quota = QuotaConfig {
            idle_evict_after: Some(Duration::from_millis(40)),
            ..QuotaConfig::default()
        };
        let evicting =
            TcpLeader::serve("127.0.0.1:0", ServerConfig::cores(2).with_quota(quota)).unwrap();
        let control = TcpLeader::serve("127.0.0.1:0", ServerConfig::cores(2)).unwrap();
        let s = JobSpec {
            momentum: 0.9, // non-trivial optimizer state in the handoff
            ..spec(256, 1)
        };
        let t = 0.05f32;
        let grads: Vec<Vec<f32>> = (0..6)
            .map(|r| {
                (0..256)
                    .map(|i| ((i * 7 + r * 13) % 11) as f32 * 0.01 - 0.03)
                    .collect()
            })
            .collect();
        // Control: six uninterrupted quantized rounds.
        let mut cw = TcpWorker::connect(control.local_addr(), 9, s).unwrap();
        let mut want = Vec::new();
        for g in &grads {
            want = cw.push_pull_quant(g, t).unwrap();
        }
        cw.bye();
        // Evicting leader: three rounds, leave, wait for the janitor,
        // readmit, three more rounds.
        let mut w = TcpWorker::connect(evicting.local_addr(), 9, s).unwrap();
        for g in &grads[..3] {
            w.push_pull_quant(g, t).unwrap();
        }
        w.bye();
        drop(w);
        let deadline = std::time::Instant::now() + Duration::from_secs(10);
        while evicting.metrics_arc().snapshot().idle_evictions == 0 {
            assert!(
                std::time::Instant::now() < deadline,
                "janitor never evicted the idle job"
            );
            std::thread::sleep(Duration::from_millis(5));
        }
        let mut w = TcpWorker::connect(evicting.local_addr(), 9, s).unwrap();
        assert_eq!(w.rounds_done(), 3, "handoff resumes at the evicted round");
        let mut got = Vec::new();
        for g in &grads[3..] {
            got = w.push_pull_quant(g, t).unwrap();
        }
        w.bye();
        assert_eq!(evicting.metrics_arc().snapshot().readmissions, 1);
        assert_eq!(got, want, "eviction/readmission must be bit-invisible");
    }
}
