//! Distributed transport: the PHub leader serving workers over TCP.
//!
//! This makes the coordinator a real network service: workers in other
//! processes (or machines) connect, rendezvous (`Hello`/`Welcome` — the
//! wire form of `ConnectService`), and exchange gradients with the same
//! chunked tall-aggregation engine the in-process path uses. The paper's
//! data plane is InfiniBand verbs with zero copy; this environment has
//! neither RDMA NICs nor kernel-bypass, so the transport is length-framed
//! TCP — the *architecture* (one connection per worker, chunk routing to
//! pinned cores, fused aggregation+optimization, dense or 2-bit-compressed
//! pushes) is the paper's.
//!
//! Two exchange patterns are spoken, negotiated per connection (see
//! `wire.rs`):
//!
//! * **v1, chunk-streamed** (default): the worker writes one `PushChunk`
//!   frame per chunk back-to-back; the leader's connection thread routes
//!   each frame straight to the chunk's pinned core as it arrives and
//!   returns `ModelChunk` frames as each chunk finishes aggregation +
//!   optimization. Reception, aggregation, optimization, and transmission
//!   of different chunks overlap, which is the whole point of the paper's
//!   §3.2 data plane.
//! * **v0, monolithic** (legacy, kept for one release): one whole-gradient
//!   frame up, one whole-model frame back, fully serializing network and
//!   compute.
//!
//! Robustness: the leader treats every byte off the wire as hostile. Job
//! specs are validated *before* any lock is taken or any state allocated
//! (a malformed `Hello` must never poison the shared jobs mutex), chunk
//! frames are bounds-checked against the key table, duplicate chunk pushes
//! are rejected at the edge (they would otherwise panic a shared core
//! thread), and a disconnected worker's slot is released so a crashed
//! worker can reconnect and resume its job.

use std::collections::HashMap;
use std::io::{BufReader, BufWriter, Read, Write};
use std::net::{TcpListener, TcpStream, ToSocketAddrs};
use std::sync::{Arc, Mutex};

use anyhow::{bail, ensure, Context, Result};

use super::chunk::KeyTable;
use super::compress::{ChunkQuantizer, QuantGrad, Quantizer};
use super::optimizer::NesterovSgd;
use super::server::{JobId, PHubServer, Reply, ServerConfig, WorkerHandle};
use super::wire::{self, Frame, Op};

/// Most workers one job admits (see the u64 arrival bitmask in
/// `aggregation.rs`, which owns the authoritative constant).
pub const MAX_WORKERS_PER_JOB: u32 = super::aggregation::MAX_WORKERS as u32;

/// Largest model accepted from the wire: 2^28 elements (1 GiB of f32),
/// sized so a legacy whole-model frame still fits under
/// [`wire::MAX_FRAME_BYTES`] — the cap `read_frame` enforces on the
/// attacker-controlled length prefix *before* any allocation.
pub const MAX_MODEL_ELEMS: u64 = 1 << 28;

/// Cap on jobs a leader will host over its lifetime (the TCP path has no
/// job GC, so this is the bound on server state a client can mint with
/// cheap `Hello`s — each admitted spec commits real model/optimizer
/// memory on the cores).
pub const MAX_JOBS: usize = 64;

/// Job parameters carried in `Hello`.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct JobSpec {
    pub model_elems: u64,
    pub chunk_elems: u64,
    pub n_workers: u32,
    pub lr: f32,
    pub momentum: f32,
}

impl JobSpec {
    /// Wire encoding (28 bytes; the protocol-version trailer is appended
    /// separately by the rendezvous).
    pub fn to_bytes(self) -> Vec<u8> {
        let mut out = Vec::with_capacity(28);
        out.extend_from_slice(&self.model_elems.to_le_bytes());
        out.extend_from_slice(&self.chunk_elems.to_le_bytes());
        out.extend_from_slice(&self.n_workers.to_le_bytes());
        out.extend_from_slice(&self.lr.to_le_bytes());
        out.extend_from_slice(&self.momentum.to_le_bytes());
        out
    }

    pub fn from_bytes(b: &[u8]) -> Result<JobSpec> {
        if b.len() < 28 {
            bail!("short Hello payload");
        }
        Ok(JobSpec {
            model_elems: u64::from_le_bytes(b[0..8].try_into().unwrap()),
            chunk_elems: u64::from_le_bytes(b[8..16].try_into().unwrap()),
            n_workers: u32::from_le_bytes(b[16..20].try_into().unwrap()),
            lr: f32::from_le_bytes(b[20..24].try_into().unwrap()),
            momentum: f32::from_le_bytes(b[24..28].try_into().unwrap()),
        })
    }

    /// Reject out-of-range specs. The leader calls this at the connection
    /// edge, *before* taking the jobs lock: `init_job` and
    /// `ChunkAggregator::new` assert on these conditions, and a panic
    /// while holding the mutex would poison it and brick the leader for
    /// every tenant.
    pub fn validate(&self) -> Result<()> {
        ensure!(
            (1..=MAX_WORKERS_PER_JOB).contains(&self.n_workers),
            "n_workers {} not in 1..={MAX_WORKERS_PER_JOB}",
            self.n_workers
        );
        ensure!(self.model_elems > 0, "model_elems must be > 0");
        ensure!(
            self.model_elems <= MAX_MODEL_ELEMS,
            "model_elems {} exceeds max {MAX_MODEL_ELEMS}",
            self.model_elems
        );
        ensure!(self.chunk_elems > 0, "chunk_elems must be > 0");
        ensure!(
            self.chunk_elems <= self.model_elems,
            "chunk_elems {} > model_elems {}",
            self.chunk_elems,
            self.model_elems
        );
        ensure!(
            self.lr.is_finite() && self.momentum.is_finite(),
            "non-finite hyperparameters"
        );
        Ok(())
    }

    fn key_table(&self) -> KeyTable {
        KeyTable::flat(self.model_elems as usize, self.chunk_elems as usize)
    }
}

struct JobEntry {
    job: JobId,
    spec: JobSpec,
    /// Next never-used slot.
    next_slot: u32,
    /// Slots whose connection ended; reusable by reconnecting workers.
    free_slots: Vec<u32>,
    /// Server handles of freed slots, keyed by slot, waiting for a
    /// reconnect (the in-process server hands each worker handle out only
    /// once, so the leader must keep it across connections).
    parked: HashMap<u32, WorkerHandle>,
}

/// The TCP leader: accepts workers and serves exchanges.
pub struct TcpLeader {
    server: Arc<PHubServer>,
    local_addr: std::net::SocketAddr,
}

impl TcpLeader {
    /// Bind and start serving in background threads. `bind` may be
    /// `"127.0.0.1:0"` to pick a free port (see `local_addr`).
    pub fn serve(bind: impl ToSocketAddrs, cfg: ServerConfig) -> Result<Arc<TcpLeader>> {
        let listener = TcpListener::bind(bind).context("bind leader socket")?;
        let local_addr = listener.local_addr()?;
        let server = PHubServer::start(cfg);
        let leader = Arc::new(TcpLeader {
            server: server.clone(),
            local_addr,
        });
        let jobs: Arc<Mutex<HashMap<u32, JobEntry>>> = Arc::new(Mutex::new(HashMap::new()));
        {
            let server = server.clone();
            std::thread::Builder::new()
                .name("phub-accept".into())
                .spawn(move || {
                    for stream in listener.incoming() {
                        let Ok(stream) = stream else { break };
                        let server = server.clone();
                        let jobs = jobs.clone();
                        std::thread::spawn(move || {
                            let _ = handle_worker(stream, server, jobs);
                        });
                    }
                })
                .context("spawn accept thread")?;
        }
        Ok(leader)
    }

    pub fn local_addr(&self) -> std::net::SocketAddr {
        self.local_addr
    }

    pub fn server(&self) -> &Arc<PHubServer> {
        &self.server
    }
}

/// Admit one connection: create the job on first contact, allocate or
/// reuse a worker slot, and hand back the server-side handle. All checks
/// that can fail run either before this function (spec validation) or
/// before any bookkeeping mutates, so the jobs mutex can never be
/// poisoned and a rejected connection leaves no trace.
///
/// Job *creation* (gigabytes of model allocation + chunk fan-out to the
/// cores for a max-size spec) deliberately happens with the jobs mutex
/// released — one tenant's first `Hello` must not stall every other
/// tenant's admission. Two racing creators are resolved by evicting the
/// loser's freshly built job.
fn admit(
    server: &Arc<PHubServer>,
    jobs: &Mutex<HashMap<u32, JobEntry>>,
    wire_job: u32,
    spec: JobSpec,
) -> Result<(JobId, u32, WorkerHandle)> {
    loop {
        // Phase 1: admit into an existing entry under the lock.
        {
            let mut map = jobs.lock().unwrap();
            if let Some(entry) = map.get_mut(&wire_job) {
                return admit_into(server, entry, wire_job, spec);
            }
            if map.len() >= MAX_JOBS {
                bail!("leader already hosts {MAX_JOBS} jobs");
            }
        }
        // Phase 2: first contact — build the job outside the lock, then
        // race to install it.
        let init = vec![0.0f32; spec.model_elems as usize];
        let job = server.init_job(
            spec.key_table(),
            &init,
            Arc::new(NesterovSgd {
                lr: spec.lr,
                momentum: spec.momentum,
            }),
            spec.n_workers as usize,
        );
        drop(init);
        {
            let mut map = jobs.lock().unwrap();
            // Re-check the cap: another creator may have filled the last
            // seat while we were allocating outside the lock.
            if map.len() >= MAX_JOBS && !map.contains_key(&wire_job) {
                drop(map);
                server.evict(job);
                bail!("leader already hosts {MAX_JOBS} jobs");
            }
            match map.entry(wire_job) {
                std::collections::hash_map::Entry::Vacant(v) => {
                    let entry = v.insert(JobEntry {
                        job,
                        spec,
                        next_slot: 0,
                        free_slots: Vec::new(),
                        parked: HashMap::new(),
                    });
                    return admit_into(server, entry, wire_job, spec);
                }
                std::collections::hash_map::Entry::Occupied(_) => {}
            }
        }
        // Lost the install race: discard our copy and retry phase 1
        // against the winner's entry.
        server.evict(job);
    }
}

/// Slot allocation half of admission (entry exists, lock held).
fn admit_into(
    server: &Arc<PHubServer>,
    entry: &mut JobEntry,
    wire_job: u32,
    spec: JobSpec,
) -> Result<(JobId, u32, WorkerHandle)> {
    if entry.spec != spec {
        bail!("job {wire_job} spec mismatch");
    }
    // Oversubscription is checked against the job's authoritative spec
    // (`entry.spec`, not the connecting worker's copy) and *before* the
    // slot counter moves, so a rejected worker can't burn a slot.
    let slot = if let Some(s) = entry.free_slots.pop() {
        s
    } else if entry.next_slot < entry.spec.n_workers {
        let s = entry.next_slot;
        entry.next_slot += 1;
        s
    } else {
        bail!(
            "job {wire_job} already has {} workers",
            entry.spec.n_workers
        );
    };
    let handle = match entry.parked.remove(&slot) {
        Some(h) => h,
        None => server.worker(entry.job, slot as usize),
    };
    Ok((entry.job, slot, handle))
}

/// Per-connection worker service loop.
fn handle_worker(
    stream: TcpStream,
    server: Arc<PHubServer>,
    jobs: Arc<Mutex<HashMap<u32, JobEntry>>>,
) -> Result<()> {
    stream.set_nodelay(true).ok();
    let mut reader = BufReader::new(stream.try_clone()?);
    let mut writer = BufWriter::new(stream);

    // Rendezvous. Everything here is hostile until proven otherwise:
    // validate the spec before touching any shared state.
    let hello = wire::read_frame(&mut reader)?;
    if hello.op != Op::Hello {
        bail!("expected Hello, got {:?}", hello.op);
    }
    let spec = JobSpec::from_bytes(&hello.payload)?;
    spec.validate()
        .with_context(|| format!("job {} rejected", hello.job))?;
    let proto = wire::proto_version_at(&hello.payload, 28).min(wire::PROTO_MAX);

    let (job, slot, mut handle) = admit(&server, &jobs, hello.job, spec)?;
    // A crashed predecessor on this slot may have left already-broadcast
    // replies in the handle's queue; drop them so rounds line up.
    while handle.try_recv_reply().is_some() {}

    // From here on every exit path must reach the parking block below: an
    // early `?` between admission and parking would burn the slot forever
    // (e.g. a Welcome write failing on an already-closed socket).
    // `clean` tracks whether the connection ended *between* rounds.
    let mut clean = true;
    let res = (|| -> Result<()> {
        let mut payload = slot.to_le_bytes().to_vec();
        wire::push_proto_version(&mut payload, proto);
        wire::write_frame(
            &mut writer,
            &Frame {
                op: Op::Welcome,
                job: hello.job,
                worker: slot,
                payload,
            },
        )?;
        // Exchange loop. The chunk fan-out/fan-in runs on the core
        // threads, so workers on other connections proceed concurrently
        // (one service thread per worker, like one QP per
        // worker-interface pair).
        if proto >= wire::PROTO_CHUNK_STREAMED {
            serve_streamed(&mut reader, &mut writer, &mut handle, hello.job, slot, &mut clean)
        } else {
            serve_monolithic(&mut reader, &mut writer, &mut handle, hello.job, slot)
        }
    })();

    // Connection over (orderly Bye, disconnect, or protocol violation):
    // if it ended between rounds, release the slot and park the server
    // handle so a reconnecting worker can take the seat instead of the
    // job sticking at N-1/N. A connection that died *mid-round* is NOT
    // recycled: its chunks are already absorbed into the open round, and
    // a successor re-pushing them would panic the shared core threads
    // (the round cannot be rolled back — that job wedges, as before this
    // fix, but other jobs are unaffected and the mutex stays healthy).
    // Clean parking also guarantees a parked handle has zero in-flight
    // replies, so a successor's `outstanding` accounting starts at truth.
    if clean {
        let mut map = jobs.lock().unwrap();
        if let Some(entry) = map.get_mut(&hello.job) {
            if entry.job == job {
                entry.free_slots.push(slot);
                entry.parked.insert(slot, handle);
            }
        }
    }
    res
}

/// v0: whole-model frames, one reply per push (legacy, kept one release).
fn serve_monolithic<R: Read, W: Write>(
    reader: &mut R,
    writer: &mut W,
    handle: &mut WorkerHandle,
    wire_job: u32,
    slot: u32,
) -> Result<()> {
    loop {
        let f = match wire::read_frame(reader) {
            Ok(f) => f,
            Err(_) => return Ok(()), // disconnect = Bye
        };
        let grad = match f.op {
            Op::PushPull => wire::bytes_to_f32s(&f.payload)?,
            Op::PushPullQuant => {
                // Compressed push: dequantize at the server edge, then the
                // normal dense tall-aggregation path (paper section 5).
                QuantGrad::from_bytes(&f.payload)?.dequantize()
            }
            Op::Bye => return Ok(()),
            other => bail!("unexpected opcode {other:?} in a monolithic (v0) session"),
        };
        ensure!(
            grad.len() == handle.model_len(),
            "gradient length {} != model {}",
            grad.len(),
            handle.model_len()
        );
        let model = handle.push_pull(&grad);
        wire::write_frame(
            writer,
            &Frame {
                op: Op::Model,
                job: wire_job,
                worker: slot,
                payload: wire::f32s_to_bytes(&model),
            },
        )?;
    }
}

/// v1: route each incoming chunk frame straight to its pinned core and
/// return `ModelChunk` frames per chunk as rounds complete server-side.
///
/// `clean` is left `true` iff the loop exits between rounds (no chunks of
/// an open round absorbed, no replies outstanding) — the caller only
/// recycles the worker slot in that state.
fn serve_streamed<R: Read, W: Write>(
    reader: &mut R,
    writer: &mut W,
    handle: &mut WorkerHandle,
    wire_job: u32,
    slot: u32,
    clean: &mut bool,
) -> Result<()> {
    let n_chunks = handle.n_chunks();
    // Per-round receive state for THIS worker's pushes.
    let mut seen = vec![false; n_chunks];
    let mut pushed = 0usize;
    // Replies owed to this worker for pulls issued this round.
    let mut outstanding = 0usize;
    // ModelChunk frames for chunks that finished while later pushes were
    // still arriving. They are encoded immediately but written only once
    // the push phase ends: writing into a worker that is still sending
    // could deadlock both sides on full socket buffers.
    let mut ready: Vec<u8> = Vec::new();
    loop {
        let f = match wire::read_frame(reader) {
            Ok(f) => f,
            Err(_) => return Ok(()), // disconnect = Bye
        };
        match f.op {
            Op::PushChunk | Op::PushChunkQuant => {
                let (chunk, off, bytes) = wire::decode_chunk_payload(&f.payload)?;
                let ci = chunk as usize;
                ensure!(ci < n_chunks, "chunk id {ci} out of range ({n_chunks} chunks)");
                let (lo, hi) = handle.chunk_range(ci);
                ensure!(
                    off as usize == lo,
                    "chunk {ci} offset {off} != expected {lo}"
                );
                // A duplicate would panic the chunk's (shared) core thread;
                // reject it here so it only costs this connection.
                ensure!(!seen[ci], "duplicate chunk {ci} in one round");
                let data: Vec<f32> = if f.op == Op::PushChunk {
                    wire::bytes_to_f32s(bytes)?
                } else {
                    QuantGrad::from_bytes(bytes)?.dequantize()
                };
                ensure!(
                    data.len() == hi - lo,
                    "chunk {ci} length {} != expected {}",
                    data.len(),
                    hi - lo
                );
                seen[ci] = true;
                pushed += 1;
                outstanding += 1;
                *clean = false;
                handle.push_chunk(chunk, data.into(), true);
                // Collect chunks the cores already finished (earlier chunks
                // of this round aggregating+optimizing under the incoming
                // frames — the paper's overlap).
                while let Some(r) = handle.try_recv_reply() {
                    write_model_chunk(&mut ready, handle, wire_job, slot, &r)?;
                    outstanding -= 1;
                }
                if pushed == n_chunks {
                    // Round fully received; the worker is now draining its
                    // socket. Send everything already finished, then stream
                    // each remaining chunk the moment it completes.
                    writer.write_all(&ready)?;
                    writer.flush()?;
                    ready.clear();
                    while outstanding > 0 {
                        let r = handle.recv_reply();
                        write_model_chunk(writer, handle, wire_job, slot, &r)?;
                        writer.flush()?;
                        outstanding -= 1;
                    }
                    pushed = 0;
                    seen.fill(false);
                    *clean = true;
                }
            }
            Op::Bye => return Ok(()),
            other => bail!("unexpected opcode {other:?} in a chunk-streamed (v1) session"),
        }
    }
}

/// Write one `ModelChunk` frame for `r` (no flush; `w` may be the socket
/// writer or the in-memory `ready` queue).
fn write_model_chunk<W: Write>(
    w: &mut W,
    handle: &WorkerHandle,
    wire_job: u32,
    slot: u32,
    r: &Reply,
) -> std::io::Result<()> {
    let (lo, _) = handle.chunk_range(r.chunk as usize);
    wire::write_chunk_frame_buffered(
        w,
        Op::ModelChunk,
        wire_job,
        slot,
        r.chunk,
        lo as u64,
        &wire::f32s_to_bytes(&r.data),
    )
}

/// A remote worker's connection to a [`TcpLeader`].
pub struct TcpWorker {
    reader: BufReader<TcpStream>,
    writer: BufWriter<TcpStream>,
    job: u32,
    pub slot: u32,
    /// Negotiated protocol version (`wire::PROTO_*`).
    proto: u32,
    /// The worker's copy of the chunk layout (derived deterministically
    /// from the spec, so it always matches the leader's).
    table: KeyTable,
    /// Error-feedback state for the compressed path (v0: whole model).
    quantizer: Option<Quantizer>,
    /// Error-feedback state for the compressed path (v1: per chunk).
    chunk_quant: Option<ChunkQuantizer>,
}

impl TcpWorker {
    /// Connect and rendezvous at the newest protocol both sides speak.
    /// All workers of a job must present an identical `spec` (the first
    /// one creates the job server-side).
    pub fn connect(addr: impl ToSocketAddrs, job: u32, spec: JobSpec) -> Result<TcpWorker> {
        Self::connect_with_proto(addr, job, spec, wire::PROTO_MAX)
    }

    /// Connect proposing a specific protocol version (the leader may
    /// answer with a lower one; see `wire.rs` on negotiation).
    pub fn connect_with_proto(
        addr: impl ToSocketAddrs,
        job: u32,
        spec: JobSpec,
        proto: u32,
    ) -> Result<TcpWorker> {
        spec.validate()?;
        let stream = TcpStream::connect(addr).context("connect to leader")?;
        stream.set_nodelay(true).ok();
        let mut reader = BufReader::new(stream.try_clone()?);
        let mut writer = BufWriter::new(stream);
        let mut payload = spec.to_bytes();
        wire::push_proto_version(&mut payload, proto.min(wire::PROTO_MAX));
        wire::write_frame(
            &mut writer,
            &Frame {
                op: Op::Hello,
                job,
                worker: 0,
                payload,
            },
        )?;
        let welcome = wire::read_frame(&mut reader)?;
        if welcome.op != Op::Welcome {
            bail!("expected Welcome, got {:?}", welcome.op);
        }
        Ok(TcpWorker {
            reader,
            writer,
            job,
            slot: welcome.worker,
            proto: wire::proto_version_at(&welcome.payload, 4).min(proto),
            table: spec.key_table(),
            quantizer: None,
            chunk_quant: None,
        })
    }

    /// The protocol version negotiated with the leader.
    pub fn proto(&self) -> u32 {
        self.proto
    }

    /// Dense fused push+pull.
    pub fn push_pull(&mut self, grad: &[f32]) -> Result<Vec<f32>> {
        ensure!(
            grad.len() == self.table.total_elems,
            "gradient length {} != model {}",
            grad.len(),
            self.table.total_elems
        );
        if self.proto >= wire::PROTO_CHUNK_STREAMED {
            // Streamed: all chunk frames go out back-to-back (single
            // flush), so server-side aggregation of the first chunk runs
            // under the transmission of the rest.
            for (i, c) in self.table.chunks.iter().enumerate() {
                wire::write_chunk_frame_buffered(
                    &mut self.writer,
                    Op::PushChunk,
                    self.job,
                    self.slot,
                    i as u32,
                    c.offset as u64,
                    &wire::f32s_to_bytes(&grad[c.offset..c.offset + c.len]),
                )?;
            }
            self.writer.flush()?;
            self.read_model_chunks()
        } else {
            wire::write_frame(
                &mut self.writer,
                &Frame {
                    op: Op::PushPull,
                    job: self.job,
                    worker: self.slot,
                    payload: wire::f32s_to_bytes(grad),
                },
            )?;
            self.read_model_monolithic()
        }
    }

    /// 2-bit compressed push+pull with error feedback (~16x less gradient
    /// traffic on the wire). On the streamed protocol each chunk is an
    /// independent `QuantGrad` segment with its own residual.
    pub fn push_pull_quant(&mut self, grad: &[f32], threshold: f32) -> Result<Vec<f32>> {
        ensure!(
            grad.len() == self.table.total_elems,
            "gradient length {} != model {}",
            grad.len(),
            self.table.total_elems
        );
        if self.proto >= wire::PROTO_CHUNK_STREAMED {
            if self.chunk_quant.is_none() {
                let lens: Vec<usize> = self.table.chunks.iter().map(|c| c.len).collect();
                self.chunk_quant = Some(ChunkQuantizer::new(&lens, threshold));
            }
            let cq = self.chunk_quant.as_mut().unwrap();
            for (i, c) in self.table.chunks.iter().enumerate() {
                let q = cq.quantize_chunk(i, &grad[c.offset..c.offset + c.len]);
                wire::write_chunk_frame_buffered(
                    &mut self.writer,
                    Op::PushChunkQuant,
                    self.job,
                    self.slot,
                    i as u32,
                    c.offset as u64,
                    &q.to_bytes(),
                )?;
            }
            self.writer.flush()?;
            self.read_model_chunks()
        } else {
            let q = self
                .quantizer
                .get_or_insert_with(|| Quantizer::new(grad.len(), threshold));
            let compressed = q.quantize(grad);
            wire::write_frame(
                &mut self.writer,
                &Frame {
                    op: Op::PushPullQuant,
                    job: self.job,
                    worker: self.slot,
                    payload: compressed.to_bytes(),
                },
            )?;
            self.read_model_monolithic()
        }
    }

    /// v0 reply: one whole-model frame.
    fn read_model_monolithic(&mut self) -> Result<Vec<f32>> {
        let reply = wire::read_frame(&mut self.reader)?;
        if reply.op != Op::Model {
            bail!("expected Model, got {:?}", reply.op);
        }
        Ok(wire::bytes_to_f32s(&reply.payload)?)
    }

    /// v1 reply: one `ModelChunk` frame per chunk, in completion order.
    fn read_model_chunks(&mut self) -> Result<Vec<f32>> {
        let n_chunks = self.table.chunks.len();
        let mut model = vec![0.0f32; self.table.total_elems];
        let mut seen = vec![false; n_chunks];
        for _ in 0..n_chunks {
            let f = wire::read_frame(&mut self.reader)?;
            if f.op != Op::ModelChunk {
                bail!("expected ModelChunk, got {:?}", f.op);
            }
            let (chunk, off, bytes) = wire::decode_chunk_payload(&f.payload)?;
            let ci = chunk as usize;
            ensure!(ci < n_chunks, "model chunk id {ci} out of range");
            let c = self.table.chunks[ci];
            ensure!(off as usize == c.offset, "model chunk {ci} offset mismatch");
            ensure!(!seen[ci], "duplicate model chunk {ci}");
            let data = wire::bytes_to_f32s(bytes)?;
            ensure!(
                data.len() == c.len,
                "model chunk {ci} length {} != {}",
                data.len(),
                c.len
            );
            model[c.offset..c.offset + c.len].copy_from_slice(&data);
            seen[ci] = true;
        }
        Ok(model)
    }

    /// Orderly shutdown.
    pub fn bye(mut self) {
        let _ = wire::write_frame(
            &mut self.writer,
            &Frame {
                op: Op::Bye,
                job: self.job,
                worker: self.slot,
                payload: vec![],
            },
        );
    }
}

#[cfg(test)]
#[allow(clippy::useless_vec)]
mod tests {
    use super::*;

    fn spec(model: u64, workers: u32) -> JobSpec {
        JobSpec {
            model_elems: model,
            chunk_elems: 64,
            n_workers: workers,
            lr: 0.5,
            momentum: 0.0,
        }
    }

    /// Send a raw Hello and wait for the leader to close the connection —
    /// proof the frame was fully processed (and rejected) before we return.
    fn raw_hello_expect_drop(addr: std::net::SocketAddr, job: u32, payload: Vec<u8>) {
        let mut stream = TcpStream::connect(addr).unwrap();
        let mut w = BufWriter::new(stream.try_clone().unwrap());
        wire::write_frame(
            &mut w,
            &Frame {
                op: Op::Hello,
                job,
                worker: 0,
                payload,
            },
        )
        .unwrap();
        let mut buf = [0u8; 64];
        loop {
            match stream.read(&mut buf) {
                Ok(0) | Err(_) => break,
                Ok(_) => {}
            }
        }
    }

    #[test]
    fn spec_roundtrip() {
        let s = spec(4096, 3);
        assert_eq!(JobSpec::from_bytes(&s.to_bytes()).unwrap(), s);
    }

    #[test]
    fn spec_validation() {
        assert!(spec(4096, 3).validate().is_ok());
        assert!(spec(4096, 0).validate().is_err());
        assert!(spec(4096, MAX_WORKERS_PER_JOB + 1).validate().is_err());
        assert!(spec(0, 1).validate().is_err());
        assert!(spec(MAX_MODEL_ELEMS + 1, 1).validate().is_err());
        let mut s = spec(4096, 1);
        s.chunk_elems = 0;
        assert!(s.validate().is_err());
        s.chunk_elems = 8192; // > model_elems
        assert!(s.validate().is_err());
        s = spec(4096, 1);
        s.lr = f32::NAN;
        assert!(s.validate().is_err());
    }

    #[test]
    fn two_workers_over_tcp_match_reference() {
        let leader = TcpLeader::serve("127.0.0.1:0", ServerConfig { n_cores: 2 }).unwrap();
        let addr = leader.local_addr();
        let n = 256usize;
        let s = spec(n as u64, 2);
        let joins: Vec<_> = (0..2)
            .map(|w| {
                std::thread::spawn(move || {
                    let mut worker = TcpWorker::connect(addr, 1, s).unwrap();
                    assert_eq!(worker.proto(), wire::PROTO_CHUNK_STREAMED);
                    let mut model = vec![0.0f32; n];
                    for round in 0..3 {
                        let grad: Vec<f32> =
                            (0..n).map(|i| (w + round) as f32 + i as f32 * 0.01).collect();
                        model = worker.push_pull(&grad).unwrap();
                    }
                    worker.bye();
                    model
                })
            })
            .collect();
        let models: Vec<Vec<f32>> = joins.into_iter().map(|j| j.join().unwrap()).collect();
        assert_eq!(models[0], models[1], "synchronous workers agree");
        // Sequential reference: p -= lr * mean(g) per round.
        let mut p = vec![0.0f32; n];
        for round in 0..3 {
            for i in 0..n {
                let mean = ((round as f32 + i as f32 * 0.01)
                    + (1.0 + round as f32 + i as f32 * 0.01))
                    / 2.0;
                p[i] -= 0.5 * mean;
            }
        }
        for (a, b) in models[0].iter().zip(&p) {
            assert!((a - b).abs() < 1e-4, "{a} vs {b}");
        }
    }

    #[test]
    fn legacy_monolithic_protocol_still_served() {
        let leader = TcpLeader::serve("127.0.0.1:0", ServerConfig { n_cores: 2 }).unwrap();
        let addr = leader.local_addr();
        let n = 192usize;
        let mut w = TcpWorker::connect_with_proto(
            addr,
            5,
            spec(n as u64, 1),
            wire::PROTO_MONOLITHIC,
        )
        .unwrap();
        assert_eq!(w.proto(), wire::PROTO_MONOLITHIC);
        let m = w.push_pull(&vec![2.0; n]).unwrap();
        assert!(m.iter().all(|&x| (x + 1.0).abs() < 1e-6));
        let m = w.push_pull_quant(&vec![0.6; n], 0.5).unwrap();
        assert!(m.iter().all(|&x| (x + 1.25).abs() < 1e-6), "{:?}", &m[..2]);
        w.bye();
    }

    #[test]
    fn quantized_path_tracks_dense_within_threshold() {
        let leader = TcpLeader::serve("127.0.0.1:0", ServerConfig { n_cores: 1 }).unwrap();
        let addr = leader.local_addr();
        let n = 128usize;
        let rounds = 20usize;
        let t = 0.05f32;
        // Single worker: quantized trajectory vs exact math.
        let mut worker = TcpWorker::connect(addr, 2, spec(n as u64, 1)).unwrap();
        let grad = vec![0.03f32; n]; // below threshold: only EF lets it through
        let mut model = vec![0.0f32; n];
        for _ in 0..rounds {
            model = worker.push_pull_quant(&grad, t).unwrap();
        }
        worker.bye();
        // Dense reference: p -= lr * g per round = -0.5*0.03*20 = -0.3.
        // EF guarantees the dequantized stream sum is within `t` of the
        // true sum, so the model is within lr * t of the reference.
        for m in &model {
            assert!((m - (-0.3f32)).abs() <= 0.5 * t + 1e-5, "{m}");
        }
    }

    #[test]
    fn two_jobs_isolated_over_tcp() {
        let leader = TcpLeader::serve("127.0.0.1:0", ServerConfig { n_cores: 1 }).unwrap();
        let addr = leader.local_addr();
        let mut wa = TcpWorker::connect(addr, 10, spec(64, 1)).unwrap();
        let mut wb = TcpWorker::connect(addr, 11, spec(64, 1)).unwrap();
        let ma = wa.push_pull(&vec![1.0; 64]).unwrap();
        let mb = wb.push_pull(&vec![2.0; 64]).unwrap();
        assert!(ma.iter().all(|&x| (x + 0.5).abs() < 1e-6));
        assert!(mb.iter().all(|&x| (x + 1.0).abs() < 1e-6));
    }

    #[test]
    fn leader_survives_abrupt_disconnect_and_releases_the_slot() {
        // Failure injection: a worker vanishes without Bye. The leader
        // must keep serving other jobs AND release the dead worker's slot
        // so the job can still reach N/N after a reconnect.
        let leader = TcpLeader::serve("127.0.0.1:0", ServerConfig { n_cores: 1 }).unwrap();
        let addr = leader.local_addr();
        {
            let w = TcpWorker::connect(addr, 20, spec(64, 2)).unwrap();
            drop(w); // TCP reset, no Bye; job 20 momentarily at 1/2 workers
        }
        // A fresh single-worker job on the same leader still works.
        let mut w2 = TcpWorker::connect(addr, 21, spec(64, 1)).unwrap();
        let m = w2.push_pull(&vec![4.0; 64]).unwrap();
        assert!(m.iter().all(|&x| (x + 2.0).abs() < 1e-6));
        w2.bye();
        // The crashed worker's slot frees once the leader observes the
        // disconnect; admitting two live workers must eventually succeed
        // (pre-fix, job 20 stayed stuck at 1/2 forever).
        let deadline = std::time::Instant::now() + std::time::Duration::from_secs(10);
        loop {
            let a = TcpWorker::connect(addr, 20, spec(64, 2));
            let b = TcpWorker::connect(addr, 20, spec(64, 2));
            match (a, b) {
                (Ok(mut a), Ok(mut b)) => {
                    let ja = std::thread::spawn(move || {
                        let m = a.push_pull(&vec![1.0; 64]).unwrap();
                        a.bye();
                        m
                    });
                    let mb = b.push_pull(&vec![3.0; 64]).unwrap();
                    b.bye();
                    let ma = ja.join().unwrap();
                    assert_eq!(ma, mb, "rejoined workers agree");
                    // p -= 0.5 * mean(1, 3) = -1.
                    assert!(ma.iter().all(|&x| (x + 1.0).abs() < 1e-6));
                    break;
                }
                _ => {
                    assert!(
                        std::time::Instant::now() < deadline,
                        "slot never released after disconnect"
                    );
                    std::thread::sleep(std::time::Duration::from_millis(20));
                }
            }
        }
    }

    #[test]
    fn malformed_payload_drops_connection_not_leader() {
        let leader = TcpLeader::serve("127.0.0.1:0", ServerConfig { n_cores: 1 }).unwrap();
        let addr = leader.local_addr();
        // Raw connection sending a garbage Hello payload.
        raw_hello_expect_drop(addr, 30, vec![1, 2, 3]); // too short for a JobSpec
        // Leader still serves correct clients afterwards.
        let mut ok = TcpWorker::connect(addr, 31, spec(32, 1)).unwrap();
        let m = ok.push_pull(&vec![2.0; 32]).unwrap();
        assert!(m.iter().all(|&x| (x + 1.0).abs() < 1e-6));
        ok.bye();
    }

    /// Regression for the poisoned-lock DoS: a `Hello` whose spec fails
    /// the asserts deep inside `init_job`/`ChunkAggregator::new` used to
    /// panic *inside* `or_insert_with` while holding the jobs mutex,
    /// poisoning it and killing the leader for every subsequent tenant.
    #[test]
    fn hostile_hello_never_poisons_the_jobs_mutex() {
        let leader = TcpLeader::serve("127.0.0.1:0", ServerConfig { n_cores: 1 }).unwrap();
        let addr = leader.local_addr();
        let hostile = [
            spec(64, 0),                      // zero workers
            spec(64, MAX_WORKERS_PER_JOB + 1), // bitmask overflow
            spec(0, 1),                       // empty model
            {
                let mut s = spec(64, 1);
                s.chunk_elems = 0; // division-by-zero chunking
                s
            },
            {
                let mut s = spec(64, 1);
                s.chunk_elems = 128; // chunk bigger than the model
                s
            },
        ];
        for (i, s) in hostile.iter().enumerate() {
            raw_hello_expect_drop(addr, 300 + i as u32, s.to_bytes());
        }
        // The leader must still admit and serve brand-new jobs.
        let mut ok = TcpWorker::connect(addr, 399, spec(32, 1)).unwrap();
        let m = ok.push_pull(&vec![2.0; 32]).unwrap();
        assert!(m.iter().all(|&x| (x + 1.0).abs() < 1e-6));
        ok.bye();
    }

    /// A duplicate chunk push in one round must cost the hostile
    /// connection, not a shared core thread (which would assert and take
    /// down aggregation for every job on that core).
    #[test]
    fn duplicate_chunk_frame_drops_connection_not_cores() {
        let leader = TcpLeader::serve("127.0.0.1:0", ServerConfig { n_cores: 1 }).unwrap();
        let addr = leader.local_addr();
        {
            let stream = TcpStream::connect(addr).unwrap();
            let mut r = BufReader::new(stream.try_clone().unwrap());
            let mut w = BufWriter::new(stream);
            // 2-worker job so the round cannot complete and reset `seen`.
            let s = spec(128, 2);
            let mut payload = s.to_bytes();
            wire::push_proto_version(&mut payload, wire::PROTO_CHUNK_STREAMED);
            wire::write_frame(
                &mut w,
                &Frame {
                    op: Op::Hello,
                    job: 40,
                    worker: 0,
                    payload,
                },
            )
            .unwrap();
            assert_eq!(wire::read_frame(&mut r).unwrap().op, Op::Welcome);
            let chunk0 = wire::encode_chunk_payload(0, 0, &wire::f32s_to_bytes(&[1.0; 64]));
            for _ in 0..2 {
                wire::write_frame(
                    &mut w,
                    &Frame {
                        op: Op::PushChunk,
                        job: 40,
                        worker: 0,
                        payload: chunk0.clone(),
                    },
                )
                .unwrap();
            }
            // Leader must drop us (read yields EOF/err, not a ModelChunk).
            assert!(wire::read_frame(&mut r).is_err());
        }
        // With a single core, any core-thread casualty would break this.
        let mut ok = TcpWorker::connect(addr, 41, spec(32, 1)).unwrap();
        let m = ok.push_pull(&vec![2.0; 32]).unwrap();
        assert!(m.iter().all(|&x| (x + 1.0).abs() < 1e-6));
        ok.bye();
    }

    /// A worker that dies *mid-round* (after some chunks were absorbed
    /// into an open round) must NOT get its slot recycled: a successor
    /// re-pushing those chunks would panic the shared core threads. The
    /// job wedges (documented limitation), but cores, mutex, and every
    /// other job stay healthy.
    #[test]
    fn mid_round_disconnect_does_not_recycle_the_slot() {
        let leader = TcpLeader::serve("127.0.0.1:0", ServerConfig { n_cores: 1 }).unwrap();
        let addr = leader.local_addr();
        {
            let stream = TcpStream::connect(addr).unwrap();
            let mut r = BufReader::new(stream.try_clone().unwrap());
            let mut w = BufWriter::new(stream);
            let s = spec(128, 2); // 2 chunks, 2 workers: round stays open
            let mut payload = s.to_bytes();
            wire::push_proto_version(&mut payload, wire::PROTO_CHUNK_STREAMED);
            wire::write_frame(
                &mut w,
                &Frame {
                    op: Op::Hello,
                    job: 70,
                    worker: 0,
                    payload,
                },
            )
            .unwrap();
            assert_eq!(wire::read_frame(&mut r).unwrap().op, Op::Welcome);
            wire::write_frame(
                &mut w,
                &Frame {
                    op: Op::PushChunk,
                    job: 70,
                    worker: 0,
                    payload: wire::encode_chunk_payload(0, 0, &wire::f32s_to_bytes(&[1.0; 64])),
                },
            )
            .unwrap();
            // Drop mid-round: chunk 0 is absorbed, the round is open.
        }
        // Slot 0 is consumed forever: exactly one more admission fits.
        let _a = TcpWorker::connect(addr, 70, spec(128, 2)).unwrap();
        match TcpWorker::connect(addr, 70, spec(128, 2)) {
            Err(_) => {}
            Ok(mut b) => assert!(b.push_pull(&vec![0.0; 128]).is_err()),
        }
        // Cores survived (single core: any casualty would break this).
        let mut ok = TcpWorker::connect(addr, 71, spec(32, 1)).unwrap();
        let m = ok.push_pull(&vec![2.0; 32]).unwrap();
        assert!(m.iter().all(|&x| (x + 1.0).abs() < 1e-6));
        ok.bye();
    }

    /// The leader hosts at most [`MAX_JOBS`] jobs: cheap `Hello`s with
    /// fresh job ids cannot mint unbounded server state.
    #[test]
    fn job_cap_rejects_excess_jobs() {
        let leader = TcpLeader::serve("127.0.0.1:0", ServerConfig { n_cores: 1 }).unwrap();
        let addr = leader.local_addr();
        let mut keep = Vec::new();
        for j in 0..MAX_JOBS as u32 {
            keep.push(TcpWorker::connect(addr, 1000 + j, spec(32, 1)).unwrap());
        }
        match TcpWorker::connect(addr, 2000, spec(32, 1)) {
            Err(_) => {}
            Ok(mut w) => assert!(w.push_pull(&vec![0.0; 32]).is_err()),
        }
        // Jobs admitted before the cap still train.
        let m = keep[0].push_pull(&vec![2.0; 32]).unwrap();
        assert!(m.iter().all(|&x| (x + 1.0).abs() < 1e-6));
    }

    #[test]
    fn oversubscribed_job_rejected() {
        let leader = TcpLeader::serve("127.0.0.1:0", ServerConfig { n_cores: 1 }).unwrap();
        let addr = leader.local_addr();
        let _w0 = TcpWorker::connect(addr, 3, spec(64, 1)).unwrap();
        // Second worker for a 1-worker job: server drops the connection.
        match TcpWorker::connect(addr, 3, spec(64, 1)) {
            Err(_) => {}
            Ok(mut w) => {
                assert!(w.push_pull(&vec![0.0; 64]).is_err());
            }
        }
    }
}
