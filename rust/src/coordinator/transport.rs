//! Distributed transport: the PHub leader serving workers over TCP.
//!
//! This makes the coordinator a real network service: workers in other
//! processes (or machines) connect, rendezvous (`Hello`/`Welcome` — the
//! wire form of `ConnectService`), and exchange gradients with the same
//! chunked tall-aggregation engine the in-process path uses. The paper's
//! data plane is InfiniBand verbs with zero copy; this environment has
//! neither RDMA NICs nor kernel-bypass, so the transport is length-framed
//! TCP — the *architecture* (one connection per worker, chunk routing to
//! pinned cores, fused aggregation+optimization, dense or 2-bit-compressed
//! pushes) is the paper's.

use std::collections::HashMap;
use std::io::{BufReader, BufWriter};
use std::net::{TcpListener, TcpStream, ToSocketAddrs};
use std::sync::{Arc, Mutex};

use anyhow::{bail, Context, Result};

use super::chunk::KeyTable;
use super::compress::{QuantGrad, Quantizer};
use super::optimizer::NesterovSgd;
use super::server::{JobId, PHubServer, ServerConfig};
use super::wire::{self, Frame, Op};

/// Job parameters carried in `Hello`.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct JobSpec {
    pub model_elems: u64,
    pub chunk_elems: u64,
    pub n_workers: u32,
    pub lr: f32,
    pub momentum: f32,
}

impl JobSpec {
    fn to_bytes(self) -> Vec<u8> {
        let mut out = Vec::with_capacity(28);
        out.extend_from_slice(&self.model_elems.to_le_bytes());
        out.extend_from_slice(&self.chunk_elems.to_le_bytes());
        out.extend_from_slice(&self.n_workers.to_le_bytes());
        out.extend_from_slice(&self.lr.to_le_bytes());
        out.extend_from_slice(&self.momentum.to_le_bytes());
        out
    }

    fn from_bytes(b: &[u8]) -> Result<JobSpec> {
        if b.len() < 28 {
            bail!("short Hello payload");
        }
        Ok(JobSpec {
            model_elems: u64::from_le_bytes(b[0..8].try_into().unwrap()),
            chunk_elems: u64::from_le_bytes(b[8..16].try_into().unwrap()),
            n_workers: u32::from_le_bytes(b[16..20].try_into().unwrap()),
            lr: f32::from_le_bytes(b[20..24].try_into().unwrap()),
            momentum: f32::from_le_bytes(b[24..28].try_into().unwrap()),
        })
    }
}

struct JobEntry {
    job: JobId,
    spec: JobSpec,
    next_slot: u32,
}

/// The TCP leader: accepts workers and serves exchanges.
pub struct TcpLeader {
    server: Arc<PHubServer>,
    local_addr: std::net::SocketAddr,
}

impl TcpLeader {
    /// Bind and start serving in background threads. `bind` may be
    /// `"127.0.0.1:0"` to pick a free port (see `local_addr`).
    pub fn serve(bind: impl ToSocketAddrs, cfg: ServerConfig) -> Result<Arc<TcpLeader>> {
        let listener = TcpListener::bind(bind).context("bind leader socket")?;
        let local_addr = listener.local_addr()?;
        let server = PHubServer::start(cfg);
        let leader = Arc::new(TcpLeader {
            server: server.clone(),
            local_addr,
        });
        let jobs: Arc<Mutex<HashMap<u32, JobEntry>>> = Arc::new(Mutex::new(HashMap::new()));
        {
            let server = server.clone();
            std::thread::Builder::new()
                .name("phub-accept".into())
                .spawn(move || {
                    for stream in listener.incoming() {
                        let Ok(stream) = stream else { break };
                        let server = server.clone();
                        let jobs = jobs.clone();
                        std::thread::spawn(move || {
                            let _ = handle_worker(stream, server, jobs);
                        });
                    }
                })
                .context("spawn accept thread")?;
        }
        Ok(leader)
    }

    pub fn local_addr(&self) -> std::net::SocketAddr {
        self.local_addr
    }

    pub fn server(&self) -> &Arc<PHubServer> {
        &self.server
    }
}

/// Per-connection worker service loop.
fn handle_worker(
    stream: TcpStream,
    server: Arc<PHubServer>,
    jobs: Arc<Mutex<HashMap<u32, JobEntry>>>,
) -> Result<()> {
    stream.set_nodelay(true).ok();
    let mut reader = BufReader::new(stream.try_clone()?);
    let mut writer = BufWriter::new(stream);

    // Rendezvous.
    let hello = wire::read_frame(&mut reader)?;
    if hello.op != Op::Hello {
        bail!("expected Hello, got {:?}", hello.op);
    }
    let spec = JobSpec::from_bytes(&hello.payload)?;
    let (job, slot) = {
        let mut map = jobs.lock().unwrap();
        let entry = map.entry(hello.job).or_insert_with(|| {
            let table = KeyTable::flat(spec.model_elems as usize, spec.chunk_elems as usize);
            let job = server.init_job(
                table,
                &vec![0.0; spec.model_elems as usize],
                Arc::new(NesterovSgd {
                    lr: spec.lr,
                    momentum: spec.momentum,
                }),
                spec.n_workers as usize,
            );
            JobEntry {
                job,
                spec,
                next_slot: 0,
            }
        });
        if entry.spec != spec {
            bail!("job {} spec mismatch", hello.job);
        }
        let slot = entry.next_slot;
        entry.next_slot += 1;
        if slot >= spec.n_workers {
            bail!("job {} already has {} workers", hello.job, spec.n_workers);
        }
        (entry.job, slot)
    };
    let mut handle = server.worker(job, slot as usize);
    wire::write_frame(
        &mut writer,
        &Frame {
            op: Op::Welcome,
            job: hello.job,
            worker: slot,
            payload: slot.to_le_bytes().to_vec(),
        },
    )?;

    // Exchange loop. Each connection thread blocks in push_pull — the
    // chunk fan-out/fan-in runs on the core threads, so workers on other
    // connections proceed concurrently (one service thread per worker,
    // like one QP per worker-interface pair).
    loop {
        let f = match wire::read_frame(&mut reader) {
            Ok(f) => f,
            Err(_) => return Ok(()), // disconnect = Bye
        };
        match f.op {
            Op::PushPull => {
                let grad = wire::bytes_to_f32s(&f.payload)?;
                let model = handle.push_pull(&grad);
                wire::write_frame(
                    &mut writer,
                    &Frame {
                        op: Op::Model,
                        job: f.job,
                        worker: slot,
                        payload: wire::f32s_to_bytes(&model),
                    },
                )?;
            }
            Op::PushPullQuant => {
                // Compressed push: dequantize at the server edge, then the
                // normal dense tall-aggregation path (paper section 5).
                let q = QuantGrad::from_bytes(&f.payload)?;
                let grad = q.dequantize();
                let model = handle.push_pull(&grad);
                wire::write_frame(
                    &mut writer,
                    &Frame {
                        op: Op::Model,
                        job: f.job,
                        worker: slot,
                        payload: wire::f32s_to_bytes(&model),
                    },
                )?;
            }
            Op::Bye => return Ok(()),
            other => bail!("unexpected opcode {:?}", other),
        }
    }
}

/// A remote worker's connection to a [`TcpLeader`].
pub struct TcpWorker {
    reader: BufReader<TcpStream>,
    writer: BufWriter<TcpStream>,
    job: u32,
    pub slot: u32,
    /// Error-feedback state for the compressed path.
    quantizer: Option<Quantizer>,
}

impl TcpWorker {
    /// Connect and rendezvous. All workers of a job must present an
    /// identical `spec` (the first one creates the job server-side).
    pub fn connect(addr: impl ToSocketAddrs, job: u32, spec: JobSpec) -> Result<TcpWorker> {
        let stream = TcpStream::connect(addr).context("connect to leader")?;
        stream.set_nodelay(true).ok();
        let mut reader = BufReader::new(stream.try_clone()?);
        let mut writer = BufWriter::new(stream);
        wire::write_frame(
            &mut writer,
            &Frame {
                op: Op::Hello,
                job,
                worker: 0,
                payload: spec.to_bytes(),
            },
        )?;
        let welcome = wire::read_frame(&mut reader)?;
        if welcome.op != Op::Welcome {
            bail!("expected Welcome, got {:?}", welcome.op);
        }
        Ok(TcpWorker {
            reader,
            writer,
            job,
            slot: welcome.worker,
            quantizer: None,
        })
    }

    /// Dense fused push+pull.
    pub fn push_pull(&mut self, grad: &[f32]) -> Result<Vec<f32>> {
        wire::write_frame(
            &mut self.writer,
            &Frame {
                op: Op::PushPull,
                job: self.job,
                worker: self.slot,
                payload: wire::f32s_to_bytes(grad),
            },
        )?;
        let reply = wire::read_frame(&mut self.reader)?;
        if reply.op != Op::Model {
            bail!("expected Model, got {:?}", reply.op);
        }
        Ok(wire::bytes_to_f32s(&reply.payload)?)
    }

    /// 2-bit compressed push+pull with error feedback (~16x less gradient
    /// traffic on the wire).
    pub fn push_pull_quant(&mut self, grad: &[f32], threshold: f32) -> Result<Vec<f32>> {
        let q = self
            .quantizer
            .get_or_insert_with(|| Quantizer::new(grad.len(), threshold));
        let compressed = q.quantize(grad);
        wire::write_frame(
            &mut self.writer,
            &Frame {
                op: Op::PushPullQuant,
                job: self.job,
                worker: self.slot,
                payload: compressed.to_bytes(),
            },
        )?;
        let reply = wire::read_frame(&mut self.reader)?;
        if reply.op != Op::Model {
            bail!("expected Model, got {:?}", reply.op);
        }
        Ok(wire::bytes_to_f32s(&reply.payload)?)
    }

    /// Orderly shutdown.
    pub fn bye(mut self) {
        let _ = wire::write_frame(
            &mut self.writer,
            &Frame {
                op: Op::Bye,
                job: self.job,
                worker: self.slot,
                payload: vec![],
            },
        );
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn spec(model: u64, workers: u32) -> JobSpec {
        JobSpec {
            model_elems: model,
            chunk_elems: 64,
            n_workers: workers,
            lr: 0.5,
            momentum: 0.0,
        }
    }

    #[test]
    fn spec_roundtrip() {
        let s = spec(4096, 3);
        assert_eq!(JobSpec::from_bytes(&s.to_bytes()).unwrap(), s);
    }

    #[test]
    fn two_workers_over_tcp_match_reference() {
        let leader = TcpLeader::serve("127.0.0.1:0", ServerConfig { n_cores: 2 }).unwrap();
        let addr = leader.local_addr();
        let n = 256usize;
        let s = spec(n as u64, 2);
        let joins: Vec<_> = (0..2)
            .map(|w| {
                std::thread::spawn(move || {
                    let mut worker = TcpWorker::connect(addr, 1, s).unwrap();
                    let mut model = vec![0.0f32; n];
                    for round in 0..3 {
                        let grad: Vec<f32> =
                            (0..n).map(|i| (w + round) as f32 + i as f32 * 0.01).collect();
                        model = worker.push_pull(&grad).unwrap();
                    }
                    worker.bye();
                    model
                })
            })
            .collect();
        let models: Vec<Vec<f32>> = joins.into_iter().map(|j| j.join().unwrap()).collect();
        assert_eq!(models[0], models[1], "synchronous workers agree");
        // Sequential reference: p -= lr * mean(g) per round.
        let mut p = vec![0.0f32; n];
        for round in 0..3 {
            for i in 0..n {
                let mean = ((round as f32 + i as f32 * 0.01)
                    + (1.0 + round as f32 + i as f32 * 0.01))
                    / 2.0;
                p[i] -= 0.5 * mean;
            }
        }
        for (a, b) in models[0].iter().zip(&p) {
            assert!((a - b).abs() < 1e-4, "{a} vs {b}");
        }
    }

    #[test]
    fn quantized_path_tracks_dense_within_threshold() {
        let leader = TcpLeader::serve("127.0.0.1:0", ServerConfig { n_cores: 1 }).unwrap();
        let addr = leader.local_addr();
        let n = 128usize;
        let rounds = 20usize;
        let t = 0.05f32;
        // Single worker: quantized trajectory vs exact math.
        let mut worker = TcpWorker::connect(addr, 2, spec(n as u64, 1)).unwrap();
        let grad = vec![0.03f32; n]; // below threshold: only EF lets it through
        let mut model = vec![0.0f32; n];
        for _ in 0..rounds {
            model = worker.push_pull_quant(&grad, t).unwrap();
        }
        worker.bye();
        // Dense reference: p -= lr * g per round = -0.5*0.03*20 = -0.3.
        // EF guarantees the dequantized stream sum is within `t` of the
        // true sum, so the model is within lr * t of the reference.
        for m in &model {
            assert!((m - (-0.3f32)).abs() <= 0.5 * t + 1e-5, "{m}");
        }
    }

    #[test]
    fn two_jobs_isolated_over_tcp() {
        let leader = TcpLeader::serve("127.0.0.1:0", ServerConfig { n_cores: 1 }).unwrap();
        let addr = leader.local_addr();
        let mut wa = TcpWorker::connect(addr, 10, spec(64, 1)).unwrap();
        let mut wb = TcpWorker::connect(addr, 11, spec(64, 1)).unwrap();
        let ma = wa.push_pull(&vec![1.0; 64]).unwrap();
        let mb = wb.push_pull(&vec![2.0; 64]).unwrap();
        assert!(ma.iter().all(|&x| (x + 0.5).abs() < 1e-6));
        assert!(mb.iter().all(|&x| (x + 1.0).abs() < 1e-6));
    }

    #[test]
    fn leader_survives_abrupt_disconnect() {
        // Failure injection: a worker vanishes without Bye; the leader
        // must keep serving other jobs.
        let leader = TcpLeader::serve("127.0.0.1:0", ServerConfig { n_cores: 1 }).unwrap();
        let addr = leader.local_addr();
        {
            let w = TcpWorker::connect(addr, 20, spec(64, 2)).unwrap();
            drop(w); // TCP reset, no Bye, job 20 now stuck at 1/2 workers
        }
        // A fresh single-worker job on the same leader still works.
        let mut w2 = TcpWorker::connect(addr, 21, spec(64, 1)).unwrap();
        let m = w2.push_pull(&vec![4.0; 64]).unwrap();
        assert!(m.iter().all(|&x| (x + 2.0).abs() < 1e-6));
        w2.bye();
    }

    #[test]
    fn malformed_payload_drops_connection_not_leader() {
        use super::super::wire::{self, Frame, Op};
        use std::io::BufWriter;
        use std::net::TcpStream;
        let leader = TcpLeader::serve("127.0.0.1:0", ServerConfig { n_cores: 1 }).unwrap();
        let addr = leader.local_addr();
        // Raw connection sending a garbage Hello payload.
        {
            let stream = TcpStream::connect(addr).unwrap();
            let mut w = BufWriter::new(stream);
            wire::write_frame(
                &mut w,
                &Frame {
                    op: Op::Hello,
                    job: 30,
                    worker: 0,
                    payload: vec![1, 2, 3], // too short for a JobSpec
                },
            )
            .unwrap();
        }
        // Leader still serves correct clients afterwards.
        let mut ok = TcpWorker::connect(addr, 31, spec(32, 1)).unwrap();
        let m = ok.push_pull(&vec![2.0; 32]).unwrap();
        assert!(m.iter().all(|&x| (x + 1.0).abs() < 1e-6));
        ok.bye();
    }

    #[test]
    fn oversubscribed_job_rejected() {
        let leader = TcpLeader::serve("127.0.0.1:0", ServerConfig { n_cores: 1 }).unwrap();
        let addr = leader.local_addr();
        let _w0 = TcpWorker::connect(addr, 3, spec(64, 1)).unwrap();
        // Second worker for a 1-worker job: server drops the connection.
        match TcpWorker::connect(addr, 3, spec(64, 1)) {
            Err(_) => {}
            Ok(mut w) => {
                assert!(w.push_pull(&vec![0.0; 64]).is_err());
            }
        }
    }
}
