//! Explicit SIMD aggregation/optimizer kernels with runtime dispatch
//! (paper sections 4.2–4.3).
//!
//! PHub's data plane is memory-bandwidth-bound: once the round is
//! allocation- and mutex-free (PRs 3–4), raw kernel throughput is the
//! dominant cost of a leader round. The five hot loops — dense LE-byte
//! absorb fold, copy-on-first-arrival, 2-bit dequantize+absorb fused,
//! fused mean+SGD, fused mean+Nesterov — live here as explicit
//! `core::arch::x86_64` implementations, selected once at startup:
//!
//! * **AVX2** (8 f32 lanes) when `is_x86_feature_detected!("avx2")`;
//! * **SSE2** (4 f32 lanes), the x86_64 baseline — always available
//!   there, so x86_64 never falls back to scalar unless asked to;
//! * **scalar**, the previous lane-chunked autovectorizer-shaped code,
//!   verbatim — the reference every vector path is property-tested
//!   bit-identical to, and the only tier on non-x86_64 targets.
//!
//! The `PHUB_KERNELS` environment variable (`scalar` | `sse2` | `avx2`)
//! overrides detection so both dispatch arms are testable anywhere; an
//! unknown value or an unavailable tier falls back to detection. The
//! selected tier is recorded in `DataPlaneMetrics::kernel_tier` by
//! `PHubServer::start`.
//!
//! # Kernel dispatch contract
//!
//! | rule | why |
//! |---|---|
//! | Raw `unsafe` tier impls are module-private; only the dispatchers in this file call them | every call site must carry a CPU-feature proof, and the dispatchers are the single place that proof is established |
//! | Hot paths call the safe top-level fns (`copy_f32s_le`, …), which branch on the cached [`active_tier`] | `resolve` only ever returns an available tier, so the `unsafe` call is sound by construction |
//! | Tests/benches use the `*_tier` variants, which `assert!` availability first | lets both arms run in one process without mutating global state |
//! | No alignment is assumed anywhere: all vector memory ops are unaligned (`loadu`/`storeu`) | wire payloads arrive at arbitrary offsets inside pooled frames |
//! | Wire bytes are reinterpreted in place — x86_64 is little-endian, so a `loadu` of LE bytes *is* `f32::from_le_bytes`, bit for bit | NaN payloads and denormals must survive the decode untouched |
//! | No FMA, ever, and vector operand order mirrors the scalar source text exactly | scalar Rust rounds `a * b + c` twice (no contraction), and x86's both-operands-NaN rule picks src1 — matching textual order makes NaN propagation identical |
//! | Vector main loop + scalar tail, split at a lane multiple | the tail is the scalar reference itself, so remainders are trivially bit-identical |
//! | Steady-state calls allocate nothing; the one-time `resolve` (env read) runs on first use | first use is warm-up in every driver, so `alloc_discipline.rs` holds with dispatch enabled |
//!
//! `aggregation.rs` (byte-fold entry points) and `optimizer.rs` (fused
//! `step_scaled` for both built-ins) delegate their inner loops here;
//! `aggregation::add_assign`/`scale` stay lane-chunked in place — the
//! slice path is the in-process reference, not a wire hot loop.

use std::sync::atomic::{AtomicU8, Ordering};

/// Environment variable overriding kernel-tier detection
/// (`scalar` | `sse2` | `avx2`, case-insensitive).
pub const ENV_KERNELS: &str = "PHUB_KERNELS";

/// Lane width of the scalar chunked loops (and the AVX2 vector width).
/// Eight f32s = one 256-bit vector.
const LANES: usize = 8;

/// A SIMD implementation tier. Discriminants are stable and mirrored in
/// `DataPlaneMetrics::kernel_tier`.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
#[repr(u8)]
pub enum KernelTier {
    /// The lane-chunked reference loops (any target).
    Scalar = 0,
    /// 128-bit `core::arch::x86_64` paths (x86_64 baseline).
    Sse2 = 1,
    /// 256-bit paths; requires runtime AVX2 detection.
    Avx2 = 2,
}

impl KernelTier {
    pub fn name(self) -> &'static str {
        match self {
            KernelTier::Scalar => "scalar",
            KernelTier::Sse2 => "sse2",
            KernelTier::Avx2 => "avx2",
        }
    }

    /// Inverse of `tier as u8` (for metrics readers).
    pub fn from_u8(v: u8) -> Option<KernelTier> {
        match v {
            0 => Some(KernelTier::Scalar),
            1 => Some(KernelTier::Sse2),
            2 => Some(KernelTier::Avx2),
            _ => None,
        }
    }
}

/// Whether `tier`'s kernels can run on this machine.
pub fn tier_available(tier: KernelTier) -> bool {
    match tier {
        KernelTier::Scalar => true,
        #[cfg(target_arch = "x86_64")]
        KernelTier::Sse2 => true,
        #[cfg(target_arch = "x86_64")]
        KernelTier::Avx2 => std::arch::is_x86_feature_detected!("avx2"),
        #[cfg(not(target_arch = "x86_64"))]
        _ => false,
    }
}

/// Every tier runnable on this machine, scalar first (for tests and
/// benches that sweep tiers; allocates, so not for the data plane).
pub fn available_tiers() -> Vec<KernelTier> {
    let mut v = vec![KernelTier::Scalar];
    if tier_available(KernelTier::Sse2) {
        v.push(KernelTier::Sse2);
    }
    if tier_available(KernelTier::Avx2) {
        v.push(KernelTier::Avx2);
    }
    v
}

const TIER_UNRESOLVED: u8 = u8::MAX;
static ACTIVE_TIER: AtomicU8 = AtomicU8::new(TIER_UNRESOLVED);

/// The tier every hot-path kernel dispatches to, resolved once per
/// process (env override, else best detected) and cached. The first call
/// reads the environment (allocates); every later call is one relaxed
/// atomic load — drivers hit it during warm-up, keeping steady-state
/// rounds allocation-free.
#[inline]
pub fn active_tier() -> KernelTier {
    match KernelTier::from_u8(ACTIVE_TIER.load(Ordering::Relaxed)) {
        Some(t) => t,
        None => {
            // Benign race: concurrent first calls resolve to the same
            // value and the store is idempotent.
            let t = resolve(std::env::var(ENV_KERNELS).ok().as_deref());
            ACTIVE_TIER.store(t as u8, Ordering::Relaxed);
            t
        }
    }
}

/// Tier selection: an explicit, available override wins; anything else
/// (unset, unknown word, tier this CPU lacks) falls back to the best
/// detected tier.
fn resolve(env: Option<&str>) -> KernelTier {
    let best = if tier_available(KernelTier::Avx2) {
        KernelTier::Avx2
    } else if tier_available(KernelTier::Sse2) {
        KernelTier::Sse2
    } else {
        KernelTier::Scalar
    };
    let req = match env.map(|v| v.to_ascii_lowercase()) {
        Some(v) if v == "scalar" => Some(KernelTier::Scalar),
        Some(v) if v == "sse2" => Some(KernelTier::Sse2),
        Some(v) if v == "avx2" => Some(KernelTier::Avx2),
        _ => None,
    };
    match req {
        Some(t) if tier_available(t) => t,
        _ => best,
    }
}

#[track_caller]
fn assert_available(tier: KernelTier) {
    assert!(
        tier_available(tier),
        "kernel tier {:?} is not available on this CPU",
        tier.name()
    );
}

// ---------------------------------------------------------------------
// Safe entry points. The argless forms are the hot path (dispatch on the
// cached active tier); the `_tier` forms are for tests and benches and
// assert availability before descending into `unsafe`.
// ---------------------------------------------------------------------

/// `dst = le_bytes` reinterpreted as little-endian f32s (bit-exact; NaN
/// payloads survive). `le_bytes.len()` must be `4 * dst.len()`.
#[inline]
pub fn copy_f32s_le(dst: &mut [f32], le_bytes: &[u8]) {
    copy_f32s_le_dispatch(active_tier(), dst, le_bytes)
}

/// [`copy_f32s_le`] on an explicit tier (panics if unavailable).
pub fn copy_f32s_le_tier(tier: KernelTier, dst: &mut [f32], le_bytes: &[u8]) {
    assert_available(tier);
    copy_f32s_le_dispatch(tier, dst, le_bytes)
}

/// `acc += le_bytes` reinterpreted as little-endian f32s: the byte-level
/// aggregation fold — decode and accumulate in one pass.
#[inline]
pub fn add_assign_le(acc: &mut [f32], le_bytes: &[u8]) {
    add_assign_le_dispatch(active_tier(), acc, le_bytes)
}

/// [`add_assign_le`] on an explicit tier (panics if unavailable).
pub fn add_assign_le_tier(tier: KernelTier, acc: &mut [f32], le_bytes: &[u8]) {
    assert_available(tier);
    add_assign_le_dispatch(tier, acc, le_bytes)
}

/// `dst = dequantize(packed)`: 4 2-bit levels per byte (0b00 = 0,
/// 0b01 = +t, 0b10 = −t). `packed.len()` must be `dst.len().div_ceil(4)`.
#[inline]
pub fn copy_dequant(dst: &mut [f32], threshold: f32, packed: &[u8]) {
    copy_dequant_dispatch(active_tier(), dst, threshold, packed)
}

/// [`copy_dequant`] on an explicit tier (panics if unavailable).
pub fn copy_dequant_tier(tier: KernelTier, dst: &mut [f32], threshold: f32, packed: &[u8]) {
    assert_available(tier);
    copy_dequant_dispatch(tier, dst, threshold, packed)
}

/// `acc += dequantize(packed)`: dequantization folded into the
/// accumulate — the 2-bit wire path never materializes a dense vector.
#[inline]
pub fn add_assign_dequant(acc: &mut [f32], threshold: f32, packed: &[u8]) {
    add_assign_dequant_dispatch(active_tier(), acc, threshold, packed)
}

/// [`add_assign_dequant`] on an explicit tier (panics if unavailable).
pub fn add_assign_dequant_tier(tier: KernelTier, acc: &mut [f32], threshold: f32, packed: &[u8]) {
    assert_available(tier);
    add_assign_dequant_dispatch(tier, acc, threshold, packed)
}

/// Fused mean+SGD: `params[i] -= lr * (grad_sum[i] * inv_n)`, with the
/// mean computed (and rounded) first, exactly like the unfused
/// scale-then-step sequence.
#[inline]
pub fn sgd_step_scaled(params: &mut [f32], grad_sum: &[f32], inv_n: f32, lr: f32) {
    sgd_dispatch(active_tier(), params, grad_sum, inv_n, lr)
}

/// [`sgd_step_scaled`] on an explicit tier (panics if unavailable).
pub fn sgd_step_scaled_tier(
    tier: KernelTier,
    params: &mut [f32],
    grad_sum: &[f32],
    inv_n: f32,
    lr: f32,
) {
    assert_available(tier);
    sgd_dispatch(tier, params, grad_sum, inv_n, lr)
}

/// Fused mean+Nesterov (MXNet rule): per element,
/// `g = sum * inv_n; m' = mu * m + g; p -= lr * (g + mu * m')`.
#[inline]
pub fn nesterov_step_scaled(
    params: &mut [f32],
    state: &mut [f32],
    grad_sum: &[f32],
    inv_n: f32,
    lr: f32,
    mu: f32,
) {
    nesterov_dispatch(active_tier(), params, state, grad_sum, inv_n, lr, mu)
}

/// [`nesterov_step_scaled`] on an explicit tier (panics if unavailable).
pub fn nesterov_step_scaled_tier(
    tier: KernelTier,
    params: &mut [f32],
    state: &mut [f32],
    grad_sum: &[f32],
    inv_n: f32,
    lr: f32,
    mu: f32,
) {
    assert_available(tier);
    nesterov_dispatch(tier, params, state, grad_sum, inv_n, lr, mu)
}

// ---------------------------------------------------------------------
// Dispatchers: the only call sites of the raw `unsafe` tier impls.
// SAFETY (all six): the tier is available — either it came from
// `resolve`, which only returns available tiers, or the public `_tier`
// wrapper asserted `tier_available` — so the `#[target_feature]`
// functions' CPU requirement holds.
// ---------------------------------------------------------------------

#[inline]
fn copy_f32s_le_dispatch(tier: KernelTier, dst: &mut [f32], le_bytes: &[u8]) {
    debug_assert_eq!(le_bytes.len(), dst.len() * 4);
    match tier {
        KernelTier::Scalar => scalar::copy_f32s_le(dst, le_bytes),
        #[cfg(target_arch = "x86_64")]
        KernelTier::Sse2 => unsafe { x86::copy_f32s_le_sse2(dst, le_bytes) },
        #[cfg(target_arch = "x86_64")]
        KernelTier::Avx2 => unsafe { x86::copy_f32s_le_avx2(dst, le_bytes) },
        #[cfg(not(target_arch = "x86_64"))]
        _ => scalar::copy_f32s_le(dst, le_bytes),
    }
}

#[inline]
fn add_assign_le_dispatch(tier: KernelTier, acc: &mut [f32], le_bytes: &[u8]) {
    debug_assert_eq!(le_bytes.len(), acc.len() * 4);
    match tier {
        KernelTier::Scalar => scalar::add_assign_le(acc, le_bytes),
        #[cfg(target_arch = "x86_64")]
        KernelTier::Sse2 => unsafe { x86::add_assign_le_sse2(acc, le_bytes) },
        #[cfg(target_arch = "x86_64")]
        KernelTier::Avx2 => unsafe { x86::add_assign_le_avx2(acc, le_bytes) },
        #[cfg(not(target_arch = "x86_64"))]
        _ => scalar::add_assign_le(acc, le_bytes),
    }
}

#[inline]
fn copy_dequant_dispatch(tier: KernelTier, dst: &mut [f32], threshold: f32, packed: &[u8]) {
    debug_assert_eq!(packed.len(), dst.len().div_ceil(4));
    match tier {
        KernelTier::Scalar => scalar::copy_dequant(dst, threshold, packed),
        #[cfg(target_arch = "x86_64")]
        KernelTier::Sse2 => unsafe { x86::copy_dequant_sse2(dst, threshold, packed) },
        #[cfg(target_arch = "x86_64")]
        KernelTier::Avx2 => unsafe { x86::copy_dequant_avx2(dst, threshold, packed) },
        #[cfg(not(target_arch = "x86_64"))]
        _ => scalar::copy_dequant(dst, threshold, packed),
    }
}

#[inline]
fn add_assign_dequant_dispatch(tier: KernelTier, acc: &mut [f32], threshold: f32, packed: &[u8]) {
    debug_assert_eq!(packed.len(), acc.len().div_ceil(4));
    match tier {
        KernelTier::Scalar => scalar::add_assign_dequant(acc, threshold, packed),
        #[cfg(target_arch = "x86_64")]
        KernelTier::Sse2 => unsafe { x86::add_assign_dequant_sse2(acc, threshold, packed) },
        #[cfg(target_arch = "x86_64")]
        KernelTier::Avx2 => unsafe { x86::add_assign_dequant_avx2(acc, threshold, packed) },
        #[cfg(not(target_arch = "x86_64"))]
        _ => scalar::add_assign_dequant(acc, threshold, packed),
    }
}

#[inline]
fn sgd_dispatch(tier: KernelTier, params: &mut [f32], grad_sum: &[f32], inv_n: f32, lr: f32) {
    debug_assert_eq!(params.len(), grad_sum.len());
    match tier {
        KernelTier::Scalar => scalar::sgd_step_scaled(params, grad_sum, inv_n, lr),
        #[cfg(target_arch = "x86_64")]
        KernelTier::Sse2 => unsafe { x86::sgd_step_scaled_sse2(params, grad_sum, inv_n, lr) },
        #[cfg(target_arch = "x86_64")]
        KernelTier::Avx2 => unsafe { x86::sgd_step_scaled_avx2(params, grad_sum, inv_n, lr) },
        #[cfg(not(target_arch = "x86_64"))]
        _ => scalar::sgd_step_scaled(params, grad_sum, inv_n, lr),
    }
}

#[inline]
fn nesterov_dispatch(
    tier: KernelTier,
    params: &mut [f32],
    state: &mut [f32],
    grad_sum: &[f32],
    inv_n: f32,
    lr: f32,
    mu: f32,
) {
    debug_assert_eq!(params.len(), grad_sum.len());
    debug_assert_eq!(state.len(), grad_sum.len());
    match tier {
        KernelTier::Scalar => scalar::nesterov_step_scaled(params, state, grad_sum, inv_n, lr, mu),
        #[cfg(target_arch = "x86_64")]
        KernelTier::Sse2 => unsafe {
            x86::nesterov_step_scaled_sse2(params, state, grad_sum, inv_n, lr, mu)
        },
        #[cfg(target_arch = "x86_64")]
        KernelTier::Avx2 => unsafe {
            x86::nesterov_step_scaled_avx2(params, state, grad_sum, inv_n, lr, mu)
        },
        #[cfg(not(target_arch = "x86_64"))]
        _ => scalar::nesterov_step_scaled(params, state, grad_sum, inv_n, lr, mu),
    }
}

// ---------------------------------------------------------------------
// Scalar reference tier: the lane-chunked loops exactly as they stood in
// aggregation.rs/optimizer.rs before this module existed. Every vector
// path is property-tested bit-identical to these, and they double as the
// tail code of the vector paths (so remainders are the reference by
// construction).
// ---------------------------------------------------------------------

pub mod scalar {
    use super::LANES;

    /// Decode one 2-bit level (0b00 = 0, 0b01 = +t, 0b10 = −t). The
    /// single home of the decode mapping — `QuantGrad::dequantize` and
    /// every vector path implement exactly this table.
    #[inline(always)]
    pub fn dequant_level(threshold: f32, code: u8) -> f32 {
        match code & 0b11 {
            0b01 => threshold,
            0b10 => -threshold,
            _ => 0.0,
        }
    }

    #[inline]
    pub fn copy_f32s_le(dst: &mut [f32], le_bytes: &[u8]) {
        debug_assert_eq!(le_bytes.len(), dst.len() * 4);
        let mut d = dst.chunks_exact_mut(LANES);
        let mut s = le_bytes.chunks_exact(LANES * 4);
        for (dd, ss) in (&mut d).zip(&mut s) {
            for i in 0..LANES {
                dd[i] = f32::from_le_bytes(ss[i * 4..i * 4 + 4].try_into().unwrap());
            }
        }
        for (dd, ss) in d
            .into_remainder()
            .iter_mut()
            .zip(s.remainder().chunks_exact(4))
        {
            *dd = f32::from_le_bytes(ss.try_into().unwrap());
        }
    }

    #[inline]
    pub fn add_assign_le(acc: &mut [f32], le_bytes: &[u8]) {
        debug_assert_eq!(le_bytes.len(), acc.len() * 4);
        let mut a = acc.chunks_exact_mut(LANES);
        let mut s = le_bytes.chunks_exact(LANES * 4);
        for (aa, ss) in (&mut a).zip(&mut s) {
            for i in 0..LANES {
                aa[i] += f32::from_le_bytes(ss[i * 4..i * 4 + 4].try_into().unwrap());
            }
        }
        for (aa, ss) in a
            .into_remainder()
            .iter_mut()
            .zip(s.remainder().chunks_exact(4))
        {
            *aa += f32::from_le_bytes(ss.try_into().unwrap());
        }
    }

    #[inline]
    pub fn copy_dequant(dst: &mut [f32], threshold: f32, packed: &[u8]) {
        debug_assert_eq!(packed.len(), dst.len().div_ceil(4));
        // Split at a lane boundary explicitly: the tail's packed bytes
        // start at `main / 4` (exact, since `main` is a multiple of LANES).
        let main = dst.len() / LANES * LANES;
        let (dm, dr) = dst.split_at_mut(main);
        for (dd, pp) in dm
            .chunks_exact_mut(LANES)
            .zip(packed[..main / 4].chunks_exact(LANES / 4))
        {
            for i in 0..LANES {
                dd[i] = dequant_level(threshold, pp[i / 4] >> ((i % 4) * 2));
            }
        }
        let pr = &packed[main / 4..];
        for (i, x) in dr.iter_mut().enumerate() {
            *x = dequant_level(threshold, pr[i / 4] >> ((i % 4) * 2));
        }
    }

    #[inline]
    pub fn add_assign_dequant(acc: &mut [f32], threshold: f32, packed: &[u8]) {
        debug_assert_eq!(packed.len(), acc.len().div_ceil(4));
        let main = acc.len() / LANES * LANES;
        let (am, ar) = acc.split_at_mut(main);
        for (aa, pp) in am
            .chunks_exact_mut(LANES)
            .zip(packed[..main / 4].chunks_exact(LANES / 4))
        {
            for i in 0..LANES {
                aa[i] += dequant_level(threshold, pp[i / 4] >> ((i % 4) * 2));
            }
        }
        let pr = &packed[main / 4..];
        for (i, x) in ar.iter_mut().enumerate() {
            *x += dequant_level(threshold, pr[i / 4] >> ((i % 4) * 2));
        }
    }

    #[inline]
    pub fn sgd_step_scaled(params: &mut [f32], grad_sum: &[f32], inv_n: f32, lr: f32) {
        debug_assert_eq!(params.len(), grad_sum.len());
        let mut p = params.chunks_exact_mut(LANES);
        let mut s = grad_sum.chunks_exact(LANES);
        for (pp, ss) in (&mut p).zip(&mut s) {
            for i in 0..LANES {
                let g = ss[i] * inv_n;
                pp[i] -= lr * g;
            }
        }
        for (pp, ss) in p.into_remainder().iter_mut().zip(s.remainder()) {
            let g = ss * inv_n;
            *pp -= lr * g;
        }
    }

    #[inline]
    pub fn nesterov_step_scaled(
        params: &mut [f32],
        state: &mut [f32],
        grad_sum: &[f32],
        inv_n: f32,
        lr: f32,
        mu: f32,
    ) {
        debug_assert_eq!(params.len(), grad_sum.len());
        debug_assert_eq!(state.len(), grad_sum.len());
        let mut p = params.chunks_exact_mut(LANES);
        let mut st = state.chunks_exact_mut(LANES);
        let mut s = grad_sum.chunks_exact(LANES);
        for ((pp, mm), ss) in (&mut p).zip(&mut st).zip(&mut s) {
            for i in 0..LANES {
                let g = ss[i] * inv_n;
                let m = mu * mm[i] + g;
                mm[i] = m;
                pp[i] -= lr * (g + mu * m);
            }
        }
        for ((pp, mm), ss) in p
            .into_remainder()
            .iter_mut()
            .zip(st.into_remainder().iter_mut())
            .zip(s.remainder())
        {
            let g = ss * inv_n;
            let m = mu * *mm + g;
            *mm = m;
            *pp -= lr * (g + mu * m);
        }
    }
}

// ---------------------------------------------------------------------
// x86_64 vector tiers.
//
// Bit-identity rules (see the module-level contract table):
//  * unaligned loads/stores only — wire payloads have no alignment;
//  * x86_64 is little-endian, so loading payload bytes as f32 lanes is
//    exactly `f32::from_le_bytes`;
//  * no FMA — scalar Rust rounds the multiply and the add separately;
//  * vector operand order mirrors the scalar source text, so x86's
//    src1-wins NaN selection behaves identically in both arms;
//  * each kernel runs the vector loop over the largest lane-multiple
//    prefix and delegates the remainder to the scalar tier.
//
// Dequantization never computes on the threshold: the ±t lanes are
// selected with integer-compare masks AND'ed against broadcast `t`/`-t`
// vectors, so arbitrary threshold bit patterns (NaN included) pass
// through untouched, exactly like `scalar::dequant_level`.
// ---------------------------------------------------------------------

#[cfg(target_arch = "x86_64")]
mod x86 {
    use super::scalar;
    use core::arch::x86_64::*;

    // ---- SSE2 (4 lanes; x86_64 baseline) ----

    /// # Safety
    /// SSE2 is part of the x86_64 baseline; callers only need to be on
    /// x86_64 (guaranteed by the enclosing `cfg`).
    #[target_feature(enable = "sse2")]
    pub unsafe fn copy_f32s_le_sse2(dst: &mut [f32], le_bytes: &[u8]) {
        let main = dst.len() / 4 * 4;
        let dp = dst.as_mut_ptr();
        let sp = le_bytes.as_ptr();
        let mut i = 0;
        while i < main {
            _mm_storeu_ps(dp.add(i), _mm_loadu_ps(sp.add(i * 4) as *const f32));
            i += 4;
        }
        scalar::copy_f32s_le(&mut dst[main..], &le_bytes[main * 4..]);
    }

    /// # Safety
    /// As [`copy_f32s_le_sse2`].
    #[target_feature(enable = "sse2")]
    pub unsafe fn add_assign_le_sse2(acc: &mut [f32], le_bytes: &[u8]) {
        let main = acc.len() / 4 * 4;
        let ap = acc.as_mut_ptr();
        let sp = le_bytes.as_ptr();
        let mut i = 0;
        while i < main {
            let a = _mm_loadu_ps(ap.add(i));
            let s = _mm_loadu_ps(sp.add(i * 4) as *const f32);
            _mm_storeu_ps(ap.add(i), _mm_add_ps(a, s));
            i += 4;
        }
        scalar::add_assign_le(&mut acc[main..], &le_bytes[main * 4..]);
    }

    /// Decode one packed byte (4 2-bit codes) into a 4-lane level vector.
    /// SSE2 has no per-lane variable shift, so code extraction is scalar;
    /// the lane selection is the same mask-and-broadcast scheme as AVX2.
    ///
    /// # Safety
    /// As [`copy_f32s_le_sse2`].
    #[target_feature(enable = "sse2")]
    unsafe fn dequant4_sse2(byte: u8, pos: __m128, neg: __m128) -> __m128 {
        let b = byte as i32;
        let codes = _mm_setr_epi32(b & 3, (b >> 2) & 3, (b >> 4) & 3, (b >> 6) & 3);
        let m1 = _mm_castsi128_ps(_mm_cmpeq_epi32(codes, _mm_set1_epi32(1)));
        let m2 = _mm_castsi128_ps(_mm_cmpeq_epi32(codes, _mm_set1_epi32(2)));
        _mm_or_ps(_mm_and_ps(m1, pos), _mm_and_ps(m2, neg))
    }

    /// # Safety
    /// As [`copy_f32s_le_sse2`].
    #[target_feature(enable = "sse2")]
    pub unsafe fn copy_dequant_sse2(dst: &mut [f32], threshold: f32, packed: &[u8]) {
        let main = dst.len() / 4 * 4;
        let pos = _mm_set1_ps(threshold);
        let neg = _mm_set1_ps(-threshold);
        let dp = dst.as_mut_ptr();
        let mut i = 0;
        while i < main {
            _mm_storeu_ps(dp.add(i), dequant4_sse2(packed[i / 4], pos, neg));
            i += 4;
        }
        scalar::copy_dequant(&mut dst[main..], threshold, &packed[main / 4..]);
    }

    /// # Safety
    /// As [`copy_f32s_le_sse2`].
    #[target_feature(enable = "sse2")]
    pub unsafe fn add_assign_dequant_sse2(acc: &mut [f32], threshold: f32, packed: &[u8]) {
        let main = acc.len() / 4 * 4;
        let pos = _mm_set1_ps(threshold);
        let neg = _mm_set1_ps(-threshold);
        let ap = acc.as_mut_ptr();
        let mut i = 0;
        while i < main {
            let a = _mm_loadu_ps(ap.add(i));
            let d = dequant4_sse2(packed[i / 4], pos, neg);
            _mm_storeu_ps(ap.add(i), _mm_add_ps(a, d));
            i += 4;
        }
        scalar::add_assign_dequant(&mut acc[main..], threshold, &packed[main / 4..]);
    }

    /// # Safety
    /// As [`copy_f32s_le_sse2`].
    #[target_feature(enable = "sse2")]
    pub unsafe fn sgd_step_scaled_sse2(params: &mut [f32], grad_sum: &[f32], inv_n: f32, lr: f32) {
        let main = params.len() / 4 * 4;
        let inv = _mm_set1_ps(inv_n);
        let lrv = _mm_set1_ps(lr);
        let pp = params.as_mut_ptr();
        let sp = grad_sum.as_ptr();
        let mut i = 0;
        while i < main {
            let g = _mm_mul_ps(_mm_loadu_ps(sp.add(i)), inv);
            let p = _mm_loadu_ps(pp.add(i));
            _mm_storeu_ps(pp.add(i), _mm_sub_ps(p, _mm_mul_ps(lrv, g)));
            i += 4;
        }
        scalar::sgd_step_scaled(&mut params[main..], &grad_sum[main..], inv_n, lr);
    }

    /// # Safety
    /// As [`copy_f32s_le_sse2`].
    #[target_feature(enable = "sse2")]
    pub unsafe fn nesterov_step_scaled_sse2(
        params: &mut [f32],
        state: &mut [f32],
        grad_sum: &[f32],
        inv_n: f32,
        lr: f32,
        mu: f32,
    ) {
        let main = params.len() / 4 * 4;
        let inv = _mm_set1_ps(inv_n);
        let lrv = _mm_set1_ps(lr);
        let muv = _mm_set1_ps(mu);
        let pp = params.as_mut_ptr();
        let mp = state.as_mut_ptr();
        let sp = grad_sum.as_ptr();
        let mut i = 0;
        while i < main {
            let g = _mm_mul_ps(_mm_loadu_ps(sp.add(i)), inv);
            let m = _mm_add_ps(_mm_mul_ps(muv, _mm_loadu_ps(mp.add(i))), g);
            _mm_storeu_ps(mp.add(i), m);
            let t = _mm_add_ps(g, _mm_mul_ps(muv, m));
            let p = _mm_loadu_ps(pp.add(i));
            _mm_storeu_ps(pp.add(i), _mm_sub_ps(p, _mm_mul_ps(lrv, t)));
            i += 4;
        }
        scalar::nesterov_step_scaled(
            &mut params[main..],
            &mut state[main..],
            &grad_sum[main..],
            inv_n,
            lr,
            mu,
        );
    }

    // ---- AVX2 (8 lanes; runtime-detected) ----

    /// # Safety
    /// Caller must have proven AVX2 support
    /// (`is_x86_feature_detected!("avx2")`).
    #[target_feature(enable = "avx2")]
    pub unsafe fn copy_f32s_le_avx2(dst: &mut [f32], le_bytes: &[u8]) {
        let main = dst.len() / 8 * 8;
        let dp = dst.as_mut_ptr();
        let sp = le_bytes.as_ptr();
        let mut i = 0;
        while i < main {
            _mm256_storeu_ps(dp.add(i), _mm256_loadu_ps(sp.add(i * 4) as *const f32));
            i += 8;
        }
        scalar::copy_f32s_le(&mut dst[main..], &le_bytes[main * 4..]);
    }

    /// # Safety
    /// As [`copy_f32s_le_avx2`].
    #[target_feature(enable = "avx2")]
    pub unsafe fn add_assign_le_avx2(acc: &mut [f32], le_bytes: &[u8]) {
        let main = acc.len() / 8 * 8;
        let ap = acc.as_mut_ptr();
        let sp = le_bytes.as_ptr();
        let mut i = 0;
        while i < main {
            let a = _mm256_loadu_ps(ap.add(i));
            let s = _mm256_loadu_ps(sp.add(i * 4) as *const f32);
            _mm256_storeu_ps(ap.add(i), _mm256_add_ps(a, s));
            i += 8;
        }
        scalar::add_assign_le(&mut acc[main..], &le_bytes[main * 4..]);
    }

    /// Decode two packed bytes (8 2-bit codes) into an 8-lane level
    /// vector: broadcast the 16 code bits, shift each lane by its own
    /// offset (AVX2 variable shift), mask to 2 bits, then select ±t via
    /// integer-compare masks.
    ///
    /// # Safety
    /// As [`copy_f32s_le_avx2`].
    #[target_feature(enable = "avx2")]
    unsafe fn dequant8_avx2(lo: u8, hi: u8, pos: __m256, neg: __m256) -> __m256 {
        let bits = u16::from_le_bytes([lo, hi]) as i32;
        let shifts = _mm256_setr_epi32(0, 2, 4, 6, 8, 10, 12, 14);
        let codes = _mm256_and_si256(
            _mm256_srlv_epi32(_mm256_set1_epi32(bits), shifts),
            _mm256_set1_epi32(3),
        );
        let m1 = _mm256_castsi256_ps(_mm256_cmpeq_epi32(codes, _mm256_set1_epi32(1)));
        let m2 = _mm256_castsi256_ps(_mm256_cmpeq_epi32(codes, _mm256_set1_epi32(2)));
        _mm256_or_ps(_mm256_and_ps(m1, pos), _mm256_and_ps(m2, neg))
    }

    /// # Safety
    /// As [`copy_f32s_le_avx2`].
    #[target_feature(enable = "avx2")]
    pub unsafe fn copy_dequant_avx2(dst: &mut [f32], threshold: f32, packed: &[u8]) {
        let main = dst.len() / 8 * 8;
        let pos = _mm256_set1_ps(threshold);
        let neg = _mm256_set1_ps(-threshold);
        let dp = dst.as_mut_ptr();
        let mut i = 0;
        while i < main {
            let d = dequant8_avx2(packed[i / 4], packed[i / 4 + 1], pos, neg);
            _mm256_storeu_ps(dp.add(i), d);
            i += 8;
        }
        scalar::copy_dequant(&mut dst[main..], threshold, &packed[main / 4..]);
    }

    /// # Safety
    /// As [`copy_f32s_le_avx2`].
    #[target_feature(enable = "avx2")]
    pub unsafe fn add_assign_dequant_avx2(acc: &mut [f32], threshold: f32, packed: &[u8]) {
        let main = acc.len() / 8 * 8;
        let pos = _mm256_set1_ps(threshold);
        let neg = _mm256_set1_ps(-threshold);
        let ap = acc.as_mut_ptr();
        let mut i = 0;
        while i < main {
            let a = _mm256_loadu_ps(ap.add(i));
            let d = dequant8_avx2(packed[i / 4], packed[i / 4 + 1], pos, neg);
            _mm256_storeu_ps(ap.add(i), _mm256_add_ps(a, d));
            i += 8;
        }
        scalar::add_assign_dequant(&mut acc[main..], threshold, &packed[main / 4..]);
    }

    /// # Safety
    /// As [`copy_f32s_le_avx2`].
    #[target_feature(enable = "avx2")]
    pub unsafe fn sgd_step_scaled_avx2(params: &mut [f32], grad_sum: &[f32], inv_n: f32, lr: f32) {
        let main = params.len() / 8 * 8;
        let inv = _mm256_set1_ps(inv_n);
        let lrv = _mm256_set1_ps(lr);
        let pp = params.as_mut_ptr();
        let sp = grad_sum.as_ptr();
        let mut i = 0;
        while i < main {
            let g = _mm256_mul_ps(_mm256_loadu_ps(sp.add(i)), inv);
            let p = _mm256_loadu_ps(pp.add(i));
            _mm256_storeu_ps(pp.add(i), _mm256_sub_ps(p, _mm256_mul_ps(lrv, g)));
            i += 8;
        }
        scalar::sgd_step_scaled(&mut params[main..], &grad_sum[main..], inv_n, lr);
    }

    /// # Safety
    /// As [`copy_f32s_le_avx2`].
    #[target_feature(enable = "avx2")]
    pub unsafe fn nesterov_step_scaled_avx2(
        params: &mut [f32],
        state: &mut [f32],
        grad_sum: &[f32],
        inv_n: f32,
        lr: f32,
        mu: f32,
    ) {
        let main = params.len() / 8 * 8;
        let inv = _mm256_set1_ps(inv_n);
        let lrv = _mm256_set1_ps(lr);
        let muv = _mm256_set1_ps(mu);
        let pp = params.as_mut_ptr();
        let mp = state.as_mut_ptr();
        let sp = grad_sum.as_ptr();
        let mut i = 0;
        while i < main {
            let g = _mm256_mul_ps(_mm256_loadu_ps(sp.add(i)), inv);
            let m = _mm256_add_ps(_mm256_mul_ps(muv, _mm256_loadu_ps(mp.add(i))), g);
            _mm256_storeu_ps(mp.add(i), m);
            let t = _mm256_add_ps(g, _mm256_mul_ps(muv, m));
            let p = _mm256_loadu_ps(pp.add(i));
            _mm256_storeu_ps(pp.add(i), _mm256_sub_ps(p, _mm256_mul_ps(lrv, t)));
            i += 8;
        }
        scalar::nesterov_step_scaled(
            &mut params[main..],
            &mut state[main..],
            &grad_sum[main..],
            inv_n,
            lr,
            mu,
        );
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tier_names_and_u8_roundtrip() {
        for t in [KernelTier::Scalar, KernelTier::Sse2, KernelTier::Avx2] {
            assert_eq!(KernelTier::from_u8(t as u8), Some(t));
        }
        assert_eq!(KernelTier::from_u8(3), None);
        assert_eq!(KernelTier::from_u8(TIER_UNRESOLVED), None);
        assert_eq!(KernelTier::Scalar.name(), "scalar");
        assert_eq!(KernelTier::Sse2.name(), "sse2");
        assert_eq!(KernelTier::Avx2.name(), "avx2");
    }

    #[test]
    fn resolve_honors_available_override_and_rejects_junk() {
        // A requested-and-available tier wins.
        assert_eq!(resolve(Some("scalar")), KernelTier::Scalar);
        assert_eq!(resolve(Some("SCALAR")), KernelTier::Scalar);
        for t in available_tiers() {
            assert_eq!(resolve(Some(t.name())), t);
        }
        // Unset, unknown, or unavailable requests fall back to detection.
        let best = resolve(None);
        assert!(tier_available(best));
        assert_eq!(resolve(Some("avx512")), best);
        assert_eq!(resolve(Some("")), best);
        if !tier_available(KernelTier::Avx2) {
            assert_eq!(resolve(Some("avx2")), best);
        }
    }

    #[test]
    fn active_tier_is_cached_and_available() {
        let t = active_tier();
        assert!(tier_available(t));
        assert_eq!(active_tier(), t);
        assert_eq!(
            KernelTier::from_u8(ACTIVE_TIER.load(Ordering::Relaxed)),
            Some(t)
        );
    }

    #[test]
    fn scalar_tier_always_listed_first() {
        let tiers = available_tiers();
        assert_eq!(tiers[0], KernelTier::Scalar);
        #[cfg(target_arch = "x86_64")]
        assert!(tiers.contains(&KernelTier::Sse2), "sse2 is x86_64 baseline");
    }

    /// Fixed-vector smoke test of every kernel on every available tier
    /// (the exhaustive bit-pattern comparison lives in
    /// `tests/prop_coordinator.rs`).
    #[test]
    fn all_tiers_agree_on_fixed_vectors() {
        let n = 21; // exercises the 8-lane and 4-lane remainders
        let src: Vec<f32> = (0..n).map(|i| (i as f32 * 0.7).sin() * 3.0).collect();
        let bytes: Vec<u8> = src.iter().flat_map(|x| x.to_le_bytes()).collect();
        let packed: Vec<u8> = (0..n.div_ceil(4)).map(|i| (i as u8).wrapping_mul(0x39)).collect();
        let base: Vec<f32> = (0..n).map(|i| i as f32 * 0.1 - 1.0).collect();
        for tier in available_tiers() {
            let mut want = vec![0.0f32; n];
            copy_f32s_le_tier(KernelTier::Scalar, &mut want, &bytes);
            let mut got = vec![0.0f32; n];
            copy_f32s_le_tier(tier, &mut got, &bytes);
            assert_eq!(want, got, "copy {tier:?}");

            let mut want = base.clone();
            add_assign_le_tier(KernelTier::Scalar, &mut want, &bytes);
            let mut got = base.clone();
            add_assign_le_tier(tier, &mut got, &bytes);
            assert_eq!(want, got, "absorb {tier:?}");

            let mut want = vec![0.0f32; n];
            copy_dequant_tier(KernelTier::Scalar, &mut want, 0.5, &packed);
            let mut got = vec![0.0f32; n];
            copy_dequant_tier(tier, &mut got, 0.5, &packed);
            assert_eq!(want, got, "dequant copy {tier:?}");

            let mut want = base.clone();
            add_assign_dequant_tier(KernelTier::Scalar, &mut want, 0.5, &packed);
            let mut got = base.clone();
            add_assign_dequant_tier(tier, &mut got, 0.5, &packed);
            assert_eq!(want, got, "dequant absorb {tier:?}");

            let mut want = base.clone();
            sgd_step_scaled_tier(KernelTier::Scalar, &mut want, &src, 0.25, 0.1);
            let mut got = base.clone();
            sgd_step_scaled_tier(tier, &mut got, &src, 0.25, 0.1);
            assert_eq!(want, got, "sgd {tier:?}");

            let (mut wp, mut wm) = (base.clone(), src.clone());
            nesterov_step_scaled_tier(KernelTier::Scalar, &mut wp, &mut wm, &src, 0.25, 0.1, 0.9);
            let (mut gp, mut gm) = (base.clone(), src.clone());
            nesterov_step_scaled_tier(tier, &mut gp, &mut gm, &src, 0.25, 0.1, 0.9);
            assert_eq!(wp, gp, "nesterov params {tier:?}");
            assert_eq!(wm, gm, "nesterov momentum {tier:?}");
        }
    }

    /// The 2-bit decode mapping itself, per tier: each of the four codes
    /// lands the right level, including the reserved 0b11 → 0.
    #[test]
    fn dequant_code_mapping_per_tier() {
        let t = 0.75f32;
        // Codes [1, 2, 0, 3, 1, 2, 0, 3, 1] over three packed bytes.
        let packed = [0b11_00_10_01u8, 0b11_00_10_01, 0b01];
        let want = [t, -t, 0.0, 0.0, t, -t, 0.0, 0.0, t];
        for tier in available_tiers() {
            let mut got = [0.0f32; 9];
            copy_dequant_tier(tier, &mut got, t, &packed);
            assert_eq!(got, want, "{tier:?}");
        }
    }

    #[test]
    #[cfg(target_arch = "x86_64")]
    fn unavailable_tier_is_a_panic_not_ub() {
        if !tier_available(KernelTier::Avx2) {
            let r = std::panic::catch_unwind(|| {
                let mut d = [0.0f32; 4];
                copy_f32s_le_tier(KernelTier::Avx2, &mut d, &[0u8; 16]);
            });
            assert!(r.is_err());
        }
    }
}
