//! Key tables and fine-grained key chunking (paper section 3.2.3).
//!
//! A *key* is one layer's parameter tensor; PHub splits keys into
//! fixed-size chunks ("virtual keys") that are the unit of transmission,
//! aggregation, optimization, and core assignment. Chunking is on even for
//! centralized servers — the goal is core/interface-level load balance and
//! transmission/processing overlap, not shard balance.

/// One key (layer) in the flattened model.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Key {
    pub name: String,
    /// Offset in f32 elements into the flat model vector.
    pub offset: usize,
    /// Length in f32 elements.
    pub len: usize,
}

/// A chunk ("virtual key"): a contiguous element range of the flat model.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Chunk {
    /// Index of the owning key.
    pub key: usize,
    /// Offset in f32 elements into the flat model vector.
    pub offset: usize,
    pub len: usize,
}

/// Identifier of a chunk within a [`KeyTable`].
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct ChunkId(pub u32);

/// The model's key table plus its chunking.
#[derive(Debug, Clone)]
pub struct KeyTable {
    pub keys: Vec<Key>,
    pub chunks: Vec<Chunk>,
    /// Chunk size in f32 elements.
    pub chunk_elems: usize,
    /// Total flat model length in elements (sum of key lengths).
    pub total_elems: usize,
}

impl KeyTable {
    /// Build a key table from (name, len) pairs laid out contiguously,
    /// chunked at `chunk_elems` granularity. Chunks never span keys (a
    /// chunk is transmitted and aggregated as a unit of exactly one key).
    pub fn new(keys: &[(String, usize)], chunk_elems: usize) -> KeyTable {
        assert!(chunk_elems > 0);
        let mut table = Vec::with_capacity(keys.len());
        let mut chunks = Vec::new();
        let mut offset = 0usize;
        for (ki, (name, len)) in keys.iter().enumerate() {
            assert!(*len > 0, "empty key {name}");
            table.push(Key {
                name: name.clone(),
                offset,
                len: *len,
            });
            let mut pos = 0usize;
            while pos < *len {
                let l = chunk_elems.min(*len - pos);
                chunks.push(Chunk {
                    key: ki,
                    offset: offset + pos,
                    len: l,
                });
                pos += l;
            }
            offset += *len;
        }
        KeyTable {
            keys: table,
            chunks,
            chunk_elems,
            total_elems: offset,
        }
    }

    /// Uniform layout: a single flat buffer of `total` elements chunked
    /// without key structure (used by benchmarks and the e2e example,
    /// where the manifest's padded flat vector is the wire format).
    pub fn flat(total: usize, chunk_elems: usize) -> KeyTable {
        Self::new(&[("flat".to_string(), total)], chunk_elems)
    }

    /// Parse from the AOT manifest's key list (name, len) plus padding to
    /// `padded` elements; the pad region becomes a synthetic final key so
    /// every element has an owning chunk.
    pub fn from_manifest_keys(
        keys: &[(String, usize)],
        padded: usize,
        chunk_elems: usize,
    ) -> KeyTable {
        let total: usize = keys.iter().map(|(_, l)| l).sum();
        assert!(padded >= total);
        let mut all = keys.to_vec();
        if padded > total {
            all.push(("__pad".to_string(), padded - total));
        }
        Self::new(&all, chunk_elems)
    }

    pub fn n_chunks(&self) -> usize {
        self.chunks.len()
    }

    /// Chunks belonging to key `k`, in order.
    pub fn chunks_of(&self, k: usize) -> impl Iterator<Item = (ChunkId, &Chunk)> {
        self.chunks
            .iter()
            .enumerate()
            .filter(move |(_, c)| c.key == k)
            .map(|(i, c)| (ChunkId(i as u32), c))
    }

    /// Verify structural invariants (used by property tests).
    pub fn check_invariants(&self) {
        // Chunks tile the model exactly, in order, without gaps/overlap.
        let mut pos = 0usize;
        for c in &self.chunks {
            assert_eq!(c.offset, pos, "gap or overlap at chunk offset");
            assert!(c.len > 0 && c.len <= self.chunk_elems);
            pos += c.len;
        }
        assert_eq!(pos, self.total_elems);
        // Every chunk lies inside its key.
        for c in &self.chunks {
            let k = &self.keys[c.key];
            assert!(c.offset >= k.offset && c.offset + c.len <= k.offset + k.len);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn keys(lens: &[usize]) -> Vec<(String, usize)> {
        lens.iter()
            .enumerate()
            .map(|(i, &l)| (format!("k{i}"), l))
            .collect()
    }

    #[test]
    fn chunks_tile_exactly() {
        let t = KeyTable::new(&keys(&[100, 250, 64]), 64);
        t.check_invariants();
        assert_eq!(t.total_elems, 414);
        // 100 -> 2 chunks, 250 -> 4, 64 -> 1.
        assert_eq!(t.n_chunks(), 7);
    }

    #[test]
    fn chunk_never_spans_keys() {
        let t = KeyTable::new(&keys(&[65, 65]), 64);
        // Each key gets a 64 + 1 split rather than sharing a chunk.
        assert_eq!(t.n_chunks(), 4);
        for c in &t.chunks {
            let k = &t.keys[c.key];
            assert!(c.offset + c.len <= k.offset + k.len);
        }
    }

    #[test]
    fn manifest_padding_becomes_key() {
        let t = KeyTable::from_manifest_keys(&keys(&[100]), 128, 64);
        assert_eq!(t.total_elems, 128);
        assert_eq!(t.keys.last().unwrap().name, "__pad");
        t.check_invariants();
    }

    #[test]
    fn flat_layout() {
        let t = KeyTable::flat(8192 * 3, 8192);
        assert_eq!(t.n_chunks(), 3);
        t.check_invariants();
    }

    #[test]
    fn chunks_of_key() {
        let t = KeyTable::new(&keys(&[100, 250]), 64);
        let c1: Vec<_> = t.chunks_of(1).collect();
        assert_eq!(c1.len(), 4);
        assert!(c1.iter().all(|(_, c)| c.key == 1));
    }
}
