//! Multi-tenant operation: several independent training jobs sharing one
//! PHub instance under different key namespaces (paper section 4.8,
//! Figure 18).
//!
//! The isolation mechanism is the namespace + nonce of
//! [`super::service::ConnectionManager`]; this module adds a measured
//! concurrent-jobs driver used by `examples/multi_tenant.rs` and the
//! Figure 18 bench: J jobs × W workers each, all exchanging through one
//! server, reporting per-job exchange throughput.

use std::sync::Arc;

use crate::metrics::JobMetricsSnapshot;

use super::chunk::KeyTable;
use super::optimizer::NesterovSgd;
use super::server::{PHubServer, ServerConfig};
use super::service::ConnectionManager;

/// Result of a concurrent-jobs run.
#[derive(Debug, Clone)]
pub struct TenancyResult {
    pub jobs: usize,
    pub rounds: usize,
    /// Per-job exchange rounds per second (length = jobs).
    pub per_job_rate: Vec<f64>,
    /// The server's per-tenant attribution at shutdown, ordered by job
    /// id (what the status plane's `/jobs` route serves live; length =
    /// jobs). Rounds are worker-rounds: `workers × rounds` each here.
    pub per_job_metrics: Vec<JobMetricsSnapshot>,
}

impl TenancyResult {
    /// Mean per-job rate.
    pub fn mean_rate(&self) -> f64 {
        self.per_job_rate.iter().sum::<f64>() / self.per_job_rate.len() as f64
    }
}

/// Run `jobs` independent synchronous training jobs concurrently on one
/// server; each job has `workers` worker threads exchanging a
/// `model_elems`-element model for `rounds` rounds. Returns per-job rates.
pub fn run_concurrent_jobs(
    n_cores: usize,
    jobs: usize,
    workers: usize,
    model_elems: usize,
    chunk_elems: usize,
    rounds: usize,
) -> TenancyResult {
    assert!(jobs >= 1 && workers >= 1 && rounds >= 1);
    let server = PHubServer::start(ServerConfig::cores(n_cores));
    let cm = ConnectionManager::new(server.clone());

    let mut handles_per_job = Vec::new();
    for j in 0..jobs {
        let h = cm
            .create_service(&format!("tenant-{j}"), workers)
            .expect("namespace");
        cm.init_service(
            &h,
            KeyTable::flat(model_elems, chunk_elems),
            &vec![0.0; model_elems],
            Arc::new(NesterovSgd {
                lr: 0.01,
                momentum: 0.9,
            }),
        )
        .expect("init");
        let whs: Vec<_> = (0..workers)
            .map(|w| cm.connect_service(&h, w).expect("connect"))
            .collect();
        handles_per_job.push(whs);
    }

    // Each worker thread runs `rounds` push_pulls; per-job wall time is
    // measured from its own start to its last worker finishing.
    let mut per_job_rate = vec![0.0; jobs];
    std::thread::scope(|s| {
        let mut job_threads = Vec::new();
        for (j, whs) in handles_per_job.drain(..).enumerate() {
            job_threads.push(s.spawn(move || {
                let start = std::time::Instant::now();
                std::thread::scope(|ws| {
                    for mut h in whs {
                        ws.spawn(move || {
                            let grad = vec![0.5f32; h.model_len()];
                            for _ in 0..rounds {
                                let _ = h.push_pull(&grad);
                            }
                        });
                    }
                });
                (j, rounds as f64 / start.elapsed().as_secs_f64())
            }));
        }
        for t in job_threads {
            let (j, rate) = t.join().unwrap();
            per_job_rate[j] = rate;
        }
    });

    // Snapshot attribution before shutdown drops the registry.
    let per_job_metrics = server.metrics().per_job.snapshot();
    PHubServer::shutdown(server);
    TenancyResult {
        jobs,
        rounds,
        per_job_rate,
        per_job_metrics,
    }
}

#[cfg(test)]
#[allow(clippy::useless_vec)]
mod tests {
    use super::*;

    /// A mid-round rollback in one tenant (the engine-level recovery for
    /// a worker death) must not perturb any other tenant sharing the same
    /// cores: rollback is per-job state, not per-core state.
    #[test]
    fn rollback_in_one_tenant_leaves_others_untouched() {
        let server = PHubServer::start(ServerConfig::cores(2));
        let cm = ConnectionManager::new(server.clone());
        let opt = || {
            Arc::new(NesterovSgd {
                lr: 0.5,
                momentum: 0.0,
            })
        };
        let ha = cm.create_service("tenant-a", 2).unwrap();
        let hb = cm.create_service("tenant-b", 1).unwrap();
        cm.init_service(&ha, KeyTable::flat(32, 8), &vec![0.0; 32], opt())
            .unwrap();
        cm.init_service(&hb, KeyTable::flat(32, 8), &vec![0.0; 32], opt())
            .unwrap();
        let mut wa0 = cm.connect_service(&ha, 0).unwrap();
        let mut wa1 = cm.connect_service(&ha, 1).unwrap();
        let mut wb = cm.connect_service(&hb, 0).unwrap();

        // Tenant A: half a round pushed, then rolled back.
        let (lo, hi) = wa1.chunk_range(0);
        wa1.push_chunk(0, vec![7.0f32; hi - lo].into(), true);
        assert_eq!(cm.rollback_service(&ha).unwrap(), 1);

        // Tenant B trains cleanly straight through A's rollback.
        let mb = wb.push_pull(&vec![2.0; 32]);
        assert!(mb.iter().all(|&x| (x + 1.0).abs() < 1e-6), "{:?}", &mb[..2]);

        // Tenant A replays and lands on the exact clean-round values.
        let (m0, m1) = std::thread::scope(|s| {
            let t = s.spawn(|| wa1.push_pull(&vec![3.0; 32]));
            (wa0.push_pull(&vec![1.0; 32]), t.join().unwrap())
        });
        assert_eq!(m0, m1);
        assert!(m0.iter().all(|&x| (x + 1.0).abs() < 1e-6), "{:?}", &m0[..2]);
        PHubServer::shutdown(server);
    }

    #[test]
    fn concurrent_jobs_complete() {
        let r = run_concurrent_jobs(2, 3, 2, 4096, 1024, 5);
        assert_eq!(r.per_job_rate.len(), 3);
        assert!(r.per_job_rate.iter().all(|&x| x > 0.0));
    }

    /// Per-tenant attribution: each job's metric set counts exactly its
    /// own traffic — `workers × rounds` worker-rounds, the matching
    /// push/pull byte volume, a populated latency histogram, and zero
    /// drops/replays/rollbacks on a clean run.
    #[test]
    fn per_job_attribution_is_exact_and_isolated() {
        let (jobs, workers, elems, rounds) = (3usize, 2usize, 4096usize, 4usize);
        let r = run_concurrent_jobs(2, jobs, workers, elems, 1024, rounds);
        assert_eq!(r.per_job_metrics.len(), jobs);
        let expect_rounds = (workers * rounds) as u64;
        for (i, jm) in r.per_job_metrics.iter().enumerate() {
            assert_eq!(jm.rounds_completed, expect_rounds, "job {i}");
            assert_eq!(jm.push_bytes, expect_rounds * elems as u64 * 4, "job {i}");
            assert_eq!(jm.pull_bytes, expect_rounds * elems as u64 * 4, "job {i}");
            assert_eq!(jm.round_latency.count, expect_rounds, "job {i}");
            assert!(jm.round_latency.mean_ns() > 0.0, "job {i}");
            assert_eq!(jm.drops, 0, "job {i}");
            assert_eq!(jm.replays, 0, "job {i}");
            assert_eq!(jm.rollbacks, 0, "job {i}");
        }
        // Distinct jobs, sorted ids: the snapshot attributes per tenant,
        // not per server.
        assert!(r.per_job_metrics.windows(2).all(|p| p[0].job < p[1].job));
    }

    #[test]
    fn single_job_baseline_not_slower_than_many() {
        // With shared cores, per-job rate with 4 jobs should not exceed
        // the single-job rate (sanity direction; exact ratios are the
        // bench's concern).
        let one = run_concurrent_jobs(2, 1, 2, 32 * 1024, 8192, 8);
        let four = run_concurrent_jobs(2, 4, 2, 32 * 1024, 8192, 8);
        assert!(four.mean_rate() <= one.mean_rate() * 1.5, "{one:?} {four:?}");
    }
}
