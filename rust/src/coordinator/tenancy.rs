//! Multi-tenant operation: several independent training jobs sharing one
//! PHub instance under different key namespaces (paper section 4.8,
//! Figure 18).
//!
//! The isolation mechanism is the namespace + nonce of
//! [`super::service::ConnectionManager`]; this module adds a measured
//! concurrent-jobs driver used by `examples/multi_tenant.rs` and the
//! Figure 18 bench: J jobs × W workers each, all exchanging through one
//! server, reporting per-job exchange throughput.

use std::sync::Arc;

use super::chunk::KeyTable;
use super::optimizer::NesterovSgd;
use super::server::{PHubServer, ServerConfig};
use super::service::ConnectionManager;

/// Result of a concurrent-jobs run.
#[derive(Debug, Clone)]
pub struct TenancyResult {
    pub jobs: usize,
    pub rounds: usize,
    /// Per-job exchange rounds per second (length = jobs).
    pub per_job_rate: Vec<f64>,
}

impl TenancyResult {
    /// Mean per-job rate.
    pub fn mean_rate(&self) -> f64 {
        self.per_job_rate.iter().sum::<f64>() / self.per_job_rate.len() as f64
    }
}

/// Run `jobs` independent synchronous training jobs concurrently on one
/// server; each job has `workers` worker threads exchanging a
/// `model_elems`-element model for `rounds` rounds. Returns per-job rates.
pub fn run_concurrent_jobs(
    n_cores: usize,
    jobs: usize,
    workers: usize,
    model_elems: usize,
    chunk_elems: usize,
    rounds: usize,
) -> TenancyResult {
    assert!(jobs >= 1 && workers >= 1 && rounds >= 1);
    let server = PHubServer::start(ServerConfig { n_cores });
    let cm = ConnectionManager::new(server.clone());

    let mut handles_per_job = Vec::new();
    for j in 0..jobs {
        let h = cm
            .create_service(&format!("tenant-{j}"), workers)
            .expect("namespace");
        cm.init_service(
            &h,
            KeyTable::flat(model_elems, chunk_elems),
            &vec![0.0; model_elems],
            Arc::new(NesterovSgd {
                lr: 0.01,
                momentum: 0.9,
            }),
        )
        .expect("init");
        let whs: Vec<_> = (0..workers)
            .map(|w| cm.connect_service(&h, w).expect("connect"))
            .collect();
        handles_per_job.push(whs);
    }

    // Each worker thread runs `rounds` push_pulls; per-job wall time is
    // measured from its own start to its last worker finishing.
    let mut per_job_rate = vec![0.0; jobs];
    std::thread::scope(|s| {
        let mut job_threads = Vec::new();
        for (j, whs) in handles_per_job.drain(..).enumerate() {
            job_threads.push(s.spawn(move || {
                let start = std::time::Instant::now();
                std::thread::scope(|ws| {
                    for mut h in whs {
                        ws.spawn(move || {
                            let grad = vec![0.5f32; h.model_len()];
                            for _ in 0..rounds {
                                let _ = h.push_pull(&grad);
                            }
                        });
                    }
                });
                (j, rounds as f64 / start.elapsed().as_secs_f64())
            }));
        }
        for t in job_threads {
            let (j, rate) = t.join().unwrap();
            per_job_rate[j] = rate;
        }
    });

    PHubServer::shutdown(server);
    TenancyResult {
        jobs,
        rounds,
        per_job_rate,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn concurrent_jobs_complete() {
        let r = run_concurrent_jobs(2, 3, 2, 4096, 1024, 5);
        assert_eq!(r.per_job_rate.len(), 3);
        assert!(r.per_job_rate.iter().all(|&x| x > 0.0));
    }

    #[test]
    fn single_job_baseline_not_slower_than_many() {
        // With shared cores, per-job rate with 4 jobs should not exceed
        // the single-job rate (sanity direction; exact ratios are the
        // bench's concern).
        let one = run_concurrent_jobs(2, 1, 2, 32 * 1024, 8192, 8);
        let four = run_concurrent_jobs(2, 4, 2, 32 * 1024, 8192, 8);
        assert!(four.mean_rate() <= one.mean_rate() * 1.5, "{one:?} {four:?}");
    }
}
