//! Recycling buffer pools for the allocation-free data plane.
//!
//! PHub's aggregation pipeline is memory-bandwidth-bound (paper §3.2,
//! §4.3): the design goal is to touch every gradient byte as few times as
//! possible and to allocate nothing at steady state. These pools are the
//! ownership half of that discipline — the arithmetic half lives in
//! [`super::aggregation`].
//!
//! A [`Pool`] hands out [`Pooled`] buffers; dropping a `Pooled` returns
//! the underlying buffer (cleared, capacity kept) to its pool, from any
//! thread. Buffers therefore cycle through the pipeline instead of being
//! reallocated per frame:
//!
//! ```text
//! leader:  pool ─take→ read_frame_into ─send→ core absorbs bytes ─drop→ pool
//! replies: pool ─take→ copy params ─send→ conn serializes frame ─drop→ pool
//! ```
//!
//! After one warm-up round every buffer in the cycle has reached its
//! high-water capacity and the steady state performs zero heap
//! allocations on the per-chunk path (asserted by
//! `rust/tests/alloc_discipline.rs`).
//!
//! Retention is bounded: a pool keeps at most `max_free` idle buffers and
//! drops the rest, so a transient burst (or a hostile peer forcing huge
//! frames) cannot pin unbounded memory forever.

use std::ops::{Deref, DerefMut};
use std::sync::{Arc, Mutex};

/// A buffer type that can be reset for reuse while keeping its capacity.
pub trait Recycle: Default + Send {
    fn recycle(&mut self);
}

impl Recycle for Vec<u8> {
    fn recycle(&mut self) {
        self.clear();
    }
}

impl Recycle for Vec<f32> {
    fn recycle(&mut self) {
        self.clear();
    }
}

/// A recycling pool of buffers. Cheap to share (`Arc`); safe to return
/// buffers into from any thread.
pub struct Pool<T: Recycle> {
    free: Mutex<Vec<T>>,
    max_free: usize,
}

impl<T: Recycle> Pool<T> {
    /// A pool retaining at most `max_free` idle buffers.
    pub fn new(max_free: usize) -> Arc<Pool<T>> {
        Arc::new(Pool {
            free: Mutex::new(Vec::new()),
            max_free,
        })
    }

    /// Take a (cleared) buffer: recycled if one is idle, fresh otherwise.
    pub fn take(self: &Arc<Self>) -> Pooled<T> {
        let buf = self.free.lock().unwrap().pop().unwrap_or_default();
        Pooled {
            inner: Some(buf),
            pool: Some(self.clone()),
        }
    }

    /// Idle buffers currently retained (diagnostics/tests).
    pub fn free_count(&self) -> usize {
        self.free.lock().unwrap().len()
    }

    fn put(&self, mut buf: T) {
        buf.recycle();
        let mut free = self.free.lock().unwrap();
        if free.len() < self.max_free {
            free.push(buf);
        } // else: drop — retention is bounded
    }
}

/// A buffer borrowed from a [`Pool`] (or detached, pool-less). Derefs to
/// the underlying buffer; returns to its pool on drop.
pub struct Pooled<T: Recycle> {
    /// `Some` until drop.
    inner: Option<T>,
    /// `None` for detached buffers (plain owned, never recycled).
    pool: Option<Arc<Pool<T>>>,
}

impl<T: Recycle> Pooled<T> {
    /// Wrap a plain buffer with no pool behind it — same type, ordinary
    /// ownership. Used where a `Pooled` is expected but recycling is not
    /// worth a pool (tests, cold paths, deep clones).
    pub fn detached(buf: T) -> Pooled<T> {
        Pooled {
            inner: Some(buf),
            pool: None,
        }
    }
}

impl<T: Recycle> Deref for Pooled<T> {
    type Target = T;
    fn deref(&self) -> &T {
        self.inner.as_ref().expect("pooled buffer present until drop")
    }
}

impl<T: Recycle> DerefMut for Pooled<T> {
    fn deref_mut(&mut self) -> &mut T {
        self.inner.as_mut().expect("pooled buffer present until drop")
    }
}

impl<T: Recycle> Drop for Pooled<T> {
    fn drop(&mut self) {
        if let (Some(buf), Some(pool)) = (self.inner.take(), self.pool.take()) {
            pool.put(buf);
        }
    }
}

impl<T: Recycle + Clone> Clone for Pooled<T> {
    /// Deep copy, detached: a clone never shares or steals pool capacity.
    fn clone(&self) -> Pooled<T> {
        Pooled::detached((**self).clone())
    }
}

impl<T: Recycle + std::fmt::Debug> std::fmt::Debug for Pooled<T> {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        (**self).fmt(f)
    }
}

/// Frame-payload byte pool (wire receive path).
pub type BytePool = Pool<Vec<u8>>;
/// A pooled frame payload.
pub type PooledBytes = Pooled<Vec<u8>>;
/// Reply-parameter pool (engine → worker path).
pub type F32Pool = Pool<Vec<f32>>;
/// A pooled parameter buffer.
pub type PooledF32 = Pooled<Vec<f32>>;

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn buffers_recycle_with_capacity() {
        let pool: Arc<BytePool> = Pool::new(4);
        let ptr;
        {
            let mut b = pool.take();
            b.extend_from_slice(&[1, 2, 3, 4]);
            ptr = b.as_ptr();
        } // drop → back to pool, cleared
        assert_eq!(pool.free_count(), 1);
        let b = pool.take();
        assert!(b.is_empty(), "recycled buffer is cleared");
        assert!(b.capacity() >= 4, "recycled buffer keeps capacity");
        assert_eq!(b.as_ptr(), ptr, "same allocation came back");
    }

    #[test]
    fn retention_is_bounded() {
        let pool: Arc<F32Pool> = Pool::new(2);
        let bufs: Vec<PooledF32> = (0..5).map(|_| pool.take()).collect();
        drop(bufs);
        assert_eq!(pool.free_count(), 2, "excess buffers dropped, not hoarded");
    }

    #[test]
    fn detached_and_clone_never_touch_a_pool() {
        let pool: Arc<F32Pool> = Pool::new(4);
        let mut b = pool.take();
        b.extend_from_slice(&[1.0, 2.0]);
        let c = b.clone();
        drop(c); // detached clone: no pool return
        assert_eq!(pool.free_count(), 0);
        drop(b);
        assert_eq!(pool.free_count(), 1);
        let d = Pooled::detached(vec![9.0f32]);
        assert_eq!(&*d, &vec![9.0]);
        drop(d); // no pool: plain drop
    }

    #[test]
    fn returns_cross_thread() {
        let pool: Arc<BytePool> = Pool::new(8);
        let b = pool.take();
        std::thread::spawn(move || drop(b)).join().unwrap();
        assert_eq!(pool.free_count(), 1);
    }
}
