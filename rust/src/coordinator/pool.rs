//! Recycling buffer pools for the allocation-free data plane.
//!
//! PHub's aggregation pipeline is memory-bandwidth-bound (paper §3.2,
//! §4.3): the design goal is to touch every gradient byte as few times as
//! possible and to allocate nothing — and take no lock — at steady
//! state. These pools are the ownership half of that discipline; the
//! arithmetic half lives in [`super::aggregation`] and the queue half in
//! [`super::ring`].
//!
//! A [`Pool`] hands out [`Pooled`] buffers; dropping a `Pooled` returns
//! the underlying buffer (cleared, capacity kept) to its pool, from any
//! thread. A [`SharedPool`] hands out [`SharedPooled`] buffers that add
//! a *pooled refcount block* on top: one buffer is filled once, shared
//! with N receivers by refcount bump, and recycled when the last
//! reference drops — the single-copy reply broadcast. Buffers therefore
//! cycle through the pipeline instead of being reallocated per frame:
//!
//! ```text
//! leader:  pool ─take→ read_frame_into ─send→ core absorbs bytes ─drop→ pool
//! replies: pool ─take→ copy params once ─clone×N→ conns serialize ─last drop→ pool
//! ```
//!
//! # Lock-freedom and the single-taker contract
//!
//! The free list is a Treiber stack of the buffers' own nodes: returns
//! (`drop`) push lock-free from any thread, and each node travels *with*
//! its buffer, so the steady state performs zero allocations and zero
//! mutex acquisitions in either direction. Pops are ABA-safe with one
//! popper at a time, and that invariant is *enforced*, not assumed: a
//! non-blocking latch around the pop means a second concurrent taker
//! just allocates a fresh buffer instead of racing the stack. The data
//! plane has exactly one taker per pool anyway (the connection thread
//! for its frame pool, the owning core for its reply pool), so the
//! latch is uncontended at steady state and recycling always hits.
//! Returns are unrestricted.
//!
//! After one warm-up round every buffer in the cycle has reached its
//! high-water capacity and the steady state performs zero heap
//! allocations on the per-chunk path (asserted, with no exclusions, by
//! `rust/tests/alloc_discipline.rs`).
//!
//! Retention is bounded: a pool keeps at most `max_free` idle buffers
//! (a soft cap under concurrent returns) and drops the rest, so a
//! transient burst (or a hostile peer forcing huge frames) cannot pin
//! unbounded memory forever.

use std::cell::UnsafeCell;
use std::ops::{Deref, DerefMut};
use std::ptr::{self, NonNull};
use std::sync::atomic::{fence, AtomicPtr, AtomicUsize, Ordering};
use std::sync::Arc;

/// A buffer type that can be reset for reuse while keeping its capacity.
pub trait Recycle: Default + Send {
    fn recycle(&mut self);
}

impl Recycle for Vec<u8> {
    fn recycle(&mut self) {
        self.clear();
    }
}

impl Recycle for Vec<f32> {
    fn recycle(&mut self) {
        self.clear();
    }
}

// ---------------------------------------------------------------------------
// The lock-free free list shared by both pool flavours.
// ---------------------------------------------------------------------------

/// A Treiber stack whose nodes are allocated by the caller and travel
/// in and out whole (no allocation on push or pop). Multi-producer
/// push; **single-consumer** pop (see module docs for why that makes
/// ABA impossible here).
struct FreeStack<N: StackNode> {
    head: AtomicPtr<N>,
    len: AtomicUsize,
    /// Soft cap on retained nodes.
    max_free: usize,
    /// Pop-exclusivity latch. A Treiber pop is ABA-safe only with one
    /// concurrent popper, and `take()` is a safe public method — so the
    /// single-taker rule is *enforced*, not just documented: a taker
    /// that finds the latch held simply allocates fresh instead of
    /// popping. Never blocks, never spins; uncontended (the designed
    /// single-taker steady state) it is one relaxed RMW.
    popping: std::sync::atomic::AtomicBool,
}

/// Access to a node's intrusive `next` pointer.
trait StackNode: Sized {
    fn next(&self) -> &AtomicPtr<Self>;
}

impl<N: StackNode> FreeStack<N> {
    fn new(max_free: usize) -> FreeStack<N> {
        FreeStack {
            head: AtomicPtr::new(ptr::null_mut()),
            len: AtomicUsize::new(0),
            max_free,
            popping: std::sync::atomic::AtomicBool::new(false),
        }
    }

    /// Push from any thread. Returns `false` (caller keeps the box and
    /// should drop it) when the pool is at its retention cap.
    fn push(&self, node: Box<N>) -> bool {
        if self.len.load(Ordering::Relaxed) >= self.max_free {
            return false;
        }
        self.len.fetch_add(1, Ordering::Relaxed);
        let raw = Box::into_raw(node);
        let mut head = self.head.load(Ordering::Relaxed);
        loop {
            unsafe { (*raw).next().store(head, Ordering::Relaxed) };
            match self.head.compare_exchange_weak(
                head,
                raw,
                Ordering::Release,
                Ordering::Relaxed,
            ) {
                Ok(_) => return true,
                Err(h) => head = h,
            }
        }
    }

    /// Pop a recycled node, or `None` when the stack is empty *or*
    /// another thread is mid-pop (the caller then allocates fresh —
    /// correct either way, just colder). The latch makes the single
    /// popper the ABA-safety proof needs a machine-checked invariant
    /// instead of a documentation one.
    fn pop(&self) -> Option<Box<N>> {
        if self.popping.swap(true, Ordering::Acquire) {
            return None;
        }
        let popped = loop {
            let head = self.head.load(Ordering::Acquire);
            if head.is_null() {
                break None;
            }
            // Safe: the latch guarantees we are the only popper, so
            // `head` stays in the stack (alive, `next` frozen) until our
            // CAS retires it; pushes only ever prepend.
            let next = unsafe { (*head).next().load(Ordering::Relaxed) };
            if self
                .head
                .compare_exchange(head, next, Ordering::AcqRel, Ordering::Acquire)
                .is_ok()
            {
                self.len.fetch_sub(1, Ordering::Relaxed);
                break Some(unsafe { Box::from_raw(head) });
            }
        };
        self.popping.store(false, Ordering::Release);
        popped
    }

    fn len(&self) -> usize {
        self.len.load(Ordering::Relaxed)
    }
}

impl<N: StackNode> Drop for FreeStack<N> {
    fn drop(&mut self) {
        let mut p = *self.head.get_mut();
        while !p.is_null() {
            let node = unsafe { Box::from_raw(p) };
            p = node.next().load(Ordering::Relaxed);
            drop(node);
        }
    }
}

// ---------------------------------------------------------------------------
// Exclusively-owned pooled buffers.
// ---------------------------------------------------------------------------

struct Node<T> {
    next: AtomicPtr<Node<T>>,
    buf: T,
}

impl<T> StackNode for Node<T> {
    fn next(&self) -> &AtomicPtr<Node<T>> {
        &self.next
    }
}

/// A recycling pool of buffers. Cheap to share (`Arc`); buffers may be
/// *returned* from any thread. [`Pool::take`] is safe from any thread
/// too, but only the pool's one steady taker thread reliably hits the
/// recycle path (module docs) — racing takers fall back to a fresh
/// allocation.
pub struct Pool<T: Recycle> {
    free: FreeStack<Node<T>>,
}

impl<T: Recycle> Pool<T> {
    /// A pool retaining at most `max_free` idle buffers.
    pub fn new(max_free: usize) -> Arc<Pool<T>> {
        Arc::new(Pool {
            free: FreeStack::new(max_free),
        })
    }

    /// Take a (cleared) buffer: recycled if one is idle, fresh otherwise.
    /// Lock-free and allocation-free once the pool is warm.
    pub fn take(self: &Arc<Self>) -> Pooled<T> {
        let node = self.free.pop().unwrap_or_else(|| {
            Box::new(Node {
                next: AtomicPtr::new(ptr::null_mut()),
                buf: T::default(),
            })
        });
        Pooled {
            node: Some(node),
            pool: Some(self.clone()),
        }
    }

    /// Idle buffers currently retained (diagnostics/tests).
    pub fn free_count(&self) -> usize {
        self.free.len()
    }

    fn put(&self, mut node: Box<Node<T>>) {
        node.buf.recycle();
        // `push` declines at the retention cap; the box then just drops.
        let _ = self.free.push(node);
    }
}

/// A buffer borrowed from a [`Pool`] (or detached, pool-less). Derefs to
/// the underlying buffer; returns to its pool on drop. The buffer's
/// free-list node travels inside, so neither take nor return allocates.
pub struct Pooled<T: Recycle> {
    /// `Some` until drop.
    node: Option<Box<Node<T>>>,
    /// `None` for detached buffers (plain owned, never recycled).
    pool: Option<Arc<Pool<T>>>,
}

impl<T: Recycle> Pooled<T> {
    /// Wrap a plain buffer with no pool behind it — same type, ordinary
    /// ownership. Used where a `Pooled` is expected but recycling is not
    /// worth a pool (tests, cold paths, deep clones).
    pub fn detached(buf: T) -> Pooled<T> {
        Pooled {
            node: Some(Box::new(Node {
                next: AtomicPtr::new(ptr::null_mut()),
                buf,
            })),
            pool: None,
        }
    }
}

impl<T: Recycle> Deref for Pooled<T> {
    type Target = T;
    fn deref(&self) -> &T {
        &self.node.as_ref().expect("pooled buffer present until drop").buf
    }
}

impl<T: Recycle> DerefMut for Pooled<T> {
    fn deref_mut(&mut self) -> &mut T {
        &mut self.node.as_mut().expect("pooled buffer present until drop").buf
    }
}

impl<T: Recycle> Drop for Pooled<T> {
    fn drop(&mut self) {
        if let Some(node) = self.node.take() {
            match self.pool.take() {
                Some(pool) => pool.put(node),
                None => drop(node),
            }
        }
    }
}

impl<T: Recycle + Clone> Clone for Pooled<T> {
    /// Deep copy, detached: a clone never shares or steals pool capacity.
    fn clone(&self) -> Pooled<T> {
        Pooled::detached((**self).clone())
    }
}

impl<T: Recycle + std::fmt::Debug> std::fmt::Debug for Pooled<T> {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        (**self).fmt(f)
    }
}

// ---------------------------------------------------------------------------
// Refcount-shared pooled buffers (single-copy reply broadcast).
// ---------------------------------------------------------------------------

/// A pooled buffer *plus* its refcount block, recycled together.
///
/// [`SharedPooled`] is the broadcast counterpart of [`Pooled`]: a chunk's
/// post-optimize parameters are copied **once** into one of these on the
/// owning core, handed to N pullers by refcount bump
/// ([`SharedPooled::clone`] — no copy, no allocation), and returned to
/// the pool when the last reference drops. `Arc<[f32]>` would give the
/// same sharing but allocates a fresh refcount block per completion;
/// here the block lives in the free-list node and cycles with its
/// buffer, so the steady state allocates exactly nothing.
struct SharedSlot<T> {
    next: AtomicPtr<SharedSlot<T>>,
    /// Live references. 1 = exclusively owned (mutation allowed).
    refs: AtomicUsize,
    /// Guarded by `refs`: `&mut` only while `refs == 1`, `&` otherwise.
    buf: UnsafeCell<T>,
}

impl<T> StackNode for SharedSlot<T> {
    fn next(&self) -> &AtomicPtr<SharedSlot<T>> {
        &self.next
    }
}

/// A recycling pool of refcount-shared buffers. The owning core is the
/// one steady taker (racing takers are safe but allocate fresh); the
/// final reference of a [`SharedPooled`] may drop — and so return the
/// slot — on any thread.
pub struct SharedPool<T: Recycle> {
    free: FreeStack<SharedSlot<T>>,
}

impl<T: Recycle> SharedPool<T> {
    /// A pool retaining at most `max_free` idle slots.
    pub fn new(max_free: usize) -> Arc<SharedPool<T>> {
        Arc::new(SharedPool {
            free: FreeStack::new(max_free),
        })
    }

    /// Take an exclusively-owned (cleared) buffer: recycled slot if one
    /// is idle, freshly boxed otherwise (warm-up only).
    pub fn take(self: &Arc<Self>) -> SharedPooled<T> {
        let slot = self.free.pop().unwrap_or_else(|| {
            Box::new(SharedSlot {
                next: AtomicPtr::new(ptr::null_mut()),
                refs: AtomicUsize::new(1),
                buf: UnsafeCell::new(T::default()),
            })
        });
        debug_assert_eq!(slot.refs.load(Ordering::Relaxed), 1);
        SharedPooled {
            slot: NonNull::from(Box::leak(slot)),
            pool: self.clone(),
        }
    }

    /// Idle slots currently retained (diagnostics/tests).
    pub fn free_count(&self) -> usize {
        self.free.len()
    }

    fn put(&self, mut slot: Box<SharedSlot<T>>) {
        slot.buf.get_mut().recycle();
        slot.refs.store(1, Ordering::Relaxed);
        let _ = self.free.push(slot);
    }
}

/// A reference to a [`SharedPool`] buffer. Derefs to `&T` always;
/// `&mut T` (via [`DerefMut`]) only while exclusively owned — the usual
/// lifecycle is *take → fill → clone N-1 times → send → last drop
/// recycles*. Cloning bumps the pooled refcount: no copy, no allocation.
pub struct SharedPooled<T: Recycle> {
    slot: NonNull<SharedSlot<T>>,
    pool: Arc<SharedPool<T>>,
}

// Safety: the slot is shared like an `Arc<T>` — `&T` access when shared,
// `&mut T` only at refcount 1, release/acquire on the count transfers
// ownership of the buffer contents between threads.
unsafe impl<T: Recycle + Sync> Send for SharedPooled<T> {}
unsafe impl<T: Recycle + Sync> Sync for SharedPooled<T> {}

impl<T: Recycle> SharedPooled<T> {
    fn slot(&self) -> &SharedSlot<T> {
        unsafe { self.slot.as_ref() }
    }

    /// Live references to this buffer (diagnostics/tests).
    pub fn ref_count(&self) -> usize {
        self.slot().refs.load(Ordering::Acquire)
    }
}

impl<T: Recycle> Deref for SharedPooled<T> {
    type Target = T;
    fn deref(&self) -> &T {
        // Shared `&T`: writers are excluded by the refcount-1 rule below.
        unsafe { &*self.slot().buf.get() }
    }
}

impl<T: Recycle> DerefMut for SharedPooled<T> {
    fn deref_mut(&mut self) -> &mut T {
        assert_eq!(
            self.slot().refs.load(Ordering::Acquire),
            1,
            "SharedPooled is only mutable while exclusively owned"
        );
        unsafe { &mut *self.slot().buf.get() }
    }
}

impl<T: Recycle> Clone for SharedPooled<T> {
    /// Refcount bump: the clone *shares* the buffer (unlike
    /// [`Pooled::clone`], which deep-copies — broadcast wants sharing).
    fn clone(&self) -> SharedPooled<T> {
        self.slot().refs.fetch_add(1, Ordering::Relaxed);
        SharedPooled {
            slot: self.slot,
            pool: self.pool.clone(),
        }
    }
}

impl<T: Recycle> Drop for SharedPooled<T> {
    fn drop(&mut self) {
        if self.slot().refs.fetch_sub(1, Ordering::Release) == 1 {
            // Last reference: acquire all prior writes, then recycle the
            // slot (buffer + refcount block together) into the pool.
            fence(Ordering::Acquire);
            let slot = unsafe { Box::from_raw(self.slot.as_ptr()) };
            self.pool.put(slot);
        }
    }
}

impl<T: Recycle + std::fmt::Debug> std::fmt::Debug for SharedPooled<T> {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        (**self).fmt(f)
    }
}

/// Frame-payload byte pool (wire receive path).
pub type BytePool = Pool<Vec<u8>>;
/// A pooled frame payload.
pub type PooledBytes = Pooled<Vec<u8>>;
/// Reply-parameter pool (engine → worker path): refcount-shared so one
/// serialized buffer broadcasts to every puller.
pub type SharedF32Pool = SharedPool<Vec<f32>>;
/// A refcount-shared pooled parameter buffer.
pub type SharedF32 = SharedPooled<Vec<f32>>;
/// Exclusively-owned f32 pool (scratch paths and benches).
pub type F32Pool = Pool<Vec<f32>>;
/// An exclusively-owned pooled f32 buffer.
pub type PooledF32 = Pooled<Vec<f32>>;

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn buffers_recycle_with_capacity() {
        let pool: Arc<BytePool> = Pool::new(4);
        let ptr;
        {
            let mut b = pool.take();
            b.extend_from_slice(&[1, 2, 3, 4]);
            ptr = b.as_ptr();
        } // drop → back to pool, cleared
        assert_eq!(pool.free_count(), 1);
        let b = pool.take();
        assert!(b.is_empty(), "recycled buffer is cleared");
        assert!(b.capacity() >= 4, "recycled buffer keeps capacity");
        assert_eq!(b.as_ptr(), ptr, "same allocation came back");
    }

    #[test]
    fn retention_is_bounded() {
        let pool: Arc<F32Pool> = Pool::new(2);
        let bufs: Vec<PooledF32> = (0..5).map(|_| pool.take()).collect();
        drop(bufs);
        assert_eq!(pool.free_count(), 2, "excess buffers dropped, not hoarded");
    }

    #[test]
    fn detached_and_clone_never_touch_a_pool() {
        let pool: Arc<F32Pool> = Pool::new(4);
        let mut b = pool.take();
        b.extend_from_slice(&[1.0, 2.0]);
        let c = b.clone();
        drop(c); // detached clone: no pool return
        assert_eq!(pool.free_count(), 0);
        drop(b);
        assert_eq!(pool.free_count(), 1);
        let d = Pooled::detached(vec![9.0f32]);
        assert_eq!(&*d, &vec![9.0]);
        drop(d); // no pool: plain drop
    }

    #[test]
    fn returns_cross_thread() {
        let pool: Arc<BytePool> = Pool::new(8);
        let b = pool.take();
        std::thread::spawn(move || drop(b)).join().unwrap();
        assert_eq!(pool.free_count(), 1);
    }

    /// Hammer the lock-free free list: many returner threads recycling
    /// into one pool while its single taker keeps taking. Exercises the
    /// push/pop CAS races; the invariant is simply no loss, no crash,
    /// bounded retention.
    #[test]
    fn concurrent_returns_race_single_taker() {
        let pool: Arc<BytePool> = Pool::new(64);
        let mut returners = Vec::new();
        for _ in 0..4 {
            // (test plumbing only — the data plane itself uses ring.rs)
            let (txi, rxi) = std::sync::mpsc::channel::<PooledBytes>();
            returners.push((
                txi,
                std::thread::spawn(move || {
                    while let Ok(b) = rxi.recv() {
                        drop(b); // return to pool from this thread
                    }
                }),
            ));
        }
        for lap in 0..2000usize {
            let mut b = pool.take();
            b.push(lap as u8);
            returners[lap % 4].0.send(b).unwrap();
        }
        for (tx, h) in returners {
            drop(tx);
            h.join().unwrap();
        }
        // The retention cap is soft under concurrent returns: the
        // check-then-push race can overshoot by at most one per
        // concurrent returner.
        assert!(pool.free_count() <= 64 + 4);
        // Pool still functional afterwards.
        let b = pool.take();
        assert!(b.is_empty());
    }

    #[test]
    fn shared_clone_shares_and_last_drop_recycles() {
        let pool: Arc<SharedF32Pool> = SharedPool::new(4);
        let mut a = pool.take();
        a.extend_from_slice(&[1.0, 2.0]);
        let ptr = a.as_ptr();
        let b = a.clone();
        let c = b.clone();
        assert_eq!(a.ref_count(), 3);
        assert_eq!(b.as_ptr(), ptr, "clones share the buffer, no copy");
        assert_eq!(&*c, &vec![1.0, 2.0]);
        drop(a);
        drop(b);
        assert_eq!(pool.free_count(), 0, "still referenced: not recycled");
        drop(c);
        assert_eq!(pool.free_count(), 1, "last drop recycles");
        // The recycled slot comes back cleared, same allocation.
        let d = pool.take();
        assert!(d.is_empty());
        assert!(d.capacity() >= 2);
        assert_eq!(d.as_ptr(), ptr, "buffer AND refcount block reused");
    }

    #[test]
    #[should_panic(expected = "only mutable while exclusively owned")]
    fn shared_mutation_requires_exclusivity() {
        let pool: Arc<SharedF32Pool> = SharedPool::new(4);
        let mut a = pool.take();
        a.push(1.0); // fine: refcount 1
        let _b = a.clone();
        a.push(2.0); // panics: shared
    }

    #[test]
    fn shared_last_drop_on_another_thread_returns_home() {
        let pool: Arc<SharedF32Pool> = SharedPool::new(4);
        let mut a = pool.take();
        a.extend_from_slice(&[3.0]);
        let b = a.clone();
        drop(a);
        std::thread::spawn(move || {
            assert_eq!(b[0], 3.0);
            drop(b);
        })
        .join()
        .unwrap();
        assert_eq!(pool.free_count(), 1);
    }

    #[test]
    fn shared_retention_is_bounded() {
        let pool: Arc<SharedF32Pool> = SharedPool::new(2);
        let bufs: Vec<SharedF32> = (0..5).map(|_| pool.take()).collect();
        drop(bufs);
        assert_eq!(pool.free_count(), 2);
    }
}
