//! Deterministic, seeded fault injection for the TCP transport.
//!
//! The production connection plane is never modified for testing:
//! faults are injected *under* it, by routing a worker's socket through
//! a local [`FaultProxy`] whose upstream (worker → leader) leg passes
//! every byte through a [`FaultStream`]. The stream reassembles wire
//! frames from the byte stream (4-byte LE length prefix + body, exactly
//! the `wire.rs` framing) and, per complete frame, consults a seeded
//! [`FaultPlan`] for an action:
//!
//! - **Forward** — pass the frame through untouched (the common case);
//! - **Delay** — sleep a few milliseconds, then forward (straggler);
//! - **Duplicate** — forward the frame twice (replayed frame; the
//!   leader must treat the second copy as a protocol violation and
//!   drop the connection, which the recovery machinery then heals);
//! - **Cut** — forward a strict byte prefix of the frame, then kill
//!   the connection (torn / mid-frame write);
//! - **Kill** — kill the connection without forwarding (clean death
//!   between frames).
//!
//! Everything is driven by one [`XorShift64`] PRNG, so a `(seed,
//! rates)` pair names a reproducible fault schedule: the chaos soak
//! test replays the exact same schedule when a seed fails in CI.
//!
//! Faults are injected on the worker → leader direction only; the
//! leader → worker leg is copied verbatim. Killing either leg tears
//! down both, so from the worker's side every injected death looks
//! like a real peer disconnect and exercises the production
//! reconnect/rollback/replay path unmodified.

use std::io::{self, Read, Write};
use std::net::{Shutdown, SocketAddr, TcpListener, TcpStream};
use std::time::Duration;

/// Minimal xorshift64* PRNG — deterministic, dependency-free, and good
/// enough for fault scheduling (this is not a statistical application).
#[derive(Debug, Clone)]
pub struct XorShift64 {
    state: u64,
}

impl XorShift64 {
    pub fn new(seed: u64) -> Self {
        // xorshift has a fixed point at zero; remap it.
        let state = if seed == 0 { 0x9E37_79B9_7F4A_7C15 } else { seed };
        Self { state }
    }

    pub fn next_u64(&mut self) -> u64 {
        let mut x = self.state;
        x ^= x << 13;
        x ^= x >> 7;
        x ^= x << 17;
        self.state = x;
        x.wrapping_mul(0x2545_F491_4F6C_DD1D)
    }

    /// Uniform in `[0, 1)`.
    pub fn next_f32(&mut self) -> f32 {
        // 24 mantissa-ish bits; exact enough for rate thresholds.
        (self.next_u64() >> 40) as f32 / (1u64 << 24) as f32
    }
}

/// Per-frame fault probabilities. Each complete frame draws once; the
/// first matching band (kill, cut, delay, dup, in that order) fires.
#[derive(Debug, Clone, Copy, Default)]
pub struct FaultRates {
    pub kill: f32,
    pub cut: f32,
    pub delay: f32,
    pub dup: f32,
}

impl FaultRates {
    /// A single overall fault rate `p`, split across the four fault
    /// kinds (40% kills, 30% cuts, 20% delays, 10% duplicates).
    pub fn uniform(p: f32) -> Self {
        Self {
            kill: p * 0.4,
            cut: p * 0.3,
            delay: p * 0.2,
            dup: p * 0.1,
        }
    }
}

/// What to do with one reassembled frame.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FaultAction {
    Forward,
    Delay(Duration),
    Duplicate,
    /// Forward `keep` bytes of the frame (a strict prefix), then die.
    Cut {
        keep: usize,
    },
    Kill,
}

/// A seeded schedule of fault actions: the same `(seed, rates)` pair
/// always yields the same action sequence for the same frame sizes.
#[derive(Debug, Clone)]
pub struct FaultPlan {
    rng: XorShift64,
    rates: FaultRates,
}

impl FaultPlan {
    pub fn new(seed: u64, rates: FaultRates) -> Self {
        Self {
            rng: XorShift64::new(seed),
            rates,
        }
    }

    /// Draw the action for the next complete frame of `frame_len`
    /// bytes (length prefix included; always ≥ 5 on the real wire).
    pub fn action_for_frame(&mut self, frame_len: usize) -> FaultAction {
        let r = self.rng.next_f32();
        let k = self.rates.kill;
        let c = k + self.rates.cut;
        let d = c + self.rates.delay;
        let u = d + self.rates.dup;
        if r < k {
            FaultAction::Kill
        } else if r < c && frame_len >= 2 {
            // A strict non-empty prefix: 1 ..= frame_len - 1.
            let keep = 1 + (self.rng.next_u64() as usize) % (frame_len - 1);
            FaultAction::Cut { keep }
        } else if r < d {
            let ms = 1 + self.rng.next_u64() % 5;
            FaultAction::Delay(Duration::from_millis(ms))
        } else if r < u {
            FaultAction::Duplicate
        } else {
            FaultAction::Forward
        }
    }
}

/// A `Write` adapter that reassembles wire frames from the byte stream
/// and applies a [`FaultPlan`] action to each one before (maybe)
/// forwarding it to the inner writer. Partial frames are buffered until
/// complete, so the only way a torn frame reaches the wire is an
/// explicit `Cut` — which is the point: torn writes are scheduled, not
/// accidental.
pub struct FaultStream<W: Write> {
    inner: W,
    plan: FaultPlan,
    buf: Vec<u8>,
    dead: bool,
    injected: u64,
}

impl<W: Write> FaultStream<W> {
    pub fn new(inner: W, plan: FaultPlan) -> Self {
        Self {
            inner,
            plan,
            buf: Vec::new(),
            dead: false,
            injected: 0,
        }
    }

    /// Number of non-`Forward` actions applied so far.
    pub fn injected(&self) -> u64 {
        self.injected
    }

    fn apply(&mut self, start: usize, end: usize) -> io::Result<()> {
        let action = self.plan.action_for_frame(end - start);
        let frame = &self.buf[start..end];
        match action {
            FaultAction::Forward => self.inner.write_all(frame),
            FaultAction::Delay(d) => {
                self.injected += 1;
                std::thread::sleep(d);
                self.inner.write_all(frame)
            }
            FaultAction::Duplicate => {
                self.injected += 1;
                self.inner.write_all(frame)?;
                self.inner.write_all(frame)
            }
            FaultAction::Cut { keep } => {
                self.injected += 1;
                self.dead = true;
                let keep = keep.min(frame.len() - 1);
                self.inner.write_all(&frame[..keep])?;
                self.inner.flush()?;
                Err(io::Error::new(
                    io::ErrorKind::BrokenPipe,
                    "fault injection: mid-frame cut",
                ))
            }
            FaultAction::Kill => {
                self.injected += 1;
                self.dead = true;
                Err(io::Error::new(
                    io::ErrorKind::ConnectionReset,
                    "fault injection: connection kill",
                ))
            }
        }
    }
}

impl<W: Write> Write for FaultStream<W> {
    fn write(&mut self, data: &[u8]) -> io::Result<usize> {
        if self.dead {
            return Err(io::Error::new(
                io::ErrorKind::BrokenPipe,
                "fault injection: stream already killed",
            ));
        }
        self.buf.extend_from_slice(data);
        // Drain every complete frame currently buffered.
        let mut start = 0usize;
        while self.buf.len() - start >= 4 {
            let body = u32::from_le_bytes([
                self.buf[start],
                self.buf[start + 1],
                self.buf[start + 2],
                self.buf[start + 3],
            ]) as usize;
            let total = 4 + body;
            if self.buf.len() - start < total {
                break;
            }
            if let Err(e) = self.apply(start, start + total) {
                self.buf.clear();
                return Err(e);
            }
            start += total;
        }
        self.buf.drain(..start);
        Ok(data.len())
    }

    fn flush(&mut self) -> io::Result<()> {
        if self.dead {
            return Err(io::Error::new(
                io::ErrorKind::BrokenPipe,
                "fault injection: stream already killed",
            ));
        }
        self.inner.flush()
    }
}

/// A one-connection TCP proxy that injects faults on the client →
/// upstream direction. `spawn` binds an ephemeral localhost port and
/// returns immediately; the first accepted connection is bridged to
/// `upstream` with the client's bytes routed through a
/// [`FaultStream`]. When either leg dies (injected or real), both
/// sockets are shut down so the death is visible end to end.
pub struct FaultProxy {
    addr: SocketAddr,
}

impl FaultProxy {
    pub fn spawn(upstream: SocketAddr, plan: FaultPlan) -> io::Result<FaultProxy> {
        let listener = TcpListener::bind("127.0.0.1:0")?;
        let addr = listener.local_addr()?;
        std::thread::Builder::new()
            .name("phub-fault-proxy".into())
            .spawn(move || {
                let Ok((client, _)) = listener.accept() else {
                    return;
                };
                let Ok(server) = TcpStream::connect(upstream) else {
                    let _ = client.shutdown(Shutdown::Both);
                    return;
                };
                let _ = client.set_nodelay(true);
                let _ = server.set_nodelay(true);
                let (Ok(client_rd), Ok(server_rd)) = (client.try_clone(), server.try_clone())
                else {
                    return;
                };
                // Downstream leg: leader → worker, copied verbatim.
                let down_client = client.try_clone();
                std::thread::spawn(move || {
                    let mut rd = server_rd;
                    if let Ok(mut wr) = down_client {
                        let _ = io::copy(&mut rd, &mut wr);
                        let _ = wr.shutdown(Shutdown::Both);
                    }
                    let _ = rd.shutdown(Shutdown::Both);
                });
                // Upstream leg: worker → leader, through the fault plan.
                let mut rd = client_rd;
                let mut faulted = FaultStream::new(&server, plan);
                let mut buf = [0u8; 4096];
                loop {
                    match rd.read(&mut buf) {
                        Ok(0) | Err(_) => break,
                        Ok(n) => {
                            if faulted.write_all(&buf[..n]).is_err() {
                                break;
                            }
                        }
                    }
                }
                let _ = client.shutdown(Shutdown::Both);
                let _ = server.shutdown(Shutdown::Both);
            })?;
        Ok(FaultProxy { addr })
    }

    /// The local address workers should connect to.
    pub fn addr(&self) -> SocketAddr {
        self.addr
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn frame(body: &[u8]) -> Vec<u8> {
        let mut f = (body.len() as u32).to_le_bytes().to_vec();
        f.extend_from_slice(body);
        f
    }

    #[test]
    fn same_seed_same_schedule() {
        let mut a = FaultPlan::new(42, FaultRates::uniform(0.5));
        let mut b = FaultPlan::new(42, FaultRates::uniform(0.5));
        for len in [20usize, 48, 20, 300, 64, 20, 20, 48] {
            assert_eq!(a.action_for_frame(len), b.action_for_frame(len));
        }
        let mut c = FaultPlan::new(43, FaultRates::uniform(0.5));
        let divergent = (0..64).any(|_| a.action_for_frame(48) != c.action_for_frame(48));
        assert!(divergent, "different seeds should diverge");
    }

    #[test]
    fn zero_rate_forwards_everything_byte_identical() {
        let mut out = Vec::new();
        let mut s = FaultStream::new(&mut out, FaultPlan::new(7, FaultRates::default()));
        let mut input = Vec::new();
        for body in [&b"hello"[..], &[0u8; 32][..], &b"x"[..]] {
            input.extend_from_slice(&frame(body));
        }
        // Dribble one byte at a time to exercise reassembly.
        for b in &input {
            s.write_all(std::slice::from_ref(b)).unwrap();
        }
        assert_eq!(s.injected(), 0);
        drop(s);
        assert_eq!(out, input);
    }

    #[test]
    fn duplicate_forwards_two_copies() {
        let mut out = Vec::new();
        let rates = FaultRates {
            dup: 1.0,
            ..FaultRates::default()
        };
        let mut s = FaultStream::new(&mut out, FaultPlan::new(1, rates));
        let f = frame(b"payload");
        s.write_all(&f).unwrap();
        assert_eq!(s.injected(), 1);
        drop(s);
        assert_eq!(out.len(), 2 * f.len());
        assert_eq!(&out[..f.len()], &f[..]);
        assert_eq!(&out[f.len()..], &f[..]);
    }

    #[test]
    fn cut_forwards_a_strict_prefix_then_kills() {
        let rates = FaultRates {
            cut: 1.0,
            ..FaultRates::default()
        };
        for seed in 1..32u64 {
            let mut out = Vec::new();
            let mut s = FaultStream::new(&mut out, FaultPlan::new(seed, rates));
            let f = frame(&[0xABu8; 60]);
            let err = s.write_all(&f).unwrap_err();
            assert_eq!(err.kind(), io::ErrorKind::BrokenPipe);
            // Once dead, every further write fails.
            assert!(s.write_all(&f).is_err());
            drop(s);
            assert!(!out.is_empty(), "cut must forward at least one byte");
            assert!(out.len() < f.len(), "cut must never forward a full frame");
            assert_eq!(&out[..], &f[..out.len()]);
        }
    }

    #[test]
    fn kill_forwards_nothing() {
        let rates = FaultRates {
            kill: 1.0,
            ..FaultRates::default()
        };
        let mut out = Vec::new();
        let mut s = FaultStream::new(&mut out, FaultPlan::new(5, rates));
        let err = s.write_all(&frame(b"doomed")).unwrap_err();
        assert_eq!(err.kind(), io::ErrorKind::ConnectionReset);
        drop(s);
        assert!(out.is_empty());
    }
}
