//! Chunk/key → shard/interface/core assignment (paper section 3.2.4).
//!
//! PHub computes all placement at initialization time: keys are sharded
//! across PS processes, and chunks are bound to a (queue pair, completion
//! queue, core, NUMA domain) tuple that never changes during training. The
//! balancer is LPT (longest-processing-time-first greedy), the classic
//! 4/3-approximation for minimum-makespan partitioning the paper cites.

/// Greedy LPT partition: assign each weighted item to the currently
/// lightest bin, heaviest items first. Returns the bin index per item.
///
/// Guarantees makespan ≤ (4/3 − 1/(3m)) · OPT.
pub fn lpt_partition(weights: &[usize], n_bins: usize) -> Vec<usize> {
    assert!(n_bins > 0);
    let mut order: Vec<usize> = (0..weights.len()).collect();
    order.sort_by(|&a, &b| weights[b].cmp(&weights[a]).then(a.cmp(&b)));
    let mut load = vec![0usize; n_bins];
    let mut assign = vec![0usize; weights.len()];
    for i in order {
        let bin = (0..n_bins).min_by_key(|&b| (load[b], b)).unwrap();
        assign[i] = bin;
        load[bin] += weights[i];
    }
    assign
}

/// Key → PS-shard assignment, balanced by key bytes.
pub fn assign_keys_to_shards(key_bytes: &[usize], n_shards: usize) -> Vec<usize> {
    lpt_partition(key_bytes, n_shards)
}

/// Maximum bin load under an assignment (for balance checks).
pub fn makespan(weights: &[usize], assign: &[usize], n_bins: usize) -> usize {
    let mut load = vec![0usize; n_bins];
    for (i, &b) in assign.iter().enumerate() {
        load[b] += weights[i];
    }
    load.into_iter().max().unwrap_or(0)
}

/// NUMA domain of a core (cores split contiguously across domains).
pub fn core_numa(core: usize, cores: usize, numa: usize) -> usize {
    core * numa / cores
}

/// NUMA domain of a NIC (NICs split contiguously across domains — the PBox
/// attaches 5 of its 10 cards to each socket, section 4.1).
pub fn nic_numa(nic: usize, nics: usize, numa: usize) -> usize {
    nic * numa / nics
}

/// Uniform-chunk slot assignment: chunk `g` → (interface, core), with the
/// core drawn from the same NUMA domain as the interface so a chunk's
/// queue pair, completion queue, and aggregation buffer never cross
/// sockets (section 3.3: "no inter-processor traffic on PBox").
pub fn chunk_slot(g: usize, nics: usize, cores: usize, numa: usize) -> (usize, usize) {
    assert!(nics > 0 && cores > 0 && numa > 0);
    let iface = g % nics;
    let dom = nic_numa(iface, nics, numa);
    // Cores belonging to this NUMA domain. Boundaries use the same
    // rounding as `core_numa` (core c is in domain c*numa/cores), i.e.
    // domain d owns [ceil(d*cores/numa), ceil((d+1)*cores/numa)).
    let first = (dom * cores).div_ceil(numa);
    let end = ((dom + 1) * cores).div_ceil(numa).min(cores);
    let count = end - first;
    let core = first + (g / nics) % count.max(1);
    (iface, core)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn lpt_balances_uniform() {
        let w = vec![1usize; 100];
        let a = lpt_partition(&w, 10);
        for b in 0..10 {
            assert_eq!(a.iter().filter(|&&x| x == b).count(), 10);
        }
    }

    #[test]
    fn lpt_heavy_item_isolated() {
        // One huge key (AlexNet fc6-like) + many small ones: the huge key
        // gets its own shard.
        let mut w = vec![10usize; 20];
        w.push(1000);
        let a = lpt_partition(&w, 4);
        let huge_bin = a[20];
        for (i, &b) in a.iter().enumerate() {
            if i != 20 {
                assert_ne!(b, huge_bin);
            }
        }
    }

    #[test]
    fn lpt_within_four_thirds_of_mean_bound() {
        // Makespan ≤ 4/3 * OPT and OPT ≥ max(mean, max_item).
        let w: Vec<usize> = (1..=50).map(|i| (i * 37) % 97 + 3).collect();
        for bins in [2, 4, 7] {
            let a = lpt_partition(&w, bins);
            let ms = makespan(&w, &a, bins);
            let total: usize = w.iter().sum();
            let opt_lb = (total as f64 / bins as f64)
                .max(*w.iter().max().unwrap() as f64);
            assert!(ms as f64 <= 4.0 / 3.0 * opt_lb + 1.0, "bins={bins} ms={ms}");
        }
    }

    #[test]
    fn chunk_slot_keeps_core_in_nic_numa() {
        let (nics, cores, numa) = (10, 28, 2);
        for g in 0..1000 {
            let (iface, core) = chunk_slot(g, nics, cores, numa);
            assert_eq!(
                nic_numa(iface, nics, numa),
                core_numa(core, cores, numa),
                "g={g} iface={iface} core={core}"
            );
        }
    }

    #[test]
    fn chunk_slot_balances_interfaces_and_cores() {
        let (nics, cores, numa) = (10, 28, 2);
        let mut per_iface = vec![0usize; nics];
        let mut per_core = vec![0usize; cores];
        let n = 10 * 28 * 10;
        for g in 0..n {
            let (i, c) = chunk_slot(g, nics, cores, numa);
            per_iface[i] += 1;
            per_core[c] += 1;
        }
        assert!(per_iface.iter().all(|&x| x == n / nics));
        let max = *per_core.iter().max().unwrap();
        let min = *per_core.iter().min().unwrap();
        assert!(max - min <= n / cores / 4, "{per_core:?}");
    }

    #[test]
    fn single_bin_and_empty_inputs() {
        assert_eq!(lpt_partition(&[5, 3], 1), vec![0, 0]);
        assert!(lpt_partition(&[], 4).is_empty());
        assert_eq!(makespan(&[], &[], 4), 0);
    }
}
