//! Chunk/key → shard/interface/core assignment (paper section 3.2.4).
//!
//! PHub computes all placement at initialization time: keys are sharded
//! across PS processes, and chunks are bound to a (queue pair, completion
//! queue, core, NUMA domain) tuple that never changes during training.
//! Two chunk→core balancers live here:
//!
//! * [`lpt_partition`] — LPT (longest-processing-time-first greedy), the
//!   classic 4/3-approximation for minimum-makespan partitioning the
//!   paper cites. For the uniform chunks `KeyTable::flat` produces, LPT
//!   degenerates to a round-robin scatter: neighboring chunks land on
//!   different cores ([`PlacementMode::Interleave`]).
//! * [`affine_partition`] — PHub's key-affinity scheme
//!   ([`PlacementMode::Affine`], the default): each core owns one
//!   *contiguous* run of chunks, i.e. one contiguous byte range of the
//!   model ≈ `model_bytes / n_cores` wide. A core's accumulators,
//!   parameters, and optimizer state then form a single contiguous
//!   working set sized to its share of the last-level cache, instead of
//!   being strided across the whole model; extent boundaries fall on
//!   chunk boundaries, which are cache-line-aligned whenever the
//!   chunking is a multiple of 16 f32s (every power-of-two
//!   `chunk_elems`). The SPSC port fabric already delivers each frame
//!   to the chunk's owning core directly (`core_of[chunk]` indexes the
//!   per-(worker,core) request ring), so with affine placement a worker
//!   connection's frames for one model region land on one core with no
//!   cross-core handoff.

/// Greedy LPT partition: assign each weighted item to the currently
/// lightest bin, heaviest items first. Returns the bin index per item.
///
/// Guarantees makespan ≤ (4/3 − 1/(3m)) · OPT.
pub fn lpt_partition(weights: &[usize], n_bins: usize) -> Vec<usize> {
    assert!(n_bins > 0);
    let mut order: Vec<usize> = (0..weights.len()).collect();
    order.sort_by(|&a, &b| weights[b].cmp(&weights[a]).then(a.cmp(&b)));
    let mut load = vec![0usize; n_bins];
    let mut assign = vec![0usize; weights.len()];
    for i in order {
        let bin = (0..n_bins).min_by_key(|&b| (load[b], b)).unwrap();
        assign[i] = bin;
        load[bin] += weights[i];
    }
    assign
}

/// Environment variable overriding the default chunk→core placement
/// (`affine` | `interleave`, case-insensitive).
pub const ENV_PLACEMENT: &str = "PHUB_PLACEMENT";

/// How `init_job` maps chunks onto aggregation cores. Discriminants are
/// stable and mirrored in `DataPlaneMetrics::placement_mode`.
///
/// Either mode yields bit-identical training: a chunk is wholly owned by
/// one core in both, so only locality changes (property-tested in
/// `server.rs`).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
#[repr(u8)]
pub enum PlacementMode {
    /// [`lpt_partition`]: balanced scatter; neighboring chunks land on
    /// different cores (the pre-affinity behavior).
    Interleave = 0,
    /// [`affine_partition`]: each core owns one contiguous byte range of
    /// the model (PHub's key-affinity scheme; the default).
    Affine = 1,
}

impl PlacementMode {
    pub fn name(self) -> &'static str {
        match self {
            PlacementMode::Interleave => "interleave",
            PlacementMode::Affine => "affine",
        }
    }

    /// Inverse of `mode as u8` (for metrics readers).
    pub fn from_u8(v: u8) -> Option<PlacementMode> {
        match v {
            0 => Some(PlacementMode::Interleave),
            1 => Some(PlacementMode::Affine),
            _ => None,
        }
    }

    /// The [`ENV_PLACEMENT`] override, or [`PlacementMode::Affine`] when
    /// unset/unrecognized. Read once per `ServerConfig` construction
    /// (init time), never on the data plane.
    pub fn from_env() -> PlacementMode {
        Self::parse_env(std::env::var(ENV_PLACEMENT).ok().as_deref())
    }

    fn parse_env(env: Option<&str>) -> PlacementMode {
        match env.map(|v| v.to_ascii_lowercase()) {
            Some(v) if v == "interleave" => PlacementMode::Interleave,
            Some(v) if v == "affine" => PlacementMode::Affine,
            _ => PlacementMode::Affine,
        }
    }

    /// Partition `weights` (chunk byte/element sizes) over `n_bins`
    /// cores under this mode.
    pub fn partition(self, weights: &[usize], n_bins: usize) -> Vec<usize> {
        match self {
            PlacementMode::Interleave => lpt_partition(weights, n_bins),
            PlacementMode::Affine => affine_partition(weights, n_bins),
        }
    }
}

/// Contiguous-extent partition (PHub key affinity): assign each item to
/// the bin its weight-midpoint falls into when the total weight is split
/// into `n_bins` equal spans. Bin indices are non-decreasing over items,
/// so every bin owns one contiguous extent, and each bin's load is
/// within one item of the ideal `total / n_bins` share
/// (load ≤ total/n_bins + max_weight; property-tested).
pub fn affine_partition(weights: &[usize], n_bins: usize) -> Vec<usize> {
    assert!(n_bins > 0);
    let total: usize = weights.iter().sum();
    if total == 0 {
        return vec![0; weights.len()];
    }
    let mut assign = Vec::with_capacity(weights.len());
    let mut before = 0usize;
    for &w in weights {
        let mid = before + w / 2;
        assign.push((mid * n_bins / total).min(n_bins - 1));
        before += w;
    }
    assign
}

/// Key → PS-shard assignment, balanced by key bytes.
pub fn assign_keys_to_shards(key_bytes: &[usize], n_shards: usize) -> Vec<usize> {
    lpt_partition(key_bytes, n_shards)
}

/// Maximum bin load under an assignment (for balance checks).
pub fn makespan(weights: &[usize], assign: &[usize], n_bins: usize) -> usize {
    let mut load = vec![0usize; n_bins];
    for (i, &b) in assign.iter().enumerate() {
        load[b] += weights[i];
    }
    load.into_iter().max().unwrap_or(0)
}

/// NUMA domain of a core (cores split contiguously across domains).
pub fn core_numa(core: usize, cores: usize, numa: usize) -> usize {
    core * numa / cores
}

/// NUMA domain of a NIC (NICs split contiguously across domains — the PBox
/// attaches 5 of its 10 cards to each socket, section 4.1).
pub fn nic_numa(nic: usize, nics: usize, numa: usize) -> usize {
    nic * numa / nics
}

/// Uniform-chunk slot assignment: chunk `g` → (interface, core), with the
/// core drawn from the same NUMA domain as the interface so a chunk's
/// queue pair, completion queue, and aggregation buffer never cross
/// sockets (section 3.3: "no inter-processor traffic on PBox").
pub fn chunk_slot(g: usize, nics: usize, cores: usize, numa: usize) -> (usize, usize) {
    assert!(nics > 0 && cores > 0 && numa > 0);
    let iface = g % nics;
    let dom = nic_numa(iface, nics, numa);
    // Cores belonging to this NUMA domain. Boundaries use the same
    // rounding as `core_numa` (core c is in domain c*numa/cores), i.e.
    // domain d owns [ceil(d*cores/numa), ceil((d+1)*cores/numa)).
    let first = (dom * cores).div_ceil(numa);
    let end = ((dom + 1) * cores).div_ceil(numa).min(cores);
    let count = end - first;
    let core = first + (g / nics) % count.max(1);
    (iface, core)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn lpt_balances_uniform() {
        let w = vec![1usize; 100];
        let a = lpt_partition(&w, 10);
        for b in 0..10 {
            assert_eq!(a.iter().filter(|&&x| x == b).count(), 10);
        }
    }

    #[test]
    fn lpt_heavy_item_isolated() {
        // One huge key (AlexNet fc6-like) + many small ones: the huge key
        // gets its own shard.
        let mut w = vec![10usize; 20];
        w.push(1000);
        let a = lpt_partition(&w, 4);
        let huge_bin = a[20];
        for (i, &b) in a.iter().enumerate() {
            if i != 20 {
                assert_ne!(b, huge_bin);
            }
        }
    }

    #[test]
    fn lpt_within_four_thirds_of_mean_bound() {
        // Makespan ≤ 4/3 * OPT and OPT ≥ max(mean, max_item).
        let w: Vec<usize> = (1..=50).map(|i| (i * 37) % 97 + 3).collect();
        for bins in [2, 4, 7] {
            let a = lpt_partition(&w, bins);
            let ms = makespan(&w, &a, bins);
            let total: usize = w.iter().sum();
            let opt_lb = (total as f64 / bins as f64)
                .max(*w.iter().max().unwrap() as f64);
            assert!(ms as f64 <= 4.0 / 3.0 * opt_lb + 1.0, "bins={bins} ms={ms}");
        }
    }

    #[test]
    fn chunk_slot_keeps_core_in_nic_numa() {
        let (nics, cores, numa) = (10, 28, 2);
        for g in 0..1000 {
            let (iface, core) = chunk_slot(g, nics, cores, numa);
            assert_eq!(
                nic_numa(iface, nics, numa),
                core_numa(core, cores, numa),
                "g={g} iface={iface} core={core}"
            );
        }
    }

    #[test]
    fn chunk_slot_balances_interfaces_and_cores() {
        let (nics, cores, numa) = (10, 28, 2);
        let mut per_iface = vec![0usize; nics];
        let mut per_core = vec![0usize; cores];
        let n = 10 * 28 * 10;
        for g in 0..n {
            let (i, c) = chunk_slot(g, nics, cores, numa);
            per_iface[i] += 1;
            per_core[c] += 1;
        }
        assert!(per_iface.iter().all(|&x| x == n / nics));
        let max = *per_core.iter().max().unwrap();
        let min = *per_core.iter().min().unwrap();
        assert!(max - min <= n / cores / 4, "{per_core:?}");
    }

    #[test]
    fn single_bin_and_empty_inputs() {
        assert_eq!(lpt_partition(&[5, 3], 1), vec![0, 0]);
        assert!(lpt_partition(&[], 4).is_empty());
        assert_eq!(makespan(&[], &[], 4), 0);
        assert_eq!(affine_partition(&[5, 3], 1), vec![0, 0]);
        assert!(affine_partition(&[], 4).is_empty());
        assert_eq!(affine_partition(&[0, 0], 4), vec![0, 0]);
    }

    #[test]
    fn affine_uniform_chunks_split_evenly_and_contiguously() {
        let w = vec![4096usize; 64];
        let a = affine_partition(&w, 4);
        // Non-decreasing (contiguous extents) and exactly 16 chunks each.
        assert!(a.windows(2).all(|p| p[0] <= p[1]), "{a:?}");
        for b in 0..4 {
            assert_eq!(a.iter().filter(|&&x| x == b).count(), 16, "{a:?}");
        }
        // First and last chunks pin the extreme cores.
        assert_eq!(a[0], 0);
        assert_eq!(a[63], 3);
    }

    #[test]
    fn affine_is_contiguous_and_balanced_for_ragged_weights() {
        let w: Vec<usize> = (1..=47).map(|i| (i * 53) % 307 + 1).collect();
        for bins in [1usize, 2, 3, 5, 8, 47, 64] {
            let a = affine_partition(&w, bins);
            assert!(a.iter().all(|&b| b < bins), "bins={bins} {a:?}");
            assert!(a.windows(2).all(|p| p[0] <= p[1]), "bins={bins} {a:?}");
            let total: usize = w.iter().sum();
            let max_w = *w.iter().max().unwrap();
            let ms = makespan(&w, &a, bins);
            assert!(
                ms <= total / bins + max_w,
                "bins={bins} makespan {ms} vs share {} + max {max_w}",
                total / bins
            );
        }
    }

    #[test]
    fn affine_more_bins_than_items_uses_spread_bins() {
        // 2 chunks over 8 bins: midpoints at 1/4 and 3/4 of the span.
        assert_eq!(affine_partition(&[10, 10], 8), vec![2, 6]);
    }

    #[test]
    fn placement_mode_env_parse_u8_roundtrip_and_partition() {
        assert_eq!(PlacementMode::parse_env(None), PlacementMode::Affine);
        assert_eq!(
            PlacementMode::parse_env(Some("interleave")),
            PlacementMode::Interleave
        );
        assert_eq!(
            PlacementMode::parse_env(Some("AFFINE")),
            PlacementMode::Affine
        );
        assert_eq!(
            PlacementMode::parse_env(Some("modulo")),
            PlacementMode::Affine
        );
        for m in [PlacementMode::Interleave, PlacementMode::Affine] {
            assert_eq!(PlacementMode::from_u8(m as u8), Some(m));
        }
        assert_eq!(PlacementMode::from_u8(9), None);
        assert_eq!(PlacementMode::Interleave.name(), "interleave");
        assert_eq!(PlacementMode::Affine.name(), "affine");
        let w = vec![8usize; 12];
        assert_eq!(
            PlacementMode::Affine.partition(&w, 3),
            affine_partition(&w, 3)
        );
        assert_eq!(
            PlacementMode::Interleave.partition(&w, 3),
            lpt_partition(&w, 3)
        );
    }
}
