//! Admission control, quotas, and load shedding for the multi-tenant
//! leader — the policy half of the tenant-guardrail layer (the
//! enforcement sites live in [`super::transport`] and
//! [`super::server`]).
//!
//! # Guardrail contract
//!
//! * **Every refusal is typed and retriable.** A `Hello` the leader
//!   cannot host is answered with a [`wire::Op::Refused`] frame carrying
//!   a [`RefuseReason`] code and a retry-after hint, then the connection
//!   closes. The client surfaces it as a typed [`Refusal`] error (never
//!   a hang, never a string-matched guess) and backs off with the
//!   transport's existing capped-backoff machinery.
//! * **Existing jobs are never refused by capacity.** Quota checks run
//!   only for `Hello`s that would *create* a job; a re-`Hello` of a
//!   hosted job (successor workers, reconnects after a fault) bypasses
//!   the job-count and capacity gates entirely, so a full leader can
//!   always heal the jobs it already accepted.
//! * **Shedding protects paying rounds.** When round-deadline trips
//!   cross [`QuotaConfig::shed_trip_threshold`] within
//!   [`QuotaConfig::shed_window`], the leader is declared overloaded
//!   and *new* admissions shed with [`RefuseReason::Overloaded`] —
//!   existing jobs keep their cores and their recovery paths.
//! * **Checks are control-plane only.** The controller is consulted at
//!   rendezvous and when a deadline trips; nothing on the per-chunk
//!   exchange path reads or writes it, so the exact-zero alloc/mutex
//!   discipline of the data plane is untouched.
//!
//! [`wire::Op::Refused`]: super::wire::Op::Refused

use std::sync::atomic::{AtomicBool, AtomicU32, AtomicU64, Ordering};
use std::time::{Duration, Instant};

use crate::config::QuotaConfig;

/// Why an admission was refused. The `u16` discriminants are the wire
/// reason codes carried in [`super::wire::Op::Refused`] payloads —
/// stable once shipped, never reassigned (same rule as opcodes).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
#[repr(u16)]
pub enum RefuseReason {
    /// The leader is shedding load: recent round-deadline trips crossed
    /// the overload watermark, so new jobs wait their turn.
    Overloaded = 1,
    /// Admitting this job would exceed [`QuotaConfig::max_jobs`].
    JobCap = 2,
    /// The job's declared worker seats exceed
    /// [`QuotaConfig::max_workers_per_job`], or every declared seat of
    /// an existing job is already taken.
    WorkerSlots = 3,
    /// The job's model exceeds [`QuotaConfig::max_model_elems_per_job`].
    ModelQuota = 4,
    /// Hosting this model would push the leader past
    /// [`QuotaConfig::max_total_model_elems`].
    TotalModelQuota = 5,
    /// This job's seats would push the leader past
    /// [`QuotaConfig::max_total_workers`].
    TotalWorkerQuota = 6,
}

impl RefuseReason {
    /// Decode a wire reason code.
    pub fn from_u16(v: u16) -> Option<RefuseReason> {
        Some(match v {
            1 => RefuseReason::Overloaded,
            2 => RefuseReason::JobCap,
            3 => RefuseReason::WorkerSlots,
            4 => RefuseReason::ModelQuota,
            5 => RefuseReason::TotalModelQuota,
            6 => RefuseReason::TotalWorkerQuota,
            _ => return None,
        })
    }

    /// Stable lowercase label (metrics/log vocabulary).
    pub fn as_str(self) -> &'static str {
        match self {
            RefuseReason::Overloaded => "overloaded",
            RefuseReason::JobCap => "job_cap",
            RefuseReason::WorkerSlots => "worker_slots",
            RefuseReason::ModelQuota => "model_quota",
            RefuseReason::TotalModelQuota => "total_model_quota",
            RefuseReason::TotalWorkerQuota => "total_worker_quota",
        }
    }
}

/// A typed, retriable admission refusal. Implements
/// [`std::error::Error`], so it travels inside `anyhow::Error` through
/// the transport and is recovered by downcast on both ends: the leader
/// turns it into an [`super::wire::Op::Refused`] frame, the client
/// turns that frame back into this type for its backoff loop.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Refusal {
    pub reason: RefuseReason,
    /// How long the leader suggests waiting before retrying. A hint,
    /// not a lease — retrying earlier is safe, just likely futile.
    pub retry_after: Duration,
}

impl std::fmt::Display for Refusal {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "admission refused ({}); retry after {} ms",
            self.reason.as_str(),
            self.retry_after.as_millis()
        )
    }
}

impl std::error::Error for Refusal {}

/// Leader-wide usage a new `Hello` is evaluated against. Derived from
/// the live jobs map under its (control-plane) lock, so the checks are
/// race-free with respect to concurrent admissions.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct LeaderUsage {
    /// Jobs currently hosted.
    pub jobs: usize,
    /// Sum of hosted jobs' model elements.
    pub model_elems: u64,
    /// Sum of hosted jobs' declared worker seats.
    pub workers: u64,
}

/// Evaluates every job-creating `Hello` against a [`QuotaConfig`] and
/// tracks the overload watermark for load shedding. Cheap enough to
/// consult with the jobs lock held; never touched by the data plane.
pub struct AdmissionController {
    quota: QuotaConfig,
    anchor: Instant,
    /// Start of the current shed window, ms since `anchor`.
    window_start_ms: AtomicU64,
    /// Deadline trips recorded inside the current window. The two cells
    /// are not updated as one atomic unit; the watermark is a pressure
    /// heuristic, and an off-by-one trip near a window edge is fine.
    window_trips: AtomicU32,
    /// Operator/test override: shed all new admissions regardless of
    /// the trip counter (drain mode).
    forced: AtomicBool,
}

impl AdmissionController {
    pub fn new(quota: QuotaConfig) -> Self {
        AdmissionController {
            quota,
            anchor: Instant::now(),
            window_start_ms: AtomicU64::new(0),
            window_trips: AtomicU32::new(0),
            forced: AtomicBool::new(false),
        }
    }

    /// The policy this controller enforces.
    pub fn quota(&self) -> &QuotaConfig {
        &self.quota
    }

    fn now_ms(&self) -> u64 {
        self.anchor.elapsed().as_millis() as u64
    }

    /// Record a round-deadline trip toward the overload watermark.
    /// Called from the leader's deadline-supervision path (already an
    /// error path — never the steady-state round).
    pub fn note_deadline_trip(&self) {
        let now = self.now_ms();
        let start = self.window_start_ms.load(Ordering::Relaxed);
        if now.saturating_sub(start) > self.quota.shed_window.as_millis() as u64 {
            self.window_start_ms.store(now, Ordering::Relaxed);
            self.window_trips.store(1, Ordering::Relaxed);
        } else {
            self.window_trips.fetch_add(1, Ordering::Relaxed);
        }
    }

    /// Is the leader past the overload watermark right now?
    pub fn overloaded(&self) -> bool {
        if self.forced.load(Ordering::Relaxed) {
            return true;
        }
        let start = self.window_start_ms.load(Ordering::Relaxed);
        if self.now_ms().saturating_sub(start) > self.quota.shed_window.as_millis() as u64 {
            return false; // the window went quiet; pressure cleared
        }
        self.window_trips.load(Ordering::Relaxed) >= self.quota.shed_trip_threshold
    }

    /// Force (or release) shedding regardless of the trip counter —
    /// drain mode for operators, determinism for tests.
    pub fn force_shed(&self, on: bool) {
        self.forced.store(on, Ordering::Relaxed);
    }

    fn refuse(&self, reason: RefuseReason) -> Refusal {
        Refusal { reason, retry_after: self.quota.retry_after }
    }

    /// Evaluate a `Hello` that would **create** a job (`n_workers`
    /// seats, `model_elems` parameters) against the quota and current
    /// usage. Re-`Hello`s of hosted jobs must not be routed here — they
    /// are admitted unconditionally (see the module contract).
    pub fn check_new_job(
        &self,
        n_workers: u32,
        model_elems: u64,
        usage: LeaderUsage,
    ) -> Result<(), Refusal> {
        if self.overloaded() {
            return Err(self.refuse(RefuseReason::Overloaded));
        }
        if usage.jobs >= self.quota.max_jobs {
            return Err(self.refuse(RefuseReason::JobCap));
        }
        if n_workers > self.quota.max_workers_per_job {
            return Err(self.refuse(RefuseReason::WorkerSlots));
        }
        if model_elems > self.quota.max_model_elems_per_job {
            return Err(self.refuse(RefuseReason::ModelQuota));
        }
        if usage.model_elems.saturating_add(model_elems) > self.quota.max_total_model_elems {
            return Err(self.refuse(RefuseReason::TotalModelQuota));
        }
        if usage.workers.saturating_add(u64::from(n_workers)) > self.quota.max_total_workers {
            return Err(self.refuse(RefuseReason::TotalWorkerQuota));
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn quota() -> QuotaConfig {
        QuotaConfig {
            max_jobs: 2,
            max_workers_per_job: 4,
            max_model_elems_per_job: 1000,
            max_total_model_elems: 1500,
            max_total_workers: 6,
            ..QuotaConfig::default()
        }
    }

    #[test]
    fn reason_codes_are_stable_and_roundtrip() {
        for r in [
            RefuseReason::Overloaded,
            RefuseReason::JobCap,
            RefuseReason::WorkerSlots,
            RefuseReason::ModelQuota,
            RefuseReason::TotalModelQuota,
            RefuseReason::TotalWorkerQuota,
        ] {
            assert_eq!(RefuseReason::from_u16(r as u16), Some(r));
        }
        assert_eq!(RefuseReason::from_u16(0), None);
        assert_eq!(RefuseReason::from_u16(999), None);
        // Shipped wire values — never reassign.
        assert_eq!(RefuseReason::Overloaded as u16, 1);
        assert_eq!(RefuseReason::JobCap as u16, 2);
        assert_eq!(RefuseReason::WorkerSlots as u16, 3);
        assert_eq!(RefuseReason::ModelQuota as u16, 4);
        assert_eq!(RefuseReason::TotalModelQuota as u16, 5);
        assert_eq!(RefuseReason::TotalWorkerQuota as u16, 6);
    }

    #[test]
    fn quota_checks_refuse_with_the_right_reason() {
        let c = AdmissionController::new(quota());
        let ok = LeaderUsage::default();
        assert_eq!(c.check_new_job(2, 500, ok), Ok(()));
        // Job cap.
        let full = LeaderUsage { jobs: 2, ..ok };
        assert_eq!(c.check_new_job(1, 1, full).unwrap_err().reason, RefuseReason::JobCap);
        // Per-job caps.
        assert_eq!(c.check_new_job(5, 1, ok).unwrap_err().reason, RefuseReason::WorkerSlots);
        assert_eq!(c.check_new_job(1, 1001, ok).unwrap_err().reason, RefuseReason::ModelQuota);
        // Leader-wide totals.
        let heavy = LeaderUsage { jobs: 1, model_elems: 900, workers: 0 };
        assert_eq!(
            c.check_new_job(1, 800, heavy).unwrap_err().reason,
            RefuseReason::TotalModelQuota
        );
        let seated = LeaderUsage { jobs: 1, model_elems: 0, workers: 5 };
        assert_eq!(
            c.check_new_job(2, 1, seated).unwrap_err().reason,
            RefuseReason::TotalWorkerQuota
        );
        // Every refusal carries the configured retry hint.
        let r = c.check_new_job(1, 1, full).unwrap_err();
        assert_eq!(r.retry_after, c.quota().retry_after);
    }

    #[test]
    fn overload_watermark_trips_and_clears() {
        let q = QuotaConfig {
            shed_trip_threshold: 3,
            shed_window: Duration::from_secs(60),
            ..quota()
        };
        let c = AdmissionController::new(q);
        assert!(!c.overloaded());
        c.note_deadline_trip();
        c.note_deadline_trip();
        assert!(!c.overloaded(), "below threshold");
        c.note_deadline_trip();
        assert!(c.overloaded(), "threshold reached inside the window");
        let r = c.check_new_job(1, 1, LeaderUsage::default()).unwrap_err();
        assert_eq!(r.reason, RefuseReason::Overloaded);

        // A short window clears on its own once trips stop.
        let q = QuotaConfig {
            shed_trip_threshold: 1,
            shed_window: Duration::from_millis(1),
            ..quota()
        };
        let c = AdmissionController::new(q);
        c.note_deadline_trip();
        std::thread::sleep(Duration::from_millis(10));
        assert!(!c.overloaded(), "quiet window clears the watermark");
    }

    #[test]
    fn forced_shed_overrides_and_releases() {
        let c = AdmissionController::new(quota());
        c.force_shed(true);
        assert!(c.overloaded());
        let r = c.check_new_job(1, 1, LeaderUsage::default()).unwrap_err();
        assert_eq!(r.reason, RefuseReason::Overloaded);
        c.force_shed(false);
        assert!(!c.overloaded());
        assert_eq!(c.check_new_job(1, 1, LeaderUsage::default()), Ok(()));
    }

    #[test]
    fn refusal_downcasts_through_anyhow() {
        let c = AdmissionController::new(quota());
        let r = c.check_new_job(99, 1, LeaderUsage::default()).unwrap_err();
        let e: anyhow::Error = r.into();
        let back = e.downcast_ref::<Refusal>().expect("typed refusal survives anyhow");
        assert_eq!(back.reason, RefuseReason::WorkerSlots);
        assert!(e.to_string().contains("worker_slots"));
    }
}
