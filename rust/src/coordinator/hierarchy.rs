//! Hierarchical cross-rack reduction (paper section 3.4, Figure 19).
//!
//! One PBox per rack aggregates its rack's gradients; PBoxes then reduce
//! across racks (ring all-reduce over the oversubscribed core); each PBox
//! runs the optimizer and broadcasts rack-locally. Cross-rack traffic
//! drops to 1/N of flat sharding (N workers per rack) at the price of an
//! extra reduction round.
//!
//! Includes the paper's benefit model: hierarchical reduction pays off when
//!
//! ```text
//! max((N-1)/B_bn, 1/(N*B_wkr)) > max(1/B_PBox, N/B_wkr) + C
//! ```
//!
//! with `B_bn = min((r-1)*B_PBox, B_core)` and `C` the inter-rack step.

use crate::collectives::ring_allreduce_inplace;
use crate::dnn::Dnn;

/// Bandwidths for the benefit model, all in bytes/s *per model exchange
/// unit* (the formula is unit-free as long as all terms share units).
#[derive(Debug, Clone, Copy)]
pub struct HierBandwidths {
    /// Aggregate PBox bandwidth.
    pub b_pbox: f64,
    /// Network-core (cross-rack) bandwidth available to the job.
    pub b_core: f64,
    /// Per-worker bandwidth.
    pub b_wkr: f64,
}

/// The bottleneck bandwidth `B_bn` for `r` racks.
pub fn b_bn(bw: HierBandwidths, racks: usize) -> f64 {
    if racks <= 1 {
        return bw.b_core;
    }
    ((racks as f64 - 1.0) * bw.b_pbox).min(bw.b_core)
}

/// Inter-rack step cost `C` using a ring collective over `r` racks.
pub fn ring_step_cost(bw: HierBandwidths, racks: usize) -> f64 {
    if racks <= 1 {
        return 0.0;
    }
    (racks as f64 - 1.0) / (racks as f64 * b_bn(bw, racks))
}

/// Paper's benefit condition: is two-level (hierarchical) reduction faster
/// than flat cross-rack sharded exchange for `n` workers/rack, `r` racks?
///
/// The published inequality's worker terms are ambiguous as printed; we use
/// the physically consistent reading (time per unit of model exchanged):
/// flat exchange costs `max((N-1)/B_bn, 1/B_wkr)` — N racks' worth of
/// gradients cross the bottleneck while each worker sends at its own line
/// rate — and hierarchical costs a rack-local phase
/// `max(N/B_PBox, 1/B_wkr)` plus the inter-rack step `C`.
pub fn hierarchical_beneficial(bw: HierBandwidths, n: usize, racks: usize) -> bool {
    if racks <= 1 {
        return false;
    }
    let n = n as f64;
    let bbn = b_bn(bw, racks);
    let flat = ((n - 1.0) / bbn).max(1.0 / bw.b_wkr);
    let hier = (n / bw.b_pbox).max(1.0 / bw.b_wkr) + ring_step_cost(bw, racks);
    flat > hier
}

/// Raw time (seconds) of the cross-rack ring phase, following the paper's
/// Figure 19 emulation: after local aggregation, chunks make ring hops —
/// `2(r-1)/r` of the model volume over the inter-rack bottleneck, plus
/// `2(r-1)` rounds of per-message latency.
pub fn cross_rack_time(
    dnn: &Dnn,
    racks: usize,
    core_gbps: f64,
    per_msg_latency: f64,
) -> f64 {
    if racks <= 1 {
        return 0.0;
    }
    let r = racks as f64;
    let bw = core_gbps * 1e9 / 8.0;
    let model = dnn.model_bytes as f64;
    2.0 * (r - 1.0) / r * model / bw + 2.0 * (r - 1.0) * per_msg_latency
}

/// *Exposed* per-iteration overhead of hierarchical reduction: chunks
/// stream into the ring as local aggregation finishes, so the cross-rack
/// phase overlaps with the backward pass; only the portion exceeding the
/// overlap budget (the compute time) is exposed.
pub fn hierarchical_overhead(
    dnn: &Dnn,
    racks: usize,
    chunk_bytes: usize,
    core_gbps: f64,
    per_msg_latency: f64,
) -> f64 {
    let _ = chunk_bytes;
    let raw = cross_rack_time(dnn, racks, core_gbps, per_msg_latency);
    (raw - dnn.time_per_batch).max(0.0)
}

/// Per-job throughput (samples/s) with hierarchical reduction, given the
/// rack-local iteration time (from sim or measurement).
pub fn throughput_with_hierarchy(
    dnn: &Dnn,
    racks: usize,
    workers_per_rack: usize,
    rack_iter_time: f64,
    chunk_bytes: usize,
    core_gbps: f64,
    per_msg_latency: f64,
) -> f64 {
    let overhead = hierarchical_overhead(dnn, racks, chunk_bytes, core_gbps, per_msg_latency);
    let iter = rack_iter_time + overhead;
    (racks * workers_per_rack) as f64 * dnn.batch as f64 / iter
}

// ---------------------------------------------------------------------------
// Real two-level reduction (executable, used by tests and rack_sim example)
// ---------------------------------------------------------------------------

/// Perform a *real* two-level reduction over per-worker gradients grouped
/// by rack: rack-local mean, cross-rack ring all-reduce of rack sums, and
/// a global mean. Returns the global mean gradient.
///
/// `grads[rack][worker]` are equal-length vectors.
pub fn two_level_reduce(grads: &[Vec<Vec<f32>>]) -> Vec<f32> {
    assert!(!grads.is_empty());
    let len = grads[0][0].len();
    let total_workers: usize = grads.iter().map(|r| r.len()).sum();
    // Stage 1: per-rack local sums (each rack's PBox).
    let mut rack_sums: Vec<Vec<f32>> = grads
        .iter()
        .map(|rack| {
            let mut acc = vec![0.0f32; len];
            for g in rack {
                assert_eq!(g.len(), len);
                for (a, x) in acc.iter_mut().zip(g) {
                    *a += x;
                }
            }
            acc
        })
        .collect();
    // Stage 2: cross-rack ring all-reduce of the rack sums.
    ring_allreduce_inplace(&mut rack_sums);
    // Stage 3: every PBox now holds the global sum; divide once.
    let mut out = rack_sums.swap_remove(0);
    for x in out.iter_mut() {
        *x /= total_workers as f32;
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn bw() -> HierBandwidths {
        // 10 Gbps workers, PBox aggregate 100 Gbps, constrained core.
        HierBandwidths {
            b_pbox: 12.5e9,
            b_core: 2.5e9,
            b_wkr: 1.25e9,
        }
    }

    #[test]
    fn single_rack_never_hierarchical() {
        assert!(!hierarchical_beneficial(bw(), 8, 1));
        assert_eq!(hierarchical_overhead(
            &Dnn::by_abbrev("AN").unwrap(), 1, 32 << 10, 10.0, 1e-5), 0.0);
    }

    #[test]
    fn oversubscribed_core_favors_hierarchy() {
        // Many workers behind a thin core: flat sharded exchange is
        // bottlenecked; hierarchy should win.
        assert!(hierarchical_beneficial(bw(), 16, 4));
    }

    #[test]
    fn fat_core_disfavors_hierarchy() {
        let fat = HierBandwidths {
            b_core: 1e12,
            ..bw()
        };
        // With an effectively infinite core and few workers, the extra
        // round is pure loss.
        assert!(!hierarchical_beneficial(fat, 2, 2));
    }

    #[test]
    fn overhead_grows_with_racks() {
        let d = Dnn::by_abbrev("AN").unwrap();
        let mut prev = 0.0;
        for r in 1..=8 {
            let o = hierarchical_overhead(&d, r, 32 << 10, 10.0, 1e-5);
            assert!(o >= prev, "r={r}: {o} < {prev}");
            prev = o;
        }
    }

    #[test]
    fn alexnet_pays_resnet_does_not() {
        // Figure 19's shape: AlexNet (huge model, fast compute) loses
        // visible throughput; ResNet 50 (small model, slow compute) barely
        // moves.
        let an = Dnn::by_abbrev("AN").unwrap();
        let rn = Dnn::by_abbrev("RN50").unwrap();
        // Rack-local iteration times on a 10G cloud-like setup (roughly:
        // AlexNet exchange-bound ~0.35s, ResNet compute-bound ~0.17s).
        let an_tp1 = throughput_with_hierarchy(&an, 1, 8, 0.35, 32 << 10, 10.0, 1e-5);
        let an_tp8 = throughput_with_hierarchy(&an, 8, 8, 0.35, 32 << 10, 10.0, 1e-5) / 8.0;
        let rn_tp1 = throughput_with_hierarchy(&rn, 1, 8, 0.17, 32 << 10, 10.0, 1e-5);
        let rn_tp8 = throughput_with_hierarchy(&rn, 8, 8, 0.17, 32 << 10, 10.0, 1e-5) / 8.0;
        let an_loss = 1.0 - an_tp8 / an_tp1;
        let rn_loss = 1.0 - rn_tp8 / rn_tp1;
        assert!(an_loss > rn_loss, "AN loss {an_loss} vs RN {rn_loss}");
        assert!(rn_loss < 0.25, "{rn_loss}");
    }

    #[test]
    fn two_level_reduce_equals_flat_mean() {
        // 3 racks x 2 workers, len 17.
        let grads: Vec<Vec<Vec<f32>>> = (0..3)
            .map(|r| {
                (0..2)
                    .map(|w| (0..17).map(|i| (r * 31 + w * 7 + i) as f32 * 0.1).collect())
                    .collect()
            })
            .collect();
        let hier = two_level_reduce(&grads);
        // Flat reference mean.
        let mut flat = vec![0.0f32; 17];
        let mut count = 0;
        for rack in &grads {
            for g in rack {
                for (a, x) in flat.iter_mut().zip(g) {
                    *a += x;
                }
                count += 1;
            }
        }
        for x in flat.iter_mut() {
            *x /= count as f32;
        }
        for (h, f) in hier.iter().zip(&flat) {
            assert!((h - f).abs() < 1e-5, "{h} vs {f}");
        }
    }
}
