//! Minimal JSON parser for the AOT manifest.
//!
//! The offline environment has no serde; the manifest emitted by
//! `python/compile/aot.py` is plain JSON, so this module implements the
//! small subset needed to read it (objects, arrays, strings, numbers,
//! booleans, null — no escapes beyond `\" \\ \/ \n \t \r \u`).

use std::collections::BTreeMap;

/// A parsed JSON value.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|n| n as usize)
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }
}

/// Parse a JSON document.
pub fn parse(text: &str) -> Result<Json, String> {
    let b = text.as_bytes();
    let mut p = Parser { b, i: 0 };
    p.skip_ws();
    let v = p.value()?;
    p.skip_ws();
    if p.i != b.len() {
        return Err(format!("trailing garbage at byte {}", p.i));
    }
    Ok(v)
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn skip_ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn expect(&mut self, c: u8) -> Result<(), String> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(format!("expected '{}' at byte {}", c as char, self.i))
        }
    }

    fn value(&mut self) -> Result<Json, String> {
        self.skip_ws();
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(format!("unexpected byte at {}", self.i)),
        }
    }

    fn lit(&mut self, s: &str, v: Json) -> Result<Json, String> {
        if self.b[self.i..].starts_with(s.as_bytes()) {
            self.i += s.len();
            Ok(v)
        } else {
            Err(format!("bad literal at byte {}", self.i))
        }
    }

    fn number(&mut self) -> Result<Json, String> {
        let start = self.i;
        while let Some(c) = self.peek() {
            if c.is_ascii_digit() || matches!(c, b'-' | b'+' | b'.' | b'e' | b'E') {
                self.i += 1;
            } else {
                break;
            }
        }
        std::str::from_utf8(&self.b[start..self.i])
            .ok()
            .and_then(|s| s.parse().ok())
            .map(Json::Num)
            .ok_or_else(|| format!("bad number at byte {start}"))
    }

    fn string(&mut self) -> Result<String, String> {
        self.expect(b'"')?;
        let mut out = String::new();
        loop {
            match self.peek() {
                None => return Err("unterminated string".into()),
                Some(b'"') => {
                    self.i += 1;
                    return Ok(out);
                }
                Some(b'\\') => {
                    self.i += 1;
                    let c = self.peek().ok_or("bad escape")?;
                    self.i += 1;
                    match c {
                        b'"' => out.push('"'),
                        b'\\' => out.push('\\'),
                        b'/' => out.push('/'),
                        b'n' => out.push('\n'),
                        b't' => out.push('\t'),
                        b'r' => out.push('\r'),
                        b'b' => out.push('\u{8}'),
                        b'f' => out.push('\u{c}'),
                        b'u' => {
                            let hex = self
                                .b
                                .get(self.i..self.i + 4)
                                .ok_or("bad \\u escape")?;
                            let cp = u32::from_str_radix(
                                std::str::from_utf8(hex).map_err(|_| "bad \\u")?,
                                16,
                            )
                            .map_err(|_| "bad \\u")?;
                            self.i += 4;
                            out.push(char::from_u32(cp).unwrap_or('\u{fffd}'));
                        }
                        _ => return Err(format!("bad escape at byte {}", self.i)),
                    }
                }
                Some(c) => {
                    // Consume one UTF-8 scalar.
                    let len = match c {
                        0x00..=0x7F => 1,
                        0xC0..=0xDF => 2,
                        0xE0..=0xEF => 3,
                        _ => 4,
                    };
                    let s = self
                        .b
                        .get(self.i..self.i + len)
                        .and_then(|s| std::str::from_utf8(s).ok())
                        .ok_or("bad utf8")?;
                    out.push_str(s);
                    self.i += len;
                }
            }
        }
    }

    fn array(&mut self) -> Result<Json, String> {
        self.expect(b'[')?;
        let mut out = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(out));
        }
        loop {
            out.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => {
                    self.i += 1;
                }
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(out));
                }
                _ => return Err(format!("expected , or ] at byte {}", self.i)),
            }
        }
    }

    fn object(&mut self) -> Result<Json, String> {
        self.expect(b'{')?;
        let mut out = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(out));
        }
        loop {
            self.skip_ws();
            let k = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let v = self.value()?;
            out.insert(k, v);
            self.skip_ws();
            match self.peek() {
                Some(b',') => {
                    self.i += 1;
                }
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(out));
                }
                _ => return Err(format!("expected , or }} at byte {}", self.i)),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_manifest_like_document() {
        let doc = r#"{
  "config": {"vocab": 256, "d_model": 128},
  "param_count": 828544,
  "keys": [
    {"name": "blk0/ln1", "offset": 0, "len": 128, "shape": [128]},
    {"name": "embed", "offset": 128, "len": 32768, "shape": [256, 128]}
  ],
  "ok": true, "nothing": null, "ratio": -1.5e-3
}"#;
        let j = parse(doc).unwrap();
        assert_eq!(j.get("param_count").unwrap().as_usize(), Some(828544));
        let keys = j.get("keys").unwrap().as_arr().unwrap();
        assert_eq!(keys.len(), 2);
        assert_eq!(keys[1].get("name").unwrap().as_str(), Some("embed"));
        assert_eq!(keys[1].get("len").unwrap().as_usize(), Some(32768));
        assert_eq!(j.get("ok"), Some(&Json::Bool(true)));
        assert_eq!(j.get("nothing"), Some(&Json::Null));
        assert!((j.get("ratio").unwrap().as_f64().unwrap() + 0.0015).abs() < 1e-12);
    }

    #[test]
    fn strings_with_escapes() {
        let j = parse(r#""a\"b\\c\ndA""#).unwrap();
        assert_eq!(j.as_str(), Some("a\"b\\c\ndA"));
    }

    #[test]
    fn rejects_garbage() {
        assert!(parse("{").is_err());
        assert!(parse("[1,]").is_err());
        assert!(parse("123abc").is_err());
        assert!(parse("{\"a\" 1}").is_err());
    }

    #[test]
    fn empty_containers() {
        assert_eq!(parse("[]").unwrap(), Json::Arr(vec![]));
        assert_eq!(parse("{}").unwrap(), Json::Obj(Default::default()));
    }

    #[test]
    fn nested_arrays() {
        let j = parse("[[1,2],[3]]").unwrap();
        let a = j.as_arr().unwrap();
        assert_eq!(a[0].as_arr().unwrap().len(), 2);
        assert_eq!(a[1].as_arr().unwrap()[0].as_f64(), Some(3.0));
    }
}
