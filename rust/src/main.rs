//! `phub` — the leader binary: run simulations, print the paper's
//! analytical tables, or drive a live in-process training job.
//!
//! ```text
//! phub sim --dnn RN50 --ps pbox --stack phub --net 56g --workers 8 [--gpu 1080ti]
//! phub breakdown --dnn RN50 --stack mxnet-tcp
//! phub bandwidth                 # Table 2
//! phub cost                      # Table 5
//! phub zoo                       # Table 3 model zoo
//! phub train --steps 50 --workers 4   # live PJRT + PHub training
//! ```

use anyhow::{bail, Result};
use phub::cli::Args;
use phub::compute::Gpu;
use phub::config::{ClusterConfig, ExchangeConfig, NetConfig, PsConfig, Stack};
use phub::costmodel::{self, CostModel, Deployment};
use phub::dnn::Dnn;
use phub::sim;

fn parse_gpu(s: &str) -> Result<Gpu> {
    Ok(match s {
        "grid520" => Gpu::Grid520,
        "k80" => Gpu::K80,
        "m60" => Gpu::M60,
        "1080ti" => Gpu::Gtx1080Ti,
        "v100" => Gpu::V100,
        "zero" => Gpu::ZeroCompute,
        _ => bail!("unknown gpu {s:?} (grid520|k80|m60|1080ti|v100|zero)"),
    })
}

fn parse_ps(s: &str) -> Result<PsConfig> {
    Ok(match s {
        "cc" => PsConfig::ColocatedCentralized,
        "cs" => PsConfig::ColocatedSharded,
        "ncc" => PsConfig::NonColocatedCentralized,
        "ncs" => PsConfig::NonColocatedSharded,
        "pbox" => PsConfig::PBox,
        _ => bail!("unknown ps config {s:?} (cc|cs|ncc|ncs|pbox)"),
    })
}

fn parse_stack(s: &str) -> Result<Stack> {
    Ok(match s {
        "mxnet-tcp" | "mxnet" => Stack::MxnetTcp,
        "mxnet-ib" => Stack::MxnetIb,
        "phub" => Stack::PHub,
        _ => bail!("unknown stack {s:?} (mxnet-tcp|mxnet-ib|phub)"),
    })
}

fn parse_net(s: &str) -> Result<NetConfig> {
    Ok(match s {
        "10g" => NetConfig::cloud_10g(),
        "56g" => NetConfig::infiniband_56g(),
        _ => bail!("unknown net {s:?} (10g|56g)"),
    })
}

fn cluster_from_args(a: &Args) -> Result<ClusterConfig> {
    let stack = parse_stack(a.get_or("stack", "phub"))?;
    let mut c = ClusterConfig::paper_testbed()
        .with_ps(parse_ps(a.get_or("ps", "pbox"))?)
        .with_stack(stack)
        .with_net(parse_net(a.get_or("net", "56g"))?)
        .with_workers(a.get_usize("workers", 8));
    if stack != Stack::PHub {
        c = c.with_exchange(ExchangeConfig::mxnet());
    }
    if let Some(chunk) = a.get("chunk-kb") {
        c.exchange.chunk_bytes = chunk.parse::<usize>()? * 1024;
    }
    Ok(c)
}

fn cmd_sim(a: &Args) -> Result<()> {
    let dnn = Dnn::by_abbrev(a.get_or("dnn", "RN50"))
        .ok_or_else(|| anyhow::anyhow!("unknown dnn (see `phub zoo`)"))?;
    let gpu = parse_gpu(a.get_or("gpu", "1080ti"))?;
    let c = cluster_from_args(a)?;
    let r = sim::simulate(&c, &dnn, gpu);
    println!(
        "{} on {} [{} {} {}Gbps x{}]",
        dnn.name,
        gpu.label(),
        c.stack.label(),
        c.ps.label(),
        c.net.link_gbps,
        c.n_workers
    );
    println!("  iter time      : {:.3} ms", r.iter_time * 1e3);
    println!("  throughput     : {:.1} samples/s", r.throughput);
    println!("  compute        : {:.3} ms", r.compute_time * 1e3);
    println!("  exposed overhead: {:.3} ms ({:.0}%)",
        r.exposed_overhead * 1e3, 100.0 * r.exposed_overhead / r.iter_time);
    println!("  exchange rate  : {:.2} /s", r.exchange_rate);
    Ok(())
}

fn cmd_breakdown(a: &Args) -> Result<()> {
    let dnn = Dnn::by_abbrev(a.get_or("dnn", "RN50"))
        .ok_or_else(|| anyhow::anyhow!("unknown dnn"))?;
    let gpu = parse_gpu(a.get_or("gpu", "1080ti"))?;
    let c = cluster_from_args(a)?;
    let b = sim::breakdown::progressive(&c, &dnn, gpu);
    println!("progressive overhead breakdown — {} ({})", dnn.name, c.stack.label());
    println!("  compute        : {:7.2} ms", b.compute * 1e3);
    println!("  data copy+comm : {:7.2} ms", b.data_copy_comm * 1e3);
    println!("  aggregation    : {:7.2} ms", b.aggregation * 1e3);
    println!("  optimization   : {:7.2} ms", b.optimization * 1e3);
    println!("  sync + other   : {:7.2} ms", b.sync_other * 1e3);
    println!("  total          : {:7.2} ms ({:.0}% overhead)",
        b.total() * 1e3, b.overhead_share() * 100.0);
    Ok(())
}

fn cmd_bandwidth() {
    println!("Table 2: minimum bisection bandwidth (Gbps) to hide communication, 8 workers");
    println!("{:<14} {:>8} {:>8} {:>8} {:>8}", "network", "CC", "CS", "NCC", "NCS");
    for d in Dnn::zoo() {
        let row = costmodel::table2_row(&d, 8);
        println!(
            "{:<14} {:>8.0} {:>8.0} {:>8.0} {:>8.0}",
            d.abbrev, row[0], row[1], row[2], row[3]
        );
    }
}

fn cmd_cost(a: &Args) {
    // Per-worker throughput inputs: derived from simulation of ResNet-50
    // with a V100-class GPU (the "future GPU" of section 4.9).
    let dnn = Dnn::by_abbrev("RN50").unwrap();
    let gpu = parse_gpu(a.get_or("gpu", "v100")).unwrap();
    // Baseline: 100GbE sharded (sim: 40G IB downclock stands in, CS/IB).
    let base = ClusterConfig::paper_testbed()
        .with_ps(PsConfig::ColocatedSharded)
        .with_stack(Stack::MxnetIb)
        .with_net(NetConfig {
            link_gbps: 40.0,
            ..NetConfig::infiniband_56g()
        })
        .with_exchange(ExchangeConfig::mxnet());
    // PHub: 25GbE via 10G IB results per the paper; +2% cross-rack.
    let phub = ClusterConfig::paper_testbed().with_net(NetConfig::cloud_10g());
    let tp_base = sim::simulate(&base, &dnn, gpu).throughput / 8.0;
    let tp_phub = sim::simulate(&phub, &dnn, gpu).throughput / 8.0 * 0.98;

    let m = CostModel::paper();
    println!("Table 5: throughput per $1000 (ResNet-50, {} workers-class GPUs)", gpu.label());
    let rows = [
        (Deployment::baseline_100g(), tp_base),
        (Deployment::phub_25g(1.0), tp_phub),
        (Deployment::phub_25g(2.0), tp_phub),
        (Deployment::phub_25g(3.0), tp_phub),
    ];
    let baseline_val = m.throughput_per_kilodollar(&rows[0].0, rows[0].1);
    for (d, tp) in rows {
        let v = m.throughput_per_kilodollar(&d, tp);
        println!(
            "  {:<22} {:>7.2}  ({:+.0}%)",
            d.name,
            v,
            (v / baseline_val - 1.0) * 100.0
        );
    }
}

fn cmd_zoo() {
    println!("{:<14} {:>6} {:>10} {:>10} {:>6} {:>7}",
        "network", "abbr", "size (MB)", "t/batch ms", "batch", "keys");
    for d in Dnn::zoo() {
        println!(
            "{:<14} {:>6} {:>10} {:>10.0} {:>6} {:>7}",
            d.name,
            d.abbrev,
            d.model_bytes / (1024 * 1024),
            d.time_per_batch * 1e3,
            d.batch,
            d.layers.len()
        );
    }
}

fn cmd_train(a: &Args) -> Result<()> {
    phub::e2e::train_cli(a)
}

fn main() -> Result<()> {
    let a = Args::from_env();
    match a.subcommand.as_deref() {
        Some("sim") => cmd_sim(&a)?,
        Some("breakdown") => cmd_breakdown(&a)?,
        Some("bandwidth") => cmd_bandwidth(),
        Some("cost") => cmd_cost(&a),
        Some("zoo") => cmd_zoo(),
        Some("train") => cmd_train(&a)?,
        other => {
            if let Some(o) = other {
                eprintln!("unknown subcommand {o:?}\n");
            }
            eprintln!(
                "usage: phub <sim|breakdown|bandwidth|cost|zoo|train> [flags]\n\
                 see rust/src/main.rs header for examples"
            );
            std::process::exit(2);
        }
    }
    Ok(())
}
