//! PS-host memory-system model: DRAM bandwidth and the PCIe-to-memory
//! bridge ceiling.
//!
//! Substitute for the paper's measured PBox memory behaviour (DESIGN.md
//! section 2). Two results depend on it:
//!
//! * **Table 4** — bidirectional memory bandwidth while training VGG with
//!   8 workers: communication alone moves ~2 model-passes of DRAM traffic
//!   per exchange (NIC DMA in + out); *cached* aggregation/optimization
//!   adds only ~8% (buffers stay in LLC), while *cache-bypassing*
//!   (non-temporal) aggregation adds ~3.9 model-passes, saturating DRAM
//!   and halving throughput.
//! * **Figure 17** — the PCIe-to-memory bridge, not NIC or DRAM bandwidth,
//!   caps PBox at ~90 GB/s; PHub reaches ~97% of that microbenchmark.

/// DRAM traffic profile of one full model exchange (gradients in, model
/// out), in units of model-size passes over memory.
#[derive(Debug, Clone, Copy)]
pub struct ExchangeMemProfile {
    /// NIC DMA traffic: receive-write + send-read = 2 passes.
    pub comm_passes: f64,
    /// Additional aggregation+optimization DRAM passes.
    pub agg_opt_passes: f64,
}

impl ExchangeMemProfile {
    /// Communication only — aggregation/optimization disabled.
    pub fn off() -> Self {
        ExchangeMemProfile {
            comm_passes: 2.0,
            agg_opt_passes: 0.0,
        }
    }

    /// Cached (temporal) aggregator/optimizer: buffers live in LLC; only
    /// compulsory misses touch DRAM (~8% of comm traffic, section 4.5).
    pub fn cached() -> Self {
        ExchangeMemProfile {
            comm_passes: 2.0,
            agg_opt_passes: 0.16,
        }
    }

    /// Cache-bypassing (non-temporal) aggregator/optimizer: every
    /// aggregation read/write and the optimizer model pass hit DRAM.
    pub fn bypass() -> Self {
        ExchangeMemProfile {
            comm_passes: 2.0,
            agg_opt_passes: 3.9,
        }
    }

    pub fn total_passes(&self) -> f64 {
        self.comm_passes + self.agg_opt_passes
    }
}

/// Memory-side exchange throughput bound (exchanges/s) for a model of
/// `model_bytes`, given sustainable DRAM bandwidth.
pub fn dram_exchange_bound(profile: ExchangeMemProfile, model_bytes: f64, dram_bw: f64) -> f64 {
    dram_bw / (profile.total_passes() * model_bytes)
}

/// Achieved exchange rate = min(network-side bound, DRAM-side bound).
pub fn exchange_rate(
    profile: ExchangeMemProfile,
    model_bytes: f64,
    net_bound: f64,
    dram_bw: f64,
) -> f64 {
    net_bound.min(dram_exchange_bound(profile, model_bytes, dram_bw))
}

/// DRAM bandwidth consumed at a given exchange rate.
pub fn mem_bw_used(profile: ExchangeMemProfile, model_bytes: f64, rate: f64) -> f64 {
    rate * model_bytes * profile.total_passes()
}

/// The PCIe-to-memory-system bridge (Figure 17).
#[derive(Debug, Clone, Copy)]
pub struct PcieBridge {
    /// Aggregate NIC-side line rate if nothing else limited (bytes/s,
    /// bidirectional). The PBox: 10 x 56 Gbps = 140 GB/s.
    pub nic_line_rate: f64,
    /// Measured bridge ceiling (bytes/s, bidirectional): ~90 GB/s.
    pub bridge_cap: f64,
    /// Fraction of the bridge microbenchmark PHub sustains (0.97).
    pub software_efficiency: f64,
}

impl PcieBridge {
    pub fn pbox() -> Self {
        PcieBridge {
            nic_line_rate: 140e9,
            bridge_cap: 90e9,
            software_efficiency: 0.97,
        }
    }

    /// "InfiniBand/PCIe limit" line: ideal aggregate bandwidth for `w`
    /// emulated workers, each contributing `per_worker` bytes/s
    /// bidirectional, with no bridge limit.
    pub fn ideal_rate(&self, workers: usize, per_worker: f64) -> f64 {
        (workers as f64 * per_worker).min(self.nic_line_rate)
    }

    /// Loopback-microbenchmark rate: ideal, clipped by the bridge.
    pub fn microbench_rate(&self, workers: usize, per_worker: f64) -> f64 {
        self.ideal_rate(workers, per_worker).min(self.bridge_cap)
    }

    /// PHub end-to-end rate: the microbenchmark ceiling times software
    /// efficiency (scheduling overhead + stragglers, section 4.7).
    pub fn phub_rate(&self, workers: usize, per_worker: f64) -> f64 {
        self.microbench_rate(workers, per_worker) * self.software_efficiency
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const VGG_BYTES: f64 = 505.0 * 1024.0 * 1024.0;
    const DRAM: f64 = 120e9;

    /// Reproduce Table 4's three rows from the model and the paper's
    /// measured network-side bound (72.08 exchanges/s for VGG, 8 workers).
    #[test]
    fn table4_rows() {
        let net = 72.08;
        // Off: network-bound, ~76 GB/s of memory traffic (paper: 77.5).
        let off = exchange_rate(ExchangeMemProfile::off(), VGG_BYTES, net, DRAM);
        assert!((off - 72.08).abs() < 0.01);
        let bw_off = mem_bw_used(ExchangeMemProfile::off(), VGG_BYTES, off) / 1e9;
        assert!((bw_off - 77.5).abs() < 4.0, "{bw_off}");

        // Cached: still network-bound, ~8% more traffic (paper: 83.5).
        let cached = exchange_rate(ExchangeMemProfile::cached(), VGG_BYTES, net, DRAM);
        assert!(cached > 0.99 * net);
        let bw_cached = mem_bw_used(ExchangeMemProfile::cached(), VGG_BYTES, cached) / 1e9;
        assert!((bw_cached - 83.5) / 83.5 < 0.05, "{bw_cached}");

        // Bypass: DRAM-bound, throughput collapses to ~40 (paper: 40.48)
        // while memory bandwidth pegs at the machine limit (paper: 119.7).
        let bypass = exchange_rate(ExchangeMemProfile::bypass(), VGG_BYTES, net, DRAM);
        assert!((bypass - 40.48).abs() / 40.48 < 0.06, "{bypass}");
        let bw_bypass = mem_bw_used(ExchangeMemProfile::bypass(), VGG_BYTES, bypass) / 1e9;
        assert!((bw_bypass - 120.0).abs() < 1.0, "{bw_bypass}");
    }

    #[test]
    fn cached_beats_bypass() {
        for model_mb in [38.0, 97.0, 194.0, 505.0] {
            let m = model_mb * 1024.0 * 1024.0;
            let c = exchange_rate(ExchangeMemProfile::cached(), m, 1e12, DRAM);
            let b = exchange_rate(ExchangeMemProfile::bypass(), m, 1e12, DRAM);
            assert!(c > b);
        }
    }

    #[test]
    fn fig17_bridge_is_the_ceiling() {
        let p = PcieBridge::pbox();
        let per_worker = 14e9; // 56 Gbps bidirectional
        // Small populations: NIC-limited, bridge irrelevant.
        assert!(p.microbench_rate(2, per_worker) < p.bridge_cap);
        // Large populations: bridge-limited at 90, not NIC 140 or DRAM 120.
        assert_eq!(p.microbench_rate(16, per_worker), 90e9);
        assert_eq!(p.ideal_rate(16, per_worker), 140e9);
        // PHub reaches 97% of the microbenchmark.
        let phub = p.phub_rate(16, per_worker);
        assert!((phub / p.microbench_rate(16, per_worker) - 0.97).abs() < 1e-9);
    }

    #[test]
    fn fig17_monotone_in_workers() {
        let p = PcieBridge::pbox();
        let mut prev = 0.0;
        for w in 1..=16 {
            let r = p.phub_rate(w, 14e9);
            assert!(r >= prev);
            prev = r;
        }
    }
}
