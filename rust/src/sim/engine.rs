//! Minimal discrete-event engine: a time-ordered event heap plus FIFO
//! server resources (cores, dispatchers, NIC injectors).

use std::cmp::Reverse;
use std::collections::BinaryHeap;

/// Simulation timestamp in seconds. Wraps f64 to provide a total order for
/// the event heap (NaN is a bug and will panic in `total_cmp`).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Time(pub f64);

impl Eq for Time {}
impl PartialOrd for Time {
    fn partial_cmp(&self, other: &Self) -> Option<std::cmp::Ordering> {
        Some(self.cmp(other))
    }
}
impl Ord for Time {
    fn cmp(&self, other: &Self) -> std::cmp::Ordering {
        self.0.total_cmp(&other.0)
    }
}

/// Event heap with stable FIFO tie-breaking for equal timestamps.
#[derive(Debug)]
pub struct EventQueue<E> {
    heap: BinaryHeap<Reverse<(Time, u64, EvBox<E>)>>,
    seq: u64,
}

/// Wrapper so the heap never compares the event payload itself.
#[derive(Debug)]
struct EvBox<E>(E);
impl<E> PartialEq for EvBox<E> {
    fn eq(&self, _: &Self) -> bool {
        true
    }
}
impl<E> Eq for EvBox<E> {}
impl<E> PartialOrd for EvBox<E> {
    fn partial_cmp(&self, other: &Self) -> Option<std::cmp::Ordering> {
        Some(self.cmp(other))
    }
}
impl<E> Ord for EvBox<E> {
    fn cmp(&self, _: &Self) -> std::cmp::Ordering {
        std::cmp::Ordering::Equal
    }
}

impl<E> EventQueue<E> {
    pub fn new() -> Self {
        EventQueue {
            heap: BinaryHeap::new(),
            seq: 0,
        }
    }

    pub fn push(&mut self, at: f64, ev: E) {
        assert!(at.is_finite(), "event scheduled at non-finite time");
        self.heap.push(Reverse((Time(at), self.seq, EvBox(ev))));
        self.seq += 1;
    }

    pub fn pop(&mut self) -> Option<(f64, E)> {
        self.heap.pop().map(|Reverse((t, _, e))| (t.0, e.0))
    }

    /// Timestamp of the next event without removing it.
    pub fn peek_time(&self) -> Option<f64> {
        self.heap.peek().map(|Reverse((t, _, _))| t.0)
    }

    pub fn is_empty(&self) -> bool {
        self.heap.is_empty()
    }

    pub fn len(&self) -> usize {
        self.heap.len()
    }
}

impl<E> Default for EventQueue<E> {
    fn default() -> Self {
        Self::new()
    }
}

/// A FIFO server: jobs queue and are serviced one at a time.
///
/// Models a pinned aggregation core, the MXNet dispatcher thread, or a NIC
/// send injector. `submit` returns the completion time; the caller
/// schedules its own event at that time.
#[derive(Debug, Clone, Default)]
pub struct FifoServer {
    busy_until: f64,
    /// Total busy (service) time accumulated, for utilization reporting.
    pub busy_time: f64,
    /// Jobs served.
    pub jobs: u64,
}

impl FifoServer {
    pub fn new() -> Self {
        Self::default()
    }

    /// Submit a job arriving at `at` with the given service time; returns
    /// the completion time (arrival waits behind earlier jobs).
    pub fn submit(&mut self, at: f64, service: f64) -> f64 {
        assert!(service >= 0.0 && at >= 0.0);
        let start = self.busy_until.max(at);
        self.busy_until = start + service;
        self.busy_time += service;
        self.jobs += 1;
        self.busy_until
    }

    pub fn busy_until(&self) -> f64 {
        self.busy_until
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn heap_orders_by_time_then_fifo() {
        let mut q = EventQueue::new();
        q.push(2.0, "b");
        q.push(1.0, "a");
        q.push(2.0, "c");
        assert_eq!(q.pop(), Some((1.0, "a")));
        assert_eq!(q.pop(), Some((2.0, "b")));
        assert_eq!(q.pop(), Some((2.0, "c")));
        assert!(q.pop().is_none());
    }

    #[test]
    fn peek_does_not_remove() {
        let mut q = EventQueue::new();
        q.push(5.0, ());
        assert_eq!(q.peek_time(), Some(5.0));
        assert_eq!(q.len(), 1);
    }

    #[test]
    fn fifo_server_queues() {
        let mut s = FifoServer::new();
        assert_eq!(s.submit(0.0, 1.0), 1.0);
        // Arrives while busy: waits.
        assert_eq!(s.submit(0.5, 1.0), 2.0);
        // Arrives after idle: starts immediately.
        assert_eq!(s.submit(10.0, 0.5), 10.5);
        assert_eq!(s.jobs, 3);
        assert!((s.busy_time - 2.5).abs() < 1e-12);
    }

    #[test]
    #[should_panic]
    fn non_finite_event_time_panics() {
        let mut q = EventQueue::new();
        q.push(f64::NAN, ());
    }
}
