//! Software-stack cost constants used by the exchange simulator.
//!
//! All magic numbers live here so the calibration pass (EXPERIMENTS.md)
//! adjusts one file. Values start from the paper's own measurements
//! (sections 2.3.2, 3.2, 4.5) and published RDMA/TCP microbenchmarks.

use crate::config::Stack;

/// Per-stack software costs for one PS process.
#[derive(Debug, Clone)]
pub struct StackParams {
    /// Data copies per message on the TCP path (MXNet: 4, section 2.3.2).
    pub copies: usize,
    /// memcpy bandwidth for those copies, bytes/s.
    pub copy_bw: f64,
    /// Sender-side per-message CPU/injection cost, seconds.
    pub send_overhead: f64,
    /// Whether all PS messages serialize through a dispatcher thread
    /// (MXNet's ZMQ/dispatcher design, section 2.3.2).
    pub dispatcher: bool,
    /// Dispatcher service per message (sync with ZMQ/agg/opt threads).
    pub dispatch_per_msg: f64,
    /// Wide aggregation: thread-gang sync cost per key per pass.
    pub wide_sync_per_key: f64,
    /// Wide aggregation parallel efficiency (tall ≈ 20x better, section 4.5).
    pub wide_efficiency: f64,
    /// Threads in the wide gang.
    pub wide_threads: usize,
}

impl StackParams {
    pub fn for_stack(stack: Stack) -> Self {
        match stack {
            // PS-Lite over TCP/ZMQ. 4 copies through OS buffers; high
            // per-message cost; single dispatcher.
            Stack::MxnetTcp => StackParams {
                copies: 4,
                copy_bw: 3.5e9,
                send_overhead: 15e-6,
                dispatcher: true,
                dispatch_per_msg: 30e-6,
                wide_sync_per_key: 60e-6,
                wide_efficiency: 0.15,
                wide_threads: 8,
            },
            // Native InfiniBand data plane (zero copy, kernel bypass) under
            // the *unchanged* MXNet PS architecture (section 4.3.1).
            Stack::MxnetIb => StackParams {
                copies: 0,
                copy_bw: 5e9,
                send_overhead: 1.5e-6,
                dispatcher: true,
                dispatch_per_msg: 10e-6,
                wide_sync_per_key: 60e-6,
                wide_efficiency: 0.15,
                wide_threads: 8,
            },
            // PHub: zero copy, minimal metadata, no dispatcher, no gang
            // synchronization (tall aggregation).
            Stack::PHub => StackParams {
                copies: 0,
                copy_bw: 5e9,
                send_overhead: 1.0e-6,
                dispatcher: false,
                dispatch_per_msg: 0.0,
                wide_sync_per_key: 0.0,
                wide_efficiency: 1.0,
                wide_threads: 1,
            },
        }
    }

    /// Per-message copy latency for a message of `bytes`.
    pub fn copy_time(&self, bytes: f64) -> f64 {
        self.copies as f64 * bytes / self.copy_bw
    }
}

/// Worker-side GPU<->host staging copy bandwidth (one copy each way is
/// always required without GPU-Direct; section 3.2.1 "Minimal Copy").
pub const GPU_STAGING_BW: f64 = 11e9;

/// Cross-NUMA aggregation bandwidth derating in Worker-by-Interface mode
/// (section 4.5: keys scatter across sockets, buffers bounce; the paper
/// measured Key-by-Interface 1.43x faster overall).
pub const CROSS_NUMA_DERATE: f64 = 0.55;

/// Per-chunk, per-worker cross-core hand-off cost in Worker-by-Interface
/// mode (serialized at the PS; calibrated so Key-by-Interface wins by the
/// paper's ~1.43x on the ZeroCompute ResNet-18 workload).
pub const WBI_SYNC_PER_CHUNK: f64 = 1.1e-6;

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tcp_has_copies_ib_does_not() {
        let tcp = StackParams::for_stack(Stack::MxnetTcp);
        let ib = StackParams::for_stack(Stack::MxnetIb);
        assert!(tcp.copy_time(1e6) > 0.0);
        assert_eq!(ib.copy_time(1e6), 0.0);
        assert!(tcp.send_overhead > ib.send_overhead);
    }

    #[test]
    fn phub_has_no_dispatcher() {
        let p = StackParams::for_stack(Stack::PHub);
        assert!(!p.dispatcher);
        assert_eq!(p.wide_sync_per_key, 0.0);
        let m = StackParams::for_stack(Stack::MxnetIb);
        assert!(m.dispatcher);
    }
}
