//! Exchange planning: cluster topology construction and the message plan
//! (key → messages → PS process / interface / core assignment).

use crate::config::{ClusterConfig, PsConfig};
use crate::coordinator::mapping;
use crate::dnn::Dnn;
use crate::fabric::{Fabric, LinkId};

/// Where a PS process runs.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum PsPlacement {
    /// Shares machine (and NIC) with worker `w`.
    OnWorker(usize),
    /// Dedicated machine.
    Dedicated,
}

/// One PS process's attachment points in the fabric.
#[derive(Debug, Clone)]
pub struct PsHost {
    pub placement: PsPlacement,
    /// Per-NIC uplinks (PS -> switch) and downlinks (switch -> PS).
    pub up: Vec<LinkId>,
    pub down: Vec<LinkId>,
    /// PCIe-to-memory bridge links (dedicated hosts only).
    pub pcie_in: Option<LinkId>,
    pub pcie_out: Option<LinkId>,
    /// Inter-socket (QPI) links, crossed only by NUMA-mismatched flows.
    pub qpi_in: Option<LinkId>,
    pub qpi_out: Option<LinkId>,
    pub cores: usize,
    pub numa_domains: usize,
}

/// The cluster realized as fabric links.
#[derive(Debug)]
pub struct Topology {
    pub fabric: Fabric,
    pub worker_up: Vec<LinkId>,
    pub worker_down: Vec<LinkId>,
    pub ps: Vec<PsHost>,
}

impl Topology {
    /// Build the intra-rack topology for a cluster (full bisection within
    /// the rack; cross-rack handled by [`crate::coordinator::hierarchy`]).
    pub fn build(cluster: &ClusterConfig) -> Topology {
        let mut fabric = Fabric::new();
        let bw = cluster.net.link_bytes_per_sec();
        let n = cluster.n_workers;

        let worker_up: Vec<_> = (0..n)
            .map(|w| fabric.add_link(format!("w{w}-up"), bw))
            .collect();
        let worker_down: Vec<_> = (0..n)
            .map(|w| fabric.add_link(format!("w{w}-down"), bw))
            .collect();

        let mut ps = Vec::new();
        let n_ps = cluster.n_ps_processes();
        for p in 0..n_ps {
            let placement = if cluster.ps.colocated() {
                PsPlacement::OnWorker(p)
            } else {
                PsPlacement::Dedicated
            };
            match placement {
                PsPlacement::OnWorker(w) => {
                    // Colocated PS shares the worker's single NIC: reuse the
                    // worker's links so PS and worker traffic contend — the
                    // paper's "2x per-interface traffic" effect (section 2.1).
                    ps.push(PsHost {
                        placement,
                        up: vec![worker_up[w]],
                        down: vec![worker_down[w]],
                        pcie_in: None,
                        pcie_out: None,
                        qpi_in: None,
                        qpi_out: None,
                        cores: cluster.ps_host.cores,
                        numa_domains: cluster.ps_host.numa_domains,
                    });
                }
                PsPlacement::Dedicated => {
                    let nics = if cluster.ps == PsConfig::PBox {
                        cluster.ps_host.nics
                    } else {
                        1
                    };
                    let up = (0..nics)
                        .map(|j| fabric.add_link(format!("ps{p}-nic{j}-up"), bw))
                        .collect();
                    let down = (0..nics)
                        .map(|j| fabric.add_link(format!("ps{p}-nic{j}-down"), bw))
                        .collect();
                    // The PCIe-to-memory bridge: every NIC flow traverses it
                    // (the real PBox ceiling, section 4.7).
                    let half = cluster.ps_host.pcie_bridge_bw / 2.0;
                    // Inter-socket interconnect: ~25 GB/s per direction on
                    // the Broadwell-class PBox prototype.
                    let qpi = 25e9;
                    ps.push(PsHost {
                        placement,
                        up,
                        down,
                        pcie_in: Some(fabric.add_link(format!("ps{p}-pcie-in"), half)),
                        pcie_out: Some(fabric.add_link(format!("ps{p}-pcie-out"), half)),
                        qpi_in: Some(fabric.add_link(format!("ps{p}-qpi-in"), qpi)),
                        qpi_out: Some(fabric.add_link(format!("ps{p}-qpi-out"), qpi)),
                        cores: cluster.ps_host.cores,
                        numa_domains: cluster.ps_host.numa_domains,
                    });
                }
            }
        }
        Topology {
            fabric,
            worker_up,
            worker_down,
            ps,
        }
    }

    /// Uplink path: worker `w` -> PS process `p` via PS NIC `iface`.
    pub fn up_path(&self, w: usize, p: usize, iface: usize) -> Vec<LinkId> {
        let host = &self.ps[p];
        if host.placement == PsPlacement::OnWorker(w) {
            return vec![]; // node-local
        }
        let mut path = vec![self.worker_up[w], host.down[iface]];
        if let Some(l) = host.pcie_in {
            path.push(l);
        }
        path
    }

    /// Downlink path: PS process `p` NIC `iface` -> worker `w`.
    pub fn down_path(&self, w: usize, p: usize, iface: usize) -> Vec<LinkId> {
        let host = &self.ps[p];
        if host.placement == PsPlacement::OnWorker(w) {
            return vec![];
        }
        let mut path = Vec::with_capacity(3);
        if let Some(l) = host.pcie_out {
            path.push(l);
        }
        path.push(host.up[iface]);
        path.push(self.worker_down[w]);
        path
    }
}

/// One wire message (a chunk, or a coarsened train of chunks).
#[derive(Debug, Clone)]
pub struct Msg {
    pub key: usize,
    pub bytes: f64,
    /// PS process handling this message's chunk range.
    pub ps: usize,
    /// NIC on the PS host (Key-by-Interface mode; Worker-by-Interface
    /// resolves the NIC from the worker id at runtime).
    pub iface: usize,
    /// Core on the PS host (tall aggregation).
    pub core: usize,
    /// Wide-aggregation group this message belongs to: the (key, shard)
    /// slice that a PS-Lite server treats as its own key.
    pub group: usize,
    /// Number of real PHub chunks this message covers (coarsening factor
    /// for per-message fixed costs).
    pub chunks: f64,
}

/// A wide-aggregation unit: one PS process's slice of one key (PS-Lite
/// slices tensors above its big-array threshold across servers; each slice
/// aggregates independently, whole-slice-at-a-time).
#[derive(Debug, Clone)]
pub struct Group {
    pub key: usize,
    pub ps: usize,
    pub bytes: f64,
    /// Message indices belonging to this group.
    pub msgs: Vec<usize>,
}

/// The full message plan for one model exchange.
#[derive(Debug)]
pub struct Plan {
    pub msgs: Vec<Msg>,
    /// Message index range (contiguous) for each key.
    pub key_msgs: Vec<(usize, usize)>,
    /// Wide-aggregation groups (one per (key, shard) pair with traffic).
    pub groups: Vec<Group>,
    /// Simulation message unit in bytes.
    pub unit: f64,
}

/// Cap on simulated messages per (worker, direction) — coarser units are
/// used for very large model/chunk ratios to bound event count. Per-message
/// fixed costs scale by `Msg::chunks` so overhead accounting is preserved.
pub const MAX_SIM_MSGS: usize = 2048;

impl Plan {
    pub fn build(cluster: &ClusterConfig, dnn: &Dnn) -> Plan {
        let chunk = cluster.exchange.chunk_bytes as f64;
        let model = dnn.model_bytes as f64;
        let unit = chunk.max(model / MAX_SIM_MSGS as f64);
        let n_ps = cluster.n_ps_processes();
        let nics = if cluster.ps == PsConfig::PBox {
            cluster.ps_host.nics
        } else {
            1
        };
        let cores = cluster.ps_host.cores;

        // Message-granular sharding across PS processes: PS-Lite slices
        // tensors above its big-array threshold and round-robins the
        // slices over servers (so does PHub with its chunks). Whole-key
        // placement would bottleneck one shard on AlexNet/VGG's giant FC
        // keys. Small keys round-robin via the running message counter.
        let mut msgs: Vec<Msg> = Vec::new();
        let mut key_msgs = Vec::new();
        let mut groups: Vec<Group> = Vec::new();
        let mut g = 0usize;
        for (k, l) in dnn.layers.iter().enumerate() {
            let start = msgs.len();
            // Group index per shard for this key (created lazily).
            let mut group_of_ps: Vec<Option<usize>> = vec![None; n_ps];
            let mut remaining = l.bytes as f64;
            while remaining > 0.0 {
                let bytes = remaining.min(unit);
                let p = g % n_ps;
                let (iface, core) =
                    mapping::chunk_slot(g, nics, cores, cluster.ps_host.numa_domains);
                let gi = *group_of_ps[p].get_or_insert_with(|| {
                    groups.push(Group {
                        key: k,
                        ps: p,
                        bytes: 0.0,
                        msgs: Vec::new(),
                    });
                    groups.len() - 1
                });
                groups[gi].bytes += bytes;
                groups[gi].msgs.push(msgs.len());
                msgs.push(Msg {
                    key: k,
                    bytes,
                    ps: p,
                    iface,
                    core,
                    group: gi,
                    chunks: (bytes / chunk).max(1.0),
                });
                remaining -= bytes;
                g += 1;
            }
            key_msgs.push((start, msgs.len()));
        }
        Plan {
            msgs,
            key_msgs,
            groups,
            unit,
        }
    }

    pub fn total_bytes(&self) -> f64 {
        self.msgs.iter().map(|m| m.bytes).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{ClusterConfig, PsConfig, Stack};

    fn cluster(ps: PsConfig) -> ClusterConfig {
        ClusterConfig::paper_testbed().with_ps(ps)
    }

    #[test]
    fn pbox_topology_has_ten_nics_and_pcie() {
        let t = Topology::build(&cluster(PsConfig::PBox));
        assert_eq!(t.ps.len(), 1);
        assert_eq!(t.ps[0].up.len(), 10);
        assert!(t.ps[0].pcie_in.is_some());
        // Path: worker up, pbox nic down, pcie in.
        assert_eq!(t.up_path(3, 0, 7).len(), 3);
    }

    #[test]
    fn colocated_local_path_is_empty() {
        let t = Topology::build(&cluster(PsConfig::ColocatedSharded));
        assert_eq!(t.ps.len(), 8);
        assert!(t.up_path(2, 2, 0).is_empty());
        assert_eq!(t.up_path(2, 3, 0).len(), 2);
    }

    #[test]
    fn colocated_ps_shares_worker_links() {
        let t = Topology::build(&cluster(PsConfig::ColocatedSharded));
        // PS 4's downlink IS worker 4's downlink: contention is structural.
        assert_eq!(t.ps[4].down[0], t.worker_down[4]);
    }

    #[test]
    fn plan_covers_model_exactly() {
        let c = cluster(PsConfig::PBox);
        for abbrev in ["AN", "RN50", "GN"] {
            let d = crate::dnn::Dnn::by_abbrev(abbrev).unwrap();
            let plan = Plan::build(&c, &d);
            assert!((plan.total_bytes() - d.model_bytes as f64).abs() < 1.0);
            assert_eq!(plan.key_msgs.len(), d.layers.len());
        }
    }

    #[test]
    fn plan_respects_message_cap() {
        let c = cluster(PsConfig::PBox);
        let d = crate::dnn::Dnn::by_abbrev("V19").unwrap(); // largest model
        let plan = Plan::build(&c, &d);
        // Per-layer ceil rounding can exceed the cap slightly.
        assert!(plan.msgs.len() <= MAX_SIM_MSGS + d.layers.len());
        // Coarsened messages carry their chunk multiplicity.
        assert!(plan.msgs[0].chunks > 1.0);
    }

    #[test]
    fn sharded_plan_balances_bytes() {
        let c = cluster(PsConfig::ColocatedSharded);
        let d = crate::dnn::Dnn::by_abbrev("RN50").unwrap();
        let plan = Plan::build(&c, &d);
        let mut per_ps = vec![0.0; 8];
        for m in &plan.msgs {
            per_ps[m.ps] += m.bytes;
        }
        let max = per_ps.iter().cloned().fold(0.0, f64::max);
        let min = per_ps.iter().cloned().fold(f64::INFINITY, f64::min);
        // Greedy LPT on ~54 conv keys should balance within ~30%.
        assert!(max / min < 1.3, "{per_ps:?}");
    }

    #[test]
    fn pbox_plan_spreads_interfaces() {
        let c = cluster(PsConfig::PBox).with_stack(Stack::PHub);
        let d = crate::dnn::Dnn::by_abbrev("RN18").unwrap();
        let plan = Plan::build(&c, &d);
        let mut per_iface = vec![0.0; 10];
        for m in &plan.msgs {
            per_iface[m.iface] += m.bytes;
        }
        let max = per_iface.iter().cloned().fold(0.0, f64::max);
        let min = per_iface.iter().cloned().fold(f64::INFINITY, f64::min);
        assert!(max / min < 1.25, "{per_iface:?}");
    }
}
