//! The parameter-exchange event loop: simulates synchronous data-parallel
//! training iterations over the fabric, with per-stack software costs.
//!
//! One simulation = one (cluster, DNN, GPU, stage-flags) configuration run
//! for a few iterations; reported numbers come from post-warmup iterations.
//!
//! Pipeline per iteration (paper Figure 3):
//!   forward → backward (per-layer gradients stream out in reverse order)
//!     → per-message upload (windowed by queue pairs)
//!     → PS receive path (dispatcher for MXNet stacks)
//!     → aggregation when all workers' copies arrive
//!         tall: per-chunk, on the chunk's pinned core, fused with opt
//!         wide: per-key, thread gang, separate opt pass (MXNet)
//!     → download back to every worker
//!   iteration ends when every worker holds the full updated model.

use super::engine::{EventQueue, FifoServer};
use super::params::{StackParams, CROSS_NUMA_DERATE, GPU_STAGING_BW, WBI_SYNC_PER_CHUNK};
use super::plan::{Msg, Plan, Topology};
use crate::compute::ComputeEngine;
use crate::config::ClusterConfig;
use crate::dnn::Dnn;
use crate::fabric::qp::{active_qps, QpCache};

/// Which pipeline components are enabled — the progressive-overhead axis
/// of Figures 5 and 14.
#[derive(Debug, Clone, Copy)]
pub struct StageFlags {
    /// Worker/PS data-copy costs (TCP OS-buffer copies, GPU staging).
    pub data_copy: bool,
    /// Gradient aggregation work.
    pub aggregation: bool,
    /// Optimizer work.
    pub optimization: bool,
    /// Synchronization & dispatcher overheads.
    pub sync_other: bool,
}

impl StageFlags {
    pub fn all() -> Self {
        StageFlags {
            data_copy: true,
            aggregation: true,
            optimization: true,
            sync_other: true,
        }
    }

    /// Communication only (the Figure 5 "data copy" stage baseline).
    pub fn comm_only() -> Self {
        StageFlags {
            data_copy: true,
            aggregation: false,
            optimization: false,
            sync_other: false,
        }
    }
}

/// Simulation knobs beyond the cluster config.
#[derive(Debug, Clone)]
pub struct SimOpts {
    pub iterations: usize,
    pub warmup: usize,
    pub stages: StageFlags,
    /// Jobs sharing the PS host (Figure 18); resources are partitioned.
    pub tenants: usize,
}

impl Default for SimOpts {
    fn default() -> Self {
        SimOpts {
            iterations: 3,
            warmup: 1,
            stages: StageFlags::all(),
            tenants: 1,
        }
    }
}

/// Simulation output.
#[derive(Debug, Clone)]
pub struct SimResult {
    /// Steady-state time per iteration (seconds).
    pub iter_time: f64,
    /// Cluster-wide training throughput, samples/s.
    pub throughput: f64,
    /// Per-iteration time spent in worker compute.
    pub compute_time: f64,
    /// iter_time - compute_time: exposed exchange overhead.
    pub exposed_overhead: f64,
    /// Mean utilization of the busiest PS aggregation core.
    pub max_core_util: f64,
    /// Dispatcher utilization (MXNet stacks; 0 for PHub).
    pub dispatcher_util: f64,
    /// Model exchanges per second (= iterations/s).
    pub exchange_rate: f64,
}

#[derive(Debug, Clone)]
enum Ev {
    /// Worker w's gradient for key k is ready for exchange.
    GradReady { w: usize, iter: usize, key: usize },
    /// Injector finished; put the upload on the wire.
    StartUpload { w: usize, m: usize },
    /// PS receive path done for worker w's message m.
    RecvDone { w: usize, m: usize },
    /// Tall path: chunk m aggregated+optimized; wide: group agg done.
    AggDone { m: usize },
    /// Wide path: group optimization done.
    OptDone { group: usize },
    /// PS injector done; put the download on the wire.
    StartDownload { w: usize, m: usize },
    /// Worker-side receive finished for message m.
    Delivered { w: usize, m: usize },
}

/// Flow tag encoding: direction (up=0/down=1) | worker | message.
fn tag(dir: u64, w: usize, m: usize) -> u64 {
    dir << 62 | (w as u64) << 40 | m as u64
}
fn untag(t: u64) -> (u64, usize, usize) {
    (t >> 62, ((t >> 40) & 0x3F_FFFF) as usize, (t & 0xFF_FFFF_FFFF) as usize)
}

pub struct ExchangeSim<'a> {
    cluster: &'a ClusterConfig,
    dnn: &'a Dnn,
    engine: ComputeEngine,
    opts: SimOpts,
    topo: Topology,
    plan: Plan,
    params: StackParams,
    qp_cache: QpCache,

    events: EventQueue<Ev>,
    now: f64,

    // Per-worker upload machinery.
    injector: Vec<FifoServer>,
    pending: Vec<std::collections::VecDeque<usize>>, // msg queue per worker
    in_flight: Vec<usize>,                           // per worker
    window: usize,

    // PS-side servers.
    dispatcher: Vec<FifoServer>,           // per PS process
    /// Worker-by-Interface coordination: cross-core hand-off of chunks
    /// whose arrival NIC is not the aggregation core's socket (section
    /// 4.5); serialized through a per-PS hand-off queue.
    wbi_coord: Vec<FifoServer>,
    cores: Vec<Vec<FifoServer>>,           // [ps][core]
    gang: Vec<FifoServer>,                 // per PS process (wide agg)
    ps_injector: Vec<Vec<FifoServer>>,     // [ps][iface]

    // Exchange state for the current iteration.
    arrived: Vec<usize>,      // per msg: workers arrived
    group_arrived: Vec<usize>, // per wide group: msgs arrived * workers
    delivered: Vec<usize>,    // per worker: msgs received back
    iter: usize,
    iter_start: f64,
    worker_done: Vec<bool>,

    // Accounting.
    iter_times: Vec<f64>,
}

impl<'a> ExchangeSim<'a> {
    pub fn new(
        cluster: &'a ClusterConfig,
        dnn: &'a Dnn,
        engine: ComputeEngine,
        opts: SimOpts,
    ) -> Self {
        let mut topo = Topology::build(cluster);
        let plan = Plan::build(cluster, dnn);
        let params = StackParams::for_stack(cluster.stack);
        let n = cluster.n_workers;
        let n_ps = cluster.n_ps_processes();

        // Multi-tenancy (Figure 18): tenants partition PS cores and NIC
        // bandwidth; this job sees 1/tenants of each. Implemented by
        // scaling the PS-side link capacities and core count.
        let tenants = opts.tenants.max(1);
        if tenants > 1 {
            // Paper section 4.8 setup: the J jobs run on the SAME worker
            // machines (the testbed has 8), so worker NICs, worker GPUs,
            // PBox NICs, the PCIe bridge, and the aggregation cores are
            // all timeshared J ways. We simulate one job seeing 1/J of
            // every shared resource.
            let scale = 1.0 / tenants as f64;
            let mut scaled = cluster.clone();
            scaled.ps_host.cores = (cluster.ps_host.cores / tenants).max(1);
            scaled.ps_host.pcie_bridge_bw = cluster.ps_host.pcie_bridge_bw * scale;
            scaled.net.link_gbps = cluster.net.link_gbps * scale;
            topo = Topology::build(&scaled);
        }
        let ps_cores = if tenants > 1 {
            (cluster.ps_host.cores / tenants).max(1)
        } else {
            cluster.ps_host.cores
        };

        // Upload window: outstanding wire messages per worker. Must cover
        // every PS interface with a couple of messages or lockstep workers
        // convoy onto a subset of PBox NICs and leave the rest idle (the
        // real system posts receives on every QP of every card; QP *count*
        // effects are modeled via the QP cache, section 4.6).
        let total_ifaces: usize = {
            let t = Topology::build(cluster);
            t.ps.iter().map(|h| h.up.len()).sum()
        };
        let window =
            (cluster.net.qps_per_connection.max(1) * total_ifaces * 2).max(8);
        let cores = (0..n_ps)
            .map(|_| vec![FifoServer::new(); ps_cores])
            .collect();
        let ps_injector = topo
            .ps
            .iter()
            .map(|h| vec![FifoServer::new(); h.up.len()])
            .collect();

        let n_msgs = plan.msgs.len();
        let n_groups = plan.groups.len();
        ExchangeSim {
            cluster,
            dnn,
            engine,
            opts,
            topo,
            plan,
            params,
            qp_cache: QpCache::new(
                cluster.net.qp_cache_entries,
                cluster.net.qp_cache_miss_penalty,
            ),
            events: EventQueue::new(),
            now: 0.0,
            injector: vec![FifoServer::new(); n],
            pending: vec![Default::default(); n],
            in_flight: vec![0; n],
            window,
            dispatcher: vec![FifoServer::new(); n_ps],
            wbi_coord: vec![FifoServer::new(); n_ps],
            cores,
            gang: vec![FifoServer::new(); n_ps],
            ps_injector,
            arrived: vec![0; n_msgs],
            group_arrived: vec![0; n_groups],
            delivered: vec![0; n],
            iter: 0,
            iter_start: 0.0,
            worker_done: vec![false; n],
            iter_times: Vec::new(),
        }
    }

    /// Effective per-core aggregation+optimization bandwidth (input
    /// gradient bytes/s), after cache policy and NUMA effects.
    fn agg_bw(&self) -> f64 {
        let mut bw = self.cluster.ps_host.core_agg_bw;
        if !self.cluster.exchange.cached_agg {
            // Non-temporal path is DRAM-bound (Table 4): roughly halves
            // effective per-core throughput under load.
            bw *= 0.5;
        }
        if !self.cluster.exchange.key_by_interface {
            bw *= CROSS_NUMA_DERATE;
        }
        // Multi-tenant cache dilution: more jobs -> more optimizer state
        // competing for LLC (Figure 18's AlexNet effect).
        if self.opts.tenants > 1 {
            bw /= 1.0 + 0.01 * self.opts.tenants as f64;
        }
        bw
    }

    /// Tall-path service time for one message on its core: aggregation
    /// reads W gradient copies, optimization makes one model pass.
    fn tall_service(&self, m: &Msg) -> f64 {
        let w = self.cluster.n_workers as f64;
        let bw = self.agg_bw();
        let mut s = 0.0;
        if self.opts.stages.aggregation {
            s += m.bytes * w / bw;
        }
        if self.opts.stages.optimization {
            s += m.bytes / bw;
        }
        s
    }

    /// Wide-path whole-slice aggregation gang service (MXNet, section
    /// 3.2.2): one (key, shard) group at a time.
    fn wide_agg_service(&self, group: usize) -> f64 {
        if !self.opts.stages.aggregation {
            return 0.0;
        }
        let bytes = self.plan.groups[group].bytes;
        let w = self.cluster.n_workers as f64;
        let threads = self.params.wide_threads as f64;
        let mut s = bytes * w / (threads * self.agg_bw() * self.params.wide_efficiency);
        if self.opts.stages.sync_other {
            s += self.params.wide_sync_per_key;
        }
        s
    }

    /// Wide-path optimization pass.
    fn wide_opt_service(&self, group: usize) -> f64 {
        if !self.opts.stages.optimization {
            return 0.0;
        }
        let bytes = self.plan.groups[group].bytes;
        let threads = self.params.wide_threads as f64;
        let mut s = bytes / (threads * self.agg_bw() * self.params.wide_efficiency);
        if self.opts.stages.sync_other {
            s += self.params.wide_sync_per_key;
        }
        s
    }

    /// Per-message fixed sender cost (CPU injection + TCP copies + QP
    /// cache pressure), scaled by the real chunks in this sim message.
    fn send_cost(&self, m: &Msg) -> f64 {
        let mut c = self.params.send_overhead * m.chunks;
        if self.opts.stages.data_copy {
            c += self.params.copy_time(m.bytes);
            // One staging copy between GPU and host memory always exists.
            c += m.bytes / GPU_STAGING_BW;
        }
        if self.opts.stages.sync_other {
            let aq = active_qps(
                self.cluster.n_workers,
                self.cluster.net.qps_per_connection,
            );
            c += self.qp_cache.message_overhead(aq) * m.chunks;
        }
        c
    }

    /// PS receive-path service (dispatcher, if this stack has one).
    fn recv_cost(&self, m: &Msg) -> f64 {
        let mut c = 0.0;
        if self.params.dispatcher && self.opts.stages.sync_other {
            c += self.params.dispatch_per_msg * m.chunks;
        }
        if self.opts.stages.data_copy {
            c += self.params.copy_time(m.bytes);
        }
        c
    }

    /// Inter-socket link for flows whose NIC and aggregation core are in
    /// different NUMA domains (only possible in Worker-by-Interface mode;
    /// Key-by-Interface pins chunk, QP, and core to one socket).
    fn cross_socket_link(
        &self,
        iface: usize,
        msg: &Msg,
        down: bool,
    ) -> Option<crate::fabric::LinkId> {
        let host = &self.topo.ps[msg.ps];
        let nics = host.up.len();
        if nics <= 1 {
            return None;
        }
        let numa = host.numa_domains;
        let cores = self.cluster.ps_host.cores;
        let nic_dom = crate::coordinator::mapping::nic_numa(iface, nics, numa);
        let core_dom = crate::coordinator::mapping::core_numa(msg.core, cores, numa);
        if nic_dom == core_dom {
            return None;
        }
        if down {
            host.qpi_out
        } else {
            host.qpi_in
        }
    }

    fn resolve_iface(&self, w: usize, m: &Msg) -> usize {
        if self.cluster.exchange.key_by_interface {
            m.iface
        } else {
            let nics = self.topo.ps[m.ps].up.len();
            w % nics
        }
    }

    fn start_iteration(&mut self) {
        self.iter_start = self.now;
        self.arrived.iter_mut().for_each(|a| *a = 0);
        self.group_arrived.iter_mut().for_each(|a| *a = 0);
        self.delivered.iter_mut().for_each(|d| *d = 0);
        self.worker_done.iter_mut().for_each(|d| *d = false);
        // Multi-tenancy: the GPU is timeshared by `tenants` jobs, so this
        // job's compute stretches by that factor.
        let tstretch = self.opts.tenants.max(1) as f64;
        let fwd = self.engine.forward_time(self.dnn) * tstretch;
        for w in 0..self.cluster.n_workers {
            let straggle = self.engine.straggler_factor(w, self.iter) * tstretch;
            for key in 0..self.dnn.layers.len() {
                let off = self.engine.grad_ready_offset(self.dnn, key);
                let t = self.now + fwd + off * straggle;
                self.events.push(
                    t,
                    Ev::GradReady {
                        w,
                        iter: self.iter,
                        key,
                    },
                );
            }
        }
        // ZeroCompute: all GradReady at now (fwd = off = 0).
    }

    fn try_start_uploads(&mut self, w: usize) {
        while self.in_flight[w] < self.window {
            let Some(m) = self.pending[w].pop_front() else {
                return;
            };
            self.in_flight[w] += 1;
            let service = self.send_cost(&self.plan.msgs[m]);
            let done = self.injector[w].submit(self.now, service);
            self.events.push(done, Ev::StartUpload { w, m });
        }
    }

    fn handle(&mut self, ev: Ev) {
        match ev {
            Ev::GradReady { w, iter, key } => {
                debug_assert_eq!(iter, self.iter);
                let (a, b) = self.plan.key_msgs[key];
                for m in a..b {
                    self.pending[w].push_back(m);
                }
                self.try_start_uploads(w);
            }
            Ev::StartUpload { w, m } => {
                let msg = &self.plan.msgs[m];
                let iface = self.resolve_iface(w, msg);
                let mut path = self.topo.up_path(w, msg.ps, iface);
                // Worker-by-Interface mode scatters a chunk's arrivals
                // across sockets: traffic whose entry NIC is not in the
                // aggregation core's NUMA domain crosses the inter-socket
                // interconnect (section 4.5's locality penalty).
                if let Some(qpi) = self.cross_socket_link(iface, msg, false) {
                    path.push(qpi);
                }
                self.topo.fabric.start_flow(path, msg.bytes, tag(0, w, m));
            }
            Ev::RecvDone { w, m } => {
                self.in_flight[w] -= 1;
                self.try_start_uploads(w);
                self.msg_arrived(m);
            }
            Ev::AggDone { m } => {
                if self.cluster.exchange.tall_aggregation {
                    self.send_downloads_msg(m);
                } else {
                    // Wide: m encodes the group; run the optimizer gang pass.
                    let group = m;
                    let service = self.wide_opt_service(group);
                    let ps = self.plan.groups[group].ps;
                    let done = self.gang[ps].submit(self.now, service);
                    self.events.push(done, Ev::OptDone { group });
                }
            }
            Ev::OptDone { group } => {
                for i in 0..self.plan.groups[group].msgs.len() {
                    let m = self.plan.groups[group].msgs[i];
                    self.send_downloads_msg(m);
                }
            }
            Ev::StartDownload { w, m } => {
                let msg = &self.plan.msgs[m];
                let iface = self.resolve_iface(w, msg);
                let mut path = self.topo.down_path(w, msg.ps, iface);
                if let Some(qpi) = self.cross_socket_link(iface, msg, true) {
                    path.push(qpi);
                }
                self.topo.fabric.start_flow(path, msg.bytes, tag(1, w, m));
            }
            Ev::Delivered { w, m } => {
                let _ = m;
                self.delivered[w] += 1;
                if std::env::var_os("PHUB_SIM_TRACE").is_some() && w == 0 {
                    let all = self.plan.msgs.len();
                    if self.delivered[0] % (all / 8).max(1) == 0 {
                        eprintln!(
                            "[trace] t={:.4} w0 delivered {}/{all}",
                            self.now - self.iter_start,
                            self.delivered[0]
                        );
                    }
                }
                if self.delivered[w] == self.plan.msgs.len() && !self.worker_done[w] {
                    self.worker_done[w] = true;
                    if self.worker_done.iter().all(|&d| d) {
                        self.iter_times.push(self.now - self.iter_start);
                        self.iter += 1;
                        if self.iter < self.opts.iterations {
                            self.start_iteration();
                        }
                    }
                }
            }
        }
    }

    /// A worker's copy of message m is fully received at the PS.
    fn msg_arrived(&mut self, m: usize) {
        if std::env::var_os("PHUB_SIM_TRACE").is_some() {
            let total: usize = self.arrived.iter().sum();
            let all = self.plan.msgs.len() * self.cluster.n_workers;
            if total % (all / 8).max(1) == 0 {
                eprintln!(
                    "[trace] t={:.4} arrivals {total}/{all}",
                    self.now - self.iter_start
                );
            }
        }
        self.arrived[m] += 1;
        let n = self.cluster.n_workers;
        let msg = &self.plan.msgs[m];
        if self.cluster.exchange.tall_aggregation {
            if self.arrived[m] == n {
                let service = self.tall_service(msg);
                // Worker-by-Interface mode: the chunk's n arrivals landed on
                // n different NICs/cores and must be handed to the
                // aggregation core — per-chunk coordination that
                // Key-by-Interface avoids entirely (section 4.5).
                let start = if !self.cluster.exchange.key_by_interface
                    && self.opts.stages.sync_other
                {
                    let coord = WBI_SYNC_PER_CHUNK * msg.chunks * n as f64;
                    self.wbi_coord[msg.ps].submit(self.now, coord)
                } else {
                    self.now
                };
                // Under multi-tenancy this job owns a subset of cores;
                // fold the precomputed core id onto the owned set.
                let n_cores = self.cores[msg.ps].len();
                let done =
                    self.cores[msg.ps][msg.core % n_cores].submit(start, service);
                self.events.push(done, Ev::AggDone { m });
            }
        } else {
            let group = msg.group;
            self.group_arrived[group] += 1;
            if self.group_arrived[group] == self.plan.groups[group].msgs.len() * n {
                // Whole slice present from all workers: wide gang
                // aggregation on the owning shard.
                let service = self.wide_agg_service(group);
                let ps = msg.ps;
                let done = self.gang[ps].submit(self.now, service);
                // AggDone carries the group index on the wide path.
                self.events.push(done, Ev::AggDone { m: group });
            }
        }
    }

    /// Queue per-worker downloads of message m through the PS injector.
    fn send_downloads_msg(&mut self, m: usize) {
        let msg = self.plan.msgs[m].clone();
        for w in 0..self.cluster.n_workers {
            let iface = self.resolve_iface(w, &msg);
            // PS-side send cost: per-message CPU plus TCP send copies.
            // Dispatcher stacks serialize sends through the same van
            // thread as receives (PS-Lite); PHub uses per-interface
            // injectors with no shared thread.
            let mut service = self.params.send_overhead * msg.chunks;
            if self.opts.stages.data_copy {
                service += self.params.copy_time(msg.bytes);
            }
            let done = if self.params.dispatcher {
                let mut svc = service;
                if self.opts.stages.sync_other {
                    svc += self.params.dispatch_per_msg * msg.chunks;
                }
                self.dispatcher[msg.ps].submit(self.now, svc)
            } else {
                self.ps_injector[msg.ps][iface].submit(self.now, service)
            };
            self.events.push(done, Ev::StartDownload { w, m });
        }
    }

    /// Run the simulation; returns steady-state statistics.
    pub fn run(mut self) -> SimResult {
        self.start_iteration();
        let guard_events = 50_000_000u64;
        let mut processed = 0u64;
        while self.iter < self.opts.iterations {
            processed += 1;
            assert!(
                processed < guard_events,
                "simulation runaway: t={} iter={} heap={} head={:?} net_dt={:?} flows={} delivered={:?} in_flight={:?} pending={:?}",
                self.now,
                self.iter,
                self.events.len(),
                self.events.peek_time(),
                self.topo.fabric.earliest_completion(),
                self.topo.fabric.n_active(),
                self.delivered,
                self.in_flight,
                self.pending.iter().map(|q| q.len()).collect::<Vec<_>>()
            );

            let heap_t = self.events.peek_time();
            let net_dt = self.topo.fabric.earliest_completion();
            let net_t = net_dt.map(|dt| self.now + dt);
            match (heap_t, net_t) {
                (None, None) => panic!("deadlock: no events, iter {}", self.iter),
                (Some(ht), nt) if nt.map_or(true, |n| ht <= n) => {
                    let (t, ev) = self.events.pop().unwrap();
                    // Apply network progress up to t.
                    let done = self.topo.fabric.advance(t - self.now);
                    self.now = t;
                    for tg in done {
                        self.flow_done(tg);
                    }
                    self.handle(ev);
                }
                (_, Some(nt)) => {
                    let done = self.topo.fabric.advance(nt - self.now);
                    self.now = nt;
                    for tg in done {
                        self.flow_done(tg);
                    }
                }
                _ => unreachable!(),
            }
        }
        self.finish()
    }

    fn flow_done(&mut self, t: u64) {
        let (dir, w, m) = untag(t);
        let msg = &self.plan.msgs[m];
        let lat = self.cluster.net.base_latency;
        if dir == 0 {
            // Upload complete; receive path (dispatcher) then arrival.
            let recv = self.recv_cost(msg);
            let done = if self.params.dispatcher {
                self.dispatcher[msg.ps].submit(self.now + lat, recv)
            } else {
                self.now + lat + recv
            };
            self.events.push(done, Ev::RecvDone { w, m });
        } else {
            // Download complete; worker-side copy then delivery. MXNet's
            // single van thread serializes receive copies; PHub's zero-copy
            // path only pays the GPU staging copy as latency.
            let mut c = 0.0;
            if self.opts.stages.data_copy {
                c += self.params.copy_time(msg.bytes) + msg.bytes / GPU_STAGING_BW;
            }
            let done = if self.params.dispatcher {
                // MXNet's worker van thread handles sends and receives.
                self.injector[w].submit(self.now + lat, c)
            } else {
                self.now + lat + c
            };
            self.events.push(done, Ev::Delivered { w, m });
        }
    }

    fn finish(self) -> SimResult {
        let warm = &self.iter_times[self.opts.warmup.min(self.iter_times.len() - 1)..];
        let iter_time = warm.iter().sum::<f64>() / warm.len() as f64;
        let compute = self.engine.batch_time(self.dnn) * self.opts.tenants.max(1) as f64;
        let total_time: f64 = self.iter_times.iter().sum();
        let max_core_util = self
            .cores
            .iter()
            .flatten()
            .map(|c| c.busy_time / total_time)
            .fold(0.0, f64::max);
        let dispatcher_util = self
            .dispatcher
            .iter()
            .map(|d| d.busy_time / total_time)
            .fold(0.0, f64::max);
        SimResult {
            iter_time,
            throughput: self.cluster.n_workers as f64 * self.dnn.batch as f64 / iter_time,
            compute_time: compute,
            exposed_overhead: (iter_time - compute).max(0.0),
            max_core_util,
            dispatcher_util,
            exchange_rate: 1.0 / iter_time,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compute::Gpu;
    use crate::config::{ClusterConfig, NetConfig, PsConfig, Stack};

    fn run(cluster: &ClusterConfig, abbrev: &str, gpu: Gpu) -> SimResult {
        let dnn = Dnn::by_abbrev(abbrev).unwrap();
        let sim = ExchangeSim::new(
            cluster,
            &dnn,
            ComputeEngine::new(gpu),
            SimOpts::default(),
        );
        sim.run()
    }

    #[test]
    fn iteration_time_at_least_compute() {
        let c = ClusterConfig::paper_testbed();
        let r = run(&c, "RN50", Gpu::Gtx1080Ti);
        assert!(r.iter_time >= 0.161, "{}", r.iter_time);
        // PHub on 56G: ResNet 50 should be close to compute-bound.
        assert!(r.exposed_overhead / r.iter_time < 0.25, "{r:?}");
    }

    #[test]
    fn network_bound_alexnet_on_10g() {
        // AlexNet: 194MB model, 16ms compute. On 10 Gbps the exchange
        // dominates; iteration time must far exceed compute.
        let c = ClusterConfig::paper_testbed().with_net(NetConfig::cloud_10g());
        let r = run(&c, "AN", Gpu::Gtx1080Ti);
        assert!(r.iter_time > 5.0 * 0.016, "{r:?}");
    }

    #[test]
    fn phub_beats_mxnet_tcp() {
        let base = ClusterConfig::paper_testbed()
            .with_ps(PsConfig::ColocatedSharded)
            .with_stack(Stack::MxnetTcp)
            .with_exchange(crate::config::ExchangeConfig::mxnet());
        let tcp = run(&base, "RN50", Gpu::Gtx1080Ti);
        let phub = run(&ClusterConfig::paper_testbed(), "RN50", Gpu::Gtx1080Ti);
        assert!(
            phub.throughput > tcp.throughput,
            "phub {} vs tcp {}",
            phub.throughput,
            tcp.throughput
        );
    }

    #[test]
    fn zero_compute_stresses_exchange() {
        let c = ClusterConfig::paper_testbed();
        let r = run(&c, "RN18", Gpu::ZeroCompute);
        assert_eq!(r.compute_time, 0.0);
        assert!(r.iter_time > 0.0);
        assert!(r.exchange_rate > 10.0, "{r:?}"); // well under a 45MB/links bound
    }

    #[test]
    fn more_workers_more_aggregate_throughput_pbox() {
        let mut prev = 0.0;
        for n in [2, 4, 8] {
            let c = ClusterConfig::paper_testbed().with_workers(n);
            let r = run(&c, "RN50", Gpu::Gtx1080Ti);
            assert!(r.throughput > prev, "n={n} {r:?}");
            prev = r.throughput;
        }
    }

    #[test]
    fn colocated_contention_slower_than_pbox() {
        let d = Dnn::by_abbrev("V11").unwrap();
        let net = NetConfig::cloud_10g();
        let pbox = ClusterConfig::paper_testbed().with_net(net.clone());
        let cs = pbox
            .clone()
            .with_ps(PsConfig::ColocatedSharded);
        let r_pbox =
            ExchangeSim::new(&pbox, &d, ComputeEngine::new(Gpu::Gtx1080Ti), SimOpts::default())
                .run();
        let r_cs =
            ExchangeSim::new(&cs, &d, ComputeEngine::new(Gpu::Gtx1080Ti), SimOpts::default())
                .run();
        // Non-colocated halves per-NIC stress (section 4.3.2).
        assert!(r_pbox.throughput > r_cs.throughput, "{r_pbox:?} vs {r_cs:?}");
    }
}
