//! Progressive overhead breakdown (paper Figures 5 and 14).
//!
//! The paper's methodology: gradually enable components of the training
//! pipeline; each segment is the *additional* iteration time the earlier
//! stages could not hide. We replicate that literally by re-running the
//! simulation with staged [`StageFlags`].

use super::{simulate_opts, SimOpts, StageFlags};
use crate::compute::Gpu;
use crate::config::ClusterConfig;
use crate::dnn::Dnn;

/// One network's progressive overhead decomposition, all in seconds per
/// iteration. Segments are non-negative by construction.
#[derive(Debug, Clone)]
pub struct Breakdown {
    pub dnn: &'static str,
    /// GPU-active time (the "compute" segment).
    pub compute: f64,
    /// Additional time from distributed data movement (copies + wire).
    pub data_copy_comm: f64,
    /// Additional time once aggregation is enabled.
    pub aggregation: f64,
    /// Additional time once the optimizer is enabled.
    pub optimization: f64,
    /// Synchronization + everything else.
    pub sync_other: f64,
}

impl Breakdown {
    pub fn total(&self) -> f64 {
        self.compute + self.data_copy_comm + self.aggregation + self.optimization + self.sync_other
    }

    /// Fraction of iteration time that is exchange overhead.
    pub fn overhead_share(&self) -> f64 {
        1.0 - self.compute / self.total()
    }
}

/// Compute the progressive breakdown for one (cluster, dnn, gpu) config.
pub fn progressive(cluster: &ClusterConfig, dnn: &Dnn, gpu: Gpu) -> Breakdown {
    let run = |stages: StageFlags| {
        simulate_opts(
            cluster,
            dnn,
            gpu,
            SimOpts {
                stages,
                ..SimOpts::default()
            },
        )
        .iter_time
    };
    let compute = crate::compute::ComputeEngine::new(gpu).batch_time(dnn);
    let t_comm = run(StageFlags {
        data_copy: true,
        aggregation: false,
        optimization: false,
        sync_other: false,
    });
    let t_agg = run(StageFlags {
        data_copy: true,
        aggregation: true,
        optimization: false,
        sync_other: false,
    });
    let t_opt = run(StageFlags {
        data_copy: true,
        aggregation: true,
        optimization: true,
        sync_other: false,
    });
    let t_all = run(StageFlags::all());
    Breakdown {
        dnn: dnn.abbrev,
        compute,
        data_copy_comm: (t_comm - compute).max(0.0),
        aggregation: (t_agg - t_comm).max(0.0),
        optimization: (t_opt - t_agg).max(0.0),
        sync_other: (t_all - t_opt).max(0.0),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{ExchangeConfig, NetConfig, PsConfig, Stack};

    fn mxnet_cluster() -> ClusterConfig {
        ClusterConfig::paper_testbed()
            .with_ps(PsConfig::ColocatedSharded)
            .with_stack(Stack::MxnetTcp)
            .with_exchange(ExchangeConfig::mxnet())
    }

    #[test]
    fn segments_nonnegative_and_total_consistent() {
        let d = Dnn::by_abbrev("RN50").unwrap();
        let b = progressive(&mxnet_cluster(), &d, Gpu::Gtx1080Ti);
        assert!(b.compute > 0.0);
        assert!(b.data_copy_comm >= 0.0);
        assert!(b.aggregation >= 0.0);
        assert!(b.optimization >= 0.0);
        assert!(b.sync_other >= 0.0);
        let full = simulate_opts(
            &mxnet_cluster(),
            &d,
            Gpu::Gtx1080Ti,
            SimOpts::default(),
        );
        assert!((b.total() - full.iter_time).abs() / full.iter_time < 0.05);
    }

    /// Figure 5 vs Figure 14: PHub's breakdown is compute-dominated while
    /// MXNet's is overhead-dominated on the same workload.
    #[test]
    fn phub_breakdown_compute_dominated() {
        let d = Dnn::by_abbrev("RN50").unwrap();
        let mx = progressive(&mxnet_cluster(), &d, Gpu::Gtx1080Ti);
        let ph = progressive(&ClusterConfig::paper_testbed(), &d, Gpu::Gtx1080Ti);
        assert!(ph.overhead_share() < mx.overhead_share(), "{ph:?} vs {mx:?}");
        assert!(ph.overhead_share() < 0.35, "{ph:?}");
    }

    /// On a 56G network the copy overhead of the TCP stack is a large
    /// share for big models (the Figure 5 claim: "link capacity accounts
    /// for a small fraction of the copy and communication overhead").
    #[test]
    fn tcp_copy_overhead_visible_on_fast_network() {
        let d = Dnn::by_abbrev("AN").unwrap();
        let tcp = progressive(&mxnet_cluster(), &d, Gpu::Gtx1080Ti);
        let ib = progressive(
            &mxnet_cluster().with_stack(Stack::MxnetIb),
            &d,
            Gpu::Gtx1080Ti,
        );
        assert!(
            tcp.data_copy_comm > ib.data_copy_comm,
            "tcp {tcp:?} vs ib {ib:?}"
        );
        let _ = NetConfig::infiniband_56g();
    }
}
