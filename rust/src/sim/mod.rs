//! Discrete-event simulation of distributed training (the paper's testbed
//! substitute — see DESIGN.md section 2 for the substitution argument).
//!
//! Public entry points:
//! * [`simulate`] — one (cluster, DNN, GPU) configuration → [`SimResult`].
//! * [`breakdown::progressive`] — the Figure 5 / Figure 14 progressive
//!   overhead decomposition.

pub mod breakdown;
pub mod engine;
pub mod exchange;
pub mod params;
pub mod plan;

pub use exchange::{ExchangeSim, SimOpts, SimResult, StageFlags};

use crate::compute::{ComputeEngine, Gpu};
use crate::config::ClusterConfig;
use crate::dnn::Dnn;

/// Simulate steady-state training of `dnn` on `cluster` with `gpu` workers.
pub fn simulate(cluster: &ClusterConfig, dnn: &Dnn, gpu: Gpu) -> SimResult {
    simulate_opts(cluster, dnn, gpu, SimOpts::default())
}

/// [`simulate`] with explicit options (stage flags, tenants, iterations).
pub fn simulate_opts(
    cluster: &ClusterConfig,
    dnn: &Dnn,
    gpu: Gpu,
    opts: SimOpts,
) -> SimResult {
    ExchangeSim::new(cluster, dnn, ComputeEngine::new(gpu), opts).run()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{NetConfig, PsConfig, Stack};

    /// Faster networks never hurt: 56G >= 10G throughput for every stack.
    #[test]
    fn faster_network_helps_or_ties() {
        let d = Dnn::by_abbrev("AN").unwrap();
        for (ps, stack) in [
            (PsConfig::PBox, Stack::PHub),
            (PsConfig::ColocatedSharded, Stack::MxnetIb),
        ] {
            let slow = ClusterConfig::paper_testbed()
                .with_ps(ps)
                .with_stack(stack)
                .with_net(NetConfig::cloud_10g());
            let fast = slow.clone().with_net(NetConfig::infiniband_56g());
            let rs = simulate(&slow, &d, crate::compute::Gpu::Gtx1080Ti);
            let rf = simulate(&fast, &d, crate::compute::Gpu::Gtx1080Ti);
            assert!(
                rf.throughput >= rs.throughput * 0.999,
                "{ps:?} {stack:?}: {rf:?} vs {rs:?}"
            );
        }
    }

    /// Figure 2's shape: as GPUs speed up, the share of iteration time
    /// spent waiting on the exchange grows.
    #[test]
    fn overhead_share_grows_with_gpu_speed() {
        let d = Dnn::by_abbrev("RN269").unwrap();
        let c = ClusterConfig::paper_testbed()
            .with_ps(PsConfig::ColocatedSharded)
            .with_stack(Stack::MxnetTcp)
            .with_net(NetConfig::cloud_10g())
            .with_exchange(crate::config::ExchangeConfig::mxnet());
        let mut prev_share = -1.0;
        for gpu in [Gpu::Grid520, Gpu::K80, Gpu::Gtx1080Ti] {
            let r = simulate(&c, &d, gpu);
            let share = r.exposed_overhead / r.iter_time;
            assert!(
                share >= prev_share - 0.02,
                "{gpu:?}: share {share} prev {prev_share}"
            );
            prev_share = share;
        }
        // With the fastest GPUs the workload is communication-dominated.
        assert!(prev_share > 0.5, "{prev_share}");
    }
}
