//! Wide aggregation (MXNet's scheme, paper section 3.2.2 & Figure 7).
//!
//! A gang of threads processes *one gradient array at a time*, each thread
//! taking a partition of that array; aggregation cannot start until the
//! key is fully received, optimization runs as a separate gang pass, and
//! every key costs two full-gang synchronizations. PHub's tall scheme
//! (chunk-per-core, no coordination) is implemented in
//! [`crate::coordinator::aggregation`]; the `hotpath` bench races the two.

use std::sync::Barrier;

use crate::coordinator::optimizer::Optimizer;

/// Aggregate `grads` (one slice per worker, equal lengths) into `out` as a
/// mean, using a `threads`-wide gang with barrier synchronization per pass
/// — the lock-step structure that hurts MXNet.
pub fn wide_aggregate_mean(grads: &[&[f32]], out: &mut [f32], threads: usize) {
    let n = grads.len();
    assert!(n > 0);
    let len = out.len();
    assert!(grads.iter().all(|g| g.len() == len));
    let threads = threads.max(1).min(len.max(1));
    let barrier = Barrier::new(threads);
    let inv = 1.0 / n as f32;

    // Partition `out` among threads; each thread sums its slice across all
    // workers (reads are strided across distinct gradient arrays — the
    // locality-hostile access pattern of wide aggregation).
    let chunk = len.div_ceil(threads);
    std::thread::scope(|s| {
        for (t, piece) in out.chunks_mut(chunk).enumerate() {
            let barrier = &barrier;
            let grads = &grads;
            s.spawn(move || {
                let a = t * chunk;
                for (i, o) in piece.iter_mut().enumerate() {
                    let mut acc = 0.0f32;
                    for g in grads.iter() {
                        acc += g[a + i];
                    }
                    *o = acc * inv;
                }
                // Lock-step completion: nobody proceeds until the gang is
                // done (models MXNet's per-key join).
                barrier.wait();
            });
        }
    });
}

/// Wide optimization: a second gang pass applying `opt` over partitions,
/// again barrier-synchronized (no overlap with aggregation).
pub fn wide_optimize(
    opt: &dyn Optimizer,
    params: &mut [f32],
    state: &mut [f32],
    mean_grad: &[f32],
    threads: usize,
) {
    let len = params.len();
    assert_eq!(mean_grad.len(), len);
    let threads = threads.max(1).min(len.max(1));
    let words = opt.state_words();
    let barrier = Barrier::new(threads);
    let chunk = len.div_ceil(threads);
    std::thread::scope(|s| {
        let state_chunks: Vec<&mut [f32]> = if words > 0 {
            state.chunks_mut(chunk * words).collect()
        } else {
            Vec::new()
        };
        let mut state_iter = state_chunks.into_iter();
        for (t, piece) in params.chunks_mut(chunk).enumerate() {
            let a = t * chunk;
            let g = &mean_grad[a..a + piece.len()];
            let st: &mut [f32] = if words > 0 {
                state_iter.next().unwrap()
            } else {
                &mut []
            };
            let barrier = &barrier;
            s.spawn(move || {
                opt.step(piece, st, g);
                barrier.wait();
            });
        }
    });
}

/// Full wide exchange for one key: aggregate then optimize, two gang
/// passes with a join between them.
pub fn wide_exchange(
    opt: &dyn Optimizer,
    grads: &[&[f32]],
    params: &mut [f32],
    state: &mut [f32],
    threads: usize,
) {
    let mut mean = vec![0.0f32; params.len()];
    wide_aggregate_mean(grads, &mut mean, threads);
    wide_optimize(opt, params, state, &mean, threads);
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::optimizer::{NesterovSgd, Sgd};

    fn grads(n: usize, len: usize) -> Vec<Vec<f32>> {
        (0..n)
            .map(|w| (0..len).map(|i| (w * 13 + i) as f32 * 0.01).collect())
            .collect()
    }

    #[test]
    fn wide_mean_correct_any_thread_count() {
        let gs = grads(4, 103);
        let refs: Vec<&[f32]> = gs.iter().map(|g| g.as_slice()).collect();
        let mut expect = vec![0.0f32; 103];
        for g in &gs {
            for (e, x) in expect.iter_mut().zip(g) {
                *e += x / 4.0;
            }
        }
        for threads in [1, 2, 3, 8, 103, 200] {
            let mut out = vec![0.0f32; 103];
            wide_aggregate_mean(&refs, &mut out, threads);
            for (o, e) in out.iter().zip(&expect) {
                assert!((o - e).abs() < 1e-6, "threads={threads}");
            }
        }
    }

    #[test]
    fn wide_exchange_matches_tall_result() {
        // Wide and tall must compute the same math; only the schedule
        // differs. Compare against the single-threaded reference.
        let gs = grads(3, 64);
        let refs: Vec<&[f32]> = gs.iter().map(|g| g.as_slice()).collect();
        let opt = NesterovSgd {
            lr: 0.1,
            momentum: 0.9,
        };
        let mut p_wide: Vec<f32> = (0..64).map(|i| i as f32 * 0.5).collect();
        let mut s_wide = vec![0.0f32; 64];
        wide_exchange(&opt, &refs, &mut p_wide, &mut s_wide, 4);

        let mut p_ref: Vec<f32> = (0..64).map(|i| i as f32 * 0.5).collect();
        let mut s_ref = vec![0.0f32; 64];
        let mut mean = vec![0.0f32; 64];
        for g in &gs {
            for (m, x) in mean.iter_mut().zip(g) {
                *m += x / 3.0;
            }
        }
        use crate::coordinator::optimizer::Optimizer as _;
        opt.step(&mut p_ref, &mut s_ref, &mean);
        for (a, b) in p_wide.iter().zip(&p_ref) {
            assert!((a - b).abs() < 1e-5);
        }
        for (a, b) in s_wide.iter().zip(&s_ref) {
            assert!((a - b).abs() < 1e-5);
        }
    }

    #[test]
    fn stateless_optimizer_wide_path() {
        let gs = grads(2, 32);
        let refs: Vec<&[f32]> = gs.iter().map(|g| g.as_slice()).collect();
        let mut p = vec![1.0f32; 32];
        let mut s = vec![];
        wide_exchange(&Sgd { lr: 1.0 }, &refs, &mut p, &mut s, 3);
        for (i, x) in p.iter().enumerate() {
            let mean = ((i as f32) * 0.01 + (13 + i) as f32 * 0.01) / 2.0;
            assert!((x - (1.0 - mean)).abs() < 1e-6);
        }
    }
}
