//! Baseline PS implementations the paper compares against.
//!
//! * [`wide`] — MXNet-style *wide* aggregation/optimization, executable,
//!   for the section 4.5 tall-vs-wide comparison.
//! * The timing behaviour of the full MXNet / MXNet-IB stacks (TCP copies,
//!   dispatcher, 4 MB chunking) is modeled in [`crate::sim::params`] and
//!   exercised through the simulator.

pub mod wide;
