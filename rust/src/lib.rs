//! PHub: a rack-scale parameter server for distributed DNN training.
//!
//! Reproduction of Luo et al., *Parameter Hub* (SoCC'18). Three-layer
//! architecture:
//!
//! * **L3 (this crate)** — the PHub coordinator: connection management, key
//!   chunking, chunk→core mapping, tall aggregation, optimizers,
//!   multi-tenancy, hierarchical cross-rack reduction; plus the simulated
//!   substrates (network fabric, memory system, GPU compute) used to
//!   regenerate every table and figure in the paper's evaluation.
//! * **L2** — a JAX transformer LM (fwd/bwd) AOT-lowered to HLO text at
//!   build time (`make artifacts`), executed from Rust via PJRT
//!   ([`runtime`]).
//! * **L1** — Pallas kernels for the fused aggregate+optimize hot path.
//!
//! See `DESIGN.md` for the experiment index and substitution table.

pub mod baseline;
pub mod cli;
pub mod collectives;
pub mod compute;
pub mod config;
pub mod coordinator;
pub mod costmodel;
pub mod dnn;
pub mod e2e;
pub mod fabric;
pub mod jsonlite;
pub mod memmodel;
pub mod metrics;
pub mod prop;
pub mod runtime;
pub mod sim;
pub mod trace;
