//! Minimum per-host bandwidth to fully hide communication (paper Figure 4,
//! evaluated for the Table 2 networks).
//!
//! Given model size M (bytes), per-iteration compute time T (s), and N
//! workers, the busiest NIC in each PS configuration must sustain
//! (bidirectionally):
//!
//! * CC  — the colocated central host serves N-1 remote workers both ways:
//!         `2 (N-1) M / T`
//! * CS  — each host's NIC carries worker push+pull of the remote (N-1)/N
//!         of the model plus its shard serving N-1 peers:
//!         `4 (N-1) M / (N T)`
//! * NCC — the dedicated central host exchanges with all N workers:
//!         `2 N M / T`
//! * NCS — each of N dedicated shards serves M/N to N workers:
//!         `2 M / T`
//!
//! (Ratios NCC:CC:CS:NCS = N : N-1 : 2(N-1)/N : 1, matching Table 2's
//! 1408 : 1232 : 308 : 176 for AlexNet exactly.)

use crate::config::PsConfig;
use crate::dnn::Dnn;

/// Required bidirectional bandwidth (bits/s) on the busiest interface.
pub fn required_bps(ps: PsConfig, model_bytes: f64, compute_time: f64, n: usize) -> f64 {
    assert!(n >= 2, "distributed training needs >= 2 workers");
    let m = model_bytes * 8.0; // bits
    let nf = n as f64;
    let per_iter = match ps {
        PsConfig::ColocatedCentralized => 2.0 * (nf - 1.0) * m,
        PsConfig::ColocatedSharded => 4.0 * (nf - 1.0) * m / nf,
        // PBox is an NCC on the PS side; Table 2 reports the NCC number
        // (PBox spreads it over 10 NICs).
        PsConfig::NonColocatedCentralized | PsConfig::PBox => 2.0 * nf * m,
        PsConfig::NonColocatedSharded => 2.0 * m,
    };
    per_iter / compute_time
}

/// Same in Gbit/s.
pub fn required_gbps(ps: PsConfig, dnn: &Dnn, n: usize) -> f64 {
    required_bps(ps, dnn.model_bytes as f64, dnn.time_per_batch, n) / 1e9
}

/// One Table 2 row: (CC, CS, NCC, NCS) Gbps for a network at N workers.
pub fn table2_row(dnn: &Dnn, n: usize) -> [f64; 4] {
    [
        required_gbps(PsConfig::ColocatedCentralized, dnn, n),
        required_gbps(PsConfig::ColocatedSharded, dnn, n),
        required_gbps(PsConfig::NonColocatedCentralized, dnn, n),
        required_gbps(PsConfig::NonColocatedSharded, dnn, n),
    ]
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ratios_match_paper_exactly() {
        let d = Dnn::by_abbrev("AN").unwrap();
        let [cc, cs, ncc, ncs] = table2_row(&d, 8);
        // NCC : CC = N : N-1.
        assert!((ncc / cc - 8.0 / 7.0).abs() < 1e-9);
        // NCS : NCC = 1/N.
        assert!((ncs / ncc - 1.0 / 8.0).abs() < 1e-9);
        // CS : NCC = 2(N-1)/N^2 (paper: 308/1408).
        assert!((cs / ncc - 308.0 / 1408.0).abs() < 1e-9);
    }

    /// Absolute Table 2 values match within the paper's own rounding
    /// (paper used slightly different M/T units; shape and ordering are
    /// what matter — see EXPERIMENTS.md).
    #[test]
    fn table2_magnitudes() {
        let expect: &[(&str, [f64; 4])] = &[
            ("RN269", [122.0, 31.0, 140.0, 17.0]),
            ("I3", [44.0, 11.0, 50.0, 6.0]),
            ("GN", [40.0, 10.0, 46.0, 6.0]),
            ("AN", [1232.0, 308.0, 1408.0, 176.0]),
        ];
        for (abbrev, row) in expect {
            let d = Dnn::by_abbrev(abbrev).unwrap();
            let got = table2_row(&d, 8);
            for (g, e) in got.iter().zip(row) {
                let rel = (g - e).abs() / e;
                assert!(rel < 0.25, "{abbrev}: got {got:?}, paper {row:?}");
            }
        }
    }

    #[test]
    fn demand_exceeds_cloud_bandwidth() {
        // The section 2.3.1 conclusion: every config for every network
        // needs more than the typical 10-25 Gbps cloud VM NIC, except the
        // cheapest config on the most compute-bound networks.
        let d = Dnn::by_abbrev("RN269").unwrap();
        let [_, cs, ncc, _] = table2_row(&d, 8);
        assert!(cs > 25.0);
        assert!(ncc > 25.0);
    }

    #[test]
    fn bandwidth_grows_with_worker_count() {
        let d = Dnn::by_abbrev("RN50").unwrap();
        let mut prev = 0.0;
        for n in [2, 4, 8, 16] {
            let b = required_gbps(PsConfig::NonColocatedCentralized, &d, n);
            assert!(b > prev);
            prev = b;
        }
    }

    #[test]
    fn ncs_is_cheapest_cc_is_most_expensive_colocated() {
        for d in Dnn::zoo() {
            let [cc, cs, ncc, ncs] = table2_row(&d, 8);
            assert!(ncs < cs && cs < cc && cc < ncc, "{}", d.name);
        }
    }
}
