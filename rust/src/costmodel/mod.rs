//! Analytical models from the paper: bandwidth requirements (Figure 4 /
//! Table 2) and the rack-scale deployment cost model (section 4.9 /
//! Table 5).

pub mod bandwidth;
pub mod cost;

pub use bandwidth::{required_gbps, table2_row};
pub use cost::{CostModel, Deployment};
