//! The rack-scale deployment cost model (paper section 4.9, Table 5).
//!
//! Compares throughput-per-dollar of a full-bisection 100 GbE sharded
//! MXNet-IB deployment against 25 GbE PHub deployments at varying ToR
//! oversubscription. Capital costs only; advertised prices from the
//! paper's references.

/// Per-component prices (2018 USD, from the paper's citations).
#[derive(Debug, Clone)]
pub struct Prices {
    /// Worker barebone (Supermicro 1028GQ-TR).
    pub worker: f64,
    /// GPU (GTX 1080 Ti class).
    pub gpu: f64,
    /// PHub barebone (Supermicro 6038R-TXR).
    pub phub: f64,
    /// 100 GbE NIC (ConnectX-4 EN) and 2 m cable.
    pub nic_100g: f64,
    pub cable_100g: f64,
    /// 25 GbE NIC (ConnectX-4 Lx EN) and breakout cable per port.
    pub nic_25g: f64,
    pub cable_25g: f64,
    /// Dual-port 25 GbE NIC per-port cost for the PHub node.
    pub phub_nic_port: f64,
    /// 32-port 100 GbE switch (Arista 7060CX-32S).
    pub switch: f64,
    pub switch_ports: usize,
}

impl Prices {
    pub fn paper() -> Self {
        Prices {
            worker: 4117.0,
            gpu: 699.0,
            phub: 8407.0,
            nic_100g: 795.0,
            cable_100g: 94.0,
            nic_25g: 260.0,
            cable_25g: 31.25,
            phub_nic_port: 162.5,
            switch: 21077.0,
            switch_ports: 32,
        }
    }

    /// Cost of one ToR switch port.
    pub fn switch_port(&self) -> f64 {
        self.switch / self.switch_ports as f64
    }
}

/// One deployment option being priced.
#[derive(Debug, Clone)]
pub struct Deployment {
    pub name: &'static str,
    /// Uses a PHub node (vs colocated sharded PS).
    pub phub: bool,
    /// ToR oversubscription factor (1.0 = full bisection).
    pub oversubscription: f64,
    /// Workers per PHub node (paper: 44 at 1:1, 65 at 2:1, 76 at 3:1).
    pub workers_per_phub: usize,
    pub gpus_per_worker: usize,
}

impl Deployment {
    pub fn baseline_100g() -> Self {
        Deployment {
            name: "100Gb Sharded 1:1",
            phub: false,
            oversubscription: 1.0,
            workers_per_phub: 0,
            gpus_per_worker: 4,
        }
    }

    pub fn phub_25g(oversub: f64) -> Self {
        let (name, k) = match oversub as u32 {
            1 => ("25Gb PHub 1:1", 44),
            2 => ("25Gb PHub 2:1", 65),
            _ => ("25Gb PHub 3:1", 76),
        };
        Deployment {
            name,
            phub: true,
            oversubscription: oversub,
            workers_per_phub: k,
            gpus_per_worker: 4,
        }
    }
}

/// The cost model evaluator.
#[derive(Debug, Clone)]
pub struct CostModel {
    pub prices: Prices,
}

impl CostModel {
    pub fn paper() -> Self {
        CostModel {
            prices: Prices::paper(),
        }
    }

    /// Amortized per-machine network cost: NIC + ToR port + cable, plus
    /// fractional aggregation/core switching under oversubscription F:
    /// `A = (N + S + C) + (4S + 2C)/F` (paper's A with F the
    /// *fraction* of cross-rack provisioning; F = 1/oversubscription).
    fn network_cost(&self, nic: f64, cable: f64, oversub: f64) -> f64 {
        let s = self.prices.switch_port();
        (nic + s + cable) + (4.0 * s + 2.0 * cable) / oversub
    }

    /// Full cost of one worker slot in the deployment (worker + GPUs +
    /// network + amortized PHub share).
    pub fn worker_cost(&self, d: &Deployment) -> f64 {
        let p = &self.prices;
        let gpus = d.gpus_per_worker as f64 * p.gpu;
        if !d.phub {
            p.worker + gpus + self.network_cost(p.nic_100g, p.cable_100g, d.oversubscription)
        } else {
            let a25 = self.network_cost(p.nic_25g, p.cable_25g, d.oversubscription);
            // PHub node: barebone + 20 NIC ports + 20 switch ports/cables.
            let phub_node = p.phub
                + 20.0 * p.phub_nic_port
                + 20.0 * (p.switch_port() + p.cable_25g);
            p.worker + gpus + a25 + phub_node / d.workers_per_phub as f64
        }
    }

    /// Throughput per $1000 given per-worker training throughput
    /// (samples/s) — the Table 5 metric.
    pub fn throughput_per_kilodollar(
        &self,
        d: &Deployment,
        per_worker_throughput: f64,
    ) -> f64 {
        per_worker_throughput / self.worker_cost(d) * 1000.0
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn phub_worker_slot_cheaper_than_100g() {
        let m = CostModel::paper();
        let base = m.worker_cost(&Deployment::baseline_100g());
        let phub = m.worker_cost(&Deployment::phub_25g(2.0));
        assert!(phub < base, "phub {phub} vs baseline {base}");
    }

    #[test]
    fn oversubscription_reduces_cost() {
        let m = CostModel::paper();
        let c1 = m.worker_cost(&Deployment::phub_25g(1.0));
        let c2 = m.worker_cost(&Deployment::phub_25g(2.0));
        let c3 = m.worker_cost(&Deployment::phub_25g(3.0));
        assert!(c1 > c2 && c2 > c3, "{c1} {c2} {c3}");
    }

    /// Table 5's headline: with equal-throughput assumptions scaled from
    /// the paper (PHub worker sustains ~98% of a 100G sharded worker on
    /// ResNet-50 — 10G PHub results + 2% hierarchical overhead vs 40G IB
    /// baseline), the 2:1 PHub deployment gives ~25% better
    /// throughput/$1000.
    #[test]
    fn table5_future_gpu_improvement() {
        let m = CostModel::paper();
        // Paper Table 5 "Future GPUs" column: 46.11 for the baseline.
        // Work back to the implied per-worker throughput, then apply the
        // paper's own PHub/baseline throughput ratio (~0.98).
        let base_cost = m.worker_cost(&Deployment::baseline_100g());
        let tp_base = 46.11 * base_cost / 1000.0;
        let tp_phub = tp_base * 0.98;
        let t5 = |d: &Deployment, tp: f64| m.throughput_per_kilodollar(d, tp);
        let baseline = t5(&Deployment::baseline_100g(), tp_base);
        let phub2 = t5(&Deployment::phub_25g(2.0), tp_phub);
        let gain = phub2 / baseline - 1.0;
        assert!(
            gain > 0.15 && gain < 0.40,
            "expected ~25% improvement, got {:.1}%",
            gain * 100.0
        );
    }

    #[test]
    fn gpu_heavy_workers_dilute_network_savings() {
        // The paper's "Spendy" column: with $8k GPUs the relative gain
        // shrinks. Model: same throughputs, pricier GPUs.
        let mut m = CostModel::paper();
        let tp = 100.0;
        let cheap_gain = m.throughput_per_kilodollar(&Deployment::phub_25g(2.0), tp * 0.98)
            / m.throughput_per_kilodollar(&Deployment::baseline_100g(), tp);
        m.prices.gpu = 8000.0;
        let spendy_gain = m.throughput_per_kilodollar(&Deployment::phub_25g(2.0), tp * 0.98)
            / m.throughput_per_kilodollar(&Deployment::baseline_100g(), tp);
        assert!(spendy_gain < cheap_gain);
        assert!(spendy_gain > 1.0, "PHub still wins: {spendy_gain}");
    }
}
